//! Sec. IV-C reproduction: the migration-strength (alpha) sweep.
//!
//! The paper reports that plain smoothing at alpha = 0.5 *increases* the
//! error over the untransformed baseline on some attention-output and
//! gate-projection layers, and that raising alpha to ~0.7 (o_proj) /
//! ~0.65 (gate_proj) keeps it below the baseline.  This example sweeps
//! alpha per module on the real captured workload (native backend — the
//! PJRT artifacts bake alpha at AOT time) and prints where smoothing
//! crosses the baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example alpha_sweep
//! ```

use anyhow::Result;
use smoothrot::pipeline;
use smoothrot::quant;
use smoothrot::report;
use smoothrot::runtime::Runtime;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string());
    let rt = Runtime::new(&artifacts)?;
    let cfg = rt.manifest().config.clone();
    let workload = pipeline::load_workload(&rt)?;
    let alphas = [0.3, 0.4, 0.5, 0.6, 0.65, 0.7, 0.8, 0.9];

    for module in ["o_proj", "gate_proj"] {
        let module: &'static str = smoothrot::MODULES.into_iter().find(|m| *m == module).unwrap();
        // per-layer untransformed baseline
        let mut base = Vec::with_capacity(cfg.n_layers);
        for layer in 0..cfg.n_layers {
            let (x, w) = workload.pair(&rt, module, layer);
            base.push(quant::quant_error(&x, &w, cfg.bits));
        }
        let base_total: f64 = base.iter().sum();

        let sweep = pipeline::alpha_sweep(&rt, &workload, module, &alphas, cfg.bits, 0)?;
        println!("\n# {module}: smoothing error vs alpha (baseline total {base_total:.3e})");
        let labels: Vec<String> = sweep.iter().map(|(a, _)| format!("alpha={a:.2}")).collect();
        let totals: Vec<f64> = sweep.iter().map(|(_, e)| e.iter().sum()).collect();
        println!("{}", report::ascii_chart("total smooth error", &labels, &totals, 40));

        // per-alpha: how many layers does smoothing beat the baseline on?
        for ((alpha, errs), total) in sweep.iter().zip(&totals) {
            let wins = errs.iter().zip(&base).filter(|(s, b)| s < b).count();
            let marker = if *total < base_total { "below baseline" } else { "ABOVE baseline" };
            println!(
                "  alpha {alpha:.2}: total {total:.3e} ({marker}), beats baseline on {wins}/{} layers",
                cfg.n_layers
            );
        }
        let best = sweep
            .iter()
            .zip(&totals)
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|((a, _), _)| *a)
            .unwrap();
        println!(
            "  -> best alpha for {module}: {best:.2} (paper: ~{} for this module kind)",
            if module == "o_proj" { "0.7" } else { "0.65" }
        );
    }
    Ok(())
}
