//! End-to-end driver — the repo's headline validation run.
//!
//! Exercises every layer of the stack on the full workload:
//! L2 capture artifact (32-layer SynLlama forward, PJRT) → L3 coordinator
//! (128 analyze jobs through the bounded-queue worker pool) → the fused
//! L1 qerror kernel inside the analyze artifacts → report layer.
//!
//! Prints the paper's Figs 3–4 summaries and checks the qualitative
//! claims; the output is recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example full_pipeline
//! ```

use anyhow::{bail, Result};
use smoothrot::coordinator::PoolConfig;
use smoothrot::pipeline::{self, Backend};
use smoothrot::report;
use smoothrot::runtime::Runtime;
use smoothrot::transforms::Mode;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string());
    let pool = PoolConfig { workers: 2, queue_cap: 64, threads: 1 };

    let t0 = std::time::Instant::now();
    let run = pipeline::run_full_experiment(&artifacts, pool, Backend::Pjrt)?;
    let wall = t0.elapsed();

    let rt = Runtime::new(&artifacts)?;
    let cfg = rt.manifest().config.clone();
    println!(
        "full pipeline: {} analyze jobs in {wall:?} ({} workers, {:.1}% coordination overhead)\n",
        run.metrics.jobs,
        pool.workers,
        100.0 * run.metrics.overhead_fraction(pool.workers)
    );

    // ---- Fig 3: layer-wise statistics ---------------------------------
    println!("{}", report::fig3_report(&run.grid));

    // ---- §IV-B: the correlation headline -------------------------------
    let (corr, text) = report::correlation_report(&run.grid, &cfg.massive_layers, cfg.tail_layer);
    println!("{text}");

    // ---- Fig 4: down_proj under all transforms ------------------------
    println!("{}", report::fig4_report(&run.grid));
    println!(
        "down_proj massive layers:\n{}",
        report::mode_layer_table(&run.grid, "down_proj", &cfg.massive_layers)
    );

    // ---- qualitative claims check (the paper's findings) --------------
    let mut claims: Vec<(String, bool)> = Vec::new();
    claims.push((format!("corr > 0.97 (got {corr:.4})"), corr > 0.97));

    for &l in &cfg.massive_layers {
        let o = run.grid.get("down_proj", l).unwrap();
        claims.push((
            format!(
                "down_proj {l}: rotation worse than none ({:.2e} > {:.2e})",
                o.errors[2], o.errors[0]
            ),
            o.errors[2] > o.errors[0],
        ));
        claims.push((
            format!("down_proj {l}: smooth_rotate best ({:.2e})", o.errors[3]),
            (0..3).all(|i| o.errors[3] < o.errors[i]),
        ));
    }

    // rotation generally beats smoothing; smooth_rotate lowest in most cases
    let mut rot_wins = 0usize;
    let mut sr_best = 0usize;
    let mut cells = 0usize;
    let mut sr_adiff_best = 0usize;
    for module in smoothrot::MODULES {
        for l in 0..cfg.n_layers {
            let o = run.grid.get(module, l).unwrap();
            cells += 1;
            if o.errors[Mode::Rotate.index()] < o.errors[Mode::Smooth.index()] {
                rot_wins += 1;
            }
            if (0..3).all(|i| o.errors[3] <= o.errors[i]) {
                sr_best += 1;
            }
            if (0..3).all(|i| o.act_difficulty[3] <= o.act_difficulty[i]) {
                sr_adiff_best += 1;
            }
        }
    }
    claims.push((
        format!("rotation beats smoothing in most cells ({rot_wins}/{cells})"),
        rot_wins * 2 > cells,
    ));
    claims.push((
        format!("smooth_rotate lowest error in most cells ({sr_best}/{cells})"),
        sr_best * 2 > cells,
    ));
    claims.push((
        format!("smooth_rotate lowest act difficulty in most cells ({sr_adiff_best}/{cells})"),
        sr_adiff_best * 2 > cells,
    ));

    // weight difficulty: smoothing raises it, rotation lowers it (Sec. IV-C/D)
    let mut smooth_raises = 0usize;
    let mut rotate_lowers = 0usize;
    for module in smoothrot::MODULES {
        for l in 0..cfg.n_layers {
            let o = run.grid.get(module, l).unwrap();
            if o.w_difficulty[1] > o.w_difficulty[0] {
                smooth_raises += 1;
            }
            if o.w_difficulty[2] < o.w_difficulty[0] {
                rotate_lowers += 1;
            }
        }
    }
    claims.push((
        format!("smoothing raises weight difficulty ({smooth_raises}/{cells})"),
        smooth_raises * 2 > cells,
    ));
    claims.push((
        format!("rotation lowers weight difficulty ({rotate_lowers}/{cells})"),
        rotate_lowers * 2 > cells,
    ));

    println!("\n# claim check");
    let mut failed = 0;
    for (desc, ok) in &claims {
        println!("  [{}] {desc}", if *ok { "PASS" } else { "FAIL" });
        if !ok {
            failed += 1;
        }
    }
    if failed > 0 {
        bail!("{failed} of {} paper claims failed", claims.len());
    }
    println!("\nall {} paper claims reproduced", claims.len());
    Ok(())
}
