//! Network serving demo: the HTTP/1.1 front-end plus the open-loop
//! load generator, end to end over loopback, artifact-free.
//!
//! Starts the serving core behind [`smoothrot::serve::net::NetServer`]
//! on an ephemeral port, drives it with [`smoothrot::loadgen`] through
//! a warm → steady → burst phase schedule (Poisson arrivals, skewed
//! tenants), prints the client-side latency percentiles and error
//! taxonomy, then proves the wire tier's two contracts:
//!
//! * **bit identity** — every OK response's `errors_bits` replayed
//!   through an in-process executor over the same job builder matches
//!   exactly (the network adds transport, not arithmetic);
//! * **graceful drain** — `POST /admin/drain` semantics via
//!   [`NetServer::drain`]: zero in-flight responses lost, and the
//!   core's end-of-run metrics account for every admitted job.
//!
//! ```bash
//! cargo run --release --example net_serve -- [steady_rps] [burst_rps]
//! ```

use anyhow::{bail, Result};
use smoothrot::loadgen::{self, LoadgenConfig, Phase};
use smoothrot::serve::net::{synth_job_builder, CoreServer, NetConfig, NetServer};
use smoothrot::serve::{NativeBatchExecutor, ServeConfig};
use smoothrot::telemetry::Telemetry;
use std::time::Duration;

const STREAM_SEED: u64 = 2025;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steady_rps: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(40.0);
    let burst_rps: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4.0 * 40.0);

    // the serving core: bounded queue + load shedding, so the burst
    // phase degrades to fast 429s instead of unbounded queue growth
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        queue_depth: 64,
        shed_queued: 48,
        ..ServeConfig::default()
    };
    let telemetry = Telemetry::new();
    let (core, rx) = CoreServer::start_with_telemetry(
        cfg,
        None,
        Some(std::sync::Arc::clone(&telemetry)),
        |_| Ok(NativeBatchExecutor::new()),
    );
    let builder = synth_job_builder(STREAM_SEED);
    let server = NetServer::start(
        NetConfig::default(),
        core,
        rx,
        Some(telemetry),
        builder.clone(),
    )
    .map_err(anyhow::Error::msg)?;
    println!("serving on http://{} (stream seed {STREAM_SEED})\n", server.addr());

    // open-loop load: Poisson arrivals, tenant skew, a 2s profile that
    // ends in a deliberate overload burst
    let lg = LoadgenConfig {
        target: server.addr().to_string(),
        phases: vec![
            Phase { name: "warm".into(), duration_ms: 400, rps: steady_rps / 2.0 },
            Phase { name: "steady".into(), duration_ms: 1_200, rps: steady_rps },
            Phase { name: "burst".into(), duration_ms: 400, rps: burst_rps },
        ],
        tenants: 4,
        layers: 4,
        rows: 8,
        seed: 1,
        concurrency: 8,
        timeout: Duration::from_secs(10),
    };
    println!(
        "loadgen: warm {:.0} rps / steady {:.0} rps / burst {:.0} rps ...",
        steady_rps / 2.0,
        steady_rps,
        burst_rps
    );
    let mut report = loadgen::run(&lg).map_err(anyhow::Error::msg)?;

    println!("\nclient-side latency (all OK responses):");
    println!(
        "  p50 {:>8.2} ms   p95 {:>8.2} ms   p99 {:>8.2} ms",
        report.percentiles.p50 / 1e3,
        report.percentiles.p95 / 1e3,
        report.percentiles.p99 / 1e3,
    );
    println!("taxonomy ({} sent):", report.sent);
    for (name, count) in &report.taxonomy {
        if *count > 0 {
            println!("  {name:<12} {count}");
        }
    }
    if let Some(secs) = report.min_retry_after_secs {
        println!("  (shed responses carried Retry-After >= {secs}s)");
    }

    // wire-tier bit identity: replay every OK sample in process
    let mut exec = NativeBatchExecutor::new();
    let mismatches = report.verify(&builder, |job| exec.run(job));
    println!(
        "\nbit-identity verify: {} samples, {mismatches} mismatches",
        report.ok_samples.len()
    );
    if mismatches > 0 {
        bail!("wire responses diverged from the in-process executor");
    }

    // graceful drain: in-flight connections finish, then the core's
    // metrics must balance the client-side ledger
    server.drain();
    let m = server.wait().map_err(anyhow::Error::msg)?;
    let ok = report.taxonomy.get("ok").copied().unwrap_or(0);
    println!(
        "\ndrained: core completed {} (errors {}, shed {}, drains {}); client ok {}",
        m.completed, m.errors, m.shed, m.drains, ok
    );
    if m.errors != 0 {
        bail!("core reported {} executor errors", m.errors);
    }
    if m.completed < ok {
        bail!("core completed {} < client-observed ok {}", m.completed, ok);
    }
    println!("net_serve demo passed");
    Ok(())
}
