//! Quickstart: load the AOT artifacts, analyze one module, print the
//! effect of each transform (paper Eq. 2 error + difficulty metric).
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example quickstart
//! ```

use anyhow::Result;
use smoothrot::pipeline;
use smoothrot::runtime::Runtime;
use smoothrot::transforms::Mode;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string());

    // 1. open the PJRT runtime over the artifact manifest
    let rt = Runtime::new(&artifacts)?;
    let cfg = rt.manifest().config.clone();
    println!(
        "SynLlama: {} layers, d_model {}, d_ffn {}, {}-bit symmetric RTN, alpha {}",
        cfg.n_layers, cfg.d_model, cfg.d_ffn, cfg.bits, cfg.alpha
    );

    // 2. run the capture artifact (full 32-layer forward) + load weights
    let workload = pipeline::load_workload(&rt)?;

    // 3. analyze one attention module mid-stack (peak of the k_proj trend)
    let (x, w) = workload.pair(&rt, "k_proj", 16);
    let out = rt.analyze(&x, &w)?;
    println!("\nk_proj layer 16 (systematic outliers):");
    for mode in Mode::ALL {
        let (err, adiff, wdiff, amax) = out.for_mode(mode);
        println!(
            "  {:>14}: error {err:>12.3e}  act_difficulty {adiff:>10.3e}  w_difficulty {wdiff:>10.3e}  max|X| {amax:>9.2}",
            mode.name()
        );
    }

    // 4. and the massive-outlier showcase: down_proj at the first massive layer
    let layer = cfg.massive_layers.first().copied().unwrap_or(1);
    let (x, w) = workload.pair(&rt, "down_proj", layer);
    let out = rt.analyze(&x, &w)?;
    println!("\ndown_proj layer {layer} (MASSIVE outliers — the paper's core case):");
    for mode in Mode::ALL {
        let (err, adiff, _, amax) = out.for_mode(mode);
        println!(
            "  {:>14}: error {err:>12.3e}  act_difficulty {adiff:>10.3e}  max|X| {amax:>9.1}",
            mode.name()
        );
    }
    let rot = out.errors[Mode::Rotate.index()];
    let none = out.errors[Mode::None.index()];
    let sr = out.errors[Mode::SmoothRotate.index()];
    println!(
        "\npaper Sec. IV-D/E: rotation {} the untransformed model here (rot/none = {:.2}),\n\
         while smooth-rotation cuts the error to {:.1}% of rotation alone.",
        if rot > none { "UNDERPERFORMS" } else { "beats" },
        rot / none,
        100.0 * sr / rot
    );
    Ok(())
}
