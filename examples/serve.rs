//! Serving demo: the batched multi-tenant serving core, artifact-free.
//!
//! Three tenants stream analysis requests (random module × layer asks
//! over paper-shaped synthetic activations) at skewed rates into the
//! serving core; compatible requests are coalesced into batches, every
//! tenant gets a fair share of dispatch slots, and results stream back
//! with per-request latency.  A second pass with batching disabled
//! (`max_batch = 1`) quantifies what coalescing buys, and the closing
//! passes demo "calibrate once, serve many": first plan-driven f32,
//! then `--exec int8`-style real integer execution (weights
//! pre-quantized once per layer, per-request work = transform +
//! quantize activation rows + i32-accumulated integer GEMM) with the
//! f32-vs-int8 throughput delta printed.  A final pass re-serves the
//! int8 stream across 2 layer-sharded runners (shared registry, work
//! stealing) and asserts the per-job outputs are bit-identical to the
//! single-server pass.
//!
//! ```bash
//! cargo run --release --example serve -- [requests] [workers] [max_batch]
//! ```
//!
//! Uses the native executor, so it runs without AOT artifacts; point the
//! `smoothrot serve` subcommand at `--backend pjrt` for the AOT path.

use anyhow::{anyhow, Result};
use smoothrot::coordinator::Job;
use smoothrot::serve::{
    serve_all, synthetic_requests, NativeBatchExecutor, Response, ServeConfig, ServeMetrics,
    TenantId,
};
use smoothrot::transforms::Mode;

fn run(cfg: ServeConfig, requests: Vec<(TenantId, Job)>) -> Result<(Vec<Response>, ServeMetrics)> {
    serve_all(cfg, requests, |_| Ok(NativeBatchExecutor::new())).map_err(|e| anyhow!(e.to_string()))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(48);
    let workers: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2);
    let max_batch: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(8);
    let rows = 24;

    let cfg = ServeConfig { workers, max_batch, queue_depth: 32, ..ServeConfig::default() };
    println!(
        "serving {n_requests} requests from 3 tenants ({workers} workers, max-batch {max_batch}, \
         queue-depth {}, native executors)...\n",
        cfg.queue_depth
    );

    let (responses, metrics) = run(cfg, synthetic_requests(n_requests, 3, rows, 32, 1))?;

    println!("first responses off the stream:");
    for r in responses.iter().take(5) {
        println!(
            "  <- req {:>3} tenant {} {:>9} layer {:<2} batch {:>2} (size {}) {:>7.2} ms",
            r.id,
            r.tenant,
            r.module,
            r.layer,
            r.batch_id,
            r.batch_size,
            r.total_micros as f64 / 1e3
        );
    }
    println!("\n{}", metrics.summary());

    // Every tenant must have been served — the fairness claim in one line.
    assert!(metrics.per_tenant.len() >= 2, "expected at least 2 concurrent tenants");
    for (tenant, t) in &metrics.per_tenant {
        assert_eq!(t.submitted, t.completed, "tenant {tenant} lost requests");
    }

    // What did the advisor decide?
    let mut recommend = std::collections::BTreeMap::<&str, usize>::new();
    for r in &responses {
        if let Ok(out) = &r.out {
            let best = Mode::ALL
                .into_iter()
                .min_by(|a, b| out.errors[a.index()].partial_cmp(&out.errors[b.index()]).unwrap())
                .unwrap();
            *recommend.entry(best.name()).or_default() += 1;
        }
    }
    println!("per-request recommended transform (argmin error):");
    for (mode, count) in &recommend {
        println!("  {mode:>14}: {count} requests");
    }

    // Same stream with batching disabled: what does coalescing buy?
    let unbatched_cfg = ServeConfig { max_batch: 1, ..cfg };
    let (_, unbatched) = run(unbatched_cfg, synthetic_requests(n_requests, 3, rows, 32, 1))?;
    println!(
        "\nbatched (max-batch {max_batch}): {:.1} req/s, mean batch {:.2} | \
         unbatched (max-batch 1): {:.1} req/s",
        metrics.throughput(),
        metrics.mean_batch(),
        unbatched.throughput(),
    );

    // Calibrate once, serve many: the same stream again, but each
    // request now runs only its pre-planned transform (zero per-request
    // transform search) instead of the four-mode analyze.
    use smoothrot::calib::registry::PlanRegistry;
    use smoothrot::pipeline::{calibrate_synthetic, CalibrateConfig};
    use std::sync::Arc;
    let calib = calibrate_synthetic(&CalibrateConfig {
        layers: 32,
        rows_per_batch: rows,
        ..CalibrateConfig::default()
    })?;
    let registry = Arc::new(PlanRegistry::from_plan(&calib.plan).map_err(anyhow::Error::msg)?);
    let reg = Arc::clone(&registry);
    let (_, planned) = serve_all(cfg, synthetic_requests(n_requests, 3, rows, 32, 1), move |_| {
        Ok(NativeBatchExecutor::with_plan(Arc::clone(&reg), 1))
    })
    .map_err(|e| anyhow!(e.to_string()))?;
    let (hits, misses) = registry.stats();
    println!(
        "plan-driven: {:.1} req/s vs analyze-per-request {:.1} req/s ({hits} planned / \
         {misses} fallback)",
        planned.throughput(),
        metrics.throughput(),
    );
    assert_eq!(misses, 0, "every request must be covered by the calibrated plan");

    // ...and once more in REAL integer arithmetic: pre-quantize the
    // planned weights once per layer (GEMM-ready i8 codes + per-channel
    // scales; seed 1 is the serving stream's fixed weight seed), then
    // each request only transforms + quantizes its activation rows
    // before the i32-accumulated integer GEMM.
    use smoothrot::serve::ExecMode;
    let loaded = registry
        .set_weight_provider(Box::new(|module, layer| {
            smoothrot::synth::layer_weight(module, layer, 1)
        }))
        .map_err(anyhow::Error::msg)?;
    // The int8 pass runs under telemetry: workers install stage-timer
    // and difficulty sinks around every dispatch, so the pass comes
    // back with per-stage latency histograms and live per-(module,
    // layer) difficulty — the observability the `smoothrot serve
    // --metrics-file` flag exports as JSON + Prometheus.
    use smoothrot::telemetry::{self, Telemetry};
    let tele = Telemetry::new();
    tele.add_collector(telemetry::plan_registry_collector(&registry));
    let reg = Arc::clone(&registry);
    let (int8_responses, int8) = smoothrot::serve::serve_all_with_telemetry(
        cfg,
        Some(Arc::clone(&tele)),
        synthetic_requests(n_requests, 3, rows, 32, 1),
        move |_| Ok(NativeBatchExecutor::with_plan_exec(Arc::clone(&reg), 1, ExecMode::Int8)),
    )
    .map_err(|e| anyhow!(e.to_string()))?;
    println!(
        "int8 plan-driven: {:.1} req/s vs f32 plan-driven {:.1} req/s ({:+.0}% throughput, \
         {loaded} weights pre-quantized once, {} requests batch-fused into stacked GEMMs)",
        int8.throughput(),
        planned.throughput(),
        100.0 * (int8.throughput() / planned.throughput().max(1e-9) - 1.0),
        registry.batch_fused(),
    );
    assert!(loaded > 0, "int8 preload must cover the calibrated plan");
    let (executed, degraded) = registry.int8_stats();
    assert!(
        executed > 0 && degraded == 0,
        "int8 pass degraded to f32: {executed} executed / {degraded} degraded"
    );
    assert!(
        registry.batch_fused() > 0,
        "int8 pass silently fell back to per-job execution (zero batch-fused requests)"
    );

    // What telemetry saw: fill the end-of-run summary into the same
    // registry, snapshot ONCE, and read everything off that snapshot —
    // per-stage timings, live difficulty vs the calibration plan, and
    // the Prometheus text a scraper would ingest.
    int8.fill(&tele);
    let snap = tele.snapshot();
    println!("\ntelemetry (int8 pass):");
    for stage in telemetry::Stage::ALL {
        let h = snap.histogram(stage.metric_name()).expect("stage histogram");
        println!(
            "  {:>35}: {:>4} obs, {:>9.3} ms total",
            stage.metric_name(),
            h.count,
            h.sum * 1e3
        );
    }
    for row in snap.difficulty.iter().take(3) {
        println!(
            "  difficulty {}/{}: live mean {:.3} vs plan {:.3} (drift {:+.3}, exec err mean \
             {:.3e}, {} samples)",
            row.module,
            row.layer,
            row.cell.mean,
            row.cell.plan,
            row.cell.drift(),
            row.cell.err_mean,
            row.cell.count
        );
    }
    assert!(!snap.difficulty.is_empty(), "int8 serving must feed the difficulty tracker");
    assert!(
        snap.histogram("smoothrot_igemm_seconds").expect("igemm histogram").count > 0,
        "integer GEMMs ran but the igemm stage timer saw none"
    );
    assert_eq!(
        snap.counter("smoothrot_int8_executed_total", &[]),
        Some(executed),
        "snapshot and registry disagree on int8 executions"
    );
    let prom = snap.to_prometheus();
    let igemm_line = prom
        .lines()
        .find(|l| l.starts_with("smoothrot_igemm_seconds_count"))
        .expect("igemm count in Prometheus text");
    println!("  prometheus: {} samples, e.g. `{igemm_line}`", prom.lines().count());

    // Finally, sharded: the same int8 stream split across 2 runners
    // that each OWN their layers (runner = layer % 2), sharing the one
    // plan registry, with idle runners stealing a busy peer's surplus.
    // Sharding changes placement, never math — every per-job output
    // must match the single-server int8 pass bit for bit.
    use smoothrot::serve::shard::{serve_all_sharded, ShardBy, ShardConfig};
    let reg = Arc::clone(&registry);
    let scfg = ShardConfig { runners: 2, shard_by: ShardBy::Layer, stealing: true, base: cfg };
    let (sharded_responses, sharded) =
        serve_all_sharded(scfg, synthetic_requests(n_requests, 3, rows, 32, 1), move |_| {
            Ok(NativeBatchExecutor::with_plan_exec(Arc::clone(&reg), 1, ExecMode::Int8))
        })
        .map_err(|e| anyhow!(e.to_string()))?;
    println!(
        "sharded int8 (2 runners by layer): {:.1} req/s vs single-server {:.1} req/s",
        sharded.throughput(),
        int8.throughput(),
    );
    for (i, &b) in sharded.per_worker_batches.iter().enumerate() {
        println!(
            "  runner {i}: routed {} batches {b} steals {}",
            sharded.per_worker_routed[i], sharded.per_worker_steals[i]
        );
    }
    let by_id = |rs: &[Response]| {
        rs.iter()
            .map(|r| (r.id, r.out.clone().expect("request errored")))
            .collect::<std::collections::BTreeMap<_, _>>()
    };
    assert_eq!(
        by_id(&sharded_responses),
        by_id(&int8_responses),
        "sharded per-job outputs diverged from the single-server int8 pass"
    );
    Ok(())
}
