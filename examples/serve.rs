//! Serving demo: the coordinator as an analysis service.
//!
//! Streams a synthetic request mix (random module × layer analysis asks,
//! mimicking a quantization-advisor service that decides per-layer which
//! transform to deploy) through the bounded-queue worker pool with PJRT
//! executors, then prints throughput, latency percentiles and the
//! per-layer transform recommendation the service would return.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example serve -- 128 2
//! ```

use anyhow::{anyhow, Result};
use smoothrot::coordinator::{run_jobs, Job, PoolConfig};
use smoothrot::pipeline::{self, PjrtExecutor};
use smoothrot::rng::Rng;
use smoothrot::runtime::Runtime;
use smoothrot::transforms::Mode;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let workers: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2);
    let artifacts = args.get(3).cloned().unwrap_or_else(|| "artifacts".to_string());

    let rt = Runtime::new(&artifacts)?;
    let cfg = rt.manifest().config.clone();
    let workload = pipeline::load_workload(&rt)?;

    let mut rng = Rng::new(2024);
    let jobs: Vec<Job> = (0..n_requests)
        .map(|i| {
            let module = smoothrot::MODULES[rng.below(4)];
            let layer = rng.below(cfg.n_layers);
            let (x, w) = workload.pair(&rt, module, layer);
            Job { id: i as u64, layer, module, x, w, alpha: cfg.alpha as f32, bits: cfg.bits }
        })
        .collect();

    println!("serving {n_requests} requests ({workers} workers, PJRT executors)...");
    let pool = PoolConfig { workers, queue_cap: 16 };
    let dir = artifacts.clone();
    let t0 = std::time::Instant::now();
    let (results, metrics) =
        run_jobs(jobs, pool, move |_| PjrtExecutor::new(dir.clone())).map_err(|e| anyhow!(e))?;
    let wall = t0.elapsed();

    let mut lat: Vec<f64> = results.iter().map(|r| r.micros as f64 / 1000.0).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lat[((lat.len() as f64 * p) as usize).min(lat.len() - 1)];
    let exec_time: f64 = results.iter().map(|r| r.micros as f64 / 1e6).sum::<f64>() / workers as f64;
    println!(
        "\nthroughput {:.1} req/s wall ({:.1} req/s steady-state, excluding the one-time\n\
         per-worker executable compile of {:.1}s) | latency ms p50 {:.2} p95 {:.2} p99 {:.2}\n\
         | max queue depth {}",
        n_requests as f64 / wall.as_secs_f64(),
        n_requests as f64 / exec_time,
        wall.as_secs_f64() - exec_time,
        pct(0.50),
        pct(0.95),
        pct(0.99),
        metrics.max_queue_depth,
    );

    // The "advisor" response: recommended transform per request = argmin error.
    let mut recommend = std::collections::BTreeMap::<&str, usize>::new();
    for r in &results {
        let best = Mode::ALL
            .into_iter()
            .min_by(|a, b| {
                r.out.errors[a.index()].partial_cmp(&r.out.errors[b.index()]).unwrap()
            })
            .unwrap();
        *recommend.entry(best.name()).or_default() += 1;
    }
    println!("\nper-request recommended transform (argmin error):");
    for (mode, count) in recommend {
        println!("  {mode:>14}: {count} requests");
    }
    println!(
        "\n(the paper's recommendation — smooth-rotation for down_proj massive-outlier layers,\n\
     rotation elsewhere — emerges from the request-level decisions above)"
    );
    Ok(())
}
