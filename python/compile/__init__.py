"""Build-time python package: L1 Pallas kernels + L2 JAX model + AOT.

Nothing in here runs at serving time — ``aot.py`` lowers everything to
HLO text artifacts once, and the rust coordinator executes those via the
PJRT C API (see DESIGN.md §3).
"""
