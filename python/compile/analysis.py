"""Layer-2 analysis graph: per-module quantization statistics.

``analyze_module`` is the computation behind the paper's whole evaluation
(Figs. 3 and 4): for one linear module's input X (n, c_in) and weight W
(c_in, c_out) it produces, for each of the four transform modes,

* the layer-wise quantization error (Eq. 2, via the fused L1 kernel),
* the activation quantization difficulty (std of channel magnitudes),
* the weight quantization difficulty,
* the activation absolute maximum (massive-outlier indicator).

One HLO artifact is lowered per (c_in, c_out) shape; the rust coordinator
feeds every (layer, module) tensor pair through the right artifact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import transforms
from .kernels import qerror, ref

__all__ = ["module_stats", "analyze_module", "N_MODES"]

N_MODES = len(transforms.MODES)


def module_stats(x: jax.Array, w: jax.Array, bits: int = 4):
    """(error, act_difficulty, w_difficulty, act_absmax) for one (X, W)."""
    err = qerror.quant_error(x, w, bits)
    act_diff = ref.quant_difficulty(x, axis=0)
    w_diff = ref.quant_difficulty(w, axis=1)
    act_max = jnp.max(jnp.abs(x))
    return err, act_diff, w_diff, act_max


def analyze_module(x: jax.Array, w: jax.Array, bits: int = 4, alpha: float = 0.5):
    """Stack stats over all transform modes.

    Returns a 4-tuple of f32[N_MODES] arrays ordered like
    ``transforms.MODES`` = (none, smooth, rotate, smooth_rotate).
    """
    errs, adiffs, wdiffs, amaxs = [], [], [], []
    for mode in transforms.MODES:
        xh, wh = transforms.apply_transform(mode, x, w, alpha)
        e, ad, wd, am = module_stats(xh, wh, bits)
        errs.append(e)
        adiffs.append(ad)
        wdiffs.append(wd)
        amaxs.append(am)
    return (
        jnp.stack(errs),
        jnp.stack(adiffs),
        jnp.stack(wdiffs),
        jnp.stack(amaxs),
    )
