"""AOT lowering driver: python runs ONCE, rust owns the request path.

``python -m compile.aot --out-dir ../artifacts`` emits:

* ``capture.hlo.txt``            — full SynLlama forward + activation capture
* ``analyze_{cin}x{cout}.hlo.txt``   — fused per-module stats over all 4
                                      transform modes (the hot path)
* ``transform_{mode}_{cin}x{cout}.hlo.txt`` — standalone (X,W)->(Xh,Wh)
* ``qdq_token_{n}x{c}.hlo.txt``  — standalone RTN quantize-dequantize
* ``params/*.bin`` + ``tokens.bin``  — raw little-endian tensors the rust
                                      runtime feeds into ``capture``
* ``manifest.json``              — the python->rust contract: every
                                      artifact, input/output shape, file
* ``golden.json``                — reference numbers for rust integration
                                      tests (PJRT output must match)

Interchange is HLO **text**: jax >= 0.5 serializes HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import analysis, model, transforms
from .config import MODULES, SynLlamaConfig, default_config
from .kernels import quant

# Weight array per recorded module kind (input of k_proj is multiplied by
# wk, etc.).
MODULE_WEIGHTS = {"k_proj": "wk", "o_proj": "wo", "gate_proj": "wg", "down_proj": "wd"}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True — the default elides big literals as
    # `constant({...})`, which would silently zero the baked Hadamard
    # matrices after the text round-trip.
    text = comp.as_hlo_text(True)
    assert "({...})" not in text, "HLO text still contains elided constants"
    return text


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _write(path: str, text: str) -> dict:
    with open(path, "w") as f:
        f.write(text)
    digest = hashlib.sha256(text.encode()).hexdigest()
    return {"bytes": len(text), "sha256": digest}


def _dump_bin(path: str, arr: np.ndarray) -> dict:
    arr = np.ascontiguousarray(arr)
    arr.tofile(path)
    return {
        "shape": list(arr.shape),
        "dtype": str(arr.dtype),
        "bytes": arr.nbytes,
    }


def lower_capture(cfg: SynLlamaConfig, out_dir: str, manifest: dict) -> None:
    specs = model.param_specs(cfg)
    tok_spec = _spec((cfg.seq_len,), jnp.int32)

    def capture_fn(*args):
        params = dict(zip(model.PARAM_ORDER, args[:-1]))
        return model.forward_capture(params, args[-1], cfg.n_heads)

    lowered = jax.jit(capture_fn).lower(*[specs[k] for k in model.PARAM_ORDER], tok_spec)
    info = _write(os.path.join(out_dir, "capture.hlo.txt"), to_hlo_text(lowered))
    L, n, d, f = cfg.n_layers, cfg.seq_len, cfg.d_model, cfg.d_ffn
    manifest["artifacts"]["capture"] = {
        "path": "capture.hlo.txt",
        **info,
        "inputs": [
            {"name": k, "shape": list(specs[k].shape), "dtype": "f32", "file": f"params/{k}.bin"}
            for k in model.PARAM_ORDER
        ]
        + [{"name": "tokens", "shape": [n], "dtype": "i32", "file": "tokens.bin"}],
        "outputs": [
            {"name": "attn_in", "shape": [L, n, d]},
            {"name": "o_in", "shape": [L, n, d]},
            {"name": "ffn_in", "shape": [L, n, d]},
            {"name": "down_in", "shape": [L, n, f]},
        ],
    }


def lower_analyze(cfg: SynLlamaConfig, out_dir: str, manifest: dict) -> None:
    n = cfg.seq_len
    for c_in, c_out in cfg.analyze_shapes():
        fn = functools.partial(analysis.analyze_module, bits=cfg.bits, alpha=cfg.alpha)
        lowered = jax.jit(fn).lower(_spec((n, c_in)), _spec((c_in, c_out)))
        name = f"analyze_{c_in}x{c_out}"
        info = _write(os.path.join(out_dir, f"{name}.hlo.txt"), to_hlo_text(lowered))
        manifest["artifacts"][name] = {
            "path": f"{name}.hlo.txt",
            **info,
            "inputs": [
                {"name": "x", "shape": [n, c_in], "dtype": "f32"},
                {"name": "w", "shape": [c_in, c_out], "dtype": "f32"},
            ],
            "outputs": [
                {"name": "errors", "shape": [analysis.N_MODES]},
                {"name": "act_difficulty", "shape": [analysis.N_MODES]},
                {"name": "w_difficulty", "shape": [analysis.N_MODES]},
                {"name": "act_absmax", "shape": [analysis.N_MODES]},
            ],
        }


def lower_transforms(cfg: SynLlamaConfig, out_dir: str, manifest: dict) -> None:
    n = cfg.seq_len
    for c_in, c_out in cfg.analyze_shapes():
        for mode in transforms.MODES[1:]:  # identity needs no artifact
            fn = transforms.transform_fn(mode, cfg.alpha)
            lowered = jax.jit(fn).lower(_spec((n, c_in)), _spec((c_in, c_out)))
            name = f"transform_{mode}_{c_in}x{c_out}"
            info = _write(os.path.join(out_dir, f"{name}.hlo.txt"), to_hlo_text(lowered))
            manifest["artifacts"][name] = {
                "path": f"{name}.hlo.txt",
                **info,
                "inputs": [
                    {"name": "x", "shape": [n, c_in], "dtype": "f32"},
                    {"name": "w", "shape": [c_in, c_out], "dtype": "f32"},
                ],
                "outputs": [
                    {"name": "x_hat", "shape": [n, c_in]},
                    {"name": "w_hat", "shape": [c_in, c_out]},
                ],
            }


def lower_qdq(cfg: SynLlamaConfig, out_dir: str, manifest: dict) -> None:
    n = cfg.seq_len
    for c_in in sorted({s[0] for s in cfg.analyze_shapes()}):
        fn = functools.partial(quant.qdq_per_token, bits=cfg.bits)
        lowered = jax.jit(lambda x: (fn(x),)).lower(_spec((n, c_in)))
        name = f"qdq_token_{n}x{c_in}"
        info = _write(os.path.join(out_dir, f"{name}.hlo.txt"), to_hlo_text(lowered))
        manifest["artifacts"][name] = {
            "path": f"{name}.hlo.txt",
            **info,
            "inputs": [{"name": "x", "shape": [n, c_in], "dtype": "f32"}],
            "outputs": [{"name": "x_qdq", "shape": [n, c_in]}],
        }


def dump_params(cfg: SynLlamaConfig, out_dir: str, manifest: dict) -> dict:
    params = model.init_params(cfg)
    tokens = model.make_tokens(cfg)
    pdir = os.path.join(out_dir, "params")
    os.makedirs(pdir, exist_ok=True)
    files = {}
    for k in model.PARAM_ORDER:
        files[k] = _dump_bin(os.path.join(pdir, f"{k}.bin"), params[k])
    files["tokens"] = _dump_bin(os.path.join(out_dir, "tokens.bin"), tokens)
    manifest["param_files"] = files
    return params


def dump_golden(cfg: SynLlamaConfig, params: dict, out_dir: str, manifest: dict) -> None:
    """Reference numbers the rust integration tests must reproduce."""
    pj = {k: jnp.asarray(v) for k, v in params.items()}
    tokens = jnp.asarray(model.make_tokens(cfg))
    caps = jax.jit(lambda p, t: model.forward_capture(p, t, cfg.n_heads))(pj, tokens)
    stacks = dict(zip(MODULES, caps))
    golden = {"capture_checksums": {}, "analyze": []}
    for mod, stack in stacks.items():
        arr = np.asarray(stack)
        golden["capture_checksums"][mod] = {
            # net sum is cancellation-dominated, so abs_sum is the robust
            # mass checksum; sum is kept for informational diffing
            "sum": float(arr.astype(np.float64).sum()),
            "abs_sum": float(np.abs(arr).astype(np.float64).sum()),
            "abs_max": float(np.abs(arr).max()),
            "shape": list(arr.shape),
        }
    analyze_jit = jax.jit(functools.partial(analysis.analyze_module, bits=cfg.bits, alpha=cfg.alpha))
    golden_layers = sorted({0, cfg.n_layers // 2, cfg.n_layers - 1, *cfg.massive_layers})
    for mod in MODULES:
        c_in, c_out = cfg.module_shape(mod)
        for layer in golden_layers:
            x = stacks[mod][layer]
            w = pj[MODULE_WEIGHTS[mod]][layer]
            errs, adiff, wdiff, amax = analyze_jit(x, w)
            golden["analyze"].append(
                {
                    "module": mod,
                    "layer": layer,
                    "c_in": c_in,
                    "c_out": c_out,
                    "errors": [float(v) for v in errs],
                    "act_difficulty": [float(v) for v in adiff],
                    "w_difficulty": [float(v) for v in wdiff],
                    "act_absmax": [float(v) for v in amax],
                }
            )
    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f, indent=1)
    manifest["golden"] = "golden.json"


def build(out_dir: str, cfg: SynLlamaConfig | None = None) -> None:
    cfg = cfg or default_config()
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {
        "config": dataclasses.asdict(cfg),
        "modes": list(transforms.MODES),
        "modules": {
            m: {
                "c_in": cfg.module_shape(m)[0],
                "c_out": cfg.module_shape(m)[1],
                "weight": MODULE_WEIGHTS[m],
                "capture_output": ["attn_in", "o_in", "ffn_in", "down_in"][MODULES.index(m)],
            }
            for m in MODULES
        },
        "artifacts": {},
    }
    print("[aot] lowering capture ...")
    lower_capture(cfg, out_dir, manifest)
    print("[aot] lowering analyze ...")
    lower_analyze(cfg, out_dir, manifest)
    print("[aot] lowering transforms ...")
    lower_transforms(cfg, out_dir, manifest)
    print("[aot] lowering qdq ...")
    lower_qdq(cfg, out_dir, manifest)
    print("[aot] dumping params ...")
    params = dump_params(cfg, out_dir, manifest)
    print("[aot] computing golden values ...")
    dump_golden(cfg, params, out_dir, manifest)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    n_art = len(manifest["artifacts"])
    print(f"[aot] done: {n_art} HLO artifacts -> {out_dir}")


def main() -> None:
    from .config import PRESETS

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="default", choices=sorted(PRESETS))
    args = ap.parse_args()
    build(args.out_dir, PRESETS[args.preset]())


if __name__ == "__main__":
    main()
