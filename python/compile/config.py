"""SynLlama configuration — the substitution substrate for LLaMA2-7B.

The paper records activations from LLaMA2-7B (32 decoder layers, d=4096,
ffn=11008) on a WikiText-2 sample.  Neither the pretrained weights nor the
dataset are available in this environment (repro band 0/5), so we build a
*real* LLaMA-architecture decoder at reduced width whose activation
statistics are calibrated to reproduce the paper's measured phenomena:

* systematic outliers — a small fixed set of channels, hot across all
  tokens, in the attention and gate/up projections (Sec. IV-A),
* massive outliers — token-specific spikes (|o| > 1000) at the down_proj
  inputs of decoder layers 1 and 30, plus a broad multi-token heavy tail
  at layer 31 (Sec. IV-A / IV-B),
* weight outliers in gate_proj 31 (elevated weight difficulty, Fig. 3c).

The profiles are *data generation*, not part of the method under test —
every transform / metric operates on (X, W) exactly as in the paper.
DESIGN.md §2 documents the substitution argument in full.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

__all__ = ["SynLlamaConfig", "MODULES", "MODULE_SHAPES", "default_config"]

# The four recorded module kinds, in paper order.
MODULES = ("k_proj", "o_proj", "gate_proj", "down_proj")


@dataclasses.dataclass(frozen=True)
class SynLlamaConfig:
    """Architecture + outlier-profile parameters (all sweepable)."""

    # -- architecture (mirrors LLaMA2-7B topology at reduced width) ------
    n_layers: int = 32
    d_model: int = 256
    n_heads: int = 8
    d_ffn: int = 704          # = 16 x 44 -> exercises the Kronecker/Paley path
    vocab: int = 512
    seq_len: int = 128
    seed: int = 1234

    # -- quantization (paper Sec. III-B) ---------------------------------
    bits: int = 4
    alpha: float = 0.5        # SmoothQuant migration strength

    # -- systematic outlier profiles (channel gains) ----------------------
    # Per-module hot-channel counts.  The FFN-side modules get ~2.75x more
    # hot channels than the attention-side ones, matching the ratio of
    # their c_in*c_out products so every module traces the same
    # error-vs-difficulty^2 line (this is what makes the paper's pooled
    # > 0.97 Pearson correlation reproducible; see EXPERIMENTS.md).
    attn_sys_channels: int = 8
    oproj_sys_channels: int = 8
    ffn_sys_channels: int = 22
    down_sys_channels: int = 22
    attn_peak_gain: float = 24.0   # k_proj: rises to mid-stack, then falls
    oproj_gain: float = 14.0       # o_proj: monotonic growth
    ffn_gain: float = 18.0         # gate_proj: monotonic growth
    down_gain: float = 4.0         # down_proj baseline systematic level
    layer_jitter: float = 0.05     # natural-looking layer-to-layer noise

    # -- massive outlier profiles (token spikes at down_proj inputs) -----
    massive_layers: Tuple[int, ...] = (1, 30)
    massive_tokens: int = 2        # tokens carrying the spike
    massive_channels: int = 8      # |O| outlier dims per spike token
    massive_value: float = 8000.0  # |o|, paper reports values exceeding 1000
    # systematic gain is suppressed at the massive layers so the spike
    # dominates, as in LLaMA2-7B where down_proj 1/30 errors are
    # out-of-trend *because of* the massive tokens (Sec. IV-B)
    suppress_sys_at_massive: bool = True
    # layer 31: large values across MANY tokens (Sec. IV-B)
    tail_layer: int = 31
    tail_tokens: int = 48
    tail_channels: int = 16
    tail_value: float = 150.0

    # -- weight outliers (gate_proj of the last layer, Fig. 3c) ----------
    wout_layer: int = 31
    wout_rows: int = 4
    wout_gain: float = 8.0

    # -- weight row-norm structure (lognormal sigma) ----------------------
    # Real LLM weights have per-input-channel norm variation; rotation
    # flattens it (Sec. IV-D).  Too much structure couples the massive
    # tokens to heavy rows and masks the rotation-vs-none inversion.
    w_row_sigma: float = 0.1

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def module_shape(self, module: str) -> Tuple[int, int]:
        """(c_in, c_out) of the weight the recorded input feeds into."""
        d, f = self.d_model, self.d_ffn
        return {
            "k_proj": (d, d),
            "o_proj": (d, d),
            "gate_proj": (d, f),
            "down_proj": (f, d),
        }[module]

    def analyze_shapes(self):
        """Distinct (c_in, c_out) pairs needing an analyze artifact."""
        return sorted({self.module_shape(m) for m in MODULES})


# (c_in, c_out) per module kind for the default config, used widely.
MODULE_SHAPES = {
    "k_proj": (256, 256),
    "o_proj": (256, 256),
    "gate_proj": (256, 704),
    "down_proj": (704, 256),
}


def default_config() -> SynLlamaConfig:
    return SynLlamaConfig()


def mistral_config() -> SynLlamaConfig:
    """SynMistral — the paper's future-work architecture (Sec. V).

    Mistral-7B differs from LLaMA2-7B in its wider FFN ratio and 32
    layers; at SynLlama scale we model it as a 16-layer stack with a
    wider relative FFN (352 = 8 x 44, still exercising the
    Kronecker/Paley Hadamard path) so the whole pipeline can be
    re-validated on a second topology (`make artifacts-mistral`).
    """
    return SynLlamaConfig(
        n_layers=16,
        d_model=128,
        n_heads=4,
        d_ffn=352,
        vocab=512,
        seq_len=128,
        seed=4321,
        attn_sys_channels=4,
        oproj_sys_channels=4,
        ffn_sys_channels=11,
        down_sys_channels=11,
        massive_layers=(1, 14),
        tail_layer=15,
        wout_layer=15,
    )


PRESETS = {"default": default_config, "mistral": mistral_config}
