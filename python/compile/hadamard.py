"""Hadamard matrix construction (build-time, numpy).

Mirrors Sec. III-D of the paper:

* Sylvester recursion for d = 2^p (Kronecker inflation of the 2x2 seed).
* For non-power-of-two dimensions, Kronecker composition with a known base
  Hadamard matrix, following QuIP#.  The paper uses 11008 = 64 x 172; our
  scaled SynLlama model uses 704 = 16 x 44, where H_44 comes from the
  Paley-I construction over GF(43) (43 is a prime congruent 3 mod 4).

The rust side re-implements the identical constructions in
``rust/src/transforms/hadamard.rs``; the pytest suite and the rust tests
both assert H @ H.T == d * I so the two sides cannot drift silently.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "sylvester",
    "paley1",
    "hadamard",
    "rotation_matrix",
    "is_hadamard",
]


def sylvester(d: int) -> np.ndarray:
    """Sylvester Hadamard matrix of size d (d must be a power of two)."""
    if d < 1 or (d & (d - 1)) != 0:
        raise ValueError(f"Sylvester construction needs a power of two, got {d}")
    h = np.array([[1.0]], dtype=np.float64)
    h2 = np.array([[1.0, 1.0], [1.0, -1.0]], dtype=np.float64)
    while h.shape[0] < d:
        h = np.kron(h2, h)
    return h


def _jacobsthal(q: int) -> np.ndarray:
    """Jacobsthal matrix Q_ij = chi(j - i) over GF(q), chi the quadratic
    residue character (chi(0) = 0)."""
    residues = {(x * x) % q for x in range(1, q)}
    chi = np.zeros(q, dtype=np.float64)
    for a in range(1, q):
        chi[a] = 1.0 if a in residues else -1.0
    idx = (np.arange(q)[None, :] - np.arange(q)[:, None]) % q
    return chi[idx]


def paley1(q: int) -> np.ndarray:
    """Paley-I Hadamard matrix of size q + 1 for prime q with q % 4 == 3.

    H = I + S with the skew matrix S = [[0, 1^T], [-1, Q]].
    """
    if q % 4 != 3:
        raise ValueError(f"Paley-I needs q % 4 == 3, got {q}")
    for p in range(2, int(q**0.5) + 1):
        if q % p == 0:
            raise ValueError(f"Paley-I implemented for prime q only, got {q}")
    d = q + 1
    s = np.zeros((d, d), dtype=np.float64)
    s[0, 1:] = 1.0
    s[1:, 0] = -1.0
    s[1:, 1:] = _jacobsthal(q)
    return np.eye(d) + s


# Base (non-Sylvester) Hadamard orders we know how to build directly.
_PALEY_ORDERS = {4: 3, 12: 11, 20: 19, 24: 23, 28: 27, 44: 43, 48: 47, 60: 59}


def hadamard(d: int) -> np.ndarray:
    """Unnormalized Hadamard matrix of size d (entries +/-1).

    Supports d = 2^p (Sylvester) and d = 2^p * b for a Paley-I base order b
    (Kronecker composition, the QuIP# trick the paper adopts for 11008).
    """
    if d >= 1 and (d & (d - 1)) == 0:
        return sylvester(d)
    for order, q in sorted(_PALEY_ORDERS.items(), reverse=True):
        if d % order == 0:
            pow2 = d // order
            if pow2 >= 1 and (pow2 & (pow2 - 1)) == 0:
                base = paley1(q)
                return np.kron(sylvester(pow2), base) if pow2 > 1 else base
    raise ValueError(f"no Hadamard construction available for d={d}")


def rotation_matrix(d: int) -> np.ndarray:
    """Orthonormal rotation R = H / sqrt(d) (Eq. 5 of the paper)."""
    return hadamard(d) / np.sqrt(float(d))


def is_hadamard(h: np.ndarray, atol: float = 1e-9) -> bool:
    """Check entries are +/-1 and rows are mutually orthogonal."""
    d = h.shape[0]
    if h.shape != (d, d):
        return False
    if not np.allclose(np.abs(h), 1.0, atol=atol):
        return False
    return np.allclose(h @ h.T, d * np.eye(d), atol=1e-6 * d)
