"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO).

All kernels run with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness path and
real-TPU performance is estimated analytically (DESIGN.md §8).

Modules
-------
quant   : symmetric RTN quantize-dequantize (per-token / per-channel) and
          the scale (Delta) reduction kernels.
matmul  : blocked matmul used for Hadamard rotation.
smooth  : SmoothQuant channel-wise scaling application.
qerror  : the hot path — fused Q(X)Q(W) vs XW layer-error kernel.
ref     : pure-jnp oracle for all of the above.
"""

from . import matmul, qerror, quant, ref, smooth  # noqa: F401
