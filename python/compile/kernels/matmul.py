"""Blocked Pallas matmul — the Hadamard-rotation workhorse.

Rotation (Sec. III-D) is X_hat = X R and W_hat = R^T W; both are dense
matmuls against the baked Hadamard constant.  On TPU this is pure MXU
work: blocks of (bm, bk) x (bk, bn) stream HBM->VMEM with the k axis kept
whole per block here (c_in <= 704 at SynLlama scale, so a full-k block is
~0.5 MB — well under VMEM; at LLaMA scale the same kernel k-tiles, see
DESIGN.md §8).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["matmul"]


def _block(n: int, target: int) -> int:
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] @ b_ref[...]


def matmul(a: jax.Array, b: jax.Array, block_m: int = 64, block_n: int = 128) -> jax.Array:
    """C = A @ B with (block_m, K) x (K, block_n) Pallas blocks."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    bm, bn = _block(m, block_m), _block(n, block_n)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.result_type(a.dtype, b.dtype)),
        interpret=True,
    )(a, b)
