"""Fused layer-wise quantization-error kernel — the L1 hot path.

Computes Eq. 2, ``||X W - Q(X) Q(W)||_F^2``, in a single pass over X and
W: each (bm, bn) output tile loads its X-row block and W-column block
once, runs BOTH the fp matmul and the fake-quantized matmul on the same
VMEM-resident operands, and reduces the squared difference to one partial
scalar per tile.  Compared to the naive pipeline (qdq X -> qdq W -> two
matmuls -> subtract -> square -> sum) this removes two full HBM
round-trips of X/W-sized intermediates and the (n, c_out)-sized Y/Yq
temporaries.

The per-token / per-channel scales (Delta) are global row/column
reductions, so they are produced first by the small reduction kernels in
``quant.py`` and streamed in as (bm, 1) / (1, bn) side inputs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import quant

__all__ = ["quant_error", "quant_error_partials"]


def _block(n: int, target: int) -> int:
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def _qerror_kernel(x_ref, w_ref, dx_ref, dw_ref, o_ref):
    x = x_ref[...]
    w = w_ref[...]
    dx = dx_ref[...]  # (bm, 1) per-token Delta
    dw = dw_ref[...]  # (1, bn) per-channel Delta
    xsafe = jnp.where(dx > 0, dx, 1.0)
    wsafe = jnp.where(dw > 0, dw, 1.0)
    xq = jnp.where(dx > 0, jnp.round(x / xsafe) * xsafe, 0.0)
    wq = jnp.where(dw > 0, jnp.round(w / wsafe) * wsafe, 0.0)
    diff = x @ w - xq @ wq
    o_ref[...] = jnp.sum(diff * diff, keepdims=True).reshape(1, 1)


def quant_error_partials(
    x: jax.Array,
    w: jax.Array,
    bits: int = 4,
    block_m: int = 32,
    block_n: int = 128,
) -> jax.Array:
    """Per-tile partial sums of Eq. 2, shape (m_blocks, n_blocks)."""
    n, c_in = x.shape
    c_in2, c_out = w.shape
    assert c_in == c_in2, f"shape mismatch: {x.shape} @ {w.shape}"
    bm, bn = _block(n, block_m), _block(c_out, block_n)
    dx = quant.token_scales(x, bits)
    dw = quant.channel_scales(w, bits)
    return pl.pallas_call(
        _qerror_kernel,
        grid=(n // bm, c_out // bn),
        in_specs=[
            pl.BlockSpec((bm, c_in), lambda i, j: (i, 0)),
            pl.BlockSpec((c_in, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n // bm, c_out // bn), x.dtype),
        interpret=True,
    )(x, w, dx, dw)


def quant_error(x: jax.Array, w: jax.Array, bits: int = 4) -> jax.Array:
    """Layer-wise quantization error (Eq. 2) as a scalar."""
    return jnp.sum(quant_error_partials(x, w, bits))
