"""Pallas RTN symmetric quantize-dequantize kernels (Eq. 1).

Two granularities, matching the paper's setup (Sec. III-B):

* per-token for activations  — one grid per row of X,
* per-channel for weights    — one grid per column of W.

TPU mapping: the absmax reduction and the round/scale pass are VPU
elementwise work; rows (tokens) tile along the sublane axis, the channel
axis stays whole inside a block so a token's Delta is computed in one
block. ``interpret=True`` everywhere (see package docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "qmax",
    "qdq_per_token",
    "qdq_per_channel",
    "token_scales",
    "channel_scales",
]


def qmax(bits: int) -> float:
    """Largest positive level of a symmetric b-bit integer grid."""
    return float(2 ** (bits - 1) - 1)


def _block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (keeps grids exact)."""
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def _qdq_rows_kernel(x_ref, o_ref, *, qm: float):
    x = x_ref[...]
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    delta = absmax / qm
    safe = jnp.where(delta > 0, delta, 1.0)
    o_ref[...] = jnp.where(delta > 0, jnp.round(x / safe) * safe, 0.0)


def qdq_per_token(x: jax.Array, bits: int = 4, block_rows: int = 32) -> jax.Array:
    """Quantize-dequantize each row of ``x`` on its own symmetric grid."""
    n, c = x.shape
    bm = _block(n, block_rows)
    return pl.pallas_call(
        functools.partial(_qdq_rows_kernel, qm=qmax(bits)),
        grid=(n // bm,),
        in_specs=[pl.BlockSpec((bm, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), x.dtype),
        interpret=True,
    )(x)


def _qdq_cols_kernel(w_ref, o_ref, *, qm: float):
    w = w_ref[...]
    absmax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    delta = absmax / qm
    safe = jnp.where(delta > 0, delta, 1.0)
    o_ref[...] = jnp.where(delta > 0, jnp.round(w / safe) * safe, 0.0)


def qdq_per_channel(w: jax.Array, bits: int = 4, block_cols: int = 64) -> jax.Array:
    """Quantize-dequantize each column of ``w`` on its own symmetric grid."""
    c_in, c_out = w.shape
    bn = _block(c_out, block_cols)
    return pl.pallas_call(
        functools.partial(_qdq_cols_kernel, qm=qmax(bits)),
        grid=(c_out // bn,),
        in_specs=[pl.BlockSpec((c_in, bn), lambda j: (0, j))],
        out_specs=pl.BlockSpec((c_in, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((c_in, c_out), w.dtype),
        interpret=True,
    )(w)


def _row_scale_kernel(x_ref, o_ref, *, qm: float):
    o_ref[...] = jnp.max(jnp.abs(x_ref[...]), axis=1, keepdims=True) / qm


def token_scales(x: jax.Array, bits: int = 4, block_rows: int = 32) -> jax.Array:
    """Per-token quantization step Delta, shape (n, 1)."""
    n, c = x.shape
    bm = _block(n, block_rows)
    return pl.pallas_call(
        functools.partial(_row_scale_kernel, qm=qmax(bits)),
        grid=(n // bm,),
        in_specs=[pl.BlockSpec((bm, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), x.dtype),
        interpret=True,
    )(x)


def _col_scale_kernel(w_ref, o_ref, *, qm: float):
    o_ref[...] = jnp.max(jnp.abs(w_ref[...]), axis=0, keepdims=True) / qm


def channel_scales(w: jax.Array, bits: int = 4, block_cols: int = 64) -> jax.Array:
    """Per-output-channel quantization step Delta, shape (1, c_out)."""
    c_in, c_out = w.shape
    bn = _block(c_out, block_cols)
    return pl.pallas_call(
        functools.partial(_col_scale_kernel, qm=qmax(bits)),
        grid=(c_out // bn,),
        in_specs=[pl.BlockSpec((c_in, bn), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, c_out), w.dtype),
        interpret=True,
    )(w)
