"""Pure-jnp reference oracle for every Pallas kernel (L1 correctness spec).

Everything here is deliberately written in the most direct jnp form — no
tiling, no fusion — so the pytest suite can assert the Pallas kernels in
``quant.py`` / ``matmul.py`` / ``smooth.py`` / ``qerror.py`` against an
independent implementation.  The rust side mirrors the same math in
``rust/src/quant`` and ``rust/src/metrics``.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "qmax",
    "qdq_per_token",
    "qdq_per_channel",
    "qdq_per_tensor",
    "token_scales",
    "channel_scales",
    "matmul",
    "smooth_scales",
    "smooth_apply",
    "quant_error",
    "channel_magnitudes",
    "quant_difficulty",
    "kurtosis",
]

_EPS = 1e-12


def qmax(bits: int) -> float:
    """Largest positive level of a symmetric b-bit integer grid (Eq. 1)."""
    return float(2 ** (bits - 1) - 1)


def token_scales(x: jnp.ndarray, bits: int = 4) -> jnp.ndarray:
    """Per-token (per-row) quantization step Delta, shape (n, 1)."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    return absmax / qmax(bits)


def channel_scales(w: jnp.ndarray, bits: int = 4) -> jnp.ndarray:
    """Per-output-channel (per-column) quantization step Delta, shape (1, c)."""
    absmax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    return absmax / qmax(bits)


def _qdq(x: jnp.ndarray, delta: jnp.ndarray) -> jnp.ndarray:
    safe = jnp.where(delta > 0, delta, 1.0)
    return jnp.where(delta > 0, jnp.round(x / safe) * safe, 0.0)


def qdq_per_token(x: jnp.ndarray, bits: int = 4) -> jnp.ndarray:
    """Symmetric RTN quantize-dequantize, one grid per row (activations)."""
    return _qdq(x, token_scales(x, bits))


def qdq_per_channel(w: jnp.ndarray, bits: int = 4) -> jnp.ndarray:
    """Symmetric RTN quantize-dequantize, one grid per column (weights)."""
    return _qdq(w, channel_scales(w, bits))


def qdq_per_tensor(x: jnp.ndarray, bits: int = 4) -> jnp.ndarray:
    """Symmetric RTN quantize-dequantize with a single tensor-wide grid."""
    delta = jnp.max(jnp.abs(x)) / qmax(bits)
    return _qdq(x, delta)


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.matmul(a, b)


def smooth_scales(x: jnp.ndarray, w: jnp.ndarray, alpha: float = 0.5) -> jnp.ndarray:
    """SmoothQuant migration factor s_j (Eq. 4), zero-safe, shape (c_in,)."""
    xmax = jnp.maximum(jnp.max(jnp.abs(x), axis=0), _EPS)
    wmax = jnp.maximum(jnp.max(jnp.abs(w), axis=1), _EPS)
    return xmax**alpha / wmax ** (1.0 - alpha)


def smooth_apply(x: jnp.ndarray, w: jnp.ndarray, s: jnp.ndarray):
    """X_hat = X diag(s)^-1, W_hat = diag(s) W (Eq. 3 with A^-1 = diag(s))."""
    return x / s[None, :], w * s[:, None]


def quant_error(x: jnp.ndarray, w: jnp.ndarray, bits: int = 4) -> jnp.ndarray:
    """Layer-wise quantization error (Eq. 2): ||XW - Q(X)Q(W)||_F^2."""
    y = x @ w
    yq = qdq_per_token(x, bits) @ qdq_per_channel(w, bits)
    return jnp.sum((y - yq) ** 2)


def channel_magnitudes(t: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """Frobenius norm of each channel (paper Sec. II-B / FlatQuant).

    For activations X (n, c_in) use axis=0 (one magnitude per input
    channel); for weights W (c_in, c_out) use axis=1 so magnitudes are also
    indexed by input channel — the axis smoothing and rotation act on.
    """
    return jnp.sqrt(jnp.sum(t * t, axis=axis))


def quant_difficulty(t: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """The paper's new metric: std of the channel magnitudes."""
    m = channel_magnitudes(t, axis=axis)
    return jnp.std(m)


def kurtosis(t: jnp.ndarray) -> jnp.ndarray:
    """Excess kurtosis of the flattened tensor (FlatQuant's flatness proxy)."""
    v = t.reshape(-1)
    mu = jnp.mean(v)
    sig2 = jnp.mean((v - mu) ** 2)
    return jnp.mean((v - mu) ** 4) / jnp.maximum(sig2 * sig2, _EPS) - 3.0
