"""Pallas SmoothQuant channel-wise scaling kernels (Eq. 3-4).

The migration factor s_j = max|X_j|^alpha / max|W_j|^(1-alpha) is a pair
of column/row absmax reductions followed by two elementwise scaling
passes: X_hat[:, j] = X[:, j] / s_j and W_hat[j, :] = s_j * W[j, :].
On TPU the scale vector lives in VMEM and is broadcast along the token
(sublane) axis by the VPU; no MXU work at all.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["smooth_scales", "scale_columns", "scale_rows", "smooth_apply"]

_EPS = 1e-12


def _block(n: int, target: int) -> int:
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def _xmax_kernel(x_ref, o_ref):
    o_ref[...] = jnp.maximum(jnp.max(jnp.abs(x_ref[...]), axis=0, keepdims=True), _EPS)


def _wmax_kernel(w_ref, o_ref):
    o_ref[...] = jnp.maximum(jnp.max(jnp.abs(w_ref[...]), axis=1, keepdims=True), _EPS)


def smooth_scales(x: jax.Array, w: jax.Array, alpha: float = 0.5) -> jax.Array:
    """s_j per input channel (Eq. 4), computed with Pallas reductions."""
    n, c_in = x.shape
    bc = _block(c_in, 128)
    xmax = pl.pallas_call(
        _xmax_kernel,
        grid=(c_in // bc,),
        in_specs=[pl.BlockSpec((n, bc), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, bc), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, c_in), x.dtype),
        interpret=True,
    )(x)
    c_out = w.shape[1]
    br = _block(c_in, 128)
    wmax = pl.pallas_call(
        _wmax_kernel,
        grid=(c_in // br,),
        in_specs=[pl.BlockSpec((br, c_out), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c_in, 1), w.dtype),
        interpret=True,
    )(w)
    return xmax[0] ** alpha / wmax[:, 0] ** (1.0 - alpha)


def _scale_cols_kernel(x_ref, s_ref, o_ref):
    o_ref[...] = x_ref[...] / s_ref[...]


def scale_columns(x: jax.Array, s: jax.Array) -> jax.Array:
    """X_hat[:, j] = X[:, j] / s_j."""
    n, c = x.shape
    bc = _block(c, 128)
    return pl.pallas_call(
        _scale_cols_kernel,
        grid=(c // bc,),
        in_specs=[
            pl.BlockSpec((n, bc), lambda j: (0, j)),
            pl.BlockSpec((1, bc), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n, bc), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, c), x.dtype),
        interpret=True,
    )(x, s[None, :])


def _scale_rows_kernel(w_ref, s_ref, o_ref):
    o_ref[...] = w_ref[...] * s_ref[...]


def scale_rows(w: jax.Array, s: jax.Array) -> jax.Array:
    """W_hat[j, :] = s_j * W[j, :]."""
    c_in, c_out = w.shape
    br = _block(c_in, 128)
    return pl.pallas_call(
        _scale_rows_kernel,
        grid=(c_in // br,),
        in_specs=[
            pl.BlockSpec((br, c_out), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, c_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c_in, c_out), w.dtype),
        interpret=True,
    )(w, s[:, None])


def smooth_apply(x: jax.Array, w: jax.Array, s: jax.Array):
    """Apply a precomputed migration vector to both sides (Eq. 3)."""
    return scale_columns(x, s), scale_rows(w, s)
