"""SynLlama — the Layer-2 JAX decoder stack with activation capture.

A faithful LLaMA-architecture decoder (RMSNorm -> causal MHA -> RMSNorm ->
SwiGLU FFN, pre-norm residual stream) at the reduced width of
``SynLlamaConfig``, plus the calibrated outlier profiles documented in
``config.py``.  ``forward_capture`` runs the full stack and returns the
four recorded module-input stacks of the paper (Sec. III-A):

* ``attn_in``  — input of k_proj (shared with q/v projections),
* ``o_in``    — input of the attention output projection,
* ``ffn_in``  — input of gate_proj (shared with up_proj),
* ``down_in`` — input of down_proj.

Parameters are *runtime inputs* of the lowered HLO (the rust side feeds
them from ``artifacts/params/*.bin``), which keeps the HLO text small; the
outlier profiles are folded into the parameter arrays so the lowered graph
is a plain transformer forward.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import SynLlamaConfig

__all__ = ["PARAM_ORDER", "init_params", "make_tokens", "forward_capture", "param_specs"]

_EPS = 1e-6

# Canonical parameter order — the artifact manifest and the rust loader
# both follow this exact sequence.
PARAM_ORDER = (
    "embed",      # [vocab, d]
    "g1",         # [L, d]   rmsnorm gain (attention)
    "g2",         # [L, d]   rmsnorm gain (ffn)
    "wq",         # [L, d, d]
    "wk",         # [L, d, d]
    "wv",         # [L, d, d]
    "wo",         # [L, d, d]
    "wg",         # [L, d, f]
    "wu",         # [L, d, f]
    "wd",         # [L, f, d]
    "attn_gain",  # [L, d]   systematic profile on attn_in
    "o_gain",     # [L, d]   systematic profile on o_in
    "ffn_gain",   # [L, d]   systematic profile on ffn_in
    "down_gain",  # [L, f]   systematic profile on down_in
    "spike_tok",  # [L, n]   massive-outlier token indicator
    "spike_chan", # [L, f]   massive-outlier channel pattern (signed)
)


def _hot_channels(rng: np.random.Generator, n_channels: int, k: int) -> np.ndarray:
    return rng.choice(n_channels, size=k, replace=False)


def init_params(cfg: SynLlamaConfig) -> Dict[str, np.ndarray]:
    """Deterministic parameter + profile generation (numpy, build time)."""
    rng = np.random.default_rng(cfg.seed)
    L, d, f, n = cfg.n_layers, cfg.d_model, cfg.d_ffn, cfg.seq_len

    def w(*shape, std):
        base = (rng.normal(size=shape) * std).astype(np.float32)
        # Real LLM weight matrices have per-input-channel norm structure
        # (rows are not i.i.d.); without it rotation would have nothing to
        # flatten on the weight side (Sec. IV-D: rotation lowers weight
        # quantization difficulty below the original).  Lognormal row
        # scales reproduce that structure.
        row_scale = np.exp(cfg.w_row_sigma * rng.normal(size=shape[:-1] + (1,))).astype(np.float32)
        return base * row_scale

    p: Dict[str, np.ndarray] = {
        "embed": (rng.normal(size=(cfg.vocab, d))).astype(np.float32),
        "g1": np.abs(1.0 + 0.05 * rng.normal(size=(L, d))).astype(np.float32),
        "g2": np.abs(1.0 + 0.05 * rng.normal(size=(L, d))).astype(np.float32),
        "wq": w(L, d, d, std=d**-0.5),
        "wk": w(L, d, d, std=d**-0.5),
        "wv": w(L, d, d, std=d**-0.5),
        "wo": w(L, d, d, std=d**-0.5),
        "wg": w(L, d, f, std=d**-0.5),
        "wu": w(L, d, f, std=d**-0.5),
        "wd": w(L, f, d, std=f**-0.5),
    }

    # ---- weight outliers: heavy rows in gate_proj of the last layer ----
    wout_rows = _hot_channels(rng, d, cfg.wout_rows)
    p["wg"][cfg.wout_layer, wout_rows, :] *= cfg.wout_gain

    # ---- systematic channel-gain profiles ------------------------------
    li = np.arange(L, dtype=np.float64) / max(L - 1, 1)
    jit = lambda: 1.0 + cfg.layer_jitter * rng.normal(size=L)  # noqa: E731

    def sys_profile(n_channels, amplitude_per_layer, k_hot):
        gain = np.ones((L, n_channels), dtype=np.float32)
        hot = _hot_channels(rng, n_channels, k_hot)
        per_ch = 1.0 + 0.25 * rng.random(k_hot)  # channel spread
        for l in range(L):
            gain[l, hot] = (1.0 + amplitude_per_layer[l] * per_ch).astype(np.float32)
        return gain

    p["attn_gain"] = sys_profile(d, cfg.attn_peak_gain * np.sin(np.pi * li) * jit(), cfg.attn_sys_channels)
    p["o_gain"] = sys_profile(d, cfg.oproj_gain * li**1.5 * jit(), cfg.oproj_sys_channels)
    p["ffn_gain"] = sys_profile(d, cfg.ffn_gain * li * jit(), cfg.ffn_sys_channels)
    p["down_gain"] = sys_profile(f, cfg.down_gain * li * jit(), cfg.down_sys_channels)
    if cfg.suppress_sys_at_massive:
        # massive-outlier layers: the spike, not the systematic channels,
        # must dominate (paper Sec. IV-B: out-of-trend errors at 1/30)
        for l in cfg.massive_layers:
            p["down_gain"][l] = 1.0

    # ---- massive outliers: token-specific spikes at down_proj inputs ---
    spike_tok = np.zeros((L, n), dtype=np.float32)
    spike_chan = np.zeros((L, f), dtype=np.float32)
    for l in cfg.massive_layers:
        toks = rng.choice(n, size=cfg.massive_tokens, replace=False)
        spike_tok[l, toks] = 1.0 + 0.2 * rng.random(cfg.massive_tokens)
        chans = _hot_channels(rng, f, cfg.massive_channels)
        signs = rng.choice([-1.0, 1.0], size=cfg.massive_channels)
        spike_chan[l, chans] = (signs * cfg.massive_value * (1.0 + 0.15 * rng.random(cfg.massive_channels))).astype(np.float32)
    # layer 31: large values across many tokens (broad heavy tail)
    lt = cfg.tail_layer
    toks = rng.choice(n, size=cfg.tail_tokens, replace=False)
    spike_tok[lt, toks] = 1.0 + 0.5 * rng.random(cfg.tail_tokens)
    chans = _hot_channels(rng, f, cfg.tail_channels)
    signs = rng.choice([-1.0, 1.0], size=cfg.tail_channels)
    spike_chan[lt, chans] = (signs * cfg.tail_value * (1.0 + 0.3 * rng.random(cfg.tail_channels))).astype(np.float32)
    p["spike_tok"] = spike_tok
    p["spike_chan"] = spike_chan

    assert set(p) == set(PARAM_ORDER)
    return p


def make_tokens(cfg: SynLlamaConfig) -> np.ndarray:
    """Deterministic token stream (the WikiText-2 sample substitute)."""
    rng = np.random.default_rng(cfg.seed + 1)
    return rng.integers(0, cfg.vocab, size=cfg.seq_len).astype(np.int32)


def param_specs(cfg: SynLlamaConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Shape/dtype specs for AOT lowering, keyed like PARAM_ORDER."""
    L, d, f, n = cfg.n_layers, cfg.d_model, cfg.d_ffn, cfg.seq_len
    shapes = {
        "embed": (cfg.vocab, d),
        "g1": (L, d), "g2": (L, d),
        "wq": (L, d, d), "wk": (L, d, d), "wv": (L, d, d), "wo": (L, d, d),
        "wg": (L, d, f), "wu": (L, d, f), "wd": (L, f, d),
        "attn_gain": (L, d), "o_gain": (L, d), "ffn_gain": (L, d),
        "down_gain": (L, f), "spike_tok": (L, n), "spike_chan": (L, f),
    }
    return {k: jax.ShapeDtypeStruct(shapes[k], jnp.float32) for k in PARAM_ORDER}


def _rmsnorm(x: jax.Array, g: jax.Array) -> jax.Array:
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + _EPS) * g


def _causal_attention(x: jax.Array, wq, wk, wv, n_heads: int) -> jax.Array:
    n, d = x.shape
    dh = d // n_heads
    q = (x @ wq).reshape(n, n_heads, dh).transpose(1, 0, 2)
    k = (x @ wk).reshape(n, n_heads, dh).transpose(1, 0, 2)
    v = (x @ wv).reshape(n, n_heads, dh).transpose(1, 0, 2)
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(float(dh))
    mask = jnp.tril(jnp.ones((n, n), dtype=bool))
    scores = jnp.where(mask[None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hqk,hkd->hqd", probs, v)
    return ctx.transpose(1, 0, 2).reshape(n, d)


def forward_capture(params: Dict[str, jax.Array], tokens: jax.Array, n_heads: int = 8):
    """Full decoder forward; returns the 4 captured module-input stacks.

    Output: (attn_in [L,n,d], o_in [L,n,d], ffn_in [L,n,d], down_in [L,n,f]).
    """
    h = params["embed"][tokens]

    layer_params = {k: params[k] for k in PARAM_ORDER if k != "embed"}

    def layer(h, lp):
        # --- attention block ---
        x1 = _rmsnorm(h, lp["g1"]) * lp["attn_gain"]          # attn_in (k_proj input)
        ctx = _causal_attention(x1, lp["wq"], lp["wk"], lp["wv"], n_heads)
        o_in = ctx * lp["o_gain"]                              # o_proj input
        h = h + o_in @ lp["wo"]
        # --- FFN block (SwiGLU) ---
        x2 = _rmsnorm(h, lp["g2"]) * lp["ffn_gain"]            # ffn_in (gate_proj input)
        act = jax.nn.silu(x2 @ lp["wg"]) * (x2 @ lp["wu"])
        down_in = act * lp["down_gain"] + lp["spike_tok"][:, None] * lp["spike_chan"][None, :]
        h = h + down_in @ lp["wd"]
        return h, (x1, o_in, x2, down_in)

    _, captures = jax.lax.scan(layer, h, layer_params)
    return captures
