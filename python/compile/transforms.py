"""Layer-2 equivalent transformations (Sec. II-C / IV-C..E of the paper).

Each transform maps (X, W) -> (X_hat, W_hat) with X W == X_hat W_hat
(Eq. 3), built from the L1 Pallas kernels so the whole thing lowers into
one HLO module:

* ``none``          — identity (the untransformed baseline),
* ``smooth``        — SmoothQuant channel-wise scaling, Eq. 4, alpha=0.5,
* ``rotate``        — Hadamard rotation X R, R^T W (Eq. 5),
* ``smooth_rotate`` — the paper's contribution: scaling first, THEN
  rotation of both sides, so the migrated outlier mass is spread across
  the weight's input channels too (Eq. 9).

The Hadamard rotation matrices are baked as compile-time constants of the
lowered HLO (they only depend on c_in).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import hadamard
from .kernels import matmul, smooth

__all__ = ["MODES", "rotation", "apply_transform", "transform_fn"]

MODES = ("none", "smooth", "rotate", "smooth_rotate")


@functools.lru_cache(maxsize=None)
def _rotation_np(d: int) -> np.ndarray:
    return hadamard.rotation_matrix(d).astype(np.float32)


def rotation(d: int) -> jax.Array:
    """Orthonormal Hadamard rotation R for dimension d (cached)."""
    return jnp.asarray(_rotation_np(d))


def apply_transform(mode: str, x: jax.Array, w: jax.Array, alpha: float = 0.5):
    """Return (X_hat, W_hat) for the requested mode. Pallas inside."""
    if mode == "none":
        return x, w
    if mode == "smooth":
        s = smooth.smooth_scales(x, w, alpha)
        return smooth.smooth_apply(x, w, s)
    if mode == "rotate":
        r = rotation(x.shape[1])
        return matmul.matmul(x, r), matmul.matmul(r.T, w)
    if mode == "smooth_rotate":
        s = smooth.smooth_scales(x, w, alpha)
        xs, ws = smooth.smooth_apply(x, w, s)
        r = rotation(x.shape[1])
        return matmul.matmul(xs, r), matmul.matmul(r.T, ws)
    raise ValueError(f"unknown transform mode {mode!r} (want one of {MODES})")


def transform_fn(mode: str, alpha: float = 0.5):
    """A (X, W) -> (X_hat, W_hat) callable for AOT lowering."""

    def fn(x, w):
        return apply_transform(mode, x, w, alpha)

    fn.__name__ = f"transform_{mode}"
    return fn
