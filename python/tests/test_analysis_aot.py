"""Analysis graph + AOT lowering tests."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import analysis, aot, transforms
from compile.config import SynLlamaConfig
from compile.kernels import ref


def _xw(n=32, c_in=64, c_out=16, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, c_in)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(c_in, c_out)).astype(np.float32))
    return x, w


def test_analyze_module_mode_order():
    """Mode 0 (none) must equal the raw quant error / difficulties."""
    x, w = _xw()
    errs, adiff, wdiff, amax = analysis.analyze_module(x, w)
    assert errs.shape == (4,)
    np.testing.assert_allclose(errs[0], ref.quant_error(x, w), rtol=1e-3)
    np.testing.assert_allclose(adiff[0], ref.quant_difficulty(x, 0), rtol=1e-5)
    np.testing.assert_allclose(wdiff[0], ref.quant_difficulty(w, 1), rtol=1e-5)
    np.testing.assert_allclose(amax[0], jnp.max(jnp.abs(x)), rtol=1e-6)


def test_analyze_module_matches_manual_transforms():
    x, w = _xw(seed=3)
    errs, _, _, _ = analysis.analyze_module(x, w)
    for i, mode in enumerate(transforms.MODES):
        xh, wh = transforms.apply_transform(mode, x, w)
        np.testing.assert_allclose(errs[i], ref.quant_error(xh, wh), rtol=2e-3, atol=1e-2)


def test_hlo_text_has_no_elided_constants():
    fn = transforms.transform_fn("rotate")
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((8, 64), jnp.float32), jax.ShapeDtypeStruct((64, 16), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "({...})" not in text
    assert "f32[64,64]" in text  # the baked Hadamard constant


def test_aot_build_smoke(tmp_path):
    """Full AOT build on a tiny config: manifest + artifacts + golden."""
    cfg = SynLlamaConfig(
        n_layers=2, d_model=32, n_heads=2, d_ffn=44, vocab=32, seq_len=16,
        massive_layers=(1,), tail_layer=0, wout_layer=1,
        attn_sys_channels=2, oproj_sys_channels=2, ffn_sys_channels=4, down_sys_channels=4,
        massive_tokens=1, massive_channels=2, tail_tokens=4, tail_channels=2, wout_rows=1,
    )
    out = str(tmp_path / "artifacts")
    # golden layers are fixed at (0,1,16,30,31) for the default config;
    # monkeypatch to the tiny layer count
    orig = aot.dump_golden

    def tiny_golden(cfg_, params, out_dir, manifest):
        import functools as ft

        pj = {k: jnp.asarray(v) for k, v in params.items()}
        tokens = jnp.asarray(aot.model.make_tokens(cfg_))
        caps = jax.jit(lambda p, t: aot.model.forward_capture(p, t, cfg_.n_heads))(pj, tokens)
        manifest["golden"] = None
        _ = caps

    aot.dump_golden = tiny_golden
    try:
        aot.build(out, cfg)
    finally:
        aot.dump_golden = orig

    manifest = json.load(open(os.path.join(out, "manifest.json")))
    assert "capture" in manifest["artifacts"]
    assert f"analyze_32x32" in manifest["artifacts"]
    for art in manifest["artifacts"].values():
        path = os.path.join(out, art["path"])
        assert os.path.exists(path)
        assert os.path.getsize(path) == art["bytes"]
    # param files exist with declared sizes
    for name, meta in manifest["param_files"].items():
        f = "tokens.bin" if name == "tokens" else f"params/{name}.bin"
        assert os.path.getsize(os.path.join(out, f)) == meta["bytes"]


def test_manifest_roundtrip_of_default_exists():
    """If the real artifacts have been built, sanity-check the manifest."""
    here = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    mpath = os.path.join(here, "artifacts", "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built yet (run `make artifacts`)")
    manifest = json.load(open(mpath))
    assert manifest["modes"] == ["none", "smooth", "rotate", "smooth_rotate"]
    assert set(manifest["modules"]) == {"k_proj", "o_proj", "gate_proj", "down_proj"}
    assert len(manifest["artifacts"]) == 15
