"""Hadamard construction tests (paper Sec. III-D)."""

import numpy as np
import pytest

from compile import hadamard as hd


@pytest.mark.parametrize("d", [1, 2, 4, 8, 16, 64, 256, 1024])
def test_sylvester_is_hadamard(d):
    assert hd.is_hadamard(hd.sylvester(d))


def test_sylvester_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        hd.sylvester(12)
    with pytest.raises(ValueError):
        hd.sylvester(0)


@pytest.mark.parametrize("q", [3, 7, 11, 19, 23, 43, 47, 59])
def test_paley1_is_hadamard(q):
    assert hd.is_hadamard(hd.paley1(q))


def test_paley1_rejects_bad_q():
    with pytest.raises(ValueError):
        hd.paley1(5)  # 5 % 4 != 3
    with pytest.raises(ValueError):
        hd.paley1(15)  # composite


@pytest.mark.parametrize("d", [12, 24, 44, 88, 176, 352, 704, 48, 96])
def test_kronecker_composition(d):
    assert hd.is_hadamard(hd.hadamard(d))


def test_unsupported_dimension():
    # 172 = 4 * 43 would need a Williamson table (43 has no Paley-I order)
    with pytest.raises(ValueError):
        hd.hadamard(172)
    with pytest.raises(ValueError):
        hd.hadamard(6)


@pytest.mark.parametrize("d", [256, 704])
def test_rotation_orthonormal(d):
    r = hd.rotation_matrix(d)
    np.testing.assert_allclose(r @ r.T, np.eye(d), atol=1e-9)


@pytest.mark.parametrize("d", [256, 704])
def test_columns_have_mean_zero_except_first(d):
    """Paper Sec. III-D: columns contain an equal number of +1 and -1
    'with an infinitesimally small number of exceptions' (the all-ones
    column of the Sylvester factor)."""
    h = hd.hadamard(d)
    col_means = h.mean(axis=0)
    n_nonzero = int(np.sum(np.abs(col_means) > 1e-12))
    # Sylvester: exactly 1 (the all-ones column). Kronecker with a Paley-I
    # base: every base column has sum 2, so the non-zero-mean columns are
    # those paired with the Sylvester all-ones column -> d/16 for 704.
    assert n_nonzero <= max(1, d // 16)


@pytest.mark.parametrize("d", [64, 256])
def test_rotation_preserves_norms(d):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(16, d))
    r = hd.rotation_matrix(d)
    np.testing.assert_allclose(
        np.linalg.norm(x @ r, axis=1), np.linalg.norm(x, axis=1), rtol=1e-9
    )
