"""Pallas matmul + smoothing kernels vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, ref, smooth

DIMS = st.sampled_from([(8, 16, 4), (32, 64, 16), (128, 256, 64), (128, 704, 32), (5, 11, 3)])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)
ALPHAS = st.sampled_from([0.3, 0.5, 0.65, 0.7, 0.9])


def _xw(dims, seed):
    n, c_in, c_out = dims
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, c_in)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(c_in, c_out)).astype(np.float32))
    return x, w


@settings(max_examples=20, deadline=None)
@given(dims=DIMS, seed=SEEDS)
def test_matmul_matches_ref(dims, seed):
    x, w = _xw(dims, seed)
    np.testing.assert_allclose(matmul.matmul(x, w), ref.matmul(x, w), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(dims=DIMS, seed=SEEDS, alpha=ALPHAS)
def test_smooth_scales_match_ref(dims, seed, alpha):
    x, w = _xw(dims, seed)
    np.testing.assert_allclose(
        smooth.smooth_scales(x, w, alpha), ref.smooth_scales(x, w, alpha), rtol=1e-5
    )


@settings(max_examples=20, deadline=None)
@given(dims=DIMS, seed=SEEDS, alpha=ALPHAS)
def test_smooth_apply_preserves_product(dims, seed, alpha):
    """Equivalence (Eq. 3): X W == (X diag(s)^-1)(diag(s) W)."""
    x, w = _xw(dims, seed)
    s = smooth.smooth_scales(x, w, alpha)
    xh, wh = smooth.smooth_apply(x, w, s)
    np.testing.assert_allclose(xh @ wh, x @ w, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(dims=DIMS, seed=SEEDS)
def test_smooth_equalizes_maxima_at_half(dims, seed):
    """At alpha=0.5 the channel maxima of X_hat and W_hat both become
    sqrt(max|X_j| * max|W_j|) (paper Sec. IV-C)."""
    x, w = _xw(dims, seed)
    s = smooth.smooth_scales(x, w, 0.5)
    xh, wh = smooth.smooth_apply(x, w, s)
    expected = np.sqrt(
        np.max(np.abs(np.asarray(x)), axis=0) * np.max(np.abs(np.asarray(w)), axis=1)
    )
    np.testing.assert_allclose(np.max(np.abs(np.asarray(xh)), axis=0), expected, rtol=1e-4)
    np.testing.assert_allclose(np.max(np.abs(np.asarray(wh)), axis=1), expected, rtol=1e-4)


def test_smooth_zero_channel_safe():
    """A channel that is all-zero on either side must not produce NaNs."""
    x = jnp.asarray(np.array([[0.0, 1.0], [0.0, -2.0]], dtype=np.float32))
    w = jnp.asarray(np.array([[1.0, 1.0], [0.5, 0.5]], dtype=np.float32))
    s = smooth.smooth_scales(x, w, 0.5)
    xh, wh = smooth.smooth_apply(x, w, s)
    assert np.all(np.isfinite(np.asarray(xh)))
    assert np.all(np.isfinite(np.asarray(wh)))
    np.testing.assert_allclose(xh @ wh, x @ w, rtol=1e-4, atol=1e-5)
