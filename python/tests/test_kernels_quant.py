"""Pallas RTN quantization kernels vs the pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant, ref

SHAPES = st.sampled_from([(8, 16), (32, 64), (128, 256), (128, 704), (7, 44), (1, 8)])
BITS = st.sampled_from([2, 3, 4, 8])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.normal(size=shape) * scale).astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(shape=SHAPES, bits=BITS, seed=SEEDS)
def test_qdq_per_token_matches_ref(shape, bits, seed):
    x = _rand(shape, seed)
    np.testing.assert_allclose(
        quant.qdq_per_token(x, bits), ref.qdq_per_token(x, bits), rtol=1e-5, atol=1e-6
    )


@settings(max_examples=25, deadline=None)
@given(shape=SHAPES, bits=BITS, seed=SEEDS)
def test_qdq_per_channel_matches_ref(shape, bits, seed):
    w = _rand(shape, seed)
    np.testing.assert_allclose(
        quant.qdq_per_channel(w, bits), ref.qdq_per_channel(w, bits), rtol=1e-5, atol=1e-6
    )


@settings(max_examples=15, deadline=None)
@given(shape=SHAPES, seed=SEEDS)
def test_scales_match_ref(shape, seed):
    x = _rand(shape, seed)
    np.testing.assert_allclose(quant.token_scales(x), ref.token_scales(x), rtol=1e-6)
    np.testing.assert_allclose(quant.channel_scales(x), ref.channel_scales(x), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(shape=SHAPES, bits=BITS, seed=SEEDS)
def test_qdq_idempotent(shape, bits, seed):
    """Q(Q(X)) == Q(X): dequantized values lie exactly on the grid."""
    x = _rand(shape, seed)
    q1 = quant.qdq_per_token(x, bits)
    q2 = quant.qdq_per_token(q1, bits)
    np.testing.assert_allclose(q1, q2, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(shape=SHAPES, bits=BITS, seed=SEEDS)
def test_qdq_error_bounded_by_half_step(shape, bits, seed):
    x = _rand(shape, seed)
    delta = np.asarray(ref.token_scales(x, bits))
    err = np.abs(np.asarray(quant.qdq_per_token(x, bits)) - np.asarray(x))
    assert np.all(err <= delta / 2 + 1e-5)


def test_qdq_zero_tensor():
    x = jnp.zeros((16, 32), jnp.float32)
    np.testing.assert_array_equal(quant.qdq_per_token(x), x)
    np.testing.assert_array_equal(quant.qdq_per_channel(x), x)


def test_qdq_levels_count():
    """4-bit symmetric grid has at most 15 distinct levels (+/-7 * Delta)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(1, 257)).astype(np.float32))
    q = np.asarray(quant.qdq_per_token(x, bits=4))
    assert len(np.unique(q)) <= 15


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_extremes_map_to_extremes(bits):
    x = jnp.asarray(np.array([[1.0, -1.0, 0.5, 0.0]], dtype=np.float32))
    q = np.asarray(quant.qdq_per_token(x, bits=bits))
    assert q[0, 0] == pytest.approx(1.0)
    assert q[0, 1] == pytest.approx(-1.0)
