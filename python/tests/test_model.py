"""SynLlama capture tests: shapes, determinism, outlier calibration."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import config, model


@pytest.fixture(scope="module")
def small_cfg():
    return config.SynLlamaConfig(
        n_layers=4, d_model=64, n_heads=4, d_ffn=176, vocab=64, seq_len=32,
        massive_layers=(1, 2), tail_layer=3, tail_tokens=8, tail_channels=4,
        attn_sys_channels=4, oproj_sys_channels=4, ffn_sys_channels=8, down_sys_channels=8,
        wout_layer=3,
    )


@pytest.fixture(scope="module")
def small_capture(small_cfg):
    p = model.init_params(small_cfg)
    pj = {k: jnp.asarray(v) for k, v in p.items()}
    toks = jnp.asarray(model.make_tokens(small_cfg))
    fwd = jax.jit(functools.partial(model.forward_capture, n_heads=small_cfg.n_heads))
    return p, fwd(pj, toks)


def test_capture_shapes(small_cfg, small_capture):
    _, caps = small_capture
    L, n, d, f = small_cfg.n_layers, small_cfg.seq_len, small_cfg.d_model, small_cfg.d_ffn
    assert caps[0].shape == (L, n, d)  # attn_in
    assert caps[1].shape == (L, n, d)  # o_in
    assert caps[2].shape == (L, n, d)  # ffn_in
    assert caps[3].shape == (L, n, f)  # down_in


def test_params_deterministic(small_cfg):
    p1 = model.init_params(small_cfg)
    p2 = model.init_params(small_cfg)
    for k in model.PARAM_ORDER:
        np.testing.assert_array_equal(p1[k], p2[k])


def test_params_shapes_match_specs(small_cfg):
    p = model.init_params(small_cfg)
    specs = model.param_specs(small_cfg)
    for k in model.PARAM_ORDER:
        assert tuple(p[k].shape) == tuple(specs[k].shape), k


def test_massive_outliers_present(small_cfg, small_capture):
    _, caps = small_capture
    down_in = np.asarray(caps[3])
    for l in small_cfg.massive_layers:
        assert np.abs(down_in[l]).max() > 0.8 * small_cfg.massive_value
    # massive outliers are token-specific: only few rows carry them
    l = small_cfg.massive_layers[0]
    hot_rows = np.sum(np.abs(down_in[l]).max(axis=1) > 0.5 * small_cfg.massive_value)
    assert hot_rows <= small_cfg.massive_tokens


def test_systematic_outliers_present(small_cfg, small_capture):
    """Hot channels are hot across (almost) ALL tokens at late layers."""
    _, caps = small_capture
    attn_in = np.asarray(caps[0])
    l = small_cfg.n_layers // 2  # peak of the sine profile
    mags = np.abs(attn_in[l])
    ch_medians = np.median(mags, axis=0)
    hot = ch_medians > 5 * np.median(ch_medians)
    assert hot.sum() >= small_cfg.attn_sys_channels // 2


def test_tokens_deterministic_and_in_range(small_cfg):
    t1, t2 = model.make_tokens(small_cfg), model.make_tokens(small_cfg)
    np.testing.assert_array_equal(t1, t2)
    assert t1.dtype == np.int32
    assert t1.min() >= 0 and t1.max() < small_cfg.vocab


def test_forward_is_finite(small_capture):
    _, caps = small_capture
    for c in caps:
        assert np.all(np.isfinite(np.asarray(c)))


def test_gate_weight_outliers(small_cfg):
    p = model.init_params(small_cfg)
    wg = p["wg"]
    row_norms = np.linalg.norm(wg[small_cfg.wout_layer], axis=1)
    base_norms = np.linalg.norm(wg[0], axis=1)
    assert row_norms.max() > 4 * base_norms.max()


def test_default_config_analyze_shapes():
    cfg = config.default_config()
    assert cfg.analyze_shapes() == [(256, 256), (256, 704), (704, 256)]
    assert cfg.d_head * cfg.n_heads == cfg.d_model
