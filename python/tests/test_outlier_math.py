"""Empirical validation of the paper's outlier formulas (Eq. 6-9).

These tests build the massive-outlier token model of Eq. 6 and check the
claims of Sec. IV-D / IV-E:

* Eq. 7: the rotated token clusters around 2^(|O|-1) centroid magnitudes,
* Eq. 8: max|t_hat| = sum_i |o_i| / sqrt(d) + O(eps),
* Eq. 9: after smoothing (alpha=0.5) + rotation the max drops to about
  sum_i sqrt(|o_i| * max|W_i| / d).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import hadamard as hd


def _token(d, outlier_dims, outlier_vals, sigma, seed):
    rng = np.random.default_rng(seed)
    t = rng.normal(scale=sigma, size=d)
    t[outlier_dims] = outlier_vals
    return t


@settings(max_examples=25, deadline=None)
@given(
    dpow=st.integers(min_value=6, max_value=10),
    n_out=st.integers(min_value=1, max_value=5),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_eq8_rotated_max(dpow, n_out, seed):
    d = 2**dpow
    rng = np.random.default_rng(seed + 1)
    dims = rng.choice(d, size=n_out, replace=False)
    vals = rng.choice([-1.0, 1.0], size=n_out) * (1000.0 + 500.0 * rng.random(n_out))
    sigma = 0.5
    t = _token(d, dims, vals, sigma, seed)
    r = hd.rotation_matrix(d)
    t_hat = t @ r
    predicted = np.sum(np.abs(vals)) / np.sqrt(d)
    # max|t_hat| = predicted + |eps|; eps ~ N(0, sigma) -> allow 6 sigma
    assert abs(np.max(np.abs(t_hat)) - predicted) < 6 * sigma


@settings(max_examples=15, deadline=None)
@given(
    n_out=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_eq7_cluster_count(n_out, seed):
    """Rotated values concentrate near at most 2^(|O|-1) magnitude levels."""
    d = 512
    rng = np.random.default_rng(seed + 2)
    dims = rng.choice(d, size=n_out, replace=False)
    vals = rng.choice([-1.0, 1.0], size=n_out) * (2000.0 + 1000.0 * rng.random(n_out))
    t = _token(d, dims, vals, 0.01, seed)
    t_hat = t @ hd.rotation_matrix(d)
    # centroid magnitudes: |sum_i h_i o_i| / sqrt(d) over all sign choices
    from itertools import product

    centroids = {
        round(abs(sum(s * abs(v) for s, v in zip(signs, vals))) / np.sqrt(d), 3)
        for signs in product([-1, 1], repeat=n_out)
    }
    assert len(centroids) <= 2 ** (n_out - 1) + 1  # +1 for degenerate collisions
    # every rotated value sits near one centroid
    mags = np.abs(t_hat)
    dist = np.min(np.abs(mags[:, None] - np.array(sorted(centroids))[None, :]), axis=1)
    assert np.max(dist) < 0.5  # sigma=0.01 -> tight clusters


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_eq9_smooth_rotate_max(seed):
    """Smoothing then rotating spreads outliers across 2d dims (Eq. 9)."""
    d, n_out = 512, 3
    rng = np.random.default_rng(seed + 3)
    dims = rng.choice(d, size=n_out, replace=False)
    vals = rng.choice([-1.0, 1.0], size=n_out) * (3000.0 + 1000.0 * rng.random(n_out))
    sigma = 0.5
    t = _token(d, dims, vals, sigma, seed)
    x = np.vstack([t, rng.normal(scale=sigma, size=(7, d))])  # t plus benign tokens
    w = rng.normal(scale=0.05, size=(d, 128))

    # smooth with alpha = 0.5 (paper's fixed sweet spot)
    xmax = np.maximum(np.abs(x).max(axis=0), 1e-12)
    wmax = np.maximum(np.abs(w).max(axis=1), 1e-12)
    s = np.sqrt(xmax / wmax)
    t_tilde = (t / s) @ hd.rotation_matrix(d)

    predicted = np.sum(np.sqrt(np.abs(vals) * wmax[dims] / d))
    got = np.max(np.abs(t_tilde))
    # Eq. 9 is approximate ("~"): accept within a factor of 2 + noise floor
    assert got < 2.0 * predicted + 6 * sigma
    assert got > 0.3 * predicted - 6 * sigma


def test_smooth_rotate_beats_rotate_on_massive_outliers():
    """The paper's core claim: with massive outliers present, rotation
    alone leaves a much larger max than smooth+rotate."""
    d = 704
    rng = np.random.default_rng(9)
    t = rng.normal(scale=0.5, size=d)
    dims = rng.choice(d, size=8, replace=False)
    t[dims] = rng.choice([-1.0, 1.0], size=8) * 6000.0
    x = np.vstack([t, rng.normal(scale=0.5, size=(127, d))])
    w = rng.normal(scale=0.05, size=(d, 256))
    r = hd.rotation_matrix(d)

    max_rot = np.abs(x @ r).max()
    xmax = np.maximum(np.abs(x).max(axis=0), 1e-12)
    wmax = np.maximum(np.abs(w).max(axis=1), 1e-12)
    s = np.sqrt(xmax / wmax)
    max_sr = np.abs((x / s) @ r).max()
    assert max_sr < 0.25 * max_rot
