"""Fused quantization-error kernel (the L1 hot path) vs the oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import qerror, ref

DIMS = st.sampled_from([(8, 16, 4), (32, 64, 16), (128, 256, 256), (128, 704, 256), (128, 256, 704)])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)
BITS = st.sampled_from([2, 4, 8])


def _xw(dims, seed, outlier=False):
    n, c_in, c_out = dims
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, c_in)).astype(np.float32)
    if outlier:
        x[rng.integers(n), rng.integers(c_in)] = 1000.0
    w = rng.normal(size=(c_in, c_out)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w)


@settings(max_examples=20, deadline=None)
@given(dims=DIMS, seed=SEEDS, bits=BITS)
def test_quant_error_matches_ref(dims, seed, bits):
    x, w = _xw(dims, seed)
    got = qerror.quant_error(x, w, bits)
    want = ref.quant_error(x, w, bits)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=1e-2)


@settings(max_examples=10, deadline=None)
@given(dims=DIMS, seed=SEEDS)
def test_quant_error_with_massive_outlier(dims, seed):
    x, w = _xw(dims, seed, outlier=True)
    np.testing.assert_allclose(
        qerror.quant_error(x, w), ref.quant_error(x, w), rtol=2e-3, atol=1e-2
    )


@settings(max_examples=10, deadline=None)
@given(dims=DIMS, seed=SEEDS)
def test_partials_sum_to_total(dims, seed):
    x, w = _xw(dims, seed)
    partials = qerror.quant_error_partials(x, w)
    np.testing.assert_allclose(jnp.sum(partials), qerror.quant_error(x, w), rtol=1e-6)


def test_error_zero_when_exactly_representable():
    """X and W already on a 4-bit grid and small enough -> zero error."""
    x = jnp.asarray(np.array([[7.0, -7.0, 1.0, 0.0]], dtype=np.float32))
    w = jnp.asarray(np.array([[7.0], [1.0], [0.0], [-7.0]], dtype=np.float32))
    assert float(qerror.quant_error(x, w, bits=4)) < 1e-6


def test_error_decreases_with_bits():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(128, 32)).astype(np.float32))
    errs = [float(qerror.quant_error(x, w, bits=b)) for b in (2, 4, 8)]
    assert errs[0] > errs[1] > errs[2]
