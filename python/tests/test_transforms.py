"""Equivalent-transformation tests (paper Eq. 3, Sec. II-C)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import transforms
from compile.kernels import ref

DIMS = st.sampled_from([(16, 16, 8), (32, 64, 16), (128, 256, 256), (128, 704, 256)])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _xw(dims, seed):
    n, c_in, c_out = dims
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, c_in)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(c_in, c_out)).astype(np.float32))
    return x, w


@settings(max_examples=12, deadline=None)
@given(dims=DIMS, seed=SEEDS, mode=st.sampled_from(transforms.MODES))
def test_transform_preserves_product(dims, seed, mode):
    """Numerical equivalence X W == X_hat W_hat for every mode."""
    x, w = _xw(dims, seed)
    xh, wh = transforms.apply_transform(mode, x, w)
    y, yh = np.asarray(x @ w), np.asarray(xh @ wh)
    scale = max(1.0, float(np.abs(y).max()))
    np.testing.assert_allclose(yh / scale, y / scale, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(dims=DIMS, seed=SEEDS)
def test_rotation_preserves_frobenius_norm(dims, seed):
    x, w = _xw(dims, seed)
    xh, wh = transforms.apply_transform("rotate", x, w)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(xh)), np.linalg.norm(np.asarray(x)), rtol=1e-5
    )
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(wh)), np.linalg.norm(np.asarray(w)), rtol=1e-5
    )


def test_unknown_mode_raises():
    x, w = _xw((8, 16, 4), 0)
    with pytest.raises(ValueError):
        transforms.apply_transform("spin", x, w)


def test_rotation_flattens_systematic_outliers():
    """A hot channel is redistributed: the rotated channel-magnitude std
    (the paper's quantization difficulty) must drop a lot."""
    rng = np.random.default_rng(5)
    x = rng.normal(size=(64, 256)).astype(np.float32)
    x[:, 17] *= 50.0  # systematic outlier channel
    w = rng.normal(size=(256, 64)).astype(np.float32)
    x, w = jnp.asarray(x), jnp.asarray(w)
    xh, _ = transforms.apply_transform("rotate", x, w)
    assert float(ref.quant_difficulty(xh)) < 0.1 * float(ref.quant_difficulty(x))


def test_smoothing_migrates_difficulty_to_weights():
    """Smoothing flattens X but RAISES weight difficulty (Sec. IV-C)."""
    rng = np.random.default_rng(6)
    x = rng.normal(size=(64, 256)).astype(np.float32)
    x[:, 17] *= 50.0
    w = rng.normal(size=(256, 64)).astype(np.float32)
    x, w = jnp.asarray(x), jnp.asarray(w)
    xh, wh = transforms.apply_transform("smooth", x, w)
    assert float(ref.quant_difficulty(xh)) < float(ref.quant_difficulty(x))
    assert float(ref.quant_difficulty(wh, axis=1)) > float(ref.quant_difficulty(w, axis=1))


def test_rotation_lowers_weight_difficulty():
    """Rotation also redistributes weights (Sec. IV-D)."""
    rng = np.random.default_rng(7)
    w = rng.normal(size=(256, 64)).astype(np.float32)
    w[17, :] *= 20.0  # heavy input-channel row
    x = jnp.asarray(rng.normal(size=(64, 256)).astype(np.float32))
    _, wh = transforms.apply_transform("rotate", x, jnp.asarray(w))
    assert float(ref.quant_difficulty(wh, axis=1)) < float(ref.quant_difficulty(jnp.asarray(w), axis=1))


def test_alpha_extremes():
    """alpha=1 pushes all difficulty to W; alpha=0 all to X."""
    rng = np.random.default_rng(8)
    x = jnp.asarray((rng.normal(size=(32, 64)) * 10).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    xh1, _ = transforms.apply_transform("smooth", x, w, alpha=1.0)
    # alpha=1: s_j = max|X_j| -> X_hat channel maxima all 1
    np.testing.assert_allclose(np.max(np.abs(np.asarray(xh1)), axis=0), 1.0, rtol=1e-4)
