//! One bench per paper table/figure — each regenerates the figure's data
//! on the captured workload (PJRT artifacts when built, with the
//! native-mirror path timed alongside) and prints the series the paper
//! reports.  Run: `cargo bench --offline` (optionally `-- --filter fig4`).

use smoothrot::bench_harness::{black_box, Bench};
use smoothrot::coordinator::{NativeExecutor, PoolConfig};
use smoothrot::pipeline::{self, Backend};
use smoothrot::report;
use smoothrot::runtime::Runtime;
use smoothrot::transforms::Mode;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("SMOOTHROT_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("artifacts not built — run `make artifacts` for the full paper benches");
        None
    }
}

fn main() {
    let mut b = Bench::from_args();
    let Some(dir) = artifacts_dir() else {
        b.finish();
        return;
    };
    let rt = Runtime::new(&dir).expect("runtime");
    let cfg = rt.manifest().config.clone();
    let workload = pipeline::load_workload(&rt).expect("workload");

    // ---- Fig 1: k_proj layer-1 magnitudes under transforms -------------
    {
        let (x, w) = workload.pair(&rt, "k_proj", 1);
        let mut profiles = Vec::new();
        b.bench("fig1_kproj1_transform_magnitudes", || {
            profiles.clear();
            for mode in Mode::ALL {
                let (xh, _) = rt.transform(mode, &x, &w).expect("transform");
                profiles.push((mode, report::sorted_channel_magnitudes(&xh)));
            }
            black_box(&profiles);
        });
        for (mode, p) in &profiles {
            println!("    fig1 {:>14}: top|ch| {:.1}  median|ch| {:.2}", mode.name(), p[0], p[p.len() / 2]);
        }
    }

    // ---- Fig 2: down_proj layer-30 magnitudes under transforms ---------
    {
        let layer = cfg.massive_layers.last().copied().unwrap_or(30);
        let (x, w) = workload.pair(&rt, "down_proj", layer);
        let mut profiles = Vec::new();
        b.bench("fig2_downproj30_transform_magnitudes", || {
            profiles.clear();
            for mode in Mode::ALL {
                let (xh, _) = rt.transform(mode, &x, &w).expect("transform");
                profiles.push((mode, report::sorted_channel_magnitudes(&xh)));
            }
            black_box(&profiles);
        });
        for (mode, p) in &profiles {
            println!("    fig2 {:>14}: top|ch| {:.1}  median|ch| {:.2}", mode.name(), p[0], p[p.len() / 2]);
        }
    }

    // ---- Fig 3 + Fig 4 + §IV-B: the full grid ---------------------------
    {
        let mut corr = 0.0;
        let mut grid = None;
        b.bench_heavy("fig3_fig4_full_grid_pjrt", 2, || {
            let run = pipeline::run_full_experiment(
                &dir,
                PoolConfig { workers: 2, queue_cap: 64, threads: 1 },
                Backend::Pjrt,
            )
            .expect("experiment");
            let (c, _) = report::correlation_report(&run.grid, &cfg.massive_layers, cfg.tail_layer);
            corr = c;
            grid = Some(run.grid);
        });
        let grid = grid.unwrap();
        println!("    §IV-B corr(error, difficulty²) = {corr:.4} (paper: > 0.97)");
        for &l in &cfg.massive_layers {
            let o = grid.get("down_proj", l).unwrap();
            println!(
                "    fig4 down_proj {l}: none {:.2e} smooth {:.2e} rotate {:.2e} smooth_rotate {:.2e}",
                o.errors[0], o.errors[1], o.errors[2], o.errors[3]
            );
        }
        // native-mirror timing for the same grid
        b.bench_heavy("fig3_fig4_full_grid_native_mirror", 2, || {
            let run = pipeline::run_full_experiment(
                &dir,
                PoolConfig { workers: 2, queue_cap: 64, threads: 1 },
                Backend::Native,
            )
            .expect("experiment");
            black_box(run.metrics.jobs);
        });
    }

    // ---- Fig 5: outlier-token quantization bins -------------------------
    {
        let layer = cfg.massive_layers.last().copied().unwrap_or(30);
        let (x, w) = workload.pair(&rt, "down_proj", layer);
        let mut curves = Vec::new();
        b.bench("fig5_outlier_token_bins", || {
            curves.clear();
            for mode in [Mode::Rotate, Mode::SmoothRotate] {
                let (xh, _) = rt.transform(mode, &x, &w).expect("transform");
                curves.push((mode, report::fig5_data(&xh, cfg.bits)));
            }
            black_box(&curves);
        });
        for (mode, d) in &curves {
            println!(
                "    fig5 {:>14}: Delta {:.3e}, effective bins {}",
                mode.name(),
                d.delta,
                d.n_effective_bins
            );
        }
    }

    // ---- §IV-C: alpha sweep table ---------------------------------------
    {
        let alphas = [0.5, 0.65, 0.7];
        let mut table = Vec::new();
        b.bench_heavy("sec4c_alpha_sweep_oproj_gateproj", 2, || {
            table.clear();
            for module in ["o_proj", "gate_proj"] {
                let module: &'static str =
                    smoothrot::MODULES.into_iter().find(|m| *m == module).unwrap();
                let sweep =
                    pipeline::alpha_sweep(&rt, &workload, module, &alphas, cfg.bits, 0).expect("sweep");
                let totals: Vec<f64> = sweep.iter().map(|(_, e)| e.iter().sum()).collect();
                table.push((module, totals));
            }
            black_box(&table);
        });
        for (module, totals) in &table {
            println!(
                "    §IV-C {module}: alpha 0.5 -> {:.3e}, 0.65 -> {:.3e}, 0.7 -> {:.3e}",
                totals[0], totals[1], totals[2]
            );
        }
    }

    // ---- Eq. 7/8/9: outlier-model predictions ---------------------------
    {
        use smoothrot::outlier::OutlierToken;
        use smoothrot::rng::Rng;
        let mut rng = Rng::new(5);
        let tok = OutlierToken::sample(704, 8, 6000.0, 0.5, &mut rng);
        let x = tok.materialize_batch(128, &mut rng);
        let w = workload.pair(&rt, "down_proj", 30).1;
        let mut lines = Vec::new();
        b.bench("eq8_eq9_outlier_model_predictions", || {
            lines.clear();
            let (xr, _) = smoothrot::transforms::apply(Mode::Rotate, &x, &w, 0.5).unwrap();
            lines.push(format!(
                "Eq.8: predicted max {:.1} vs rotated max {:.1}",
                tok.predicted_rotated_max(),
                xr.abs_max()
            ));
            let (xsr, _) = smoothrot::transforms::apply(Mode::SmoothRotate, &x, &w, 0.5).unwrap();
            let mut wmax = vec![0.0f32; 704];
            for i in 0..704 {
                wmax[i] = w.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            }
            lines.push(format!(
                "Eq.9: predicted max {:.2} vs smooth-rotated max {:.2}",
                tok.predicted_smooth_rotated_max(&wmax),
                xsr.abs_max()
            ));
        });
        for l in &lines {
            println!("    {l}");
        }
    }

    // ---- extension: bit-width ablation ----------------------------------
    {
        let mut rows = Vec::new();
        b.bench_heavy("ablation_bitwidth_native", 2, || {
            rows = pipeline::bits_sweep(&rt, &workload, &[2, 4, 8], 0).expect("bits sweep");
        });
        for (bits, totals) in &rows {
            println!(
                "    W{bits}A{bits}: none {:.2e}  smooth_rotate {:.2e}  (ratio {:.1}x)",
                totals[0],
                totals[3],
                totals[0] / totals[3]
            );
        }
        let _ = NativeExecutor;
    }

    b.finish();
}
