//! Performance benches over the hot paths of each layer:
//!
//! * L3 native math: blocked matmul, quantizer, fused qerror kernel,
//!   Hadamard construction + application,
//! * L3 kernel engine: fused vs naive all-modes analyze, FWHT vs dense
//!   rotation, 1-vs-N-thread parallel matmul,
//! * L3 coordinator: scheduling overhead at varying worker counts,
//! * L3 integer execution: i8 / packed-i4 GEMM vs the f32 matmul + qdq
//!   simulation it replaces, the packed-tile register-blocked GEMM vs
//!   the row-major kernel, the runtime-dispatched SIMD microkernel vs
//!   the scalar reference over the same packed tiles, and per-token
//!   activation quantization,
//! * L3 serving core: batched vs unbatched dispatch throughput over the
//!   multi-tenant scheduler (native executors), plan-driven serve
//!   (calibrated transform per request) vs per-request four-mode
//!   analyze, int8 plan-driven serve (real integer GEMM over
//!   pre-quantized weights) vs the f32 qdq plan-driven path, and the
//!   headline ratio: **batch-fused** int8 serve (one stacked GEMM per
//!   coalesced batch) vs per-job int8 serve, and sharded multi-runner
//!   scaling (the same fused int8 stream at 1 / 2 / 4 shard-owning
//!   runners),
//! * wire tier: the same int8 stream through the HTTP/1.1 loopback
//!   front-end (accept, parse, submit, chunked NDJSON, drain) vs the
//!   in-process batch-fused path — the delta is pure wire machinery,
//! * runtime: PJRT execute latency for the analyze/transform artifacts
//!   (the end-to-end request-path unit).
//!
//! CI runs this binary with `--smoke` (minimal iterations) so kernel
//! regressions fail loudly without timing flakiness.  The §Perf section
//! of EXPERIMENTS.md quotes the full-run numbers.  Every run also
//! writes a machine-readable `BENCH_10.json` **at the repo root** (the
//! committed bench-trajectory artifact; override the path with
//! `BENCH_JSON=...`).

use smoothrot::bench_harness::{black_box, Bench};
use smoothrot::coordinator::{run_jobs, Executor, Job, NativeExecutor, PoolConfig};
use smoothrot::kernels::fused::analyze_all_modes;
use smoothrot::kernels::par::resolve_threads;
use smoothrot::kernels::workspace::Workspace;
use smoothrot::quant::{self, Granularity};
use smoothrot::rng::Rng;
use smoothrot::runtime::{AnalyzeOut, Runtime};
use smoothrot::tensor::Matrix;
use smoothrot::transforms::{self, Mode, Rotation, RotationCache};

fn rand_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_vec(rows, cols, rng.normals_f32(rows * cols))
}

fn main() {
    let mut b = Bench::from_args();

    // ---- L3 native math hot paths --------------------------------------
    let x = rand_matrix(128, 704, 1);
    let w = rand_matrix(704, 256, 2);
    let flops = 2.0 * 128.0 * 704.0 * 256.0;

    b.bench_items("native_matmul_128x704x256", flops, || {
        black_box(x.matmul(&w));
    });

    b.bench_items("native_qdq_per_token_128x704", (128 * 704) as f64, || {
        black_box(quant::qdq(&x, 4, Granularity::PerToken));
    });

    b.bench_items("native_qerror_two_matmuls", 2.0 * flops, || {
        black_box(quant::quant_error(&x, &w, 4));
    });

    b.bench_items("native_qerror_fused_single_pass", 2.0 * flops, || {
        black_box(quant::quant_error_fused(&x, &w, 4));
    });

    b.bench("hadamard_construct_704_kronecker_paley", || {
        black_box(transforms::hadamard(704).unwrap());
    });

    b.bench("hadamard_construct_256_sylvester", || {
        black_box(transforms::hadamard(256).unwrap());
    });

    let r704 = transforms::rotation(704).unwrap();
    b.bench_items("rotate_apply_dense_128x704", 2.0 * 128.0 * 704.0 * 704.0, || {
        black_box(x.matmul(&r704));
    });

    // FWHT path: same rotation, O(d log d) per row instead of O(d^2)
    let rot704 = Rotation::build(704).unwrap();
    assert!(rot704.is_fwht());
    b.bench_items("rotate_apply_fwht_128x704", 2.0 * 128.0 * 704.0 * 704.0, || {
        black_box(rot704.apply_right(&x, 1));
    });

    b.bench("smooth_scales_and_apply_128x704", || {
        let s = transforms::smooth_scales(&x, &w, 0.5);
        black_box(transforms::smooth_apply(&x, &w, &s));
    });

    // ---- integer execution: i8 / packed-i4 GEMM vs the f32 simulation --
    {
        use smoothrot::kernels::igemm::{igemm_into, igemm_packed_into};
        use smoothrot::qtensor::{PackedWeight, QMatrix, ScaleAxis};
        let mut iws = Workspace::new();
        let qx8 = QMatrix::quantize(&x, 8, ScaleAxis::PerRow).unwrap();
        let qw8 = QMatrix::quantize(&w, 8, ScaleAxis::PerCol).unwrap();
        let mut out = vec![0.0f32; 128 * 256];
        let rowmajor_med = b
            .bench_items("igemm_i8_128x704x256", flops, || {
                igemm_into(&mut out, &qx8, &qw8, &mut iws, 1).unwrap();
                black_box(out[0]);
            })
            .map(|m| m.median());
        // the serving layout: weight tiles packed once, register-blocked
        // microkernel, no i32 accumulator plane
        let pw8 =
            PackedWeight::pack(&QMatrix::quantize_i8(&w, 8, ScaleAxis::PerCol).unwrap()).unwrap();
        let packed_med = b
            .bench_items("igemm_i8_packed_128x704x256", flops, || {
                igemm_packed_into(&mut out, &qx8, &pw8, &mut iws, 1).unwrap();
                black_box(out[0]);
            })
            .map(|m| m.median());
        if let (Some(r), Some(p)) = (rowmajor_med, packed_med) {
            println!(
                "    -> packed-tile igemm vs row-major igemm: {:.2}x",
                r.as_secs_f64() / p.as_secs_f64()
            );
        }
        // runtime-dispatched SIMD microkernel vs a scalar-pinned run
        // over the SAME packed tiles.  Outputs are bit-identical
        // (pinned by tests/differential_kernels.rs), so the ratio is
        // pure kernel throughput.  On a host without AVX2/NEON both
        // scenarios run scalar and the ratio prints ~1.00x.
        use smoothrot::kernels::igemm::igemm_packed_into_with;
        use smoothrot::kernels::simd::KernelBackend;
        let simd_be = KernelBackend::detect();
        let scalar_med = b
            .bench_items("igemm_i8_packed_scalar_128x704x256", flops, || {
                igemm_packed_into_with(&mut out, &qx8, &pw8, &mut iws, 1, KernelBackend::Scalar)
                    .unwrap();
                black_box(out[0]);
            })
            .map(|m| m.median());
        let simd_med = b
            .bench_items("igemm_i8_simd_vs_scalar", flops, || {
                igemm_packed_into_with(&mut out, &qx8, &pw8, &mut iws, 1, simd_be).unwrap();
                black_box(out[0]);
            })
            .map(|m| m.median());
        if let (Some(s), Some(v)) = (scalar_med, simd_med) {
            println!(
                "    -> packed igemm, {simd_be} kernels vs scalar: {:.2}x",
                s.as_secs_f64() / v.as_secs_f64()
            );
        }
        let qx4 = QMatrix::quantize(&x, 4, ScaleAxis::PerRow).unwrap();
        let qw4 = QMatrix::quantize(&w, 4, ScaleAxis::PerCol).unwrap();
        b.bench_items("igemm_i4_packed_128x704x256", flops, || {
            igemm_into(&mut out, &qx4, &qw4, &mut iws, 1).unwrap();
            black_box(out[0]);
        });
        b.bench_items("quantize_rows_i8_128x704", (128 * 704) as f64, || {
            let q = QMatrix::quantize_i8_with(&x, 8, ScaleAxis::PerRow, &mut iws).unwrap();
            black_box(q.scales()[0]);
            q.recycle(&mut iws);
        });
    }

    // ---- kernel engine: fused vs naive analyze, 1 vs N threads ----------
    let auto_threads = resolve_threads(0);
    let naive_med = b
        .bench("analyze_naive_per_mode_704x256", || {
            black_box(NativeExecutor::analyze_naive(&x, &w, 4, 0.5).unwrap());
        })
        .map(|m| m.median());
    let mut cache = RotationCache::new();
    let mut scratch = Workspace::new();
    b.bench("analyze_fused_704x256_t1", || {
        black_box(analyze_all_modes(&x, &w, 4, 0.5, &mut cache, &mut scratch, 1).unwrap());
    });
    let mut cache_n = RotationCache::new();
    let mut scratch_n = Workspace::new();
    let fused_med = b
        .bench(&format!("analyze_fused_704x256_t{auto_threads}"), || {
            black_box(
                analyze_all_modes(&x, &w, 4, 0.5, &mut cache_n, &mut scratch_n, auto_threads)
                    .unwrap(),
            );
        })
        .map(|m| m.median());
    if let (Some(naive), Some(fused)) = (naive_med, fused_med) {
        println!(
            "    -> fused multi-threaded analyze vs naive single-threaded: {:.2}x",
            naive.as_secs_f64() / fused.as_secs_f64()
        );
    }

    b.bench_items("par_matmul_128x704x256_t1", flops, || {
        black_box(x.matmul_threaded(&w, 1));
    });
    b.bench_items(&format!("par_matmul_128x704x256_t{auto_threads}"), flops, || {
        black_box(x.matmul_threaded(&w, auto_threads));
    });

    b.bench("native_analyze_all_modes_704x256", || {
        black_box(NativeExecutor::analyze(&x, &w, 4, 0.5).unwrap());
    });

    // ---- L3 coordinator overhead ----------------------------------------
    struct NoopExec;
    impl Executor for NoopExec {
        fn run(&mut self, _job: &Job) -> Result<AnalyzeOut, String> {
            Ok(AnalyzeOut::default())
        }
    }
    for workers in [1usize, 2, 4] {
        let name = format!("coordinator_noop_512_jobs_w{workers}");
        b.bench_items(&name, 512.0, || {
            let jobs: Vec<Job> = (0..512)
                .map(|i| Job {
                    id: i,
                    layer: 0,
                    module: "k_proj",
                    x: Matrix::zeros(1, 1),
                    w: Matrix::zeros(1, 1),
                    alpha: 0.5,
                    bits: 4,
                })
                .collect();
            let (r, _) =
                run_jobs(jobs, PoolConfig { workers, queue_cap: 64, threads: 1 }, |_| Ok(NoopExec))
                    .unwrap();
            black_box(r.len());
        });
    }

    // ---- serving core: batched vs unbatched dispatch --------------------
    // Four tenants submit 256 same-key analysis requests; the paused
    // config queues everything up front so batch formation is
    // deterministic, and the two runs differ only in max_batch.  Small
    // matrices keep the jobs dispatch-dominated — the regime batching
    // is for.
    {
        use smoothrot::serve::{serve_all, NativeBatchExecutor, ServeConfig};
        let n = 256usize;
        let base: Vec<(usize, Job)> = (0..n)
            .map(|i| {
                let job = Job {
                    id: i as u64,
                    layer: i % 8,
                    module: "k_proj",
                    x: rand_matrix(16, 64, 100 + i as u64),
                    w: rand_matrix(64, 16, 200 + i as u64),
                    alpha: 0.5,
                    bits: 4,
                };
                (i % 4, job)
            })
            .collect();
        let mut medians = Vec::new();
        for max_batch in [1usize, 16] {
            let cfg = ServeConfig {
                workers: 2,
                max_batch,
                queue_depth: n,
                paused: true,
                ..ServeConfig::default()
            };
            let name = format!("serve_native_256req_4tenants_batch{max_batch}");
            let reqs = base.clone();
            let med = b
                .bench_items(&name, n as f64, move || {
                    let (_, metrics) =
                        serve_all(cfg, reqs.clone(), |_| Ok(NativeBatchExecutor::new())).unwrap();
                    assert_eq!(metrics.completed as usize, n);
                    black_box(metrics.batches);
                })
                .map(|m| m.median());
            medians.push(med);
        }
        if let (Some(Some(unbatched)), Some(Some(batched))) =
            (medians.first().copied(), medians.get(1).copied())
        {
            println!(
                "    -> batching speedup (max-batch 16 vs 1): {:.2}x",
                unbatched.as_secs_f64() / batched.as_secs_f64()
            );
        }
    }

    // ---- plan-driven serve vs per-request analyze ------------------------
    // Calibrate k_proj once (streaming stats -> plan search -> registry),
    // then serve the same request stream twice: the baseline executor
    // runs the four-mode analyze per request; the plan-driven executor
    // runs only the calibrated transform.  Same scheduler, same batches
    // — the delta is exactly the per-request transform search the plan
    // eliminates (ISSUE acceptance: plan-driven must be strictly
    // faster).
    {
        use smoothrot::calib::plan::{Provenance, QuantPlan};
        use smoothrot::calib::registry::PlanRegistry;
        use smoothrot::calib::search::{search_layer, SearchConfig};
        use smoothrot::calib::stats::LayerCollector;
        use smoothrot::serve::{serve_all, NativeBatchExecutor, ServeConfig};
        use std::sync::Arc;

        let n_layers = 8usize;
        let mut entries = Vec::new();
        let mut cal_cache = RotationCache::new();
        let mut cal_ws = Workspace::new();
        for layer in 0..n_layers {
            let (mut spec, c_out) = smoothrot::synth::module_stream("k_proj", 400).unwrap();
            spec.n_tokens = 64;
            let xl = spec.layer(layer);
            let wl = spec.weight(c_out, layer);
            let mut c = LayerCollector::new(xl.cols(), 0);
            c.observe(&xl).unwrap();
            let found = search_layer(
                "k_proj",
                layer,
                &c,
                &wl,
                &SearchConfig::default(),
                &mut cal_cache,
                &mut cal_ws,
            )
            .unwrap();
            entries.extend(found.entries);
        }
        let plan = QuantPlan { provenance: Provenance::default(), entries };
        let registry = Arc::new(PlanRegistry::from_plan(&plan).unwrap());

        // serving weights are the calibration stream's fixed per-layer
        // weights (seed 400): activations vary per request, the model
        // does not — which is what lets the int8 registry pre-quantize
        // each layer's weight once below.  Arrival order is
        // layer-BLOCKED (concurrent requests sit at the same depth,
        // like lockstep forward passes over one model): the scheduler's
        // FIFO key-coalescing then forms layer-pure batches, i.e. each
        // batch is one plan cell — the regime the batch-fused executor
        // turns into a single stacked GEMM.
        let n = 96usize;
        let base: Vec<(usize, Job)> = (0..n)
            .map(|i| {
                let layer = (i * n_layers) / n;
                let (mut spec, _) =
                    smoothrot::synth::module_stream("k_proj", 500 + i as u64).unwrap();
                spec.n_tokens = 32;
                let job = Job {
                    id: i as u64,
                    layer,
                    module: "k_proj",
                    x: spec.layer(layer),
                    w: smoothrot::synth::layer_weight("k_proj", layer, 400).unwrap(),
                    alpha: 0.5,
                    bits: 4,
                };
                (i % 4, job)
            })
            .collect();
        let cfg = ServeConfig {
            workers: 2,
            max_batch: 8,
            queue_depth: n,
            paused: true,
            ..ServeConfig::default()
        };

        let analyze_med = {
            let reqs = base.clone();
            b.bench_items("serve_analyze_per_request_96req", n as f64, move || {
                let (_, m) =
                    serve_all(cfg, reqs.clone(), |_| Ok(NativeBatchExecutor::new())).unwrap();
                assert_eq!(m.completed as usize, n);
                black_box(m.batches);
            })
            .map(|m| m.median())
        };
        let plan_med = {
            let reqs = base.clone();
            let reg_outer = Arc::clone(&registry);
            b.bench_items("serve_plan_driven_96req", n as f64, move || {
                let reg = Arc::clone(&reg_outer);
                let (_, m) = serve_all(cfg, reqs.clone(), move |_| {
                    Ok(NativeBatchExecutor::with_plan(Arc::clone(&reg), 1))
                })
                .unwrap();
                assert_eq!(m.completed as usize, n);
                black_box(m.batches);
            })
            .map(|m| m.median())
        };
        if plan_med.is_some() {
            let (planned, fallback) = registry.stats();
            assert!(planned > 0 && fallback == 0, "plan must cover every benched request");
        }
        if let (Some(a), Some(p)) = (analyze_med, plan_med) {
            println!(
                "    -> plan-driven serve vs per-request analyze: {:.2}x",
                a.as_secs_f64() / p.as_secs_f64()
            );
        }

        // int8 plan-driven serve: same scheduler, same requests, same
        // plan — but covered cells run the REAL integer pipeline
        // (pre-quantized i8 weights + i32-accumulated GEMM) instead
        // of f32 quantize-dequantize + f32 matmuls.  ISSUE 4
        // acceptance: this must beat the f32 qdq scenario above.
        // Batch fusion is DISABLED here: this scenario is the per-job
        // integer baseline the batch-fused scenario below is measured
        // against.
        use smoothrot::serve::ExecMode;
        let loaded = registry
            .set_weight_provider(Box::new(|module, layer| {
                smoothrot::synth::layer_weight(module, layer, 400)
            }))
            .unwrap();
        assert!(loaded > 0, "int8 preload must cover the benched plan");
        let int_med = {
            let reqs = base.clone();
            let reg_outer = Arc::clone(&registry);
            b.bench_items("serve_plan_int8_96req", n as f64, move || {
                let reg = Arc::clone(&reg_outer);
                let (_, m) = serve_all(cfg, reqs.clone(), move |_| {
                    Ok(NativeBatchExecutor::with_plan_exec(Arc::clone(&reg), 1, ExecMode::Int8)
                        .with_batch_fusion(false))
                })
                .unwrap();
                assert_eq!(m.completed as usize, n);
                black_box(m.batches);
            })
            .map(|m| m.median())
        };
        if int_med.is_some() {
            // the ratio below is only honest if the int8 scenario
            // actually executed integer GEMMs (no silent f32 fallback)
            // — and the per-job baseline must never have stacked
            let (executed, degraded) = registry.int8_stats();
            assert!(
                executed > 0 && degraded == 0,
                "int8 bench degraded to f32: {executed} executed / {degraded} degraded"
            );
            assert_eq!(registry.batch_fused(), 0, "per-job baseline must not batch-fuse");
        }
        if let (Some(f), Some(i)) = (plan_med, int_med) {
            println!(
                "    -> int8 plan-driven serve vs f32 qdq plan-driven: {:.2}x",
                f.as_secs_f64() / i.as_secs_f64()
            );
        }

        // the ISSUE 5 headline: the SAME int8 scenario with stacked
        // batch fusion (default) — each coalesced same-cell group runs
        // as one tall transform + quantize + integer GEMM instead of
        // per-job kernel dispatches.  Bit-identical outputs (pinned in
        // proptest_batchfused.rs); the delta is pure execution
        // efficiency.
        let fused_med = {
            let reqs = base.clone();
            let reg_outer = Arc::clone(&registry);
            b.bench_items("serve_plan_int8_batchfused_96req", n as f64, move || {
                let reg = Arc::clone(&reg_outer);
                let (_, m) = serve_all(cfg, reqs.clone(), move |_| {
                    Ok(NativeBatchExecutor::with_plan_exec(Arc::clone(&reg), 1, ExecMode::Int8))
                })
                .unwrap();
                assert_eq!(m.completed as usize, n);
                black_box(m.batches);
            })
            .map(|m| m.median())
        };
        if fused_med.is_some() {
            assert!(
                registry.batch_fused() > 0,
                "batch-fused bench silently fell back to per-job execution"
            );
        }
        if let (Some(pj), Some(fu)) = (int_med, fused_med) {
            println!(
                "    -> batch-fused int8 serve vs per-job int8 serve: {:.2}x",
                pj.as_secs_f64() / fu.as_secs_f64()
            );
        }

        // the same batch-fused int8 scenario with the kernel backend
        // explicitly pinned to the best SIMD path this host detects.
        // The default scenario above follows the session resolution
        // (SMOOTHROT_KERNEL or auto-detect), so under a scalar-pinned
        // session (the CI scalar leg sets SMOOTHROT_KERNEL=scalar) the
        // ratio below is a true end-to-end SIMD-vs-scalar serve delta;
        // under auto both run the same backend and it prints ~1.00x.
        let simd_be = smoothrot::kernels::simd::KernelBackend::detect();
        let simd_serve_med = {
            let reqs = base.clone();
            let reg_outer = Arc::clone(&registry);
            b.bench_items("serve_plan_int8_simd_96req", n as f64, move || {
                let reg = Arc::clone(&reg_outer);
                let (_, m) = serve_all(cfg, reqs.clone(), move |_| {
                    Ok(NativeBatchExecutor::with_plan_exec(Arc::clone(&reg), 1, ExecMode::Int8)
                        .with_kernel_backend(simd_be))
                })
                .unwrap();
                assert_eq!(m.completed as usize, n);
                black_box(m.batches);
            })
            .map(|m| m.median())
        };
        if let (Some(fu), Some(sv)) = (fused_med, simd_serve_med) {
            println!(
                "    -> batch-fused int8 serve, {simd_be} kernels vs session default: {:.2}x",
                fu.as_secs_f64() / sv.as_secs_f64()
            );
        }

        // telemetry on vs off over the SAME batch-fused int8 scenario:
        // the observability acceptance gate is that live telemetry
        // (stage spans in the kernels, the difficulty sink per job,
        // admission-wait / batch-form timers in the scheduler) costs
        // < 2% end-to-end.  The telemetry-off baseline is the
        // batch-fused scenario above — identical config, requests and
        // plan; the only delta is the installed sinks.
        let tele_med = {
            use smoothrot::telemetry::{plan_registry_collector, Telemetry};
            let tele = Telemetry::new();
            tele.add_collector(plan_registry_collector(&registry));
            let tele_outer = Arc::clone(&tele);
            let reqs = base.clone();
            let reg_outer = Arc::clone(&registry);
            let med = b
                .bench_items("serve_plan_int8_telemetry_on_vs_off_96req", n as f64, move || {
                    let reg = Arc::clone(&reg_outer);
                    let (_, m) = smoothrot::serve::serve_all_with_telemetry(
                        cfg,
                        Some(Arc::clone(&tele)),
                        reqs.clone(),
                        move |_| {
                            Ok(NativeBatchExecutor::with_plan_exec(
                                Arc::clone(&reg),
                                1,
                                ExecMode::Int8,
                            ))
                        },
                    )
                    .unwrap();
                    assert_eq!(m.completed as usize, n);
                    black_box(m.batches);
                })
                .map(|m| m.median());
            if med.is_some() {
                // the overhead number is only honest if the sinks were
                // actually live: the igemm stage histogram must have
                // seen every timed iteration's integer GEMMs
                let snap = tele_outer.snapshot();
                assert!(
                    snap.histogram("smoothrot_igemm_seconds").is_some_and(|h| h.count > 0),
                    "telemetry-on bench ran with dead sinks"
                );
            }
            med
        };
        if let (Some(off), Some(on)) = (fused_med, tele_med) {
            println!(
                "    -> telemetry-on batch-fused int8 serve vs telemetry-off: {:.3}x \
                 ({:+.2}% overhead; acceptance gate < 2%)",
                on.as_secs_f64() / off.as_secs_f64(),
                100.0 * (on.as_secs_f64() / off.as_secs_f64() - 1.0)
            );
        }

        // ---- sharded multi-runner scaling (ISSUE 7) ------------------
        // The same batch-fused int8 workload, 192 requests over the
        // 8-layer plan, served by 1 / 2 / 4 shard-owning runners (layer
        // sharding, stealing on).  Per-job results are bit-identical at
        // any runner count (proptest_serve_sharded.rs); the delta is
        // aggregate throughput — the acceptance target is >= 2.5x at 4
        // runners on a machine with >= 8 cores.
        {
            use smoothrot::serve::shard::{serve_all_sharded, ShardBy, ShardConfig};

            let n2 = 192usize;
            let sharded_reqs: Vec<(usize, Job)> = (0..n2)
                .map(|i| {
                    let layer = (i * n_layers) / n2;
                    let (mut spec, _) =
                        smoothrot::synth::module_stream("k_proj", 600 + i as u64).unwrap();
                    spec.n_tokens = 32;
                    let job = Job {
                        id: i as u64,
                        layer,
                        module: "k_proj",
                        x: spec.layer(layer),
                        w: smoothrot::synth::layer_weight("k_proj", layer, 400).unwrap(),
                        alpha: 0.5,
                        bits: 4,
                    };
                    (i % 4, job)
                })
                .collect();
            let mut meds: Vec<(usize, Option<std::time::Duration>)> = Vec::new();
            for runners in [1usize, 2, 4] {
                let reqs = sharded_reqs.clone();
                let reg_outer = Arc::clone(&registry);
                let med = b
                    .bench_items(
                        &format!("serve_plan_int8_sharded_{runners}runner_192req"),
                        n2 as f64,
                        move || {
                            let reg = Arc::clone(&reg_outer);
                            let scfg = ShardConfig {
                                runners,
                                shard_by: ShardBy::Layer,
                                stealing: true,
                                base: ServeConfig {
                                    workers: 1, // overridden by the runner count
                                    max_batch: 8,
                                    queue_depth: n2,
                                    paused: true,
                                    ..ServeConfig::default()
                                },
                            };
                            let (_, m) = serve_all_sharded(scfg, reqs.clone(), move |_| {
                                Ok(NativeBatchExecutor::with_plan_exec(
                                    Arc::clone(&reg),
                                    1,
                                    ExecMode::Int8,
                                ))
                            })
                            .unwrap();
                            assert_eq!(m.completed as usize, n2);
                            assert_eq!(m.per_worker_routed.iter().sum::<u64>(), m.batches);
                            black_box(m.batches);
                        },
                    )
                    .map(|m| m.median());
                meds.push((runners, med));
            }
            let (executed, degraded) = registry.int8_stats();
            assert!(
                executed > 0 && degraded == 0,
                "sharded int8 bench degraded to f32: {executed} executed / {degraded} degraded"
            );
            if let (Some((_, Some(one))), Some((_, Some(four)))) =
                (meds.first().cloned(), meds.last().cloned())
            {
                println!(
                    "    -> 4-runner sharded int8 serve vs 1-runner: {:.2}x aggregate \
                     throughput ({} cores available)",
                    one.as_secs_f64() / four.as_secs_f64(),
                    resolve_threads(0)
                );
            }
        }

        // ---- wire tier: HTTP loopback serve vs in-process (ISSUE 10) --
        // The same int8 stream pushed through the HTTP/1.1 front-end
        // over loopback: thread-per-connection accept, request parse,
        // job build, submit, chunked NDJSON response, graceful drain.
        // Outputs are bit-identical to the in-process path (pinned by
        // chaos_net.rs and `loadgen --verify`), so the ratio vs
        // serve_plan_int8_batchfused_96req is pure wire + connection
        // machinery overhead — the PR 10 headline.
        let net_med = {
            use smoothrot::serve::net::{synth_job_builder, CoreServer, NetConfig, NetServer};
            use smoothrot::serve::proto;
            use std::io::{BufReader, BufWriter, Write};
            use std::net::TcpStream;

            let reg_outer = Arc::clone(&registry);
            b.bench_items("serve_net_loopback_int8_96req", n as f64, move || {
                let reg = Arc::clone(&reg_outer);
                let (core, rx) = CoreServer::start_with_telemetry(
                    ServeConfig {
                        workers: 2,
                        max_batch: 8,
                        queue_depth: n,
                        ..ServeConfig::default()
                    },
                    None,
                    None,
                    move |_| {
                        Ok(NativeBatchExecutor::with_plan_exec(
                            Arc::clone(&reg),
                            1,
                            ExecMode::Int8,
                        ))
                    },
                );
                let server =
                    NetServer::start(NetConfig::default(), core, rx, None, synth_job_builder(400))
                        .unwrap();
                let addr = server.addr();
                let clients = 4usize;
                let handles: Vec<_> = (0..clients)
                    .map(|c| {
                        std::thread::spawn(move || {
                            for i in (c..n).step_by(clients) {
                                let layer = (i * n_layers) / n;
                                let body = format!(
                                    r#"{{"module":"k_proj","layer":{layer},"rows":32,"seed":{}}}"#,
                                    500 + i
                                );
                                let stream = TcpStream::connect(addr).unwrap();
                                let mut w = BufWriter::new(stream.try_clone().unwrap());
                                proto::write_request(&mut w, "POST", "/analyze", body.as_bytes())
                                    .unwrap();
                                w.flush().unwrap();
                                let resp =
                                    proto::read_response(&mut BufReader::new(stream)).unwrap();
                                assert_eq!(resp.status, 200);
                            }
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().unwrap();
                }
                server.drain();
                let m = server.wait().unwrap();
                assert_eq!(m.completed as usize, n);
                assert_eq!(m.errors, 0);
                black_box(m.batches);
            })
            .map(|m| m.median())
        };
        if let (Some(fu), Some(nm)) = (fused_med, net_med) {
            println!(
                "    -> HTTP loopback int8 serve vs in-process batch-fused: {:.2}x \
                 (wire + connection machinery overhead)",
                nm.as_secs_f64() / fu.as_secs_f64()
            );
        }
    }

    // ---- PJRT request-path latency --------------------------------------
    let dir = std::env::var("SMOOTHROT_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        let rt = Runtime::new(&dir).expect("runtime");
        // warm the executable cache outside the timing loop
        let xs = rand_matrix(128, 256, 3);
        let ws = rand_matrix(256, 256, 4);
        let _ = rt.analyze(&xs, &ws).unwrap();
        b.bench("pjrt_analyze_256x256_all_modes", || {
            black_box(rt.analyze(&xs, &ws).unwrap());
        });
        let xl = rand_matrix(128, 704, 5);
        let wl = rand_matrix(704, 256, 6);
        let _ = rt.analyze(&xl, &wl).unwrap();
        b.bench("pjrt_analyze_704x256_all_modes", || {
            black_box(rt.analyze(&xl, &wl).unwrap());
        });
        let _ = rt.transform(Mode::SmoothRotate, &xl, &wl).unwrap();
        b.bench("pjrt_transform_smooth_rotate_704x256", || {
            black_box(rt.transform(Mode::SmoothRotate, &xl, &wl).unwrap());
        });
        b.bench_heavy("pjrt_capture_full_32_layer_forward", 3, || {
            black_box(rt.capture().unwrap());
        });
    } else {
        eprintln!("artifacts not built — skipping PJRT benches (run `make artifacts`)");
    }

    b.finish();

    // machine-readable trajectory artifact: scenario name, ns/iter and
    // throughput for every bench above.  The default path resolves to
    // the repo root AT RUNTIME (a compile-time env! path would dangle
    // if the checkout moves or a cached bench binary runs elsewhere),
    // so `cargo bench` refreshes the committed BENCH_10.json trajectory
    // file from any working directory inside the repo; BENCH_JSON
    // overrides (CI points it at a scratch path to exercise the writer
    // without dirtying the tree).
    let json_path = std::env::var("BENCH_JSON").unwrap_or_else(|_| default_bench_json());
    b.write_json("perf_benches", &json_path).expect("write bench json");
    println!("wrote {json_path}");
}

/// Nearest ancestor of the current directory that looks like the repo
/// root (workspace `Cargo.toml` next to the `rust/` member), falling
/// back to the current directory.
fn default_bench_json() -> String {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| std::path::PathBuf::from("."));
    loop {
        if dir.join("Cargo.toml").exists() && dir.join("rust").is_dir() {
            return dir.join("BENCH_10.json").to_string_lossy().into_owned();
        }
        if !dir.pop() {
            return "BENCH_10.json".to_string();
        }
    }
}
