//! criterion-lite: a small measurement harness for `cargo bench`
//! (the offline registry has no criterion).
//!
//! Provides warmup + N timed samples, median / mean / p95 statistics,
//! optional throughput reporting, and a `--filter` argument matching the
//! substring semantics of criterion.  Results are printed in a stable
//! one-line-per-bench format that `EXPERIMENTS.md` quotes directly.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<Duration>,
    /// Items processed per iteration (for throughput), if meaningful.
    pub items_per_iter: Option<f64>,
}

impl Measurement {
    fn sorted_nanos(&self) -> Vec<u128> {
        let mut v: Vec<u128> = self.samples.iter().map(Duration::as_nanos).collect();
        v.sort_unstable();
        v
    }

    pub fn median(&self) -> Duration {
        let v = self.sorted_nanos();
        Duration::from_nanos(v[v.len() / 2] as u64)
    }

    pub fn mean(&self) -> Duration {
        let total: u128 = self.samples.iter().map(Duration::as_nanos).sum();
        Duration::from_nanos((total / self.samples.len() as u128) as u64)
    }

    pub fn p95(&self) -> Duration {
        let v = self.sorted_nanos();
        let idx = ((v.len() as f64) * 0.95) as usize;
        Duration::from_nanos(v[idx.min(v.len() - 1)] as u64)
    }

    /// One machine-readable scenario row (the element shape of
    /// `BENCH_<n>.json`'s `results` array, shared with the loadgen
    /// report so perf-trajectory tooling parses both identically).
    pub fn to_json_row(&self) -> crate::jsonio::Json {
        use crate::jsonio::{obj, Json};
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("median_ns", Json::Num(self.median().as_nanos() as f64)),
            ("mean_ns", Json::Num(self.mean().as_nanos() as f64)),
            ("p95_ns", Json::Num(self.p95().as_nanos() as f64)),
            ("samples", Json::Num(self.samples.len() as f64)),
        ];
        // a 0ns median (empty closure on a coarse clock) would divide
        // to +inf, which is not representable JSON — emit null instead
        // of corrupting the artifact
        let med_secs = self.median().as_secs_f64();
        match self.items_per_iter {
            Some(items) if med_secs > 0.0 => {
                fields.push(("items_per_sec", Json::Num(items / med_secs)))
            }
            _ => fields.push(("items_per_sec", Json::Null)),
        }
        obj(fields)
    }

    pub fn report_line(&self) -> String {
        let med = self.median();
        let thr = self
            .items_per_iter
            .map(|items| {
                let per_sec = items / self.median().as_secs_f64();
                if per_sec > 1e6 {
                    format!("  {:.2} Melem/s", per_sec / 1e6)
                } else {
                    format!("  {:.1} elem/s", per_sec)
                }
            })
            .unwrap_or_default();
        format!(
            "{:<44} median {:>12?}  mean {:>12?}  p95 {:>12?}{}",
            self.name,
            med,
            self.mean(),
            self.p95(),
            thr
        )
    }
}

/// Bench runner configuration.
#[derive(Clone, Debug)]
pub struct Bench {
    pub warmup_iters: usize,
    pub sample_count: usize,
    /// Iteration profile this run used (`full` | `fast` | `smoke`) —
    /// recorded in the JSON artifact so trajectory numbers are never
    /// compared across profiles by accident.
    pub mode: &'static str,
    filter: Option<String>,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::from_args()
    }
}

impl Bench {
    /// Parse `--filter <substr>` / `--fast` / `--smoke` from the bench
    /// binary's args (cargo passes `--bench`; ignore it).  `--smoke`
    /// runs the minimum iterations that still exercise every kernel —
    /// CI uses it so regressions fail loudly without timing flakiness.
    pub fn from_args() -> Self {
        let args: Vec<String> = std::env::args().collect();
        let mut filter = None;
        let mut fast = false;
        let mut smoke = false;
        let mut i = 1;
        while i < args.len() {
            match args[i].as_str() {
                "--filter" => {
                    filter = args.get(i + 1).cloned();
                    i += 1;
                }
                "--fast" => fast = true,
                "--smoke" => smoke = true,
                _ => {
                    // bare positional (criterion style) acts as a filter
                    if !args[i].starts_with('-') {
                        filter = Some(args[i].clone());
                    }
                }
            }
            i += 1;
        }
        let (warmup_iters, sample_count, mode) = if smoke {
            (1, 2, "smoke")
        } else if fast {
            (1, 5, "fast")
        } else {
            (3, 15, "full")
        };
        Self { warmup_iters, sample_count, mode, filter, results: Vec::new() }
    }

    fn enabled(&self, name: &str) -> bool {
        self.filter.as_deref().map(|f| name.contains(f)).unwrap_or(true)
    }

    /// Time `f` (whole-call granularity); returns the measurement if run.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> Option<&Measurement> {
        self.bench_with_items(name, None, None, move || f())
    }

    /// Time `f` and report throughput as `items / median`.
    pub fn bench_items(&mut self, name: &str, items: f64, mut f: impl FnMut()) -> Option<&Measurement> {
        self.bench_with_items(name, None, Some(items), move || f())
    }

    /// Heavy benchmark: override warmup/sample counts (e.g. whole-grid
    /// experiments where one iteration takes tens of seconds).
    pub fn bench_heavy(&mut self, name: &str, samples: usize, mut f: impl FnMut()) -> Option<&Measurement> {
        self.bench_with_items(name, Some((1, samples)), None, move || f())
    }

    fn bench_with_items(
        &mut self,
        name: &str,
        counts: Option<(usize, usize)>,
        items: Option<f64>,
        mut f: impl FnMut(),
    ) -> Option<&Measurement> {
        if !self.enabled(name) {
            return None;
        }
        let (warmup, count) = counts.unwrap_or((self.warmup_iters, self.sample_count));
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(count);
        for _ in 0..count {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        let m = Measurement { name: name.to_string(), samples, items_per_iter: items };
        println!("{}", m.report_line());
        self.results.push(m);
        self.results.last()
    }

    /// All measurements taken so far.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Print a closing summary (count only; lines were live-printed).
    pub fn finish(&self) {
        println!("\n{} benchmark(s) completed", self.results.len());
    }

    /// Serialize every measurement as a machine-readable JSON document
    /// (the repo's `BENCH_<n>.json` trajectory artifacts): per scenario
    /// the name, ns/iter (median / mean / p95), sample count, and — for
    /// throughput benches — items per second at the median.
    pub fn to_json(&self, bench: &str) -> crate::jsonio::Json {
        use crate::jsonio::{obj, Json};
        let results: Vec<Json> = self.results.iter().map(Measurement::to_json_row).collect();
        obj(vec![
            ("bench", Json::Str(bench.to_string())),
            ("mode", Json::Str(self.mode.to_string())),
            // a filtered run covers only a subset of scenarios — record
            // it so a partial artifact can never pass for a full one
            (
                "filter",
                match &self.filter {
                    Some(f) => Json::Str(f.clone()),
                    None => Json::Null,
                },
            ),
            ("scenarios", Json::Num(self.results.len() as f64)),
            ("results", Json::Arr(results)),
        ])
    }

    /// [`Bench::to_json`] written to `path` (pretty-printed).
    pub fn write_json(&self, bench: &str, path: &str) -> Result<(), String> {
        std::fs::write(path, self.to_json(bench).to_string_pretty())
            .map_err(|e| format!("write {path}: {e}"))
    }
}

/// Prevent the optimizer from discarding a value (ptr::read volatile
/// blackbox — std::hint::black_box is stable since 1.66 but keep a
/// wrapper for call-site clarity).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_bench() -> Bench {
        Bench { warmup_iters: 1, sample_count: 5, mode: "fast", filter: None, results: Vec::new() }
    }

    #[test]
    fn measurement_stats_ordering() {
        let m = Measurement {
            name: "t".into(),
            samples: (1..=10).map(Duration::from_micros).collect(),
            items_per_iter: None,
        };
        assert!(m.median() <= m.p95());
        assert!(m.mean() >= Duration::from_micros(1));
    }

    #[test]
    fn bench_runs_and_records() {
        let mut b = quiet_bench();
        let mut count = 0u64;
        b.bench("counter", || {
            count += 1;
        });
        assert_eq!(b.results().len(), 1);
        // warmup + samples
        assert_eq!(count, 6);
    }

    #[test]
    fn filter_skips_non_matching() {
        let mut b = quiet_bench();
        b.filter = Some("xyz".into());
        assert!(b.bench("abc", || {}).is_none());
        assert_eq!(b.results().len(), 0);
    }

    #[test]
    fn throughput_line_mentions_rate() {
        let m = Measurement {
            name: "thr".into(),
            samples: vec![Duration::from_millis(1); 3],
            items_per_iter: Some(1_000_000.0),
        };
        assert!(m.report_line().contains("elem/s"));
    }

    #[test]
    fn json_export_round_trips() {
        let mut b = quiet_bench();
        // real work in the timed closure so the median cannot round to
        // 0ns (which would legitimately null out items_per_sec)
        let mut acc = 0u64;
        b.bench_items("with_items", 100.0, || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        black_box(acc);
        b.bench("no_items", || {});
        let doc = b.to_json("unit_test");
        assert_eq!(doc.get("bench").and_then(|j| j.as_str()), Some("unit_test"));
        assert_eq!(doc.get("mode").and_then(|j| j.as_str()), Some("fast"));
        assert_eq!(doc.get("filter"), Some(&crate::jsonio::Json::Null), "unfiltered run");
        let results = doc.get("results").and_then(|j| j.as_arr()).unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("name").and_then(|j| j.as_str()), Some("with_items"));
        assert!(results[0].get("median_ns").and_then(|j| j.as_f64()).is_some());
        assert!(results[0].get("items_per_sec").and_then(|j| j.as_f64()).is_some());
        // no-throughput scenarios carry an explicit null
        assert_eq!(results[1].get("items_per_sec"), Some(&crate::jsonio::Json::Null));
        // the document re-parses: it is real JSON, not a format string
        let text = doc.to_string_pretty();
        let parsed = crate::jsonio::parse(&text).unwrap();
        assert_eq!(parsed.get("scenarios").and_then(|j| j.as_usize()), Some(2));
    }
}
