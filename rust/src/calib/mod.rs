//! Calibration & quantization-plan subsystem — "calibrate once, serve
//! many".
//!
//! The paper's channel-magnitude difficulty metric and the hybrid
//! smooth-then-rotate transform are calibration products: SmoothQuant's
//! Eq. 4 migration vector and the per-layer transform choice both come
//! from *observed* activation statistics.  Before this module those
//! products lived only inside one-shot offline sweeps
//! ([`crate::pipeline::run_full_experiment`]) — nothing persisted what
//! was learned, and the serving path re-derived everything per request.
//!
//! This subsystem closes the loop in four stages:
//!
//! ```text
//!   activation batches ──> [stats]    streaming, mergeable per-channel
//!                             │       accumulators (Welford shards)
//!                             v
//!                          [search]   per-(module, layer): mode × alpha
//!                             │       × bits grid through the fused
//!                             │       kernel engine (Eq. 2 / Eq. 4)
//!                             v
//!                          [plan]     versioned, content-hashed JSON
//!                             │       artifact with provenance
//!                             v
//!                          [registry] load-time resolution into
//!                                     rotations + smoothing vectors;
//!                                     consulted by the serving path
//! ```
//!
//! * [`stats`] — [`stats::ChannelStats`] accumulates per-channel
//!   absolute-max / mean / magnitude over batches and merges
//!   deterministically across worker shards; [`stats::LayerCollector`]
//!   pairs it with a bounded deterministic sample reservoir.
//! * [`search`] — [`search::search_layer`] grids mode × alpha × bits on
//!   the collected stats + sample through
//!   [`crate::kernels::fused::analyze_all_modes`], choosing per cell via
//!   [`search::choose_mode`] (the same chooser
//!   [`crate::policy::recommend`] is now expressed on).
//! * [`plan`] — [`plan::QuantPlan`]: schema-versioned, content-hashed,
//!   provenance-carrying artifact with strict round-trip and
//!   newer-version rejection.
//! * [`registry`] — [`registry::PlanRegistry`]: resolves a plan into
//!   ready-to-apply transforms (pre-built [`crate::transforms::Rotation`]
//!   entries, pre-scaled smoothing vectors) that
//!   [`crate::serve::NativeBatchExecutor`] consults per request, with a
//!   SIGHUP-free content-hash-poll hot reload.
//!
//! The CLI entry points are `smoothrot calibrate` (stream → stats →
//! search → plan file) and `smoothrot serve --plan <path>`; the
//! calibrate-vs-analyze equivalence is pinned by
//! `rust/tests/calib_equivalence.rs` and the `calibrate --selfcheck`
//! flag.

pub mod plan;
pub mod registry;
pub mod search;
pub mod stats;
