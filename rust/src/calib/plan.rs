//! Versioned quantization-plan artifacts.
//!
//! A [`QuantPlan`] is what calibration persists: per (module, layer,
//! bits) the chosen transform, its migration strength, the Eq. 4
//! smoothing vector (when the transform smooths), the predicted Eq. 2
//! error, and the difficulty metric before/after.  The artifact is a
//! JSON document with three integrity layers:
//!
//! * **schema version** — [`PLAN_SCHEMA_VERSION`]; loading a plan
//!   written by a *newer* schema fails loudly instead of misreading it,
//!   while unknown extra fields from same-version writers are ignored
//!   (forward-compatible readers, strict version ceiling),
//! * **content hash** — an FNV-1a 64 digest of the canonical compact
//!   serialization of the body, recomputed on load; a plan whose values
//!   were edited by hand no longer matches its declared hash,
//! * **provenance** — the seed, search grids, margin and thread count
//!   that produced the plan, so any artifact can be regenerated.
//!
//! Round-trip strictness (serialize → parse → identical plan, newer
//! versions rejected) is pinned by `rust/tests/proptest_plan.rs`.

use crate::jsonio::{self, obj, Json};
use crate::transforms::Mode;

/// Schema version written by this crate; readers reject anything newer.
pub const PLAN_SCHEMA_VERSION: u32 = 1;

/// FNV-1a 64-bit digest (the artifact content hash).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// How a plan came to be: enough to regenerate it.
#[derive(Clone, Debug, PartialEq)]
pub struct Provenance {
    /// Calibration stream seed.
    pub seed: u64,
    /// Migration-strength grid searched.
    pub alphas: Vec<f64>,
    /// Bit-width grid searched.
    pub bits_grid: Vec<u32>,
    /// Smooth-rotation adoption margin (paper Sec. V conservatism).
    pub sr_margin: f64,
    /// Math threads the search ran with.
    pub threads: usize,
    /// Producing tool + version.
    pub tool: String,
}

impl Default for Provenance {
    fn default() -> Self {
        Self {
            seed: 0,
            alphas: vec![0.5],
            bits_grid: vec![4],
            sr_margin: 1.25,
            threads: 1,
            tool: format!("smoothrot {}", crate::VERSION),
        }
    }
}

/// One calibrated cell: the transform to deploy for (module, layer,
/// bits) requests.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanEntry {
    /// Module kind (one of [`crate::MODULES`]).
    pub module: String,
    /// Layer index.
    pub layer: usize,
    /// Quantization bit width this entry was searched at.
    pub bits: u32,
    /// Activation width (validates request shapes at apply time).
    pub c_in: usize,
    /// Chosen transform.
    pub mode: Mode,
    /// Chosen migration strength (meaningful for smoothing modes).
    pub alpha: f32,
    /// Predicted Eq. 2 error under the chosen transform.
    pub predicted_error: f64,
    /// Quantization difficulty of the untransformed activations.
    pub difficulty_before: f64,
    /// Quantization difficulty after the chosen transform.
    pub difficulty_after: f64,
    /// Eq. 4 migration vector `s` (length `c_in`), present iff the
    /// chosen mode smooths — computed from the *streaming* channel
    /// maxima at calibration time and applied verbatim online.
    pub smooth: Option<Vec<f32>>,
}

/// A complete, versioned calibration product.
///
/// ```
/// use smoothrot::calib::plan::{PlanEntry, Provenance, QuantPlan};
/// use smoothrot::transforms::Mode;
///
/// let plan = QuantPlan {
///     provenance: Provenance { seed: 7, ..Provenance::default() },
///     entries: vec![PlanEntry {
///         module: "down_proj".into(),
///         layer: 30,
///         bits: 4,
///         c_in: 704,
///         mode: Mode::SmoothRotate,
///         alpha: 0.5,
///         predicted_error: 12.5,
///         difficulty_before: 40.0,
///         difficulty_after: 1.5,
///         smooth: None,
///     }],
/// };
/// let text = plan.to_json_string();
/// let back = QuantPlan::parse(&text).unwrap();
/// assert_eq!(back, plan);
/// assert_eq!(back.get("down_proj", 30, 4).unwrap().mode, Mode::SmoothRotate);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct QuantPlan {
    pub provenance: Provenance,
    pub entries: Vec<PlanEntry>,
}

impl QuantPlan {
    /// Entry for (module, layer, bits), if calibrated.
    pub fn get(&self, module: &str, layer: usize, bits: u32) -> Option<&PlanEntry> {
        self.entries
            .iter()
            .find(|e| e.module == module && e.layer == layer && e.bits == bits)
    }

    /// The canonical body (everything except the content hash).
    fn body_json(&self) -> Json {
        let p = &self.provenance;
        let provenance = obj(vec![
            // seed is u64: stored as a decimal string so values above
            // 2^53 survive the f64 number model losslessly
            ("seed", Json::Str(p.seed.to_string())),
            ("alphas", jsonio::num_arr(&p.alphas)),
            (
                "bits_grid",
                Json::Arr(p.bits_grid.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
            ("sr_margin", Json::Num(p.sr_margin)),
            ("threads", Json::Num(p.threads as f64)),
            ("tool", Json::Str(p.tool.clone())),
        ]);
        let entries = Json::Arr(
            self.entries
                .iter()
                .map(|e| {
                    let mut fields = vec![
                        ("module", Json::Str(e.module.clone())),
                        ("layer", Json::Num(e.layer as f64)),
                        ("bits", Json::Num(e.bits as f64)),
                        ("c_in", Json::Num(e.c_in as f64)),
                        ("mode", Json::Str(e.mode.name().into())),
                        ("alpha", Json::Num(e.alpha as f64)),
                        ("predicted_error", Json::Num(e.predicted_error)),
                        ("difficulty_before", Json::Num(e.difficulty_before)),
                        ("difficulty_after", Json::Num(e.difficulty_after)),
                    ];
                    if let Some(s) = &e.smooth {
                        fields.push((
                            "smooth",
                            Json::Arr(s.iter().map(|&v| Json::Num(v as f64)).collect()),
                        ));
                    }
                    obj(fields)
                })
                .collect(),
        );
        obj(vec![
            ("version", Json::Num(PLAN_SCHEMA_VERSION as f64)),
            ("provenance", provenance),
            ("entries", entries),
        ])
    }

    /// Content hash of the canonical body, as `fnv1a64:<hex>`.
    pub fn content_hash(&self) -> String {
        format!("fnv1a64:{:016x}", fnv1a64(self.body_json().to_string_compact().as_bytes()))
    }

    /// Full artifact JSON (body + content hash).
    pub fn to_json(&self) -> Json {
        match self.body_json() {
            Json::Obj(mut fields) => {
                fields.push(("content_hash".to_string(), Json::Str(self.content_hash())));
                Json::Obj(fields)
            }
            _ => unreachable!("body is always an object"),
        }
    }

    /// Pretty-printed artifact text (what `smoothrot calibrate` writes).
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Strict parse: schema-version ceiling, required fields, content
    /// hash re-verified against the canonical re-serialization (so
    /// value edits are caught while unknown extra fields and formatting
    /// differences are tolerated).
    pub fn parse(text: &str) -> Result<QuantPlan, String> {
        let j = jsonio::parse(text).map_err(|e| format!("quant plan: {e}"))?;
        let version = j
            .get("version")
            .and_then(Json::as_u64)
            .ok_or("quant plan: missing 'version'")?;
        if version > PLAN_SCHEMA_VERSION as u64 {
            return Err(format!(
                "quant plan: schema version {version} is newer than supported {PLAN_SCHEMA_VERSION} — upgrade smoothrot or regenerate the plan"
            ));
        }
        if version == 0 {
            return Err("quant plan: schema version 0 is invalid".into());
        }
        let p = j.get("provenance").ok_or("quant plan: missing 'provenance'")?;
        let provenance = Provenance {
            seed: p
                .get("seed")
                .and_then(Json::as_str)
                .and_then(|s| s.parse::<u64>().ok())
                .ok_or("quant plan: provenance.seed must be a decimal string")?,
            alphas: p
                .get("alphas")
                .and_then(Json::as_f64_vec)
                .ok_or("quant plan: provenance.alphas")?,
            bits_grid: p
                .get("bits_grid")
                .and_then(Json::as_arr)
                .ok_or("quant plan: provenance.bits_grid")?
                .iter()
                .map(|v| v.as_u64().map(|b| b as u32).ok_or("quant plan: bad bits_grid entry"))
                .collect::<Result<_, _>>()?,
            sr_margin: p
                .get("sr_margin")
                .and_then(Json::as_f64)
                .ok_or("quant plan: provenance.sr_margin")?,
            threads: p
                .get("threads")
                .and_then(Json::as_usize)
                .ok_or("quant plan: provenance.threads")?,
            tool: p
                .get("tool")
                .and_then(Json::as_str)
                .ok_or("quant plan: provenance.tool")?
                .to_string(),
        };
        let mut entries = Vec::new();
        for (i, e) in j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or("quant plan: missing 'entries'")?
            .iter()
            .enumerate()
        {
            let field = |k: &str| {
                e.get(k).ok_or_else(|| format!("quant plan: entry {i} missing '{k}'"))
            };
            let bad = |k: &str| format!("quant plan: entry {i}: bad '{k}'");
            let mode_name = field("mode")?.as_str().ok_or_else(|| bad("mode"))?;
            let mode = Mode::from_name(mode_name)
                .ok_or_else(|| format!("quant plan: entry {i}: unknown mode {mode_name:?}"))?;
            let smooth = match e.get("smooth") {
                None => None,
                Some(s) => Some(s.as_f32_vec().ok_or_else(|| bad("smooth"))?),
            };
            entries.push(PlanEntry {
                module: field("module")?
                    .as_str()
                    .ok_or_else(|| bad("module"))?
                    .to_string(),
                layer: field("layer")?.as_usize().ok_or_else(|| bad("layer"))?,
                bits: field("bits")?.as_u64().ok_or_else(|| bad("bits"))? as u32,
                c_in: field("c_in")?.as_usize().ok_or_else(|| bad("c_in"))?,
                mode,
                alpha: field("alpha")?.as_f64().ok_or_else(|| bad("alpha"))? as f32,
                predicted_error: field("predicted_error")?
                    .as_f64()
                    .ok_or_else(|| bad("predicted_error"))?,
                difficulty_before: field("difficulty_before")?
                    .as_f64()
                    .ok_or_else(|| bad("difficulty_before"))?,
                difficulty_after: field("difficulty_after")?
                    .as_f64()
                    .ok_or_else(|| bad("difficulty_after"))?,
                smooth,
            });
        }
        let plan = QuantPlan { provenance, entries };
        let declared = j
            .get("content_hash")
            .and_then(Json::as_str)
            .ok_or("quant plan: missing 'content_hash'")?;
        let recomputed = plan.content_hash();
        if declared != recomputed {
            return Err(format!(
                "quant plan: content hash mismatch (declared {declared}, recomputed {recomputed}) — the artifact was edited or corrupted"
            ));
        }
        Ok(plan)
    }

    /// Load and parse a plan file.
    pub fn load(path: &std::path::Path) -> Result<QuantPlan, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading plan {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Write the artifact to `path` (creating parent directories).
    pub fn save(&self, path: &std::path::Path) -> Result<(), String> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .map_err(|e| format!("mkdir {}: {e}", dir.display()))?;
            }
        }
        std::fs::write(path, self.to_json_string())
            .map_err(|e| format!("writing plan {}: {e}", path.display()))
    }

    /// Layer count covered per module (max layer index + 1), for
    /// summaries.
    pub fn n_layers(&self) -> usize {
        self.entries.iter().map(|e| e.layer + 1).max().unwrap_or(0)
    }

    /// Human-readable summary table (per module: chosen-mode counts).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "# quantization plan (schema v{PLAN_SCHEMA_VERSION}, {} entries, hash {})\n",
            self.entries.len(),
            self.content_hash()
        );
        for module in crate::MODULES {
            let picks: Vec<&PlanEntry> =
                self.entries.iter().filter(|e| e.module == module).collect();
            if picks.is_empty() {
                continue;
            }
            let count = |m: Mode| picks.iter().filter(|e| e.mode == m).count();
            s.push_str(&format!(
                "{module:>10}: none {} smooth {} rotate {} smooth_rotate {}\n",
                count(Mode::None),
                count(Mode::Smooth),
                count(Mode::Rotate),
                count(Mode::SmoothRotate),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_plan() -> QuantPlan {
        QuantPlan {
            provenance: Provenance { seed: u64::MAX - 3, ..Provenance::default() },
            entries: vec![
                PlanEntry {
                    module: "k_proj".into(),
                    layer: 0,
                    bits: 4,
                    c_in: 8,
                    mode: Mode::Rotate,
                    alpha: 0.5,
                    predicted_error: 1.25,
                    difficulty_before: 3.0,
                    difficulty_after: 0.5,
                    smooth: None,
                },
                PlanEntry {
                    module: "down_proj".into(),
                    layer: 1,
                    bits: 4,
                    c_in: 4,
                    mode: Mode::SmoothRotate,
                    alpha: 0.65,
                    predicted_error: 0.75,
                    difficulty_before: 9.0,
                    difficulty_after: 0.25,
                    smooth: Some(vec![0.5, 2.0, 1.0, 0.125]),
                },
            ],
        }
    }

    #[test]
    fn roundtrip_is_identical_including_u64_seed() {
        let plan = tiny_plan();
        let back = QuantPlan::parse(&plan.to_json_string()).unwrap();
        assert_eq!(back, plan);
        assert_eq!(back.provenance.seed, u64::MAX - 3);
        assert_eq!(back.content_hash(), plan.content_hash());
    }

    #[test]
    fn newer_schema_version_is_rejected() {
        let text = tiny_plan()
            .to_json_string()
            .replace(&format!("\"version\": {PLAN_SCHEMA_VERSION}"), "\"version\": 99");
        let err = QuantPlan::parse(&text).unwrap_err();
        assert!(err.contains("newer than supported"), "{err}");
    }

    #[test]
    fn value_tampering_breaks_the_content_hash() {
        let text = tiny_plan().to_json_string();
        assert!(text.contains("\"predicted_error\": 1.25"));
        let tampered = text.replace("\"predicted_error\": 1.25", "\"predicted_error\": 99");
        let err = QuantPlan::parse(&tampered).unwrap_err();
        assert!(err.contains("content hash mismatch"), "{err}");
    }

    #[test]
    fn unknown_extra_fields_are_tolerated() {
        let text = tiny_plan()
            .to_json_string()
            .replacen("\"provenance\"", "\"future_field\": [1, 2],\n \"provenance\"", 1);
        let back = QuantPlan::parse(&text).unwrap();
        assert_eq!(back, tiny_plan());
    }

    #[test]
    fn lookup_and_summary() {
        let plan = tiny_plan();
        assert_eq!(plan.get("down_proj", 1, 4).unwrap().mode, Mode::SmoothRotate);
        assert!(plan.get("down_proj", 1, 8).is_none());
        assert!(plan.get("o_proj", 0, 4).is_none());
        assert_eq!(plan.n_layers(), 2);
        let s = plan.summary();
        assert!(s.contains("down_proj") && s.contains("fnv1a64:"), "{s}");
    }

    #[test]
    fn save_and_load_through_a_file() {
        let dir = std::env::temp_dir().join("smoothrot_plan_test");
        let path = dir.join("plan.json");
        let plan = tiny_plan();
        plan.save(&path).unwrap();
        let back = QuantPlan::load(&path).unwrap();
        assert_eq!(back, plan);
        std::fs::remove_dir_all(&dir).ok();
    }
}
