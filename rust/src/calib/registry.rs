//! Plan registry — the serving-side face of calibration.
//!
//! A [`PlanRegistry`] turns a parsed [`QuantPlan`] into ready-to-apply
//! state at *load time*: one shared [`Rotation`] per distinct
//! activation width (FWHT-planned, built once), and the plan's Eq. 4
//! smoothing vectors held behind `Arc` so per-request lookups clone
//! pointers, not data.  [`crate::serve::NativeBatchExecutor`] consults
//! the registry per job and, on a hit, runs the single planned
//! transform ([`crate::kernels::fused::analyze_planned`]) instead of
//! the four-mode analyze — zero per-request transform search.
//!
//! Hot reload is SIGHUP-free: [`PlanRegistry::reload_if_changed`] polls
//! the plan file's *content* — a raw-byte FNV-1a hash short-circuits
//! the untouched-file case, the plan's canonical content hash decides
//! whether anything semantically changed — and atomically swaps the
//! resolved state only on a real change.  No mtime/length stamps: a
//! same-second same-size rewrite is detected, and a formatting-only
//! rewrite is skipped (and counted) instead of re-resolved.  All
//! runners of a sharded server share one registry, so the swap is
//! observed atomically across the fleet.

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::calib::plan::{fnv1a64, QuantPlan};
use crate::qtensor::PlannedWeight;
use crate::tensor::Matrix;
use crate::transforms::{Mode, Rotation};

/// One plan entry resolved for the hot path.
#[derive(Clone, Debug)]
pub struct ResolvedEntry {
    /// Planned transform.
    pub mode: Mode,
    /// Planned migration strength.
    pub alpha: f32,
    /// Expected activation width (requests with another width fall
    /// back to the full analyze).
    pub c_in: usize,
    /// Calibration-predicted Eq. 2 error.
    pub predicted_error: f64,
    /// The plan's recorded post-transform quantization difficulty
    /// (`PlanEntry::difficulty_after`) — the baseline live serving
    /// telemetry compares against to expose activation drift
    /// ([`crate::telemetry::difficulty`]).
    pub calib_difficulty: f64,
    /// Eq. 4 vector from the plan (smoothing modes only).
    pub smooth: Option<Arc<Vec<f32>>>,
    /// Reciprocals `1/s` for the activation side, computed once at
    /// resolve time so the hot path never rebuilds them per request.
    pub smooth_inv: Option<Arc<Vec<f32>>>,
    /// Pre-built rotation, shared across every entry of this width.
    pub rotation: Option<Arc<Rotation>>,
    /// Pre-quantized transformed weight for the integer execution path
    /// (`serve --exec int8`): built once per entry when a weight
    /// provider is installed ([`PlanRegistry::set_weight_provider`]),
    /// rebuilt automatically after a hot reload.  `None` until then, or
    /// for entries whose bit width exceeds i8 storage.
    pub qweight: Option<Arc<PlannedWeight>>,
}

/// Resolved lookup state (swapped wholesale on reload).  The outer map
/// is keyed by module *name* so the per-request lookup can borrow the
/// job's `&str` (`String: Borrow<str>`) — no key allocation on the hot
/// path.
#[derive(Debug)]
struct Resolved {
    map: BTreeMap<String, BTreeMap<(usize, u32), ResolvedEntry>>,
    content_hash: String,
    /// FNV-1a hash of the backing file's raw bytes as last read —
    /// the cheap poll short-circuit (no parse when the file is
    /// byte-identical).  Unlike an (mtime, length) stamp it cannot
    /// miss a same-second same-size rewrite.
    file_hash: Option<u64>,
}

/// Source of the serving model's per-(module, layer) weights, consulted
/// when pre-quantizing planned weights for the integer execution path.
pub type WeightFn = Box<dyn Fn(&str, usize) -> Option<Matrix> + Send + Sync>;

/// Debug-opaque wrapper so the registry stays `derive(Debug)`-able.
struct WeightProvider(WeightFn);

impl fmt::Debug for WeightProvider {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("WeightProvider(..)")
    }
}

/// Shared, reloadable registry of resolved plan entries.
#[derive(Debug)]
pub struct PlanRegistry {
    path: Option<PathBuf>,
    state: RwLock<Resolved>,
    /// Installed weight source for int8 preload (re-applied on reload).
    provider: Mutex<Option<WeightProvider>>,
    /// Lookups answered by a plan entry.
    planned: AtomicU64,
    /// Lookups that fell back to the full analyze.
    fallback: AtomicU64,
    /// Int8-exec jobs that actually ran the integer pipeline.
    int8_executed: AtomicU64,
    /// Int8-exec jobs on plan-covered cells that had to degrade to the
    /// f32 planned path (missing or shape-mismatched pre-quantized
    /// weight) — the silent-degradation counter.
    int8_degraded: AtomicU64,
    /// Int8-exec jobs executed through the **stacked batch-fused** GEMM
    /// path ([`crate::kernels::fused::analyze_planned_int_batch`]) — the
    /// observability counter for a silent per-job fallback, mirroring
    /// `int8_executed`.
    batch_fused: AtomicU64,
    /// Polls that found a rewritten file whose *canonical* plan content
    /// was identical (formatting-only rewrite): no resolve, no swap.
    reload_skipped: AtomicU64,
    /// Bumped once per real hot swap, inside the state write lock — a
    /// fleet-wide plan version counter for "which plan generation am I
    /// serving" assertions.
    generation: AtomicU64,
    /// Reload attempts that failed (unreadable / corrupt / torn /
    /// version-rejected rewrite).  The old resolved plan stays live on
    /// every failure.
    reload_failed: AtomicU64,
    /// Plan entries whose int8 weight preload failed and were degraded
    /// to the f32 planned path instead of stripping the whole plan.
    preload_degraded_count: AtomicU64,
    /// Bounded exponential backoff after a failed reload: polls
    /// short-circuit until the deadline passes, so a persistently
    /// corrupt rewrite cannot burn a parse + resolve per poll.
    backoff: Mutex<ReloadBackoff>,
}

/// Backoff state for [`PlanRegistry::reload_if_changed`] failures.
#[derive(Debug, Default)]
struct ReloadBackoff {
    /// Polls before this instant return `Ok(false)` without touching
    /// the file.
    until: Option<Instant>,
    /// Delay applied by the *next* failure (doubles per consecutive
    /// failure, [`RELOAD_BACKOFF_INITIAL`] up to [`RELOAD_BACKOFF_MAX`];
    /// any success resets it).
    delay: Duration,
}

/// First-failure reload backoff delay.
pub const RELOAD_BACKOFF_INITIAL: Duration = Duration::from_millis(100);

/// Ceiling on the doubled reload backoff delay.
pub const RELOAD_BACKOFF_MAX: Duration = Duration::from_secs(5);

fn resolve(plan: &QuantPlan) -> Result<Resolved, String> {
    // one rotation per distinct width that any rotating entry needs
    let mut rotations: BTreeMap<usize, Arc<Rotation>> = BTreeMap::new();
    for e in &plan.entries {
        if matches!(e.mode, Mode::Rotate | Mode::SmoothRotate)
            && !rotations.contains_key(&e.c_in)
        {
            let rot = Rotation::build(e.c_in).map_err(|err| {
                format!("plan registry: {} layer {}: {err}", e.module, e.layer)
            })?;
            rotations.insert(e.c_in, Arc::new(rot));
        }
    }
    let mut map = BTreeMap::new();
    for e in &plan.entries {
        let smooths = matches!(e.mode, Mode::Smooth | Mode::SmoothRotate);
        let (smooth, smooth_inv) = match (&e.smooth, smooths) {
            (Some(s), true) => {
                if s.len() != e.c_in {
                    return Err(format!(
                        "plan registry: {} layer {}: smoothing vector has {} channels, entry says c_in {}",
                        e.module,
                        e.layer,
                        s.len(),
                        e.c_in
                    ));
                }
                let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
                (Some(Arc::new(s.clone())), Some(Arc::new(inv)))
            }
            (None, true) => {
                return Err(format!(
                    "plan registry: {} layer {}: mode {} without a smoothing vector",
                    e.module,
                    e.layer,
                    e.mode.name()
                ));
            }
            (_, false) => (None, None),
        };
        let rotation = matches!(e.mode, Mode::Rotate | Mode::SmoothRotate)
            .then(|| Arc::clone(&rotations[&e.c_in]));
        let prev = map.entry(e.module.clone()).or_default().insert(
            (e.layer, e.bits),
            ResolvedEntry {
                mode: e.mode,
                alpha: e.alpha,
                c_in: e.c_in,
                predicted_error: e.predicted_error,
                calib_difficulty: e.difficulty_after,
                smooth,
                smooth_inv,
                rotation,
                qweight: None,
            },
        );
        if prev.is_some() {
            return Err(format!(
                "plan registry: duplicate entry for {} layer {} bits {}",
                e.module, e.layer, e.bits
            ));
        }
    }
    Ok(Resolved { map, content_hash: plan.content_hash(), file_hash: None })
}

/// Outcome of one preload pass over a resolved state.
struct PreloadOutcome {
    /// Entries now carrying a pre-quantized weight.
    loaded: usize,
    /// Entries whose preload *failed* and were degraded to the f32
    /// planned path (`qweight = None`) instead of failing the pass.
    degraded: usize,
    /// First degradation error, for the caller's log line.
    first_error: Option<String>,
}

/// Pre-quantize every loadable entry's transformed weight into the
/// resolved state: fetch each layer's weight once, apply the entry's
/// Eq. 4 row scaling and Eq. 3 rotation, quantize per-channel at the
/// entry's bit width (GEMM-ready i8 codes — see [`PlannedWeight`]).
/// Entries whose bits exceed i8 storage, or for which the provider has
/// no weight, keep `qweight = None` (the executor falls back to the
/// f32 planned path for them).
///
/// A *failing* entry — provider weight mismatching the plan's width,
/// quantization rejecting the weight, or the `plan.preload_fail`
/// failpoint — degrades that one entry to f32-planned and is counted,
/// rather than stripping the whole plan: the blast radius of one bad
/// weight is one cell, and the rest of the plan keeps serving int8.
fn preload_into(res: &mut Resolved, f: &WeightFn) -> PreloadOutcome {
    let mut out = PreloadOutcome { loaded: 0, degraded: 0, first_error: None };
    for (module, inner) in res.map.iter_mut() {
        // one provider call per layer, shared across bit widths
        let mut weights: BTreeMap<usize, Option<Matrix>> = BTreeMap::new();
        for (&(layer, bits), entry) in inner.iter_mut() {
            entry.qweight = None;
            if !(2..=8).contains(&bits) {
                continue;
            }
            let w = weights.entry(layer).or_insert_with(|| f(module, layer));
            let Some(w) = w else { continue };
            let attempt = if crate::faults::fire_key("plan.preload_fail", layer as u64) {
                Err("fault injected: plan.preload_fail".to_string())
            } else if w.rows() != entry.c_in {
                Err(format!(
                    "weight has {} input channels, plan says {}",
                    w.rows(),
                    entry.c_in
                ))
            } else {
                let smooth = entry.smooth.as_ref().map(|s| s.as_slice());
                PlannedWeight::from_plan(w, smooth, entry.rotation.as_deref(), bits, 1)
            };
            match attempt {
                Ok(pw) => {
                    entry.qweight = Some(Arc::new(pw));
                    out.loaded += 1;
                }
                Err(e) => {
                    // degrade just this cell to the f32 planned path
                    out.degraded += 1;
                    out.first_error
                        .get_or_insert(format!("plan registry: {module} layer {layer}: {e}"));
                }
            }
        }
    }
    out
}

fn read_plan_text(path: &Path) -> Result<String, String> {
    std::fs::read_to_string(path)
        .map_err(|e| format!("plan registry: read {}: {e}", path.display()))
}

impl PlanRegistry {
    /// Resolve an in-memory plan (no backing file; reload is a no-op).
    pub fn from_plan(plan: &QuantPlan) -> Result<Self, String> {
        Ok(Self {
            path: None,
            state: RwLock::new(resolve(plan)?),
            provider: Mutex::new(None),
            planned: AtomicU64::new(0),
            fallback: AtomicU64::new(0),
            int8_executed: AtomicU64::new(0),
            int8_degraded: AtomicU64::new(0),
            batch_fused: AtomicU64::new(0),
            reload_skipped: AtomicU64::new(0),
            generation: AtomicU64::new(0),
            reload_failed: AtomicU64::new(0),
            preload_degraded_count: AtomicU64::new(0),
            backoff: Mutex::new(ReloadBackoff::default()),
        })
    }

    /// Load, parse and resolve a plan file, remembering its raw-byte
    /// hash for [`PlanRegistry::reload_if_changed`].
    pub fn load(path: impl Into<PathBuf>) -> Result<Self, String> {
        let path = path.into();
        let text = read_plan_text(&path)?;
        let plan = QuantPlan::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        let mut resolved = resolve(&plan)?;
        resolved.file_hash = Some(fnv1a64(text.as_bytes()));
        Ok(Self {
            path: Some(path),
            state: RwLock::new(resolved),
            provider: Mutex::new(None),
            planned: AtomicU64::new(0),
            fallback: AtomicU64::new(0),
            int8_executed: AtomicU64::new(0),
            int8_degraded: AtomicU64::new(0),
            batch_fused: AtomicU64::new(0),
            reload_skipped: AtomicU64::new(0),
            generation: AtomicU64::new(0),
            reload_failed: AtomicU64::new(0),
            preload_degraded_count: AtomicU64::new(0),
            backoff: Mutex::new(ReloadBackoff::default()),
        })
    }

    /// Install the serving model's weight source and pre-quantize every
    /// covered entry's transformed weight for the integer execution
    /// path (`serve --exec int8`) — once per (module, layer, bits), not
    /// per request.  The provider is remembered, so a successful hot
    /// reload re-quantizes against the fresh plan automatically.
    /// Returns the number of entries now carrying a pre-quantized
    /// weight.
    ///
    /// An entry whose preload fails (provider weight mismatching the
    /// plan's width, quantization rejecting it) is degraded to the f32
    /// planned path — `qweight = None` for that one cell — and counted
    /// via [`PlanRegistry::preload_degraded`]; the rest of the plan
    /// keeps its int8 weights and the provider stays installed for the
    /// next hot reload.  That is the middle rung of the degradation
    /// ladder (int8 → f32-planned → full-analyze): one bad weight must
    /// not strip a whole fleet's integer path.
    pub fn set_weight_provider(&self, f: WeightFn) -> Result<usize, String> {
        // hold the provider slot across the whole install so a
        // concurrent reload can neither run with the half-installed
        // provider nor swap in a weightless state mid-install (lock
        // order is always provider -> state, never nested the other
        // way)
        let mut guard = match self.provider.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let outcome = {
            let mut state = match self.state.write() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            preload_into(&mut state, &f)
        };
        if outcome.degraded > 0 {
            self.preload_degraded_count.fetch_add(outcome.degraded as u64, Ordering::Relaxed);
            if let Some(e) = &outcome.first_error {
                eprintln!(
                    "plan registry: {} entr{} degraded to f32-planned (first: {e})",
                    outcome.degraded,
                    if outcome.degraded == 1 { "y" } else { "ies" }
                );
            }
        }
        *guard = Some(WeightProvider(f));
        Ok(outcome.loaded)
    }

    /// Entries currently carrying a pre-quantized weight.
    pub fn preloaded(&self) -> usize {
        self.read()
            .map
            .values()
            .flat_map(BTreeMap::values)
            .filter(|e| e.qweight.is_some())
            .count()
    }

    /// The backing plan file, if any.
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Resolved entries currently loaded.
    pub fn len(&self) -> usize {
        self.read().map.values().map(BTreeMap::len).sum()
    }

    /// Whether no entries are loaded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Content hash of the currently loaded plan.
    pub fn content_hash(&self) -> String {
        self.read().content_hash.clone()
    }

    fn read(&self) -> std::sync::RwLockReadGuard<'_, Resolved> {
        match self.state.read() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    /// The resolved entry for a (module, layer, bits) request of
    /// activation width `c_in`, counting the outcome: a usable hit
    /// bumps the planned counter; a miss — including an entry whose
    /// calibrated width disagrees with the request's — bumps the
    /// fallback counter (the caller is expected to run the full
    /// analyze on a miss), so the coverage stats always reflect what
    /// actually executed.
    pub fn lookup(
        &self,
        module: &str,
        layer: usize,
        bits: u32,
        c_in: usize,
    ) -> Option<ResolvedEntry> {
        // module is looked up by borrowed &str and the inner key is
        // Copy, so the hot path allocates nothing; a hit clones Arcs
        // plus a few scalars.  The request's `alpha` is deliberately
        // NOT part of the key: the calibrated transform (including its
        // grid-searched alpha and smoothing vector) *overrides* the
        // per-request migration strength — that is the "calibrate
        // once" contract, and keying on request alpha would evict
        // every grid-searched entry.
        let got = self
            .read()
            .map
            .get(module)
            .and_then(|m| m.get(&(layer, bits)))
            .cloned()
            .filter(|e| e.c_in == c_in);
        if got.is_some() {
            self.planned.fetch_add(1, Ordering::Relaxed);
        } else {
            self.fallback.fetch_add(1, Ordering::Relaxed);
        }
        got
    }

    /// `(planned, fallback)` lookup counters since creation.
    pub fn stats(&self) -> (u64, u64) {
        (self.planned.load(Ordering::Relaxed), self.fallback.load(Ordering::Relaxed))
    }

    /// Credit `n` additional plan-answered requests to the coverage
    /// stats.  The batch-fused executor resolves a whole same-cell
    /// group with **one** [`PlanRegistry::lookup`] (which counts one
    /// request) and then credits the rest of the group here, so the
    /// coverage numbers keep their per-request meaning regardless of
    /// how requests were grouped.
    pub fn note_planned_many(&self, n: u64) {
        self.planned.fetch_add(n, Ordering::Relaxed);
    }

    /// [`PlanRegistry::note_planned_many`] for the fallback counter.
    pub fn note_fallback_many(&self, n: u64) {
        self.fallback.fetch_add(n, Ordering::Relaxed);
    }

    /// Record whether an [`ExecMode::Int8`]-requested job actually ran
    /// the integer pipeline (`true`) or silently degraded to the f32
    /// planned path on a covered cell (`false`) — bumped by the serving
    /// executor so operators can see when int8 is not really executing.
    ///
    /// [`ExecMode::Int8`]: crate::serve::ExecMode::Int8
    pub fn note_int8(&self, executed: bool) {
        self.note_int8_many(executed, 1);
    }

    /// [`PlanRegistry::note_int8`] for `n` requests at once (one
    /// batch-fused group).
    pub fn note_int8_many(&self, executed: bool, n: u64) {
        if executed {
            self.int8_executed.fetch_add(n, Ordering::Relaxed);
        } else {
            self.int8_degraded.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Record `n` requests executed through the stacked batch-fused
    /// integer path (one fused group = one tall GEMM for `n` requests).
    /// Zero while int8 requests are executing means the hot path
    /// silently fell back to per-job dispatch — the serve CLI fails on
    /// that, mirroring the `int8_executed == 0` gate.
    pub fn note_batch_fused(&self, n: u64) {
        self.batch_fused.fetch_add(n, Ordering::Relaxed);
    }

    /// Requests executed through the stacked batch-fused integer path
    /// since creation.
    pub fn batch_fused(&self) -> u64 {
        self.batch_fused.load(Ordering::Relaxed)
    }

    /// `(executed, degraded)` int8-exec counters since creation.
    pub fn int8_stats(&self) -> (u64, u64) {
        (self.int8_executed.load(Ordering::Relaxed), self.int8_degraded.load(Ordering::Relaxed))
    }

    /// Polls that skipped a formatting-only plan-file rewrite (raw
    /// bytes changed, canonical content identical) since creation.
    pub fn reload_skipped_identical(&self) -> u64 {
        self.reload_skipped.load(Ordering::Relaxed)
    }

    /// Reload attempts that failed since creation (the old plan stayed
    /// live each time).
    pub fn reload_failed(&self) -> u64 {
        self.reload_failed.load(Ordering::Relaxed)
    }

    /// Plan entries degraded to the f32 planned path by a failed int8
    /// weight preload since creation.
    pub fn preload_degraded(&self) -> u64 {
        self.preload_degraded_count.load(Ordering::Relaxed)
    }

    /// Hot swaps performed since creation.  Bumped inside the state
    /// write lock, so a reader that observes generation `g` is
    /// guaranteed to resolve lookups against plan generation `>= g`.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Relaxed)
    }

    /// Poll the backing file's *content* and atomically swap in the
    /// re-resolved plan when it semantically changed.  Returns
    /// `Ok(true)` iff a new plan is now live.  Registries without a
    /// backing file always return `Ok(false)`.
    ///
    /// Two-level change detection, cheapest first:
    /// 1. FNV-1a over the raw file bytes — byte-identical file, no
    ///    parse, no swap.  Content-addressed, so a same-second
    ///    same-size rewrite (which an mtime+length stamp misses) is
    ///    still caught.
    /// 2. The parsed plan's canonical [`QuantPlan::content_hash`] — a
    ///    rewrite that only changes formatting is remembered (its raw
    ///    hash becomes the new short-circuit) and counted via
    ///    [`PlanRegistry::reload_skipped_identical`], but never
    ///    re-resolved or swapped.
    ///
    /// **Never serves a torn artifact.**  Any failure — unreadable
    /// file, corrupt/partial JSON, schema/version rejection, resolve
    /// error — leaves the previously resolved plan live and untouched,
    /// bumps [`PlanRegistry::reload_failed`], and arms a bounded
    /// exponential backoff ([`RELOAD_BACKOFF_INITIAL`] doubling up to
    /// [`RELOAD_BACKOFF_MAX`]): polls inside the backoff window return
    /// `Ok(false)` without touching the file, so a persistently corrupt
    /// rewrite costs one parse per backoff step, not one per poll.  Any
    /// successful poll (including a no-change short-circuit) resets the
    /// backoff.
    pub fn reload_if_changed(&self) -> Result<bool, String> {
        if self.path.is_none() {
            return Ok(false);
        }
        {
            let b = match self.backoff.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            if let Some(until) = b.until {
                if Instant::now() < until {
                    return Ok(false);
                }
            }
        }
        let result = self.try_reload();
        let mut b = match self.backoff.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        match &result {
            Ok(_) => {
                b.until = None;
                b.delay = Duration::ZERO;
            }
            Err(_) => {
                self.reload_failed.fetch_add(1, Ordering::Relaxed);
                b.delay = if b.delay.is_zero() {
                    RELOAD_BACKOFF_INITIAL
                } else {
                    (b.delay * 2).min(RELOAD_BACKOFF_MAX)
                };
                b.until = Some(Instant::now() + b.delay);
            }
        }
        result
    }

    /// One reload attempt (no backoff bookkeeping).
    fn try_reload(&self) -> Result<bool, String> {
        let Some(path) = &self.path else { return Ok(false) };
        // `plan.reload_corrupt` failpoint: force this reload attempt to
        // be treated as a torn read, for chaos coverage of the
        // keep-old-plan path without racing real partial writes.  Fires
        // before the raw-hash short-circuit so an unchanged file still
        // exercises the failure path deterministically.
        if crate::faults::fire("plan.reload_corrupt") {
            return Err(format!(
                "plan registry: {}: fault injected: plan.reload_corrupt",
                path.display()
            ));
        }
        let text = read_plan_text(path)?;
        let raw_hash = fnv1a64(text.as_bytes());
        {
            let state = self.read();
            if state.file_hash == Some(raw_hash) {
                return Ok(false);
            }
        }
        let plan = QuantPlan::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        {
            let mut state = match self.state.write() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            if state.content_hash == plan.content_hash() {
                // formatting-only rewrite: adopt the new raw hash so
                // the next poll short-circuits, count the skip, keep
                // the live state untouched
                state.file_hash = Some(raw_hash);
                self.reload_skipped.fetch_add(1, Ordering::Relaxed);
                return Ok(false);
            }
        }
        let mut resolved = resolve(&plan)?;
        resolved.file_hash = Some(raw_hash);
        // re-quantize planned weights against the fresh plan *before*
        // the swap, so int8 serving never sees a weightless window.
        // The provider slot stays locked across the swap itself
        // (provider -> state, same order as set_weight_provider):
        // otherwise a concurrent set_weight_provider could slip in
        // between preload and swap and be clobbered by weights from
        // the provider it just replaced.
        let guard = match self.provider.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if let Some(p) = guard.as_ref() {
            // entry-level preload failures degrade those cells to the
            // f32 planned path; they never abort the reload (the fresh
            // plan with a few weightless cells still beats the stale
            // plan)
            let outcome = preload_into(&mut resolved, &p.0);
            if outcome.degraded > 0 {
                self.preload_degraded_count
                    .fetch_add(outcome.degraded as u64, Ordering::Relaxed);
                if let Some(e) = &outcome.first_error {
                    eprintln!(
                        "plan registry: reload degraded {} entr{} to f32-planned (first: {e})",
                        outcome.degraded,
                        if outcome.degraded == 1 { "y" } else { "ies" }
                    );
                }
            }
        }
        let changed = {
            let mut state = match self.state.write() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            let changed = state.content_hash != resolved.content_hash;
            *state = resolved;
            if changed {
                // inside the write lock: a reader can never observe the
                // new generation number with the old plan still live
                self.generation.fetch_add(1, Ordering::Relaxed);
            }
            changed
        };
        drop(guard);
        Ok(changed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::plan::{PlanEntry, Provenance};

    fn entry(module: &str, layer: usize, mode: Mode, c_in: usize) -> PlanEntry {
        PlanEntry {
            module: module.into(),
            layer,
            bits: 4,
            c_in,
            mode,
            alpha: 0.5,
            predicted_error: 1.0,
            difficulty_before: 2.0,
            difficulty_after: 1.0,
            smooth: matches!(mode, Mode::Smooth | Mode::SmoothRotate)
                .then(|| vec![1.0f32; c_in]),
        }
    }

    fn plan(entries: Vec<PlanEntry>) -> QuantPlan {
        QuantPlan { provenance: Provenance::default(), entries }
    }

    #[test]
    fn resolves_rotations_once_per_width_and_counts_lookups() {
        let reg = PlanRegistry::from_plan(&plan(vec![
            entry("k_proj", 0, Mode::Rotate, 16),
            entry("k_proj", 1, Mode::SmoothRotate, 16),
            entry("down_proj", 0, Mode::None, 8),
        ]))
        .unwrap();
        assert_eq!(reg.len(), 3);
        let a = reg.lookup("k_proj", 0, 4, 16).unwrap();
        let b = reg.lookup("k_proj", 1, 4, 16).unwrap();
        // both 16-wide rotating entries share one pre-built rotation
        assert!(Arc::ptr_eq(a.rotation.as_ref().unwrap(), b.rotation.as_ref().unwrap()));
        assert!(b.smooth.is_some() && a.smooth.is_none());
        // reciprocals are resolved once, alongside the vector itself
        let inv = b.smooth_inv.as_ref().unwrap();
        for (s, i) in b.smooth.as_ref().unwrap().iter().zip(inv.iter()) {
            assert_eq!(*i, 1.0 / s);
        }
        assert!(reg.lookup("down_proj", 0, 4, 8).unwrap().rotation.is_none());
        assert!(reg.lookup("o_proj", 0, 4, 16).is_none(), "uncalibrated cell misses");
        assert!(reg.lookup("k_proj", 0, 8, 16).is_none(), "bits is part of the key");
        // a width mismatch is a FALLBACK, not a planned hit — coverage
        // stats must reflect what actually executed
        assert!(reg.lookup("k_proj", 0, 4, 32).is_none(), "width mismatch falls back");
        assert_eq!(reg.stats(), (3, 3));
    }

    #[test]
    fn invalid_plans_are_rejected_at_resolve_time() {
        // smoothing mode without its vector
        let mut e = entry("k_proj", 0, Mode::SmoothRotate, 16);
        e.smooth = None;
        assert!(PlanRegistry::from_plan(&plan(vec![e])).is_err());
        // wrong-length smoothing vector
        let mut e = entry("k_proj", 0, Mode::Smooth, 16);
        e.smooth = Some(vec![1.0; 4]);
        assert!(PlanRegistry::from_plan(&plan(vec![e])).is_err());
        // unconstructible rotation width
        let e = entry("k_proj", 0, Mode::Rotate, 6);
        assert!(PlanRegistry::from_plan(&plan(vec![e])).is_err());
        // duplicate key
        let err = PlanRegistry::from_plan(&plan(vec![
            entry("k_proj", 0, Mode::None, 8),
            entry("k_proj", 0, Mode::None, 8),
        ]))
        .unwrap_err();
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn reload_swaps_on_content_change_only() {
        let dir = std::env::temp_dir().join("smoothrot_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        plan(vec![entry("k_proj", 0, Mode::None, 8)]).save(&path).unwrap();
        let reg = PlanRegistry::load(&path).unwrap();
        assert_eq!(reg.len(), 1);
        // untouched file: no reload
        assert!(!reg.reload_if_changed().unwrap());
        // rewrite with a different plan
        plan(vec![
            entry("k_proj", 0, Mode::Rotate, 16),
            entry("o_proj", 3, Mode::SmoothRotate, 16),
        ])
        .save(&path)
        .unwrap();
        assert!(reg.reload_if_changed().unwrap(), "new content must swap in");
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.lookup("k_proj", 0, 4, 16).unwrap().mode, Mode::Rotate);
        assert!(!reg.reload_if_changed().unwrap(), "second poll sees no change");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reload_catches_a_same_size_rewrite() {
        // an mtime+length stamp misses a rewrite that lands in the same
        // second with the same byte length; raw-byte hashing must not.
        // The two plans serialize to the same length (only a layer
        // index differs) and are written back to back.
        let dir = std::env::temp_dir().join("smoothrot_registry_samesize_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        let a = plan(vec![entry("k_proj", 0, Mode::None, 8)]);
        let b = plan(vec![entry("k_proj", 1, Mode::None, 8)]);
        assert_eq!(
            a.to_json_string().len(),
            b.to_json_string().len(),
            "fixture must be a same-size rewrite"
        );
        a.save(&path).unwrap();
        let reg = PlanRegistry::load(&path).unwrap();
        assert_eq!(reg.generation(), 0);
        b.save(&path).unwrap();
        assert!(reg.reload_if_changed().unwrap(), "same-size rewrite must swap in");
        assert!(reg.lookup("k_proj", 1, 4, 8).is_some());
        assert!(reg.lookup("k_proj", 0, 4, 8).is_none());
        assert_eq!(reg.generation(), 1, "a real swap bumps the generation");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn formatting_only_rewrite_is_skipped_and_counted() {
        let dir = std::env::temp_dir().join("smoothrot_registry_fmt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        let p = plan(vec![entry("k_proj", 0, Mode::None, 8)]);
        p.save(&path).unwrap();
        let reg = PlanRegistry::load(&path).unwrap();
        // rewrite the same plan with different raw bytes but identical
        // canonical content (trailing whitespace is formatting)
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, format!("{text}\n\n")).unwrap();
        assert_eq!(reg.reload_skipped_identical(), 0);
        assert!(!reg.reload_if_changed().unwrap(), "identical content must not swap");
        assert_eq!(reg.reload_skipped_identical(), 1);
        assert_eq!(reg.generation(), 0, "a skipped reload is not a new generation");
        // the rewritten bytes became the new short-circuit: the next
        // poll is a cheap raw-hash hit, not another parse-and-skip
        assert!(!reg.reload_if_changed().unwrap());
        assert_eq!(reg.reload_skipped_identical(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn weight_provider_prequantizes_once_per_entry() {
        use std::sync::atomic::{AtomicUsize, Ordering};

        let mut e8 = entry("k_proj", 0, Mode::SmoothRotate, 16);
        e8.bits = 8;
        let reg = PlanRegistry::from_plan(&plan(vec![
            entry("k_proj", 0, Mode::SmoothRotate, 16),
            e8,
            entry("k_proj", 1, Mode::None, 16),
            entry("down_proj", 0, Mode::Rotate, 8),
        ]))
        .unwrap();
        assert_eq!(reg.preloaded(), 0, "no weights before a provider is installed");
        let calls = Arc::new(AtomicUsize::new(0));
        let calls2 = Arc::clone(&calls);
        let loaded = reg
            .set_weight_provider(Box::new(move |module, layer| {
                calls2.fetch_add(1, Ordering::Relaxed);
                let c_in = if module == "k_proj" { 16 } else { 8 };
                Some(crate::tensor::Matrix::from_fn(c_in, 4, |i, j| {
                    (i * 7 + j * 3 + layer) as f32 * 0.1 - 1.0
                }))
            }))
            .unwrap();
        assert_eq!(loaded, 4);
        assert_eq!(reg.preloaded(), 4);
        // one provider call per distinct (module, layer), shared across
        // the 4- and 8-bit entries of (k_proj, 0)
        assert_eq!(calls.load(Ordering::Relaxed), 3);
        let e = reg.lookup("k_proj", 0, 4, 16).unwrap();
        let pw = e.qweight.expect("preloaded weight");
        // serving weights are held in the GEMM-ready tile layout only
        // (plain i8 codes even at 4 bits — nothing to unpack per request)
        assert_eq!(pw.packed.shape(), (16, 4));
        assert_eq!(pw.packed.bits(), 4);
    }

    #[test]
    fn provider_width_mismatch_degrades_only_that_entry() {
        let reg = PlanRegistry::from_plan(&plan(vec![
            entry("k_proj", 0, Mode::None, 8),
            entry("o_proj", 0, Mode::None, 16),
        ]))
        .unwrap();
        // good provider first: both entries carry weights
        reg.set_weight_provider(Box::new(|module, _| {
            let c_in = if module == "k_proj" { 8 } else { 16 };
            Some(crate::tensor::Matrix::zeros(c_in, 4))
        }))
        .unwrap();
        assert_eq!(reg.preloaded(), 2);
        assert_eq!(reg.preload_degraded(), 0);
        // a provider whose weight width only fits k_proj: the o_proj
        // entry degrades to f32-planned, k_proj keeps its int8 weight —
        // blast radius of one bad weight is one cell, not the plan
        let loaded = reg
            .set_weight_provider(Box::new(|_, _| Some(crate::tensor::Matrix::zeros(8, 4))))
            .unwrap();
        assert_eq!(loaded, 1);
        assert_eq!(reg.preloaded(), 1, "the matching entry must keep its weight");
        assert_eq!(reg.preload_degraded(), 1, "the mismatching entry is counted as degraded");
        assert!(reg.lookup("k_proj", 0, 4, 8).unwrap().qweight.is_some());
        assert!(reg.lookup("o_proj", 0, 4, 16).unwrap().qweight.is_none());
    }

    #[test]
    fn reload_requantizes_planned_weights() {
        let dir = std::env::temp_dir().join("smoothrot_registry_int8_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        plan(vec![entry("k_proj", 0, Mode::None, 8)]).save(&path).unwrap();
        let reg = PlanRegistry::load(&path).unwrap();
        reg.set_weight_provider(Box::new(|_, _| Some(crate::tensor::Matrix::zeros(8, 4))))
            .unwrap();
        assert_eq!(reg.preloaded(), 1);
        plan(vec![entry("k_proj", 0, Mode::None, 8), entry("k_proj", 1, Mode::None, 8)])
            .save(&path)
            .unwrap();
        assert!(reg.reload_if_changed().unwrap());
        assert_eq!(reg.preloaded(), 2, "hot reload must re-quantize against the new plan");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn in_memory_registry_never_reloads() {
        let reg = PlanRegistry::from_plan(&plan(vec![entry("k_proj", 0, Mode::None, 8)])).unwrap();
        assert!(reg.path().is_none());
        assert!(!reg.reload_if_changed().unwrap());
    }
}
