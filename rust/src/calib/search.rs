//! Per-layer quantization-plan search — mode × alpha × bits on the
//! collected calibration stats.
//!
//! For each (module, layer) the searcher runs the Eq. 2 / Eq. 4
//! machinery through the fused kernel engine
//! ([`crate::kernels::fused::analyze_all_modes`]) over a migration-
//! strength grid and a bit-width grid, then picks the transform with
//! [`choose_mode`] — the paper's Sec. V rule: the error-minimizing
//! calibration-free transform (`none` | `rotate`), upgraded to
//! `smooth_rotate` only where its advantage exceeds the `sr_margin`
//! conservatism.  [`crate::policy::recommend`] is re-expressed on the
//! same chooser, which is what the calibrate-vs-analyze equivalence pin
//! (`rust/tests/calib_equivalence.rs`) relies on.
//!
//! The Eq. 4 smoothing vector recorded in the plan is computed from the
//! *streaming* channel maxima ([`super::stats::ChannelStats::abs_max`]),
//! not from the retained sample — with full retention the two coincide
//! bit-for-bit; with subsampling the stream-exact maxima are the more
//! faithful deployment vector.

use crate::calib::plan::PlanEntry;
use crate::calib::stats::LayerCollector;
use crate::kernels::fused::analyze_all_modes;
use crate::kernels::workspace::Workspace;
use crate::runtime::AnalyzeOut;
use crate::tensor::Matrix;
use crate::transforms::{self, Mode, RotationCache};

/// Search-space configuration.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    /// Migration-strength grid for the smoothing modes.
    pub alphas: Vec<f64>,
    /// Bit widths to emit plan entries for.
    pub bits_grid: Vec<u32>,
    /// Minimum error ratio before adopting smooth-rotation (Sec. V).
    pub sr_margin: f64,
    /// Math threads inside the fused kernels (`0` = all cores).
    pub threads: usize,
    /// Re-evaluate each chosen entry through the REAL integer kernels
    /// ([`crate::kernels::fused::analyze_planned_int`]) and record the
    /// executed error alongside the simulated prediction
    /// ([`LayerSearch::executed`], `smoothrot calibrate --exec-check`).
    /// Only entries at ≤ 8 bits can execute in integers; wider grids
    /// report `NaN`.
    pub exec_check: bool,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self {
            alphas: vec![0.5],
            bits_grid: vec![4],
            sr_margin: 1.25,
            threads: 1,
            exec_check: false,
        }
    }
}

impl SearchConfig {
    /// Reject empty or out-of-range grids before a search starts.
    pub fn validate(&self) -> Result<(), String> {
        if self.alphas.is_empty() {
            return Err("plan search: alpha grid is empty".into());
        }
        if self.alphas.iter().any(|&a| !(0.0..=1.0).contains(&a)) {
            return Err("plan search: alphas must be in [0, 1]".into());
        }
        if self.bits_grid.is_empty() {
            return Err("plan search: bits grid is empty".into());
        }
        for &b in &self.bits_grid {
            crate::quant::validate_bits(b).map_err(|e| format!("plan search: {e}"))?;
        }
        if self.sr_margin <= 0.0 {
            return Err("plan search: sr_margin must be positive".into());
        }
        Ok(())
    }
}

/// The Sec. V transform chooser over one cell's per-mode errors
/// (indexed in [`Mode::ALL`] order): best calibration-free transform
/// (`none` | `rotate`), upgraded to `smooth_rotate` only when
/// `free_error / sr_error >= sr_margin`.
///
/// Shared by the plan search and [`crate::policy::recommend`], so the
/// offline policy and the calibration plan can never disagree on the
/// same errors.
pub fn choose_mode(errors: &[f64; 4], sr_margin: f64) -> Mode {
    let free = [Mode::None, Mode::Rotate]
        .into_iter()
        .min_by(|a, b| errors[a.index()].partial_cmp(&errors[b.index()]).unwrap())
        .unwrap();
    let free_err = errors[free.index()];
    let sr_err = errors[Mode::SmoothRotate.index()];
    if sr_err > 0.0 && free_err / sr_err >= sr_margin {
        Mode::SmoothRotate
    } else {
        free
    }
}

/// Search result for one (module, layer): plan entries (one per bit
/// width) plus the analyze output at the first grid point — the anchor
/// the policy-equivalence pin compares against.
#[derive(Clone, Debug)]
pub struct LayerSearch {
    /// One entry per `bits_grid` value.
    pub entries: Vec<PlanEntry>,
    /// `analyze_all_modes` output at `(alphas[0], bits_grid[0])`.
    pub base: AnalyzeOut,
    /// Executed integer-path error per entry (same order as
    /// `entries`), populated when [`SearchConfig::exec_check`] is set;
    /// `NaN` for entries whose bit width exceeds i8 storage.  Empty
    /// when the check is off.
    pub executed: Vec<f64>,
}

/// Grid-search one (module, layer) cell on its collected stats +
/// retained sample, reusing the caller's rotation cache and workspace
/// across every grid point.
pub fn search_layer(
    module: &str,
    layer: usize,
    collector: &LayerCollector,
    w: &Matrix,
    cfg: &SearchConfig,
    cache: &mut RotationCache,
    ws: &mut Workspace,
) -> Result<LayerSearch, String> {
    cfg.validate()?;
    let x = collector.reservoir.sample();
    if x.rows() == 0 {
        return Err(format!("plan search: {module} layer {layer}: no calibration sample retained"));
    }
    if w.rows() != x.cols() {
        return Err(format!(
            "plan search: {module} layer {layer}: sample width {} vs weight rows {}",
            x.cols(),
            w.rows()
        ));
    }
    let difficulty_before = collector.stats.difficulty();
    let wmax = transforms::weight_row_abs_max(w);

    let mut entries = Vec::with_capacity(cfg.bits_grid.len());
    let mut base: Option<AnalyzeOut> = None;
    for &bits in &cfg.bits_grid {
        // one fused all-modes analyze at the first grid point (the
        // policy-equivalence anchor); `none` and `rotate` are
        // alpha-independent, so every further alpha needs only a
        // single-mode smooth-rotate evaluation through the planned
        // kernel with the stream-exact Eq. 4 vector for that alpha —
        // exactly the vector a plan choosing it would deploy
        let first = analyze_all_modes(&x, w, bits, cfg.alphas[0] as f32, cache, ws, cfg.threads)?;
        if base.is_none() {
            base = Some(first);
        }
        let sr_i = Mode::SmoothRotate.index();
        let (mut sr_alpha, mut sr_out) = (cfg.alphas[0] as f32, first);
        for &alpha in &cfg.alphas[1..] {
            let alpha = alpha as f32;
            let s = transforms::smooth_scales_from_max(collector.stats.abs_max(), &wmax, alpha);
            let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
            let rot = cache.get(x.cols())?;
            let out = crate::kernels::fused::analyze_planned(
                &x,
                w,
                bits,
                Mode::SmoothRotate,
                Some((&s[..], &inv[..])),
                Some(rot),
                ws,
                cfg.threads,
            )?;
            if out.errors[sr_i] < sr_out.errors[sr_i] {
                sr_alpha = alpha;
                sr_out = out;
            }
        }
        // errors[Smooth] is informational only: choose_mode implements
        // the paper's Sec. V rule, which never deploys standalone
        // smoothing (it upgrades free transforms to smooth_rotate or
        // nothing), so a searched plan never emits a `smooth` entry —
        // the artifact/registry still accept one for hand-written plans
        let errors = [
            first.errors[Mode::None.index()],
            first.errors[Mode::Smooth.index()],
            first.errors[Mode::Rotate.index()],
            sr_out.errors[sr_i],
        ];
        let mode = choose_mode(&errors, cfg.sr_margin);
        let (alpha, chosen_out) = match mode {
            Mode::SmoothRotate => (sr_alpha, sr_out),
            _ => (cfg.alphas[0] as f32, first),
        };
        let smooth = matches!(mode, Mode::Smooth | Mode::SmoothRotate).then(|| {
            transforms::smooth_scales_from_max(collector.stats.abs_max(), &wmax, alpha)
        });
        entries.push(PlanEntry {
            module: module.to_string(),
            layer,
            bits,
            c_in: x.cols(),
            mode,
            alpha,
            predicted_error: errors[mode.index()],
            difficulty_before,
            difficulty_after: chosen_out.act_difficulty[mode.index()],
            smooth,
        });
    }
    let mut executed = Vec::new();
    if cfg.exec_check {
        // re-run each chosen transform through the real integer path
        // (pre-quantized transformed weight + i32-accumulated GEMM on
        // the calibration sample) — the executed error the deployment
        // will actually produce, not the f32 simulation
        for e in &entries {
            if e.bits > 8 {
                executed.push(f64::NAN);
                continue;
            }
            let smooth_s = e.smooth.as_deref();
            let inv: Option<Vec<f32>> = smooth_s.map(|s| s.iter().map(|&v| 1.0 / v).collect());
            let rot: Option<&crate::transforms::Rotation> =
                if matches!(e.mode, Mode::Rotate | Mode::SmoothRotate) {
                    Some(cache.get(x.cols())?)
                } else {
                    None
                };
            let pw =
                crate::qtensor::PlannedWeight::from_plan(w, smooth_s, rot, e.bits, cfg.threads)?;
            let smooth_pair = match (smooth_s, inv.as_deref()) {
                (Some(s), Some(i)) => Some((s, i)),
                _ => None,
            };
            let out = crate::kernels::fused::analyze_planned_int(
                &x,
                w,
                e.bits,
                e.mode,
                smooth_pair,
                rot,
                &pw,
                ws,
                cfg.threads,
            )?;
            executed.push(out.errors[e.mode.index()]);
        }
    }
    Ok(LayerSearch { entries, base: base.expect("bits grid validated non-empty"), executed })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn choose_mode_mirrors_sec_v_rule() {
        // ordinary cell: rotate best among free, sr within margin
        assert_eq!(choose_mode(&[10.0, 6.0, 4.0, 3.5], 1.25), Mode::Rotate);
        // same cell, eager margin adopts smooth-rotation
        assert_eq!(choose_mode(&[10.0, 6.0, 4.0, 3.5], 1.0), Mode::SmoothRotate);
        // massive cell: rotation hurts, sr pays for itself
        assert_eq!(choose_mode(&[100.0, 40.0, 150.0, 2.0], 1.25), Mode::SmoothRotate);
        // degenerate sr error never divides by zero
        assert_eq!(choose_mode(&[5.0, 5.0, 6.0, 0.0], 1.25), Mode::None);
    }

    fn collector_for(x: &Matrix) -> LayerCollector {
        let mut c = LayerCollector::new(x.cols(), 0);
        c.observe(x).unwrap();
        c
    }

    #[test]
    fn massive_outlier_layer_chooses_smooth_rotation() {
        let (spec, c_out) = crate::synth::module_stream("down_proj", 11).unwrap();
        let mut spec = spec;
        spec.n_tokens = 48;
        let layer = 30; // massive-spike layer in the down_proj profile
        let x = spec.layer(layer);
        let w = spec.weight(c_out, layer);
        let collector = collector_for(&x);
        let mut cache = RotationCache::new();
        let mut ws = Workspace::new();
        let cfg = SearchConfig::default();
        let got =
            search_layer("down_proj", layer, &collector, &w, &cfg, &mut cache, &mut ws).unwrap();
        assert_eq!(got.entries.len(), 1);
        let e = &got.entries[0];
        assert_eq!(e.mode, Mode::SmoothRotate, "massive layer must smooth-rotate");
        assert_eq!(e.c_in, x.cols());
        assert!(e.difficulty_after < e.difficulty_before, "transform must flatten");
        assert_eq!(e.smooth.as_ref().map(Vec::len), Some(x.cols()));
        // stream-exact Eq. 4 vector: with full retention it equals the
        // matrix-pass scales exactly
        let want = transforms::smooth_scales(&x, &w, e.alpha);
        assert_eq!(e.smooth.as_ref().unwrap(), &want);
    }

    #[test]
    fn wider_alpha_grid_never_predicts_worse() {
        let mut rng = Rng::new(21);
        let mut x = Matrix::from_vec(32, 64, rng.normals_f32(32 * 64));
        for i in 0..32 {
            x.row_mut(i)[5] *= 30.0;
        }
        let w = Matrix::from_vec(64, 16, rng.normals_f32(64 * 16));
        let collector = collector_for(&x);
        let mut cache = RotationCache::new();
        let mut ws = Workspace::new();
        let narrow = SearchConfig { sr_margin: 1.0, ..SearchConfig::default() };
        let wide = SearchConfig {
            alphas: vec![0.3, 0.5, 0.7],
            sr_margin: 1.0,
            ..SearchConfig::default()
        };
        let a = search_layer("k_proj", 0, &collector, &w, &narrow, &mut cache, &mut ws).unwrap();
        let b = search_layer("k_proj", 0, &collector, &w, &wide, &mut cache, &mut ws).unwrap();
        assert!(
            b.entries[0].predicted_error <= a.entries[0].predicted_error,
            "wide {} vs narrow {}",
            b.entries[0].predicted_error,
            a.entries[0].predicted_error
        );
    }

    #[test]
    fn one_entry_per_bits_grid_point() {
        let mut rng = Rng::new(22);
        let x = Matrix::from_vec(16, 32, rng.normals_f32(16 * 32));
        let w = Matrix::from_vec(32, 8, rng.normals_f32(32 * 8));
        let collector = collector_for(&x);
        let mut cache = RotationCache::new();
        let mut ws = Workspace::new();
        let cfg = SearchConfig { bits_grid: vec![4, 8], ..SearchConfig::default() };
        let got = search_layer("k_proj", 2, &collector, &w, &cfg, &mut cache, &mut ws).unwrap();
        assert_eq!(got.entries.len(), 2);
        assert_eq!((got.entries[0].bits, got.entries[1].bits), (4, 8));
        // 8-bit quantization of the same tensors errs strictly less
        assert!(got.entries[1].predicted_error < got.entries[0].predicted_error);
    }

    #[test]
    fn exec_check_reports_executed_errors_near_predictions() {
        let mut rng = Rng::new(23);
        let x = Matrix::from_vec(24, 32, rng.normals_f32(24 * 32));
        let w = Matrix::from_vec(32, 8, rng.normals_f32(32 * 8));
        let collector = collector_for(&x);
        let mut cache = RotationCache::new();
        let mut ws = Workspace::new();
        let cfg =
            SearchConfig { bits_grid: vec![4, 8], exec_check: true, ..SearchConfig::default() };
        let got = search_layer("k_proj", 0, &collector, &w, &cfg, &mut cache, &mut ws).unwrap();
        assert_eq!(got.executed.len(), got.entries.len());
        for (e, &exec) in got.entries.iter().zip(&got.executed) {
            let denom = e.predicted_error.abs().max(1e-12);
            let rel = (e.predicted_error - exec).abs() / denom;
            assert!(
                rel < 1e-2,
                "bits {}: predicted {} vs executed {exec}",
                e.bits,
                e.predicted_error
            );
        }
        // off by default: no integer re-evaluation
        let quiet = search_layer(
            "k_proj",
            0,
            &collector,
            &w,
            &SearchConfig::default(),
            &mut cache,
            &mut ws,
        )
        .unwrap();
        assert!(quiet.executed.is_empty());
    }

    #[test]
    fn invalid_configs_and_empty_samples_error() {
        assert!(SearchConfig { alphas: vec![], ..SearchConfig::default() }.validate().is_err());
        assert!(SearchConfig { bits_grid: vec![1], ..SearchConfig::default() }
            .validate()
            .is_err());
        assert!(SearchConfig { sr_margin: 0.0, ..SearchConfig::default() }.validate().is_err());
        let empty = LayerCollector::new(8, 0);
        let w = Matrix::zeros(8, 4);
        let mut cache = RotationCache::new();
        let mut ws = Workspace::new();
        let err = search_layer(
            "k_proj",
            0,
            &empty,
            &w,
            &SearchConfig::default(),
            &mut cache,
            &mut ws,
        )
        .unwrap_err();
        assert!(err.contains("no calibration sample"), "{err}");
    }
}
