//! Streaming per-channel statistics — the collection stage of
//! calibration.
//!
//! The experiment path computes channel magnitudes and difficulty with
//! all-at-once matrix passes ([`crate::metrics::channel_magnitudes`]);
//! calibration instead *streams* activation batches through a
//! [`ChannelStats`] accumulator that keeps, per channel, the Welford
//! running mean and M2 plus the absolute maximum.  Shards built on
//! different workers merge deterministically (the parallel-variance
//! combine applied in a fixed shard order), so a sharded collection
//! reproduces bit-identical statistics run after run.
//!
//! The Eq. 4 migration vector only needs the per-channel absolute
//! maxima, so it can be computed *exactly* over the full stream from
//! the stats alone ([`crate::transforms::smooth_scales_from_max`]); the
//! plan search additionally needs a representative activation matrix,
//! which a bounded deterministic [`SampleReservoir`] retains.
//! [`LayerCollector`] pairs the two for one (module, layer) stream.

use crate::tensor::Matrix;

/// Mergeable per-channel accumulator: absolute max, Welford mean / M2,
/// and token count.
///
/// Channel `j`'s **magnitude** (the Frobenius norm the paper's
/// difficulty metric is built on) is recovered from the Welford state
/// as `sqrt(M2_j + n · mean_j²)`, so a streamed collection yields the
/// same magnitudes as a one-shot pass over the concatenated batches,
/// without ever holding them.
#[derive(Clone, Debug)]
pub struct ChannelStats {
    /// Tokens (rows) observed.
    n: u64,
    /// Welford running mean per channel.
    mean: Vec<f64>,
    /// Welford running sum of squared deviations per channel.
    m2: Vec<f64>,
    /// Absolute maximum per channel.
    abs_max: Vec<f32>,
}

impl ChannelStats {
    /// Empty accumulator over `channels` channels.
    pub fn new(channels: usize) -> Self {
        Self { n: 0, mean: vec![0.0; channels], m2: vec![0.0; channels], abs_max: vec![0.0; channels] }
    }

    /// Number of channels tracked.
    pub fn channels(&self) -> usize {
        self.mean.len()
    }

    /// Tokens (rows) observed so far.
    pub fn tokens(&self) -> u64 {
        self.n
    }

    /// Fold one activation batch in (rows are tokens, columns are
    /// channels).
    pub fn update(&mut self, batch: &Matrix) -> Result<(), String> {
        if batch.cols() != self.channels() {
            return Err(format!(
                "ChannelStats::update: batch has {} channels, accumulator tracks {}",
                batch.cols(),
                self.channels()
            ));
        }
        for i in 0..batch.rows() {
            self.n += 1;
            let n = self.n as f64;
            for (j, &v) in batch.row(i).iter().enumerate() {
                let v64 = v as f64;
                let d = v64 - self.mean[j];
                self.mean[j] += d / n;
                self.m2[j] += d * (v64 - self.mean[j]);
                let a = v.abs();
                if a > self.abs_max[j] {
                    self.abs_max[j] = a;
                }
            }
        }
        Ok(())
    }

    /// Fold another shard in (parallel Welford combine).  Merging the
    /// same shards in the same order is deterministic; `self` absorbs
    /// `other` as if `other`'s tokens had streamed in after `self`'s.
    pub fn merge(&mut self, other: &ChannelStats) -> Result<(), String> {
        if other.channels() != self.channels() {
            return Err(format!(
                "ChannelStats::merge: shard has {} channels, accumulator tracks {}",
                other.channels(),
                self.channels()
            ));
        }
        if other.n == 0 {
            return Ok(());
        }
        if self.n == 0 {
            *self = other.clone();
            return Ok(());
        }
        let (na, nb) = (self.n as f64, other.n as f64);
        let n = na + nb;
        for j in 0..self.channels() {
            let d = other.mean[j] - self.mean[j];
            self.mean[j] += d * (nb / n);
            self.m2[j] += other.m2[j] + d * d * (na * nb / n);
            if other.abs_max[j] > self.abs_max[j] {
                self.abs_max[j] = other.abs_max[j];
            }
        }
        self.n += other.n;
        Ok(())
    }

    /// Per-channel absolute maxima over the stream (Eq. 4's `max|X_j|`).
    pub fn abs_max(&self) -> &[f32] {
        &self.abs_max
    }

    /// Per-channel mean over the stream.
    pub fn mean(&self) -> &[f64] {
        &self.mean
    }

    /// Per-channel Frobenius magnitude over the stream
    /// (`sqrt(sum_i x_ij²)` — the paper's channel magnitude).
    pub fn channel_magnitudes(&self) -> Vec<f64> {
        let n = self.n as f64;
        self.m2
            .iter()
            .zip(&self.mean)
            .map(|(&m2, &mean)| (m2 + n * mean * mean).max(0.0).sqrt())
            .collect()
    }

    /// The paper's quantization difficulty of the streamed activations:
    /// standard deviation of the channel magnitudes.
    pub fn difficulty(&self) -> f64 {
        crate::metrics::std_dev(&self.channel_magnitudes())
    }
}

/// Bounded deterministic retention of sample token rows for the plan
/// search.  The first `max_rows` rows are kept verbatim; rows beyond
/// the cap overwrite a deterministic pseudo-random slot (Fibonacci hash
/// of the row index), so memory is bounded and the retained sample is
/// reproducible without an RNG.
#[derive(Clone, Debug)]
pub struct SampleReservoir {
    max_rows: usize,
    cols: usize,
    data: Vec<f32>,
    /// Rows currently retained.
    rows: usize,
    /// Rows ever offered.
    seen: u64,
}

impl SampleReservoir {
    /// Reservoir holding at most `max_rows` rows of width `cols`
    /// (`max_rows == 0` means unbounded: retain everything).
    pub fn new(max_rows: usize, cols: usize) -> Self {
        Self { max_rows, cols, data: Vec::new(), rows: 0, seen: 0 }
    }

    /// Rows ever offered to the reservoir.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Rows currently retained.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Offer every row of one batch.
    pub fn observe(&mut self, batch: &Matrix) -> Result<(), String> {
        if batch.cols() != self.cols {
            return Err(format!(
                "SampleReservoir::observe: batch has {} channels, reservoir holds {}",
                batch.cols(),
                self.cols
            ));
        }
        for i in 0..batch.rows() {
            let row = batch.row(i);
            if self.max_rows == 0 || self.rows < self.max_rows {
                self.data.extend_from_slice(row);
                self.rows += 1;
            } else {
                let slot = (self.seen.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 16) as usize
                    % self.max_rows;
                self.data[slot * self.cols..(slot + 1) * self.cols].copy_from_slice(row);
            }
            self.seen += 1;
        }
        Ok(())
    }

    /// The retained sample as one activation matrix (row order =
    /// retention order).
    pub fn sample(&self) -> Matrix {
        Matrix::from_vec(self.rows, self.cols, self.data.clone())
    }
}

/// Streaming collector for one (module, layer) activation stream:
/// exact per-channel statistics plus a bounded representative sample.
#[derive(Clone, Debug)]
pub struct LayerCollector {
    pub stats: ChannelStats,
    pub reservoir: SampleReservoir,
}

impl LayerCollector {
    /// Collector over `channels` channels retaining at most
    /// `max_sample_rows` rows (`0` = retain everything).
    pub fn new(channels: usize, max_sample_rows: usize) -> Self {
        Self {
            stats: ChannelStats::new(channels),
            reservoir: SampleReservoir::new(max_sample_rows, channels),
        }
    }

    /// Fold one activation batch into both the stats and the sample.
    pub fn observe(&mut self, batch: &Matrix) -> Result<(), String> {
        self.stats.update(batch)?;
        self.reservoir.observe(batch)
    }

    /// Fold another shard in (stats merge + sample concatenation up to
    /// the cap, in call order — deterministic for a fixed shard order).
    pub fn merge(&mut self, other: &LayerCollector) -> Result<(), String> {
        self.stats.merge(&other.stats)?;
        if other.reservoir.rows > 0 {
            self.reservoir.observe(&other.reservoir.sample())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{self, Channels};
    use crate::rng::Rng;

    fn rand_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(rows, cols, rng.normals_f32(rows * cols))
    }

    #[test]
    fn streamed_stats_match_one_shot_pass() {
        let full = rand_matrix(64, 16, 1);
        let mut stats = ChannelStats::new(16);
        // stream in three uneven row batches
        for (lo, hi) in [(0usize, 10usize), (10, 37), (37, 64)] {
            let rows = hi - lo;
            let mut batch = Matrix::zeros(rows, 16);
            for i in 0..rows {
                batch.row_mut(i).copy_from_slice(full.row(lo + i));
            }
            stats.update(&batch).unwrap();
        }
        assert_eq!(stats.tokens(), 64);
        let want_mags = metrics::channel_magnitudes(&full, Channels::Columns);
        let got_mags = stats.channel_magnitudes();
        for (a, b) in want_mags.iter().zip(&got_mags) {
            assert!((a - b).abs() / a.abs().max(1e-9) < 1e-10, "{a} vs {b}");
        }
        let want_diff = metrics::quant_difficulty(&full, Channels::Columns);
        assert!((stats.difficulty() - want_diff).abs() < 1e-9);
        let want_max = full.col_abs_max();
        assert_eq!(stats.abs_max(), &want_max[..], "abs max is exact, not approximate");
    }

    #[test]
    fn merge_matches_single_stream_and_is_deterministic() {
        let a = rand_matrix(31, 8, 2);
        let b = rand_matrix(17, 8, 3);
        let c = rand_matrix(5, 8, 4);
        let mut single = ChannelStats::new(8);
        for m in [&a, &b, &c] {
            single.update(m).unwrap();
        }
        let shard = |m: &Matrix| {
            let mut s = ChannelStats::new(8);
            s.update(m).unwrap();
            s
        };
        let mut merged = shard(&a);
        merged.merge(&shard(&b)).unwrap();
        merged.merge(&shard(&c)).unwrap();
        assert_eq!(merged.tokens(), single.tokens());
        for (x, y) in merged.channel_magnitudes().iter().zip(single.channel_magnitudes()) {
            assert!((x - y).abs() / y.abs().max(1e-9) < 1e-9);
        }
        assert_eq!(merged.abs_max(), single.abs_max());
        // fixed shard order is bit-deterministic
        let mut again = shard(&a);
        again.merge(&shard(&b)).unwrap();
        again.merge(&shard(&c)).unwrap();
        assert_eq!(again.mean(), merged.mean());
        assert_eq!(again.channel_magnitudes(), merged.channel_magnitudes());
    }

    #[test]
    fn merge_into_empty_adopts_the_shard() {
        let m = rand_matrix(9, 4, 5);
        let mut shard = ChannelStats::new(4);
        shard.update(&m).unwrap();
        let mut empty = ChannelStats::new(4);
        empty.merge(&shard).unwrap();
        assert_eq!(empty.tokens(), 9);
        assert_eq!(empty.abs_max(), shard.abs_max());
        // and merging an empty shard is a no-op
        let before = shard.channel_magnitudes();
        shard.merge(&ChannelStats::new(4)).unwrap();
        assert_eq!(shard.channel_magnitudes(), before);
    }

    #[test]
    fn shape_mismatches_error() {
        let mut s = ChannelStats::new(4);
        assert!(s.update(&Matrix::zeros(2, 5)).is_err());
        assert!(s.merge(&ChannelStats::new(5)).is_err());
        let mut r = SampleReservoir::new(4, 4);
        assert!(r.observe(&Matrix::zeros(2, 5)).is_err());
    }

    #[test]
    fn reservoir_retains_everything_under_cap() {
        let m = rand_matrix(12, 6, 6);
        let mut r = SampleReservoir::new(0, 6);
        r.observe(&m).unwrap();
        assert_eq!(r.rows(), 12);
        assert_eq!(r.sample().as_slice(), m.as_slice());
        let mut capped = SampleReservoir::new(32, 6);
        capped.observe(&m).unwrap();
        assert_eq!(capped.sample().as_slice(), m.as_slice());
    }

    #[test]
    fn reservoir_is_bounded_and_deterministic_beyond_cap() {
        let m = rand_matrix(40, 3, 7);
        let mut a = SampleReservoir::new(8, 3);
        let mut b = SampleReservoir::new(8, 3);
        a.observe(&m).unwrap();
        b.observe(&m).unwrap();
        assert_eq!(a.rows(), 8);
        assert_eq!(a.seen(), 40);
        assert_eq!(a.sample().as_slice(), b.sample().as_slice());
    }

    #[test]
    fn layer_collector_merge_matches_stream() {
        let a = rand_matrix(10, 8, 8);
        let b = rand_matrix(14, 8, 9);
        let mut whole = LayerCollector::new(8, 0);
        whole.observe(&a).unwrap();
        whole.observe(&b).unwrap();
        let mut sa = LayerCollector::new(8, 0);
        sa.observe(&a).unwrap();
        let mut sb = LayerCollector::new(8, 0);
        sb.observe(&b).unwrap();
        sa.merge(&sb).unwrap();
        assert_eq!(sa.reservoir.sample().as_slice(), whole.reservoir.sample().as_slice());
        for (x, y) in
            sa.stats.channel_magnitudes().iter().zip(whole.stats.channel_magnitudes())
        {
            assert!((x - y).abs() / y.abs().max(1e-9) < 1e-9);
        }
    }
}
