//! proptest-lite: a tiny property-testing harness (no proptest offline).
//!
//! A property is a closure over a [`Gen`] (a seeded RNG wrapper with
//! convenience samplers).  `check(name, cases, prop)` runs it `cases`
//! times with distinct deterministic seeds and reports the failing seed
//! so any counterexample is reproducible with `CHECK_SEED=<n>`.

use crate::rng::Rng;

/// Generator context handed to properties.
pub struct Gen {
    pub rng: Rng,
    /// The case seed (for error messages).
    pub seed: u64,
}

impl Gen {
    /// Uniform usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.f32()
    }

    /// Standard normal f32 vector.
    pub fn normals(&mut self, n: usize) -> Vec<f32> {
        self.rng.normals_f32(n)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// Random matrix of standard normals.
    pub fn matrix(&mut self, rows: usize, cols: usize) -> crate::tensor::Matrix {
        crate::tensor::Matrix::from_vec(rows, cols, self.normals(rows * cols))
    }
}

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Run `prop` for `cases` deterministic seeds; panic with the seed on the
/// first failure.  Set `CHECK_SEED` to re-run a single failing case.
pub fn check(name: &str, cases: u64, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    if let Ok(s) = std::env::var("CHECK_SEED") {
        let seed: u64 = s.parse().expect("CHECK_SEED must be an integer");
        let mut g = Gen { rng: Rng::new(seed), seed };
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed at CHECK_SEED={seed}: {msg}");
        }
        return;
    }
    for case in 0..cases {
        // decorrelate case seeds
        let seed = 0x5EED_0000u64.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen { rng: Rng::new(seed), seed };
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed on case {case} (CHECK_SEED={seed}): {msg}");
        }
    }
}

/// Assert-like helpers for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Relative closeness check with context.
pub fn close(a: f64, b: f64, rtol: f64, what: &str) -> PropResult {
    let denom = a.abs().max(b.abs()).max(1e-12);
    if (a - b).abs() / denom <= rtol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (rtol {rtol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 10, |_g| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "CHECK_SEED=")]
    fn failing_property_reports_seed() {
        check("fails", 5, |g| {
            let v = g.usize_in(0, 10);
            ensure(v > 100, format!("v={v} not > 100"))
        });
    }

    #[test]
    fn generators_in_bounds() {
        check("bounds", 50, |g| {
            let n = g.usize_in(3, 9);
            ensure(n >= 3 && n <= 9, format!("n={n}"))?;
            let f = g.f32_in(-1.0, 1.0);
            ensure((-1.0..1.0).contains(&f), format!("f={f}"))?;
            let m = g.matrix(4, 5);
            ensure(m.shape() == (4, 5), "matrix shape")
        });
    }

    #[test]
    fn close_helper() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, "x").is_ok());
        assert!(close(1.0, 2.0, 1e-6, "x").is_err());
    }
}
