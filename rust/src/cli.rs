//! Dependency-free CLI argument parser (no clap offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with generated help text.  Deliberately small:
//! exactly what the `smoothrot` binary and the examples need.

use std::collections::BTreeMap;

/// One option specification.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// A parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    /// Subcommand this line was parsed for — prefixes value-parse
    /// errors, so `--workers abc` reports *which* command's flag was
    /// malformed when several subcommands share the flag name.
    pub command: &'static str,
    pub values: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Parsed {
    /// `"<command>: "` prefix for error messages (empty when the
    /// command is unknown, e.g. a hand-built `Parsed`).
    fn ctx(&self) -> String {
        if self.command.is_empty() {
            String::new()
        } else {
            format!("{}: ", self.command)
        }
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("{}--{name}: expected integer, got {v:?}", self.ctx())),
        }
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("{}--{name}: expected integer, got {v:?}", self.ctx())),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("{}--{name}: expected number, got {v:?}", self.ctx())),
        }
    }

    /// Comma-separated unsigned integer list (e.g. a `--bits-grid`).
    /// Range validation is the caller's job — this only parses, so the
    /// error names the command, the flag and the offending token.
    pub fn get_u32_list(&self, name: &str) -> Result<Option<Vec<u32>>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim().parse::<u32>().map_err(|_| {
                        format!(
                            "{}--{name}: expected comma-separated integers, got {s:?}",
                            self.ctx()
                        )
                    })
                })
                .collect::<Result<Vec<u32>, String>>()
                .map(Some),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Command definition: name, summary, options.
pub struct Command {
    pub name: &'static str,
    pub summary: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, summary: &'static str) -> Self {
        Self { name, summary, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec { name, help, default, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    /// Parse arguments following the subcommand name.
    pub fn parse(&self, args: &[String]) -> Result<Parsed, String> {
        let mut out = Parsed { command: self.name, ..Parsed::default() };
        // seed defaults
        for o in &self.opts {
            if let Some(d) = o.default {
                out.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key} (see --help)"))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{key} is a flag and takes no value"));
                    }
                    out.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i).cloned().ok_or_else(|| format!("--{key} needs a value"))?
                        }
                    };
                    out.values.insert(key, val);
                }
            } else {
                out.positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// Help text for this command.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.summary);
        for o in &self.opts {
            let kind = if o.is_flag { "" } else { " <value>" };
            let def = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  --{}{}\n      {}{}\n", o.name, kind, o.help, def));
        }
        s
    }
}

/// Top-level application: dispatches subcommands.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl App {
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nusage: {} <command> [options]\n\ncommands:\n", self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&format!("  {:<12} {}\n", c.name, c.summary));
        }
        s.push_str("\nrun `<command> --help` for per-command options\n");
        s
    }

    pub fn find(&self, name: &str) -> Option<&Command> {
        self.commands.iter().find(|c| c.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("analyze", "run the analysis")
            .opt("layers", "layer count", Some("32"))
            .opt("alpha", "migration strength", Some("0.5"))
            .flag("verbose", "print more")
    }

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_applied() {
        let p = cmd().parse(&args(&[])).unwrap();
        assert_eq!(p.get("layers"), Some("32"));
        assert_eq!(p.get_f64("alpha").unwrap(), Some(0.5));
        assert!(!p.has_flag("verbose"));
    }

    #[test]
    fn key_value_styles() {
        let p = cmd().parse(&args(&["--layers", "16", "--alpha=0.7", "--verbose"])).unwrap();
        assert_eq!(p.get_usize("layers").unwrap(), Some(16));
        assert_eq!(p.get_u64("layers").unwrap(), Some(16));
        assert_eq!(p.get_f64("alpha").unwrap(), Some(0.7));
        assert!(p.has_flag("verbose"));
    }

    #[test]
    fn u64_values_parse_and_name_the_subcommand() {
        let p = cmd().parse(&args(&["--layers", "abc"])).unwrap();
        let err = p.get_u64("layers").unwrap_err();
        assert!(err.starts_with("analyze: ") && err.contains("expected integer"), "{err}");
        assert_eq!(cmd().parse(&args(&[])).unwrap().get_u64("missing").unwrap(), None);
    }

    #[test]
    fn positionals_collected() {
        let p = cmd().parse(&args(&["input.bin", "--layers", "8", "out.csv"])).unwrap();
        assert_eq!(p.positionals, vec!["input.bin", "out.csv"]);
    }

    #[test]
    fn errors_are_useful() {
        assert!(cmd().parse(&args(&["--nope"])).is_err());
        assert!(cmd().parse(&args(&["--layers"])).is_err());
        assert!(cmd().parse(&args(&["--verbose=1"])).is_err());
        let p = cmd().parse(&args(&["--layers", "abc"])).unwrap();
        assert!(p.get_usize("layers").is_err());
    }

    #[test]
    fn parse_errors_name_the_subcommand() {
        // the same flag on two subcommands must yield distinguishable
        // error messages
        let analyze = cmd().parse(&args(&["--layers", "abc"])).unwrap();
        let err = analyze.get_usize("layers").unwrap_err();
        assert!(err.starts_with("analyze: "), "{err}");
        assert!(err.contains("--layers") && err.contains("abc"), "{err}");
        let serve = Command::new("serve", "serve things")
            .opt("layers", "layer count", None)
            .parse(&args(&["--layers", "abc"]))
            .unwrap();
        assert!(serve.get_usize("layers").unwrap_err().starts_with("serve: "));
        let ferr = analyze.get_f64("alpha");
        assert!(ferr.is_ok(), "default alpha still parses");
        let bad = cmd().parse(&args(&["--alpha", "xyz"])).unwrap();
        let err = bad.get_f64("alpha").unwrap_err();
        assert!(err.starts_with("analyze: ") && err.contains("expected number"), "{err}");
        // a hand-built Parsed (no command) keeps the bare message
        let mut anon = Parsed::default();
        anon.values.insert("n".into(), "x".into());
        assert!(anon.get_usize("n").unwrap_err().starts_with("--n:"));
    }

    #[test]
    fn u32_lists_parse_and_report_bad_tokens() {
        let grid = Command::new("sweep", "sweep things").opt("grid", "bit grid", Some("2,4,8"));
        let p = grid.parse(&args(&[])).unwrap();
        assert_eq!(p.get_u32_list("grid").unwrap(), Some(vec![2, 4, 8]));
        let p = grid.parse(&args(&["--grid", " 4 , 8 "])).unwrap();
        assert_eq!(p.get_u32_list("grid").unwrap(), Some(vec![4, 8]));
        let p = grid.parse(&args(&["--grid", "4,x,8"])).unwrap();
        let err = p.get_u32_list("grid").unwrap_err();
        assert!(err.starts_with("sweep: ") && err.contains("\"x\""), "{err}");
        assert_eq!(p.get_u32_list("missing").unwrap(), None);
    }

    #[test]
    fn help_mentions_options() {
        let h = cmd().help();
        assert!(h.contains("--layers"));
        assert!(h.contains("default: 32"));
    }
}
