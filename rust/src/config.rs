//! Typed experiment configuration + key=value config-file parser.
//!
//! Mirrors `python/compile/config.py` (the manifest embeds the python
//! dataclass verbatim; [`ExperimentConfig::from_manifest`] reads it back
//! so the rust side always analyzes with the exact parameters the
//! artifacts were built with).  A small `key = value` file format (with
//! `#` comments) allows overriding runtime knobs — worker counts, sweep
//! ranges — without recompiling.

use crate::jsonio::Json;
use std::collections::BTreeMap;

/// The four recorded module kinds in paper order.
pub const MODULES: [&str; 4] = ["k_proj", "o_proj", "gate_proj", "down_proj"];

/// Architecture + quantization parameters (the python side's source of
/// truth, read back from the manifest).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ffn: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub seed: u64,
    pub bits: u32,
    pub alpha: f64,
    pub massive_layers: Vec<usize>,
    pub tail_layer: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        Self {
            n_layers: 32,
            d_model: 256,
            n_heads: 8,
            d_ffn: 704,
            vocab: 512,
            seq_len: 128,
            seed: 1234,
            bits: 4,
            alpha: 0.5,
            massive_layers: vec![1, 30],
            tail_layer: 31,
        }
    }
}

impl ModelConfig {
    /// (c_in, c_out) of the weight fed by the recorded module input.
    pub fn module_shape(&self, module: &str) -> Option<(usize, usize)> {
        let (d, f) = (self.d_model, self.d_ffn);
        match module {
            "k_proj" | "o_proj" => Some((d, d)),
            "gate_proj" => Some((d, f)),
            "down_proj" => Some((f, d)),
            _ => None,
        }
    }

    /// Parse the `config` object embedded in `manifest.json`.
    pub fn from_manifest(manifest: &Json) -> Result<Self, String> {
        let c = manifest.get("config").ok_or("manifest missing 'config'")?;
        let u = |k: &str| -> Result<usize, String> {
            c.get(k).and_then(Json::as_usize).ok_or(format!("config missing {k}"))
        };
        let f = |k: &str| -> Result<f64, String> {
            c.get(k).and_then(Json::as_f64).ok_or(format!("config missing {k}"))
        };
        Ok(Self {
            n_layers: u("n_layers")?,
            d_model: u("d_model")?,
            n_heads: u("n_heads")?,
            d_ffn: u("d_ffn")?,
            vocab: u("vocab")?,
            seq_len: u("seq_len")?,
            seed: u("seed")? as u64,
            bits: u("bits")? as u32,
            alpha: f("alpha")?,
            massive_layers: c
                .get("massive_layers")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            tail_layer: u("tail_layer")?,
        })
    }
}

/// Runtime knobs for the coordinator and sweeps.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    /// Worker threads in the coordinator pool.
    pub workers: usize,
    /// Bounded job-queue capacity (backpressure threshold).
    pub queue_cap: usize,
    /// Directory holding the AOT artifacts.
    pub artifacts_dir: String,
    /// Output directory for reports.
    pub report_dir: String,
    /// Alpha sweep grid for the Sec. IV-C experiment.
    pub alpha_grid: Vec<f64>,
    /// Bit-width sweep for the extension experiment.
    pub bits_grid: Vec<u32>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_cap: 64,
            artifacts_dir: "artifacts".into(),
            report_dir: "reports".into(),
            alpha_grid: vec![0.3, 0.4, 0.5, 0.6, 0.65, 0.7, 0.8, 0.9],
            bits_grid: vec![2, 3, 4, 6, 8],
        }
    }
}

impl RunConfig {
    /// Parse `key = value` lines (# comments, blank lines ok).
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut cfg = Self::default();
        let map = parse_kv(text)?;
        for (k, v) in &map {
            match k.as_str() {
                "workers" => cfg.workers = parse_num(k, v)?,
                "queue_cap" => cfg.queue_cap = parse_num(k, v)?,
                "artifacts_dir" => cfg.artifacts_dir = v.clone(),
                "report_dir" => cfg.report_dir = v.clone(),
                "alpha_grid" => {
                    cfg.alpha_grid = v
                        .split(',')
                        .map(|s| s.trim().parse::<f64>().map_err(|_| format!("bad alpha {s:?}")))
                        .collect::<Result<_, _>>()?
                }
                "bits_grid" => {
                    cfg.bits_grid = v
                        .split(',')
                        .map(|s| s.trim().parse::<u32>().map_err(|_| format!("bad bits {s:?}")))
                        .collect::<Result<_, _>>()?
                }
                _ => return Err(format!("unknown config key {k:?}")),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.workers == 0 {
            return Err("workers must be >= 1".into());
        }
        if self.queue_cap == 0 {
            return Err("queue_cap must be >= 1".into());
        }
        if self.alpha_grid.iter().any(|&a| !(0.0..=1.0).contains(&a)) {
            return Err("alpha_grid entries must be in [0, 1]".into());
        }
        if self.bits_grid.iter().any(|&b| !(2..=16).contains(&b)) {
            return Err("bits_grid entries must be in [2, 16]".into());
        }
        Ok(())
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Self::parse(&text)
    }
}

fn parse_kv(text: &str) -> Result<BTreeMap<String, String>, String> {
    let mut map = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or(format!("line {}: expected key = value, got {raw:?}", lineno + 1))?;
        map.insert(k.trim().to_string(), v.trim().to_string());
    }
    Ok(map)
}

fn parse_num<T: std::str::FromStr>(k: &str, v: &str) -> Result<T, String> {
    v.parse().map_err(|_| format!("{k}: expected number, got {v:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonio;

    #[test]
    fn model_config_module_shapes() {
        let c = ModelConfig::default();
        assert_eq!(c.module_shape("k_proj"), Some((256, 256)));
        assert_eq!(c.module_shape("gate_proj"), Some((256, 704)));
        assert_eq!(c.module_shape("down_proj"), Some((704, 256)));
        assert_eq!(c.module_shape("nope"), None);
    }

    #[test]
    fn model_config_from_manifest_json() {
        let manifest = jsonio::parse(
            r#"{"config": {"n_layers": 4, "d_model": 64, "n_heads": 4, "d_ffn": 176,
                "vocab": 64, "seq_len": 32, "seed": 7, "bits": 4, "alpha": 0.5,
                "massive_layers": [1, 2], "tail_layer": 3}}"#,
        )
        .unwrap();
        let c = ModelConfig::from_manifest(&manifest).unwrap();
        assert_eq!(c.n_layers, 4);
        assert_eq!(c.massive_layers, vec![1, 2]);
        assert_eq!(c.alpha, 0.5);
    }

    #[test]
    fn from_manifest_missing_field() {
        let manifest = jsonio::parse(r#"{"config": {"n_layers": 4}}"#).unwrap();
        assert!(ModelConfig::from_manifest(&manifest).is_err());
    }

    #[test]
    fn run_config_parse_and_defaults() {
        let cfg = RunConfig::parse(
            "# comment\nworkers = 4\nalpha_grid = 0.3, 0.5, 0.7\nartifacts_dir = /tmp/a\n",
        )
        .unwrap();
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.alpha_grid, vec![0.3, 0.5, 0.7]);
        assert_eq!(cfg.artifacts_dir, "/tmp/a");
        assert_eq!(cfg.queue_cap, RunConfig::default().queue_cap);
    }

    #[test]
    fn run_config_rejects_bad_values() {
        assert!(RunConfig::parse("workers = 0").is_err());
        assert!(RunConfig::parse("alpha_grid = 1.5").is_err());
        assert!(RunConfig::parse("bits_grid = 1").is_err());
        assert!(RunConfig::parse("nonsense = 1").is_err());
        assert!(RunConfig::parse("no equals sign").is_err());
    }
}
