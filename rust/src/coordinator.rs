//! Experiment coordinator — the L3 orchestration layer.
//!
//! The paper's evaluation is a sweep over (layer × module × transform).
//! This module turns that into a streaming pipeline:
//!
//! ```text
//!   producer (slices X from the capture stacks, W from the weight
//!   stacks)  --bounded queue (backpressure)-->  worker pool  -->
//!   result channel --> aggregator (ExperimentGrid)
//! ```
//!
//! Workers are generic over an [`Executor`].  Two implementations exist:
//!
//! * [`NativeExecutor`] — the pure-rust mirror (Send; any worker count),
//! * `PjrtExecutor` (constructed inside a worker thread via the factory,
//!   see [`run_jobs`]) — the AOT/PJRT hot path.  PJRT handles are not
//!   `Send`, so the factory pattern builds one runtime per worker thread
//!   and the executables are compiled once per worker.
//!
//! Invariants (enforced by the property tests in `tests/`):
//! every submitted job completes exactly once; results are keyed
//! correctly regardless of worker count or queue capacity; the bounded
//! queue never holds more than `queue_cap` jobs.
//!
//! This module runs *one fixed sweep*.  For the continuous,
//! multi-tenant request path (admission control, batch coalescing,
//! fair-share scheduling, latency percentiles) see [`crate::serve`],
//! which builds on the same [`Job`] / [`Executor`] vocabulary.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::kernels::workspace::Workspace;
use crate::metrics::{self, CacheStats, Channels};
use crate::quant;
use crate::runtime::AnalyzeOut;
use crate::tensor::{Matrix, Stack};
use crate::transforms::{self, Mode};

/// One unit of work: analyze a (layer, module) tensor pair.
#[derive(Clone, Debug)]
pub struct Job {
    pub id: u64,
    pub layer: usize,
    pub module: &'static str,
    pub x: Matrix,
    pub w: Matrix,
    /// Migration strength for smoothing modes.
    pub alpha: f32,
    /// Quantization bit width.
    pub bits: u32,
}

/// Completed job with provenance + timing.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: u64,
    pub layer: usize,
    pub module: &'static str,
    pub out: AnalyzeOut,
    pub worker: usize,
    pub micros: u64,
}

/// Anything that can process a job into per-mode stats.
pub trait Executor {
    fn run(&mut self, job: &Job) -> Result<AnalyzeOut, String>;

    /// Rotation-cache hit/miss counters, when the executor keeps a
    /// persistent per-width cache (the serving summary aggregates
    /// these across workers).  `None` for cache-less executors.
    fn rotation_stats(&self) -> Option<CacheStats> {
        None
    }
}

/// Pure-rust analysis executor (mirror of the `analyze_*` artifacts).
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeExecutor;

impl NativeExecutor {
    /// Analyze one (X, W) pair across all four transform modes — a
    /// thin wrapper over the fused kernel engine
    /// ([`crate::kernels::fused::analyze_all_modes`]) with a one-shot
    /// rotation cache and workspace.
    pub fn analyze(x: &Matrix, w: &Matrix, bits: u32, alpha: f32) -> Result<AnalyzeOut, String> {
        let mut cache = transforms::RotationCache::new();
        Self::analyze_cached(x, w, bits, alpha, &mut cache)
    }

    /// [`Self::analyze`] with rotation reuse — the serving hot path
    /// ([`crate::serve::NativeBatchExecutor`]) shares one cache across
    /// every job, so each rotation is built once per width.
    pub fn analyze_cached(
        x: &Matrix,
        w: &Matrix,
        bits: u32,
        alpha: f32,
        cache: &mut transforms::RotationCache,
    ) -> Result<AnalyzeOut, String> {
        let mut ws = Workspace::new();
        crate::kernels::fused::analyze_all_modes(x, w, bits, alpha, cache, &mut ws, 1)
    }

    /// The pre-refactor reference path: evaluate every mode
    /// independently with fully re-materialized intermediates and a
    /// dense `X @ H` rotation matmul (built once per call, as the old
    /// per-call rotation cache did).  Kept as the baseline the
    /// property tests pin [`crate::kernels::fused::analyze_all_modes`]
    /// against (1e-4 relative) and as the perf-bench comparison point.
    pub fn analyze_naive(x: &Matrix, w: &Matrix, bits: u32, alpha: f32) -> Result<AnalyzeOut, String> {
        let r = transforms::rotation(x.cols())?;
        let mut out = AnalyzeOut::default();
        for mode in Mode::ALL {
            let (xh, wh) = match mode {
                Mode::None => (x.clone(), w.clone()),
                Mode::Smooth => {
                    let s = transforms::smooth_scales(x, w, alpha);
                    transforms::smooth_apply(x, w, &s)
                }
                Mode::Rotate => (x.matmul(&r), r.transpose().matmul(w)),
                Mode::SmoothRotate => {
                    let s = transforms::smooth_scales(x, w, alpha);
                    let (xs, ws) = transforms::smooth_apply(x, w, &s);
                    (xs.matmul(&r), r.transpose().matmul(&ws))
                }
            };
            let i = mode.index();
            out.errors[i] = quant::quant_error_fused(&xh, &wh, bits);
            out.act_difficulty[i] = metrics::quant_difficulty(&xh, Channels::Columns);
            out.w_difficulty[i] = metrics::quant_difficulty(&wh, Channels::Rows);
            out.act_absmax[i] = xh.abs_max() as f64;
        }
        Ok(out)
    }
}

impl Executor for NativeExecutor {
    fn run(&mut self, job: &Job) -> Result<AnalyzeOut, String> {
        Self::analyze(&job.x, &job.w, job.bits, job.alpha)
    }
}

/// Coordinator runtime metrics.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub jobs: usize,
    pub errors: usize,
    pub wall_micros: u64,
    pub exec_micros_total: u64,
    pub per_worker_jobs: Vec<usize>,
    /// Highest number of jobs simultaneously queued (backpressure probe).
    pub max_queue_depth: usize,
}

impl RunMetrics {
    /// Fraction of wall time NOT spent inside executors — the
    /// coordination overhead the perf pass drives toward zero.
    pub fn overhead_fraction(&self, workers: usize) -> f64 {
        if self.wall_micros == 0 {
            return 0.0;
        }
        let busy = self.exec_micros_total as f64 / workers.max(1) as f64;
        (1.0 - busy / self.wall_micros as f64).max(0.0)
    }
}

/// Pool configuration.
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    pub workers: usize,
    pub queue_cap: usize,
    /// Math threads inside each executor's kernels (`0` = all cores);
    /// consumed by the native backend's fused analyze engine.  This
    /// multiplies with `workers` — keep `workers * threads` at or
    /// below the core count to avoid oversubscription (the default
    /// splits `std::thread::available_parallelism()` across the
    /// workers for exactly that reason; the CLI defaults to 1).
    pub threads: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        let workers = 2;
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self { workers, queue_cap: 64, threads: (cores / workers).max(1) }
    }
}

/// Run `jobs` through a worker pool; `make_executor(worker_idx)` is
/// invoked *inside* each worker thread, so non-Send executors (PJRT)
/// work with `workers == 1..n`, each owning its own runtime.
///
/// ```
/// use smoothrot::coordinator::{run_jobs, Job, NativeExecutor, PoolConfig};
/// use smoothrot::tensor::Matrix;
///
/// let jobs = vec![Job {
///     id: 0,
///     layer: 0,
///     module: "k_proj",
///     x: Matrix::zeros(4, 8),
///     w: Matrix::zeros(8, 4),
///     alpha: 0.5,
///     bits: 4,
/// }];
/// let (results, metrics) =
///     run_jobs(jobs, PoolConfig::default(), |_| Ok(NativeExecutor)).unwrap();
/// assert_eq!(results.len(), 1);
/// assert_eq!(metrics.jobs, 1);
/// ```
pub fn run_jobs<E, F>(
    jobs: Vec<Job>,
    cfg: PoolConfig,
    make_executor: F,
) -> Result<(Vec<JobResult>, RunMetrics), String>
where
    E: Executor,
    F: Fn(usize) -> Result<E, String> + Send + Sync + 'static,
{
    assert!(cfg.workers >= 1, "need at least one worker");
    let n_jobs = jobs.len();
    let start = Instant::now();

    let (job_tx, job_rx) = mpsc::sync_channel::<Job>(cfg.queue_cap);
    let job_rx = Arc::new(Mutex::new(job_rx));
    let (res_tx, res_rx) = mpsc::channel::<Result<JobResult, String>>();
    let make_executor = Arc::new(make_executor);

    let depth = Arc::new(AtomicUsize::new(0));
    let max_depth = Arc::new(AtomicUsize::new(0));

    let mut handles = Vec::with_capacity(cfg.workers);
    for widx in 0..cfg.workers {
        let rx = Arc::clone(&job_rx);
        let tx = res_tx.clone();
        let mk = Arc::clone(&make_executor);
        let depth = Arc::clone(&depth);
        handles.push(std::thread::spawn(move || {
            // On init failure the worker must keep DRAINING the queue
            // (reporting an error per job) — exiting immediately would
            // leave the producer blocked on the bounded queue forever.
            let mut exec = match mk(widx) {
                Ok(e) => Some(e),
                Err(msg) => {
                    let _ = tx.send(Err(format!("worker {widx}: executor init failed: {msg}")));
                    None
                }
            };
            loop {
                let job = {
                    // recover a poisoned lock: a peer that panicked
                    // while holding it was only *receiving* (the queue
                    // itself cannot be left half-mutated), so the
                    // remaining workers keep draining instead of
                    // wedging the producer forever
                    let guard = match rx.lock() {
                        Ok(g) => g,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                    guard.recv()
                };
                let job = match job {
                    Ok(j) => j,
                    Err(_) => break, // producer closed, queue drained
                };
                depth.fetch_sub(1, Ordering::SeqCst);
                let t0 = Instant::now();
                let outcome = match exec.as_mut() {
                    Some(e) => e.run(&job).map(|out| JobResult {
                        id: job.id,
                        layer: job.layer,
                        module: job.module,
                        out,
                        worker: widx,
                        micros: t0.elapsed().as_micros() as u64,
                    }),
                    None => Err(format!("worker {widx}: job {} dropped (executor init failed)", job.id)),
                };
                if tx.send(outcome).is_err() {
                    break;
                }
            }
        }));
    }
    drop(res_tx);

    // Producer: feed jobs with backpressure (sync_channel blocks at cap).
    let producer_depth = Arc::clone(&depth);
    let producer_max = Arc::clone(&max_depth);
    let producer = std::thread::spawn(move || {
        for job in jobs {
            let d = producer_depth.fetch_add(1, Ordering::SeqCst) + 1;
            producer_max.fetch_max(d, Ordering::SeqCst);
            if job_tx.send(job).is_err() {
                break;
            }
        }
        // dropping job_tx closes the queue
    });

    let mut results = Vec::with_capacity(n_jobs);
    let mut metrics = RunMetrics { per_worker_jobs: vec![0; cfg.workers], ..Default::default() };
    let mut first_error: Option<String> = None;
    for outcome in res_rx.iter() {
        match outcome {
            Ok(r) => {
                metrics.jobs += 1;
                metrics.exec_micros_total += r.micros;
                metrics.per_worker_jobs[r.worker] += 1;
                results.push(r);
            }
            Err(msg) => {
                metrics.errors += 1;
                if first_error.is_none() {
                    first_error = Some(msg);
                }
            }
        }
    }
    producer.join().map_err(|_| "producer thread panicked".to_string())?;
    for h in handles {
        h.join().map_err(|_| "worker thread panicked".to_string())?;
    }
    metrics.wall_micros = start.elapsed().as_micros() as u64;
    metrics.max_queue_depth = max_depth.load(Ordering::SeqCst);

    if let Some(msg) = first_error {
        return Err(format!("{} job(s) failed; first error: {msg}", metrics.errors));
    }
    if results.len() != n_jobs {
        return Err(format!("lost results: {} of {n_jobs} completed", results.len()));
    }
    results.sort_by_key(|r| r.id);
    Ok((results, metrics))
}

/// Aggregated experiment output: `[module][layer] -> AnalyzeOut`.
#[derive(Clone, Debug, Default)]
pub struct ExperimentGrid {
    pub cells: BTreeMap<&'static str, Vec<Option<AnalyzeOut>>>,
    pub n_layers: usize,
}

impl ExperimentGrid {
    pub fn new(n_layers: usize) -> Self {
        let mut cells = BTreeMap::new();
        for m in crate::MODULES {
            cells.insert(m, vec![None; n_layers]);
        }
        Self { cells, n_layers }
    }

    pub fn insert(&mut self, r: &JobResult) {
        if let Some(row) = self.cells.get_mut(r.module) {
            row[r.layer] = Some(r.out);
        }
    }

    pub fn from_results(n_layers: usize, results: &[JobResult]) -> Self {
        let mut g = Self::new(n_layers);
        for r in results {
            g.insert(r);
        }
        g
    }

    pub fn get(&self, module: &str, layer: usize) -> Option<&AnalyzeOut> {
        self.cells.get(module)?.get(layer)?.as_ref()
    }

    /// Per-mode Eq. 2 errors of one cell, when analyzed — the input
    /// shape [`crate::calib::search::choose_mode`] and
    /// [`crate::policy::recommend`] decide on.
    pub fn cell_errors(&self, module: &str, layer: usize) -> Option<[f64; 4]> {
        self.get(module, layer).map(|o| o.errors)
    }

    /// Series of one statistic across layers for a module.
    pub fn series(&self, module: &str, f: impl Fn(&AnalyzeOut) -> f64) -> Vec<f64> {
        self.cells
            .get(module)
            .map(|row| row.iter().map(|c| c.as_ref().map(&f).unwrap_or(f64::NAN)).collect())
            .unwrap_or_default()
    }

    /// The paper's §IV-B correlation: Pearson(error, act_difficulty²) for
    /// mode `none`, excluding the massive/tail outlier cells.
    pub fn headline_correlation(&self, exclude: &[(&str, usize)]) -> f64 {
        let mut errs = Vec::new();
        let mut diffs_sq = Vec::new();
        for (&module, row) in &self.cells {
            for (layer, cell) in row.iter().enumerate() {
                if exclude.iter().any(|&(m, l)| m == module && l == layer) {
                    continue;
                }
                if let Some(out) = cell {
                    errs.push(out.errors[0]);
                    diffs_sq.push(out.act_difficulty[0] * out.act_difficulty[0]);
                }
            }
        }
        metrics::pearson(&errs, &diffs_sq)
    }
}

/// Build the standard (layer × module) job list from capture stacks and
/// weight stacks.
pub fn build_jobs(
    stacks: &BTreeMap<&'static str, &Stack>,
    weights: &BTreeMap<&'static str, &Stack>,
    alpha: f32,
    bits: u32,
) -> Vec<Job> {
    let mut jobs = Vec::new();
    let mut id = 0u64;
    for module in crate::MODULES {
        let xs = stacks[module];
        let ws = weights[module];
        assert_eq!(xs.layers(), ws.layers(), "{module}: stack layer mismatch");
        for layer in 0..xs.layers() {
            jobs.push(Job { id, layer, module, x: xs.layer(layer), w: ws.layer(layer), alpha, bits });
            id += 1;
        }
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn small_jobs(n: usize, seed: u64) -> Vec<Job> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| Job {
                id: i as u64,
                layer: i % 4,
                module: crate::MODULES[i % 4],
                x: Matrix::from_vec(8, 16, rng.normals_f32(8 * 16)),
                w: Matrix::from_vec(16, 8, rng.normals_f32(16 * 8)),
                alpha: 0.5,
                bits: 4,
            })
            .collect()
    }

    #[test]
    fn all_jobs_complete_exactly_once() {
        let jobs = small_jobs(20, 1);
        let (results, m) =
            run_jobs(jobs, PoolConfig { workers: 3, queue_cap: 4, threads: 1 }, |_| Ok(NativeExecutor)).unwrap();
        assert_eq!(results.len(), 20);
        assert_eq!(m.jobs, 20);
        let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 20);
    }

    #[test]
    fn queue_depth_bounded() {
        struct SlowExec;
        impl Executor for SlowExec {
            fn run(&mut self, _job: &Job) -> Result<AnalyzeOut, String> {
                std::thread::sleep(std::time::Duration::from_millis(1));
                Ok(AnalyzeOut::default())
            }
        }
        let jobs = small_jobs(40, 2);
        let cap = 4;
        let (_, m) = run_jobs(jobs, PoolConfig { workers: 2, queue_cap: cap, threads: 1 }, |_| Ok(SlowExec)).unwrap();
        // queue cap + jobs momentarily held by the two workers
        assert!(m.max_queue_depth <= cap + 2 + 1, "depth {} exceeds bound", m.max_queue_depth);
    }

    #[test]
    fn executor_errors_surface() {
        struct FailExec;
        impl Executor for FailExec {
            fn run(&mut self, job: &Job) -> Result<AnalyzeOut, String> {
                if job.id == 3 {
                    Err("boom".into())
                } else {
                    Ok(AnalyzeOut::default())
                }
            }
        }
        let err = run_jobs(small_jobs(8, 3), PoolConfig::default(), |_| Ok(FailExec)).unwrap_err();
        assert!(err.contains("boom"), "{err}");
    }

    #[test]
    fn executor_init_failure_surfaces() {
        let err = run_jobs(small_jobs(4, 4), PoolConfig { workers: 1, queue_cap: 2, threads: 1 }, |_| {
            Err::<NativeExecutor, _>("no artifacts".to_string())
        })
        .unwrap_err();
        assert!(err.contains("no artifacts"), "{err}");
    }

    #[test]
    fn native_executor_produces_ordered_modes() {
        // rotation must beat none on a systematic-outlier matrix
        let mut rng = Rng::new(5);
        let mut x = Matrix::from_vec(32, 64, rng.normals_f32(32 * 64));
        for i in 0..32 {
            x.row_mut(i)[7] *= 40.0;
        }
        let w = Matrix::from_vec(64, 16, rng.normals_f32(64 * 16));
        let out = NativeExecutor::analyze(&x, &w, 4, 0.5).unwrap();
        assert!(out.errors[2] < out.errors[0], "rotate {} vs none {}", out.errors[2], out.errors[0]);
        assert!(out.act_difficulty[1] < out.act_difficulty[0]);
    }

    #[test]
    fn grid_series_and_correlation() {
        let jobs = small_jobs(16, 6);
        let (results, _) = run_jobs(jobs, PoolConfig::default(), |_| Ok(NativeExecutor)).unwrap();
        let grid = ExperimentGrid::from_results(4, &results);
        let s = grid.series("k_proj", |o| o.errors[0]);
        assert_eq!(s.len(), 4);
        let corr = grid.headline_correlation(&[]);
        assert!(corr.is_finite());
    }

    #[test]
    fn single_worker_deterministic_order() {
        let jobs = small_jobs(10, 7);
        let (r1, _) = run_jobs(jobs.clone(), PoolConfig { workers: 1, queue_cap: 2, threads: 1 }, |_| Ok(NativeExecutor)).unwrap();
        let (r2, _) = run_jobs(jobs, PoolConfig { workers: 1, queue_cap: 2, threads: 1 }, |_| Ok(NativeExecutor)).unwrap();
        for (a, b) in r1.iter().zip(&r2) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.out.errors, b.out.errors);
        }
    }
}
