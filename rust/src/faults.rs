//! Deterministic fault injection: named failpoints for chaos testing.
//!
//! The serving stack's fault-tolerance claims (panic isolation, torn-plan
//! rejection, deadline eviction) are only testable if faults can be made
//! to happen *on demand and reproducibly*.  This module provides named
//! failpoints — call sites like `serve.exec_panic` or
//! `plan.reload_corrupt` ask [`fire`] / [`fire_key`] whether to misbehave
//! — armed from a spec string via [`arm`], the `SMOOTHROT_FAULTS`
//! environment variable ([`arm_from_env`]), or the `--faults` CLI knob.
//!
//! ## Spec grammar
//!
//! `site=trigger[,site=trigger...]` (`,` or `;` separate entries):
//!
//! | trigger | fires |
//! |---|---|
//! | `always` | every evaluation |
//! | `once` | first evaluation only |
//! | `hit:N` | the Nth evaluation only (1-based) |
//! | `every:N` | every Nth evaluation |
//! | `prob:P:SEED` | deterministically pseudo-random with probability `P`: hashes `SEED` with the caller key (or the hit counter when unkeyed), so the same seed always yields the same fault schedule |
//! | `mod:K:R` | caller key `% K == R` (hit counter when unkeyed) — a stable "poisoned subset" of jobs |
//!
//! ## Wire-level sites
//!
//! The network front-end ([`crate::serve::net`]) adds four failpoints
//! that fire in *connection* threads — never in workers, which is the
//! isolation the chaos suite asserts (a wire fault must not quarantine
//! the faulted request's batchmates):
//!
//! | site | effect |
//! |---|---|
//! | `net.accept_fail` | an accepted connection is dropped before handling (unkeyed) |
//! | `net.conn_drop` | connection torn down after submit, before any response byte (keyed by wire request id) |
//! | `net.slow_client` | connection thread stalls before reading, like a byte-trickling client (keyed) |
//! | `net.partial_write` | half the first result line's bytes, then teardown (keyed) |
//!
//! Keyed sites take the wire request counter, so `mod:K:R` poisons a
//! stable, schedule-independent subset of requests.
//!
//! ## Cost when unarmed
//!
//! A single relaxed atomic load: [`fire`] checks a global `ARMED` flag
//! before touching any state, so production serving with no faults armed
//! pays one predictable branch per failpoint.
//!
//! Arming is process-global, so tests that arm faults must serialize via
//! [`exclusive`] and disarm before releasing the guard.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Fast-path flag: true iff a fault plan is installed.
static ARMED: AtomicBool = AtomicBool::new(false);

/// The installed plan (None when disarmed).  `fire` clones the `Arc`
/// and drops the lock before evaluating, so failpoint evaluation never
/// holds this mutex across trigger logic.
static PLAN: Mutex<Option<Arc<FaultPlan>>> = Mutex::new(None);

/// Serializes tests that arm global fault state.
static EXCLUSIVE: Mutex<()> = Mutex::new(());

/// How a single failpoint decides to fire.
#[derive(Clone, Debug, PartialEq)]
enum Trigger {
    Always,
    /// Fires on the Nth evaluation only (1-based; `once` == `Hit(1)`).
    Hit(u64),
    /// Fires on every Nth evaluation.
    Every(u64),
    /// Fires with probability `p`, deterministically keyed on
    /// `hash(seed, key-or-hit)`.
    Prob(f64, u64),
    /// Fires when `key % k == r` (hit counter when unkeyed).
    Mod(u64, u64),
}

#[derive(Debug)]
struct FaultSite {
    trigger: Trigger,
    hits: AtomicU64,
}

/// A parsed, armed set of failpoints.
#[derive(Debug, Default)]
struct FaultPlan {
    sites: BTreeMap<String, FaultSite>,
}

/// SplitMix64 finalizer — decorrelates seed/key pairs for `prob`.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

fn parse_trigger(s: &str) -> Result<Trigger, String> {
    let parts: Vec<&str> = s.split(':').collect();
    let nat = |tok: &str| -> Result<u64, String> {
        tok.parse::<u64>().map_err(|_| format!("faults: expected integer, got {tok:?} in {s:?}"))
    };
    match parts.as_slice() {
        ["always"] => Ok(Trigger::Always),
        ["once"] => Ok(Trigger::Hit(1)),
        ["hit", n] => {
            let n = nat(n)?;
            if n == 0 {
                return Err(format!("faults: hit:N is 1-based, got 0 in {s:?}"));
            }
            Ok(Trigger::Hit(n))
        }
        ["every", n] => {
            let n = nat(n)?;
            if n == 0 {
                return Err(format!("faults: every:N needs N >= 1 in {s:?}"));
            }
            Ok(Trigger::Every(n))
        }
        ["prob", p, seed] => {
            let p: f64 = p
                .parse()
                .map_err(|_| format!("faults: expected probability, got {p:?} in {s:?}"))?;
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("faults: probability out of [0,1] in {s:?}"));
            }
            Ok(Trigger::Prob(p, nat(seed)?))
        }
        ["mod", k, r] => {
            let k = nat(k)?;
            let r = nat(r)?;
            if k == 0 || r >= k {
                return Err(format!("faults: mod:K:R needs K >= 1 and R < K in {s:?}"));
            }
            Ok(Trigger::Mod(k, r))
        }
        _ => Err(format!(
            "faults: unknown trigger {s:?} (expected always | once | hit:N | every:N | prob:P:SEED | mod:K:R)"
        )),
    }
}

fn parse_spec(spec: &str) -> Result<FaultPlan, String> {
    let mut plan = FaultPlan::default();
    for entry in spec.split([',', ';']) {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (site, trig) = entry
            .split_once('=')
            .ok_or_else(|| format!("faults: expected site=trigger, got {entry:?}"))?;
        let site = site.trim();
        if site.is_empty() {
            return Err(format!("faults: empty site name in {entry:?}"));
        }
        let trigger = parse_trigger(trig.trim())?;
        plan.sites
            .insert(site.to_string(), FaultSite { trigger, hits: AtomicU64::new(0) });
    }
    Ok(plan)
}

fn plan_lock() -> MutexGuard<'static, Option<Arc<FaultPlan>>> {
    match PLAN.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Arm a fault plan from a spec string, replacing any previous plan.
/// Returns the number of failpoints armed (0 for an empty spec, which
/// disarms).
pub fn arm(spec: &str) -> Result<usize, String> {
    let plan = parse_spec(spec)?;
    let n = plan.sites.len();
    let mut guard = plan_lock();
    if n == 0 {
        *guard = None;
        ARMED.store(false, Ordering::Release);
    } else {
        *guard = Some(Arc::new(plan));
        ARMED.store(true, Ordering::Release);
    }
    Ok(n)
}

/// Arm from the `SMOOTHROT_FAULTS` environment variable.  Unset or
/// empty means no faults; a malformed spec is an error (silent typos in
/// a chaos run would fake a green result).
pub fn arm_from_env() -> Result<usize, String> {
    match std::env::var("SMOOTHROT_FAULTS") {
        Ok(spec) if !spec.trim().is_empty() => arm(&spec),
        _ => Ok(0),
    }
}

/// Remove the fault plan; all failpoints revert to the no-op branch.
pub fn disarm() {
    let mut guard = plan_lock();
    *guard = None;
    ARMED.store(false, Ordering::Release);
}

/// True iff any fault plan is armed (single relaxed atomic load).
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

fn evaluate(site: &FaultSite, key: Option<u64>) -> bool {
    let hit = site.hits.fetch_add(1, Ordering::Relaxed) + 1;
    match site.trigger {
        Trigger::Always => true,
        Trigger::Hit(n) => hit == n,
        Trigger::Every(n) => hit % n == 0,
        Trigger::Prob(p, seed) => {
            let x = mix(seed ^ mix(key.unwrap_or(hit)));
            // top 53 bits -> uniform in [0, 1)
            let u = (x >> 11) as f64 / (1u64 << 53) as f64;
            u < p
        }
        Trigger::Mod(k, r) => key.unwrap_or(hit) % k == r,
    }
}

fn fire_impl(site: &str, key: Option<u64>) -> bool {
    if !armed() {
        return false;
    }
    let plan = match plan_lock().as_ref() {
        Some(p) => Arc::clone(p),
        None => return false,
    };
    match plan.sites.get(site) {
        Some(s) => evaluate(s, key),
        None => false,
    }
}

/// Should the named failpoint fire?  No-op (false) when unarmed.
#[inline]
pub fn fire(site: &str) -> bool {
    if !armed() {
        return false;
    }
    fire_impl(site, None)
}

/// Keyed variant: `mod` / `prob` triggers evaluate against `key`
/// (e.g. a job id), yielding a deterministic poisoned subset that is
/// stable across retries and across runs.
#[inline]
pub fn fire_key(site: &str, key: u64) -> bool {
    if !armed() {
        return false;
    }
    fire_impl(site, Some(key))
}

/// How many times the named failpoint has been evaluated since arming
/// (0 when unarmed or never hit) — observability for chaos tests.
pub fn hits(site: &str) -> u64 {
    let guard = plan_lock();
    match guard.as_ref().and_then(|p| p.sites.get(site)) {
        Some(s) => s.hits.load(Ordering::Relaxed),
        None => 0,
    }
}

/// Serialize tests (and any other callers) that arm process-global
/// fault state.  Hold the guard for the whole armed region and
/// [`disarm`] before dropping it.
pub fn exclusive() -> MutexGuard<'static, ()> {
    match EXCLUSIVE.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_failpoints_never_fire() {
        let _g = exclusive();
        disarm();
        assert!(!armed());
        assert!(!fire("serve.exec_panic"));
        assert!(!fire_key("serve.exec_panic", 7));
        assert_eq!(hits("serve.exec_panic"), 0);
    }

    #[test]
    fn hit_and_every_triggers_count_evaluations() {
        let _g = exclusive();
        arm("a=hit:3,b=every:2").unwrap();
        let a: Vec<bool> = (0..5).map(|_| fire("a")).collect();
        assert_eq!(a, vec![false, false, true, false, false]);
        let b: Vec<bool> = (0..6).map(|_| fire("b")).collect();
        assert_eq!(b, vec![false, true, false, true, false, true]);
        assert_eq!(hits("a"), 5);
        disarm();
        assert!(!fire("a"));
    }

    #[test]
    fn once_fires_exactly_once() {
        let _g = exclusive();
        arm("x=once").unwrap();
        assert!(fire("x"));
        assert!(!fire("x"));
        assert!(!fire("x"));
        disarm();
    }

    #[test]
    fn mod_trigger_selects_a_stable_key_subset() {
        let _g = exclusive();
        arm("p=mod:4:1").unwrap();
        // retries of the same key give the same answer: no hidden state
        for _ in 0..3 {
            assert!(fire_key("p", 1));
            assert!(fire_key("p", 5));
            assert!(!fire_key("p", 0));
            assert!(!fire_key("p", 7));
        }
        disarm();
    }

    #[test]
    fn prob_trigger_is_deterministic_per_key_and_seed() {
        let _g = exclusive();
        arm("q=prob:0.5:42").unwrap();
        let first: Vec<bool> = (0..64).map(|k| fire_key("q", k)).collect();
        let second: Vec<bool> = (0..64).map(|k| fire_key("q", k)).collect();
        assert_eq!(first, second, "same seed + key must give the same schedule");
        let fired = first.iter().filter(|&&f| f).count();
        assert!(fired > 8 && fired < 56, "p=0.5 over 64 keys fired {fired} times");
        // a different seed gives a different schedule
        arm("q=prob:0.5:43").unwrap();
        let third: Vec<bool> = (0..64).map(|k| fire_key("q", k)).collect();
        assert_ne!(first, third);
        disarm();
    }

    #[test]
    fn unknown_sites_do_not_fire_and_specs_validate() {
        let _g = exclusive();
        arm("known=always").unwrap();
        assert!(!fire("unknown"));
        assert!(fire("known"));
        disarm();
        assert!(arm("bad").is_err());
        assert!(arm("s=banana").is_err());
        assert!(arm("s=hit:0").is_err());
        assert!(arm("s=prob:1.5:1").is_err());
        assert!(arm("s=mod:0:0").is_err());
        assert!(arm("s=mod:4:4").is_err());
        assert!(!armed(), "failed arm must not leave a plan installed");
        assert_eq!(arm("").unwrap(), 0);
        assert!(!armed());
    }

    #[test]
    fn spec_allows_both_separators_and_whitespace() {
        let _g = exclusive();
        let n = arm(" a=always ; b=every:3 , c=mod:2:0 ").unwrap();
        assert_eq!(n, 3);
        assert!(fire("a"));
        disarm();
    }
}
