//! Minimal JSON substrate (the offline registry has no serde).
//!
//! A small value model plus a recursive-descent parser and a writer —
//! enough for the artifact manifest, golden files, and report output.
//! Numbers parse to f64; object key order is preserved for stable
//! report diffs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    /// Non-negative integral number as u64 (`None` for negatives,
    /// fractions, or non-numbers).
    pub fn as_u64(&self) -> Option<u64> {
        match self.as_f64() {
            Some(v) if v >= 0.0 && v.fract() == 0.0 && v < 1.8446744073709552e19 => {
                Some(v as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Nested lookup by path.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Array of f64 helper.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr().map(|a| a.iter().filter_map(Json::as_f64).collect())
    }

    /// Array of f32 helper (used by the quant-plan smoothing vectors).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr().map(|a| a.iter().filter_map(|v| v.as_f64().map(|x| x as f32)).collect())
    }

    /// Serialize (compact).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 1-space indentation (matches python json.dump(indent=1)).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !fields.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(b'N') => self.literal("NaN", Json::Num(f64::NAN)),
            Some(b'I') => self.literal("Infinity", Json::Num(f64::INFINITY)),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.bytes[self.pos..];
                    let len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..len.min(rest.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos += chunk.len();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
            if self.peek() == Some(b'I') {
                return self.literal("Infinity", Json::Num(f64::NEG_INFINITY));
            }
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Convenience: object builder.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience: f64 array.
pub fn num_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&v| Json::Num(v)).collect())
}

/// Map keyed by string, sorted (for deterministic output).
pub fn sorted_obj(map: BTreeMap<String, Json>) -> Json {
    Json::Obj(map.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(), Some("c"));
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"k":[1,2.5,"x"],"nested":{"a":true}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""café ő""#).unwrap();
        assert_eq!(v.as_str(), Some("café ő"));
        let round = parse(&v.to_string_compact()).unwrap();
        assert_eq!(round, v);
    }

    #[test]
    fn python_nan_inf_literals() {
        // python json.dump emits NaN / Infinity for non-finite floats
        assert!(parse("NaN").unwrap().as_f64().unwrap().is_nan());
        assert_eq!(parse("-Infinity").unwrap().as_f64(), Some(f64::NEG_INFINITY));
    }

    #[test]
    fn integer_formatting_is_stable() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn as_u64_accepts_integers_only() {
        assert_eq!(Json::Num(3.0).as_u64(), Some(3));
        assert_eq!(Json::Num(0.0).as_u64(), Some(0));
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Str("3".into()).as_u64(), None);
    }

    #[test]
    fn as_f32_vec_reads_number_arrays() {
        let v = parse("[0.5, 2, -1.25]").unwrap();
        assert_eq!(v.as_f32_vec(), Some(vec![0.5f32, 2.0, -1.25]));
        assert_eq!(Json::Null.as_f32_vec(), None);
    }
}
