//! Single-pass fused analyze: all four transform modes, shared
//! intermediates, zero steady-state allocation.
//!
//! The pre-refactor path (`NativeExecutor::analyze_naive`) evaluated
//! each [`Mode`] independently: four full (X̂, Ŵ) materializations, a
//! dense `X @ H` rotation matmul per rotating mode, and a fresh set of
//! quantization intermediates per mode.  [`analyze_all_modes`] computes
//! the identical [`AnalyzeOut`] with one pass per shared intermediate:
//!
//! * the Eq. 4 migration vector and the smoothed pair (X·s⁻¹, s·W) are
//!   built **once** and shared by `smooth` and `smooth_rotate` (the
//!   latter rotates the smoothed pair in place),
//! * rotation runs through the cached [`Rotation`] — the O(d log d)
//!   FWHT butterfly for every width with a Sylvester ⊗ Paley
//!   factorization, never a dense `X @ H` matmul on that path,
//! * per mode, `Q(X)` and the residuals `X − Q(X)`, `W − Q(W)` are
//!   produced by one-pass slice kernels ([`crate::quant::qdq_split_slice`])
//!   and feed a single Eq. 2 accumulator via the delta identity
//!   `Y − Y_q = (X − Q(X)) W + Q(X) (W − Q(W))`,
//! * every matrix-sized buffer comes from the caller's [`Workspace`],
//!   so a warm worker's per-request allocations shrink to the small
//!   O(rows + cols) scale vectors (Eq. 1/4 deltas and migration
//!   factors) — the O(rows x cols) traffic is pooled,
//! * all row-loops fan out over `threads` scoped threads
//!   ([`crate::kernels::par`]) without changing per-row accumulation
//!   order, so results are deterministic at every thread count.
//!
//! `tests/proptest_kernels.rs` pins `analyze_all_modes` against the
//! naive per-mode path within 1e-4 relative error across random
//! shapes, bit widths and migration strengths.

use crate::kernels::igemm;
use crate::kernels::par;
use crate::kernels::workspace::Workspace;
use crate::metrics::{self, Channels};
use crate::qtensor::{PlannedWeight, QMatrix, ScaleAxis};
use crate::quant;
use crate::runtime::AnalyzeOut;
use crate::telemetry::timers;
use crate::tensor::{self, Matrix};
use crate::transforms::{self, Mode, Rotation, RotationCache};

/// One-pass `Q(X)` + residual split over every row (per-token grids),
/// rows fanned out across `threads` via the shared two-plane chunker
/// ([`par::for_each_row_chunk2`]; a serving executor's persistent pool
/// is picked up automatically).
fn split_token(src: &Matrix, deltas: &[f32], q: &mut [f32], d: &mut [f32], threads: usize) {
    let (n, c) = src.shape();
    if n == 0 || c == 0 {
        return;
    }
    par::for_each_row_chunk2(q, d, c, threads, |row0, qc, dc| {
        let rows = qc.len() / c;
        for i in 0..rows {
            quant::qdq_split_slice(
                src.row(row0 + i),
                deltas[row0 + i],
                &mut qc[i * c..(i + 1) * c],
                &mut dc[i * c..(i + 1) * c],
            );
        }
    });
}

/// Residual `W − Q(W)` under per-column grids, rows fanned out across
/// `threads`.
fn resid_channel(src: &Matrix, deltas: &[f32], out: &mut [f32], threads: usize) {
    let (n, c) = src.shape();
    if n == 0 || c == 0 {
        return;
    }
    par::for_each_row_chunk(out, c, threads, |row0, chunk| {
        let rows = chunk.len() / c;
        for i in 0..rows {
            quant::qdq_resid_cols(src.row(row0 + i), deltas, &mut chunk[i * c..(i + 1) * c]);
        }
    });
}

/// Eq. 2 error + the paper's difficulty metrics for one transformed
/// (X̂, Ŵ) pair, all scratch drawn from `ws`.
fn eval_pair(
    xh: &Matrix,
    wh: &Matrix,
    bits: u32,
    ws: &mut Workspace,
    threads: usize,
) -> (f64, f64, f64, f64) {
    let (n, c_in) = xh.shape();
    let c_out = wh.cols();
    let tok = quant::token_scales(xh, bits);
    let ch = quant::channel_scales(wh, bits);

    let mut qx = ws.take(n * c_in);
    let mut dx = ws.take(n * c_in);
    split_token(xh, &tok, &mut qx, &mut dx, threads);
    let mut dw = ws.take(c_in * c_out);
    resid_channel(wh, &ch, &mut dw, threads);

    let qx = Matrix::from_vec(n, c_in, qx);
    let dx = Matrix::from_vec(n, c_in, dx);
    let dw = Matrix::from_vec(c_in, c_out, dw);
    let mut acc = ws.take(n * c_out);
    // delta identity: Y - Yq = (X - Q(X)) W + Q(X) (W - Q(W)); the
    // residual factor is sparse-ish, so it takes the zero-skip kernel
    par::matmul_acc_sparse_into(&mut acc, &dx, wh, threads);
    par::matmul_acc_into(&mut acc, &qx, &dw, threads);
    let err: f64 = acc.iter().map(|&v| (v as f64) * (v as f64)).sum();

    let act_diff = metrics::quant_difficulty(xh, Channels::Columns);
    let w_diff = metrics::quant_difficulty(wh, Channels::Rows);
    let absmax = xh.abs_max() as f64;

    ws.give(acc);
    ws.give(qx.into_vec());
    ws.give(dx.into_vec());
    ws.give(dw.into_vec());
    (err, act_diff, w_diff, absmax)
}

/// `R^T W` (the weight side of Eq. 3) without a dense `R`:
/// `R^T W = (W^T R)^T`, so transpose, row-rotate, transpose back.
fn rotate_weights(rot: &Rotation, w: &Matrix, ws: &mut Workspace, threads: usize) -> Matrix {
    let (r, c) = w.shape();
    let mut wt = ws.take_matrix(c, r);
    par::transpose_into(w, &mut wt, threads);
    rot.apply_rows(&mut wt, threads);
    let mut out = ws.take_matrix(r, c);
    par::transpose_into(&wt, &mut out, threads);
    ws.give_matrix(wt);
    out
}

/// Analyze one (X, W) pair across all four transform modes in a single
/// fused pass — the kernel-engine replacement for the per-mode loop.
///
/// Rotations come from `cache` (built once per width, FWHT whenever
/// the width factors as 2^p · paley), matrix-sized scratch comes from
/// `ws` (pooled in steady state; only small scale vectors still
/// allocate), and row-parallel kernels use up to `threads` threads
/// (`0` = all cores, `1` = fully inline).
pub fn analyze_all_modes(
    x: &Matrix,
    w: &Matrix,
    bits: u32,
    alpha: f32,
    cache: &mut RotationCache,
    ws: &mut Workspace,
    threads: usize,
) -> Result<AnalyzeOut, String> {
    let c_in = x.cols();
    if w.rows() != c_in {
        return Err(format!("analyze shape mismatch: {x:?} @ {w:?}"));
    }
    fn put(out: &mut AnalyzeOut, mode: Mode, v: (f64, f64, f64, f64)) {
        let i = mode.index();
        out.errors[i] = v.0;
        out.act_difficulty[i] = v.1;
        out.w_difficulty[i] = v.2;
        out.act_absmax[i] = v.3;
    }
    let mut out = AnalyzeOut::default();

    // mode `none`: straight off the inputs
    let v = eval_pair(x, w, bits, ws, threads);
    put(&mut out, Mode::None, v);

    // one Eq. 4 migration vector + one smoothed pair, shared by both
    // smoothing modes
    let s = transforms::smooth_scales(x, w, alpha);
    let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
    let mut xs = ws.take_matrix_copy(x);
    xs.scale_cols_mut(&inv);
    let mut wsm = ws.take_matrix_copy(w);
    wsm.scale_rows_mut(&s);
    let v = eval_pair(&xs, &wsm, bits, ws, threads);
    put(&mut out, Mode::Smooth, v);

    // one rotation per width, shared by both rotating modes
    let rot = cache.get(c_in)?;

    let mut xr = ws.take_matrix_copy(x);
    rot.apply_rows(&mut xr, threads);
    let wr = rotate_weights(rot, w, ws, threads);
    let v = eval_pair(&xr, &wr, bits, ws, threads);
    put(&mut out, Mode::Rotate, v);
    ws.give_matrix(xr);
    ws.give_matrix(wr);

    // smooth-rotate reuses the smoothed pair: rotate X̂ in place
    rot.apply_rows(&mut xs, threads);
    let wsr = rotate_weights(rot, &wsm, ws, threads);
    let v = eval_pair(&xs, &wsr, bits, ws, threads);
    put(&mut out, Mode::SmoothRotate, v);
    ws.give_matrix(xs);
    ws.give_matrix(wsm);
    ws.give_matrix(wsr);

    Ok(out)
}

/// Shared input validation for the planned evaluation paths: gate the
/// smoothing pair / rotation down to what `mode` actually uses, and
/// reject missing or width-mismatched plan ingredients with an error
/// prefixed by `what`.  Keeping this in one place guarantees the f32
/// and integer planned paths can never drift in which plans they
/// accept.
#[allow(clippy::type_complexity)]
fn planned_inputs<'a>(
    what: &str,
    c_in: usize,
    mode: Mode,
    smooth: Option<(&'a [f32], &'a [f32])>,
    rot: Option<&'a Rotation>,
) -> Result<(Option<(&'a [f32], &'a [f32])>, Option<&'a Rotation>), String> {
    let smooths = matches!(mode, Mode::Smooth | Mode::SmoothRotate);
    let rotates = matches!(mode, Mode::Rotate | Mode::SmoothRotate);
    let smooth = if smooths {
        let (s, inv) = smooth.ok_or_else(|| {
            format!("{what}: mode {} needs the plan's smoothing vector", mode.name())
        })?;
        if s.len() != c_in || inv.len() != c_in {
            return Err(format!(
                "{what}: smoothing vectors have {}/{} channels, activations have {c_in}",
                s.len(),
                inv.len()
            ));
        }
        Some((s, inv))
    } else {
        None
    };
    let rot = if rotates {
        let r = rot
            .ok_or_else(|| format!("{what}: mode {} needs a pre-resolved rotation", mode.name()))?;
        if r.dim() != c_in {
            return Err(format!(
                "{what}: rotation is {}-wide, activations are {c_in}-wide",
                r.dim()
            ));
        }
        Some(r)
    } else {
        None
    };
    Ok((smooth, rot))
}

/// Analyze one (X, W) pair under a *single, pre-decided* transform —
/// the plan-driven serving path ("calibrate once, serve many").
///
/// Where [`analyze_all_modes`] evaluates all four modes and implicitly
/// searches, this evaluates exactly the planned `mode`: the Eq. 4
/// smoothing vector and its reciprocals come from the calibration plan
/// (`smooth = (s, 1/s)`, both resolved once at plan-load time and
/// applied verbatim — never recomputed from the request), and the
/// rotation comes pre-resolved from the plan registry (`rot`).  One
/// shared `eval_pair` pass instead of four, zero per-request transform
/// search, and no weight copy on the pure-rotate path.
///
/// The returned [`AnalyzeOut`] carries the evaluated mode's error,
/// difficulty and absmax in that mode's slot; every *other* mode's
/// error is set to `f64::INFINITY` (so an argmin over the errors
/// recovers the planned mode) and its remaining slots stay zero.
// One knob per plan ingredient: the argument list IS the plan entry.
#[allow(clippy::too_many_arguments)]
pub fn analyze_planned(
    x: &Matrix,
    w: &Matrix,
    bits: u32,
    mode: Mode,
    smooth: Option<(&[f32], &[f32])>,
    rot: Option<&Rotation>,
    ws: &mut Workspace,
    threads: usize,
) -> Result<AnalyzeOut, String> {
    let c_in = x.cols();
    if w.rows() != c_in {
        return Err(format!("analyze_planned shape mismatch: {x:?} @ {w:?}"));
    }
    let (s, rot) = planned_inputs("analyze_planned", c_in, mode, smooth, rot)?;

    let mut out = AnalyzeOut::default();
    for i in 0..4 {
        out.errors[i] = f64::INFINITY;
    }
    let i = mode.index();
    let v = match (s, rot) {
        // mode `none`: straight off the inputs, nothing copied
        (None, None) => eval_pair(x, w, bits, ws, threads),
        // pure rotate: X is copied (rotated in place), W is only read
        (None, Some(rot)) => {
            let mut xr = ws.take_matrix_copy(x);
            rot.apply_rows(&mut xr, threads);
            let wr = rotate_weights(rot, w, ws, threads);
            let v = eval_pair(&xr, &wr, bits, ws, threads);
            ws.give_matrix(xr);
            ws.give_matrix(wr);
            v
        }
        // smoothing modes: scaled copies of both sides, then rotate
        // the smoothed pair for smooth-rotate
        (Some((s, inv)), rot) => {
            let mut xh = ws.take_matrix_copy(x);
            xh.scale_cols_mut(inv);
            let mut wh = ws.take_matrix_copy(w);
            wh.scale_rows_mut(s);
            let v = if let Some(rot) = rot {
                rot.apply_rows(&mut xh, threads);
                let wr = rotate_weights(rot, &wh, ws, threads);
                let v = eval_pair(&xh, &wr, bits, ws, threads);
                ws.give_matrix(wr);
                v
            } else {
                eval_pair(&xh, &wh, bits, ws, threads)
            };
            ws.give_matrix(xh);
            ws.give_matrix(wh);
            v
        }
    };
    out.errors[i] = v.0;
    out.act_difficulty[i] = v.1;
    out.w_difficulty[i] = v.2;
    out.act_absmax[i] = v.3;
    Ok(out)
}

/// [`analyze_planned`]'s integer-execution twin: evaluate the planned
/// transform by **actually computing in integers** instead of
/// simulating quantization in f32.
///
/// Where the f32 planned path transforms both sides, quantize-
/// dequantizes them and runs two f32 matmuls per request, this path
/// assumes the weight side was transformed and quantized **once** at
/// plan load ([`PlannedWeight`], built by the plan registry) and per
/// request only:
///
/// 1. transforms the activation rows (plan smoothing vector / rotation,
///    exactly as [`analyze_planned`]),
/// 2. quantizes them onto per-token i8 grids (pooled code buffer, only
///    the O(rows) scale vector allocates),
/// 3. runs the `i32`-accumulated integer GEMM
///    ([`crate::kernels::igemm`]) against the pre-quantized weight,
/// 4. reports the **executed** Eq. 2 error `‖XW − dequant(Q(X̂)·Q(Ŵ))‖²`
///    — the untransformed product is the reference because the Eq. 3–4
///    transforms preserve it (`diag(s)·diag(1/s)` cancels, `R Rᵀ = I`).
///
/// The returned [`AnalyzeOut`] has the same planned-mode shape as
/// [`analyze_planned`] (every other mode's error is `+∞`, so an argmin
/// recovers the plan); the weight-difficulty slot carries the metric
/// captured when the planned weight was prepared.
#[allow(clippy::too_many_arguments)]
pub fn analyze_planned_int(
    x: &Matrix,
    w: &Matrix,
    bits: u32,
    mode: Mode,
    smooth: Option<(&[f32], &[f32])>,
    rot: Option<&Rotation>,
    pw: &PlannedWeight,
    ws: &mut Workspace,
    threads: usize,
) -> Result<AnalyzeOut, String> {
    let (n, c_in) = x.shape();
    if w.rows() != c_in {
        return Err(format!("analyze_planned_int shape mismatch: {x:?} @ {w:?}"));
    }
    let c_out = w.cols();
    if pw.packed.shape() != (c_in, c_out) {
        return Err(format!(
            "analyze_planned_int: pre-quantized weight is {:?}, request needs ({c_in}, {c_out})",
            pw.packed.shape()
        ));
    }
    let (smooth, rot) = planned_inputs("analyze_planned_int", c_in, mode, smooth, rot)?;
    let inv = smooth.map(|(_, inv)| inv);

    // activation side only: the weight was transformed + quantized at
    // plan load
    let mut xh = ws.take_matrix_copy(x);
    {
        let _span = timers::span(timers::Stage::Transform);
        if let Some(inv) = inv {
            xh.scale_cols_mut(inv);
        }
        if let Some(rot) = rot {
            rot.apply_rows(&mut xh, threads);
        }
    }

    // the only per-request quantization work on this path; the GEMM
    // streams the weight's packed tiles (register-blocked microkernel,
    // bit-identical to the row-major kernel)
    let qx = {
        let _span = timers::span(timers::Stage::Quantize);
        QMatrix::quantize_i8_with(&xh, bits, ScaleAxis::PerRow, ws)?
    };
    let mut yq = ws.take(n * c_out);
    {
        let _span = timers::span(timers::Stage::Igemm);
        igemm::igemm_packed_into(&mut yq, &qx, &pw.packed, ws, threads)?;
    }

    // f32 reference product (transform-invariant, so no weight
    // transform per request)
    let _span = timers::span(timers::Stage::Postprocess);
    let mut y = ws.take(n * c_out);
    par::matmul_acc_into(&mut y, x, w, threads);
    let err = tensor::frob_dist_sq(&y, &yq);

    let act_diff = metrics::quant_difficulty(&xh, Channels::Columns);
    let absmax = xh.abs_max() as f64;
    drop(_span);
    ws.give(y);
    ws.give(yq);
    qx.recycle(ws);
    ws.give_matrix(xh);

    let mut out = AnalyzeOut::default();
    for i in 0..4 {
        out.errors[i] = f64::INFINITY;
    }
    let i = mode.index();
    out.errors[i] = err;
    out.act_difficulty[i] = act_diff;
    out.w_difficulty[i] = pw.w_difficulty;
    out.act_absmax[i] = absmax;
    Ok(out)
}

/// [`analyze_planned_int`] over a whole coalesced **batch** in one
/// fused kernel invocation — the serving core's stacked hot path
/// ([`crate::serve::NativeBatchExecutor`]'s `run_batch`).
///
/// All jobs must share the planned cell's shape (`c_in`, `c_out`) and
/// transform; their activation row counts may differ.  Instead of
/// re-running the whole pipeline per job, the batch:
///
/// 1. **stacks** every job's activation rows into one tall workspace
///    matrix,
/// 2. applies the plan transform **once** — one smoothing-scale sweep
///    and one FWHT pass over the stacked rows,
/// 3. per-token-quantizes the stack **once**,
/// 4. runs **one** tall integer GEMM against the entry's packed
///    [`PlannedWeight`],
/// 5. splits the output rows back per job, computing each job's
///    executed Eq. 2 error from its own slice (against its own `X W`
///    reference product).
///
/// Every step of 2–4 is **row-local** — Eq. 4 column scaling touches
/// each row independently, the Eq. 3/5 rotation is applied per row,
/// Eq. 1 per-token grids depend only on their own row, and the GEMM
/// computes each output row from its own activation row — so the
/// stacked pass is **bit-identical** to running [`analyze_planned_int`]
/// per job (pinned in `rust/tests/proptest_batchfused.rs`), while
/// paying the kernel-dispatch, transform-setup and GEMM-startup costs
/// once per batch instead of once per request.
///
/// Returns one [`AnalyzeOut`] per job, in job order, each with the
/// planned-mode shape of [`analyze_planned_int`].  An empty batch
/// returns an empty vector.
#[allow(clippy::too_many_arguments)]
pub fn analyze_planned_int_batch(
    jobs: &[(&Matrix, &Matrix)],
    bits: u32,
    mode: Mode,
    smooth: Option<(&[f32], &[f32])>,
    rot: Option<&Rotation>,
    pw: &PlannedWeight,
    ws: &mut Workspace,
    threads: usize,
) -> Result<Vec<AnalyzeOut>, String> {
    // `fused.batch_panic` failpoint: a panic originating *inside* the
    // fused kernel (under the worker's thread pool) — distinct from
    // `serve.exec_panic`, which fires at dispatch — so chaos tests
    // prove the serving worker's panic isolation holds for kernel-level
    // failures too.  No-op branch when unarmed.
    if crate::faults::fire("fused.batch_panic") {
        panic!("fault injected: fused.batch_panic");
    }
    let Some(&(x0, w0)) = jobs.first() else {
        return Ok(Vec::new());
    };
    let c_in = x0.cols();
    let c_out = w0.cols();
    for &(x, w) in jobs {
        if x.cols() != c_in || w.rows() != c_in || w.cols() != c_out {
            return Err(format!(
                "analyze_planned_int_batch: mixed shapes in one batch: {x:?} @ {w:?} \
                 vs ({c_in}, {c_out})"
            ));
        }
    }
    if pw.packed.shape() != (c_in, c_out) {
        return Err(format!(
            "analyze_planned_int_batch: pre-quantized weight is {:?}, batch needs \
             ({c_in}, {c_out})",
            pw.packed.shape()
        ));
    }
    let (smooth, rot) = planned_inputs("analyze_planned_int_batch", c_in, mode, smooth, rot)?;
    let inv = smooth.map(|(_, inv)| inv);

    // 1. stack every job's activation rows into one tall matrix
    let total: usize = jobs.iter().map(|(x, _)| x.rows()).sum();
    let mut buf = ws.take(total * c_in);
    let mut r0 = 0usize;
    for (x, _) in jobs {
        buf[r0 * c_in..(r0 + x.rows()) * c_in].copy_from_slice(x.as_slice());
        r0 += x.rows();
    }
    let mut xh = Matrix::from_vec(total, c_in, buf);

    // 2. one shared transform pass (row-local, so exactly per-job)
    {
        let _span = timers::span(timers::Stage::Transform);
        if let Some(inv) = inv {
            xh.scale_cols_mut(inv);
        }
        if let Some(rot) = rot {
            rot.apply_rows(&mut xh, threads);
        }
    }

    // 3. one per-token quantize; 4. one tall packed integer GEMM
    let qx = {
        let _span = timers::span(timers::Stage::Quantize);
        QMatrix::quantize_i8_with(&xh, bits, ScaleAxis::PerRow, ws)?
    };
    let mut yq = ws.take(total * c_out);
    {
        let _span = timers::span(timers::Stage::Igemm);
        igemm::igemm_packed_into(&mut yq, &qx, &pw.packed, ws, threads)?;
    }

    // f32 reference products: per job against its *own* weight, so the
    // executed-vs-reference association stays per request
    let _span = timers::span(timers::Stage::Postprocess);
    let mut y = ws.take(total * c_out);
    r0 = 0;
    for (x, w) in jobs {
        let rows = x.rows();
        par::matmul_acc_into(&mut y[r0 * c_out..(r0 + rows) * c_out], x, w, threads);
        r0 += rows;
    }

    // 5. split the stacked planes back per job
    let mut outs = Vec::with_capacity(jobs.len());
    r0 = 0;
    for (x, _) in jobs {
        let rows = x.rows();
        let err = tensor::frob_dist_sq(
            &y[r0 * c_out..(r0 + rows) * c_out],
            &yq[r0 * c_out..(r0 + rows) * c_out],
        );
        // per-job difficulty/absmax straight off this job's rows of the
        // stacked plane — zero copies, and the folds visit the same
        // elements in the same order as the per-job path's own matrix
        // (bit-identity, not closeness)
        let xj = &xh.as_slice()[r0 * c_in..(r0 + rows) * c_in];
        let act_diff = metrics::quant_difficulty_rows(xj, c_in);
        let absmax = xj.iter().fold(0.0f32, |m, &v| m.max(v.abs())) as f64;

        let mut out = AnalyzeOut::default();
        for e in out.errors.iter_mut() {
            *e = f64::INFINITY;
        }
        let i = mode.index();
        out.errors[i] = err;
        out.act_difficulty[i] = act_diff;
        out.w_difficulty[i] = pw.w_difficulty;
        out.act_absmax[i] = absmax;
        outs.push(out);
        r0 += rows;
    }

    ws.give(y);
    ws.give(yq);
    qx.recycle(ws);
    ws.give_matrix(xh);
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NativeExecutor;
    use crate::rng::Rng;

    fn rand_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(rows, cols, rng.normals_f32(rows * cols))
    }

    fn close(a: f64, b: f64, what: &str) {
        let denom = a.abs().max(b.abs()).max(1e-12);
        assert!((a - b).abs() / denom < 1e-4, "{what}: {a} vs {b}");
    }

    #[test]
    fn fused_matches_naive_per_mode_path() {
        for (n, c_in, c_out, bits, seed) in
            [(16usize, 64usize, 8usize, 4u32, 1u64), (9, 44, 5, 8, 2), (32, 128, 16, 3, 3)]
        {
            let x = rand_matrix(n, c_in, seed);
            let w = rand_matrix(c_in, c_out, seed + 100);
            let naive = NativeExecutor::analyze_naive(&x, &w, bits, 0.5).unwrap();
            let mut cache = RotationCache::new();
            let mut ws = Workspace::new();
            let fused = analyze_all_modes(&x, &w, bits, 0.5, &mut cache, &mut ws, 2).unwrap();
            for i in 0..4 {
                close(fused.errors[i], naive.errors[i], "errors");
                close(fused.act_difficulty[i], naive.act_difficulty[i], "act_difficulty");
                close(fused.w_difficulty[i], naive.w_difficulty[i], "w_difficulty");
                close(fused.act_absmax[i], naive.act_absmax[i], "act_absmax");
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let x = rand_matrix(24, 64, 7);
        let w = rand_matrix(64, 12, 8);
        let mut c1 = RotationCache::new();
        let mut w1 = Workspace::new();
        let a = analyze_all_modes(&x, &w, 4, 0.5, &mut c1, &mut w1, 1).unwrap();
        let mut c2 = RotationCache::new();
        let mut w2 = Workspace::new();
        let b = analyze_all_modes(&x, &w, 4, 0.5, &mut c2, &mut w2, 4).unwrap();
        assert_eq!(a.errors, b.errors);
        assert_eq!(a.act_difficulty, b.act_difficulty);
        assert_eq!(a.w_difficulty, b.w_difficulty);
        assert_eq!(a.act_absmax, b.act_absmax);
    }

    #[test]
    fn shape_mismatch_is_an_error() {
        let x = Matrix::zeros(4, 8);
        let w = Matrix::zeros(16, 4);
        let mut cache = RotationCache::new();
        let mut ws = Workspace::new();
        assert!(analyze_all_modes(&x, &w, 4, 0.5, &mut cache, &mut ws, 1).is_err());
    }

    #[test]
    fn unconstructible_width_surfaces_the_rotation_error() {
        let x = rand_matrix(4, 6, 9);
        let w = rand_matrix(6, 4, 10);
        let mut cache = RotationCache::new();
        let mut ws = Workspace::new();
        let err = analyze_all_modes(&x, &w, 4, 0.5, &mut cache, &mut ws, 1).unwrap_err();
        assert!(err.contains("Hadamard"), "{err}");
    }

    #[test]
    fn planned_single_mode_matches_the_full_analyze_slot() {
        let x = rand_matrix(12, 64, 21);
        let w = rand_matrix(64, 8, 22);
        let alpha = 0.5f32;
        let mut cache = RotationCache::new();
        let mut ws = Workspace::new();
        let full = analyze_all_modes(&x, &w, 4, alpha, &mut cache, &mut ws, 1).unwrap();
        let s = transforms::smooth_scales(&x, &w, alpha);
        let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
        for mode in Mode::ALL {
            let smooth =
                matches!(mode, Mode::Smooth | Mode::SmoothRotate).then_some((&s[..], &inv[..]));
            let rot = if matches!(mode, Mode::Rotate | Mode::SmoothRotate) {
                Some(cache.get(64).unwrap().clone())
            } else {
                None
            };
            let got =
                analyze_planned(&x, &w, 4, mode, smooth, rot.as_ref(), &mut ws, 1).unwrap();
            let i = mode.index();
            assert_eq!(got.errors[i], full.errors[i], "{mode:?} error");
            assert_eq!(got.act_difficulty[i], full.act_difficulty[i], "{mode:?} difficulty");
            assert_eq!(got.act_absmax[i], full.act_absmax[i], "{mode:?} absmax");
            // every other mode's error is infinite, so argmin = planned
            for j in 0..4 {
                if j != i {
                    assert!(got.errors[j].is_infinite(), "{mode:?} slot {j}");
                }
            }
            let best = Mode::ALL
                .into_iter()
                .min_by(|a, b| got.errors[a.index()].partial_cmp(&got.errors[b.index()]).unwrap())
                .unwrap();
            assert_eq!(best, mode);
        }
    }

    #[test]
    fn planned_validates_its_inputs() {
        let x = rand_matrix(4, 16, 23);
        let w = rand_matrix(16, 4, 24);
        let mut ws = Workspace::new();
        // smoothing mode without the plan vector
        assert!(analyze_planned(&x, &w, 4, Mode::Smooth, None, None, &mut ws, 1).is_err());
        // rotating mode without a rotation
        assert!(analyze_planned(&x, &w, 4, Mode::Rotate, None, None, &mut ws, 1).is_err());
        // wrong-width smoothing vector
        let bad = vec![1.0f32; 8];
        assert!(analyze_planned(
            &x,
            &w,
            4,
            Mode::Smooth,
            Some((&bad, &bad)),
            None,
            &mut ws,
            1
        )
        .is_err());
        // wrong-width rotation
        let rot = crate::transforms::Rotation::build(8).unwrap();
        assert!(
            analyze_planned(&x, &w, 4, Mode::Rotate, None, Some(&rot), &mut ws, 1).is_err()
        );
    }

    #[test]
    fn planned_int_tracks_the_simulated_planned_error() {
        let x = rand_matrix(12, 64, 31);
        let w = rand_matrix(64, 8, 32);
        let alpha = 0.5f32;
        let mut cache = RotationCache::new();
        let mut ws = Workspace::new();
        let s = transforms::smooth_scales(&x, &w, alpha);
        let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
        for mode in Mode::ALL {
            let smooth =
                matches!(mode, Mode::Smooth | Mode::SmoothRotate).then_some((&s[..], &inv[..]));
            let rot = if matches!(mode, Mode::Rotate | Mode::SmoothRotate) {
                Some(cache.get(64).unwrap().clone())
            } else {
                None
            };
            let sim =
                analyze_planned(&x, &w, 8, mode, smooth, rot.as_ref(), &mut ws, 1).unwrap();
            let pw = PlannedWeight::from_plan(
                &w,
                smooth.map(|(s, _)| s),
                rot.as_ref(),
                8,
                1,
            )
            .unwrap();
            let exec =
                analyze_planned_int(&x, &w, 8, mode, smooth, rot.as_ref(), &pw, &mut ws, 1)
                    .unwrap();
            let i = mode.index();
            // executed (integer) error vs simulated (f32 qdq) error:
            // identical math, different accumulation order + reference
            // association — tight but not bit-equal
            let denom = sim.errors[i].abs().max(1e-12);
            let rel = (sim.errors[i] - exec.errors[i]).abs() / denom;
            assert!(
                rel < 1e-2,
                "{mode:?}: simulated {} vs executed {}",
                sim.errors[i],
                exec.errors[i]
            );
            assert_eq!(exec.act_difficulty[i], sim.act_difficulty[i], "{mode:?} difficulty");
            assert_eq!(exec.act_absmax[i], sim.act_absmax[i], "{mode:?} absmax");
            for j in 0..4 {
                if j != i {
                    assert!(exec.errors[j].is_infinite(), "{mode:?} slot {j}");
                }
            }
        }
    }

    #[test]
    fn planned_int_batch_is_bit_identical_to_per_job() {
        let c_in = 64usize;
        let c_out = 8usize;
        let w = rand_matrix(c_in, c_out, 41);
        let xs: Vec<Matrix> =
            (0..4).map(|i| rand_matrix(3 + 5 * i, c_in, 42 + i as u64)).collect();
        let alpha = 0.5f32;
        let s = transforms::smooth_scales(&xs[0], &w, alpha);
        let inv: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
        let mut cache = RotationCache::new();
        let mut ws = Workspace::new();
        for mode in Mode::ALL {
            let smooth =
                matches!(mode, Mode::Smooth | Mode::SmoothRotate).then_some((&s[..], &inv[..]));
            let rot = if matches!(mode, Mode::Rotate | Mode::SmoothRotate) {
                Some(cache.get(c_in).unwrap().clone())
            } else {
                None
            };
            let pw =
                PlannedWeight::from_plan(&w, smooth.map(|(s, _)| s), rot.as_ref(), 8, 1).unwrap();
            let per_job: Vec<AnalyzeOut> = xs
                .iter()
                .map(|x| {
                    analyze_planned_int(x, &w, 8, mode, smooth, rot.as_ref(), &pw, &mut ws, 2)
                        .unwrap()
                })
                .collect();
            let pairs: Vec<(&Matrix, &Matrix)> = xs.iter().map(|x| (x, &w)).collect();
            let fused =
                analyze_planned_int_batch(&pairs, 8, mode, smooth, rot.as_ref(), &pw, &mut ws, 2)
                    .unwrap();
            assert_eq!(fused.len(), per_job.len());
            for (a, b) in per_job.iter().zip(&fused) {
                assert_eq!(a.errors, b.errors, "{mode:?} errors must be bit-identical");
                assert_eq!(a.act_difficulty, b.act_difficulty, "{mode:?} difficulty");
                assert_eq!(a.w_difficulty, b.w_difficulty, "{mode:?} w difficulty");
                assert_eq!(a.act_absmax, b.act_absmax, "{mode:?} absmax");
            }
        }
        // empty batch: empty result
        assert!(analyze_planned_int_batch(
            &[],
            8,
            Mode::None,
            None,
            None,
            &PlannedWeight::from_plan(&w, None, None, 8, 1).unwrap(),
            &mut ws,
            1
        )
        .unwrap()
        .is_empty());
    }

    #[test]
    fn planned_int_batch_rejects_mixed_shapes() {
        let w = rand_matrix(16, 4, 51);
        let pw = PlannedWeight::from_plan(&w, None, None, 8, 1).unwrap();
        let a = rand_matrix(3, 16, 52);
        let b = rand_matrix(3, 8, 53); // wrong width
        let w8 = rand_matrix(8, 4, 54);
        let mut ws = Workspace::new();
        let err = analyze_planned_int_batch(
            &[(&a, &w), (&b, &w8)],
            8,
            Mode::None,
            None,
            None,
            &pw,
            &mut ws,
            1,
        )
        .unwrap_err();
        assert!(err.contains("mixed shapes"), "{err}");
        // pre-quantized weight of the wrong shape
        let pw_bad = PlannedWeight::from_plan(&rand_matrix(16, 6, 55), None, None, 8, 1).unwrap();
        let err =
            analyze_planned_int_batch(&[(&a, &w)], 8, Mode::None, None, None, &pw_bad, &mut ws, 1)
                .unwrap_err();
        assert!(err.contains("pre-quantized weight"), "{err}");
    }

    #[test]
    fn planned_int_validates_its_inputs() {
        let x = rand_matrix(4, 16, 33);
        let w = rand_matrix(16, 4, 34);
        let mut ws = Workspace::new();
        let pw = PlannedWeight::from_plan(&w, None, None, 8, 1).unwrap();
        // smoothing mode without the plan vector
        assert!(
            analyze_planned_int(&x, &w, 8, Mode::Smooth, None, None, &pw, &mut ws, 1).is_err()
        );
        // rotating mode without a rotation
        assert!(
            analyze_planned_int(&x, &w, 8, Mode::Rotate, None, None, &pw, &mut ws, 1).is_err()
        );
        // pre-quantized weight of the wrong shape
        let pw_bad = PlannedWeight::from_plan(&rand_matrix(16, 6, 35), None, None, 8, 1).unwrap();
        let err = analyze_planned_int(&x, &w, 8, Mode::None, None, None, &pw_bad, &mut ws, 1)
            .unwrap_err();
        assert!(err.contains("pre-quantized weight"), "{err}");
    }

    #[test]
    fn workspace_reaches_steady_state() {
        let x = rand_matrix(16, 64, 11);
        let w = rand_matrix(64, 8, 12);
        let mut cache = RotationCache::new();
        let mut ws = Workspace::new();
        // the pool converges to peak concurrent demand within a few calls
        for _ in 0..3 {
            analyze_all_modes(&x, &w, 4, 0.5, &mut cache, &mut ws, 1).unwrap();
        }
        let (_, warm_allocs) = ws.stats();
        for _ in 0..4 {
            analyze_all_modes(&x, &w, 4, 0.5, &mut cache, &mut ws, 1).unwrap();
        }
        let (reuses, allocs) = ws.stats();
        assert_eq!(allocs, warm_allocs, "steady-state analyze must not allocate");
        assert!(reuses > 0);
    }
}
