//! In-place fast Walsh–Hadamard transform (FWHT).
//!
//! QuaRot-style rotation (Eq. 5) multiplies each activation row by
//! `R = H / sqrt(d)`.  Materializing `H` and running a dense `X @ H`
//! matmul costs O(d²) per row; the Sylvester butterfly computes the
//! identical product in O(d log d) with no matrix at all.  For the
//! paper's non-power-of-two widths (e.g. d = 704 = 16 · 44) the crate's
//! Hadamard is `sylvester(2^p) ⊗ paley1(q)` — the same factorization
//! ([`crate::transforms::hadamard_factor`]) turns into a strided
//! butterfly over the 2^p dimension plus one small dense Paley block
//! (≤ 60×60, stack-allocated scratch) per row.  Widths with no Hadamard
//! construction keep the dense fallback in
//! [`crate::transforms::Rotation`].

use crate::tensor::Matrix;
use crate::transforms;

/// Largest Paley-I base order the crate constructs (see
/// `transforms::PALEY_ORDERS`); bounds the per-row stack scratch.
const MAX_PALEY_ORDER: usize = 60;

/// In-place unnormalized Walsh–Hadamard transform of a power-of-two
/// length slice: `x <- x @ H_sylvester` (the Sylvester matrix is
/// symmetric, so row- and column-transform coincide).
///
/// ```
/// use smoothrot::kernels::fwht::fwht;
/// use smoothrot::transforms::sylvester;
///
/// let mut v = vec![1.0f32, 2.0, 3.0, 4.0];
/// fwht(&mut v);
/// // matches the dense product against H_4
/// let h = sylvester(4).unwrap();
/// let want: Vec<f32> =
///     (0..4).map(|j| (0..4).map(|i| [1.0, 2.0, 3.0, 4.0][i] * h.get(i, j)).sum()).collect();
/// assert_eq!(v, want);
/// ```
pub fn fwht(xs: &mut [f32]) {
    let n = xs.len();
    // hard assert: a release-mode caller with a bad length would
    // otherwise scramble the slice and then index out of bounds
    assert!(n <= 1 || n.is_power_of_two(), "fwht needs a power-of-two length, got {n}");
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = xs[j];
                let b = xs[j + h];
                xs[j] = a + b;
                xs[j + h] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

/// [`fwht`] over the strided sub-sequence `xs[offset + k*stride]` for
/// `k in 0..n` — the 2^p axis of a Kronecker-factored width.
fn fwht_strided(xs: &mut [f32], offset: usize, stride: usize, n: usize) {
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let pa = offset + j * stride;
                let pb = offset + (j + h) * stride;
                let a = xs[pa];
                let b = xs[pb];
                xs[pa] = a + b;
                xs[pb] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

/// Precomputed fast-rotation plan for one width: how `d` factors as
/// `2^p · paley_order`, the dense Paley base block (if any), and the
/// `1/sqrt(d)` normalization of Eq. 5.
///
/// [`FwhtPlan::apply_row`] maps `row <- row @ (H_d / sqrt(d))` with the
/// exact same `H_d` as [`crate::transforms::hadamard`]:
/// `x (A ⊗ B) = vec(Aᵀ (X B))` for the row reshaped to `(2^p, order)`,
/// and the Sylvester factor `A` is symmetric, so the strided butterfly
/// over the 2^p axis after the per-block `X B` multiply is exact.
#[derive(Clone, Debug)]
pub struct FwhtPlan {
    d: usize,
    pow2: usize,
    /// Dense Paley-I base block; `None` for pure power-of-two widths.
    base: Option<Matrix>,
    scale: f32,
}

impl FwhtPlan {
    /// Build the plan for width `d`, or `None` when `d` has no
    /// Sylvester ⊗ Paley factorization (no Hadamard exists either).
    pub fn new(d: usize) -> Option<FwhtPlan> {
        let (pow2, q) = transforms::hadamard_factor(d)?;
        let base = if q == 0 { None } else { Some(transforms::paley1(q).ok()?) };
        Some(FwhtPlan { d, pow2, base, scale: 1.0 / (d as f32).sqrt() })
    }

    /// The width this plan rotates.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Apply the orthonormal rotation in place: `row <- row @ R`,
    /// `R = H_d / sqrt(d)`.
    pub fn apply_row(&self, row: &mut [f32]) {
        debug_assert_eq!(row.len(), self.d, "plan is for width {}", self.d);
        match &self.base {
            None => fwht(row),
            Some(b) => {
                let bdim = b.rows();
                let mut tmp = [0.0f32; MAX_PALEY_ORDER];
                // per-block dense multiply by the Paley base: X <- X B
                for blk in row.chunks_mut(bdim) {
                    let t = &mut tmp[..bdim];
                    t.fill(0.0);
                    for (j1, &v) in blk.iter().enumerate() {
                        let brow = b.row(j1);
                        for (tv, &bv) in t.iter_mut().zip(brow) {
                            *tv += v * bv;
                        }
                    }
                    blk.copy_from_slice(t);
                }
                // butterfly over the 2^p axis at each base offset
                for j in 0..bdim {
                    fwht_strided(row, j, bdim, self.pow2);
                }
            }
        }
        for v in row.iter_mut() {
            *v *= self.scale;
        }
    }

    /// Rotate every row of `x` in place, rows split across `threads`.
    pub fn apply_matrix(&self, x: &mut Matrix, threads: usize) {
        let d = x.cols();
        debug_assert_eq!(d, self.d, "plan is for width {}", self.d);
        let plan = self;
        super::par::for_each_row_chunk(x.as_mut_slice(), d, threads, |_, chunk| {
            for row in chunk.chunks_mut(d) {
                plan.apply_row(row);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::transforms::rotation;

    fn rand_row(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        rng.normals_f32(d)
    }

    #[test]
    fn fwht_matches_dense_sylvester() {
        for d in [1usize, 2, 4, 8, 32, 128] {
            let x = rand_row(d, d as u64);
            let mut got = x.clone();
            fwht(&mut got);
            let h = transforms::sylvester(d).unwrap();
            for j in 0..d {
                let want: f32 = (0..d).map(|i| x[i] * h.get(i, j)).sum();
                assert!((got[j] - want).abs() < 1e-3, "d={d} col {j}: {} vs {want}", got[j]);
            }
        }
    }

    #[test]
    fn plan_matches_dense_rotation_pow2_and_paley() {
        for d in [2usize, 16, 64, 44, 88, 176] {
            let plan = FwhtPlan::new(d).expect("factorable width");
            assert_eq!(plan.dim(), d);
            let x = rand_row(d, 100 + d as u64);
            let mut got = x.clone();
            plan.apply_row(&mut got);
            let r = rotation(d).unwrap();
            for j in 0..d {
                let want: f32 = (0..d).map(|i| x[i] * r.get(i, j)).sum();
                assert!((got[j] - want).abs() < 1e-4, "d={d} col {j}: {} vs {want}", got[j]);
            }
        }
    }

    #[test]
    fn plan_absent_for_unconstructible_widths() {
        assert!(FwhtPlan::new(6).is_none());
        assert!(FwhtPlan::new(172).is_none());
        assert!(FwhtPlan::new(0).is_none());
    }

    #[test]
    fn apply_matrix_rotates_every_row() {
        let d = 64;
        let plan = FwhtPlan::new(d).unwrap();
        let mut rng = Rng::new(9);
        let x = Matrix::from_vec(7, d, rng.normals_f32(7 * d));
        let mut a = x.clone();
        plan.apply_matrix(&mut a, 1);
        let mut b = x.clone();
        plan.apply_matrix(&mut b, 4);
        assert_eq!(a.as_slice(), b.as_slice(), "thread count must not change results");
        // isometry per row
        for i in 0..7 {
            let n0: f64 = x.row(i).iter().map(|&v| (v as f64).powi(2)).sum();
            let n1: f64 = a.row(i).iter().map(|&v| (v as f64).powi(2)).sum();
            assert!((n0.sqrt() - n1.sqrt()).abs() / n0.sqrt().max(1e-9) < 1e-5);
        }
    }
}
