//! Integer GEMM — the arithmetic side of real integer execution.
//!
//! `i8 × i8 → i32`-accumulated matrix product over [`QMatrix`] codes,
//! with the scale product `Δx_i · Δw_j` applied exactly once per output
//! element.  This is the operation the paper's premise promises
//! ("quantizing activations *and* weights enables faster operations via
//! integer arithmetic") and that the rest of the repo only simulated
//! with f32 quantize-dequantize followed by f32 matmuls.
//!
//! Two kernels share the contract:
//!
//! * [`igemm_into`] — the general row-major kernel: cache-blocked i-k-j
//!   loop (`KB = 64` k-panel), contiguous branch-free inner j loop over
//!   the weight row and the accumulator row, so it auto-vectorizes,
//! * [`igemm_packed_into`] — the serving hot path over a
//!   [`PackedWeight`]: per output row and weight tile, `TILE = 16`
//!   `i32` accumulators live in registers across the whole `k` loop
//!   (register blocking), the tile panel is streamed contiguously
//!   (`TILE` bytes per `k` step instead of an `n`-strided row), and the
//!   `i32` accumulator *plane* disappears entirely — partial sums never
//!   round-trip through memory; the per-tile dot product dispatches to
//!   the active [`crate::kernels::simd`] backend (AVX2/NEON when
//!   detected), which is pinned **bit-identical** to the scalar
//!   reference by `rust/tests/differential_kernels.rs`,
//! * output rows split into contiguous chunks across up to `threads`
//!   threads via [`super::par`] (`0` = all cores, `1` = fully inline;
//!   a serving executor's persistent pool is picked up automatically) —
//!   and because integer addition is associative, results are
//!   **exactly** identical at every thread count *and* across the two
//!   kernels, not just bit-stable per row,
//! * any `i32` accumulator plane and i4-unpack scratch come from the
//!   caller's [`Workspace`] typed pools, so steady-state serving
//!   allocates nothing on this path,
//! * a k-bound guard rejects shapes whose worst-case `Σ |q_x·q_w|`
//!   could overflow `i32` (unreachable below ~131k inner channels at
//!   8 bits).
//!
//! `rust/tests/proptest_igemm.rs` pins the output against the f32
//! `qdq`-then-`matmul` reference to ≤ 1e-4 relative Frobenius error
//! across shapes, bit widths, granularities and thread counts, and
//! `rust/tests/proptest_batchfused.rs` pins packed == row-major
//! exactly.

use crate::kernels::par;
use crate::kernels::simd::{self, KernelBackend};
use crate::kernels::workspace::Workspace;
use crate::qtensor::{PackedWeight, QMatrix, ScaleAxis};
use crate::tensor::Matrix;

/// Largest code magnitude of a symmetric b-bit grid, as u64.
fn max_level(bits: u32) -> u64 {
    (1u64 << (bits - 1)) - 1
}

/// `out = dequant(xq @ wq)`: integer product of the codes accumulated
/// in `i32`, scaled once per output element by `Δx_i · Δw_j`.
///
/// `xq` must carry per-row (per-token) scales, `wq` per-column
/// (per-channel) scales — the paper's activation × weight setting.
/// `out` is fully overwritten (shape `xq.rows() × wq.cols()`,
/// row-major).
pub fn igemm_into(
    out: &mut [f32],
    xq: &QMatrix,
    wq: &QMatrix,
    ws: &mut Workspace,
    threads: usize,
) -> Result<(), String> {
    let (m, k) = xq.shape();
    let (k2, n) = wq.shape();
    if k != k2 {
        return Err(format!("igemm inner dims: {m}x{k} @ {k2}x{n}"));
    }
    if xq.axis() != ScaleAxis::PerRow {
        return Err("igemm: activations need per-row (per-token) scales".to_string());
    }
    if wq.axis() != ScaleAxis::PerCol {
        return Err("igemm: weights need per-column (per-channel) scales".to_string());
    }
    if out.len() != m * n {
        return Err(format!("igemm output buffer: {} elements, want {m}x{n}", out.len()));
    }
    // worst-case |Σ q_x q_w| must fit an i32 accumulator
    if (k as u64) * max_level(xq.bits()) * max_level(wq.bits()) > i32::MAX as u64 {
        return Err(format!(
            "igemm: {k} inner channels at {}x{} bits can overflow the i32 accumulator",
            xq.bits(),
            wq.bits()
        ));
    }
    if m == 0 || n == 0 {
        return Ok(());
    }

    // i8 code views: borrow plain storage, unpack i4 nibbles into
    // pooled scratch
    let x_unpacked: Option<Vec<i8>> = if xq.is_packed() {
        let mut b = ws.take_i8(m * k);
        xq.unpack_into(&mut b);
        Some(b)
    } else {
        None
    };
    let w_unpacked: Option<Vec<i8>> = if wq.is_packed() {
        let mut b = ws.take_i8(k * n);
        wq.unpack_into(&mut b);
        Some(b)
    } else {
        None
    };
    let xcodes: &[i8] = x_unpacked.as_deref().unwrap_or_else(|| xq.i8_codes().expect("i8 codes"));
    let wcodes: &[i8] = w_unpacked.as_deref().unwrap_or_else(|| wq.i8_codes().expect("i8 codes"));

    let mut acc = ws.take_i32(m * n);
    let (sx, sw) = (xq.scales(), wq.scales());
    par::for_each_row_chunk2(out, &mut acc, n, threads, |row0, oc, ac| {
        chunk_kernel(row0, oc, ac, xcodes, wcodes, sx, sw, k, n);
    });

    ws.give_i32(acc);
    if let Some(b) = x_unpacked {
        ws.give_i8(b);
    }
    if let Some(b) = w_unpacked {
        ws.give_i8(b);
    }
    Ok(())
}

/// [`igemm_into`] into a fresh matrix.
pub fn igemm(
    xq: &QMatrix,
    wq: &QMatrix,
    ws: &mut Workspace,
    threads: usize,
) -> Result<Matrix, String> {
    let mut out = Matrix::zeros(xq.rows(), wq.cols());
    igemm_into(out.as_mut_slice(), xq, wq, ws, threads)?;
    Ok(out)
}

/// [`igemm_into`] over a pre-packed weight — the serving hot path.
///
/// Per output row and [`PackedWeight`] tile, the microkernel keeps
/// `TILE = 16` partial sums in `i32` **registers** across the whole
/// `k` loop and reads exactly `TILE` contiguous weight bytes per `k`
/// step, so (vs the row-major kernel) the inner loop is unrolled to a
/// fixed width, the weight traffic is sequential, and no `i32`
/// accumulator plane is ever written to memory.  The per-element
/// products and their `k`-ascending summation order are identical to
/// [`igemm_into`], and integer addition is associative — so the two
/// kernels (and every thread count) produce **bit-identical** output.
///
/// Only the activation side may still be workspace-unpacked (`i4`
/// request codes); the weight side was unpacked once at pack time.
///
/// The tile microkernel dispatches through the active
/// [`KernelBackend`] ([`simd::current`] — i.e. the executor's pinned
/// choice or the `SMOOTHROT_KERNEL` default), resolved here on the
/// calling thread *before* the row fan-out so pool workers inherit it.
pub fn igemm_packed_into(
    out: &mut [f32],
    xq: &QMatrix,
    pw: &PackedWeight,
    ws: &mut Workspace,
    threads: usize,
) -> Result<(), String> {
    igemm_packed_into_with(out, xq, pw, ws, threads, simd::current())
}

/// [`igemm_packed_into`] with an explicit [`KernelBackend`] — the
/// entry point the differential test harness uses to pin every SIMD
/// backend against [`KernelBackend::Scalar`] on identical inputs.
pub fn igemm_packed_into_with(
    out: &mut [f32],
    xq: &QMatrix,
    pw: &PackedWeight,
    ws: &mut Workspace,
    threads: usize,
    backend: KernelBackend,
) -> Result<(), String> {
    let (m, k) = xq.shape();
    let (k2, n) = pw.shape();
    if k != k2 {
        return Err(format!("igemm inner dims: {m}x{k} @ {k2}x{n}"));
    }
    if xq.axis() != ScaleAxis::PerRow {
        return Err("igemm: activations need per-row (per-token) scales".to_string());
    }
    if out.len() != m * n {
        return Err(format!("igemm output buffer: {} elements, want {m}x{n}", out.len()));
    }
    if (k as u64) * max_level(xq.bits()) * max_level(pw.bits()) > i32::MAX as u64 {
        return Err(format!(
            "igemm: {k} inner channels at {}x{} bits can overflow the i32 accumulator",
            xq.bits(),
            pw.bits()
        ));
    }
    if m == 0 || n == 0 {
        return Ok(());
    }

    let x_unpacked: Option<Vec<i8>> = if xq.is_packed() {
        let mut b = ws.take_i8(m * k);
        xq.unpack_into(&mut b);
        Some(b)
    } else {
        None
    };
    let xcodes: &[i8] = x_unpacked.as_deref().unwrap_or_else(|| xq.i8_codes().expect("i8 codes"));
    let sx = xq.scales();
    let sw = pw.scales();

    par::for_each_row_chunk(out, n, threads, |row0, chunk| {
        let rows = chunk.len() / n;
        for i in 0..rows {
            let arow = &xcodes[(row0 + i) * k..(row0 + i + 1) * k];
            packed_row_kernel(backend, arow, pw, sx[row0 + i], sw, &mut chunk[i * n..(i + 1) * n]);
        }
    });

    if let Some(b) = x_unpacked {
        ws.give_i8(b);
    }
    Ok(())
}

/// One output row of the packed GEMM: per weight tile, `TILE`
/// register-resident `i32` accumulators over the whole `k` loop
/// (dispatched to the backend's [`simd::tile_dot`] microkernel), then
/// one scale pass into the f32 output.
fn packed_row_kernel(
    backend: KernelBackend,
    arow: &[i8],
    pw: &PackedWeight,
    sxi: f32,
    sw: &[f32],
    orow: &mut [f32],
) {
    const JT: usize = PackedWeight::TILE;
    let n = orow.len();
    for t in 0..pw.tiles() {
        let panel = pw.panel(t);
        let j0 = t * JT;
        let jw = JT.min(n - j0);
        // the register block: a fixed-width accumulator array the
        // microkernel keeps out of memory
        let mut acc = [0i32; JT];
        simd::tile_dot(backend, arow, panel, &mut acc);
        let scales = &sw[j0..j0 + jw];
        for ((o, &a), &cw) in orow[j0..j0 + jw].iter_mut().zip(&acc[..jw]).zip(scales) {
            *o = a as f32 * (sxi * cw);
        }
    }
}

/// One contiguous row chunk: k-blocked `i32` accumulation, then a
/// single scale pass writing `acc * Δx_i * Δw_j` into the f32 output.
#[allow(clippy::too_many_arguments)]
fn chunk_kernel(
    row0: usize,
    out: &mut [f32],
    acc: &mut [i32],
    xcodes: &[i8],
    wcodes: &[i8],
    sx: &[f32],
    sw: &[f32],
    k: usize,
    n: usize,
) {
    const KB: usize = 64;
    debug_assert_eq!(out.len(), acc.len());
    let rows = out.len() / n;
    for kb in (0..k).step_by(KB) {
        let kend = (kb + KB).min(k);
        for i in 0..rows {
            let arow = &xcodes[(row0 + i) * k..(row0 + i) * k + k];
            let orow = &mut acc[i * n..(i + 1) * n];
            for kk in kb..kend {
                let av = arow[kk] as i32;
                let brow = &wcodes[kk * n..(kk + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += av * b as i32;
                }
            }
        }
    }
    for i in 0..rows {
        let s = sx[row0 + i];
        let arow = &acc[i * n..(i + 1) * n];
        let orow = &mut out[i * n..(i + 1) * n];
        for ((o, &a), &cw) in orow.iter_mut().zip(arow).zip(sw) {
            *o = a as f32 * (s * cw);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{self, Granularity};
    use crate::rng::Rng;

    fn rand_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(rows, cols, rng.normals_f32(rows * cols))
    }

    /// Relative Frobenius distance of two equally-shaped matrices.
    fn rel_frob(a: &Matrix, b: &Matrix) -> f64 {
        let dist = crate::tensor::frob_dist_sq(a.as_slice(), b.as_slice()).sqrt();
        dist / a.frob().max(1e-12)
    }

    #[test]
    fn igemm_matches_qdq_matmul_reference() {
        for (m, k, n, bits, seed) in
            [(8usize, 32usize, 6usize, 8u32, 1u64), (5, 17, 9, 4, 2), (12, 64, 16, 5, 3)]
        {
            let x = rand_matrix(m, k, seed);
            let w = rand_matrix(k, n, seed + 50);
            let qx = QMatrix::quantize(&x, bits, ScaleAxis::PerRow).unwrap();
            let qw = QMatrix::quantize(&w, bits, ScaleAxis::PerCol).unwrap();
            let mut ws = Workspace::new();
            let got = igemm(&qx, &qw, &mut ws, 1).unwrap();
            let want = quant::qdq(&x, bits, Granularity::PerToken)
                .matmul(&quant::qdq(&w, bits, Granularity::PerChannel));
            let rel = rel_frob(&want, &got);
            assert!(rel < 1e-4, "bits {bits}: rel frobenius {rel}");
        }
    }

    #[test]
    fn thread_counts_are_exactly_identical() {
        let x = rand_matrix(13, 40, 4);
        let w = rand_matrix(40, 11, 5);
        let qx = QMatrix::quantize(&x, 8, ScaleAxis::PerRow).unwrap();
        let qw = QMatrix::quantize(&w, 4, ScaleAxis::PerCol).unwrap();
        let mut ws = Workspace::new();
        let serial = igemm(&qx, &qw, &mut ws, 1).unwrap();
        for threads in [2usize, 3, 8, 64] {
            let par = igemm(&qx, &qw, &mut ws, threads).unwrap();
            // integer accumulation is associative: bit-identical, not
            // merely close
            assert_eq!(par.as_slice(), serial.as_slice(), "threads {threads}");
        }
    }

    #[test]
    fn packed_i4_operands_match_i8_storage() {
        let x = rand_matrix(7, 24, 6);
        let w = rand_matrix(24, 5, 7);
        let mut ws = Workspace::new();
        // force i8 storage at the same 4-bit grid via the workspace path
        let qx8 = QMatrix::quantize_i8_with(&x, 4, ScaleAxis::PerRow, &mut ws).unwrap();
        let qx4 = QMatrix::quantize(&x, 4, ScaleAxis::PerRow).unwrap();
        assert!(qx4.is_packed() && !qx8.is_packed());
        let qw4 = QMatrix::quantize(&w, 4, ScaleAxis::PerCol).unwrap();
        let a = igemm(&qx8, &qw4, &mut ws, 1).unwrap();
        let b = igemm(&qx4, &qw4, &mut ws, 2).unwrap();
        assert_eq!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn packed_weight_gemm_is_bit_identical_to_row_major() {
        // ragged n (not a multiple of the tile) exercises the padded tail
        for (m, k, n, bits) in [(7usize, 40usize, 21usize, 8u32), (12, 64, 16, 8), (5, 33, 3, 4)] {
            let x = rand_matrix(m, k, 20 + n as u64);
            let w = rand_matrix(k, n, 30 + n as u64);
            let qx = QMatrix::quantize(&x, bits, ScaleAxis::PerRow).unwrap();
            let qw = QMatrix::quantize_i8(&w, bits, ScaleAxis::PerCol).unwrap();
            let pw = PackedWeight::pack(&qw).unwrap();
            let mut ws = Workspace::new();
            let want = igemm(&qx, &qw, &mut ws, 1).unwrap();
            for threads in [1usize, 2, 8] {
                let mut got = vec![0.0f32; m * n];
                igemm_packed_into(&mut got, &qx, &pw, &mut ws, threads).unwrap();
                assert_eq!(
                    got.as_slice(),
                    want.as_slice(),
                    "m={m} k={k} n={n} bits={bits} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn packed_gemm_validates_and_handles_empty_shapes() {
        let x = rand_matrix(4, 8, 40);
        let w = rand_matrix(8, 4, 41);
        let qx = QMatrix::quantize(&x, 8, ScaleAxis::PerRow).unwrap();
        let pw = PackedWeight::pack(&QMatrix::quantize_i8(&w, 8, ScaleAxis::PerCol).unwrap())
            .unwrap();
        let mut ws = Workspace::new();
        // wrong activation granularity
        let qx_col = QMatrix::quantize(&x, 8, ScaleAxis::PerCol).unwrap();
        let mut out = vec![0.0f32; 4 * 4];
        assert!(igemm_packed_into(&mut out, &qx_col, &pw, &mut ws, 1)
            .unwrap_err()
            .contains("per-row"));
        // wrong inner dims
        let qx_bad = QMatrix::quantize(&rand_matrix(4, 6, 42), 8, ScaleAxis::PerRow).unwrap();
        assert!(igemm_packed_into(&mut out, &qx_bad, &pw, &mut ws, 1)
            .unwrap_err()
            .contains("inner dims"));
        // wrong output length
        let mut short = vec![0.0f32; 3];
        assert!(igemm_packed_into(&mut short, &qx, &pw, &mut ws, 1)
            .unwrap_err()
            .contains("output"));
        // zero-row activations are fine
        let q0 = QMatrix::quantize(&Matrix::zeros(0, 8), 8, ScaleAxis::PerRow).unwrap();
        let mut empty: Vec<f32> = Vec::new();
        igemm_packed_into(&mut empty, &q0, &pw, &mut ws, 2).unwrap();
    }

    #[test]
    fn packed_gemm_steady_state_allocates_nothing() {
        let x = rand_matrix(6, 16, 43);
        let w = rand_matrix(16, 20, 44);
        // i4 activations force the unpack scratch path
        let qx = QMatrix::quantize(&x, 4, ScaleAxis::PerRow).unwrap();
        let pw = PackedWeight::pack(&QMatrix::quantize_i8(&w, 4, ScaleAxis::PerCol).unwrap())
            .unwrap();
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; 6 * 20];
        igemm_packed_into(&mut out, &qx, &pw, &mut ws, 1).unwrap();
        let (_, warm) = ws.stats();
        for _ in 0..5 {
            igemm_packed_into(&mut out, &qx, &pw, &mut ws, 1).unwrap();
        }
        let (_, allocs) = ws.stats();
        assert_eq!(allocs, warm, "steady-state packed igemm must not allocate");
    }

    #[test]
    fn steady_state_allocates_nothing() {
        let x = rand_matrix(6, 16, 8);
        let w = rand_matrix(16, 4, 9);
        let qx = QMatrix::quantize(&x, 4, ScaleAxis::PerRow).unwrap();
        let qw = QMatrix::quantize(&w, 4, ScaleAxis::PerCol).unwrap();
        let mut ws = Workspace::new();
        let mut out = vec![0.0f32; 6 * 4];
        igemm_into(&mut out, &qx, &qw, &mut ws, 1).unwrap();
        let (_, warm) = ws.stats();
        for _ in 0..5 {
            igemm_into(&mut out, &qx, &qw, &mut ws, 1).unwrap();
        }
        let (reuses, allocs) = ws.stats();
        assert_eq!(allocs, warm, "steady-state igemm must not allocate");
        assert!(reuses > 0);
    }

    #[test]
    fn mismatched_inputs_are_named_errors() {
        let x = rand_matrix(4, 8, 10);
        let w = rand_matrix(8, 4, 11);
        let qx = QMatrix::quantize(&x, 8, ScaleAxis::PerRow).unwrap();
        let qw = QMatrix::quantize(&w, 8, ScaleAxis::PerCol).unwrap();
        let mut ws = Workspace::new();
        // wrong granularities
        let qx_col = QMatrix::quantize(&x, 8, ScaleAxis::PerCol).unwrap();
        assert!(igemm(&qx_col, &qw, &mut ws, 1).unwrap_err().contains("per-row"));
        let qw_row = QMatrix::quantize(&w, 8, ScaleAxis::PerRow).unwrap();
        assert!(igemm(&qx, &qw_row, &mut ws, 1).unwrap_err().contains("per-column"));
        // wrong inner dims
        let w_bad = QMatrix::quantize(&rand_matrix(6, 4, 12), 8, ScaleAxis::PerCol).unwrap();
        assert!(igemm(&qx, &w_bad, &mut ws, 1).unwrap_err().contains("inner dims"));
        // wrong output length
        let mut short = vec![0.0f32; 3];
        assert!(igemm_into(&mut short, &qx, &qw, &mut ws, 1).unwrap_err().contains("output"));
    }

    #[test]
    fn zero_sized_shapes_are_fine() {
        let x = Matrix::zeros(0, 8);
        let w = rand_matrix(8, 4, 13);
        let qx = QMatrix::quantize(&x, 8, ScaleAxis::PerRow).unwrap();
        let qw = QMatrix::quantize(&w, 8, ScaleAxis::PerCol).unwrap();
        let mut ws = Workspace::new();
        assert_eq!(igemm(&qx, &qw, &mut ws, 2).unwrap().shape(), (0, 4));
    }
}
