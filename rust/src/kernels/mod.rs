//! Fused multi-threaded kernel engine — the compute core under both
//! request paths.
//!
//! The paper's hot loop (transform X/W per Eq. 3–5, quantize per Eq. 1,
//! accumulate the layer-wise error of Eq. 2) used to run per mode on a
//! single-threaded scalar [`crate::tensor::Matrix`], re-materializing
//! full intermediates for each of the four [`crate::transforms::Mode`]s
//! and rotating via a dense `X @ H` matmul.  This subsystem replaces
//! that architecture:
//!
//! | module | role |
//! |---|---|
//! | [`par`] | row-parallel matmul / transpose / apply primitives + the persistent [`par::ThreadPool`] serving executors install around their hot path |
//! | [`fwht`] | in-place fast Walsh–Hadamard rotation, O(d log d) per row |
//! | [`igemm`] | `i8 × i8 → i32`-accumulated integer GEMM over [`crate::qtensor::QMatrix`] codes — row-major and packed-tile register-blocked kernels |
//! | [`simd`] | runtime-dispatched AVX2/NEON microkernels (tile dot product, per-token quantize/abs-max) pinned bit-identical to the scalar reference; [`simd::KernelBackend`] + the `SMOOTHROT_KERNEL` knob |
//! | [`fused`] | single-pass analyze computing all four mode errors with shared intermediates; planned + batch-fused integer execution |
//! | [`workspace`] | reusable per-worker scratch buffers (f32 + typed i8/i32 pools, fully pooled in steady state, trimmable between batches) |
//!
//! Layering: `par` and `workspace` sit directly on `tensor`; `fwht`
//! reuses the Sylvester ⊗ Paley factorization of
//! [`crate::transforms::hadamard`]; `fused` ties them together and is
//! what [`crate::coordinator::NativeExecutor::analyze`] and
//! [`crate::serve::NativeBatchExecutor`] delegate to.  Every kernel is
//! deterministic for a fixed input regardless of the `threads` knob
//! (rows are partitioned, per-row accumulation order never changes), so
//! the property tests can pin fused-vs-naive agreement exactly.

pub mod fused;
pub mod fwht;
pub mod igemm;
pub mod par;
pub mod simd;
pub mod workspace;
