//! Row-parallel primitives on scoped threads.
//!
//! All heavy kernels in this crate are embarrassingly parallel over
//! output rows, so one helper carries the whole subsystem:
//! [`for_each_row_chunk`] splits a row-major buffer into at most
//! `threads` contiguous row chunks and runs a closure per chunk on
//! `std::thread::scope` threads.  Per-row work is identical to the
//! serial kernels (same cache-blocked i-k-j loop, same accumulation
//! order), so results are bit-identical at every thread count — the
//! property tests rely on that.
//!
//! The `threads` knob is uniform across the crate: `0` resolves to
//! `std::thread::available_parallelism()`, `1` stays on the calling
//! thread (no spawn at all), `n > 1` uses up to `n` scoped threads.
//!
//! Scoped threads are spawned per call, not pooled: spawn cost (tens
//! of microseconds) only pays off on large rows-×-cols work, which is
//! why the serving default is `threads = 1` — worker-level parallelism
//! with zero per-kernel spawns — and `--threads N` opts bigger jobs
//! into intra-kernel fan-out.  A persistent per-executor pool is the
//! natural next step if profiles show spawn overhead on wide requests.

use crate::tensor::Matrix;

/// Resolve a thread-count knob: `0` means "all cores".
pub fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Split the row-major buffer `data` (rows of `cols` elements) into at
/// most `threads` contiguous row chunks and run `f(first_row, chunk)`
/// for each, in parallel on scoped threads.  With one effective thread
/// (or one row) `f` runs inline on the caller's thread.
pub fn for_each_row_chunk(
    data: &mut [f32],
    cols: usize,
    threads: usize,
    f: impl Fn(usize, &mut [f32]) + Sync,
) {
    let rows = if cols == 0 { 0 } else { data.len() / cols };
    let t = resolve_threads(threads).min(rows.max(1));
    if t <= 1 {
        f(0, data);
        return;
    }
    let per = (rows + t - 1) / t;
    std::thread::scope(|s| {
        for (ci, chunk) in data.chunks_mut(per * cols).enumerate() {
            let f = &f;
            s.spawn(move || f(ci * per, chunk));
        }
    });
}

/// `out += a @ b` over a row-major `out` buffer of shape
/// `(a.rows, b.cols)`, output rows split across `threads`.
///
/// Same cache-blocked i-k-j kernel as [`Matrix::matmul`] — dense inner
/// loop, no per-element branch, so it auto-vectorizes.
pub fn matmul_acc_into(out: &mut [f32], a: &Matrix, b: &Matrix, threads: usize) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    assert_eq!(b.rows(), k, "matmul inner dims: {a:?} @ {b:?}");
    assert_eq!(out.len(), m * n, "matmul output buffer shape");
    const KB: usize = 64;
    for_each_row_chunk(out, n, threads, |row0, chunk| {
        let rows = if n == 0 { 0 } else { chunk.len() / n };
        for kb in (0..k).step_by(KB) {
            let kend = (kb + KB).min(k);
            for i in 0..rows {
                let arow = a.row(row0 + i);
                let orow = &mut chunk[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let av = arow[kk];
                    let brow = b.row(kk);
                    for j in 0..n {
                        orow[j] += av * brow[j];
                    }
                }
            }
        }
    });
}

/// [`matmul_acc_into`] with the zero-skip branch kept: skips the whole
/// AXPY when the left-hand element is exactly zero.  A misprediction
/// tax on dense data, a win on sparse-ish *delta* factors like
/// `X - Q(X)` (zero wherever a value sits exactly on the grid) — the
/// dedicated entry point for [`crate::quant::quant_error_fused`] and
/// the fused analyze pass.
pub fn matmul_acc_sparse_into(out: &mut [f32], a: &Matrix, b: &Matrix, threads: usize) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    assert_eq!(b.rows(), k, "matmul inner dims: {a:?} @ {b:?}");
    assert_eq!(out.len(), m * n, "matmul output buffer shape");
    const KB: usize = 64;
    for_each_row_chunk(out, n, threads, |row0, chunk| {
        let rows = if n == 0 { 0 } else { chunk.len() / n };
        for kb in (0..k).step_by(KB) {
            let kend = (kb + KB).min(k);
            for i in 0..rows {
                let arow = a.row(row0 + i);
                let orow = &mut chunk[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let av = arow[kk];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = b.row(kk);
                    for j in 0..n {
                        orow[j] += av * brow[j];
                    }
                }
            }
        }
    });
}

/// `a @ b` with output rows split across `threads` scoped threads.
pub fn matmul(a: &Matrix, b: &Matrix, threads: usize) -> Matrix {
    let mut out = Matrix::zeros(a.rows(), b.cols());
    matmul_acc_into(out.as_mut_slice(), a, b, threads);
    out
}

/// Transpose of `src` written into `dst` (shape `(src.cols, src.rows)`),
/// output rows split across `threads`.
pub fn transpose_into(src: &Matrix, dst: &mut Matrix, threads: usize) {
    let (r, c) = src.shape();
    assert_eq!(dst.shape(), (c, r), "transpose output shape");
    let flat = src.as_slice();
    for_each_row_chunk(dst.as_mut_slice(), r, threads, |row0, chunk| {
        let rows = if r == 0 { 0 } else { chunk.len() / r };
        for i in 0..rows {
            let col = row0 + i;
            for (j, ov) in chunk[i * r..(i + 1) * r].iter_mut().enumerate() {
                *ov = flat[j * c + col];
            }
        }
    });
}

/// Transposed copy with output rows split across `threads`.
pub fn transpose(src: &Matrix, threads: usize) -> Matrix {
    let mut out = Matrix::zeros(src.cols(), src.rows());
    transpose_into(src, &mut out, threads);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(rows, cols, rng.normals_f32(rows * cols))
    }

    #[test]
    fn resolve_threads_zero_is_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn row_chunks_cover_every_row_once() {
        let cols = 5;
        let mut data = vec![0.0f32; 17 * cols];
        for threads in [1usize, 2, 3, 8, 64] {
            data.iter_mut().for_each(|v| *v = 0.0);
            for_each_row_chunk(&mut data, cols, threads, |row0, chunk| {
                let rows = chunk.len() / cols;
                for i in 0..rows {
                    for v in &mut chunk[i * cols..(i + 1) * cols] {
                        *v += (row0 + i) as f32 + 1.0;
                    }
                }
            });
            for (idx, &v) in data.iter().enumerate() {
                assert_eq!(v, (idx / cols) as f32 + 1.0, "threads={threads} idx={idx}");
            }
        }
    }

    #[test]
    fn empty_buffer_is_a_noop() {
        let mut data: Vec<f32> = Vec::new();
        for_each_row_chunk(&mut data, 0, 4, |_, chunk| assert!(chunk.is_empty()));
        for_each_row_chunk(&mut data, 3, 4, |_, chunk| assert!(chunk.is_empty()));
    }

    #[test]
    fn parallel_matmul_bit_identical_to_serial() {
        let a = rand_matrix(13, 37, 1);
        let b = rand_matrix(37, 11, 2);
        let serial = a.matmul(&b);
        for threads in [1usize, 2, 5] {
            let par = matmul(&a, &b, threads);
            assert_eq!(par.as_slice(), serial.as_slice(), "threads={threads}");
        }
    }

    #[test]
    fn sparse_matches_dense_on_delta_like_input() {
        let mut a = rand_matrix(8, 16, 3);
        // zero out about half the entries, like a quantization residual
        for (i, v) in a.as_mut_slice().iter_mut().enumerate() {
            if i % 2 == 0 {
                *v = 0.0;
            }
        }
        let b = rand_matrix(16, 6, 4);
        let mut dense = vec![0.0f32; 8 * 6];
        let mut sparse = vec![0.0f32; 8 * 6];
        matmul_acc_into(&mut dense, &a, &b, 2);
        matmul_acc_sparse_into(&mut sparse, &a, &b, 2);
        for (d, s) in dense.iter().zip(&sparse) {
            assert!((d - s).abs() < 1e-6);
        }
    }

    #[test]
    fn parallel_transpose_matches_serial() {
        let a = rand_matrix(9, 23, 5);
        let serial = a.transpose();
        for threads in [1usize, 3, 16] {
            assert_eq!(transpose(&a, threads).as_slice(), serial.as_slice());
        }
    }
}
