//! Row-parallel primitives — and the persistent worker pool behind
//! them.
//!
//! All heavy kernels in this crate are embarrassingly parallel over
//! output rows, so two helpers carry the whole subsystem:
//! [`for_each_task`] runs `f(0..tasks)` concurrently, and
//! [`for_each_row_chunk`] splits a row-major buffer into at most
//! `threads` contiguous row chunks and dispatches each as a task.
//! Per-row work is identical to the serial kernels (same cache-blocked
//! i-k-j loop, same accumulation order), so results are bit-identical
//! at every thread count — the property tests rely on that.
//!
//! The `threads` knob is uniform across the crate: `0` resolves to
//! `std::thread::available_parallelism()`, `1` stays on the calling
//! thread (no dispatch at all), `n > 1` splits into up to `n` chunks.
//!
//! **Execution backend.**  By default tasks run on `std::thread::scope`
//! threads spawned per call — fine for one-shot sweeps, but a spawn
//! costs tens of microseconds, which a serving worker pays on *every*
//! kernel of every request.  A long-lived executor therefore owns a
//! persistent [`ThreadPool`] and installs it around its hot path with
//! [`with_pool`]; every `par`-routed kernel on that thread — f32
//! matmuls and transposes, the FWHT rotation, the integer GEMMs — then
//! dispatches chunks to the pool's parked workers instead of spawning.
//! Chunk boundaries are computed from the `threads` knob alone (never
//! from the pool size), so pooled and scoped execution are
//! **bit-identical**: the backend only decides *where* a chunk runs,
//! never *what* it computes.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::tensor::Matrix;

/// Resolve a thread-count knob: `0` means "all cores".
pub fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Default per-runner thread budget when `runners` runner instances
/// share the machine: an even split of all cores, floored at one.
/// Keeps `--runners N` from oversubscribing N× (each runner owns its
/// own persistent pool); an explicit `--threads` overrides this.
pub fn threads_per_runner(runners: usize) -> usize {
    (resolve_threads(0) / runners.max(1)).max(1)
}

// ---------------------------------------------------------------------
// persistent worker pool
// ---------------------------------------------------------------------

/// Lifetime-erased pointer to the task closure of one [`ThreadPool::run`]
/// call.  Only dereferenced by tasks claimed before the job's
/// `remaining` count hits zero, and `run` does not return until then —
/// so the borrow it was created from outlives every dereference.
struct TaskFn(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared-call-safe) and outlives all
// uses (see `TaskFn` docs); the raw pointer is only a capability token.
unsafe impl Send for TaskFn {}

/// One in-flight [`ThreadPool::run`] call.
struct ActiveJob {
    f: TaskFn,
    tasks: usize,
    /// Next unclaimed task index.
    next: usize,
    /// Claimed-or-unclaimed tasks not yet finished.
    remaining: usize,
    panicked: bool,
}

#[derive(Default)]
struct PoolState {
    job: Option<ActiveJob>,
    /// Whether the most recently finished job had a panicking task
    /// (read and reset by the submitter).
    finished_panicked: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Wakes parked workers when a job arrives (or on shutdown).
    work: Condvar,
    /// Wakes the submitter when the last task of a job finishes.
    done: Condvar,
}

fn plock(m: &Mutex<PoolState>) -> std::sync::MutexGuard<'_, PoolState> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

fn pwait<'a>(
    cv: &Condvar,
    g: std::sync::MutexGuard<'a, PoolState>,
) -> std::sync::MutexGuard<'a, PoolState> {
    match cv.wait(g) {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// A persistent pool of kernel worker threads — the serving executor's
/// replacement for per-call scoped-thread spawning.
///
/// `ThreadPool::new(t)` parks `t - 1` workers; [`ThreadPool::run`]
/// executes `f(0..tasks)` across those workers **and the submitting
/// thread**, so total concurrency matches the `threads` knob the pool
/// was sized from.  One job runs at a time (single submitter — each
/// serving worker owns its own pool); a panicking task is caught on the
/// worker, recorded, and re-raised on the submitter after the job
/// drains, mirroring scoped-thread semantics without killing the pool.
///
/// Determinism: the pool never decides how work is *split* — callers
/// (e.g. [`for_each_row_chunk`]) compute chunk boundaries from the
/// `threads` knob and the pool only executes them, so results are
/// bit-identical to the scoped-thread backend at every pool size.
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
    size: usize,
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ThreadPool {{ size: {} }}", self.size)
    }
}

impl ThreadPool {
    /// A pool sized for `threads` total executors: the submitting
    /// thread plus `threads - 1` parked workers (`0` resolves to all
    /// cores, like every other `threads` knob in the crate).
    pub fn new(threads: usize) -> ThreadPool {
        let size = resolve_threads(threads).max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState::default()),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (1..size)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(shared))
            })
            .collect();
        ThreadPool { shared, handles, size }
    }

    /// Total executors (submitter + parked workers) this pool was sized
    /// for.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Execute `f(i)` for every `i in 0..tasks`, on the parked workers
    /// and the calling thread; returns when all tasks finished.
    /// Panics (on the caller) if any task panicked.
    pub fn run(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if tasks == 0 {
            return;
        }
        // While driving tasks on the submitting thread, uninstall the
        // thread-local pool: a task that (unexpectedly) re-enters
        // `for_each_task` then falls back to scoped threads instead of
        // deadlocking on the single job slot.
        let _nested_guard = PoolInstall::new(None);
        // SAFETY: erases the borrow's lifetime (a plain cast cannot,
        // because the raw-pointer type defaults the trait-object bound
        // to 'static).  Sound per the `TaskFn` contract: `run` does not
        // return until `remaining == 0`, so the borrow outlives every
        // dereference.
        let erased = TaskFn(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(f)
        });
        {
            let mut st = plock(&self.shared.state);
            assert!(st.job.is_none(), "ThreadPool::run is single-submitter");
            st.job =
                Some(ActiveJob { f: erased, tasks, next: 0, remaining: tasks, panicked: false });
        }
        self.shared.work.notify_all();
        // claim phase: the submitter works through tasks like a worker
        loop {
            let claimed = {
                let mut st = plock(&self.shared.state);
                match st.job.as_mut() {
                    Some(job) if job.next < job.tasks => {
                        let idx = job.next;
                        job.next += 1;
                        Some((job.f.0, idx))
                    }
                    _ => None,
                }
            };
            match claimed {
                Some((fp, idx)) => execute_claimed(&self.shared, fp, idx),
                None => break,
            }
        }
        // drain phase: wait for straggler tasks claimed by workers
        let mut st = plock(&self.shared.state);
        while st.job.is_some() {
            st = pwait(&self.shared.done, st);
        }
        let panicked = std::mem::take(&mut st.finished_panicked);
        drop(st);
        if panicked {
            panic!("ThreadPool: a task panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = plock(&self.shared.state);
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Run one claimed task and retire it, completing the job when it was
/// the last.
fn execute_claimed(shared: &PoolShared, f: *const (dyn Fn(usize) + Sync), idx: usize) {
    // SAFETY: `f` outlives the job (see `TaskFn`); `AssertUnwindSafe`
    // is sound because a panicking task poisons nothing — the job is
    // marked panicked and the submitter re-raises.
    let ok = catch_unwind(AssertUnwindSafe(|| unsafe { (*f)(idx) })).is_ok();
    let mut st = plock(&shared.state);
    let job = st.job.as_mut().expect("job outlives its last task");
    job.remaining -= 1;
    if !ok {
        job.panicked = true;
    }
    if job.remaining == 0 {
        let job = st.job.take().expect("checked above");
        st.finished_panicked = job.panicked;
        shared.done.notify_all();
    }
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let claimed = {
            let mut st = plock(&shared.state);
            loop {
                if let Some(job) = st.job.as_mut() {
                    if job.next < job.tasks {
                        let idx = job.next;
                        job.next += 1;
                        break (job.f.0, idx);
                    }
                }
                if st.shutdown {
                    return;
                }
                st = pwait(&shared.work, st);
            }
        };
        execute_claimed(&shared, claimed.0, claimed.1);
    }
}

thread_local! {
    static CURRENT_POOL: std::cell::RefCell<Option<Arc<ThreadPool>>> =
        const { std::cell::RefCell::new(None) };
}

/// RAII install/restore of the calling thread's dispatch pool.
struct PoolInstall {
    prev: Option<Arc<ThreadPool>>,
}

impl PoolInstall {
    fn new(pool: Option<Arc<ThreadPool>>) -> PoolInstall {
        PoolInstall {
            prev: CURRENT_POOL.with(|c| std::mem::replace(&mut *c.borrow_mut(), pool)),
        }
    }
}

impl Drop for PoolInstall {
    fn drop(&mut self) {
        CURRENT_POOL.with(|c| *c.borrow_mut() = self.prev.take());
    }
}

/// Install `pool` as the calling thread's kernel-dispatch backend for
/// the duration of `f`: every `par`-routed kernel invoked inside — f32
/// matmul/transpose, FWHT rotation, quantize splits, integer GEMMs —
/// executes its chunks on the pool's persistent workers instead of
/// spawning scoped threads.  `None` is a no-op wrapper (scoped-thread
/// behavior), so call sites can wire an *optional* pool unconditionally.
/// The previous install is restored on exit, panic included.
pub fn with_pool<R>(pool: Option<Arc<ThreadPool>>, f: impl FnOnce() -> R) -> R {
    let _guard = PoolInstall::new(pool);
    f()
}

/// Run `f(i)` for every `i in 0..tasks`, concurrently: on the calling
/// thread's installed [`ThreadPool`] when one is live ([`with_pool`]),
/// else on per-call scoped threads.  `tasks <= 1` runs inline.  This is
/// the single dispatch point every parallel kernel in the crate funnels
/// through, so installing a pool accelerates all of them at once.
pub fn for_each_task(tasks: usize, f: impl Fn(usize) + Sync) {
    match tasks {
        0 => {}
        1 => f(0),
        _ => {
            let pool = CURRENT_POOL.with(|c| c.borrow().clone());
            match pool {
                Some(p) => p.run(tasks, &f),
                None => std::thread::scope(|s| {
                    for i in 1..tasks {
                        let f = &f;
                        s.spawn(move || f(i));
                    }
                    f(0);
                }),
            }
        }
    }
}

/// A raw pointer that may cross task boundaries.  Every user hands each
/// task a *disjoint* region derived from the pointer, so the aliasing
/// rules hold even though the compiler can no longer see it.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr<T>(pub(crate) *mut T);

// SAFETY: see the type docs — regions handed out per task are disjoint.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Split the row-major buffer `data` (rows of `cols` elements) into at
/// most `threads` contiguous row chunks and run `f(first_row, chunk)`
/// for each, in parallel via [`for_each_task`].  With one effective
/// thread (or one row) `f` runs inline on the caller's thread.  Chunk
/// boundaries depend only on `threads`, never on the execution backend.
pub fn for_each_row_chunk(
    data: &mut [f32],
    cols: usize,
    threads: usize,
    f: impl Fn(usize, &mut [f32]) + Sync,
) {
    let rows = if cols == 0 { 0 } else { data.len() / cols };
    let t = resolve_threads(threads).min(rows.max(1));
    if t <= 1 {
        f(0, data);
        return;
    }
    let per = (rows + t - 1) / t;
    let chunks = (rows + per - 1) / per;
    let len = data.len();
    let base = SendPtr(data.as_mut_ptr());
    for_each_task(chunks, |ci| {
        let start = ci * per * cols;
        let end = (start + per * cols).min(len);
        // SAFETY: tasks receive disjoint row ranges of one exclusively
        // borrowed buffer, so the &mut subslices never alias.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
        f(ci * per, chunk);
    });
}

/// Two-plane variant of [`for_each_row_chunk`]: split two equally-sized
/// row-major buffers into the *same* contiguous row chunks and run
/// `f(first_row, chunk_a, chunk_b)` per chunk in parallel.  One
/// chunk-boundary computation — and one disjointness argument — shared
/// by every two-plane kernel (the fused Q/residual split, the integer
/// GEMM's output + accumulator planes), so the crate's thread-count
/// bit-identity guarantee has a single source of truth for how rows
/// are partitioned.
pub fn for_each_row_chunk2<A: Send, B: Send>(
    a: &mut [A],
    b: &mut [B],
    cols: usize,
    threads: usize,
    f: impl Fn(usize, &mut [A], &mut [B]) + Sync,
) {
    // hard assert: chunk boundaries are computed from `a` alone and
    // materialized as raw-pointer subslices of BOTH planes, so a
    // shorter `b` would be out-of-bounds UB — never let a safe caller
    // reach that (same spirit as matmul_acc_into's shape asserts)
    assert_eq!(a.len(), b.len(), "two-plane chunking needs equal lengths");
    let rows = if cols == 0 { 0 } else { a.len() / cols };
    let t = resolve_threads(threads).min(rows.max(1));
    if t <= 1 {
        f(0, a, b);
        return;
    }
    let per = (rows + t - 1) / t;
    let chunks = (rows + per - 1) / per;
    let len = a.len();
    let a_base = SendPtr(a.as_mut_ptr());
    let b_base = SendPtr(b.as_mut_ptr());
    for_each_task(chunks, |ci| {
        let start = ci * per * cols;
        let end = (start + per * cols).min(len);
        // SAFETY: tasks receive disjoint row ranges of the two
        // exclusively borrowed buffers, so the &mut subslices never
        // alias.
        let (ca, cb) = unsafe {
            (
                std::slice::from_raw_parts_mut(a_base.0.add(start), end - start),
                std::slice::from_raw_parts_mut(b_base.0.add(start), end - start),
            )
        };
        f(ci * per, ca, cb);
    });
}

/// `out += a @ b` over a row-major `out` buffer of shape
/// `(a.rows, b.cols)`, output rows split across `threads`.
///
/// Same cache-blocked i-k-j kernel as [`Matrix::matmul`] — dense inner
/// loop, no per-element branch, so it auto-vectorizes.
pub fn matmul_acc_into(out: &mut [f32], a: &Matrix, b: &Matrix, threads: usize) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    assert_eq!(b.rows(), k, "matmul inner dims: {a:?} @ {b:?}");
    assert_eq!(out.len(), m * n, "matmul output buffer shape");
    const KB: usize = 64;
    for_each_row_chunk(out, n, threads, |row0, chunk| {
        let rows = if n == 0 { 0 } else { chunk.len() / n };
        for kb in (0..k).step_by(KB) {
            let kend = (kb + KB).min(k);
            for i in 0..rows {
                let arow = a.row(row0 + i);
                let orow = &mut chunk[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let av = arow[kk];
                    let brow = b.row(kk);
                    for j in 0..n {
                        orow[j] += av * brow[j];
                    }
                }
            }
        }
    });
}

/// [`matmul_acc_into`] with the zero-skip branch kept: skips the whole
/// AXPY when the left-hand element is exactly zero.  A misprediction
/// tax on dense data, a win on sparse-ish *delta* factors like
/// `X - Q(X)` (zero wherever a value sits exactly on the grid) — the
/// dedicated entry point for [`crate::quant::quant_error_fused`] and
/// the fused analyze pass.
pub fn matmul_acc_sparse_into(out: &mut [f32], a: &Matrix, b: &Matrix, threads: usize) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    assert_eq!(b.rows(), k, "matmul inner dims: {a:?} @ {b:?}");
    assert_eq!(out.len(), m * n, "matmul output buffer shape");
    const KB: usize = 64;
    for_each_row_chunk(out, n, threads, |row0, chunk| {
        let rows = if n == 0 { 0 } else { chunk.len() / n };
        for kb in (0..k).step_by(KB) {
            let kend = (kb + KB).min(k);
            for i in 0..rows {
                let arow = a.row(row0 + i);
                let orow = &mut chunk[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let av = arow[kk];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = b.row(kk);
                    for j in 0..n {
                        orow[j] += av * brow[j];
                    }
                }
            }
        }
    });
}

/// `a @ b` with output rows split across `threads` scoped threads.
pub fn matmul(a: &Matrix, b: &Matrix, threads: usize) -> Matrix {
    let mut out = Matrix::zeros(a.rows(), b.cols());
    matmul_acc_into(out.as_mut_slice(), a, b, threads);
    out
}

/// Transpose of `src` written into `dst` (shape `(src.cols, src.rows)`),
/// output rows split across `threads`.
pub fn transpose_into(src: &Matrix, dst: &mut Matrix, threads: usize) {
    let (r, c) = src.shape();
    assert_eq!(dst.shape(), (c, r), "transpose output shape");
    let flat = src.as_slice();
    for_each_row_chunk(dst.as_mut_slice(), r, threads, |row0, chunk| {
        let rows = if r == 0 { 0 } else { chunk.len() / r };
        for i in 0..rows {
            let col = row0 + i;
            for (j, ov) in chunk[i * r..(i + 1) * r].iter_mut().enumerate() {
                *ov = flat[j * c + col];
            }
        }
    });
}

/// Transposed copy with output rows split across `threads`.
pub fn transpose(src: &Matrix, threads: usize) -> Matrix {
    let mut out = Matrix::zeros(src.cols(), src.rows());
    transpose_into(src, &mut out, threads);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn rand_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(rows, cols, rng.normals_f32(rows * cols))
    }

    #[test]
    fn resolve_threads_zero_is_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn threads_per_runner_splits_cores_evenly() {
        let all = resolve_threads(0);
        assert_eq!(threads_per_runner(1), all);
        assert_eq!(threads_per_runner(2), (all / 2).max(1));
        // more runners than cores still leaves every runner one thread
        assert_eq!(threads_per_runner(all * 4), 1);
        assert_eq!(threads_per_runner(0), all, "0 runners treated as 1");
    }

    #[test]
    fn row_chunks_cover_every_row_once() {
        let cols = 5;
        let mut data = vec![0.0f32; 17 * cols];
        for threads in [1usize, 2, 3, 8, 64] {
            data.iter_mut().for_each(|v| *v = 0.0);
            for_each_row_chunk(&mut data, cols, threads, |row0, chunk| {
                let rows = chunk.len() / cols;
                for i in 0..rows {
                    for v in &mut chunk[i * cols..(i + 1) * cols] {
                        *v += (row0 + i) as f32 + 1.0;
                    }
                }
            });
            for (idx, &v) in data.iter().enumerate() {
                assert_eq!(v, (idx / cols) as f32 + 1.0, "threads={threads} idx={idx}");
            }
        }
    }

    #[test]
    fn two_plane_chunks_cover_every_row_once_in_lockstep() {
        let cols = 3;
        let mut a = vec![0.0f32; 11 * cols];
        let mut b = vec![0i32; 11 * cols];
        for threads in [1usize, 2, 4, 32] {
            a.iter_mut().for_each(|v| *v = 0.0);
            b.iter_mut().for_each(|v| *v = 0);
            for_each_row_chunk2(&mut a, &mut b, cols, threads, |row0, ca, cb| {
                assert_eq!(ca.len(), cb.len(), "planes chunked in lockstep");
                let rows = ca.len() / cols;
                for i in 0..rows {
                    for v in &mut ca[i * cols..(i + 1) * cols] {
                        *v += (row0 + i) as f32 + 1.0;
                    }
                    for v in &mut cb[i * cols..(i + 1) * cols] {
                        *v += (row0 + i) as i32 + 1;
                    }
                }
            });
            for (idx, (&va, &vb)) in a.iter().zip(&b).enumerate() {
                assert_eq!(va, (idx / cols) as f32 + 1.0, "threads={threads} idx={idx}");
                assert_eq!(vb, (idx / cols) as i32 + 1, "threads={threads} idx={idx}");
            }
        }
    }

    #[test]
    fn empty_buffer_is_a_noop() {
        let mut data: Vec<f32> = Vec::new();
        for_each_row_chunk(&mut data, 0, 4, |_, chunk| assert!(chunk.is_empty()));
        for_each_row_chunk(&mut data, 3, 4, |_, chunk| assert!(chunk.is_empty()));
    }

    #[test]
    fn parallel_matmul_bit_identical_to_serial() {
        let a = rand_matrix(13, 37, 1);
        let b = rand_matrix(37, 11, 2);
        let serial = a.matmul(&b);
        for threads in [1usize, 2, 5] {
            let par = matmul(&a, &b, threads);
            assert_eq!(par.as_slice(), serial.as_slice(), "threads={threads}");
        }
    }

    #[test]
    fn sparse_matches_dense_on_delta_like_input() {
        let mut a = rand_matrix(8, 16, 3);
        // zero out about half the entries, like a quantization residual
        for (i, v) in a.as_mut_slice().iter_mut().enumerate() {
            if i % 2 == 0 {
                *v = 0.0;
            }
        }
        let b = rand_matrix(16, 6, 4);
        let mut dense = vec![0.0f32; 8 * 6];
        let mut sparse = vec![0.0f32; 8 * 6];
        matmul_acc_into(&mut dense, &a, &b, 2);
        matmul_acc_sparse_into(&mut sparse, &a, &b, 2);
        for (d, s) in dense.iter().zip(&sparse) {
            assert!((d - s).abs() < 1e-6);
        }
    }

    #[test]
    fn parallel_transpose_matches_serial() {
        let a = rand_matrix(9, 23, 5);
        let serial = a.transpose();
        for threads in [1usize, 3, 16] {
            assert_eq!(transpose(&a, threads).as_slice(), serial.as_slice());
        }
    }

    #[test]
    fn pool_runs_every_task_exactly_once() {
        let pool = ThreadPool::new(3);
        assert_eq!(pool.size(), 3);
        let hits: Vec<AtomicUsize> = (0..50).map(|_| AtomicUsize::new(0)).collect();
        pool.run(50, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {i}");
        }
        // the pool is reusable: a second job runs on the same workers
        pool.run(50, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 2));
    }

    #[test]
    fn pool_backed_kernels_bit_identical_to_scoped() {
        let a = rand_matrix(13, 37, 6);
        let b = rand_matrix(37, 11, 7);
        let serial = a.matmul(&b);
        let pool = Arc::new(ThreadPool::new(4));
        for threads in [2usize, 3, 8] {
            let pooled = with_pool(Some(Arc::clone(&pool)), || matmul(&a, &b, threads));
            assert_eq!(pooled.as_slice(), serial.as_slice(), "threads={threads}");
            let tp = with_pool(Some(Arc::clone(&pool)), || transpose(&a, threads));
            assert_eq!(tp.as_slice(), a.transpose().as_slice(), "transpose threads={threads}");
        }
        // the install is scoped: outside with_pool, no pool is live
        assert!(CURRENT_POOL.with(|c| c.borrow().is_none()));
    }

    #[test]
    fn pool_install_restores_on_exit() {
        let pool = Arc::new(ThreadPool::new(2));
        with_pool(Some(Arc::clone(&pool)), || {
            assert!(CURRENT_POOL.with(|c| c.borrow().is_some()));
            // nested installs shadow and restore
            with_pool(None, || {
                assert!(CURRENT_POOL.with(|c| c.borrow().is_none()));
            });
            assert!(CURRENT_POOL.with(|c| c.borrow().is_some()));
        });
        assert!(CURRENT_POOL.with(|c| c.borrow().is_none()));
    }

    #[test]
    fn pool_task_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(3);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, &|i| {
                if i == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err(), "submitter must re-raise the task panic");
        // the pool is still usable afterwards
        let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        pool.run(4, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn more_tasks_than_workers_complete() {
        let pool = ThreadPool::new(2);
        let counter = AtomicUsize::new(0);
        pool.run(123, &|_| {
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 123);
    }
}
