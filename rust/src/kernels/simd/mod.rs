//! Runtime-dispatched SIMD integer microkernels — the hardware side of
//! real integer execution.
//!
//! Every int8 serving request flows through the packed-tile GEMM
//! ([`crate::kernels::igemm::igemm_packed_into`]) and the per-token
//! quantizer ([`crate::qtensor::QMatrix::quantize_i8_with`]); until
//! this module both ran scalar loops.  Here the two inner primitives
//! get hardware implementations behind one [`KernelBackend`] dispatch:
//!
//! * [`tile_dot`] — the 16-column-tile `i8 × i8 → i32` dot product the
//!   packed microkernel runs per (output row, weight tile),
//! * [`row_absmax`] / [`quantize_row`] — the per-token grid-step
//!   reduction and the `round(v/Δ)` code conversion on the same path.
//!
//! **The contract is bit identity, not closeness.**  The integer side
//! is easy: the overflow guard in `igemm` proves no intermediate sum
//! can leave `i32`, and exact integer addition is associative, so any
//! lane layout or horizontal reduction reproduces the scalar result
//! *exactly* — provided no saturating instruction sneaks in (this is
//! why the AVX2 kernel widens `i8 → i16` and multiplies with
//! `_mm256_mullo_epi16` + `i32` widening adds instead of using
//! `_mm256_maddubs_epi16`, whose `u8 × i8` pair sums saturate at
//! `i16`).  The float side needs care in exactly two places: `max` is
//! order-free over finite values (so the abs-max reduction is exact),
//! and `f32::round` rounds ties *away from zero* while the x86 vector
//! rounding instruction rounds ties to even — the AVX2 quantizer
//! detects exact-tie lanes and steps them outward to match the scalar
//! semantics (NEON's `FRINTA` rounds ties away natively).
//! `rust/tests/differential_kernels.rs` pins every available backend
//! against [`KernelBackend::Scalar`] across randomized shapes, bits,
//! thread counts and adversarial inputs.
//!
//! Dispatch is decided **once** per executor or call site, not per
//! tile: [`KernelBackend::resolve`] picks the backend from an explicit
//! request (`--kernel-backend`), the `SMOOTHROT_KERNEL` env var, or
//! hardware detection (`is_x86_feature_detected!` / target arch), and
//! [`with_backend`] installs it around a closure the way
//! [`crate::kernels::par::with_pool`] installs a thread pool.  Kernels
//! read [`current`] on the *calling* thread before fanning work out to
//! pool workers, so the choice is immune to which thread runs a chunk.
//! The scalar kernel is the always-available reference; backends never
//! silently fall back (an unavailable explicit request is an error,
//! and `SMOOTHROT_REQUIRE_BACKEND` lets CI turn "not detected" into a
//! hard test failure).

use std::cell::Cell;
use std::sync::OnceLock;

#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

/// Output channels per packed weight tile — the panel ABI shared with
/// [`crate::qtensor::PackedWeight`]: one `k` step of a tile is `TILE`
/// contiguous `i8` codes, i.e. exactly one 128-bit vector load.
pub const TILE: usize = 16;

/// Env var naming the kernel backend (`scalar` | `avx2` | `neon` |
/// `auto`) — the CI matrix knob; `--kernel-backend` overrides it.
pub const ENV_KERNEL: &str = "SMOOTHROT_KERNEL";

/// Env var naming a backend that MUST be available: the differential
/// test harness hard-fails when it is not detected, so a CI host
/// quietly lacking AVX2/NEON cannot vacuously pass the SIMD suite.
pub const ENV_REQUIRE: &str = "SMOOTHROT_REQUIRE_BACKEND";

/// Which microkernel implementation the integer hot path dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelBackend {
    /// Portable scalar loops — always available, the bit-exact
    /// reference every SIMD backend is pinned against.
    Scalar,
    /// x86_64 AVX2: widened `i8 → i16` products, `i32` lane
    /// accumulators (two 256-bit registers cover one 16-lane tile).
    Avx2,
    /// aarch64 NEON: `vmull_s8` widened multiply + `i32` widening adds
    /// (four 128-bit accumulators per tile).
    Neon,
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

impl KernelBackend {
    /// All variants, scalar first.
    pub const ALL: [KernelBackend; 3] =
        [KernelBackend::Scalar, KernelBackend::Avx2, KernelBackend::Neon];

    /// Stable lowercase name (the `--kernel-backend` vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Avx2 => "avx2",
            KernelBackend::Neon => "neon",
        }
    }

    /// Whether this backend can run on the current host (runtime CPU
    /// feature detection for AVX2, target arch for NEON).
    pub fn available(self) -> bool {
        match self {
            KernelBackend::Scalar => true,
            KernelBackend::Avx2 => avx2_available(),
            KernelBackend::Neon => cfg!(target_arch = "aarch64"),
        }
    }

    /// Best backend the host supports (`Scalar` when no SIMD path is).
    pub fn detect() -> KernelBackend {
        if KernelBackend::Avx2.available() {
            KernelBackend::Avx2
        } else if KernelBackend::Neon.available() {
            KernelBackend::Neon
        } else {
            KernelBackend::Scalar
        }
    }

    /// Parse a backend name; `auto` resolves to [`KernelBackend::detect`].
    pub fn from_name(name: &str) -> Result<KernelBackend, String> {
        match name {
            "scalar" => Ok(KernelBackend::Scalar),
            "avx2" => Ok(KernelBackend::Avx2),
            "neon" => Ok(KernelBackend::Neon),
            "auto" => Ok(KernelBackend::detect()),
            other => Err(format!(
                "unknown kernel backend {other:?} (choices: auto, scalar, avx2, neon)"
            )),
        }
    }

    /// Resolve the backend an executor should pin: an explicit
    /// non-`auto` request wins, else the `SMOOTHROT_KERNEL` env var,
    /// else hardware detection.  A named backend the host cannot run is
    /// a hard error, never a silent scalar fallback.
    pub fn resolve(explicit: Option<&str>) -> Result<KernelBackend, String> {
        match explicit {
            Some(name) if name != "auto" => Self::named("--kernel-backend", name),
            _ => match std::env::var(ENV_KERNEL) {
                Ok(name) if !name.is_empty() && name != "auto" => {
                    Self::named(ENV_KERNEL, name.as_str())
                }
                _ => Ok(Self::detect()),
            },
        }
    }

    /// [`KernelBackend::from_name`] + availability check, with the
    /// requesting knob named in errors.
    fn named(origin: &str, name: &str) -> Result<KernelBackend, String> {
        let backend = Self::from_name(name).map_err(|e| format!("{origin}: {e}"))?;
        if !backend.available() {
            return Err(format!(
                "{origin}: kernel backend {} is not available on this host (best detected: {})",
                backend.name(),
                Self::detect().name()
            ));
        }
        Ok(backend)
    }
}

impl std::fmt::Display for KernelBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Backend the host requires tests to exercise
/// ([`ENV_REQUIRE`]; `None` when unset/empty).  The value must name a
/// concrete SIMD backend — requiring `scalar` or `auto` is an error,
/// since both would make the requirement vacuous.
pub fn required_backend() -> Result<Option<KernelBackend>, String> {
    match std::env::var(ENV_REQUIRE) {
        Ok(name) if !name.is_empty() => parse_required(&name).map(Some),
        _ => Ok(None),
    }
}

fn parse_required(name: &str) -> Result<KernelBackend, String> {
    match KernelBackend::from_name(name) {
        Ok(KernelBackend::Scalar) => Err(format!(
            "{ENV_REQUIRE}={name}: requiring the always-available scalar/auto backend is vacuous \
             — name avx2 or neon"
        )),
        Ok(backend) => Ok(backend),
        Err(e) => Err(format!("{ENV_REQUIRE}: {e}")),
    }
}

/// Process-default backend: `SMOOTHROT_KERNEL` when set (resolved once
/// and cached; an invalid or unavailable value panics loudly rather
/// than silently degrading a CI matrix leg), else hardware detection.
pub fn default_backend() -> KernelBackend {
    static DEFAULT: OnceLock<KernelBackend> = OnceLock::new();
    *DEFAULT.get_or_init(|| match KernelBackend::resolve(None) {
        Ok(backend) => backend,
        Err(e) => panic!("{e}"),
    })
}

thread_local! {
    static OVERRIDE: Cell<Option<KernelBackend>> = const { Cell::new(None) };
}

/// The backend kernels on this thread dispatch to: the innermost
/// [`with_backend`] override, else [`default_backend`].  Kernels read
/// this once per call *before* fanning out to pool workers, so an
/// executor's choice survives the hop onto its persistent thread pool.
pub fn current() -> KernelBackend {
    OVERRIDE.with(|o| o.get()).unwrap_or_else(default_backend)
}

/// Run `f` with `backend` installed as this thread's kernel backend
/// (restored on exit, even across panics) — how
/// [`crate::serve::NativeBatchExecutor`] pins its construction-time
/// choice around every run, and how the differential tests drive the
/// same code path through different backends.
pub fn with_backend<R>(backend: KernelBackend, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<KernelBackend>);
    impl Drop for Restore {
        fn drop(&mut self) {
            OVERRIDE.with(|o| o.set(self.0));
        }
    }
    let prev = OVERRIDE.with(|o| o.replace(Some(backend)));
    let _restore = Restore(prev);
    f()
}

/// `acc[j] += Σ_k arow[k] · panel[k·TILE + j]` — one weight tile of
/// one output row, the innermost loop of the packed integer GEMM.
/// `panel` is a [`crate::qtensor::PackedWeight`] panel
/// (`arow.len() · TILE` codes, `k`-contiguous rows of `TILE` columns).
///
/// Bit-identical across backends: products are exact at every width
/// (`|i8 · i8| ≤ 16129` fits `i16`), the igemm overflow guard keeps
/// every partial sum inside `i32`, and integer addition is
/// associative.
pub fn tile_dot(backend: KernelBackend, arow: &[i8], panel: &[i8], acc: &mut [i32; TILE]) {
    debug_assert_eq!(panel.len(), arow.len() * TILE, "panel ABI: k x TILE codes");
    debug_assert!(backend.available(), "unavailable backend reached tile_dot");
    match backend {
        KernelBackend::Scalar => tile_dot_scalar(arow, panel, acc),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `available()` gated dispatch — AVX2 is present.
        KernelBackend::Avx2 => unsafe { x86::tile_dot(arow, panel, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        KernelBackend::Neon => unsafe { neon::tile_dot(arow, panel, acc) },
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        _ => tile_dot_scalar(arow, panel, acc),
        #[cfg(all(target_arch = "x86_64", not(target_arch = "aarch64")))]
        KernelBackend::Neon => tile_dot_scalar(arow, panel, acc),
        #[cfg(all(target_arch = "aarch64", not(target_arch = "x86_64")))]
        KernelBackend::Avx2 => tile_dot_scalar(arow, panel, acc),
    }
}

fn tile_dot_scalar(arow: &[i8], panel: &[i8], acc: &mut [i32; TILE]) {
    for (&a, p) in arow.iter().zip(panel.chunks_exact(TILE)) {
        let av = a as i32;
        for (ac, &pv) in acc.iter_mut().zip(p) {
            *ac += av * pv as i32;
        }
    }
}

/// Largest |v| of a row — the per-token grid-step reduction
/// ([`crate::quant::token_scales`] numerator).  Exact under any
/// association over finite values, so SIMD == scalar bit for bit.
pub fn row_absmax(backend: KernelBackend, row: &[f32]) -> f32 {
    debug_assert!(backend.available(), "unavailable backend reached row_absmax");
    match backend {
        KernelBackend::Scalar => row_absmax_scalar(row),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `available()` gated dispatch — AVX2 is present.
        KernelBackend::Avx2 => unsafe { x86::row_absmax(row) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        KernelBackend::Neon => unsafe { neon::row_absmax(row) },
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        _ => row_absmax_scalar(row),
        #[cfg(all(target_arch = "x86_64", not(target_arch = "aarch64")))]
        KernelBackend::Neon => row_absmax_scalar(row),
        #[cfg(all(target_arch = "aarch64", not(target_arch = "x86_64")))]
        KernelBackend::Avx2 => row_absmax_scalar(row),
    }
}

fn row_absmax_scalar(row: &[f32]) -> f32 {
    row.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

/// `out[j] = round(row[j] / delta).clamp(-qm, qm) as i8` — one token
/// row onto its Eq. 1 grid (`delta > 0`; finite inputs).  The scalar
/// loop is the semantics; SIMD backends must reproduce its
/// round-half-away-from-zero ties exactly (see the module docs).
pub fn quantize_row(backend: KernelBackend, row: &[f32], delta: f32, qm: f32, out: &mut [i8]) {
    debug_assert_eq!(row.len(), out.len());
    debug_assert!(delta > 0.0, "quantize_row needs a positive grid step");
    debug_assert!(backend.available(), "unavailable backend reached quantize_row");
    match backend {
        KernelBackend::Scalar => quantize_row_scalar(row, delta, qm, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `available()` gated dispatch — AVX2 is present.
        KernelBackend::Avx2 => unsafe { x86::quantize_row(row, delta, qm, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        KernelBackend::Neon => unsafe { neon::quantize_row(row, delta, qm, out) },
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        _ => quantize_row_scalar(row, delta, qm, out),
        #[cfg(all(target_arch = "x86_64", not(target_arch = "aarch64")))]
        KernelBackend::Neon => quantize_row_scalar(row, delta, qm, out),
        #[cfg(all(target_arch = "aarch64", not(target_arch = "x86_64")))]
        KernelBackend::Avx2 => quantize_row_scalar(row, delta, qm, out),
    }
}

fn quantize_row_scalar(row: &[f32], delta: f32, qm: f32, out: &mut [i8]) {
    for (o, &v) in out.iter_mut().zip(row) {
        *o = (v / delta).round().clamp(-qm, qm) as i8;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn simd_backends() -> Vec<KernelBackend> {
        [KernelBackend::Avx2, KernelBackend::Neon]
            .into_iter()
            .filter(|b| b.available())
            .collect()
    }

    fn rand_codes(rng: &mut Rng, n: usize) -> Vec<i8> {
        (0..n).map(|_| (rng.below(255) as i64 - 127) as i8).collect()
    }

    #[test]
    fn names_round_trip_and_auto_detects() {
        for be in KernelBackend::ALL {
            assert_eq!(KernelBackend::from_name(be.name()).unwrap(), be);
            assert_eq!(format!("{be}"), be.name());
        }
        assert_eq!(KernelBackend::from_name("auto").unwrap(), KernelBackend::detect());
        assert!(KernelBackend::from_name("sse9").unwrap_err().contains("choices"));
        assert!(KernelBackend::Scalar.available());
        assert!(KernelBackend::detect().available());
    }

    #[test]
    fn resolve_rejects_unavailable_named_backends() {
        // at most one of avx2/neon can be available on one host
        let missing = [KernelBackend::Avx2, KernelBackend::Neon]
            .into_iter()
            .find(|b| !b.available())
            .expect("no host has both AVX2 and NEON");
        let err = KernelBackend::resolve(Some(missing.name())).unwrap_err();
        assert!(err.contains("--kernel-backend") && err.contains("not available"), "{err}");
        // explicit scalar always resolves; auto defers to env/detection
        assert_eq!(KernelBackend::resolve(Some("scalar")).unwrap(), KernelBackend::Scalar);
    }

    #[test]
    fn required_backend_rejects_vacuous_names() {
        assert!(parse_required("scalar").unwrap_err().contains("vacuous"));
        assert!(parse_required("auto").unwrap_err().contains("vacuous"));
        assert!(parse_required("sse9").unwrap_err().contains(ENV_REQUIRE));
        assert_eq!(parse_required("avx2").unwrap(), KernelBackend::Avx2);
        assert_eq!(parse_required("neon").unwrap(), KernelBackend::Neon);
    }

    #[test]
    fn with_backend_scopes_and_restores() {
        let outer = current();
        let inner = with_backend(KernelBackend::Scalar, || {
            assert_eq!(current(), KernelBackend::Scalar);
            with_backend(KernelBackend::detect(), current)
        });
        assert_eq!(inner, KernelBackend::detect());
        assert_eq!(current(), outer);
    }

    #[test]
    fn scalar_tile_dot_matches_plain_reference() {
        let mut rng = Rng::new(11);
        for k in [0usize, 1, 2, 7, 16, 33] {
            let arow = rand_codes(&mut rng, k);
            let panel = rand_codes(&mut rng, k * TILE);
            let mut acc = [3i32; TILE];
            tile_dot_scalar(&arow, &panel, &mut acc);
            for (j, &got) in acc.iter().enumerate() {
                let want: i32 =
                    3 + (0..k).map(|kk| arow[kk] as i32 * panel[kk * TILE + j] as i32).sum::<i32>();
                assert_eq!(got, want, "k={k} j={j}");
            }
        }
    }

    #[test]
    fn simd_tile_dot_bit_identical_to_scalar() {
        let mut rng = Rng::new(12);
        for be in simd_backends() {
            for k in [1usize, 2, 5, 16, 63, 256] {
                let arow = rand_codes(&mut rng, k);
                let panel = rand_codes(&mut rng, k * TILE);
                let mut want = [0i32; TILE];
                tile_dot_scalar(&arow, &panel, &mut want);
                let mut got = [0i32; TILE];
                tile_dot(be, &arow, &panel, &mut got);
                assert_eq!(got, want, "{be} k={k}");
            }
            // worst-case magnitudes: all codes at +/-127
            let k = 1024usize;
            let arow = vec![127i8; k];
            let panel: Vec<i8> =
                (0..k * TILE).map(|i| if i % 2 == 0 { 127 } else { -127 }).collect();
            let mut want = [0i32; TILE];
            tile_dot_scalar(&arow, &panel, &mut want);
            let mut got = [0i32; TILE];
            tile_dot(be, &arow, &panel, &mut got);
            assert_eq!(got, want, "{be} all-qmax");
        }
    }

    #[test]
    fn simd_row_absmax_bit_identical_to_scalar() {
        let mut rng = Rng::new(13);
        for be in simd_backends() {
            for n in [0usize, 1, 7, 8, 9, 64, 127] {
                let mut row = rng.normals_f32(n);
                if n > 3 {
                    row[n / 2] = -1e30; // the max hides mid-vector, negative
                }
                assert_eq!(row_absmax(be, &row), row_absmax_scalar(&row), "{be} n={n}");
            }
        }
    }

    #[test]
    fn simd_quantize_row_bit_identical_including_ties() {
        // exact grid-tie values are where round-to-even (the x86 vector
        // rounding mode) and f32::round (ties away from zero) disagree;
        // delta = 1 makes v/delta exact so every tie actually fires
        let planted = [
            -3.5f32, -2.5, -1.5, -0.5, 0.5, 1.5, 2.5, 3.5, 126.5, -126.5, 127.5, -127.5, 1e30,
            -1e30, 0.0, -0.0,
        ];
        let mut rng = Rng::new(14);
        for be in simd_backends() {
            for delta in [1.0f32, 0.5, 0.37, 2.25] {
                for extra in [0usize, 1, 3, 17] {
                    let mut row = planted.to_vec();
                    row.extend(rng.normals_f32(extra));
                    let mut want = vec![0i8; row.len()];
                    quantize_row_scalar(&row, delta, 127.0, &mut want);
                    let mut got = vec![0i8; row.len()];
                    quantize_row(be, &row, delta, 127.0, &mut got);
                    assert_eq!(got, want, "{be} delta={delta} extra={extra}");
                }
            }
        }
    }
}
