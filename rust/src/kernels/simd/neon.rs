//! NEON implementations of the integer-path primitives (aarch64 only).
//!
//! Same bit-identity contract as the AVX2 module, with less ceremony:
//!
//! * `tile_dot` uses `vmull_s8` (widening `i8 × i8 → i16`, exact) and
//!   `vaddw_s16` widening adds into four `i32` quad-accumulators — a
//!   plain widened multiply-add with no saturating step anywhere.
//! * `quantize_row` gets the tie handling for free: `vrndaq_f32` is
//!   `FRINTA`, round-to-nearest with ties **away from zero**, which is
//!   exactly `f32::round`'s semantics — no even/away fixup is needed,
//!   unlike x86.
//! * `row_absmax` is `vabsq_f32` + lanewise max + `vmaxvq_f32`; max is
//!   exact under any association over finite values.

use super::TILE;
#[allow(clippy::wildcard_imports)]
use std::arch::aarch64::*;

/// `acc[j] += Σ_k arow[k] · panel[k·TILE + j]`, bit-identical to
/// [`super::tile_dot`]'s scalar arm.
///
/// # Safety
/// NEON must be available (baseline on aarch64); `panel.len()` must
/// equal `arow.len() * TILE`.
#[target_feature(enable = "neon")]
pub unsafe fn tile_dot(arow: &[i8], panel: &[i8], acc: &mut [i32; TILE]) {
    debug_assert_eq!(panel.len(), arow.len() * TILE);
    let mut acc0 = vld1q_s32(acc.as_ptr());
    let mut acc1 = vld1q_s32(acc.as_ptr().add(4));
    let mut acc2 = vld1q_s32(acc.as_ptr().add(8));
    let mut acc3 = vld1q_s32(acc.as_ptr().add(12));
    for (&a, p) in arow.iter().zip(panel.chunks_exact(TILE)) {
        let av = vdup_n_s8(a);
        // one k step of the panel = 16 contiguous i8 codes (the
        // PackedWeight ABI)
        let pv = vld1q_s8(p.as_ptr());
        let prod_lo = vmull_s8(vget_low_s8(pv), av); // exact i16 products
        let prod_hi = vmull_s8(vget_high_s8(pv), av);
        acc0 = vaddw_s16(acc0, vget_low_s16(prod_lo));
        acc1 = vaddw_s16(acc1, vget_high_s16(prod_lo));
        acc2 = vaddw_s16(acc2, vget_low_s16(prod_hi));
        acc3 = vaddw_s16(acc3, vget_high_s16(prod_hi));
    }
    vst1q_s32(acc.as_mut_ptr(), acc0);
    vst1q_s32(acc.as_mut_ptr().add(4), acc1);
    vst1q_s32(acc.as_mut_ptr().add(8), acc2);
    vst1q_s32(acc.as_mut_ptr().add(12), acc3);
}

/// Largest |v| of `row`, bit-identical to the scalar fold.
///
/// # Safety
/// NEON must be available (baseline on aarch64).
#[target_feature(enable = "neon")]
pub unsafe fn row_absmax(row: &[f32]) -> f32 {
    let mut m = vdupq_n_f32(0.0);
    let mut it = row.chunks_exact(4);
    for chunk in &mut it {
        m = vmaxq_f32(m, vabsq_f32(vld1q_f32(chunk.as_ptr())));
    }
    let head = vmaxvq_f32(m);
    it.remainder().iter().fold(head, |a, &v| a.max(v.abs()))
}

/// `out[j] = round(row[j] / delta).clamp(-qm, qm) as i8`, bit-identical
/// to the scalar loop including tie rounding (`FRINTA` rounds ties
/// away from zero, matching `f32::round` directly).
///
/// # Safety
/// NEON must be available (baseline on aarch64); `out.len()` must
/// equal `row.len()`; `delta > 0` and `qm > 0` (the
/// [`super::quantize_row`] contract).
#[target_feature(enable = "neon")]
pub unsafe fn quantize_row(row: &[f32], delta: f32, qm: f32, out: &mut [i8]) {
    debug_assert_eq!(row.len(), out.len());
    let vd = vdupq_n_f32(delta);
    let vqm = vdupq_n_f32(qm);
    let vnqm = vdupq_n_f32(-qm);
    let mut lanes = [0.0f32; 4];
    let mut rows_it = row.chunks_exact(4);
    let mut out_it = out.chunks_exact_mut(4);
    for (chunk, ochunk) in (&mut rows_it).zip(&mut out_it) {
        let q = vdivq_f32(vld1q_f32(chunk.as_ptr()), vd);
        let r = vrndaq_f32(q);
        let clamped = vminq_f32(vmaxq_f32(r, vnqm), vqm);
        vst1q_f32(lanes.as_mut_ptr(), clamped);
        for (o, &v) in ochunk.iter_mut().zip(&lanes) {
            *o = v as i8;
        }
    }
    for (o, &v) in out_it.into_remainder().iter_mut().zip(rows_it.remainder()) {
        *o = (v / delta).round().clamp(-qm, qm) as i8;
    }
}
