//! AVX2 implementations of the integer-path primitives (x86_64 only).
//!
//! Every routine here is pinned bit-identical to its scalar reference
//! in [`super`], so instruction choice is driven by exactness first:
//!
//! * `tile_dot` widens `i8 → i16`, multiplies with
//!   `_mm256_mullo_epi16` (exact: `|i8 · i8| ≤ 16129` fits `i16`), and
//!   widens each product to `i32` before adding.  The obvious faster
//!   choice, `_mm256_maddubs_epi16`, is *rejected*: it takes a
//!   `u8 × i8` operand pair and its horizontal pair-add saturates at
//!   `i16`, both of which break bit identity.  Wrapping `i32` adds are
//!   safe because the igemm overflow guard bounds every partial sum.
//! * `quantize_row` divides (IEEE division is exactly rounded, so it
//!   matches the scalar `v / delta` lane for lane), then emulates
//!   `f32::round`'s ties-away-from-zero semantics on top of the
//!   hardware round-to-nearest-even: a lane is adjusted outward by
//!   `copysign(1.0, q)` exactly when `|q - round_even(q)| == 0.5`
//!   *and* the remainder points in `q`'s own direction (i.e. the even
//!   choice landed on the toward-zero side).  The subtraction
//!   `q - round_even(q)` is exact by Sterbenz's lemma (`|diff| ≤ 0.5`
//!   forces the operands within a factor of two whenever a tie can
//!   occur), so the tie test never misfires.  Sign agreement is tested
//!   on the raw sign bits (`xor` then integer compare) because a float
//!   compare cannot distinguish `+0.0` from `-0.0`.

use super::TILE;
#[allow(clippy::wildcard_imports)]
use std::arch::x86_64::*;

/// `acc[j] += Σ_k arow[k] · panel[k·TILE + j]`, bit-identical to
/// [`super::tile_dot`]'s scalar arm.
///
/// # Safety
/// The host must support AVX2 (`is_x86_feature_detected!("avx2")`);
/// `panel.len()` must equal `arow.len() * TILE`.
#[target_feature(enable = "avx2")]
pub unsafe fn tile_dot(arow: &[i8], panel: &[i8], acc: &mut [i32; TILE]) {
    debug_assert_eq!(panel.len(), arow.len() * TILE);
    let mut acc_lo = _mm256_loadu_si256(acc.as_ptr() as *const __m256i);
    let mut acc_hi = _mm256_loadu_si256(acc.as_ptr().add(8) as *const __m256i);
    for (&a, p) in arow.iter().zip(panel.chunks_exact(TILE)) {
        let av = _mm256_set1_epi16(a as i16);
        // one k step of the panel = 16 contiguous i8 codes (the
        // PackedWeight ABI), sign-extended to 16 i16 lanes
        let pv = _mm256_cvtepi8_epi16(_mm_loadu_si128(p.as_ptr() as *const __m128i));
        let prod = _mm256_mullo_epi16(av, pv); // exact: |i8 * i8| fits i16
        let lo = _mm256_cvtepi16_epi32(_mm256_castsi256_si128(prod));
        let hi = _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(prod));
        acc_lo = _mm256_add_epi32(acc_lo, lo);
        acc_hi = _mm256_add_epi32(acc_hi, hi);
    }
    _mm256_storeu_si256(acc.as_mut_ptr() as *mut __m256i, acc_lo);
    _mm256_storeu_si256(acc.as_mut_ptr().add(8) as *mut __m256i, acc_hi);
}

/// Largest |v| of `row`, bit-identical to the scalar fold.
///
/// # Safety
/// The host must support AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn row_absmax(row: &[f32]) -> f32 {
    let absmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
    let mut m = _mm256_setzero_ps();
    let mut it = row.chunks_exact(8);
    for chunk in &mut it {
        let v = _mm256_loadu_ps(chunk.as_ptr());
        m = _mm256_max_ps(m, _mm256_and_ps(v, absmask));
    }
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), m);
    let head = lanes.iter().fold(0.0f32, |a, &b| a.max(b));
    it.remainder().iter().fold(head, |a, &v| a.max(v.abs()))
}

/// `out[j] = round(row[j] / delta).clamp(-qm, qm) as i8`, bit-identical
/// to the scalar loop including tie rounding.
///
/// # Safety
/// The host must support AVX2; `out.len()` must equal `row.len()`;
/// `delta > 0` and `qm > 0` (the [`super::quantize_row`] contract).
#[target_feature(enable = "avx2")]
pub unsafe fn quantize_row(row: &[f32], delta: f32, qm: f32, out: &mut [i8]) {
    debug_assert_eq!(row.len(), out.len());
    let vd = _mm256_set1_ps(delta);
    let vqm = _mm256_set1_ps(qm);
    let vnqm = _mm256_set1_ps(-qm);
    let half = _mm256_set1_ps(0.5);
    let one = _mm256_set1_ps(1.0);
    let signmask = _mm256_set1_ps(-0.0);
    let absmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF));
    let mut lanes = [0.0f32; 8];
    let mut rows_it = row.chunks_exact(8);
    let mut out_it = out.chunks_exact_mut(8);
    for (chunk, ochunk) in (&mut rows_it).zip(&mut out_it) {
        let q = _mm256_div_ps(_mm256_loadu_ps(chunk.as_ptr()), vd);
        let re = _mm256_round_ps::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(q);
        let diff = _mm256_sub_ps(q, re); // exact (Sterbenz) whenever a tie is possible
        // tie lanes where round-to-even chose the toward-zero side:
        // |diff| == 0.5 and diff's sign bit agrees with q's
        let tie = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_EQ_OQ>(
            _mm256_and_ps(diff, absmask),
            half,
        ));
        let toward_zero = _mm256_cmpeq_epi32(
            _mm256_castps_si256(_mm256_and_ps(_mm256_xor_ps(diff, q), signmask)),
            _mm256_setzero_si256(),
        );
        let step = _mm256_or_ps(one, _mm256_and_ps(q, signmask)); // copysign(1.0, q)
        let adj = _mm256_and_ps(_mm256_castsi256_ps(_mm256_and_si256(tie, toward_zero)), step);
        let r = _mm256_add_ps(re, adj);
        let clamped = _mm256_min_ps(_mm256_max_ps(r, vnqm), vqm);
        _mm256_storeu_ps(lanes.as_mut_ptr(), clamped);
        // the f32 -> i8 conversion itself stays scalar: the values are
        // already clamped integers, so `as` is exact and cheap
        for (o, &v) in ochunk.iter_mut().zip(&lanes) {
            *o = v as i8;
        }
    }
    for (o, &v) in out_it.into_remainder().iter_mut().zip(rows_it.remainder()) {
        *o = (v / delta).round().clamp(-qm, qm) as i8;
    }
}
