//! Reusable per-worker scratch buffers.
//!
//! The fused analyze pass needs roughly ten intermediate buffers per
//! request (smoothed/rotated copies of X and W, quantization residuals,
//! the error accumulator).  Allocating them per request puts the
//! allocator on the serving hot path; a [`Workspace`] is a small
//! checkout/checkin pool of `Vec<f32>` owned by each worker, so
//! steady-state serving reuses the same capacity for every
//! matrix-sized intermediate, request after request (the remaining
//! per-request allocations are the O(rows + cols) scale vectors).
//!
//! Checkout is best-fit by capacity; checkin caps the pool size so a
//! one-off giant request cannot pin unbounded memory, and
//! [`Workspace::trim`] lets a long-lived owner (the serving batch
//! executor, between batches) shrink retained capacity back below a
//! steady-state budget after a burst.  The counters
//! ([`Workspace::stats`]) let tests pin the "no allocation in steady
//! state" claim.
//!
//! Besides the f32 pool, the workspace keeps typed side pools for the
//! integer execution path ([`crate::kernels::igemm`]): `i8` code
//! buffers (quantized activation rows, unpacked i4 weights) and `i32`
//! GEMM accumulators, with the same best-fit/bounded semantics and the
//! shared byte ceiling.

use crate::tensor::Matrix;

/// Most buffers retained for reuse (per typed pool); extra checkins are
/// simply dropped.
const MAX_POOLED: usize = 32;

/// Byte ceiling on retained capacity across all typed pools: a one-off
/// giant request must not pin hundreds of MB in a long-lived worker
/// once traffic shrinks.
const MAX_POOLED_BYTES: usize = 64 << 20;

/// Checkout/checkin pool of reusable `f32` buffers (plus typed `i8` /
/// `i32` side pools for the integer kernels).
///
/// ```
/// use smoothrot::kernels::workspace::Workspace;
/// let mut ws = Workspace::new();
/// let buf = ws.take(128);          // first take allocates
/// ws.give(buf);
/// let buf = ws.take(64);           // second take reuses the capacity
/// assert_eq!(buf.len(), 64);
/// let (reuses, allocs) = ws.stats();
/// assert_eq!((reuses, allocs), (1, 1));
/// ```
#[derive(Debug, Default)]
pub struct Workspace {
    pool: Vec<Vec<f32>>,
    pool_i8: Vec<Vec<i8>>,
    pool_i32: Vec<Vec<i32>>,
    /// Total capacity currently parked across all pools, in bytes.
    pooled_bytes: usize,
    reuses: u64,
    allocs: u64,
}

/// Best-fit checkout shared by every typed pool: pop the
/// smallest-capacity pooled buffer that fits, allocating only when none
/// does.  Returned buffers are zero-filled to exactly `len`.
fn take_pooled<T: Clone + Default>(
    pool: &mut Vec<Vec<T>>,
    pooled_bytes: &mut usize,
    reuses: &mut u64,
    allocs: &mut u64,
    len: usize,
) -> Vec<T> {
    let mut best: Option<(usize, usize)> = None; // (index, capacity)
    for (i, b) in pool.iter().enumerate() {
        let cap = b.capacity();
        let better = match best {
            None => true,
            Some((_, bc)) => cap < bc,
        };
        if cap >= len && better {
            best = Some((i, cap));
        }
    }
    match best {
        Some((i, cap)) => {
            *reuses += 1;
            *pooled_bytes -= cap * std::mem::size_of::<T>();
            let mut b = pool.swap_remove(i);
            b.clear();
            b.resize(len, T::default());
            b
        }
        None => {
            *allocs += 1;
            vec![T::default(); len]
        }
    }
}

/// Checkin shared by every typed pool: retain the capacity under the
/// count and byte ceilings, drop it otherwise.
fn give_pooled<T>(pool: &mut Vec<Vec<T>>, pooled_bytes: &mut usize, buf: Vec<T>) {
    let bytes = buf.capacity() * std::mem::size_of::<T>();
    if bytes > 0 && pool.len() < MAX_POOLED && *pooled_bytes + bytes <= MAX_POOLED_BYTES {
        *pooled_bytes += bytes;
        pool.push(buf);
    }
}

impl Workspace {
    /// Empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// A zero-filled buffer of exactly `len` elements.  Pops the
    /// best-fitting pooled buffer when one has enough capacity,
    /// allocating only otherwise.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        take_pooled(&mut self.pool, &mut self.pooled_bytes, &mut self.reuses, &mut self.allocs, len)
    }

    /// A buffer pre-filled with a copy of `src`.
    pub fn take_copy(&mut self, src: &[f32]) -> Vec<f32> {
        let mut b = self.take(src.len());
        b.copy_from_slice(src);
        b
    }

    /// Matrix-shaped checkout (zero-filled).
    pub fn take_matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take(rows * cols))
    }

    /// Matrix-shaped checkout holding a copy of `src`.
    pub fn take_matrix_copy(&mut self, src: &Matrix) -> Matrix {
        let (r, c) = src.shape();
        Matrix::from_vec(r, c, self.take_copy(src.as_slice()))
    }

    /// Return a buffer's capacity to the pool for reuse.  Checkins
    /// beyond the count or byte ceilings are dropped on the floor, so
    /// retained memory is bounded regardless of peak request size.
    pub fn give(&mut self, buf: Vec<f32>) {
        give_pooled(&mut self.pool, &mut self.pooled_bytes, buf);
    }

    /// [`Workspace::give`] for a matrix checkout.
    pub fn give_matrix(&mut self, m: Matrix) {
        self.give(m.into_vec());
    }

    /// A zero-filled `i8` buffer of exactly `len` elements — the
    /// integer-path twin of [`Workspace::take`] (quantized activation
    /// codes, unpacked i4 weights).
    pub fn take_i8(&mut self, len: usize) -> Vec<i8> {
        take_pooled(
            &mut self.pool_i8,
            &mut self.pooled_bytes,
            &mut self.reuses,
            &mut self.allocs,
            len,
        )
    }

    /// Return an `i8` buffer's capacity to its pool, under the same
    /// count and byte ceilings as [`Workspace::give`].
    pub fn give_i8(&mut self, buf: Vec<i8>) {
        give_pooled(&mut self.pool_i8, &mut self.pooled_bytes, buf);
    }

    /// A zero-filled `i32` buffer of exactly `len` elements — the
    /// integer GEMM's accumulator checkout.
    pub fn take_i32(&mut self, len: usize) -> Vec<i32> {
        take_pooled(
            &mut self.pool_i32,
            &mut self.pooled_bytes,
            &mut self.reuses,
            &mut self.allocs,
            len,
        )
    }

    /// Return an `i32` buffer's capacity to its pool, under the same
    /// count and byte ceilings as [`Workspace::give`].
    pub fn give_i32(&mut self, buf: Vec<i32>) {
        give_pooled(&mut self.pool_i32, &mut self.pooled_bytes, buf);
    }

    /// Release parked capacity until at most `max_bytes` remain across
    /// all typed pools, dropping the **largest** buffers first so the
    /// small steady-state buffers survive.
    ///
    /// Without this, the pools converge to the *high-water* request
    /// size: one giant request leaves giant buffers parked for the
    /// worker's lifetime.  The serving batch executor calls `trim`
    /// between batches with its steady-state budget
    /// ([`crate::serve::NativeBatchExecutor::TRIM_BYTES`]), so a burst
    /// is released while ordinary traffic stays allocation-free (the
    /// buffers it needs fit under the budget and are never dropped).
    pub fn trim(&mut self, max_bytes: usize) {
        while self.pooled_bytes > max_bytes {
            let cands = [
                Self::largest_bytes(&self.pool),
                Self::largest_bytes(&self.pool_i8),
                Self::largest_bytes(&self.pool_i32),
            ];
            let best = cands
                .into_iter()
                .enumerate()
                .filter_map(|(which, c)| c.map(|(idx, bytes)| (which, idx, bytes)))
                .max_by_key(|&(_, _, bytes)| bytes);
            let Some((which, idx, bytes)) = best else { break };
            if bytes == 0 {
                break;
            }
            match which {
                0 => drop(self.pool.swap_remove(idx)),
                1 => drop(self.pool_i8.swap_remove(idx)),
                _ => drop(self.pool_i32.swap_remove(idx)),
            }
            self.pooled_bytes -= bytes;
        }
    }

    /// `(index, capacity bytes)` of the largest buffer parked in `pool`.
    fn largest_bytes<T>(pool: &[Vec<T>]) -> Option<(usize, usize)> {
        pool.iter()
            .enumerate()
            .map(|(i, b)| (i, b.capacity() * std::mem::size_of::<T>()))
            .max_by_key(|&(_, bytes)| bytes)
    }

    /// Total capacity currently parked across all typed pools, in bytes.
    pub fn pooled_bytes(&self) -> usize {
        self.pooled_bytes
    }

    /// `(reused, freshly allocated)` checkout counters since creation.
    pub fn stats(&self) -> (u64, u64) {
        (self.reuses, self.allocs)
    }

    /// Buffers currently parked in the f32 pool.
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zero_filled_and_sized() {
        let mut ws = Workspace::new();
        let mut b = ws.take(10);
        assert_eq!(b.len(), 10);
        assert!(b.iter().all(|&v| v == 0.0));
        b[3] = 7.0;
        ws.give(b);
        // the dirtied buffer comes back zeroed
        let b2 = ws.take(10);
        assert!(b2.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient_capacity() {
        let mut ws = Workspace::new();
        let small = ws.take(8);
        let big = ws.take(1024);
        ws.give(big);
        ws.give(small);
        // a request for 8 must not burn the 1024 buffer
        let got = ws.take(8);
        assert!(got.capacity() < 1024);
        assert_eq!(ws.pooled(), 1);
    }

    #[test]
    fn steady_state_allocates_nothing() {
        let mut ws = Workspace::new();
        let sizes = [64usize, 32, 128, 64];
        for &s in &sizes {
            let b = ws.take(s);
            ws.give(b);
        }
        let (_, allocs_warm) = ws.stats();
        for _ in 0..5 {
            for &s in &sizes {
                let b = ws.take(s);
                ws.give(b);
            }
        }
        let (reuses, allocs) = ws.stats();
        assert_eq!(allocs, allocs_warm, "steady state must not allocate");
        assert!(reuses >= 20);
    }

    #[test]
    fn matrix_roundtrip() {
        let mut ws = Workspace::new();
        let m = ws.take_matrix(3, 4);
        assert_eq!(m.shape(), (3, 4));
        ws.give_matrix(m);
        let src = Matrix::from_fn(2, 2, |i, j| (i * 2 + j) as f32);
        let copy = ws.take_matrix_copy(&src);
        assert_eq!(copy.as_slice(), src.as_slice());
    }

    #[test]
    fn pool_is_bounded() {
        let mut ws = Workspace::new();
        for _ in 0..2 * MAX_POOLED {
            let b = vec![0.0f32; 4];
            ws.give(b);
        }
        assert!(ws.pooled() <= MAX_POOLED);
    }

    #[test]
    fn typed_pools_reuse_and_zero_fill() {
        let mut ws = Workspace::new();
        let mut a = ws.take_i8(16);
        a[0] = 7;
        ws.give_i8(a);
        let a2 = ws.take_i8(8);
        assert_eq!(a2.len(), 8);
        assert!(a2.iter().all(|&v| v == 0), "recycled i8 buffer must come back zeroed");
        let mut b = ws.take_i32(16);
        b[3] = -5;
        ws.give_i32(b);
        let b2 = ws.take_i32(16);
        assert!(b2.iter().all(|&v| v == 0), "recycled i32 buffer must come back zeroed");
        let (reuses, allocs) = ws.stats();
        assert_eq!((reuses, allocs), (2, 2));
        // typed pools are independent of the f32 pool count
        assert_eq!(ws.pooled(), 0);
    }

    #[test]
    fn trim_drops_largest_first_and_respects_the_budget() {
        let mut ws = Workspace::new();
        // park a mix of sizes across the typed pools
        ws.give(vec![0.0f32; 1 << 16]); // 256 KiB — the burst buffer
        ws.give(vec![0.0f32; 64]);
        ws.give_i8(vec![0i8; 128]);
        ws.give_i32(vec![0i32; 64]);
        let small_bytes = 64 * 4 + 128 + 64 * 4;
        ws.trim(small_bytes);
        // the giant f32 buffer is gone, every small buffer survived
        assert_eq!(ws.pooled_bytes(), small_bytes);
        assert_eq!(ws.pooled(), 1, "small f32 buffer retained");
        // a take at the small size still reuses (no allocation)
        let (_, allocs_before) = ws.stats();
        let b = ws.take(64);
        let (_, allocs_after) = ws.stats();
        assert_eq!(allocs_after, allocs_before, "trim must not evict steady-state sizes");
        ws.give(b);
        // trimming to zero empties everything
        ws.trim(0);
        assert_eq!(ws.pooled_bytes(), 0);
        assert_eq!(ws.pooled(), 0);
        // idempotent on an empty pool
        ws.trim(0);
        assert_eq!(ws.pooled_bytes(), 0);
    }

    #[test]
    fn steady_state_with_trim_between_batches_allocates_nothing() {
        // the serving pattern: one giant burst, then ordinary batches
        // with a trim after each — the burst is released, the ordinary
        // sizes keep reusing
        let mut ws = Workspace::new();
        let budget = 64 * 1024usize; // bytes
        let giant = ws.take(1 << 20);
        ws.give(giant);
        ws.trim(budget);
        assert!(ws.pooled_bytes() <= budget, "burst released");
        let sizes = [512usize, 256, 1024];
        for &s in &sizes {
            let b = ws.take(s);
            ws.give(b);
        }
        ws.trim(budget);
        let (_, warm) = ws.stats();
        for _ in 0..5 {
            for &s in &sizes {
                let b = ws.take(s);
                ws.give(b);
            }
            ws.trim(budget);
        }
        let (reuses, allocs) = ws.stats();
        assert_eq!(allocs, warm, "steady state with per-batch trim must not allocate");
        assert!(reuses > 0);
    }

    #[test]
    fn pool_byte_ceiling_drops_giant_checkins() {
        let mut ws = Workspace::new();
        let quarter = MAX_POOLED_BYTES / std::mem::size_of::<f32>() / 4;
        for _ in 0..8 {
            ws.give(vec![0.0f32; quarter]);
        }
        // at most 4 quarter-cap buffers fit under the byte ceiling
        assert!(ws.pooled() <= 4, "pooled {} buffers", ws.pooled());
        // taking one frees byte budget for the next checkin
        let b = ws.take(quarter);
        let before = ws.pooled();
        ws.give(b);
        assert_eq!(ws.pooled(), before + 1);
    }
}
