//! # smoothrot
//!
//! Reproduction of *"Turning LLM Activations Quantization-Friendly"*
//! (Czakó, Kertész, Szénási — 2025) as a three-layer Rust + JAX + Pallas
//! stack: Pallas kernels (L1) and the SynLlama capture model (L2) are
//! AOT-lowered to HLO text by `python/compile/aot.py`; this crate (L3)
//! loads the artifacts through the PJRT C API and owns everything on the
//! request path — scheduling, batching, metrics, reporting.
//!
//! The crate doubles as a *native mirror* of the math: [`quant`],
//! [`transforms`] and [`metrics`] re-implement Eq. 1–9 of the paper in
//! pure Rust, and the integration tests pin the PJRT path against the
//! native path so neither can drift.
//!
//! Two request paths sit on the shared math:
//!
//! * [`coordinator`] + [`pipeline`] — the *experiment* path: run the
//!   paper's fixed (layer × module) sweep once through a worker pool,
//! * [`serve`] — the *serving* path: a batched, multi-tenant core with
//!   per-tenant admission control, fair-share scheduling, a
//!   work-stealing worker pool and streaming p50/p95/p99-tracked
//!   responses (`smoothrot serve`, `examples/serve.rs`).
//!
//! The [`calib`] subsystem bridges the two: `smoothrot calibrate`
//! streams activations into mergeable channel statistics, searches a
//! per-layer transform plan, and persists it as a versioned artifact
//! that `smoothrot serve --plan` applies with zero per-request
//! transform search ("calibrate once, serve many").
//!
//! PJRT execution (the `xla` bindings) is optional: build with the
//! `pjrt` cargo feature for the AOT hot path, or without it for the
//! fully self-contained native mirror (see README.md).
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`tensor`] | dense f32 matrix substrate (matmul, reductions, slicing) |
//! | [`rng`] | SplitMix64 / Xoshiro256++ deterministic PRNG |
//! | [`quant`] | RTN symmetric quantizer, layer-wise error (Eq. 1–2) |
//! | [`transforms`] | Hadamard construction + smoothing / rotation / smooth-rotation (Eq. 3–5) |
//! | [`outlier`] | massive-outlier token model and Eq. 6–9 predictions |
//! | [`metrics`] | channel magnitudes, quantization difficulty, kurtosis, Pearson, percentiles |
//! | [`synth`] | native activation generator mirroring SynLlama's profiles |
//! | [`qtensor`] | integer tensor substrate: i8 / bit-packed i4 codes + per-token/per-channel scales |
//! | [`kernels`] | fused multi-threaded kernel engine: row-parallel matmul, FWHT rotation, integer GEMM, single-pass analyze, workspace reuse |
//! | [`calib`] | calibration subsystem: streaming channel stats, plan search, versioned plan artifacts, serving-side plan registry |
//! | [`jsonio`] | minimal JSON value model + parser + writer |
//! | [`config`] | typed experiment configuration + file parser |
//! | [`cli`] | dependency-free argument parser |
//! | [`check`] | proptest-lite property-testing harness |
//! | [`faults`] | deterministic fault injection: named failpoints for chaos testing |
//! | [`runtime`] | PJRT client wrapper, artifact manifest, executable cache |
//! | [`coordinator`] | experiment scheduler: worker pool, bounded queue, backpressure |
//! | [`serve`] | batched multi-tenant serving core (admission, fair share, work stealing) |
//! | [`pipeline`] | high-level experiment drivers tying runtime + coordinator |
//! | [`policy`] | per-layer transform deployment recommendations (paper Sec. V) |
//! | [`report`] | figure/table emitters (CSV, ASCII charts, markdown) |
//! | [`telemetry`] | serving observability: typed metric registry, per-stage timers, live difficulty tracking, Prometheus/JSON exporters |
//! | [`bench_harness`] | criterion-lite timing harness used by `cargo bench` |

pub mod bench_harness;
pub mod calib;
pub mod check;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod faults;
pub mod jsonio;
pub mod kernels;
pub mod loadgen;
pub mod metrics;
pub mod outlier;
pub mod pipeline;
pub mod policy;
pub mod qtensor;
pub mod quant;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod synth;
pub mod telemetry;
pub mod tensor;
pub mod transforms;

/// Crate version string (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// The four transform modes, in the canonical artifact order.
pub const MODES: [&str; 4] = ["none", "smooth", "rotate", "smooth_rotate"];

/// The four recorded module kinds, in paper order.
pub const MODULES: [&str; 4] = ["k_proj", "o_proj", "gate_proj", "down_proj"];
