//! Open-loop load generator for the network front-end
//! (`smoothrot loadgen`).
//!
//! Open-loop means arrivals are *scheduled*, not paced by responses: a
//! Poisson process (exponential inter-arrival gaps from the repo's
//! seeded [`crate::rng::Rng`]) fixes every request's due time up
//! front, and sender threads fire at those times whether or not
//! earlier requests have completed.  This is the load shape that
//! exposes overload behavior — a closed-loop client slows down with
//! the server and never drives it past saturation, so shedding (429),
//! queue deadlines (504), and the connection cap (503) would all stay
//! untested.
//!
//! The generated stream mirrors [`crate::serve::synthetic_requests`]:
//! tenants drawn by [`crate::serve::skewed_tenant`], modules uniform,
//! layers uniform in `0..layers`, per-request activation seeds — so a
//! `--verify-plan` replay through the in-process executor must produce
//! bit-identical `errors_bits` for every request the server answered
//! 200 (the server's weights come from *its* stream seed; the replay
//! uses the same builder).
//!
//! The report is bench-harness-shaped: each phase (and the overall
//! run) serializes via [`Measurement::to_json_row`], so the perf
//! trajectory tooling parses `LOADGEN.json` and `BENCH_<n>.json`
//! identically, plus a client-side error taxonomy and p50/p95/p99.

use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::bench_harness::Measurement;
use crate::jsonio::{self, Json};
use crate::metrics::Percentiles;
use crate::rng::Rng;
use crate::serve::proto::{self, JobSpec};
use crate::serve::skewed_tenant;

/// One load phase: `rps` Poisson arrivals for `duration_ms`.
#[derive(Clone, Debug, PartialEq)]
pub struct Phase {
    pub name: String,
    pub duration_ms: u64,
    pub rps: f64,
}

/// Parse `name:duration_ms:rps[,name:duration_ms:rps...]`, e.g.
/// `warm:500:20,overload:2000:400`.
pub fn parse_phases(spec: &str) -> Result<Vec<Phase>, String> {
    let mut phases = Vec::new();
    for part in spec.split(',').filter(|s| !s.trim().is_empty()) {
        let fields: Vec<&str> = part.trim().split(':').collect();
        if fields.len() != 3 {
            return Err(format!(
                "phase {part:?}: want name:duration_ms:rps (e.g. steady:2000:50)"
            ));
        }
        let name = fields[0].to_string();
        if name.is_empty() {
            return Err(format!("phase {part:?}: empty name"));
        }
        let duration_ms: u64 =
            fields[1].parse().map_err(|e| format!("phase {part:?}: duration: {e}"))?;
        let rps: f64 = fields[2].parse().map_err(|e| format!("phase {part:?}: rps: {e}"))?;
        if duration_ms == 0 || !(rps > 0.0) || !rps.is_finite() {
            return Err(format!("phase {part:?}: duration and rps must be positive"));
        }
        phases.push(Phase { name, duration_ms, rps });
    }
    if phases.is_empty() {
        return Err("no phases (want name:duration_ms:rps[,...])".to_string());
    }
    Ok(phases)
}

/// One scheduled request: fire at `due` (µs from run start).
#[derive(Clone, Debug)]
pub struct Arrival {
    pub due_micros: u64,
    pub phase: usize,
    pub spec: JobSpec,
}

/// Generator knobs.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Target `host:port`.
    pub target: String,
    pub phases: Vec<Phase>,
    /// Tenant universe (skewed: tenant 0 gets ~40%).
    pub tenants: usize,
    /// Layers drawn uniformly from `0..layers`.
    pub layers: usize,
    /// Token rows per request.
    pub rows: usize,
    /// Schedule seed (arrival times, tenant/module/layer draws, and the
    /// per-request activation seeds derived from it).
    pub seed: u64,
    /// Sender threads; open-loop fidelity needs enough to cover the
    /// peak in-flight count (late sends are still sent and counted).
    pub concurrency: usize,
    /// Per-request socket timeout.
    pub timeout: Duration,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            target: "127.0.0.1:7433".to_string(),
            phases: vec![Phase { name: "steady".to_string(), duration_ms: 2_000, rps: 50.0 }],
            tenants: 4,
            layers: 4,
            rows: 8,
            seed: 1,
            concurrency: 8,
            timeout: Duration::from_millis(10_000),
        }
    }
}

/// Build the full deterministic arrival schedule.  Exponential gaps
/// `-ln(1-u)/rps` make each phase a Poisson process; the spec draws
/// reproduce the [`crate::serve::synthetic_requests`] distribution
/// with per-request seeds `seed + 1000 + i`.
pub fn build_schedule(cfg: &LoadgenConfig) -> Vec<Arrival> {
    let mut rng = Rng::new(cfg.seed);
    let mut schedule = Vec::new();
    let mut phase_start = 0u64;
    let mut i = 0u64;
    for (p, phase) in cfg.phases.iter().enumerate() {
        let phase_end = phase_start + phase.duration_ms * 1_000;
        let mut t = phase_start as f64;
        loop {
            let gap_secs = -(1.0 - rng.f64()).ln() / phase.rps;
            t += gap_secs * 1e6;
            if t >= phase_end as f64 {
                break;
            }
            let tenant = skewed_tenant(&mut rng, cfg.tenants);
            let module = crate::MODULES[rng.below(crate::MODULES.len())].to_string();
            let layer = rng.below(cfg.layers.max(1));
            let model = crate::config::ModelConfig::default();
            schedule.push(Arrival {
                due_micros: t as u64,
                phase: p,
                spec: JobSpec {
                    id: i,
                    tenant,
                    module,
                    layer,
                    rows: cfg.rows,
                    seed: cfg.seed.wrapping_add(1_000 + i),
                    bits: model.bits,
                    alpha: model.alpha,
                },
            });
            i += 1;
        }
        phase_start = phase_end;
    }
    schedule
}

/// Client-side outcome taxonomy.  Stable keys — CI greps these.
pub const TAXONOMY: [&str; 9] = [
    "ok",
    "http_400",
    "http_429",
    "http_500",
    "http_503",
    "http_504",
    "http_other",
    "conn_error",
    "timeout",
];

/// A request the server answered 200 with a clean result line —
/// retained for the bit-identity replay.
#[derive(Clone, Debug)]
pub struct OkSample {
    pub spec: JobSpec,
    /// `errors_bits` hex strings from the result line (exact IEEE-754).
    pub errors_bits: Vec<String>,
}

struct Attempt {
    phase: usize,
    outcome: &'static str,
    latency_micros: u64,
    ok: Option<OkSample>,
    /// `Retry-After` seconds when the server answered 429 with one.
    retry_after_secs: Option<u64>,
}

/// Aggregated client-side results.
pub struct LoadReport {
    pub cfg: LoadgenConfig,
    pub sent: u64,
    pub taxonomy: BTreeMap<&'static str, u64>,
    pub per_phase: Vec<Measurement>,
    pub overall: Option<Measurement>,
    pub percentiles: Percentiles,
    pub ok_samples: Vec<OkSample>,
    /// Smallest positive `Retry-After` observed on a 429 (None when no
    /// 429 carried one) — the overload smoke asserts this is ≥ 1.
    pub min_retry_after_secs: Option<u64>,
    /// Set by [`LoadReport::verify`].
    pub verify_mismatches: Option<u64>,
}

/// Fire one request and classify the outcome.
fn send_one(cfg: &LoadgenConfig, arrival: &Arrival) -> Attempt {
    let t0 = Instant::now();
    let fail = |outcome: &'static str, t0: Instant, phase: usize| Attempt {
        phase,
        outcome,
        latency_micros: t0.elapsed().as_micros() as u64,
        ok: None,
        retry_after_secs: None,
    };
    let stream = match TcpStream::connect(&cfg.target) {
        Ok(s) => s,
        Err(_) => return fail("conn_error", t0, arrival.phase),
    };
    let _ = stream.set_read_timeout(Some(cfg.timeout));
    let _ = stream.set_write_timeout(Some(cfg.timeout));
    let _ = stream.set_nodelay(true);
    let body = arrival.spec.to_json().to_string_compact();
    let mut w = BufWriter::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return fail("conn_error", t0, arrival.phase),
    });
    if proto::write_request(&mut w, "POST", "/analyze", body.as_bytes()).is_err()
        || w.flush().is_err()
    {
        return fail("conn_error", t0, arrival.phase);
    }
    let resp = match proto::read_response(&mut BufReader::new(stream)) {
        Ok(r) => r,
        Err(proto::ProtoError::Timeout) => return fail("timeout", t0, arrival.phase),
        Err(_) => return fail("conn_error", t0, arrival.phase),
    };
    let latency_micros = t0.elapsed().as_micros() as u64;
    let retry_after_secs = resp.header("retry-after").and_then(|v| v.parse().ok());
    // a 200 envelope streams one result line whose own status is the
    // job's fate (200 clean, 504 deadline-evicted, 500 exec error)
    let (outcome, ok) = match resp.status {
        200 => match parse_result_line(&resp.body) {
            Some((200, bits)) => (
                "ok",
                Some(OkSample { spec: arrival.spec.clone(), errors_bits: bits }),
            ),
            Some((504, _)) => ("http_504", None),
            Some((500, _)) | None => ("http_500", None),
            Some((_, _)) => ("http_other", None),
        },
        400 | 404 | 405 | 408 | 411 | 413 | 431 => ("http_400", None),
        429 => ("http_429", None),
        500 => ("http_500", None),
        503 => ("http_503", None),
        504 => ("http_504", None),
        _ => ("http_other", None),
    };
    Attempt { phase: arrival.phase, outcome, latency_micros, ok, retry_after_secs }
}

/// First NDJSON result line → `(per-job status, errors_bits)`.
fn parse_result_line(body: &[u8]) -> Option<(u64, Vec<String>)> {
    let text = std::str::from_utf8(body).ok()?;
    let line = jsonio::parse(text.lines().next()?).ok()?;
    let status = line.get("status")?.as_u64()?;
    let bits = match line.get("errors_bits").and_then(Json::as_arr) {
        Some(arr) => arr.iter().filter_map(|j| j.as_str().map(str::to_string)).collect(),
        None => Vec::new(),
    };
    Some((status, bits))
}

/// Run the schedule against the target.  Sender threads pull arrivals
/// from a shared index, sleep until each one's due time, and fire —
/// open loop: a slow server makes requests late (never skipped), and
/// the lateness shows up as client-side latency.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport, String> {
    let schedule = Arc::new(build_schedule(cfg));
    if schedule.is_empty() {
        return Err("schedule is empty (rps too low for the phase durations?)".to_string());
    }
    let next = Arc::new(AtomicUsize::new(0));
    let attempts: Arc<Mutex<Vec<Attempt>>> =
        Arc::new(Mutex::new(Vec::with_capacity(schedule.len())));
    let start = Instant::now();
    let mut senders = Vec::new();
    for _ in 0..cfg.concurrency.max(1) {
        let schedule = Arc::clone(&schedule);
        let next = Arc::clone(&next);
        let attempts = Arc::clone(&attempts);
        let cfg = cfg.clone();
        senders.push(std::thread::spawn(move || {
            let mut local = Vec::new();
            loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                let Some(arrival) = schedule.get(i) else { break };
                let due = Duration::from_micros(arrival.due_micros);
                let elapsed = start.elapsed();
                if due > elapsed {
                    std::thread::sleep(due - elapsed);
                }
                local.push(send_one(&cfg, arrival));
            }
            attempts.lock().unwrap_or_else(|p| p.into_inner()).extend(local);
        }));
    }
    for h in senders {
        let _ = h.join();
    }
    let attempts = match Arc::try_unwrap(attempts) {
        Ok(m) => m.into_inner().unwrap_or_else(|p| p.into_inner()),
        Err(_) => return Err("sender thread leaked its results".to_string()),
    };

    let mut taxonomy: BTreeMap<&'static str, u64> = TAXONOMY.iter().map(|&k| (k, 0)).collect();
    let mut latencies: Vec<u64> = Vec::with_capacity(attempts.len());
    let mut per_phase_samples: Vec<Vec<Duration>> = vec![Vec::new(); cfg.phases.len()];
    let mut ok_samples = Vec::new();
    let mut min_retry_after_secs: Option<u64> = None;
    for a in attempts {
        *taxonomy.entry(a.outcome).or_insert(0) += 1;
        latencies.push(a.latency_micros);
        per_phase_samples[a.phase].push(Duration::from_micros(a.latency_micros));
        if let Some(s) = a.ok {
            ok_samples.push(s);
        }
        if a.outcome == "http_429" {
            if let Some(secs) = a.retry_after_secs {
                min_retry_after_secs =
                    Some(min_retry_after_secs.map_or(secs, |m: u64| m.min(secs)));
            }
        }
    }
    let sent = latencies.len() as u64;
    let per_phase: Vec<Measurement> = cfg
        .phases
        .iter()
        .zip(per_phase_samples)
        .filter(|(_, samples)| !samples.is_empty())
        .map(|(phase, samples)| Measurement {
            name: format!("loadgen/{}", phase.name),
            samples,
            items_per_iter: Some(1.0),
        })
        .collect();
    let overall = (!latencies.is_empty()).then(|| Measurement {
        name: "loadgen/overall".to_string(),
        samples: latencies.iter().map(|&us| Duration::from_micros(us)).collect(),
        items_per_iter: Some(1.0),
    });
    let percentiles = Percentiles::of_micros(&latencies);
    Ok(LoadReport {
        cfg: cfg.clone(),
        sent,
        taxonomy,
        per_phase,
        overall,
        percentiles,
        ok_samples,
        min_retry_after_secs,
        verify_mismatches: None,
    })
}

impl LoadReport {
    /// Replay every 200-OK request through `exec` (an in-process
    /// executor over the same job builder the server uses) and count
    /// `errors_bits` mismatches.  Zero is the wire-tier bit-identity
    /// contract: the network front-end adds transport, not arithmetic.
    pub fn verify(
        &mut self,
        builder: &crate::serve::net::JobBuilder,
        mut exec: impl FnMut(&crate::coordinator::Job) -> Result<crate::serve::AnalyzeOut, String>,
    ) -> u64 {
        let mut mismatches = 0u64;
        for sample in &self.ok_samples {
            let replayed = builder(&sample.spec, sample.spec.id)
                .map_err(|e| e.to_string())
                .and_then(|(_, job)| exec(&job));
            let bits: Vec<String> = match &replayed {
                Ok(out) => out.errors.iter().map(|&e| proto::f64_bits_hex(e)).collect(),
                Err(_) => Vec::new(),
            };
            if bits.is_empty() || bits != sample.errors_bits {
                mismatches += 1;
            }
        }
        self.verify_mismatches = Some(mismatches);
        mismatches
    }

    /// The report artifact: bench-harness-shaped rows plus the
    /// client-side taxonomy and percentiles.
    pub fn to_json(&self) -> Json {
        let mut results: Vec<Json> =
            self.per_phase.iter().map(Measurement::to_json_row).collect();
        if let Some(overall) = &self.overall {
            results.push(overall.to_json_row());
        }
        let taxonomy: Vec<(&str, Json)> =
            self.taxonomy.iter().map(|(&k, &v)| (k, Json::Num(v as f64))).collect();
        jsonio::obj(vec![
            ("kind", Json::Str("smoothrot-loadgen".to_string())),
            ("bench", Json::Str("loadgen".to_string())),
            ("target", Json::Str(self.cfg.target.clone())),
            ("seed", Json::Num(self.cfg.seed as f64)),
            ("sent", Json::Num(self.sent as f64)),
            ("scenarios", Json::Num(results.len() as f64)),
            ("results", Json::Arr(results)),
            ("taxonomy", jsonio::obj(taxonomy)),
            ("p50_us", Json::Num(self.percentiles.p50)),
            ("p95_us", Json::Num(self.percentiles.p95)),
            ("p99_us", Json::Num(self.percentiles.p99)),
            (
                "min_retry_after_secs",
                match self.min_retry_after_secs {
                    Some(s) => Json::Num(s as f64),
                    None => Json::Null,
                },
            ),
            (
                "verify_mismatches",
                match self.verify_mismatches {
                    Some(n) => Json::Num(n as f64),
                    None => Json::Null,
                },
            ),
        ])
    }
}

/// Ask the target to drain (`POST /admin/drain`) and wait until it
/// stops answering (bounded by `deadline`).  Returns whether the
/// server was observed gone.
pub fn drain_target(target: &str, deadline: Duration) -> bool {
    if let Ok(stream) = TcpStream::connect(target) {
        let _ = stream.set_read_timeout(Some(Duration::from_millis(2_000)));
        let mut w = BufWriter::new(match stream.try_clone() {
            Ok(s) => s,
            Err(_) => return false,
        });
        if proto::write_request(&mut w, "POST", "/admin/drain", b"").is_ok() {
            let _ = w.flush();
            let _ = proto::read_response(&mut BufReader::new(stream));
        }
    }
    let t0 = Instant::now();
    while t0.elapsed() < deadline {
        match TcpStream::connect(target) {
            Ok(_) => std::thread::sleep(Duration::from_millis(50)),
            Err(_) => return true,
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_grammar_round_trip() {
        let phases = parse_phases("warm:500:20, overload:2000:400").unwrap();
        assert_eq!(phases.len(), 2);
        assert_eq!(phases[0], Phase { name: "warm".to_string(), duration_ms: 500, rps: 20.0 });
        assert_eq!(phases[1].rps, 400.0);
        assert!(parse_phases("").is_err());
        assert!(parse_phases("bad:500").is_err());
        assert!(parse_phases("x:0:10").is_err());
        assert!(parse_phases("x:10:0").is_err());
        assert!(parse_phases("x:10:-1").is_err());
    }

    #[test]
    fn schedule_is_deterministic_and_poisson_shaped() {
        let cfg = LoadgenConfig {
            phases: parse_phases("a:1000:100,b:500:200").unwrap(),
            seed: 7,
            ..LoadgenConfig::default()
        };
        let s1 = build_schedule(&cfg);
        let s2 = build_schedule(&cfg);
        assert_eq!(s1.len(), s2.len());
        assert!(!s1.is_empty());
        for (a, b) in s1.iter().zip(&s2) {
            assert_eq!(a.due_micros, b.due_micros);
            assert_eq!(a.spec.seed, b.spec.seed);
            assert_eq!(a.spec.module, b.spec.module);
        }
        // ~100 rps for 1s + ~200 rps for 0.5s ≈ 200 arrivals; Poisson
        // noise stays well inside ±50%
        assert!(s1.len() > 100 && s1.len() < 300, "got {}", s1.len());
        // due times are monotone and phase boundaries respected
        for w in s1.windows(2) {
            assert!(w[0].due_micros <= w[1].due_micros);
        }
        let a_max = s1.iter().filter(|a| a.phase == 0).map(|a| a.due_micros).max().unwrap();
        let b_min = s1.iter().filter(|a| a.phase == 1).map(|a| a.due_micros).min().unwrap();
        assert!(a_max < 1_000_000);
        assert!((1_000_000..1_500_000).contains(&b_min));
        // per-request seeds are unique
        let mut seeds: Vec<u64> = s1.iter().map(|a| a.spec.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), s1.len());
    }

    #[test]
    fn tenant_skew_matches_serve_stream() {
        let cfg = LoadgenConfig {
            phases: parse_phases("a:2000:500").unwrap(),
            tenants: 4,
            seed: 3,
            ..LoadgenConfig::default()
        };
        let s = build_schedule(&cfg);
        let t0 = s.iter().filter(|a| a.spec.tenant == 0).count();
        let share = t0 as f64 / s.len() as f64;
        // skewed_tenant gives tenant 0 a 40% + (60% / 3 × 0) share
        assert!((0.3..0.55).contains(&share), "tenant-0 share {share}");
    }

    #[test]
    fn report_json_has_taxonomy_present_at_zero() {
        let cfg = LoadgenConfig::default();
        let report = LoadReport {
            cfg: cfg.clone(),
            sent: 1,
            taxonomy: TAXONOMY.iter().map(|&k| (k, 0)).collect(),
            per_phase: Vec::new(),
            overall: Some(Measurement {
                name: "loadgen/overall".to_string(),
                samples: vec![Duration::from_micros(250)],
                items_per_iter: Some(1.0),
            }),
            percentiles: Percentiles::of_micros(&[250]),
            ok_samples: Vec::new(),
            min_retry_after_secs: None,
            verify_mismatches: None,
        };
        let json = report.to_json();
        for key in TAXONOMY {
            assert!(
                json.get("taxonomy").and_then(|t| t.get(key)).is_some(),
                "taxonomy key {key} missing"
            );
        }
        assert_eq!(json.get("kind").and_then(Json::as_str), Some("smoothrot-loadgen"));
        let rows = json.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].get("median_ns").is_some());
        // round-trips through the parser (the artifact is consumed by jq)
        let text = json.to_string_pretty();
        jsonio::parse(&text).unwrap();
    }
}
