//! `smoothrot` — leader binary: CLI over the L3 coordinator.
//!
//! ```text
//! smoothrot capture     run the SynLlama capture artifact, print stats
//! smoothrot analyze     full (layer × module) sweep -> figure reports
//! smoothrot figures     regenerate a specific paper figure (1..5)
//! smoothrot sweep-alpha Sec. IV-C migration-strength sweep (native)
//! smoothrot sweep-bits  bit-width ablation (native)
//! smoothrot selfcheck   PJRT output vs golden.json + native mirror
//! smoothrot calibrate   stream -> channel stats -> plan search -> plan file
//! smoothrot serve       batched multi-tenant serving core demo
//!                       (--plan <file> serves a calibration plan with
//!                       zero per-request transform search +
//!                       content-hash-poll hot reload; --runners N
//!                       shards the fleet into N work-stealing runners;
//!                       --listen ADDR serves HTTP/1.1 instead of the
//!                       synthetic stream)
//! smoothrot loadgen     open-loop Poisson load generator against a
//!                       serve --listen target (client-side p50/p95/p99
//!                       + error taxonomy, optional bit-identity replay)
//! ```

use std::io::Write as _;

use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};
use smoothrot::cli::{App, Command};
use smoothrot::coordinator::PoolConfig;
use smoothrot::pipeline::{self, Backend};
use smoothrot::report;
use smoothrot::runtime::Runtime;
use smoothrot::telemetry::{self, Telemetry};
use smoothrot::transforms::Mode;

/// Shared `--metrics-file` help text (every subcommand takes it).
const METRICS_FILE_HELP: &str = "write a telemetry snapshot at exit: schema-versioned JSON at \
     this path plus Prometheus text at the .prom sibling";

fn app() -> App {
    App {
        name: "smoothrot",
        about: "quantization-difficulty analysis & smooth-rotation transforms (paper reproduction)",
        commands: vec![
            Command::new("capture", "run the SynLlama capture artifact and print per-layer stats")
                .opt("artifacts", "artifacts directory", Some("artifacts"))
                .opt("metrics-file", METRICS_FILE_HELP, None),
            Command::new("analyze", "full layer x module sweep; writes figure reports")
                .opt("artifacts", "artifacts directory", Some("artifacts"))
                .opt("backend", "pjrt | native", Some("pjrt"))
                .opt("workers", "worker threads", Some("2"))
                .opt(
                    "threads",
                    "math threads per worker, 0 = all cores; keep workers x threads <= cores \
                     (native backend)",
                    Some("1"),
                )
                .opt("queue-cap", "bounded queue capacity", Some("64"))
                .opt("out", "report output directory", Some("reports"))
                .opt("metrics-file", METRICS_FILE_HELP, None),
            Command::new("figures", "regenerate one paper figure (1, 2, 3, 4 or 5)")
                .opt("artifacts", "artifacts directory", Some("artifacts"))
                .opt("fig", "figure number", Some("3"))
                .opt("layer", "layer override for figs 1/2/5", None)
                .opt("out", "report output directory", Some("reports"))
                .opt("metrics-file", METRICS_FILE_HELP, None),
            Command::new("sweep-alpha", "Sec. IV-C migration-strength sweep (native backend)")
                .opt("artifacts", "artifacts directory", Some("artifacts"))
                .opt("module", "module kind", Some("o_proj"))
                .opt("threads", "math threads, 0 = all cores", Some("0"))
                .opt("grid", "comma-separated alphas", Some("0.3,0.4,0.5,0.6,0.65,0.7,0.8,0.9"))
                .opt("metrics-file", METRICS_FILE_HELP, None),
            Command::new("sweep-bits", "bit-width ablation 2..8 (native backend)")
                .opt("artifacts", "artifacts directory", Some("artifacts"))
                .opt("threads", "math threads, 0 = all cores", Some("0"))
                .opt("grid", "comma-separated bit widths", Some("2,3,4,6,8"))
                .opt("metrics-file", METRICS_FILE_HELP, None),
            Command::new("selfcheck", "verify PJRT outputs against golden.json and the native mirror")
                .opt("artifacts", "artifacts directory", Some("artifacts"))
                .opt("rtol", "relative tolerance (golden was built by a newer XLA)", Some("5e-2"))
                .opt("metrics-file", METRICS_FILE_HELP, None),
            Command::new("recommend", "emit a per-layer transform deployment policy (paper Sec. V)")
                .opt("artifacts", "artifacts directory", Some("artifacts"))
                .opt("backend", "pjrt | native", Some("pjrt"))
                .opt("sr-margin", "min error ratio before adopting smooth-rotation", Some("1.25"))
                .opt("out", "policy JSON output path", Some("reports/policy.json"))
                .opt("metrics-file", METRICS_FILE_HELP, None),
            Command::new("calibrate", "stream synth activations -> channel stats -> plan search -> versioned plan file")
                .opt("out", "plan artifact output path", Some("reports/plan.json"))
                .opt("layers", "layers to calibrate per module", Some("8"))
                .opt("rows", "token rows per streamed batch", Some("32"))
                .opt("batches", "batches streamed per (module, layer)", Some("2"))
                .opt("shards", "parallel collector shards (merged deterministically)", Some("2"))
                .opt("sample-rows", "sample reservoir cap per cell, 0 = retain the full stream", Some("0"))
                .opt("seed", "synthetic stream seed", Some("2025"))
                .opt("alpha-grid", "comma-separated migration strengths to search", Some("0.5"))
                .opt("bits-grid", "comma-separated bit widths to emit entries for", Some("4"))
                .opt("sr-margin", "min error ratio before adopting smooth-rotation", Some("1.25"))
                .opt("threads", "math threads, 0 = all cores", Some("1"))
                .flag("selfcheck", "pin the plan against policy::recommend on the same workload")
                .flag("exec-check", "re-run each chosen entry through the real integer kernels and report executed vs predicted error")
                .opt("metrics-file", METRICS_FILE_HELP, None),
            Command::new("serve", "batched multi-tenant serving demo over the serving core")
                .opt("backend", "native | pjrt", Some("native"))
                .opt("artifacts", "artifacts directory (pjrt backend)", Some("artifacts"))
                .opt("plan", "calibration plan file: serve plan-driven (the calibrated transform and alpha override the request's) with content-hash-poll hot reload (native backend)", None)
                .opt("requests", "number of synthetic requests", Some("64"))
                .opt("tenants", "synthetic tenants (tenant 0 is the noisy neighbor)", Some("4"))
                .opt("layers", "layer range of synthetic requests (match the calibrated depth)", Some("32"))
                .opt("workers", "worker threads", Some("2"))
                .opt("threads", "math threads per worker, 0 = all cores (an even per-runner share under --runners) (native backend)", Some("1"))
                .opt("max-batch", "max jobs coalesced into one executor dispatch", Some("8"))
                .opt("queue-depth", "per-tenant admission queue capacity", Some("32"))
                .opt("rows", "token rows per synthetic request (native backend)", Some("32"))
                .opt("exec", "execution path on plan-covered cells: f32 (simulated qdq) | int8 (real integer GEMM over weights pre-quantized at plan load; needs --plan)", Some("f32"))
                .opt("kernel-backend", "integer microkernel backend: auto | scalar | avx2 | neon (auto honors SMOOTHROT_KERNEL, else detects; results are bit-identical across backends)", Some("auto"))
                .opt("runners", "sharded runner instances, each owning its executor, thread pool and workspace; 0 = one per core; replaces --workers (native backend)", None)
                .opt("shard-by", "shard key routing each batch to its owning runner: layer | tenant (--runners)", Some("layer"))
                .opt("trim-bytes", "workspace bytes retained across batches before trimming, 0 = never trim; overrides env SMOOTHROT_TRIM_BYTES (native backend)", None)
                .opt("metrics-file", METRICS_FILE_HELP, None)
                .opt("metrics-interval", "seconds between metrics-file rewrites while serving (0 = write only at exit; needs --metrics-file)", Some("0"))
                .opt("deadline-ms", "per-request queue deadline in milliseconds; requests still queued past it get an errored response at batch formation (0 = no deadline)", Some("0"))
                .opt("shed-queued", "shed new admissions with a retry-after hint once this many requests are queued (0 = never shed)", Some("0"))
                .opt("faults", "arm deterministic failpoints for chaos testing, e.g. 'serve.exec_panic=prob:0.05:42,plan.reload_corrupt=hit:2'; also honored from env SMOOTHROT_FAULTS", None)
                .opt("listen", "serve over HTTP/1.1 on this address (host:port; port 0 binds an ephemeral one) instead of the synthetic stream; clients drive the server (see loadgen) and graceful drain comes from SIGTERM/SIGINT or POST /admin/drain (native backend)", None)
                .opt("max-conns", "concurrent connection cap: over it new connections get an immediate 503 (with --listen)", Some("256"))
                .opt("conn-timeout-ms", "per-connection socket read/write deadline in milliseconds, the slow-loris bound (with --listen)", Some("5000"))
                .flag("drain", "gracefully drain after the last submission: stop admission, finish every in-flight batch, then collect")
                .flag("no-steal", "disable idle runners stealing surplus batches from the heaviest peer (--runners)")
                .flag("skew-layers", "skew the synthetic stream so ~half of all requests hit layer 0 (the sharding stress case; native backend)")
                .flag("reject", "reject instead of block when a tenant queue is full"),
            Command::new("loadgen", "open-loop load generator against a serve --listen target")
                .opt("target", "host:port of the serving front-end", Some("127.0.0.1:7433"))
                .opt("phases", "load phases, name:duration_ms:rps[,...] — e.g. 'warm:500:20,overload:2000:400' (Poisson arrivals per phase)", Some("steady:2000:50"))
                .opt("tenants", "tenant universe (tenant 0 is the noisy neighbor, ~40% of requests)", Some("4"))
                .opt("layers", "layers drawn uniformly from 0..N (match the served plan's depth)", Some("4"))
                .opt("rows", "token rows per request", Some("8"))
                .opt("seed", "schedule seed (arrival times, draws, and per-request activation seeds)", Some("1"))
                .opt("concurrency", "sender threads; enough to cover the peak in-flight count keeps the loop open", Some("8"))
                .opt("timeout-ms", "per-request socket timeout in milliseconds", Some("10000"))
                .opt("report", "write the loadgen report JSON (bench-harness-shaped rows + client-side taxonomy) to this path", None)
                .opt("verify-plan", "replay every 200-OK response through the in-process executor over this plan file and count errors_bits mismatches (0 = the wire added transport, not arithmetic)", None)
                .opt("verify-exec", "execution path for --verify-plan: f32 | int8 — must match the server's --exec", Some("f32"))
                .opt("stream-seed", "server weight stream seed; must match the serve side", Some("2025"))
                .flag("verify", "replay 200-OK responses through the plain in-process executor (no plan)")
                .flag("drain", "after the run, POST /admin/drain and wait for the server to exit"),
        ],
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let app = app();
    if args.is_empty() || args[0] == "--help" || args[0] == "-h" {
        print!("{}", app.usage());
        return;
    }
    let cmd_name = args[0].clone();
    let Some(cmd) = app.find(&cmd_name) else {
        eprintln!("unknown command {cmd_name:?}\n\n{}", app.usage());
        std::process::exit(2);
    };
    if args.iter().any(|a| a == "--help") {
        print!("{}", cmd.help());
        return;
    }
    let parsed = match cmd.parse(&args[1..]) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cmd.help());
            std::process::exit(2);
        }
    };
    // Deterministic fault injection: arm failpoints before any work
    // runs, from the environment (works for every subcommand) and from
    // `serve --faults`.  A malformed spec is a named error and a
    // nonzero exit, never a silent no-op — a typo'd chaos run must not
    // fake a green result.
    if let Err(e) = smoothrot::faults::arm_from_env() {
        eprintln!("error: SMOOTHROT_FAULTS: {e}");
        std::process::exit(1);
    }
    if let Some(spec) = parsed.get("faults") {
        match smoothrot::faults::arm(spec) {
            Ok(n) => eprintln!("faults: armed {n} failpoint(s)"),
            Err(e) => {
                eprintln!("error: --faults: {e}");
                std::process::exit(1);
            }
        }
    }
    // Every subcommand under --metrics-file gets one Telemetry
    // instance whose snapshot is dumped at exit; the command dispatch
    // runs under its sinks, so stage spans and difficulty observations
    // made on this thread are captured even outside `serve` (serving
    // worker threads install the sinks themselves via
    // Server::start_with_telemetry).
    let metrics_file = parsed.get("metrics-file").map(std::path::PathBuf::from);
    // Fail fast on an unwritable metrics target: discovering it only at
    // the exit dump would throw away the whole run's snapshot.
    if let Some(path) = &metrics_file {
        if path.is_dir() {
            eprintln!("error: --metrics-file {}: is a directory, need a file path", path.display());
            std::process::exit(1);
        }
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            if !dir.is_dir() {
                eprintln!(
                    "error: --metrics-file {}: parent directory {} does not exist",
                    path.display(),
                    dir.display()
                );
                std::process::exit(1);
            }
        }
    }
    let telemetry = metrics_file.as_ref().map(|_| Telemetry::new());
    let result = telemetry::scoped(telemetry.as_ref(), || match cmd_name.as_str() {
        "capture" => cmd_capture(&parsed),
        "analyze" => cmd_analyze(&parsed),
        "figures" => cmd_figures(&parsed),
        "sweep-alpha" => cmd_sweep_alpha(&parsed),
        "sweep-bits" => cmd_sweep_bits(&parsed),
        "selfcheck" => cmd_selfcheck(&parsed),
        "recommend" => cmd_recommend(&parsed),
        "calibrate" => cmd_calibrate(&parsed),
        "serve" => cmd_serve(&parsed, telemetry.as_ref()),
        "loadgen" => cmd_loadgen(&parsed),
        _ => unreachable!(),
    });
    // exit dump happens even when the command failed — a failed run's
    // partial counters are exactly what one wants to look at
    if let (Some(t), Some(path)) = (&telemetry, &metrics_file) {
        match smoothrot::telemetry::export::write_files(&t.snapshot(), path) {
            Ok(prom) => {
                eprintln!("telemetry: wrote {} and {}", path.display(), prom.display())
            }
            Err(e) => eprintln!("telemetry: writing {} failed: {e}", path.display()),
        }
    }
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_capture(p: &smoothrot::cli::Parsed) -> Result<()> {
    let rt = Runtime::new(p.get_or("artifacts", "artifacts"))?;
    let t0 = std::time::Instant::now();
    let cap = rt.capture()?;
    println!("capture executed in {:?}", t0.elapsed());
    for (name, stack) in [
        ("attn_in", &cap.attn_in),
        ("o_in", &cap.o_in),
        ("ffn_in", &cap.ffn_in),
        ("down_in", &cap.down_in),
    ] {
        let mut maxima = Vec::new();
        for l in 0..stack.layers() {
            maxima.push(stack.layer(l).abs_max() as f64);
        }
        let s = smoothrot::metrics::Summary::of(&maxima);
        println!(
            "{name:>8}: [L={} n={} c={}]  absmax per layer: min {:.1} mean {:.1} max {:.1}",
            stack.layers(),
            stack.rows(),
            stack.cols(),
            s.min,
            s.mean,
            s.max
        );
    }
    Ok(())
}

fn write_report(dir: &str, file: &str, content: &str) -> Result<()> {
    std::fs::create_dir_all(dir).with_context(|| format!("mkdir {dir}"))?;
    let path = format!("{dir}/{file}");
    std::fs::write(&path, content).with_context(|| format!("write {path}"))?;
    println!("wrote {path}");
    Ok(())
}

fn cmd_analyze(p: &smoothrot::cli::Parsed) -> Result<()> {
    let artifacts = p.get_or("artifacts", "artifacts");
    let backend = Backend::from_name(&p.get_or("backend", "pjrt"))?;
    let pool = PoolConfig {
        workers: p.get_usize("workers").map_err(|e| anyhow!(e))?.unwrap_or(2),
        queue_cap: p.get_usize("queue-cap").map_err(|e| anyhow!(e))?.unwrap_or(64),
        threads: p.get_usize("threads").map_err(|e| anyhow!(e))?.unwrap_or(1),
    };
    let out_dir = p.get_or("out", "reports");

    let t0 = std::time::Instant::now();
    let run = pipeline::run_full_experiment(&artifacts, pool, backend)?;
    println!(
        "analyze: {} jobs in {:?} ({} workers, backend {:?}, coordination overhead {:.1}%)",
        run.metrics.jobs,
        t0.elapsed(),
        pool.workers,
        backend,
        100.0 * run.metrics.overhead_fraction(pool.workers)
    );

    let rt = Runtime::new(&artifacts)?;
    let cfg = &rt.manifest().config;
    write_report(&out_dir, "fig3_layerwise.csv", &report::layerwise_csv(&run.grid, |o, _| o.errors[0]))?;
    write_report(&out_dir, "fig3.md", &report::fig3_report(&run.grid))?;
    write_report(&out_dir, "fig4.md", &report::fig4_report(&run.grid))?;
    write_report(
        &out_dir,
        "fig4_errors.csv",
        &report::layerwise_csv(&run.grid, |o, i| o.errors[i]),
    )?;
    let (corr, text) = report::correlation_report(&run.grid, &cfg.massive_layers, cfg.tail_layer);
    write_report(&out_dir, "correlation.md", &text)?;
    println!("{text}");
    println!(
        "down_proj massive-layer errors:\n{}",
        report::mode_layer_table(&run.grid, "down_proj", &cfg.massive_layers)
    );
    if corr < 0.9 {
        bail!("headline correlation {corr:.3} is suspiciously low — check artifacts");
    }
    Ok(())
}

fn cmd_figures(p: &smoothrot::cli::Parsed) -> Result<()> {
    let artifacts = p.get_or("artifacts", "artifacts");
    let fig = p.get_usize("fig").map_err(|e| anyhow!(e))?.unwrap_or(3);
    let out_dir = p.get_or("out", "reports");
    let rt = Runtime::new(&artifacts)?;
    let cfg = rt.manifest().config.clone();

    match fig {
        1 | 2 => {
            // Fig 1: k_proj layer 1; Fig 2: down_proj layer 30.
            let (module, default_layer): (&'static str, usize) =
                if fig == 1 { ("k_proj", 1) } else { ("down_proj", 30) };
            let layer = p.get_usize("layer").map_err(|e| anyhow!(e))?.unwrap_or(default_layer);
            let workload = pipeline::load_workload(&rt)?;
            let (x, w) = workload.pair(&rt, module, layer);
            let mut profiles = Vec::new();
            for mode in Mode::ALL {
                let (xh, _) = rt.transform(mode, &x, &w)?;
                profiles.push((mode, report::sorted_channel_magnitudes(&xh)));
            }
            let csv = report::magnitude_profile_csv(&profiles);
            write_report(&out_dir, &format!("fig{fig}_{module}_{layer}.csv"), &csv)?;
            for (mode, prof) in &profiles {
                println!(
                    "{:>14}: top channel magnitudes {:?}",
                    mode.name(),
                    prof.iter().take(5).map(|v| format!("{v:.1}")).collect::<Vec<_>>()
                );
            }
        }
        3 | 4 => {
            let run = pipeline::run_full_experiment(&artifacts, PoolConfig::default(), Backend::Pjrt)?;
            let text = if fig == 3 { report::fig3_report(&run.grid) } else { report::fig4_report(&run.grid) };
            write_report(&out_dir, &format!("fig{fig}.md"), &text)?;
            println!("{text}");
        }
        5 => {
            let layer = p.get_usize("layer").map_err(|e| anyhow!(e))?.unwrap_or(30);
            let workload = pipeline::load_workload(&rt)?;
            let (x, w) = workload.pair(&rt, "down_proj", layer);
            let mut curves = Vec::new();
            for mode in [Mode::Rotate, Mode::SmoothRotate] {
                let (xh, _) = rt.transform(mode, &x, &w)?;
                curves.push((mode, report::fig5_data(&xh, cfg.bits)));
            }
            write_report(&out_dir, &format!("fig5_down_proj_{layer}.csv"), &report::fig5_csv(&curves))?;
            println!("{}", report::fig5_report(&curves));
        }
        n => bail!("unknown figure {n} (want 1..5)"),
    }
    Ok(())
}

fn cmd_sweep_alpha(p: &smoothrot::cli::Parsed) -> Result<()> {
    let rt = Runtime::new(p.get_or("artifacts", "artifacts"))?;
    let module: &'static str = smoothrot::MODULES
        .into_iter()
        .find(|m| *m == p.get_or("module", "o_proj"))
        .context("unknown module")?;
    let grid: Vec<f64> = p
        .get_or("grid", "0.5")
        .split(',')
        .map(|s| s.trim().parse::<f64>().map_err(|_| anyhow!("bad alpha {s:?}")))
        .collect::<Result<_>>()?;
    let threads = p.get_usize("threads").map_err(|e| anyhow!(e))?.unwrap_or(0);
    let workload = pipeline::load_workload(&rt)?;
    let cfg = rt.manifest().config.clone();
    let sweep = pipeline::alpha_sweep(&rt, &workload, module, &grid, cfg.bits, threads)?;

    // baseline: untransformed total error
    let mut base_total = 0.0;
    for layer in 0..cfg.n_layers {
        let (x, w) = workload.pair(&rt, module, layer);
        base_total += smoothrot::quant::quant_error(&x, &w, cfg.bits);
    }
    println!("# alpha sweep on {module} (Sec. IV-C)\nuntransformed total error: {base_total:.3e}");
    let labels: Vec<String> = sweep.iter().map(|(a, _)| format!("alpha={a}")).collect();
    let totals: Vec<f64> = sweep.iter().map(|(_, errs)| errs.iter().sum()).collect();
    println!("{}", report::ascii_chart("smooth total error vs alpha", &labels, &totals, 40));
    let best = sweep
        .iter()
        .zip(&totals)
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|((a, _), t)| (*a, *t))
        .unwrap();
    println!("best alpha: {} (total {:.3e}; {} baseline)", best.0, best.1, if best.1 < base_total { "beats" } else { "does NOT beat" });
    Ok(())
}

fn cmd_sweep_bits(p: &smoothrot::cli::Parsed) -> Result<()> {
    let rt = Runtime::new(p.get_or("artifacts", "artifacts"))?;
    let grid: Vec<u32> =
        p.get_u32_list("grid").map_err(|e| anyhow!(e))?.unwrap_or_else(|| vec![4]);
    for &b in &grid {
        // validate up front: out-of-range CLI bits (e.g. --grid 1) are
        // a named error here, not a qmax assert deep in the sweep
        smoothrot::quant::validate_bits(b).map_err(|e| anyhow!("sweep-bits: --grid: {e}"))?;
    }
    let threads = p.get_usize("threads").map_err(|e| anyhow!(e))?.unwrap_or(0);
    let workload = pipeline::load_workload(&rt)?;
    let sweep = pipeline::bits_sweep(&rt, &workload, &grid, threads)?;
    println!("# bit-width ablation (total error over all modules/layers)\n");
    println!("| bits | none | smooth | rotate | smooth_rotate |");
    println!("|---|---|---|---|---|");
    for (bits, totals) in &sweep {
        println!(
            "| {bits} | {:.3e} | {:.3e} | {:.3e} | {:.3e} |",
            totals[0], totals[1], totals[2], totals[3]
        );
    }
    Ok(())
}

fn cmd_selfcheck(p: &smoothrot::cli::Parsed) -> Result<()> {
    let artifacts = p.get_or("artifacts", "artifacts");
    let rtol = p.get_f64("rtol").map_err(|e| anyhow!(e))?.unwrap_or(5e-2);
    let rt = Runtime::new(&artifacts)?;
    let golden_path = format!("{artifacts}/golden.json");
    let golden = smoothrot::jsonio::parse(
        &std::fs::read_to_string(&golden_path).with_context(|| format!("reading {golden_path}"))?,
    )
    .map_err(|e| anyhow!("parsing golden.json: {e}"))?;

    let workload = pipeline::load_workload(&rt)?;
    let mut checked = 0;
    let mut failures = Vec::new();
    for case in golden.get("analyze").and_then(|j| j.as_arr()).context("golden analyze")? {
        let module = case.get("module").and_then(|j| j.as_str()).context("module")?;
        let module: &'static str =
            smoothrot::MODULES.into_iter().find(|m| *m == module).context("module name")?;
        let layer = case.get("layer").and_then(|j| j.as_usize()).context("layer")?;
        let want_errors = case.get("errors").and_then(|j| j.as_f64_vec()).context("errors")?;
        let (x, w) = workload.pair(&rt, module, layer);
        let got = rt.analyze(&x, &w)?;
        for (i, (&want, got)) in want_errors.iter().zip(got.errors).enumerate() {
            let rel = (want - got).abs() / want.abs().max(1e-9);
            if rel > rtol {
                failures.push(format!("{module} layer {layer} mode {i}: golden {want:.6e} vs pjrt {got:.6e} (rel {rel:.2e})"));
            }
        }
        // cross-check against the native mirror (looser: different matmul order)
        let native = smoothrot::coordinator::NativeExecutor::analyze(
            &x,
            &w,
            rt.manifest().config.bits,
            rt.manifest().config.alpha as f32,
        )
        .map_err(|e| anyhow!(e))?;
        for i in 0..4 {
            let rel = (native.errors[i] - got.errors[i]).abs() / got.errors[i].abs().max(1e-9);
            if rel > 20.0 * rtol {
                failures.push(format!(
                    "{module} layer {layer} mode {i}: native {:.6e} vs pjrt {:.6e} (rel {rel:.2e})",
                    native.errors[i], got.errors[i]
                ));
            }
        }
        checked += 1;
    }
    if failures.is_empty() {
        println!("selfcheck OK: {checked} golden cases match PJRT and the native mirror (rtol {rtol:.0e})");
        Ok(())
    } else {
        for f in &failures {
            eprintln!("MISMATCH: {f}");
        }
        bail!("{} mismatches in {checked} cases", failures.len());
    }
}

fn cmd_recommend(p: &smoothrot::cli::Parsed) -> Result<()> {
    use smoothrot::policy::{recommend, PolicyConfig};
    let artifacts = p.get_or("artifacts", "artifacts");
    let backend = Backend::from_name(&p.get_or("backend", "pjrt"))?;
    let sr_margin = p.get_f64("sr-margin").map_err(|e| anyhow!(e))?.unwrap_or(1.25);
    let out_path = p.get_or("out", "reports/policy.json");

    let run = pipeline::run_full_experiment(&artifacts, PoolConfig::default(), backend)?;
    let policy = recommend(&run.grid, PolicyConfig { sr_margin });
    println!("{}", policy.summary());

    if let Some(dir) = std::path::Path::new(&out_path).parent() {
        std::fs::create_dir_all(dir).ok();
    }
    std::fs::write(&out_path, policy.to_json().to_string_pretty())
        .with_context(|| format!("write {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

fn cmd_calibrate(p: &smoothrot::cli::Parsed) -> Result<()> {
    use smoothrot::calib::search::SearchConfig;
    use smoothrot::pipeline::{calibrate_synthetic, check_plan_matches_policy, CalibrateConfig};

    let alphas: Vec<f64> = p
        .get_or("alpha-grid", "0.5")
        .split(',')
        .map(|s| s.trim().parse::<f64>().map_err(|_| anyhow!("calibrate: bad alpha {s:?}")))
        .collect::<Result<_>>()?;
    let bits_grid: Vec<u32> =
        p.get_u32_list("bits-grid").map_err(|e| anyhow!(e))?.unwrap_or_else(|| vec![4]);
    for &b in &bits_grid {
        smoothrot::quant::validate_bits(b).map_err(|e| anyhow!("calibrate: --bits-grid: {e}"))?;
    }
    let cfg = CalibrateConfig {
        layers: p.get_usize("layers").map_err(|e| anyhow!(e))?.unwrap_or(8),
        rows_per_batch: p.get_usize("rows").map_err(|e| anyhow!(e))?.unwrap_or(32),
        batches: p.get_usize("batches").map_err(|e| anyhow!(e))?.unwrap_or(2),
        shards: p.get_usize("shards").map_err(|e| anyhow!(e))?.unwrap_or(2),
        max_sample_rows: p.get_usize("sample-rows").map_err(|e| anyhow!(e))?.unwrap_or(0),
        seed: p.get_usize("seed").map_err(|e| anyhow!(e))?.unwrap_or(2025) as u64,
        search: SearchConfig {
            alphas,
            bits_grid,
            sr_margin: p.get_f64("sr-margin").map_err(|e| anyhow!(e))?.unwrap_or(1.25),
            threads: p.get_usize("threads").map_err(|e| anyhow!(e))?.unwrap_or(1),
            exec_check: p.has_flag("exec-check"),
        },
    };
    let out_path = p.get_or("out", "reports/plan.json");

    let t0 = std::time::Instant::now();
    let run = calibrate_synthetic(&cfg)?;
    println!(
        "calibrate: {} entries ({} layers x {} modules x {} bit widths) from {} batches x {} \
         rows per cell over {} shard(s) in {:?}",
        run.plan.entries.len(),
        cfg.layers,
        smoothrot::MODULES.len(),
        cfg.search.bits_grid.len(),
        cfg.batches,
        cfg.rows_per_batch,
        cfg.shards,
        t0.elapsed()
    );
    println!("{}", run.plan.summary());

    if !run.executed.is_empty() {
        let mut max_rel = 0.0f64;
        let mut worst = None;
        let (mut checked, mut skipped) = (0usize, 0usize);
        for (module, layer, bits, predicted, exec) in &run.executed {
            if exec.is_nan() {
                // bits > 8 cannot execute in i8 storage
                skipped += 1;
                continue;
            }
            checked += 1;
            let rel = (predicted - exec).abs() / predicted.abs().max(1e-12);
            if rel >= max_rel {
                max_rel = rel;
                worst = Some((module.clone(), *layer, *bits));
            }
        }
        println!(
            "exec-check: {checked} entries re-executed on the integer path{}; max \
             |executed - predicted| / predicted = {max_rel:.2e}{}",
            if skipped > 0 {
                format!(" ({skipped} skipped: bits > 8 have no integer storage)")
            } else {
                String::new()
            },
            worst
                .map(|(m, l, b)| format!(" ({m} layer {l} @ {b} bits)"))
                .unwrap_or_default()
        );
        if checked == 0 {
            bail!("exec-check: no entry was executable in integers (every bit width > 8)");
        }
        if max_rel > 0.05 {
            bail!("exec-check: executed integer error drifted {max_rel:.2e} from the prediction");
        }
    }

    if p.has_flag("selfcheck") {
        check_plan_matches_policy(&run).map_err(|e| anyhow!(e))?;
        println!("selfcheck OK: plan matches policy::recommend on the same workload");
    }

    run.plan.save(std::path::Path::new(&out_path)).map_err(|e| anyhow!(e))?;
    println!("wrote {out_path} ({})", run.plan.content_hash());
    Ok(())
}

fn cmd_serve(p: &smoothrot::cli::Parsed, telemetry: Option<&Arc<Telemetry>>) -> Result<()> {
    use smoothrot::coordinator::Job;
    use smoothrot::serve::net::{self, CoreServer, NetConfig, NetServer, ShardTopo};
    use smoothrot::serve::shard::ShardBy;
    use smoothrot::serve::{
        skewed_tenant, synthetic_requests, synthetic_requests_skewed, Admission, BatchExecutor,
        ExecMode, NativeBatchExecutor, Response, ServeConfig, ServeMetrics, TenantId,
    };

    /// Start a server (sharded when a runner topology is given), submit
    /// the stream (printing the first few responses as they arrive),
    /// optionally drain gracefully, and summarize.
    fn run_serve<E, F>(
        cfg: ServeConfig,
        shard: ShardTopo,
        telemetry: Option<Arc<Telemetry>>,
        requests: Vec<(TenantId, Job)>,
        drain: bool,
        make_executor: F,
    ) -> Result<(Vec<Response>, ServeMetrics)>
    where
        E: BatchExecutor,
        F: Fn(usize) -> Result<E, String> + Send + Sync + 'static,
    {
        let total = requests.len();
        let sharded = shard.is_some();
        let (server, rx) = CoreServer::start_with_telemetry(cfg, shard, telemetry, make_executor);
        if sharded {
            if let (CoreServer::Sharded(s), Some((_, shard_by, stealing))) = (&server, shard) {
                println!(
                    "sharding: {} runners by {}, stealing {}",
                    s.runners(),
                    shard_by.name(),
                    if stealing { "on" } else { "off" }
                );
            }
        }
        let mut rejected = 0usize;
        let mut shed = 0usize;
        for (tenant, job) in requests {
            match server.submit(tenant, job) {
                Ok(()) => {}
                Err(SubmitError::Full { .. }) => rejected += 1,
                Err(SubmitError::Shed { .. }) => shed += 1,
                Err(e) => return Err(anyhow!(e.to_string())),
            }
        }
        if shed > 0 {
            println!("  shed {shed} requests under queue pressure (retry-after hints issued)");
        }
        if drain {
            // stop admission, let every in-flight batch complete, then
            // collect the already-streamed responses below
            server.drain();
            println!("  drained: admission stopped, in-flight work complete");
        }
        let admitted = total - rejected - shed;
        let mut responses = Vec::with_capacity(admitted);
        for r in rx.iter().take(admitted) {
            if responses.len() < 5 {
                println!(
                    "  <- req {:>3} tenant {} {:>9} layer {:<2} batch {:>3} (size {}) {:>8.2} ms",
                    r.id,
                    r.tenant,
                    r.module,
                    r.layer,
                    r.batch_id,
                    r.batch_size,
                    r.total_micros as f64 / 1e3
                );
            } else if responses.len() == 5 {
                println!("  <- ... ({} more responses streaming)", admitted - 5);
            }
            responses.push(r);
        }
        let metrics = server.finish();
        Ok((responses, metrics))
    }

    /// Serve over the wire instead of the synthetic stream: start the
    /// core, attach the HTTP front-end, route SIGTERM/SIGINT into a
    /// graceful drain, and block until the drain completes.
    fn run_net<E, F>(
        cfg: ServeConfig,
        shard: ShardTopo,
        telemetry: Option<Arc<Telemetry>>,
        net_cfg: NetConfig,
        stream_seed: u64,
        make_executor: F,
    ) -> Result<ServeMetrics>
    where
        E: BatchExecutor,
        F: Fn(usize) -> Result<E, String> + Send + Sync + 'static,
    {
        let (core, rx) =
            CoreServer::start_with_telemetry(cfg, shard, telemetry.clone(), make_executor);
        let server =
            NetServer::start(net_cfg, core, rx, telemetry, net::synth_job_builder(stream_seed))
                .map_err(|e| anyhow!(e))?;
        println!(
            "listening on http://{} (drain: SIGTERM/SIGINT or POST /admin/drain)",
            server.addr()
        );
        if !net::install_term_handler() {
            eprintln!("warning: no signal handler on this platform; drain via POST /admin/drain");
        }
        let watcher = net::spawn_term_watcher(&server);
        let metrics = server.wait().map_err(|e| anyhow!(e))?;
        let _ = watcher.join();
        println!("drained: accept loop stopped, in-flight connections complete");
        Ok(metrics)
    }

    let backend = Backend::from_name(&p.get_or("backend", "native"))?;
    let artifacts = p.get_or("artifacts", "artifacts");
    let n_requests = p.get_usize("requests").map_err(|e| anyhow!(e))?.unwrap_or(64);
    let n_tenants = p.get_usize("tenants").map_err(|e| anyhow!(e))?.unwrap_or(4).max(1);
    let rows = p.get_usize("rows").map_err(|e| anyhow!(e))?.unwrap_or(32).max(1);
    let layers = p.get_usize("layers").map_err(|e| anyhow!(e))?.unwrap_or(32).max(1);
    let threads = p.get_usize("threads").map_err(|e| anyhow!(e))?.unwrap_or(1);
    let plan_path = p.get("plan").map(str::to_string);
    let exec = ExecMode::from_name(&p.get_or("exec", "f32")).map_err(|e| anyhow!("serve: {e}"))?;
    let kernel = smoothrot::kernels::simd::KernelBackend::resolve(p.get("kernel-backend"))
        .map_err(|e| anyhow!("serve: {e}"))?;
    let runners = p.get_usize("runners").map_err(|e| anyhow!(e))?;
    let shard_by = ShardBy::from_name(&p.get_or("shard-by", "layer"))
        .map_err(|e| anyhow!("serve: {e}"))?;
    let stealing = !p.has_flag("no-steal");
    let skew_layers = p.has_flag("skew-layers");
    let drain = p.has_flag("drain");
    let listen = p.get("listen").map(str::to_string);
    let max_conns = p.get_usize("max-conns").map_err(|e| anyhow!(e))?.unwrap_or(256).max(1);
    let conn_timeout_ms =
        p.get_u64("conn-timeout-ms").map_err(|e| anyhow!(e))?.unwrap_or(5_000).max(1);
    let deadline_ms = p.get_u64("deadline-ms").map_err(|e| anyhow!(e))?.unwrap_or(0);
    let shed_queued = p.get_usize("shed-queued").map_err(|e| anyhow!(e))?.unwrap_or(0);
    let trim_bytes =
        smoothrot::serve::resolve_trim_bytes(p.get_usize("trim-bytes").map_err(|e| anyhow!(e))?)
            .map_err(|e| anyhow!("serve: {e}"))?;
    let metrics_interval = p.get_u64("metrics-interval").map_err(|e| anyhow!(e))?.unwrap_or(0);
    let metrics_file = p.get("metrics-file").map(std::path::PathBuf::from);
    if metrics_interval > 0 && metrics_file.is_none() {
        bail!("serve: --metrics-interval needs --metrics-file");
    }
    let shard_topo: ShardTopo = runners.map(|r| (r, shard_by, stealing));
    // under sharding, "0 = all cores" becomes an even per-runner share
    // so N runner pools never oversubscribe the machine N-fold
    let threads = match (runners, threads) {
        (Some(r), 0) => smoothrot::kernels::par::threads_per_runner(
            smoothrot::serve::shard::resolve_runners(r),
        ),
        _ => threads,
    };
    let cfg = ServeConfig {
        workers: p.get_usize("workers").map_err(|e| anyhow!(e))?.unwrap_or(2),
        max_batch: p.get_usize("max-batch").map_err(|e| anyhow!(e))?.unwrap_or(8),
        queue_depth: p.get_usize("queue-depth").map_err(|e| anyhow!(e))?.unwrap_or(32),
        admission: if p.has_flag("reject") { Admission::Reject } else { Admission::Block },
        deadline_micros: deadline_ms.saturating_mul(1000),
        shed_queued,
        ..ServeConfig::default()
    };
    if plan_path.is_some() && backend != Backend::Native {
        bail!("serve: --plan is native-only (the plan pre-resolves native transforms)");
    }
    if exec == ExecMode::Int8 && plan_path.is_none() {
        bail!("serve: --exec int8 needs --plan (weights are pre-quantized at plan load)");
    }
    if backend != Backend::Native && (runners.is_some() || skew_layers) {
        bail!("serve: --runners/--skew-layers are native-only");
    }
    if listen.is_some() && backend != Backend::Native {
        bail!("serve: --listen is native-only (the front-end synthesizes activations natively)");
    }

    println!(
        "serve: {n_requests} requests, {n_tenants} tenants, {} workers x {threads} math \
         threads, max-batch {}, queue-depth {}, {:?} admission, backend {backend:?}, exec {}",
        cfg.workers,
        cfg.max_batch,
        cfg.queue_depth,
        cfg.admission,
        exec.name(),
    );
    if backend == Backend::Native {
        // the active integer-microkernel dispatch (bit-identical across
        // choices; CI greps this line on the avx2 matrix leg)
        println!(
            "kernel backend: {kernel} (packed i8 tile GEMM + per-token quantize dispatch)"
        );
    }

    // Periodic exporter: rewrite the metrics files every interval while
    // the server runs (atomic tmp + rename, so a scraper never reads a
    // torn file).  A guard owns the thread: `flush_final` stops it,
    // joins, and writes one last snapshot — and Drop does the same, so
    // the drain path AND every fatal-error path (`bail!` below) leave a
    // final-state metrics file, never a stale mid-run one racing the
    // exit dump in main().
    struct MetricsWriter {
        stop: Arc<std::sync::atomic::AtomicBool>,
        handle: Option<std::thread::JoinHandle<()>>,
        telemetry: Arc<Telemetry>,
        path: std::path::PathBuf,
    }

    impl MetricsWriter {
        fn flush_final(&mut self) {
            let Some(handle) = self.handle.take() else { return };
            self.stop.store(true, std::sync::atomic::Ordering::Relaxed);
            let _ = handle.join();
            if let Err(e) = telemetry::export::write_files(&self.telemetry.snapshot(), &self.path)
            {
                eprintln!("telemetry: final periodic flush failed: {e}");
            }
        }
    }

    impl Drop for MetricsWriter {
        fn drop(&mut self) {
            self.flush_final();
        }
    }

    let mut metrics_writer = match (telemetry, &metrics_file) {
        (Some(t), Some(path)) if metrics_interval > 0 => {
            let t = Arc::clone(t);
            let path = path.clone();
            let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
            let stop2 = Arc::clone(&stop);
            let (t2, path2) = (Arc::clone(&t), path.clone());
            let handle = std::thread::spawn(move || {
                while !stop2.load(std::sync::atomic::Ordering::Relaxed) {
                    if let Err(e) = telemetry::export::write_files(&t2.snapshot(), &path2) {
                        eprintln!("telemetry: periodic write failed: {e}");
                    }
                    // sleep in slices so shutdown stays prompt
                    for _ in 0..metrics_interval * 10 {
                        if stop2.load(std::sync::atomic::Ordering::Relaxed) {
                            break;
                        }
                        std::thread::sleep(std::time::Duration::from_millis(100));
                    }
                }
            });
            Some(MetricsWriter { stop, handle: Some(handle), telemetry: t, path })
        }
        _ => None,
    };

    let (responses, metrics) = match backend {
        Backend::Native => {
            use smoothrot::calib::registry::PlanRegistry;
            use std::sync::atomic::{AtomicBool, Ordering};
            use std::sync::Arc;

            // the request stream's base seed also fixes the per-layer
            // serving weights (synth::layer_weight) that int8 preload
            // quantizes — keep the two in lockstep (wire requests use
            // the same weights: net::synth_job_builder shares the seed)
            let stream_seed = 2025u64;
            let net_cfg = listen.as_ref().map(|addr| NetConfig {
                addr: addr.clone(),
                max_conns,
                read_timeout: std::time::Duration::from_millis(conn_timeout_ms),
                write_timeout: std::time::Duration::from_millis(conn_timeout_ms),
                ..NetConfig::default()
            });
            let requests = if net_cfg.is_some() {
                Vec::new() // wire clients drive the server instead
            } else if skew_layers {
                synthetic_requests_skewed(n_requests, n_tenants, rows, layers, stream_seed)
            } else {
                synthetic_requests(n_requests, n_tenants, rows, layers, stream_seed)
            };
            match plan_path {
                None => {
                    let make = move |_| {
                        Ok(NativeBatchExecutor::with_threads(threads)
                            .with_kernel_backend(kernel)
                            .with_trim_budget(trim_bytes))
                    };
                    match net_cfg {
                        Some(nc) => {
                            let m = run_net(
                                cfg,
                                shard_topo,
                                telemetry.cloned(),
                                nc,
                                stream_seed,
                                make,
                            )?;
                            (None, m)
                        }
                        None => {
                            let (r, m) = run_serve(
                                cfg,
                                shard_topo,
                                telemetry.cloned(),
                                requests,
                                drain,
                                make,
                            )?;
                            (Some(r), m)
                        }
                    }
                }
                Some(path) => {
                    let registry =
                        Arc::new(PlanRegistry::load(path.clone()).map_err(|e| anyhow!(e))?);
                    println!(
                        "plan: {path} ({} entries, {})",
                        registry.len(),
                        registry.content_hash()
                    );
                    // every snapshot (periodic and exit) reads the plan
                    // registry's live coverage / int8 / fusion counters
                    if let Some(t) = telemetry {
                        t.add_collector(telemetry::plan_registry_collector(&registry));
                    }
                    if exec == ExecMode::Int8 {
                        // pre-quantize every covered layer's transformed
                        // weight once, i8/i4 + per-channel scales; the
                        // reload poller below re-runs this automatically
                        // after a hot swap
                        let loaded = registry
                            .set_weight_provider(Box::new(move |module, layer| {
                                smoothrot::synth::layer_weight(module, layer, stream_seed)
                            }))
                            .map_err(|e| anyhow!(e))?;
                        println!(
                            "int8: pre-quantized {loaded} planned weights (i8 codes + \
                             per-channel scales)"
                        );
                        if loaded == 0 {
                            bail!(
                                "serve: --exec int8 pre-quantized zero weights — are all plan \
                                 bit widths wider than 8?"
                            );
                        }
                    }
                    // SIGHUP-free hot reload: poll the plan file's
                    // content hash while the server runs and swap in
                    // changed content atomically (shared registry —
                    // every runner observes the swap at once).
                    let stop = Arc::new(AtomicBool::new(false));
                    let poller = {
                        let registry = Arc::clone(&registry);
                        let stop = Arc::clone(&stop);
                        std::thread::spawn(move || {
                            while !stop.load(Ordering::Relaxed) {
                                match registry.reload_if_changed() {
                                    Ok(true) => eprintln!(
                                        "plan reloaded ({})",
                                        registry.content_hash()
                                    ),
                                    Ok(false) => {}
                                    Err(e) => eprintln!("plan reload failed: {e}"),
                                }
                                std::thread::sleep(std::time::Duration::from_millis(200));
                            }
                        })
                    };
                    let exec_registry = Arc::clone(&registry);
                    let make = move |_| {
                        Ok(NativeBatchExecutor::with_plan_exec(
                            Arc::clone(&exec_registry),
                            threads,
                            exec,
                        )
                        .with_kernel_backend(kernel)
                        .with_trim_budget(trim_bytes))
                    };
                    let net_mode = net_cfg.is_some();
                    let out = match net_cfg {
                        Some(nc) => {
                            run_net(cfg, shard_topo, telemetry.cloned(), nc, stream_seed, make)
                                .map(|m| (None, m))
                        }
                        None => {
                            run_serve(cfg, shard_topo, telemetry.cloned(), requests, drain, make)
                                .map(|(r, m)| (Some(r), m))
                        }
                    };
                    stop.store(true, Ordering::Relaxed);
                    let _ = poller.join();
                    let out = out?;
                    // In net mode traffic is client-driven: a drain
                    // before any request arrived legitimately completes
                    // zero jobs, so the coverage/int8 gates only fire
                    // when requests actually ran.
                    let completed_any = out.1.completed > 0;
                    let (planned, fallback) = registry.stats();
                    println!(
                        "plan lookups: {planned} planned / {fallback} fallback ({:.0}% coverage)",
                        if planned + fallback == 0 {
                            0.0
                        } else {
                            100.0 * planned as f64 / (planned + fallback) as f64
                        }
                    );
                    if planned == 0 && (!net_mode || completed_any) {
                        bail!(
                            "serve: the plan covered zero requests — keep serve's --layers \
                             within the calibrated depth and the bit widths aligned"
                        );
                    }
                    if exec == ExecMode::Int8 {
                        let (executed, degraded) = registry.int8_stats();
                        let batch_fused = registry.batch_fused();
                        println!(
                            "int8 exec: {executed} requests ran the integer GEMM \
                             ({batch_fused} batch-fused into stacked GEMMs), {degraded} \
                             degraded to the f32 planned path"
                        );
                        if executed == 0 && (!net_mode || completed_any) {
                            bail!(
                                "serve: --exec int8 executed zero integer GEMMs — the \
                                 pre-quantized weights never matched the request shapes"
                            );
                        }
                        // mirror of the int8_executed gate one level up:
                        // integer GEMMs ran, but none through the stacked
                        // batch-fused path — the hot path silently fell
                        // back to per-job dispatch.  Wire traffic only
                        // coalesces when arrivals overlap, so in net
                        // mode this demotes to a warning instead of
                        // failing a legitimately quiet run.
                        if batch_fused == 0 && executed > 0 {
                            if net_mode {
                                eprintln!(
                                    "warning: zero batch-fused GEMMs (wire arrivals never \
                                     coalesced into a stacked batch)"
                                );
                            } else {
                                bail!(
                                    "serve: --exec int8 executed zero batch-fused GEMMs — the \
                                     stacked hot path silently fell back to per-job execution"
                                );
                            }
                        }
                    }
                    out
                }
            }
        }
        Backend::Pjrt => {
            let rt = Runtime::new(&artifacts)?;
            let model = rt.manifest().config.clone();
            let workload = pipeline::load_workload(&rt)?;
            let mut rng = smoothrot::rng::Rng::new(2025);
            let requests: Vec<(TenantId, Job)> = (0..n_requests)
                .map(|i| {
                    let tenant = skewed_tenant(&mut rng, n_tenants);
                    let module = smoothrot::MODULES[rng.below(4)];
                    let layer = rng.below(model.n_layers);
                    let (x, w) = workload.pair(&rt, module, layer);
                    let job = Job {
                        id: i as u64,
                        layer,
                        module,
                        x,
                        w,
                        alpha: model.alpha as f32,
                        bits: model.bits,
                    };
                    (tenant, job)
                })
                .collect();
            let dir = artifacts.clone();
            let (r, m) = run_serve(cfg, None, telemetry.cloned(), requests, drain, move |_| {
                pipeline::PjrtExecutor::new(dir.clone())
            })?;
            (Some(r), m)
        }
    };

    // With telemetry on, register the end-of-run summary in the shared
    // registry and render the console lines FROM its snapshot — the
    // exact rows the exit dump writes to the JSON/Prometheus files, so
    // the printed numbers and the exported ones cannot diverge.  The
    // delta-bump happens BEFORE the final periodic flush so the last
    // interval file already carries the end-of-run counters.
    let summary = match telemetry {
        Some(t) => {
            metrics.fill(t);
            telemetry::render_summary(&t.snapshot())
        }
        None => metrics.summary(),
    };
    if let Some(w) = metrics_writer.as_mut() {
        w.flush_final();
    }
    println!("\n{summary}");
    if metrics.completed > 0 && metrics.errors == metrics.completed {
        let first = responses
            .iter()
            .flatten()
            .find_map(|r| r.out.as_ref().err())
            .cloned()
            .unwrap_or_default();
        bail!("all {} requests errored; first error: {first}", metrics.completed);
    }

    // The advisor response: per-request error-minimizing transform
    // (in-process modes only — wire clients got their argmin in each
    // result line's mode_best field).
    if let Some(responses) = &responses {
        let mut recommend = std::collections::BTreeMap::<&str, usize>::new();
        for r in responses {
            if let Ok(out) = &r.out {
                let best = Mode::ALL
                    .into_iter()
                    .min_by(|a, b| {
                        out.errors[a.index()].partial_cmp(&out.errors[b.index()]).unwrap()
                    })
                    .unwrap();
                *recommend.entry(best.name()).or_default() += 1;
            }
        }
        println!("per-request recommended transform (argmin error):");
        for (mode, count) in recommend {
            println!("  {mode:>14}: {count} requests");
        }
    }
    std::io::stdout().flush().ok();
    Ok(())
}

fn cmd_loadgen(p: &smoothrot::cli::Parsed) -> Result<()> {
    use smoothrot::loadgen::{self, LoadgenConfig};
    use smoothrot::serve::{net, ExecMode, NativeBatchExecutor};

    let cfg = LoadgenConfig {
        target: p.get_or("target", "127.0.0.1:7433"),
        phases: loadgen::parse_phases(&p.get_or("phases", "steady:2000:50"))
            .map_err(|e| anyhow!("loadgen: --phases: {e}"))?,
        tenants: p.get_usize("tenants").map_err(|e| anyhow!(e))?.unwrap_or(4).max(1),
        layers: p.get_usize("layers").map_err(|e| anyhow!(e))?.unwrap_or(4).max(1),
        rows: p.get_usize("rows").map_err(|e| anyhow!(e))?.unwrap_or(8).max(1),
        seed: p.get_u64("seed").map_err(|e| anyhow!(e))?.unwrap_or(1),
        concurrency: p.get_usize("concurrency").map_err(|e| anyhow!(e))?.unwrap_or(8).max(1),
        timeout: std::time::Duration::from_millis(
            p.get_u64("timeout-ms").map_err(|e| anyhow!(e))?.unwrap_or(10_000).max(1),
        ),
    };
    let phases_desc: Vec<String> = cfg
        .phases
        .iter()
        .map(|ph| format!("{}({}ms @ {}rps)", ph.name, ph.duration_ms, ph.rps))
        .collect();
    println!(
        "loadgen: open loop against {} — {} | {} senders, seed {}",
        cfg.target,
        phases_desc.join(" -> "),
        cfg.concurrency,
        cfg.seed
    );
    let mut report = loadgen::run(&cfg).map_err(|e| anyhow!("loadgen: {e}"))?;
    println!(
        "sent {} requests; p50 {:.0}µs p95 {:.0}µs p99 {:.0}µs",
        report.sent, report.percentiles.p50, report.percentiles.p95, report.percentiles.p99
    );
    println!("client-side taxonomy:");
    for (outcome, count) in &report.taxonomy {
        println!("  {outcome:>12}: {count}");
    }

    // Bit-identity replay: the server and this process share the job
    // builder (same stream seed → same weights, same per-request
    // activations), so every 200-OK errors_bits must match exactly.
    let verify_plan = p.get("verify-plan").map(str::to_string);
    if p.has_flag("verify") || verify_plan.is_some() {
        let stream_seed = p.get_u64("stream-seed").map_err(|e| anyhow!(e))?.unwrap_or(2025);
        let builder = net::synth_job_builder(stream_seed);
        let replayed = report.ok_samples.len();
        let mismatches = match verify_plan {
            Some(path) => {
                use smoothrot::calib::registry::PlanRegistry;
                let exec_mode = ExecMode::from_name(&p.get_or("verify-exec", "f32"))
                    .map_err(|e| anyhow!("loadgen: {e}"))?;
                let registry = Arc::new(PlanRegistry::load(path).map_err(|e| anyhow!(e))?);
                if exec_mode == ExecMode::Int8 {
                    let loaded = registry
                        .set_weight_provider(Box::new(move |module, layer| {
                            smoothrot::synth::layer_weight(module, layer, stream_seed)
                        }))
                        .map_err(|e| anyhow!(e))?;
                    if loaded == 0 {
                        bail!("loadgen: --verify-exec int8 pre-quantized zero weights");
                    }
                }
                let mut exec = NativeBatchExecutor::with_plan_exec(registry, 1, exec_mode);
                report.verify(&builder, move |job| exec.run(job))
            }
            None => {
                let mut exec = NativeBatchExecutor::new();
                report.verify(&builder, move |job| exec.run(job))
            }
        };
        println!("verify: {replayed} responses replayed in-process, {mismatches} mismatches");
        if mismatches > 0 {
            // write the report before failing — the artifact records
            // the mismatch count for the postmortem
            if let Some(path) = p.get("report") {
                std::fs::write(path, report.to_json().to_string_pretty())
                    .with_context(|| format!("write {path}"))?;
                println!("wrote {path}");
            }
            bail!(
                "loadgen: {mismatches} of {replayed} wire responses differ bit-for-bit from \
                 the in-process replay"
            );
        }
    }

    if p.has_flag("drain") {
        let gone = loadgen::drain_target(&cfg.target, std::time::Duration::from_secs(30));
        if gone {
            println!("drain: server stopped answering (graceful exit observed)");
        } else {
            bail!("loadgen: --drain: server still answering 30s after POST /admin/drain");
        }
    }

    if let Some(path) = p.get("report") {
        std::fs::write(path, report.to_json().to_string_pretty())
            .with_context(|| format!("write {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}
