//! Quantization-difficulty metric and statistics (paper Sec. II-B/IV-B).
//!
//! * channel magnitudes — Frobenius norm per channel (FlatQuant's view),
//! * quantization difficulty — the paper's new metric: the standard
//!   deviation of the channel magnitudes,
//! * excess kurtosis (FlatQuant's flatness proxy),
//! * Pearson correlation (used for the >0.97 headline claim),
//! * small summary/histogram helpers for the report layer,
//! * latency percentile summaries ([`Percentiles`]) for the serving
//!   core's p50/p95/p99 tracking,
//! * cache hit/miss counters ([`CacheStats`]) surfacing rotation-cache
//!   effectiveness in the serve summary.

use crate::tensor::Matrix;

/// Channel axis selector for magnitude computations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Channels {
    /// Channels are columns (activations X: one channel per input dim).
    Columns,
    /// Channels are rows (weights W: indexed by input channel).
    Rows,
}

/// Frobenius norm of each channel.
pub fn channel_magnitudes(t: &Matrix, ch: Channels) -> Vec<f64> {
    match ch {
        Channels::Columns => t.col_norms(),
        Channels::Rows => t.row_norms(),
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    (xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// The paper's quantization difficulty: std of channel magnitudes.
pub fn quant_difficulty(t: &Matrix, ch: Channels) -> f64 {
    std_dev(&channel_magnitudes(t, ch))
}

/// [`quant_difficulty`] with [`Channels::Columns`] over a contiguous
/// run of row-major rows (`flat.len()` must be a multiple of `cols`) —
/// the zero-copy equivalent of slicing those rows into their own
/// matrix.  Both forms run the SAME column-magnitude fold
/// ([`crate::tensor::col_norms_flat`], which [`Matrix::col_norms`]
/// delegates to), so the result is **bit-identical** by construction;
/// the batch-fused serving path relies on that to report per-job
/// difficulty straight off its stacked activation plane.
pub fn quant_difficulty_rows(flat: &[f32], cols: usize) -> f64 {
    std_dev(&crate::tensor::col_norms_flat(flat, cols))
}

/// Excess kurtosis of the flattened tensor.
pub fn kurtosis(t: &Matrix) -> f64 {
    let n = t.as_slice().len() as f64;
    if n == 0.0 {
        return 0.0;
    }
    let mean = t.as_slice().iter().map(|&v| v as f64).sum::<f64>() / n;
    let m2 = t.as_slice().iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    let m4 = t.as_slice().iter().map(|&v| (v as f64 - mean).powi(4)).sum::<f64>() / n;
    if m2 <= 0.0 {
        return 0.0;
    }
    m4 / (m2 * m2) - 3.0
}

/// Pearson correlation coefficient of two equal-length series.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len(), "pearson needs equal lengths");
    let n = xs.len() as f64;
    if xs.is_empty() {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        let dx = x - mx;
        let dy = y - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx <= 0.0 || syy <= 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Simple summary statistics of a series.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub std: f64,
    pub n: usize,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        Summary {
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            mean,
            std: std_dev(xs),
            n,
        }
    }
}

/// p50/p95/p99/p99.9 summary of a latency (or any) sample set, computed
/// by nearest-rank on a sorted copy.
///
/// ```
/// use smoothrot::metrics::Percentiles;
/// let samples: Vec<f64> = (1..=100).map(|v| v as f64).collect();
/// let p = Percentiles::of(&samples);
/// assert_eq!(p.p50, 50.0);
/// assert_eq!(p.p95, 95.0);
/// assert_eq!(p.p99, 99.0);
/// assert_eq!(p.p999, 100.0);
/// assert!(p.p50 <= p.p95 && p.p95 <= p.p99 && p.p99 <= p.p999);
/// ```
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Percentiles {
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
    /// 99.9th percentile (the tail the per-stage timers exist to
    /// explain).
    pub p999: f64,
}

impl Percentiles {
    /// Nearest-rank pick over an ascending-sorted, finite, non-empty
    /// sample vector.
    fn of_sorted(v: &[f64]) -> Percentiles {
        let pick = |p: f64| {
            // nearest-rank: 1-based rank ceil(n * p), clamped into range
            let rank = ((v.len() as f64) * p).ceil() as usize;
            v[rank.saturating_sub(1).min(v.len() - 1)]
        };
        Percentiles { p50: pick(0.50), p95: pick(0.95), p99: pick(0.99), p999: pick(0.999) }
    }

    /// Summarize `samples` (empty or all-non-finite input yields zeros).
    pub fn of(samples: &[f64]) -> Percentiles {
        let mut v: Vec<f64> = samples.iter().copied().filter(|x| x.is_finite()).collect();
        if v.is_empty() {
            return Percentiles::default();
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Percentiles::of_sorted(&v)
    }

    /// Summarize integer microsecond samples (the serving core's native
    /// latency unit).
    pub fn of_micros(samples: &[u64]) -> Percentiles {
        let v: Vec<f64> = samples.iter().map(|&x| x as f64).collect();
        Percentiles::of(&v)
    }

    /// Combine several *pre-sorted* per-shard sample vectors (e.g. one
    /// per serving worker) with a [`std::collections::BinaryHeap`]
    /// k-way merge — O(total · log shards) comparisons, no global
    /// concatenation is ever re-sorted.  Equals [`Percentiles::of`] on
    /// the concatenation of the shards; pinned by the
    /// `merge_matches_naive_concatenation` test.  Non-finite values are
    /// skipped, like [`Percentiles::of`].
    ///
    /// ```
    /// use smoothrot::metrics::Percentiles;
    /// let a = [1.0, 3.0, 5.0];
    /// let b = [2.0, 4.0];
    /// let merged = Percentiles::merge(&[&a, &b]);
    /// assert_eq!(merged, Percentiles::of(&[1.0, 2.0, 3.0, 4.0, 5.0]));
    /// ```
    pub fn merge(shards: &[&[f64]]) -> Percentiles {
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;

        // finite-only total order (every heap key is finite, so
        // total_cmp is plain numeric order)
        #[derive(PartialEq)]
        struct Key(f64);
        impl Eq for Key {}
        impl PartialOrd for Key {
            fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
                Some(self.cmp(other))
            }
        }
        impl Ord for Key {
            fn cmp(&self, other: &Self) -> std::cmp::Ordering {
                self.0.total_cmp(&other.0)
            }
        }

        // cursors skip non-finite samples up front, so the heap only
        // ever holds one finite head per non-exhausted shard
        fn next_finite(s: &[f64], mut i: usize) -> usize {
            while i < s.len() && !s[i].is_finite() {
                i += 1;
            }
            i
        }

        let total: usize = shards.iter().map(|s| s.len()).sum();
        let mut heap: BinaryHeap<Reverse<(Key, usize, usize)>> =
            BinaryHeap::with_capacity(shards.len());
        for (k, s) in shards.iter().enumerate() {
            let i = next_finite(s, 0);
            if i < s.len() {
                heap.push(Reverse((Key(s[i]), k, i)));
            }
        }
        let mut v = Vec::with_capacity(total);
        while let Some(Reverse((Key(val), k, i))) = heap.pop() {
            v.push(val);
            let s = shards[k];
            let j = next_finite(s, i + 1);
            if j < s.len() {
                heap.push(Reverse((Key(s[j]), k, j)));
            }
        }
        if v.is_empty() {
            return Percentiles::default();
        }
        Percentiles::of_sorted(&v)
    }

    /// Per-shard summaries of *pre-sorted* sample vectors (e.g. the
    /// per-runner latency shards of a sharded server) — one
    /// [`Percentiles`] per shard, empty shards yielding zeros.  The
    /// complement of [`Percentiles::merge`]: merge answers "what does
    /// the fleet look like", this answers "what does each runner look
    /// like".
    ///
    /// ```
    /// use smoothrot::metrics::Percentiles;
    /// let shards = vec![vec![1.0, 2.0, 3.0], vec![], vec![5.0]];
    /// let per = Percentiles::of_each_sorted(&shards);
    /// assert_eq!(per.len(), 3);
    /// assert_eq!(per[0], Percentiles::of(&[1.0, 2.0, 3.0]));
    /// assert_eq!(per[1], Percentiles::default());
    /// assert_eq!(per[2].p50, 5.0);
    /// ```
    pub fn of_each_sorted(shards: &[Vec<f64>]) -> Vec<Percentiles> {
        shards
            .iter()
            .map(|s| {
                if s.is_empty() {
                    Percentiles::default()
                } else {
                    Percentiles::of_sorted(s)
                }
            })
            .collect()
    }
}

/// Hit/miss counters of a keyed cache, e.g. the per-width
/// [`crate::transforms::RotationCache`] each serving worker owns.
/// Surfaced in the serve summary line via
/// [`crate::serve::ServeMetrics`].
///
/// ```
/// use smoothrot::metrics::CacheStats;
/// let mut s = CacheStats { hits: 3, misses: 1 };
/// s.merge(CacheStats { hits: 1, misses: 1 });
/// assert_eq!(s.lookups(), 6);
/// assert!((s.hit_rate() - 4.0 / 6.0).abs() < 1e-12);
/// ```
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that had to build the entry.
    pub misses: u64,
}

impl CacheStats {
    /// Total lookups.
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of lookups served from the cache (0 when never used).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }

    /// Fold another counter pair in (per-worker caches -> run total).
    pub fn merge(&mut self, other: CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
    }
}

/// Fixed-width histogram over [lo, hi].  Degenerate parameters
/// (`bins == 0` or `hi <= lo`) yield an empty vector instead of
/// panicking — a report helper must never take the process down.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    if bins == 0 || hi <= lo {
        return Vec::new();
    }
    let mut counts = vec![0usize; bins];
    let width = (hi - lo) / bins as f64;
    for &x in xs {
        if x < lo || x > hi {
            continue;
        }
        let mut b = ((x - lo) / width) as usize;
        if b >= bins {
            b = bins - 1;
        }
        counts[b] += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn difficulty_zero_for_flat_tensor() {
        let t = Matrix::from_fn(4, 8, |_, _| 1.5);
        assert!(quant_difficulty(&t, Channels::Columns) < 1e-12);
    }

    #[test]
    fn difficulty_rows_bit_identical_to_matrix_form() {
        // the zero-copy row-range fold must equal slicing the rows into
        // their own matrix EXACTLY (the batch-fused path relies on it)
        let t = Matrix::from_fn(7, 5, |i, j| ((i * 31 + j * 17) as f32).sin() * (j as f32 + 0.3));
        let flat = t.as_slice();
        for (r0, r1) in [(0usize, 7usize), (0, 3), (2, 6), (4, 5), (3, 3)] {
            let rows = r1 - r0;
            let sub = Matrix::from_vec(rows, 5, flat[r0 * 5..r1 * 5].to_vec());
            assert_eq!(
                quant_difficulty_rows(&flat[r0 * 5..r1 * 5], 5),
                quant_difficulty(&sub, Channels::Columns),
                "rows {r0}..{r1}"
            );
        }
        // degenerate shapes
        assert_eq!(quant_difficulty_rows(&[], 5), 0.0);
        assert_eq!(quant_difficulty_rows(&[], 0), 0.0);
    }

    #[test]
    fn difficulty_detects_hot_channel() {
        let mut t = Matrix::from_fn(4, 8, |_, _| 1.0);
        for i in 0..4 {
            t.set(i, 3, 100.0);
        }
        let d = quant_difficulty(&t, Channels::Columns);
        assert!(d > 10.0, "difficulty {d}");
    }

    #[test]
    fn channel_axis_selection() {
        let t = Matrix::from_vec(2, 3, vec![3.0, 0.0, 0.0, 4.0, 0.0, 0.0]);
        let cols = channel_magnitudes(&t, Channels::Columns);
        assert!((cols[0] - 5.0).abs() < 1e-12);
        let rows = channel_magnitudes(&t, Channels::Rows);
        assert!((rows[0] - 3.0).abs() < 1e-12);
        assert!((rows[1] - 4.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_inverse() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_degenerate_is_zero() {
        let xs = [1.0, 1.0, 1.0];
        let ys = [1.0, 2.0, 3.0];
        assert_eq!(pearson(&xs, &ys), 0.0);
    }

    #[test]
    fn kurtosis_normal_vs_outlier() {
        use crate::rng::Rng;
        let mut rng = Rng::new(1);
        let t = Matrix::from_vec(64, 64, rng.normals_f32(64 * 64));
        let k_normal = kurtosis(&t);
        assert!(k_normal.abs() < 0.5, "normal kurtosis {k_normal}");
        let mut t2 = t.clone();
        t2.set(0, 0, 500.0);
        assert!(kurtosis(&t2) > 10.0);
    }

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_empty_and_singleton() {
        assert_eq!(Percentiles::of(&[]), Percentiles::default());
        let p = Percentiles::of(&[7.0]);
        assert_eq!((p.p50, p.p95, p.p99, p.p999), (7.0, 7.0, 7.0, 7.0));
    }

    #[test]
    fn p999_needs_a_thousand_samples_to_leave_the_max() {
        // nearest-rank: below 1000 samples p999 is the max
        let v: Vec<f64> = (1..=999).map(|x| x as f64).collect();
        assert_eq!(Percentiles::of(&v).p999, 999.0);
        let v: Vec<f64> = (1..=2000).map(|x| x as f64).collect();
        assert_eq!(Percentiles::of(&v).p999, 1998.0);
    }

    #[test]
    fn percentiles_ignore_non_finite() {
        let p = Percentiles::of(&[1.0, f64::NAN, 2.0, f64::INFINITY, 3.0]);
        assert!(p.p50.is_finite() && p.p99.is_finite());
        assert!(p.p99 <= 3.0);
    }

    #[test]
    fn percentiles_of_micros_matches_f64() {
        let micros: Vec<u64> = (0..50).map(|v| v * 10).collect();
        let floats: Vec<f64> = micros.iter().map(|&v| v as f64).collect();
        assert_eq!(Percentiles::of_micros(&micros), Percentiles::of(&floats));
    }

    #[test]
    fn merge_matches_naive_concatenation() {
        use crate::rng::Rng;
        let mut rng = Rng::new(42);
        for shards_n in [1usize, 2, 3, 5] {
            let mut shards: Vec<Vec<f64>> = Vec::new();
            let mut concat = Vec::new();
            for s in 0..shards_n {
                let n = 1 + rng.below(40 + s);
                let mut v: Vec<f64> =
                    (0..n).map(|_| (rng.below(10_000) as f64) / 7.0).collect();
                concat.extend_from_slice(&v);
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                shards.push(v);
            }
            let refs: Vec<&[f64]> = shards.iter().map(|v| v.as_slice()).collect();
            assert_eq!(
                Percentiles::merge(&refs),
                Percentiles::of(&concat),
                "{shards_n} shards: merge must equal the naive concatenation path"
            );
        }
    }

    #[test]
    fn merge_handles_empty_and_uneven_shards() {
        assert_eq!(Percentiles::merge(&[]), Percentiles::default());
        assert_eq!(Percentiles::merge(&[&[], &[]]), Percentiles::default());
        let a = [7.0];
        assert_eq!(Percentiles::merge(&[&[], &a]), Percentiles::of(&a));
        let b = [1.0, 2.0, f64::NAN];
        let merged = Percentiles::merge(&[&b, &a]);
        assert_eq!(merged, Percentiles::of(&[1.0, 2.0, 7.0]), "non-finite values skipped");
    }

    #[test]
    fn cache_stats_empty_rate_is_zero() {
        let s = CacheStats::default();
        assert_eq!(s.lookups(), 0);
        assert_eq!(s.hit_rate(), 0.0);
    }

    #[test]
    fn histogram_counts() {
        let h = histogram(&[0.1, 0.2, 0.5, 0.9, 2.0], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 2]); // 0.5 lands in the second bin; 2.0 is out of range
    }

    #[test]
    fn histogram_degenerate_params_yield_empty_not_panic() {
        assert!(histogram(&[1.0], 0.0, 1.0, 0).is_empty());
        assert!(histogram(&[1.0], 1.0, 1.0, 4).is_empty());
        assert!(histogram(&[1.0], 2.0, 1.0, 4).is_empty());
    }
}
