//! The massive-outlier token model of paper Sec. IV-D/E (Eq. 6–9).
//!
//! Builds the synthetic token of Eq. 6 (a few massive outliers on a
//! Gaussian floor), and provides the paper's closed-form predictions:
//!
//! * Eq. 7 — the rotated token's values cluster at the 2^(|O|-1) sign-
//!   combination centroids,
//! * Eq. 8 — `max|t_hat| = sum_i |o_i| / sqrt(d) + |eps|`,
//! * Eq. 9 — after smoothing (alpha = 0.5) and rotation,
//!   `max|t_tilde| ~ sum_i sqrt(|o_i| * max|W_i| / d)`.
//!
//! The property tests in `check`-based suites validate the predictions
//! against the actual transforms.

use crate::rng::Rng;
use crate::tensor::Matrix;

/// Specification of a massive-outlier token (Eq. 6).
#[derive(Clone, Debug)]
pub struct OutlierToken {
    /// Dimensionality d.
    pub dim: usize,
    /// Outlier dimensions O.
    pub dims: Vec<usize>,
    /// Outlier values o_j (signed).
    pub values: Vec<f32>,
    /// Gaussian floor sigma.
    pub sigma: f32,
}

impl OutlierToken {
    /// Sample a token spec with `n_out` outliers of magnitude around `scale`.
    pub fn sample(dim: usize, n_out: usize, scale: f32, sigma: f32, rng: &mut Rng) -> Self {
        let dims = rng.choose_distinct(dim, n_out);
        let values =
            (0..n_out).map(|_| rng.sign() * scale * (1.0 + 0.5 * rng.f32())).collect();
        Self { dim, dims, values, sigma }
    }

    /// Materialize the token vector (Eq. 6).
    pub fn materialize(&self, rng: &mut Rng) -> Vec<f32> {
        let mut t: Vec<f32> = (0..self.dim).map(|_| self.sigma * rng.normal() as f32).collect();
        for (&j, &v) in self.dims.iter().zip(&self.values) {
            t[j] = v;
        }
        t
    }

    /// Materialize a matrix of `n` tokens where row 0 is the outlier token
    /// and the rest are benign Gaussian rows.
    pub fn materialize_batch(&self, n: usize, rng: &mut Rng) -> Matrix {
        let mut m = Matrix::zeros(n, self.dim);
        let t = self.materialize(rng);
        m.row_mut(0).copy_from_slice(&t);
        for i in 1..n {
            for v in m.row_mut(i) {
                *v = self.sigma * rng.normal() as f32;
            }
        }
        m
    }

    /// Eq. 8 prediction: max|t_hat| after Hadamard rotation (without the
    /// |eps| noise term).
    pub fn predicted_rotated_max(&self) -> f64 {
        self.values.iter().map(|v| v.abs() as f64).sum::<f64>() / (self.dim as f64).sqrt()
    }

    /// Eq. 7 centroid magnitudes: |sum_i h_i |o_i|| / sqrt(d) over all
    /// sign combinations (deduplicated, sorted ascending).
    ///
    /// The enumeration is exponential in the outlier count, so tokens
    /// with more than 20 outliers return an error instead of a
    /// 2^k-sized allocation (a panic here would take down a serving
    /// worker on attacker-shaped input).
    pub fn centroid_magnitudes(&self) -> Result<Vec<f64>, String> {
        let k = self.values.len();
        if k > 20 {
            return Err(format!(
                "centroid enumeration needs 2^{k} sign combinations — refusing above 20 outliers"
            ));
        }
        let mut mags: Vec<f64> = (0..(1usize << k))
            .map(|mask| {
                let mut acc = 0.0f64;
                for (i, v) in self.values.iter().enumerate() {
                    let sign = if mask >> i & 1 == 1 { 1.0 } else { -1.0 };
                    acc += sign * v.abs() as f64;
                }
                acc.abs() / (self.dim as f64).sqrt()
            })
            .collect();
        mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
        mags.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        Ok(mags)
    }

    /// Eq. 9 prediction: max|t_tilde| after smooth (alpha=0.5) + rotate,
    /// given the per-input-channel weight maxima of W.
    pub fn predicted_smooth_rotated_max(&self, w_col_max: &[f32]) -> f64 {
        assert_eq!(w_col_max.len(), self.dim);
        self.dims
            .iter()
            .zip(&self.values)
            .map(|(&j, &o)| ((o.abs() as f64) * (w_col_max[j] as f64) / self.dim as f64).sqrt())
            .sum()
    }
}

/// Number of distinct centroids predicted by Eq. 7: 2^(|O|-1).
pub fn predicted_cluster_count(n_outliers: usize) -> usize {
    if n_outliers == 0 {
        1
    } else {
        1usize << (n_outliers - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transforms;

    #[test]
    fn eq8_prediction_matches_rotation() {
        let mut rng = Rng::new(42);
        for _ in 0..5 {
            let tok = OutlierToken::sample(256, 3, 2000.0, 0.5, &mut rng);
            let t = tok.materialize(&mut rng);
            let x = Matrix::from_vec(1, 256, t);
            let r = transforms::rotation(256).unwrap();
            let rotated = x.matmul(&r);
            let got = rotated.abs_max() as f64;
            let want = tok.predicted_rotated_max();
            assert!((got - want).abs() < 6.0 * 0.5, "got {got}, want {want}");
        }
    }

    #[test]
    fn eq7_values_near_centroids() {
        let mut rng = Rng::new(7);
        let tok = OutlierToken::sample(512, 3, 3000.0, 0.01, &mut rng);
        let t = tok.materialize(&mut rng);
        let x = Matrix::from_vec(1, 512, t);
        let r = transforms::rotation(512).unwrap();
        let rotated = x.matmul(&r);
        let centroids = tok.centroid_magnitudes().unwrap();
        assert!(centroids.len() <= predicted_cluster_count(3) + 1);
        for &v in rotated.as_slice() {
            let mag = v.abs() as f64;
            let nearest =
                centroids.iter().map(|c| (c - mag).abs()).fold(f64::INFINITY, f64::min);
            assert!(nearest < 0.5, "value {mag} far from all centroids");
        }
    }

    #[test]
    fn cluster_count_formula() {
        assert_eq!(predicted_cluster_count(0), 1);
        assert_eq!(predicted_cluster_count(1), 1);
        assert_eq!(predicted_cluster_count(4), 8);
    }

    #[test]
    fn too_many_outliers_is_an_error_not_a_panic() {
        let tok = OutlierToken {
            dim: 64,
            dims: (0..21).collect(),
            values: vec![100.0; 21],
            sigma: 0.1,
        };
        let err = tok.centroid_magnitudes().unwrap_err();
        assert!(err.contains("20"), "{err}");
        // at the boundary the enumeration still works
        let ok = OutlierToken { dim: 64, dims: (0..2).collect(), values: vec![10.0; 2], sigma: 0.1 };
        assert!(ok.centroid_magnitudes().is_ok());
    }

    #[test]
    fn eq9_smooth_rotate_shrinks_max() {
        let mut rng = Rng::new(11);
        let tok = OutlierToken::sample(704, 8, 6000.0, 0.5, &mut rng);
        let x = tok.materialize_batch(32, &mut rng);
        let mut w = Matrix::zeros(704, 128);
        for v in w.as_mut_slice() {
            *v = 0.05 * rng.normal() as f32;
        }
        let (xr, _) = transforms::apply(transforms::Mode::Rotate, &x, &w, 0.5).unwrap();
        let (xsr, _) = transforms::apply(transforms::Mode::SmoothRotate, &x, &w, 0.5).unwrap();
        let max_rot = xr.abs_max() as f64;
        let max_sr = xsr.abs_max() as f64;
        assert!(max_sr < 0.25 * max_rot, "rot {max_rot} sr {max_sr}");
        // Eq. 9 prediction within a factor of ~2
        let mut wmax = vec![0.0f32; 704];
        for i in 0..704 {
            wmax[i] = w.row(i).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        }
        let pred = tok.predicted_smooth_rotated_max(&wmax);
        assert!(max_sr < 2.0 * pred + 3.0, "sr {max_sr} pred {pred}");
        assert!(max_sr > 0.3 * pred - 3.0, "sr {max_sr} pred {pred}");
    }

    #[test]
    fn materialize_batch_only_first_row_is_massive() {
        let mut rng = Rng::new(3);
        let tok = OutlierToken::sample(128, 2, 1000.0, 0.1, &mut rng);
        let x = tok.materialize_batch(8, &mut rng);
        let row_max = x.row_abs_max();
        assert!(row_max[0] > 500.0);
        for &m in &row_max[1..] {
            assert!(m < 10.0);
        }
    }
}
