//! High-level experiment drivers tying runtime + coordinator + report.
//!
//! Used by the `smoothrot` binary, the examples and the benches, so each
//! of those stays a thin shell.  Two backends:
//!
//! * **pjrt** — the production path: capture + analyze artifacts executed
//!   through PJRT (alpha/bits fixed at AOT time by the manifest; needs
//!   the `pjrt` cargo feature),
//! * **native** — the rust mirror: same jobs, pure-rust math; supports
//!   arbitrary alpha/bits, used for sweeps and as the cross-check.
//!
//! Both executors also plug into the serving path: [`PjrtExecutor`] and
//! [`crate::serve::NativeBatchExecutor`] implement the coordinator's
//! [`Executor`], which the serving core adapts into batch dispatches
//! (see [`crate::serve`]).
//!
//! The third driver is [`calibrate_synthetic`] (`smoothrot calibrate`):
//! it streams the synthetic workload through sharded
//! [`crate::calib::stats`] collectors, grid-searches a per-layer
//! transform plan, and returns a versioned [`QuantPlan`] artifact plus
//! the analyze-derived grid [`check_plan_matches_policy`] pins the plan
//! against.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{anyhow, Context, Result};

use crate::calib::plan::{Provenance, QuantPlan};
use crate::calib::search::{self, SearchConfig};
use crate::calib::stats::LayerCollector;
use crate::coordinator::{
    build_jobs, run_jobs, ExperimentGrid, Executor, Job, PoolConfig, RunMetrics,
};
use crate::kernels::fused::analyze_all_modes;
use crate::kernels::workspace::Workspace;
use crate::runtime::{AnalyzeOut, Capture, Runtime};
use crate::tensor::{Matrix, Stack};
use crate::transforms::RotationCache;

/// Which executor processes the jobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Pjrt,
    Native,
}

impl Backend {
    pub fn from_name(s: &str) -> Result<Backend> {
        match s {
            "pjrt" => Ok(Backend::Pjrt),
            "native" => Ok(Backend::Native),
            _ => Err(anyhow!("unknown backend {s:?} (want pjrt|native)")),
        }
    }
}

/// PJRT-backed executor: owns a runtime built inside its worker thread.
pub struct PjrtExecutor {
    runtime: Runtime,
}

impl PjrtExecutor {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self, String> {
        let runtime = Runtime::new(artifacts_dir.into()).map_err(|e| e.to_string())?;
        // Pre-warm: compile every analyze artifact NOW so no request pays
        // the multi-second first-compile cost (perf pass: this moved the
        // serve demo's p95 from ~3.6 s to the steady-state latency).
        let names: Vec<String> = runtime
            .manifest()
            .artifacts
            .keys()
            .filter(|n| n.starts_with("analyze_"))
            .cloned()
            .collect();
        for name in names {
            runtime.executable(&name).map_err(|e| e.to_string())?;
        }
        Ok(Self { runtime })
    }
}

impl Executor for PjrtExecutor {
    fn run(&mut self, job: &Job) -> Result<AnalyzeOut, String> {
        // alpha/bits are baked into the analyze artifact at AOT time; the
        // coordinator only schedules jobs matching the manifest config.
        self.runtime.analyze(&job.x, &job.w).map_err(|e| e.to_string())
    }
}

/// The captured activations plus per-module weight stacks.
pub struct Workload {
    /// Output of the capture artifact (per-module activation stacks).
    pub capture: Capture,
    /// Weight stack per module kind, loaded from `params/*.bin`.
    pub weights: BTreeMap<&'static str, Stack>,
}

/// Run the capture artifact and load the weight stacks for all modules.
pub fn load_workload(rt: &Runtime) -> Result<Workload> {
    let capture = rt.capture()?;
    let mut weights = BTreeMap::new();
    for module in crate::MODULES {
        let spec = rt
            .manifest()
            .modules
            .get(module)
            .with_context(|| format!("manifest missing module {module}"))?;
        let w = rt.load_weight_stack(&spec.weight, spec.c_in, spec.c_out)?;
        weights.insert(module, w);
    }
    Ok(Workload { capture, weights })
}

impl Workload {
    /// Borrow the capture stack for each module kind.
    pub fn stacks(&self, rt: &Runtime) -> BTreeMap<&'static str, &Stack> {
        let mut map = BTreeMap::new();
        for module in crate::MODULES {
            let out_name = &rt.manifest().modules[module].capture_output;
            map.insert(module, self.capture.by_output(out_name).expect("capture output"));
        }
        map
    }

    /// One (X, W) pair.
    pub fn pair(&self, rt: &Runtime, module: &'static str, layer: usize) -> (Matrix, Matrix) {
        let out_name = &rt.manifest().modules[module].capture_output;
        let x = self.capture.by_output(out_name).expect("capture output").layer(layer);
        let w = self.weights[module].layer(layer);
        (x, w)
    }
}

/// Result of a full-grid experiment run.
pub struct ExperimentRun {
    /// Per-(module, layer) analysis outputs.
    pub grid: ExperimentGrid,
    /// Coordinator timing/backpressure counters for the run.
    pub metrics: RunMetrics,
}

/// Run the full (layer × module) analysis sweep.
///
/// The runtime is created on the caller's thread for capture/weights; the
/// PJRT backend then builds one additional runtime per worker thread.
pub fn run_full_experiment(
    artifacts_dir: &str,
    pool: PoolConfig,
    backend: Backend,
) -> Result<ExperimentRun> {
    let rt = Runtime::new(artifacts_dir)?;
    let cfg = rt.manifest().config.clone();
    let workload = load_workload(&rt)?;
    let stacks = workload.stacks(&rt);
    let weights_ref: BTreeMap<&'static str, &Stack> =
        workload.weights.iter().map(|(k, v)| (*k, v)).collect();
    let jobs = build_jobs(&stacks, &weights_ref, cfg.alpha as f32, cfg.bits);

    let (results, metrics) = match backend {
        // each worker owns a fused-engine executor: persistent rotation
        // cache + workspace, kernels fanned out over pool.threads
        Backend::Native => {
            let threads = pool.threads;
            run_jobs(jobs, pool, move |_| {
                Ok(crate::serve::NativeJobExecutor(
                    crate::serve::NativeBatchExecutor::with_threads(threads),
                ))
            })
            .map_err(|e| anyhow!(e))?
        }
        Backend::Pjrt => {
            let dir = artifacts_dir.to_string();
            run_jobs(jobs, pool, move |_| PjrtExecutor::new(dir.clone())).map_err(|e| anyhow!(e))?
        }
    };
    Ok(ExperimentRun { grid: ExperimentGrid::from_results(cfg.n_layers, &results), metrics })
}

/// Native-only sweep over migration strength alpha for one module.
/// Returns (alpha, per-layer smooth-mode errors).  One rotation cache
/// and workspace are shared across every (alpha, layer) cell, and the
/// fused kernels fan out over `threads` (`0` = all cores).
pub fn alpha_sweep(
    rt: &Runtime,
    workload: &Workload,
    module: &'static str,
    alphas: &[f64],
    bits: u32,
    threads: usize,
) -> Result<Vec<(f64, Vec<f64>)>> {
    let n_layers = rt.manifest().config.n_layers;
    let mut cache = RotationCache::new();
    let mut scratch = Workspace::new();
    let mut out = Vec::with_capacity(alphas.len());
    for &alpha in alphas {
        let mut errs = Vec::with_capacity(n_layers);
        for layer in 0..n_layers {
            let (x, w) = workload.pair(rt, module, layer);
            let a = analyze_all_modes(&x, &w, bits, alpha as f32, &mut cache, &mut scratch, threads)
                .map_err(|e| anyhow!(e))?;
            errs.push(a.errors[crate::transforms::Mode::Smooth.index()]);
        }
        out.push((alpha, errs));
    }
    Ok(out)
}

/// Configuration of a synthetic-stream calibration run
/// (`smoothrot calibrate`).
#[derive(Clone, Debug)]
pub struct CalibrateConfig {
    /// Layers to calibrate per module (clamped to the synth depth).
    pub layers: usize,
    /// Token rows per streamed batch.
    pub rows_per_batch: usize,
    /// Batches streamed per (module, layer).
    pub batches: usize,
    /// Parallel collector shards the stream is split over (merged
    /// deterministically in shard order).
    pub shards: usize,
    /// Sample-reservoir cap per cell (`0` = retain the full stream —
    /// required for the exact policy-equivalence pin).
    pub max_sample_rows: usize,
    /// Synthetic stream seed.
    pub seed: u64,
    /// Plan-search grids and margin.
    pub search: SearchConfig,
}

impl Default for CalibrateConfig {
    fn default() -> Self {
        Self {
            layers: 8,
            rows_per_batch: 32,
            batches: 2,
            shards: 2,
            max_sample_rows: 0,
            seed: 2025,
            search: SearchConfig::default(),
        }
    }
}

/// Output of [`calibrate_synthetic`]: the persisted artifact plus the
/// analyze-derived grid at the first grid point, which the
/// calibrate-vs-analyze equivalence pin compares policies on.
pub struct CalibrationRun {
    /// The versioned plan (save with [`QuantPlan::save`]).
    pub plan: QuantPlan,
    /// `analyze_all_modes` output per cell at `(alphas[0],
    /// bits_grid[0])`.
    pub grid: ExperimentGrid,
    /// `(module, layer, bits, predicted, executed)` rows when
    /// [`SearchConfig::exec_check`] re-evaluated the chosen entries
    /// through the real integer kernels (empty otherwise) — calibration
    /// reporting the error the deployment will *execute*, not just the
    /// f32 simulation.
    pub executed: Vec<(String, usize, u32, f64, f64)>,
}

/// Calibrate over the native synthetic workload: per (module, layer)
/// the activation stream is generated batch by batch, split over
/// [`CalibrateConfig::shards`] collector shards (each accumulating a
/// mergeable [`LayerCollector`] in its own scoped thread), merged in
/// shard order, and handed to the plan search — the streaming
/// replacement for the experiment path's all-at-once matrix passes.
pub fn calibrate_synthetic(cfg: &CalibrateConfig) -> Result<CalibrationRun> {
    cfg.search.validate().map_err(|e| anyhow!(e))?;
    if cfg.layers == 0 || cfg.batches == 0 || cfg.rows_per_batch == 0 {
        return Err(anyhow!("calibrate: layers, batches and rows must all be >= 1"));
    }
    let shards = cfg.shards.max(1).min(cfg.batches);
    let mut cache = RotationCache::new();
    let mut scratch = Workspace::new();
    let mut entries = Vec::new();
    let mut executed = Vec::new();
    let mut grid: Option<ExperimentGrid> = None;

    for module in crate::MODULES {
        let (base_spec, c_out) =
            crate::synth::module_stream(module, cfg.seed).expect("known module");
        let layers = cfg.layers.min(base_spec.n_layers);
        let channels = base_spec.channels;
        if grid.is_none() {
            grid = Some(ExperimentGrid::new(layers));
        }
        for layer in 0..layers {
            // weights come from the base seed so every batch of the
            // stream pairs with the same W
            let w = base_spec.weight(c_out, layer);
            // shard k streams the contiguous batch range [k*per,
            // (k+1)*per) — merging in k order reproduces the
            // single-stream concatenation exactly
            let per = (cfg.batches + shards - 1) / shards;
            let shard_collectors: Vec<LayerCollector> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..shards)
                    .map(|k| {
                        let lo = k * per;
                        let hi = ((k + 1) * per).min(cfg.batches);
                        s.spawn(move || {
                            // the user's reservoir cap applies per
                            // shard too, so collection memory is
                            // bounded while the stream is in flight,
                            // not only after the merge
                            let mut c = LayerCollector::new(channels, cfg.max_sample_rows);
                            for b in lo..hi {
                                let (mut spec, _) = crate::synth::module_stream(
                                    module,
                                    cfg.seed.wrapping_add((b as u64 + 1) * 0x9E37_79B9),
                                )
                                .expect("known module");
                                spec.n_tokens = cfg.rows_per_batch;
                                c.observe(&spec.layer(layer)).expect("consistent widths");
                            }
                            c
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("collector shard panicked")).collect()
            });
            let mut collector = LayerCollector::new(channels, cfg.max_sample_rows);
            for shard in &shard_collectors {
                collector.merge(shard).map_err(|e| anyhow!(e))?;
            }
            let found = search::search_layer(
                module,
                layer,
                &collector,
                &w,
                &cfg.search,
                &mut cache,
                &mut scratch,
            )
            .map_err(|e| anyhow!(e))?;
            if let Some(g) = grid.as_mut() {
                if let Some(row) = g.cells.get_mut(module) {
                    if layer < row.len() {
                        row[layer] = Some(found.base);
                    }
                }
            }
            for (e, &exec) in found.entries.iter().zip(&found.executed) {
                executed.push((e.module.clone(), e.layer, e.bits, e.predicted_error, exec));
            }
            entries.extend(found.entries);
        }
    }
    let plan = QuantPlan {
        provenance: Provenance {
            seed: cfg.seed,
            alphas: cfg.search.alphas.clone(),
            bits_grid: cfg.search.bits_grid.clone(),
            sr_margin: cfg.search.sr_margin,
            threads: cfg.search.threads,
            ..Provenance::default()
        },
        entries,
    };
    Ok(CalibrationRun { plan, grid: grid.unwrap_or_else(|| ExperimentGrid::new(0)), executed })
}

/// The calibrate-vs-analyze equivalence pin: on a single-alpha grid the
/// plan's chosen transform per (module, layer) must equal
/// [`crate::policy::recommend`] on the analyze-derived grid of the same
/// workload (they share [`search::choose_mode`], so a divergence means
/// the bridge broke).  On wider alpha grids the plan may only *improve*
/// on the single-alpha choice, which is what is checked instead.
pub fn check_plan_matches_policy(run: &CalibrationRun) -> Result<(), String> {
    let sr_margin = run.plan.provenance.sr_margin;
    let bits = *run.plan.provenance.bits_grid.first().ok_or("plan has an empty bits grid")?;
    let single_alpha = run.plan.provenance.alphas.len() == 1;
    let policy = crate::policy::recommend(
        &run.grid,
        crate::policy::PolicyConfig { sr_margin },
    );
    for (module, modes) in &policy.cells {
        for (layer, &want) in modes.iter().enumerate() {
            let Some(errors) = run.grid.cell_errors(module, layer) else { continue };
            let Some(entry) = run.plan.get(module, layer, bits) else {
                return Err(format!("plan is missing calibrated cell {module} layer {layer}"));
            };
            if single_alpha {
                if entry.mode != want {
                    return Err(format!(
                        "equivalence violation: {module} layer {layer}: plan chose {} but policy::recommend chose {}",
                        entry.mode.name(),
                        want.name()
                    ));
                }
            } else {
                let single_err = errors[want.index()];
                if entry.predicted_error > single_err * (1.0 + 1e-9) {
                    return Err(format!(
                        "equivalence violation: {module} layer {layer}: plan error {} exceeds the single-alpha policy error {}",
                        entry.predicted_error, single_err
                    ));
                }
            }
        }
    }
    Ok(())
}

/// Native-only sweep over quantization bit width (extension experiment).
/// Returns (bits, mode) -> total error across all modules/layers, with
/// the same shared cache/workspace reuse as [`alpha_sweep`].
pub fn bits_sweep(
    rt: &Runtime,
    workload: &Workload,
    bits_grid: &[u32],
    threads: usize,
) -> Result<Vec<(u32, [f64; 4])>> {
    let cfg = rt.manifest().config.clone();
    let mut cache = RotationCache::new();
    let mut scratch = Workspace::new();
    let mut out = Vec::new();
    for &bits in bits_grid {
        let mut totals = [0.0f64; 4];
        for module in crate::MODULES {
            for layer in 0..cfg.n_layers {
                let (x, w) = workload.pair(rt, module, layer);
                let a = analyze_all_modes(
                    &x,
                    &w,
                    bits,
                    cfg.alpha as f32,
                    &mut cache,
                    &mut scratch,
                    threads,
                )
                .map_err(|e| anyhow!(e))?;
                for i in 0..4 {
                    totals[i] += a.errors[i];
                }
            }
        }
        out.push((bits, totals));
    }
    Ok(out)
}
