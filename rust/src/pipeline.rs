//! High-level experiment drivers tying runtime + coordinator + report.
//!
//! Used by the `smoothrot` binary, the examples and the benches, so each
//! of those stays a thin shell.  Two backends:
//!
//! * **pjrt** — the production path: capture + analyze artifacts executed
//!   through PJRT (alpha/bits fixed at AOT time by the manifest; needs
//!   the `pjrt` cargo feature),
//! * **native** — the rust mirror: same jobs, pure-rust math; supports
//!   arbitrary alpha/bits, used for sweeps and as the cross-check.
//!
//! Both executors also plug into the serving path: [`PjrtExecutor`] and
//! [`crate::serve::NativeBatchExecutor`] implement the coordinator's
//! [`Executor`], which the serving core adapts into batch dispatches
//! (see [`crate::serve`]).

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::{
    build_jobs, run_jobs, ExperimentGrid, Executor, Job, PoolConfig, RunMetrics,
};
use crate::kernels::fused::analyze_all_modes;
use crate::kernels::workspace::Workspace;
use crate::runtime::{AnalyzeOut, Capture, Runtime};
use crate::tensor::{Matrix, Stack};
use crate::transforms::RotationCache;

/// Which executor processes the jobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Pjrt,
    Native,
}

impl Backend {
    pub fn from_name(s: &str) -> Result<Backend> {
        match s {
            "pjrt" => Ok(Backend::Pjrt),
            "native" => Ok(Backend::Native),
            _ => Err(anyhow!("unknown backend {s:?} (want pjrt|native)")),
        }
    }
}

/// PJRT-backed executor: owns a runtime built inside its worker thread.
pub struct PjrtExecutor {
    runtime: Runtime,
}

impl PjrtExecutor {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self, String> {
        let runtime = Runtime::new(artifacts_dir.into()).map_err(|e| e.to_string())?;
        // Pre-warm: compile every analyze artifact NOW so no request pays
        // the multi-second first-compile cost (perf pass: this moved the
        // serve demo's p95 from ~3.6 s to the steady-state latency).
        let names: Vec<String> = runtime
            .manifest()
            .artifacts
            .keys()
            .filter(|n| n.starts_with("analyze_"))
            .cloned()
            .collect();
        for name in names {
            runtime.executable(&name).map_err(|e| e.to_string())?;
        }
        Ok(Self { runtime })
    }
}

impl Executor for PjrtExecutor {
    fn run(&mut self, job: &Job) -> Result<AnalyzeOut, String> {
        // alpha/bits are baked into the analyze artifact at AOT time; the
        // coordinator only schedules jobs matching the manifest config.
        self.runtime.analyze(&job.x, &job.w).map_err(|e| e.to_string())
    }
}

/// The captured activations plus per-module weight stacks.
pub struct Workload {
    /// Output of the capture artifact (per-module activation stacks).
    pub capture: Capture,
    /// Weight stack per module kind, loaded from `params/*.bin`.
    pub weights: BTreeMap<&'static str, Stack>,
}

/// Run the capture artifact and load the weight stacks for all modules.
pub fn load_workload(rt: &Runtime) -> Result<Workload> {
    let capture = rt.capture()?;
    let mut weights = BTreeMap::new();
    for module in crate::MODULES {
        let spec = rt
            .manifest()
            .modules
            .get(module)
            .with_context(|| format!("manifest missing module {module}"))?;
        let w = rt.load_weight_stack(&spec.weight, spec.c_in, spec.c_out)?;
        weights.insert(module, w);
    }
    Ok(Workload { capture, weights })
}

impl Workload {
    /// Borrow the capture stack for each module kind.
    pub fn stacks(&self, rt: &Runtime) -> BTreeMap<&'static str, &Stack> {
        let mut map = BTreeMap::new();
        for module in crate::MODULES {
            let out_name = &rt.manifest().modules[module].capture_output;
            map.insert(module, self.capture.by_output(out_name).expect("capture output"));
        }
        map
    }

    /// One (X, W) pair.
    pub fn pair(&self, rt: &Runtime, module: &'static str, layer: usize) -> (Matrix, Matrix) {
        let out_name = &rt.manifest().modules[module].capture_output;
        let x = self.capture.by_output(out_name).expect("capture output").layer(layer);
        let w = self.weights[module].layer(layer);
        (x, w)
    }
}

/// Result of a full-grid experiment run.
pub struct ExperimentRun {
    /// Per-(module, layer) analysis outputs.
    pub grid: ExperimentGrid,
    /// Coordinator timing/backpressure counters for the run.
    pub metrics: RunMetrics,
}

/// Run the full (layer × module) analysis sweep.
///
/// The runtime is created on the caller's thread for capture/weights; the
/// PJRT backend then builds one additional runtime per worker thread.
pub fn run_full_experiment(
    artifacts_dir: &str,
    pool: PoolConfig,
    backend: Backend,
) -> Result<ExperimentRun> {
    let rt = Runtime::new(artifacts_dir)?;
    let cfg = rt.manifest().config.clone();
    let workload = load_workload(&rt)?;
    let stacks = workload.stacks(&rt);
    let weights_ref: BTreeMap<&'static str, &Stack> =
        workload.weights.iter().map(|(k, v)| (*k, v)).collect();
    let jobs = build_jobs(&stacks, &weights_ref, cfg.alpha as f32, cfg.bits);

    let (results, metrics) = match backend {
        // each worker owns a fused-engine executor: persistent rotation
        // cache + workspace, kernels fanned out over pool.threads
        Backend::Native => {
            let threads = pool.threads;
            run_jobs(jobs, pool, move |_| Ok(crate::serve::NativeBatchExecutor::with_threads(threads)))
                .map_err(|e| anyhow!(e))?
        }
        Backend::Pjrt => {
            let dir = artifacts_dir.to_string();
            run_jobs(jobs, pool, move |_| PjrtExecutor::new(dir.clone())).map_err(|e| anyhow!(e))?
        }
    };
    Ok(ExperimentRun { grid: ExperimentGrid::from_results(cfg.n_layers, &results), metrics })
}

/// Native-only sweep over migration strength alpha for one module.
/// Returns (alpha, per-layer smooth-mode errors).  One rotation cache
/// and workspace are shared across every (alpha, layer) cell, and the
/// fused kernels fan out over `threads` (`0` = all cores).
pub fn alpha_sweep(
    rt: &Runtime,
    workload: &Workload,
    module: &'static str,
    alphas: &[f64],
    bits: u32,
    threads: usize,
) -> Result<Vec<(f64, Vec<f64>)>> {
    let n_layers = rt.manifest().config.n_layers;
    let mut cache = RotationCache::new();
    let mut scratch = Workspace::new();
    let mut out = Vec::with_capacity(alphas.len());
    for &alpha in alphas {
        let mut errs = Vec::with_capacity(n_layers);
        for layer in 0..n_layers {
            let (x, w) = workload.pair(rt, module, layer);
            let a = analyze_all_modes(&x, &w, bits, alpha as f32, &mut cache, &mut scratch, threads)
                .map_err(|e| anyhow!(e))?;
            errs.push(a.errors[crate::transforms::Mode::Smooth.index()]);
        }
        out.push((alpha, errs));
    }
    Ok(out)
}

/// Native-only sweep over quantization bit width (extension experiment).
/// Returns (bits, mode) -> total error across all modules/layers, with
/// the same shared cache/workspace reuse as [`alpha_sweep`].
pub fn bits_sweep(
    rt: &Runtime,
    workload: &Workload,
    bits_grid: &[u32],
    threads: usize,
) -> Result<Vec<(u32, [f64; 4])>> {
    let cfg = rt.manifest().config.clone();
    let mut cache = RotationCache::new();
    let mut scratch = Workspace::new();
    let mut out = Vec::new();
    for &bits in bits_grid {
        let mut totals = [0.0f64; 4];
        for module in crate::MODULES {
            for layer in 0..cfg.n_layers {
                let (x, w) = workload.pair(rt, module, layer);
                let a = analyze_all_modes(
                    &x,
                    &w,
                    bits,
                    cfg.alpha as f32,
                    &mut cache,
                    &mut scratch,
                    threads,
                )
                .map_err(|e| anyhow!(e))?;
                for i in 0..4 {
                    totals[i] += a.errors[i];
                }
            }
        }
        out.push((bits, totals));
    }
    Ok(out)
}
