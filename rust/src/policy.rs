//! Transform deployment policy — the paper's Sec. V recommendation as a
//! first-class feature.
//!
//! The paper concludes: *"we currently recommend Smooth Rotation only for
//! down projection layers, where it effectively mitigates massive
//! outliers"* — i.e. the transform to deploy is a per-module decision
//! informed by the measured errors, balanced against smooth-rotation's
//! costs (weight-difficulty increase + calibration dependence).
//!
//! [`recommend`] turns an [`ExperimentGrid`] into a [`Policy`]: per
//! (module, layer) the error-minimizing transform, except that
//! smooth-rotation is only chosen where its advantage over the best
//! calibration-free transform exceeds `sr_margin` (the paper's
//! conservatism), plus per-module-kind defaults for deployments that
//! cannot specialize per layer.

use crate::coordinator::ExperimentGrid;
use crate::jsonio::{obj, Json};
use crate::transforms::Mode;

/// Policy construction parameters.
#[derive(Clone, Copy, Debug)]
pub struct PolicyConfig {
    /// Minimum relative advantage (error ratio) smooth-rotation must show
    /// over the best calibration-free transform to be selected.
    pub sr_margin: f64,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        // require 25% improvement before taking on calibration dependence
        Self { sr_margin: 1.25 }
    }
}

/// Chosen transform per (module, layer) plus per-module defaults.
#[derive(Clone, Debug)]
pub struct Policy {
    /// cells[module] = one mode per layer.
    pub cells: Vec<(&'static str, Vec<Mode>)>,
    /// Majority mode per module kind.
    pub module_defaults: Vec<(&'static str, Mode)>,
}

/// Pick per-cell transforms from measured errors.
///
/// Re-expressed on top of the calibration plan search: each cell goes
/// through [`crate::calib::search::choose_mode`] — the same Sec. V
/// chooser `smoothrot calibrate` uses — so an offline `recommend` run
/// and a calibration plan built from the same workload can never
/// disagree (pinned by `rust/tests/calib_equivalence.rs`).
pub fn recommend(grid: &ExperimentGrid, cfg: PolicyConfig) -> Policy {
    let mut cells = Vec::new();
    let mut module_defaults = Vec::new();
    for module in crate::MODULES {
        let mut modes = Vec::with_capacity(grid.n_layers);
        for layer in 0..grid.n_layers {
            // calibration-free = none|rotate (smoothing is grouped with
            // the calibration-dependent transforms under the paper's
            // stricter reading); smooth-rotation must beat the best
            // free option by sr_margin to pay for its calibration
            // dependence — all encoded in the shared chooser.
            let mode = match grid.cell_errors(module, layer) {
                None => Mode::None,
                Some(errors) => crate::calib::search::choose_mode(&errors, cfg.sr_margin),
            };
            modes.push(mode);
        }
        // majority default
        let default = Mode::ALL
            .into_iter()
            .max_by_key(|m| modes.iter().filter(|&&x| x == *m).count())
            .unwrap();
        module_defaults.push((module, default));
        cells.push((module, modes));
    }
    Policy { cells, module_defaults }
}

impl Policy {
    /// How many layers of a module chose `mode`.
    pub fn count(&self, module: &str, mode: Mode) -> usize {
        self.cells
            .iter()
            .find(|(m, _)| *m == module)
            .map(|(_, modes)| modes.iter().filter(|&&x| x == mode).count())
            .unwrap_or(0)
    }

    /// Serialize to JSON for deployment tooling.
    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "module_defaults",
                Json::Obj(
                    self.module_defaults
                        .iter()
                        .map(|(m, mode)| (m.to_string(), Json::Str(mode.name().into())))
                        .collect(),
                ),
            ),
            (
                "layers",
                Json::Obj(
                    self.cells
                        .iter()
                        .map(|(m, modes)| {
                            (
                                m.to_string(),
                                Json::Arr(
                                    modes.iter().map(|x| Json::Str(x.name().into())).collect(),
                                ),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Human-readable summary.
    pub fn summary(&self) -> String {
        let mut s = String::from("# transform deployment policy (paper Sec. V)\n");
        for (module, default) in &self.module_defaults {
            let sr = self.count(module, Mode::SmoothRotate);
            let rot = self.count(module, Mode::Rotate);
            let none = self.count(module, Mode::None);
            s.push_str(&format!(
                "{module:>10}: default {:<14} (per-layer: rotate {rot}, smooth_rotate {sr}, none {none})\n",
                default.name()
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::AnalyzeOut;

    fn grid_with(down_massive: &[usize]) -> ExperimentGrid {
        let mut g = ExperimentGrid::new(4);
        for module in crate::MODULES {
            for layer in 0..4 {
                let massive = module == "down_proj" && down_massive.contains(&layer);
                let mut out = AnalyzeOut::default();
                // ordinary cell: rotate slightly best, sr marginally better
                // massive cell: rotate worse than none, sr hugely better
                out.errors = if massive {
                    [100.0, 40.0, 150.0, 2.0]
                } else {
                    [10.0, 6.0, 4.0, 3.5]
                };
                g.cells.get_mut(module).unwrap()[layer] = Some(out);
            }
        }
        g
    }

    #[test]
    fn massive_layers_get_smooth_rotation() {
        let g = grid_with(&[1, 3]);
        let p = recommend(&g, PolicyConfig::default());
        let down = &p.cells.iter().find(|(m, _)| *m == "down_proj").unwrap().1;
        assert_eq!(down[1], Mode::SmoothRotate);
        assert_eq!(down[3], Mode::SmoothRotate);
        // ordinary layers stay calibration-free: 4.0 / 3.5 < 1.25 margin
        assert_eq!(down[0], Mode::Rotate);
    }

    #[test]
    fn margin_controls_sr_adoption() {
        let g = grid_with(&[]);
        let eager = recommend(&g, PolicyConfig { sr_margin: 1.0 });
        let conservative = recommend(&g, PolicyConfig { sr_margin: 2.0 });
        assert!(eager.count("k_proj", Mode::SmoothRotate) > 0);
        assert_eq!(conservative.count("k_proj", Mode::SmoothRotate), 0);
    }

    #[test]
    fn defaults_are_majorities() {
        let g = grid_with(&[1]);
        let p = recommend(&g, PolicyConfig::default());
        let (_, d) = p.module_defaults.iter().find(|(m, _)| *m == "k_proj").unwrap();
        assert_eq!(*d, Mode::Rotate);
    }

    #[test]
    fn json_roundtrips() {
        let g = grid_with(&[1]);
        let p = recommend(&g, PolicyConfig::default());
        let j = p.to_json();
        let parsed = crate::jsonio::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(
            parsed.path(&["layers", "down_proj"]).unwrap().as_arr().unwrap()[1].as_str(),
            Some("smooth_rotate")
        );
        assert!(p.summary().contains("down_proj"));
    }

    #[test]
    fn empty_cells_default_to_none() {
        let g = ExperimentGrid::new(2);
        let p = recommend(&g, PolicyConfig::default());
        assert_eq!(p.count("k_proj", Mode::None), 2);
    }
}
