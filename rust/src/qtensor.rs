//! Integer tensor substrate — the storage side of real integer
//! execution.
//!
//! Everything upstream of this module *simulates* quantization: the
//! `quant::qdq_*` kernels round onto the Eq. 1 grid and immediately
//! return to f32, so the hot-path matmuls stay float.  A [`QMatrix`]
//! instead **keeps** the integer codes: row-major `i8` values (or
//! bit-packed `i4` nibbles for 4-bit grids) plus the per-token or
//! per-channel f32 grid steps, exactly the `(q, Δ)` factorization of
//! Eq. 1.  The companion GEMM ([`crate::kernels::igemm`]) multiplies the
//! codes in `i32` and applies the scale product `Δx_i · Δw_j` once per
//! output element.
//!
//! The quantizer reuses the RTN symmetric grid of [`crate::quant`]
//! verbatim — same `round(v / Δ)` rounding, same per-token
//! ([`crate::quant::token_scales`]) and per-channel
//! ([`crate::quant::channel_scales`]) steps — so
//! [`QMatrix::dequantize`] reproduces `quant::qdq` **bit for bit**:
//! `round(v/Δ)` saturates inside the grid (±qmax) by construction, and
//! `q as f32 * Δ` is the same multiply `qdq_val` performs.  The
//! equivalence proptests (`rust/tests/proptest_igemm.rs`) pin both that
//! identity and the integer-GEMM-vs-fake-quant agreement.
//!
//! [`PlannedWeight`] is the serving-side unit: a weight matrix
//! transformed per its calibration-plan entry (Eq. 4 smoothing rows,
//! Eq. 3 rotation) and quantized per-channel **once** — the plan
//! registry builds one per covered entry at load time so requests only
//! ever quantize their activation rows.  Alongside the row-major codes
//! it carries a [`PackedWeight`]: the same codes rearranged into
//! GEMM-ready output-channel tiles (i4 pre-unpacked to `i8` at pack
//! time), which the register-blocked integer microkernel
//! ([`crate::kernels::igemm::igemm_packed_into`]) streams contiguously.

use crate::kernels::simd;
use crate::kernels::workspace::Workspace;
use crate::metrics::{self, Channels};
use crate::quant;
use crate::tensor::Matrix;
use crate::transforms::Rotation;

/// Which axis the grid steps run along.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleAxis {
    /// One grid step per row — the paper's per-token activation setting.
    PerRow,
    /// One grid step per column — the paper's per-channel weight setting.
    PerCol,
}

/// Integer value storage of a [`QMatrix`].
#[derive(Clone, Debug)]
pub enum QStorage {
    /// One byte per value.
    I8(Vec<i8>),
    /// Two 4-bit two's-complement nibbles per byte, packed in flat
    /// row-major order (low nibble first); see [`pack_i4`].
    I4(Vec<u8>),
}

/// Pack a flat slice of 4-bit values (each in `-8..=7`) into nibbles:
/// value `2t` lands in the low nibble of byte `t`, value `2t + 1` in
/// the high nibble.  An odd trailing value leaves the high nibble zero.
pub fn pack_i4(vals: &[i8]) -> Vec<u8> {
    let mut out = vec![0u8; (vals.len() + 1) / 2];
    for (idx, &v) in vals.iter().enumerate() {
        debug_assert!((-8..=7).contains(&v), "i4 value out of range: {v}");
        let nib = (v as u8) & 0x0F;
        if idx % 2 == 0 {
            out[idx / 2] |= nib;
        } else {
            out[idx / 2] |= nib << 4;
        }
    }
    out
}

/// Inverse of [`pack_i4`]: sign-extend `len` nibbles back to `i8`.
pub fn unpack_i4(packed: &[u8], len: usize, out: &mut [i8]) {
    assert!(out.len() >= len, "unpack_i4 output too short");
    assert!(packed.len() >= (len + 1) / 2, "unpack_i4 input too short");
    for (idx, o) in out.iter_mut().take(len).enumerate() {
        let byte = packed[idx / 2];
        let nib = if idx % 2 == 0 { byte & 0x0F } else { byte >> 4 };
        // shift the nibble to the top of the byte, then arithmetic
        // shift back down to sign-extend 4-bit two's complement
        *o = ((nib << 4) as i8) >> 4;
    }
}

/// A quantized matrix: integer codes plus the f32 grid steps that map
/// them back to values (`value = code * Δ`), i.e. Eq. 1 held in its
/// factored form instead of collapsed back to f32.
#[derive(Clone, Debug)]
pub struct QMatrix {
    rows: usize,
    cols: usize,
    bits: u32,
    axis: ScaleAxis,
    /// Grid steps Δ: one per row ([`ScaleAxis::PerRow`]) or per column
    /// ([`ScaleAxis::PerCol`]).
    scales: Vec<f32>,
    data: QStorage,
}

/// Quantize one row-major matrix into `out` under the given steps.
fn quantize_flat(x: &Matrix, deltas: &[f32], axis: ScaleAxis, qm: f32, out: &mut [i8]) {
    let (rows, cols) = x.shape();
    debug_assert_eq!(out.len(), rows * cols);
    // per-token rows ride the serving hot path: dispatch the code
    // conversion to the active SIMD backend, resolved once on the
    // calling thread (bit-identical to the scalar loop by contract)
    let backend = simd::current();
    for i in 0..rows {
        let row = x.row(i);
        let orow = &mut out[i * cols..(i + 1) * cols];
        match axis {
            ScaleAxis::PerRow => {
                let d = deltas[i];
                if d > 0.0 {
                    simd::quantize_row(backend, row, d, qm, orow);
                } else {
                    orow.fill(0);
                }
            }
            ScaleAxis::PerCol => {
                for ((o, &v), &d) in orow.iter_mut().zip(row).zip(deltas) {
                    *o = if d > 0.0 { (v / d).round().clamp(-qm, qm) as i8 } else { 0 };
                }
            }
        }
    }
}

impl QMatrix {
    /// The RTN symmetric grid steps of `x` along `axis` — identical to
    /// [`crate::quant::token_scales`] / [`crate::quant::channel_scales`].
    fn grid(x: &Matrix, bits: u32, axis: ScaleAxis) -> Result<Vec<f32>, String> {
        quant::validate_bits(bits).map_err(|e| e.to_string())?;
        if bits > 8 {
            return Err(format!(
                "integer execution stores i8/i4 codes: bits {bits} exceeds 8"
            ));
        }
        Ok(match axis {
            ScaleAxis::PerRow => {
                // same grid as quant::token_scales, with the per-row
                // abs-max reduction dispatched to the active SIMD
                // backend (exact: max is order-free over finite f32)
                let backend = simd::current();
                let qm = quant::qmax(bits);
                (0..x.rows()).map(|i| simd::row_absmax(backend, x.row(i)) / qm).collect()
            }
            ScaleAxis::PerCol => quant::channel_scales(x, bits),
        })
    }

    /// One shared quantization body: fill the caller-supplied code
    /// buffer (owned or workspace-pooled) under the Eq. 1 grid.
    fn quantize_into(
        x: &Matrix,
        bits: u32,
        axis: ScaleAxis,
        mut codes: Vec<i8>,
    ) -> Result<QMatrix, String> {
        let scales = Self::grid(x, bits, axis)?;
        let qm = quant::qmax(bits);
        let (rows, cols) = x.shape();
        debug_assert_eq!(codes.len(), rows * cols);
        quantize_flat(x, &scales, axis, qm, &mut codes);
        Ok(QMatrix { rows, cols, bits, axis, scales, data: QStorage::I8(codes) })
    }

    /// Quantize `x` onto the symmetric b-bit grid, keeping the codes:
    /// bit-packed `i4` storage for `bits == 4`, plain `i8` otherwise
    /// (`bits` must be in `2..=8`).
    pub fn quantize(x: &Matrix, bits: u32, axis: ScaleAxis) -> Result<QMatrix, String> {
        let mut q = Self::quantize_i8(x, bits, axis)?;
        if bits == 4 {
            if let QStorage::I8(codes) = &q.data {
                q.data = QStorage::I4(pack_i4(codes));
            }
        }
        Ok(q)
    }

    /// [`QMatrix::quantize`] forced to plain `i8` storage regardless of
    /// bit width — for operands that live on the GEMM hot path, where a
    /// per-call nibble unpack would cost more than the halved memory
    /// saves (e.g. planned serving weights, multiplied every request).
    pub fn quantize_i8(x: &Matrix, bits: u32, axis: ScaleAxis) -> Result<QMatrix, String> {
        let len = x.rows() * x.cols();
        Self::quantize_into(x, bits, axis, vec![0i8; len])
    }

    /// [`QMatrix::quantize_i8`] with the code buffer drawn from the
    /// caller's [`Workspace`] — the per-request activation path, where
    /// the buffer is pooled and only the O(rows) scale vector
    /// allocates.  Return the buffer with [`QMatrix::recycle`].
    pub fn quantize_i8_with(
        x: &Matrix,
        bits: u32,
        axis: ScaleAxis,
        ws: &mut Workspace,
    ) -> Result<QMatrix, String> {
        let codes = ws.take_i8(x.rows() * x.cols());
        Self::quantize_into(x, bits, axis, codes)
    }

    /// Return a workspace-backed code buffer to its pool (packed `i4`
    /// storage is simply dropped).
    pub fn recycle(self, ws: &mut Workspace) {
        if let QStorage::I8(codes) = self.data {
            ws.give_i8(codes);
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Grid bit width.
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Which axis the grid steps run along.
    pub fn axis(&self) -> ScaleAxis {
        self.axis
    }

    /// Grid steps Δ (length `rows` or `cols` per [`QMatrix::axis`]).
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Whether the codes are bit-packed `i4` nibbles.
    pub fn is_packed(&self) -> bool {
        matches!(self.data, QStorage::I4(_))
    }

    /// Borrow the codes directly when stored as plain `i8`.
    pub fn i8_codes(&self) -> Option<&[i8]> {
        match &self.data {
            QStorage::I8(v) => Some(v),
            QStorage::I4(_) => None,
        }
    }

    /// Write all `rows * cols` codes into `out` as `i8`, unpacking
    /// nibbles when the storage is `i4`.
    pub fn unpack_into(&self, out: &mut [i8]) {
        let len = self.rows * self.cols;
        assert!(out.len() >= len, "unpack_into output too short");
        match &self.data {
            QStorage::I8(v) => out[..len].copy_from_slice(v),
            QStorage::I4(packed) => unpack_i4(packed, len, out),
        }
    }

    /// Map the codes back to f32 — **bit-identical** to
    /// [`crate::quant::qdq`] at the matching granularity, because the
    /// codes are the same `round(v/Δ)` and the dequantizing multiply is
    /// the same `q * Δ` (see the module docs for why saturation never
    /// fires inside the grid).
    pub fn dequantize(&self) -> Matrix {
        let len = self.rows * self.cols;
        let mut codes = vec![0i8; len];
        self.unpack_into(&mut codes);
        let mut data = vec![0.0f32; len];
        match self.axis {
            ScaleAxis::PerRow => {
                for i in 0..self.rows {
                    let d = self.scales[i];
                    for j in 0..self.cols {
                        data[i * self.cols + j] = codes[i * self.cols + j] as f32 * d;
                    }
                }
            }
            ScaleAxis::PerCol => {
                for i in 0..self.rows {
                    for (j, &d) in self.scales.iter().enumerate() {
                        data[i * self.cols + j] = codes[i * self.cols + j] as f32 * d;
                    }
                }
            }
        }
        Matrix::from_vec(self.rows, self.cols, data)
    }
}

/// A [`QMatrix`] weight rearranged into the integer GEMM's preferred
/// memory layout: output-channel **tiles** of [`PackedWeight::TILE`]
/// columns, each tile storing its `k` rows contiguously
/// (`tile-major, k-contiguous` — panel element `(kk, jr)` of tile `t`
/// lives at `t·k·TILE + kk·TILE + jr`).
///
/// This layout is not merely a cache optimization — it is the **ABI
/// the SIMD microkernels assume** ([`crate::kernels::simd::tile_dot`]):
///
/// * one `k` step of a panel is exactly `TILE = 16` contiguous `i8`
///   codes, i.e. one unaligned 128-bit vector load (`TILE` is
///   re-exported from [`crate::kernels::simd::TILE`] so the two sides
///   cannot drift apart),
/// * codes are plain `i8` — `i4` storage is unpacked at pack time, so
///   the microkernel never sees a nibble,
/// * the ragged trailing tile is zero-padded to full width: the SIMD
///   kernel always multiply-accumulates all 16 lanes, and the padding
///   lanes contribute exactly zero to the integer product, so no lane
///   masking is needed,
/// * panel addresses carry no alignment guarantee (`Vec<i8>` storage);
///   the kernels use unaligned loads by contract.
///
/// The `packed_panel_layout_is_the_simd_abi` self-test pins the flat
/// index formula element by element.
///
/// Row-major weight codes make the microkernel's inner loop read a full
/// `n`-wide row per `k` step — a strided, cache-hostile access once `n`
/// outgrows a few cache lines.  Packed tiles let the register-blocked
/// kernel ([`crate::kernels::igemm::igemm_packed_into`]) hold one tile's
/// `TILE` partial sums in `i32` registers and stream exactly
/// `TILE` contiguous bytes per `k` step.  Ragged trailing tiles are
/// zero-padded (zero codes contribute nothing to the integer product),
/// and `i4` storage is unpacked to plain `i8` **at pack time** — the
/// plan registry packs once per entry at plan load, so the per-request
/// hot loop never touches a nibble.
///
/// Packing reorders *storage only*: the per-element products and their
/// `k`-ascending accumulation order are untouched, and integer addition
/// is associative, so the packed GEMM is **bit-identical** to the
/// row-major one (pinned in `rust/tests/proptest_batchfused.rs`).
#[derive(Clone, Debug)]
pub struct PackedWeight {
    k: usize,
    n: usize,
    bits: u32,
    /// Per-output-channel grid steps (length `n`).
    scales: Vec<f32>,
    /// `ceil(n / TILE)` panels of `k · TILE` codes each.
    data: Vec<i8>,
}

impl PackedWeight {
    /// Output channels per packed tile.  16 `i32` accumulators fit the
    /// register budget of every target the crate cares about while
    /// keeping ragged-edge waste under one tile.  Shared with the SIMD
    /// microkernels as [`crate::kernels::simd::TILE`] — one `k` step of
    /// a panel is one 128-bit load there.
    pub const TILE: usize = simd::TILE;

    /// Rearrange a per-channel-quantized weight into packed tiles,
    /// unpacking `i4` nibble storage to plain `i8` on the way.
    pub fn pack(qw: &QMatrix) -> Result<PackedWeight, String> {
        if qw.axis() != ScaleAxis::PerCol {
            return Err("packed weight needs per-column (per-channel) scales".to_string());
        }
        let (k, n) = qw.shape();
        let tiles = n.div_ceil(Self::TILE);
        let mut codes = vec![0i8; k * n];
        qw.unpack_into(&mut codes);
        let mut data = vec![0i8; tiles * k * Self::TILE];
        for t in 0..tiles {
            let j0 = t * Self::TILE;
            let jw = Self::TILE.min(n - j0);
            let panel = &mut data[t * k * Self::TILE..(t + 1) * k * Self::TILE];
            for kk in 0..k {
                for jr in 0..jw {
                    panel[kk * Self::TILE + jr] = codes[kk * n + j0 + jr];
                }
            }
        }
        Ok(PackedWeight { k, n, bits: qw.bits(), scales: qw.scales().to_vec(), data })
    }

    /// Logical (unpadded) shape `(k, n)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.k, self.n)
    }

    /// Grid bit width of the packed codes.
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Per-output-channel grid steps Δw (length `n`).
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Number of packed tiles (`ceil(n / TILE)`).
    pub fn tiles(&self) -> usize {
        self.n.div_ceil(Self::TILE)
    }

    /// Tile `t`'s panel: `k · TILE` codes, `k`-contiguous rows of
    /// `TILE` columns (trailing tile zero-padded).
    pub fn panel(&self, t: usize) -> &[i8] {
        &self.data[t * self.k * Self::TILE..(t + 1) * self.k * Self::TILE]
    }
}

/// A serving-ready weight: transformed per its calibration-plan entry
/// and quantized per-channel **once**, plus the transformed weight's
/// difficulty metric so the integer request path never needs the f32
/// weight again.  Only the GEMM-ready tile layout is retained
/// ([`PackedWeight`]: plain `i8` codes even for 4-bit grids, packed-i4
/// [`QMatrix::quantize`] remains the at-rest / artifact form) — the
/// row-major [`QMatrix`] built during preparation is transient, so a
/// long-lived registry pins one copy of every covered weight's codes,
/// not two.
#[derive(Clone, Debug)]
pub struct PlannedWeight {
    /// The transformed, per-channel-quantized weight in the
    /// microkernel's tile layout — the only form the serving GEMM
    /// reads (shape checks go through [`PackedWeight::shape`]).
    pub packed: PackedWeight,
    /// `metrics::quant_difficulty` of the transformed f32 weight,
    /// captured at preparation time (the integer path reports it
    /// without re-materializing the transformed weight).
    pub w_difficulty: f64,
}

impl PlannedWeight {
    /// Quantize an already-transformed weight per-channel at `bits`.
    pub fn prepare(wh: &Matrix, bits: u32) -> Result<PlannedWeight, String> {
        let qw = QMatrix::quantize_i8(wh, bits, ScaleAxis::PerCol)?;
        let packed = PackedWeight::pack(&qw)?;
        let w_difficulty = metrics::quant_difficulty(wh, Channels::Rows);
        Ok(PlannedWeight { packed, w_difficulty })
    }

    /// Apply a plan entry's weight-side transform (Eq. 4 row scaling by
    /// `s`, then Eq. 3 rotation `R^T W`) and quantize the result — what
    /// the plan registry runs once per covered entry at load time.
    pub fn from_plan(
        w: &Matrix,
        smooth: Option<&[f32]>,
        rot: Option<&Rotation>,
        bits: u32,
        threads: usize,
    ) -> Result<PlannedWeight, String> {
        let mut wh = w.clone();
        if let Some(s) = smooth {
            if s.len() != wh.rows() {
                return Err(format!(
                    "planned weight: smoothing vector has {} channels, weight has {} rows",
                    s.len(),
                    wh.rows()
                ));
            }
            wh.scale_rows_mut(s);
        }
        let wh = match rot {
            Some(r) => {
                if r.dim() != wh.rows() {
                    return Err(format!(
                        "planned weight: rotation is {}-wide, weight has {} rows",
                        r.dim(),
                        wh.rows()
                    ));
                }
                r.apply_left_t(&wh, threads)
            }
            None => wh,
        };
        Self::prepare(&wh, bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Granularity;
    use crate::rng::Rng;

    fn rand_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(rows, cols, rng.normals_f32(rows * cols))
    }

    #[test]
    fn i4_pack_unpack_roundtrip_identity() {
        // every representable nibble value, odd length included
        let vals: Vec<i8> = (-8..=7).chain([-7, 0, 7]).collect();
        let packed = pack_i4(&vals);
        assert_eq!(packed.len(), (vals.len() + 1) / 2);
        let mut got = vec![0i8; vals.len()];
        unpack_i4(&packed, vals.len(), &mut got);
        assert_eq!(got, vals);
    }

    #[test]
    fn dequantize_is_bit_identical_to_qdq() {
        let x = rand_matrix(9, 17, 1);
        for (bits, packed) in [(8u32, false), (5, false), (4, true)] {
            let qr = QMatrix::quantize(&x, bits, ScaleAxis::PerRow).unwrap();
            assert_eq!(qr.is_packed(), packed, "bits {bits}");
            assert_eq!(
                qr.dequantize().as_slice(),
                quant::qdq(&x, bits, Granularity::PerToken).as_slice(),
                "per-row bits {bits}"
            );
            let qc = QMatrix::quantize(&x, bits, ScaleAxis::PerCol).unwrap();
            assert_eq!(
                qc.dequantize().as_slice(),
                quant::qdq(&x, bits, Granularity::PerChannel).as_slice(),
                "per-col bits {bits}"
            );
        }
    }

    #[test]
    fn workspace_path_matches_owned_quantize() {
        let x = rand_matrix(6, 10, 2);
        let mut ws = Workspace::new();
        let a = QMatrix::quantize_i8_with(&x, 8, ScaleAxis::PerRow, &mut ws).unwrap();
        let b = QMatrix::quantize(&x, 8, ScaleAxis::PerRow).unwrap();
        assert_eq!(a.dequantize().as_slice(), b.dequantize().as_slice());
        assert_eq!(a.scales(), b.scales());
        a.recycle(&mut ws);
        // the recycled buffer is reused on the next request
        let c = QMatrix::quantize_i8_with(&x, 8, ScaleAxis::PerRow, &mut ws).unwrap();
        let (reuses, _) = ws.stats();
        assert_eq!(reuses, 1);
        c.recycle(&mut ws);
    }

    #[test]
    fn zero_rows_quantize_to_zero_codes() {
        let x = Matrix::zeros(3, 4);
        let q = QMatrix::quantize(&x, 8, ScaleAxis::PerRow).unwrap();
        assert_eq!(q.dequantize().as_slice(), x.as_slice());
    }

    #[test]
    fn out_of_range_bits_are_named_errors() {
        let x = Matrix::zeros(2, 2);
        let err = QMatrix::quantize(&x, 1, ScaleAxis::PerRow).unwrap_err();
        assert!(err.contains("unsupported bit width 1"), "{err}");
        let err = QMatrix::quantize(&x, 16, ScaleAxis::PerRow).unwrap_err();
        assert!(err.contains("exceeds 8"), "{err}");
    }

    #[test]
    fn packed_weight_reorders_codes_without_changing_them() {
        let w = rand_matrix(13, 21, 9); // ragged: 21 = 16 + 5
        for bits in [4u32, 8] {
            // pack from both storage kinds: plain i8 and nibble-packed i4
            for qw in [
                QMatrix::quantize_i8(&w, bits, ScaleAxis::PerCol).unwrap(),
                QMatrix::quantize(&w, bits, ScaleAxis::PerCol).unwrap(),
            ] {
                let pw = PackedWeight::pack(&qw).unwrap();
                assert_eq!(pw.shape(), qw.shape());
                assert_eq!(pw.bits(), bits);
                assert_eq!(pw.scales(), qw.scales());
                assert_eq!(pw.tiles(), 2);
                let mut codes = vec![0i8; 13 * 21];
                qw.unpack_into(&mut codes);
                for t in 0..pw.tiles() {
                    let panel = pw.panel(t);
                    let j0 = t * PackedWeight::TILE;
                    for kk in 0..13 {
                        for jr in 0..PackedWeight::TILE {
                            let want = if j0 + jr < 21 { codes[kk * 21 + j0 + jr] } else { 0 };
                            assert_eq!(
                                panel[kk * PackedWeight::TILE + jr],
                                want,
                                "bits {bits} tile {t} k {kk} jr {jr}"
                            );
                        }
                    }
                }
            }
        }
        // per-row scales are rejected
        let qr = QMatrix::quantize(&w, 8, ScaleAxis::PerRow).unwrap();
        assert!(PackedWeight::pack(&qr).unwrap_err().contains("per-column"));
    }

    #[test]
    fn packed_panel_layout_is_the_simd_abi() {
        // the flat-index formula the SIMD microkernel assumes: panel
        // element (kk, jr) of tile t at t*k*TILE + kk*TILE + jr, plain
        // i8 codes, ragged tail zero-padded to full tile width
        assert_eq!(PackedWeight::TILE, simd::TILE);
        assert_eq!(PackedWeight::TILE, 16, "the SIMD kernels hardcode 128-bit panel steps");
        const T: usize = PackedWeight::TILE;
        let (k, n) = (5usize, 2 * T + 3); // two full tiles + a ragged one
        let w = rand_matrix(k, n, 77);
        let qw = QMatrix::quantize_i8(&w, 8, ScaleAxis::PerCol).unwrap();
        let codes = qw.i8_codes().unwrap().to_vec();
        let pw = PackedWeight::pack(&qw).unwrap();
        assert_eq!(pw.tiles(), 3);
        assert_eq!(pw.data.len(), pw.tiles() * k * T);
        for t in 0..pw.tiles() {
            // panel(t) is a view into the flat buffer at t*k*TILE
            assert_eq!(pw.panel(t).as_ptr(), pw.data[t * k * T..].as_ptr());
            for kk in 0..k {
                for jr in 0..T {
                    let j = t * T + jr;
                    let want = if j < n { codes[kk * n + j] } else { 0 };
                    assert_eq!(
                        pw.data[t * k * T + kk * T + jr],
                        want,
                        "tile {t} k-step {kk} lane {jr}"
                    );
                }
            }
        }
    }

    #[test]
    fn planned_weight_transforms_then_quantizes() {
        let w = rand_matrix(16, 6, 3);
        let s: Vec<f32> = (0..16).map(|i| 1.0 + 0.1 * i as f32).collect();
        let rot = Rotation::build(16).unwrap();
        let pw = PlannedWeight::from_plan(&w, Some(&s), Some(&rot), 4, 1).unwrap();
        // reference: transform by hand, then quantize
        let mut wh = w.clone();
        wh.scale_rows_mut(&s);
        let wh = rot.apply_left_t(&wh, 1);
        let want = QMatrix::quantize(&wh, 4, ScaleAxis::PerCol).unwrap();
        let want_packed = PackedWeight::pack(&want).unwrap();
        assert_eq!(pw.packed.shape(), want_packed.shape());
        assert_eq!(pw.packed.scales(), want_packed.scales());
        for t in 0..want_packed.tiles() {
            assert_eq!(pw.packed.panel(t), want_packed.panel(t), "tile {t}");
        }
        assert_eq!(pw.w_difficulty, metrics::quant_difficulty(&wh, Channels::Rows));
        // mismatched transform widths are named errors
        assert!(PlannedWeight::from_plan(&w, Some(&s[..4]), None, 4, 1).is_err());
        let bad_rot = Rotation::build(8).unwrap();
        assert!(PlannedWeight::from_plan(&w, None, Some(&bad_rot), 4, 1).is_err());
    }
}
