//! RTN symmetric quantization — the rust-native mirror of Eq. 1–2.
//!
//! Identical semantics to `python/compile/kernels/quant.py` /
//! `qerror.py`: symmetric integer grid, RTN rounding, per-token
//! (activations) and per-channel (weights) granularity, no clipping.
//! Integration tests pin this module against the PJRT-executed Pallas
//! kernels.

use std::fmt;

use crate::tensor::Matrix;

/// Quantization granularity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One grid per row (token) — the paper's activation setting.
    PerToken,
    /// One grid per column (output channel) — the paper's weight setting.
    PerChannel,
    /// A single grid for the whole tensor.
    PerTensor,
}

/// A bit width outside the supported symmetric-grid range.
///
/// Returned (not panicked) by [`try_qmax`] / [`validate_bits`] so CLI
/// inputs like `--bits 1` surface as named errors instead of asserts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BitsError {
    /// The rejected bit width.
    pub bits: u32,
}

impl fmt::Display for BitsError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "unsupported bit width {} (supported range: 2..=16)", self.bits)
    }
}

impl std::error::Error for BitsError {}

/// Validate a bit width against the supported symmetric-grid range.
pub fn validate_bits(bits: u32) -> Result<(), BitsError> {
    if (2..=16).contains(&bits) {
        Ok(())
    } else {
        Err(BitsError { bits })
    }
}

/// [`qmax`] that returns a named error instead of panicking — the entry
/// point for bit widths that arrive from user input.
pub fn try_qmax(bits: u32) -> Result<f32, BitsError> {
    validate_bits(bits)?;
    Ok(((1u32 << (bits - 1)) - 1) as f32)
}

/// Largest positive level of a symmetric b-bit integer grid (Eq. 1).
///
/// Panics on out-of-range widths; validate first with
/// [`validate_bits`] / [`try_qmax`] when `bits` is user-provided.
pub fn qmax(bits: u32) -> f32 {
    match try_qmax(bits) {
        Ok(v) => v,
        Err(e) => panic!("{e}"),
    }
}

#[inline]
fn qdq_val(v: f32, delta: f32) -> f32 {
    if delta > 0.0 {
        (v / delta).round() * delta
    } else {
        0.0
    }
}

// ---------------------------------------------------------------------
// Slice-level kernels — the building blocks the fused analyze engine
// (`kernels::fused`) consumes directly, so it never re-materializes a
// whole-matrix intermediate it only needs one row of.
// ---------------------------------------------------------------------

/// In-place quantize-dequantize of a slice sharing one grid step.
pub fn qdq_slice(xs: &mut [f32], delta: f32) {
    for v in xs {
        *v = qdq_val(*v, delta);
    }
}

/// In-place quantize-dequantize of one row under per-column grid steps.
pub fn qdq_slice_cols(xs: &mut [f32], deltas: &[f32]) {
    debug_assert_eq!(xs.len(), deltas.len());
    for (v, &d) in xs.iter_mut().zip(deltas) {
        *v = qdq_val(*v, d);
    }
}

/// One-pass `Q(x)` **and** residual `x - Q(x)` for a token row (one
/// shared grid step) — the two factors of the Eq. 2 delta identity in
/// a single read of the source.
pub fn qdq_split_slice(src: &[f32], delta: f32, q: &mut [f32], resid: &mut [f32]) {
    debug_assert!(src.len() == q.len() && src.len() == resid.len());
    for ((&s, qv), rv) in src.iter().zip(q.iter_mut()).zip(resid.iter_mut()) {
        let val = qdq_val(s, delta);
        *qv = val;
        *rv = s - val;
    }
}

/// Residual `x - Q(x)` for one row under per-column grid steps.
pub fn qdq_resid_cols(src: &[f32], deltas: &[f32], resid: &mut [f32]) {
    debug_assert!(src.len() == deltas.len() && src.len() == resid.len());
    for ((&s, &d), rv) in src.iter().zip(deltas).zip(resid.iter_mut()) {
        *rv = s - qdq_val(s, d);
    }
}

/// Per-token quantization steps Delta (one per row).
pub fn token_scales(x: &Matrix, bits: u32) -> Vec<f32> {
    let qm = qmax(bits);
    x.row_abs_max().iter().map(|&m| m / qm).collect()
}

/// Per-output-channel quantization steps Delta (one per column).
pub fn channel_scales(w: &Matrix, bits: u32) -> Vec<f32> {
    let qm = qmax(bits);
    w.col_abs_max().iter().map(|&m| m / qm).collect()
}

/// Quantize-dequantize a copy of `x` at the given granularity.
pub fn qdq(x: &Matrix, bits: u32, gran: Granularity) -> Matrix {
    let rows = x.rows();
    let mut out = x.clone();
    match gran {
        Granularity::PerToken => {
            let deltas = token_scales(x, bits);
            for i in 0..rows {
                qdq_slice(out.row_mut(i), deltas[i]);
            }
        }
        Granularity::PerChannel => {
            let deltas = channel_scales(x, bits);
            for i in 0..rows {
                qdq_slice_cols(out.row_mut(i), &deltas);
            }
        }
        Granularity::PerTensor => {
            let delta = x.abs_max() / qmax(bits);
            for v in out.as_mut_slice() {
                *v = qdq_val(*v, delta);
            }
        }
    }
    out
}

/// Layer-wise quantization error (Eq. 2): `||XW - Q(X)Q(W)||_F^2`,
/// with per-token X and per-channel W grids.
pub fn quant_error(x: &Matrix, w: &Matrix, bits: u32) -> f64 {
    let y = x.matmul(w);
    let yq = qdq(x, bits, Granularity::PerToken).matmul(&qdq(w, bits, Granularity::PerChannel));
    y.sub(&yq).frob_sq()
}

/// Fused version of [`quant_error`] — mirrors the L1 Pallas hot-path
/// kernel's one-accumulator structure via the delta identity
///
/// ```text
/// Y - Yq = (X - Q(X)) W  +  Q(X) (W - Q(W))
/// ```
///
/// so only ONE (n, c_out) accumulator is materialized (vs Y and Yq plus
/// a subtraction pass in the naive pipeline).  The residual factor
/// `X - Q(X)` is sparse-ish (zero where values sit exactly on the
/// grid), so its product goes through the dedicated zero-skip kernel
/// [`Matrix::matmul_acc_sparse`]; the dense `Q(X)` product uses the
/// branch-free cache-blocked kernel.
pub fn quant_error_fused(x: &Matrix, w: &Matrix, bits: u32) -> f64 {
    let (n, c_in) = x.shape();
    let (c_in2, c_out) = w.shape();
    assert_eq!(c_in, c_in2);
    let xq = qdq(x, bits, Granularity::PerToken);
    let wq = qdq(w, bits, Granularity::PerChannel);
    let dx = x.sub(&xq); // X - Q(X)
    let dw = w.sub(&wq); // W - Q(W)
    let mut acc = Matrix::zeros(n, c_out);
    acc.matmul_acc_sparse(&dx, w);
    acc.matmul_acc(&xq, &dw);
    acc.frob_sq()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(rows, cols, rng.normals_f32(rows * cols))
    }

    #[test]
    fn qmax_values() {
        assert_eq!(qmax(4), 7.0);
        assert_eq!(qmax(8), 127.0);
        assert_eq!(qmax(2), 1.0);
    }

    #[test]
    #[should_panic]
    fn qmax_rejects_1bit() {
        qmax(1);
    }

    #[test]
    fn qdq_zero_tensor_stays_zero() {
        let x = Matrix::zeros(4, 4);
        for gran in [Granularity::PerToken, Granularity::PerChannel, Granularity::PerTensor] {
            assert_eq!(qdq(&x, 4, gran).as_slice(), x.as_slice());
        }
    }

    #[test]
    fn qdq_extremes_exact() {
        // the row max must quantize to itself (it defines the grid)
        let x = Matrix::from_vec(1, 4, vec![1.0, -1.0, 0.5, 0.0]);
        let q = qdq(&x, 4, Granularity::PerToken);
        assert!((q.get(0, 0) - 1.0).abs() < 1e-7);
        assert!((q.get(0, 1) + 1.0).abs() < 1e-7);
    }

    #[test]
    fn qdq_idempotent() {
        let x = rand_matrix(16, 32, 1);
        let q1 = qdq(&x, 4, Granularity::PerToken);
        let q2 = qdq(&q1, 4, Granularity::PerToken);
        for (a, b) in q1.as_slice().iter().zip(q2.as_slice()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn qdq_error_bounded_by_half_step() {
        let x = rand_matrix(8, 16, 2);
        let deltas = token_scales(&x, 4);
        let q = qdq(&x, 4, Granularity::PerToken);
        for i in 0..8 {
            for j in 0..16 {
                assert!((q.get(i, j) - x.get(i, j)).abs() <= deltas[i] / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn slice_kernels_match_whole_matrix_qdq() {
        let x = rand_matrix(6, 10, 9);
        let tok = token_scales(&x, 4);
        let q_ref = qdq(&x, 4, Granularity::PerToken);
        let cols = 10;
        let mut q = vec![0.0f32; 6 * cols];
        let mut resid = vec![0.0f32; 6 * cols];
        for i in 0..6 {
            qdq_split_slice(x.row(i), tok[i], &mut q[i * cols..(i + 1) * cols], &mut resid[i * cols..(i + 1) * cols]);
        }
        for i in 0..6 {
            for j in 0..cols {
                assert_eq!(q[i * cols + j], q_ref.get(i, j), "split Q mismatch");
                assert_eq!(resid[i * cols + j], x.get(i, j) - q_ref.get(i, j), "residual mismatch");
            }
        }
        // channel residuals against the per-channel whole-matrix path
        let ch = channel_scales(&x, 4);
        let qc_ref = qdq(&x, 4, Granularity::PerChannel);
        let mut rc = vec![0.0f32; cols];
        qdq_resid_cols(x.row(2), &ch, &mut rc);
        for j in 0..cols {
            assert_eq!(rc[j], x.get(2, j) - qc_ref.get(2, j));
        }
    }

    #[test]
    fn more_bits_less_error() {
        let x = rand_matrix(32, 64, 3);
        let w = rand_matrix(64, 16, 4);
        let e2 = quant_error(&x, &w, 2);
        let e4 = quant_error(&x, &w, 4);
        let e8 = quant_error(&x, &w, 8);
        assert!(e2 > e4 && e4 > e8, "{e2} {e4} {e8}");
    }

    #[test]
    fn fused_matches_unfused() {
        let x = rand_matrix(24, 48, 5);
        let w = rand_matrix(48, 20, 6);
        let a = quant_error(&x, &w, 4);
        let b = quant_error_fused(&x, &w, 4);
        let rel = (a - b).abs() / a.max(1e-12);
        assert!(rel < 1e-4, "unfused {a} vs fused {b}");
    }

    #[test]
    fn error_zero_when_grid_exact() {
        let x = Matrix::from_vec(1, 2, vec![7.0, -7.0]);
        let w = Matrix::from_vec(2, 1, vec![7.0, 1.0]);
        assert!(quant_error(&x, &w, 4) < 1e-9);
    }

    #[test]
    fn per_tensor_coarser_than_per_token() {
        // with a huge outlier in one row, per-tensor hurts the other rows
        let mut x = rand_matrix(8, 16, 7);
        x.set(0, 0, 1000.0);
        let w = rand_matrix(16, 8, 8);
        let per_tok = {
            let yq = qdq(&x, 4, Granularity::PerToken).matmul(&qdq(&w, 4, Granularity::PerChannel));
            x.matmul(&w).sub(&yq).frob_sq()
        };
        let per_tensor = {
            let yq = qdq(&x, 4, Granularity::PerTensor).matmul(&qdq(&w, 4, Granularity::PerChannel));
            x.matmul(&w).sub(&yq).frob_sq()
        };
        assert!(per_tensor > per_tok);
    }
}
