//! Figure/table emitters — regenerates every figure of the paper as
//! CSV (for plotting) plus an ASCII rendering for the terminal.
//!
//! | paper figure | emitter |
//! |---|---|
//! | Fig 1/2 — activation magnitude maps under transforms | [`magnitude_profile_csv`], [`ascii_chart`] |
//! | Fig 3 — layer-wise error / act difficulty / weight difficulty | [`layerwise_csv`], [`fig3_report`] |
//! | Fig 4 — down_proj stats under all transforms | [`fig4_report`] |
//! | Fig 5 — outlier-token sorted magnitudes + quantization bins | [`fig5_csv`], [`fig5_report`] |
//! | §IV-B correlation headline | [`correlation_report`] |

use std::fmt::Write as _;

use crate::coordinator::ExperimentGrid;
use crate::metrics;
use crate::runtime::AnalyzeOut;
use crate::tensor::Matrix;
use crate::transforms::Mode;

/// Write rows of (label, series...) as CSV.
pub fn csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    s.push_str(&headers.join(","));
    s.push('\n');
    for row in rows {
        s.push_str(&row.join(","));
        s.push('\n');
    }
    s
}

/// Sorted per-channel magnitudes of a tensor (FlatQuant-style view used
/// by Figs 1/2/5): descending Frobenius norm per channel.
pub fn sorted_channel_magnitudes(x: &Matrix) -> Vec<f64> {
    let mut mags = x.col_norms();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    mags
}

/// CSV for a magnitude profile under each transform mode (Fig 1/2).
pub fn magnitude_profile_csv(profiles: &[(Mode, Vec<f64>)]) -> String {
    let n = profiles.iter().map(|(_, v)| v.len()).max().unwrap_or(0);
    let headers: Vec<&str> = std::iter::once("channel_rank")
        .chain(profiles.iter().map(|(m, _)| m.name()))
        .collect();
    let rows: Vec<Vec<String>> = (0..n)
        .map(|i| {
            std::iter::once(i.to_string())
                .chain(profiles.iter().map(|(_, v)| {
                    v.get(i).map(|x| format!("{x:.6}")).unwrap_or_default()
                }))
                .collect()
        })
        .collect();
    csv(&headers, &rows)
}

/// ASCII log-scale bar chart of a series (terminal rendering of figures).
pub fn ascii_chart(title: &str, labels: &[String], values: &[f64], width: usize) -> String {
    assert_eq!(labels.len(), values.len());
    let mut s = format!("## {title}\n");
    let max = values.iter().cloned().fold(f64::MIN_POSITIVE, f64::max);
    let min = values.iter().cloned().filter(|v| *v > 0.0).fold(max, f64::min);
    let log_span = (max.ln() - min.ln()).max(1e-9);
    for (label, &v) in labels.iter().zip(values) {
        let frac = if v > 0.0 { ((v.ln() - min.ln()) / log_span).clamp(0.0, 1.0) } else { 0.0 };
        let bars = 1 + (frac * (width.saturating_sub(1)) as f64).round() as usize;
        let _ = writeln!(s, "{label:>14} | {} {v:.3e}", "#".repeat(bars));
    }
    s
}

/// CSV of one statistic across layers for all modules × modes (Fig 3/4).
pub fn layerwise_csv(grid: &ExperimentGrid, stat: impl Fn(&AnalyzeOut, usize) -> f64) -> String {
    let mut headers: Vec<String> = vec!["layer".into()];
    for module in crate::MODULES {
        for mode in Mode::ALL {
            headers.push(format!("{module}.{}", mode.name()));
        }
    }
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let rows: Vec<Vec<String>> = (0..grid.n_layers)
        .map(|l| {
            let mut row = vec![l.to_string()];
            for module in crate::MODULES {
                for mode in Mode::ALL {
                    let v = grid
                        .get(module, l)
                        .map(|o| stat(o, mode.index()))
                        .unwrap_or(f64::NAN);
                    row.push(format!("{v:.6e}"));
                }
            }
            row
        })
        .collect();
    csv(&header_refs, &rows)
}

/// Fig 3 report: per-module layer trends for mode `none`.
pub fn fig3_report(grid: &ExperimentGrid) -> String {
    let mut s = String::from("# Fig 3 — layer-wise statistics (untransformed)\n\n");
    for (title, f) in [
        ("(a) quantization error", 0usize),
        ("(b) activation difficulty", 1),
        ("(c) weight difficulty", 2),
    ] {
        let _ = writeln!(s, "## Fig 3{title}");
        for module in crate::MODULES {
            let series = grid.series(module, |o| match f {
                0 => o.errors[0],
                1 => o.act_difficulty[0],
                _ => o.w_difficulty[0],
            });
            let line: Vec<String> = series.iter().map(|v| format!("{v:.3e}")).collect();
            let _ = writeln!(s, "{module:>10}: [{}]", line.join(", "));
        }
        s.push('\n');
    }
    s
}

/// Fig 4 report: down_proj error + difficulties under all four modes.
pub fn fig4_report(grid: &ExperimentGrid) -> String {
    let mut s = String::from("# Fig 4 — down_proj layer-wise statistics by transform\n\n");
    for (title, pick) in [
        ("(a) quantization error", 0usize),
        ("(b) activation difficulty", 1),
        ("(c) weight difficulty", 2),
    ] {
        let _ = writeln!(s, "## Fig 4{title}");
        for mode in Mode::ALL {
            let series = grid.series("down_proj", |o| match pick {
                0 => o.errors[mode.index()],
                1 => o.act_difficulty[mode.index()],
                _ => o.w_difficulty[mode.index()],
            });
            let line: Vec<String> = series.iter().map(|v| format!("{v:.3e}")).collect();
            let _ = writeln!(s, "{:>14}: [{}]", mode.name(), line.join(", "));
        }
        s.push('\n');
    }
    s
}

/// §IV-B headline: the correlation between error and difficulty².
pub fn correlation_report(grid: &ExperimentGrid, massive_layers: &[usize], tail_layer: usize) -> (f64, String) {
    let mut exclude: Vec<(&str, usize)> = massive_layers.iter().map(|&l| ("down_proj", l)).collect();
    exclude.push(("down_proj", tail_layer));
    exclude.push(("gate_proj", tail_layer));
    let corr = grid.headline_correlation(&exclude);
    let all = grid.headline_correlation(&[]);
    let text = format!(
        "# §IV-B correlation headline\n\
         Pearson(error, act_difficulty^2), excluding down_proj {massive_layers:?}/{tail_layer} and gate_proj {tail_layer}:\n\
         corr = {corr:.4}   (paper: > 0.97)\n\
         without exclusions: corr = {all:.4} (paper: 'not entirely linear' for massive-outlier layers)\n"
    );
    (corr, text)
}

/// Fig 5 data: sorted |values| of the max-magnitude token plus the
/// effective quantization bin edges (multiples of Delta up to max).
pub struct Fig5Data {
    pub sorted_abs: Vec<f64>,
    pub delta: f64,
    pub n_effective_bins: usize,
}

/// Extract Fig 5 data from a (possibly transformed) activation matrix:
/// takes the token (row) with the largest absolute value.
pub fn fig5_data(x: &Matrix, bits: u32) -> Fig5Data {
    let row_max = x.row_abs_max();
    let (token, _) = row_max
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .expect("empty matrix");
    let mut sorted_abs: Vec<f64> = x.row(token).iter().map(|v| v.abs() as f64).collect();
    sorted_abs.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let delta = sorted_abs[0] / crate::quant::qmax(bits) as f64;
    // effective bins: how many grid levels the token actually occupies
    let occupied: std::collections::BTreeSet<i64> = x
        .row(token)
        .iter()
        .map(|&v| if delta > 0.0 { (v as f64 / delta).round() as i64 } else { 0 })
        .collect();
    Fig5Data { sorted_abs, delta, n_effective_bins: occupied.len() }
}

/// CSV for Fig 5 curves across modes.
pub fn fig5_csv(curves: &[(Mode, Fig5Data)]) -> String {
    let n = curves.iter().map(|(_, d)| d.sorted_abs.len()).max().unwrap_or(0);
    let headers: Vec<String> = std::iter::once("rank".to_string())
        .chain(curves.iter().flat_map(|(m, _)| {
            [format!("{}_abs", m.name()), format!("{}_delta", m.name())]
        }))
        .collect();
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let rows: Vec<Vec<String>> = (0..n)
        .map(|i| {
            let mut row = vec![i.to_string()];
            for (_, d) in curves {
                row.push(d.sorted_abs.get(i).map(|v| format!("{v:.6e}")).unwrap_or_default());
                row.push(if i == 0 { format!("{:.6e}", d.delta) } else { String::new() });
            }
            row
        })
        .collect();
    csv(&header_refs, &rows)
}

/// Human-readable Fig 5 summary.
pub fn fig5_report(curves: &[(Mode, Fig5Data)]) -> String {
    let mut s = String::from("# Fig 5 — massive-outlier token: magnitudes and effective bins\n");
    for (mode, d) in curves {
        let _ = writeln!(
            s,
            "{:>14}: max={:.3e}  Delta={:.3e}  effective_bins={}  p50|v|={:.3e}",
            mode.name(),
            d.sorted_abs.first().unwrap_or(&0.0),
            d.delta,
            d.n_effective_bins,
            d.sorted_abs.get(d.sorted_abs.len() / 2).unwrap_or(&0.0),
        );
    }
    s
}

/// Markdown table: error by (mode × selected layers) for one module.
pub fn mode_layer_table(grid: &ExperimentGrid, module: &str, layers: &[usize]) -> String {
    let mut s = format!("| {module} layer |");
    for mode in Mode::ALL {
        let _ = write!(s, " {} |", mode.name());
    }
    s.push_str("\n|---|---|---|---|---|\n");
    for &l in layers {
        let _ = write!(s, "| {l} |");
        for mode in Mode::ALL {
            let v = grid.get(module, l).map(|o| o.errors[mode.index()]).unwrap_or(f64::NAN);
            let _ = write!(s, " {v:.3e} |");
        }
        s.push('\n');
    }
    s
}

/// Summary statistics table over a set of series (used by ablations).
pub fn summary_table(rows: &[(&str, &[f64])]) -> String {
    let mut s = String::from("| series | n | min | mean | max | std |\n|---|---|---|---|---|---|\n");
    for (name, xs) in rows {
        let sum = metrics::Summary::of(xs);
        let _ = writeln!(
            s,
            "| {name} | {} | {:.3e} | {:.3e} | {:.3e} | {:.3e} |",
            sum.n, sum.min, sum.mean, sum.max, sum.std
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_jobs, Job, NativeExecutor, PoolConfig};
    use crate::rng::Rng;

    fn tiny_grid() -> ExperimentGrid {
        let mut rng = Rng::new(1);
        let mut jobs = Vec::new();
        let mut id = 0;
        for module in crate::MODULES {
            for layer in 0..3 {
                jobs.push(Job {
                    id,
                    layer,
                    module,
                    x: Matrix::from_vec(8, 16, rng.normals_f32(128)),
                    w: Matrix::from_vec(16, 8, rng.normals_f32(128)),
                    alpha: 0.5,
                    bits: 4,
                });
                id += 1;
            }
        }
        let (results, _) = run_jobs(jobs, PoolConfig::default(), |_| Ok(NativeExecutor)).unwrap();
        ExperimentGrid::from_results(3, &results)
    }

    #[test]
    fn csv_shape() {
        let out = csv(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(out, "a,b\n1,2\n");
    }

    #[test]
    fn magnitude_profile_csv_has_all_modes() {
        let profiles: Vec<(Mode, Vec<f64>)> =
            Mode::ALL.iter().map(|&m| (m, vec![3.0, 2.0, 1.0])).collect();
        let out = magnitude_profile_csv(&profiles);
        assert!(out.starts_with("channel_rank,none,smooth,rotate,smooth_rotate"));
        assert_eq!(out.lines().count(), 4);
    }

    #[test]
    fn sorted_magnitudes_descending() {
        let x = Matrix::from_vec(2, 3, vec![1.0, 5.0, 2.0, 1.0, 5.0, 2.0]);
        let mags = sorted_channel_magnitudes(&x);
        assert!(mags[0] >= mags[1] && mags[1] >= mags[2]);
    }

    #[test]
    fn layerwise_csv_dimensions() {
        let grid = tiny_grid();
        let out = layerwise_csv(&grid, |o, i| o.errors[i]);
        // header + 3 layers
        assert_eq!(out.lines().count(), 4);
        // layer + 4 modules * 4 modes columns
        assert_eq!(out.lines().next().unwrap().split(',').count(), 17);
    }

    #[test]
    fn reports_mention_modules_and_modes() {
        let grid = tiny_grid();
        assert!(fig3_report(&grid).contains("down_proj"));
        assert!(fig4_report(&grid).contains("smooth_rotate"));
        let (corr, text) = correlation_report(&grid, &[1], 2);
        assert!(corr.is_finite());
        assert!(text.contains("Pearson"));
    }

    #[test]
    fn fig5_bins_shrink_with_flatter_token() {
        // flat token occupies many bins; spiky token collapses to few
        let mut rng = Rng::new(2);
        let flat = Matrix::from_vec(4, 64, rng.normals_f32(256));
        let mut spiky = Matrix::from_vec(4, 64, rng.normals_f32(256));
        spiky.set(0, 0, 10_000.0);
        let f = fig5_data(&flat, 4);
        let s = fig5_data(&spiky, 4);
        assert!(s.n_effective_bins <= 3, "spiky bins {}", s.n_effective_bins);
        assert!(f.n_effective_bins > s.n_effective_bins);
    }

    #[test]
    fn ascii_chart_renders_all_rows() {
        let out = ascii_chart("t", &["a".into(), "b".into()], &[1.0, 100.0], 20);
        assert_eq!(out.lines().count(), 3);
        assert!(out.contains('#'));
    }

    #[test]
    fn tables_render() {
        let grid = tiny_grid();
        let t = mode_layer_table(&grid, "down_proj", &[0, 2]);
        assert!(t.contains("| 0 |") && t.contains("| 2 |"));
        let s = summary_table(&[("x", &[1.0, 2.0, 3.0])]);
        assert!(s.contains("| x | 3 |"));
    }
}
