//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! SplitMix64 for seeding and Xoshiro256++ for the main stream — the
//! standard pairing.  Normal variates via Box–Muller.  Used by the
//! synthetic data generator, the property-testing harness and the
//! benches; determinism is what makes `golden.json` and the rust-native
//! mirrors comparable across runs.

/// SplitMix64 — tiny, good-enough stream for seeding.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 (any u64 works, including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()], spare_normal: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire-style rejection-free enough for our sizes.
        (self.next_u64() % (n as u64)) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Standard normal (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Vector of standard normals as f32.
    pub fn normals_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot choose {k} distinct from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Random sign, ±1.
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn choose_distinct_is_distinct_and_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..100 {
            let picked = r.choose_distinct(50, 10);
            let mut sorted = picked.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 10);
            assert!(picked.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(5);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
