//! PJRT runtime: loads the AOT artifacts and executes them.
//!
//! This is the only module that touches the `xla` crate.  It owns:
//!
//! * the artifact **manifest** (`manifest.json`, the python→rust
//!   contract: every artifact's inputs/outputs/shapes/files + the model
//!   config the artifacts were built with),
//! * raw **tensor file** loading (`params/*.bin`, little-endian f32/i32),
//! * the **executable cache**: HLO text is parsed and compiled once per
//!   artifact and reused for every subsequent call (compilation is
//!   milliseconds-to-seconds; execution is the hot path),
//! * typed entry points: [`Runtime::capture`], [`Runtime::analyze`],
//!   [`Runtime::transform`], [`Runtime::qdq_token`].
//!
//! The `xla` bindings (and their libxla_extension build) are not
//! available in every environment, so everything that executes HLO is
//! gated behind the `pjrt` cargo feature.  Without it, the manifest /
//! weight-loading half of [`Runtime`] still works (it is pure Rust) and
//! the execution entry points return a descriptive error, so the native
//! mirror, the serving core and all default tests build and run
//! everywhere.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ModelConfig;
use crate::jsonio::{self, Json};
use crate::tensor::{Matrix, Stack};
use crate::transforms::Mode;

/// One input/output slot of an artifact.
#[derive(Clone, Debug)]
pub struct SlotSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
    /// For capture inputs: the .bin file feeding this slot.
    pub file: Option<String>,
}

impl SlotSpec {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j.get("name").and_then(Json::as_str).context("slot missing name")?.to_string(),
            shape: j
                .get("shape")
                .and_then(Json::as_arr)
                .context("slot missing shape")?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            dtype: j.get("dtype").and_then(Json::as_str).unwrap_or("f32").to_string(),
            file: j.get("file").and_then(Json::as_str).map(str::to_string),
        })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Manifest entry for one HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: String,
    pub bytes: usize,
    pub inputs: Vec<SlotSpec>,
    pub outputs: Vec<SlotSpec>,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub config: ModelConfig,
    pub modes: Vec<String>,
    /// module kind -> (c_in, c_out, weight param name, capture output name)
    pub modules: BTreeMap<String, ModuleSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

#[derive(Clone, Debug)]
pub struct ModuleSpec {
    pub c_in: usize,
    pub c_out: usize,
    pub weight: String,
    pub capture_output: String,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let j = jsonio::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;

        let config = ModelConfig::from_manifest(&j).map_err(|e| anyhow!(e))?;
        let modes = j
            .get("modes")
            .and_then(Json::as_arr)
            .context("manifest missing modes")?
            .iter()
            .filter_map(Json::as_str)
            .map(str::to_string)
            .collect();

        let mut modules = BTreeMap::new();
        if let Some(Json::Obj(fields)) = j.get("modules") {
            for (name, m) in fields {
                modules.insert(
                    name.clone(),
                    ModuleSpec {
                        c_in: m.get("c_in").and_then(Json::as_usize).context("module c_in")?,
                        c_out: m.get("c_out").and_then(Json::as_usize).context("module c_out")?,
                        weight: m
                            .get("weight")
                            .and_then(Json::as_str)
                            .context("module weight")?
                            .to_string(),
                        capture_output: m
                            .get("capture_output")
                            .and_then(Json::as_str)
                            .context("module capture_output")?
                            .to_string(),
                    },
                );
            }
        }

        let mut artifacts = BTreeMap::new();
        if let Some(Json::Obj(fields)) = j.get("artifacts") {
            for (name, a) in fields {
                let inputs = a
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .context("artifact inputs")?
                    .iter()
                    .map(SlotSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                let outputs = a
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .context("artifact outputs")?
                    .iter()
                    .map(SlotSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                artifacts.insert(
                    name.clone(),
                    ArtifactSpec {
                        name: name.clone(),
                        path: a.get("path").and_then(Json::as_str).context("artifact path")?.to_string(),
                        bytes: a.get("bytes").and_then(Json::as_usize).unwrap_or(0),
                        inputs,
                        outputs,
                    },
                );
            }
        }

        let m = Self { config, modes, modules, artifacts, dir };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        if self.modes != crate::MODES {
            bail!("manifest modes {:?} != expected {:?}", self.modes, crate::MODES);
        }
        for name in crate::MODULES {
            if !self.modules.contains_key(name) {
                bail!("manifest missing module {name}");
            }
        }
        for art in self.artifacts.values() {
            let p = self.dir.join(&art.path);
            let meta = std::fs::metadata(&p)
                .with_context(|| format!("artifact file missing: {}", p.display()))?;
            if art.bytes > 0 && meta.len() as usize != art.bytes {
                bail!("artifact {} size mismatch: manifest {} vs file {}", art.name, art.bytes, meta.len());
            }
        }
        Ok(())
    }

    /// Name of the analyze artifact for a module shape.
    pub fn analyze_artifact(&self, module: &str) -> Result<String> {
        let m = self.modules.get(module).with_context(|| format!("unknown module {module}"))?;
        Ok(format!("analyze_{}x{}", m.c_in, m.c_out))
    }
}

/// Read a little-endian f32 .bin file.
pub fn read_f32_bin(path: impl AsRef<Path>) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.as_ref().display(), bytes.len());
    }
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// Read a little-endian i32 .bin file.
pub fn read_i32_bin(path: impl AsRef<Path>) -> Result<Vec<i32>> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.as_ref().display(), bytes.len());
    }
    Ok(bytes.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// The captured module-input stacks (paper Sec. III-A).
#[derive(Clone, Debug)]
pub struct Capture {
    pub attn_in: Stack,
    pub o_in: Stack,
    pub ffn_in: Stack,
    pub down_in: Stack,
}

impl Capture {
    /// Stack for a module kind by its capture-output name.
    pub fn by_output(&self, name: &str) -> Option<&Stack> {
        match name {
            "attn_in" => Some(&self.attn_in),
            "o_in" => Some(&self.o_in),
            "ffn_in" => Some(&self.ffn_in),
            "down_in" => Some(&self.down_in),
            _ => None,
        }
    }
}

/// Output of one analyze call: one value per transform mode.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AnalyzeOut {
    pub errors: [f64; 4],
    pub act_difficulty: [f64; 4],
    pub w_difficulty: [f64; 4],
    pub act_absmax: [f64; 4],
}

impl AnalyzeOut {
    pub fn for_mode(&self, mode: Mode) -> (f64, f64, f64, f64) {
        let i = mode.index();
        (self.errors[i], self.act_difficulty[i], self.w_difficulty[i], self.act_absmax[i])
    }
}

/// PJRT runtime with a compiled-executable cache.
pub struct Runtime {
    manifest: Manifest,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    cache: RefCell<BTreeMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    /// Execution counters (for the coordinator's metrics).
    pub stats: RefCell<RuntimeStats>,
}

/// Compile/execute counters kept by [`Runtime`].
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    /// Artifacts compiled so far (cache misses).
    pub compiles: u64,
    /// Artifact executions so far.
    pub executions: u64,
}

impl Runtime {
    /// Create a CPU PJRT client (when built with the `pjrt` feature) and
    /// load the manifest.
    // `return` keeps the cfg-split branches as plain statements (an
    // attribute on a tail expression would not parse on stable).
    #[allow(clippy::needless_return)]
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        #[cfg(feature = "pjrt")]
        {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            return Ok(Self {
                manifest,
                client,
                cache: RefCell::new(BTreeMap::new()),
                stats: RefCell::new(RuntimeStats::default()),
            });
        }
        #[cfg(not(feature = "pjrt"))]
        return Ok(Self { manifest, stats: RefCell::new(RuntimeStats::default()) });
    }

    /// The parsed `manifest.json` the runtime was opened on.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load a stacked weight parameter `[L, c_in, c_out]` from its .bin.
    pub fn load_weight_stack(&self, param: &str, c_in: usize, c_out: usize) -> Result<Stack> {
        let path = self.manifest.dir.join("params").join(format!("{param}.bin"));
        let data = read_f32_bin(&path)?;
        let l = self.manifest.config.n_layers;
        if data.len() != l * c_in * c_out {
            bail!("{param}.bin has {} elements, want {}", data.len(), l * c_in * c_out);
        }
        Ok(Stack::from_vec(l, c_in, c_out, data))
    }
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Compile (or fetch from cache) an artifact's executable.
    pub fn executable(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let art = self
            .manifest
            .artifacts
            .get(name)
            .with_context(|| format!("unknown artifact {name}"))?;
        let path = self.manifest.dir.join(&art.path);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.stats.borrow_mut().compiles += 1;
        let rc = std::rc::Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Execute an artifact on literal inputs; returns the output tuple.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let art = self.manifest.artifacts.get(name).with_context(|| format!("unknown artifact {name}"))?;
        if inputs.len() != art.inputs.len() {
            bail!("artifact {name} wants {} inputs, got {}", art.inputs.len(), inputs.len());
        }
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(inputs).map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        self.stats.borrow_mut().executions += 1;
        let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("fetch result of {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple result of {name}: {e:?}"))
    }

    fn matrix_literal(m: &Matrix) -> Result<xla::Literal> {
        xla::Literal::vec1(m.as_slice())
            .reshape(&[m.rows() as i64, m.cols() as i64])
            .map_err(|e| anyhow!("reshape literal: {e:?}"))
    }

    fn literal_f64s(lit: &xla::Literal) -> Result<Vec<f64>> {
        Ok(lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))?.into_iter().map(|v| v as f64).collect())
    }

    /// Run the full SynLlama forward; feeds `params/*.bin` + `tokens.bin`.
    pub fn capture(&self) -> Result<Capture> {
        let art = self.manifest.artifacts.get("capture").context("manifest missing capture")?;
        let mut inputs = Vec::with_capacity(art.inputs.len());
        for slot in &art.inputs {
            let file = slot.file.as_ref().with_context(|| format!("capture input {} has no file", slot.name))?;
            let path = self.manifest.dir.join(file);
            let lit = if slot.dtype == "i32" {
                let data = read_i32_bin(&path)?;
                if data.len() != slot.elements() {
                    bail!("{}: {} elements, want {}", path.display(), data.len(), slot.elements());
                }
                xla::Literal::vec1(&data)
            } else {
                let data = read_f32_bin(&path)?;
                if data.len() != slot.elements() {
                    bail!("{}: {} elements, want {}", path.display(), data.len(), slot.elements());
                }
                let dims: Vec<i64> = slot.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&data).reshape(&dims).map_err(|e| anyhow!("reshape {}: {e:?}", slot.name))?
            };
            inputs.push(lit);
        }
        let out = self.execute("capture", &inputs)?;
        if out.len() != 4 {
            bail!("capture returned {} outputs, want 4", out.len());
        }
        let c = &self.manifest.config;
        let (l, n, d, f) = (c.n_layers, c.seq_len, c.d_model, c.d_ffn);
        let stack = |lit: &xla::Literal, cols: usize| -> Result<Stack> {
            let data = lit.to_vec::<f32>().map_err(|e| anyhow!("capture output: {e:?}"))?;
            Ok(Stack::from_vec(l, n, cols, data))
        };
        Ok(Capture {
            attn_in: stack(&out[0], d)?,
            o_in: stack(&out[1], d)?,
            ffn_in: stack(&out[2], d)?,
            down_in: stack(&out[3], f)?,
        })
    }

    /// Run the fused analyze artifact on one (X, W) pair.
    pub fn analyze(&self, x: &Matrix, w: &Matrix) -> Result<AnalyzeOut> {
        let name = format!("analyze_{}x{}", x.cols(), w.cols());
        let out = self.execute(&name, &[Self::matrix_literal(x)?, Self::matrix_literal(w)?])?;
        if out.len() != 4 {
            bail!("{name} returned {} outputs, want 4", out.len());
        }
        let take = |lit: &xla::Literal| -> Result<[f64; 4]> {
            let v = Self::literal_f64s(lit)?;
            if v.len() != 4 {
                bail!("{name}: output length {} != 4", v.len());
            }
            Ok([v[0], v[1], v[2], v[3]])
        };
        Ok(AnalyzeOut {
            errors: take(&out[0])?,
            act_difficulty: take(&out[1])?,
            w_difficulty: take(&out[2])?,
            act_absmax: take(&out[3])?,
        })
    }

    /// Run a standalone transform artifact.
    pub fn transform(&self, mode: Mode, x: &Matrix, w: &Matrix) -> Result<(Matrix, Matrix)> {
        if mode == Mode::None {
            return Ok((x.clone(), w.clone()));
        }
        let name = format!("transform_{}_{}x{}", mode.name(), x.cols(), w.cols());
        let out = self.execute(&name, &[Self::matrix_literal(x)?, Self::matrix_literal(w)?])?;
        if out.len() != 2 {
            bail!("{name} returned {} outputs, want 2", out.len());
        }
        let xh = Matrix::from_vec(
            x.rows(),
            x.cols(),
            out[0].to_vec::<f32>().map_err(|e| anyhow!("{name} xh: {e:?}"))?,
        );
        let wh = Matrix::from_vec(
            w.rows(),
            w.cols(),
            out[1].to_vec::<f32>().map_err(|e| anyhow!("{name} wh: {e:?}"))?,
        );
        Ok((xh, wh))
    }

    /// Run the standalone per-token quantize-dequantize artifact.
    pub fn qdq_token(&self, x: &Matrix) -> Result<Matrix> {
        let name = format!("qdq_token_{}x{}", x.rows(), x.cols());
        let out = self.execute(&name, &[Self::matrix_literal(x)?])?;
        Ok(Matrix::from_vec(
            x.rows(),
            x.cols(),
            out[0].to_vec::<f32>().map_err(|e| anyhow!("{name}: {e:?}"))?,
        ))
    }
}

/// Stubs for builds without the `pjrt` feature: the manifest / weight
/// half of [`Runtime`] works everywhere, while every entry point that
/// would execute HLO reports how to enable the real backend.  Keeping
/// the signatures identical lets the pipeline, CLI and examples compile
/// unchanged.
#[cfg(not(feature = "pjrt"))]
impl Runtime {
    fn no_pjrt<T>(what: &str) -> Result<T> {
        Err(anyhow!(
            "{what} requires the PJRT backend, but this build has the `pjrt` cargo feature \
             disabled; use the native backend, or see README.md for enabling PJRT"
        ))
    }

    /// Compile an artifact's executable (PJRT builds only).
    pub fn executable(&self, name: &str) -> Result<()> {
        Self::no_pjrt(&format!("compiling artifact {name:?}"))
    }

    /// Run the full SynLlama forward (PJRT builds only).
    pub fn capture(&self) -> Result<Capture> {
        Self::no_pjrt("the capture artifact")
    }

    /// Run the fused analyze artifact (PJRT builds only).
    pub fn analyze(&self, _x: &Matrix, _w: &Matrix) -> Result<AnalyzeOut> {
        Self::no_pjrt("the analyze artifact")
    }

    /// Run a standalone transform artifact (PJRT builds only).
    pub fn transform(&self, _mode: Mode, _x: &Matrix, _w: &Matrix) -> Result<(Matrix, Matrix)> {
        Self::no_pjrt("the transform artifacts")
    }

    /// Run the per-token quantize-dequantize artifact (PJRT builds only).
    pub fn qdq_token(&self, _x: &Matrix) -> Result<Matrix> {
        Self::no_pjrt("the qdq artifact")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bin_readers_roundtrip() {
        let dir = std::env::temp_dir().join("smoothrot_bin_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, &bytes).unwrap();
        assert_eq!(read_f32_bin(&p).unwrap(), vals);
        let ints = [1i32, -7, 100];
        let bytes: Vec<u8> = ints.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, &bytes).unwrap();
        assert_eq!(read_i32_bin(&p).unwrap(), ints);
        std::fs::write(&p, [0u8; 5]).unwrap();
        assert!(read_f32_bin(&p).is_err());
    }

    #[test]
    fn manifest_missing_dir_errors() {
        assert!(Manifest::load("/nonexistent/dir").is_err());
    }

    #[test]
    fn analyze_out_mode_accessor() {
        let a = AnalyzeOut {
            errors: [1.0, 2.0, 3.0, 4.0],
            act_difficulty: [0.1, 0.2, 0.3, 0.4],
            w_difficulty: [0.0; 4],
            act_absmax: [9.0; 4],
        };
        let (e, ad, _, _) = a.for_mode(Mode::SmoothRotate);
        assert_eq!(e, 4.0);
        assert!((ad - 0.4).abs() < 1e-12);
    }
}
