//! Batched, multi-tenant serving core — the request path of the L3
//! host.
//!
//! The experiment coordinator ([`crate::coordinator`]) answers "run this
//! fixed sweep once"; this module answers the production question the
//! paper motivates (quantization "reduces the serving costs of LLMs"):
//! many tenants stream analysis requests concurrently, and the host must
//! batch compatible work, keep every tenant responsive, and bound its
//! own memory.
//!
//! ```text
//!   tenants --submit()--> per-tenant bounded queues   (admission control)
//!                               |
//!                       scheduler thread              (fair-share RR +
//!                               |                      key-coalescing batcher)
//!                     per-worker batch deques         (work-stealing pool)
//!                        |       |       |
//!                      worker  worker  worker         (one executor each)
//!                        \       |       /
//!                     streaming Response channel + latency tracking
//! ```
//!
//! Design points:
//!
//! * **Admission control** — each tenant owns a bounded queue of
//!   [`ServeConfig::queue_depth`] requests.  A full queue either blocks
//!   the submitter or rejects the request ([`Admission`]), and the
//!   scheduler keeps at most ~2 batches per worker in flight, so one
//!   noisy tenant can neither exhaust host memory nor push out other
//!   tenants — total buffered work is bounded by
//!   `tenants x queue_depth + 2 x workers x max_batch`.
//! * **Batching** — the scheduler coalesces requests whose [`BatchKey`]
//!   (module, bits, alpha, shape) matches into one dispatch of at most
//!   [`ServeConfig::max_batch`] jobs, lingering briefly for stragglers;
//!   tenant queues are indexed by key, so forming a batch never rescans
//!   a backlog.  A batch is not just a queueing unit: on plan-covered
//!   int8 cells [`NativeBatchExecutor`]'s `run_batch` executes the whole
//!   same-cell group as ONE fused kernel invocation — activation rows
//!   stacked into one tall matrix, one shared transform + quantize
//!   pass, one tall integer GEMM against the pre-quantized weight —
//!   with bit-identical per-job results
//!   ([`crate::kernels::fused::analyze_planned_int_batch`]).  Requests
//!   of the same tenant and key stay FIFO relative to each other.
//! * **Fair share** — the batch *seed* rotates round-robin over tenants,
//!   and batch *filling* takes at most one request per tenant per pass,
//!   so a tenant submitting 10x the load gets batches, not the machine.
//! * **Work stealing** — batches land on the least-loaded worker's
//!   deque; an idle worker steals from the back of the longest peer
//!   deque, keeping the pool busy under skewed batch costs.
//! * **Sharded runners** — [`shard::ShardedServer`] runs the same
//!   scheduler with *owner routing*: every worker is a full runner
//!   (its own executor, thread pool, workspace, kernel-backend pin and
//!   pre-quantized weight view), batches are routed to the runner that
//!   owns their deterministic shard key (layer or tenant), and idle
//!   runners steal only from peers holding **more than one** batch, so
//!   a runner that was routed work always executes some of it.
//!   Placement never changes per-job math, so results stay
//!   bit-identical to the single-runner path at any runner count
//!   (pinned by `rust/tests/proptest_serve_sharded.rs`).
//! * **Streaming delivery** — every completed request is sent on an
//!   unbounded channel as its batch finishes, with per-request queue /
//!   execution / total latency; each worker keeps its own sorted
//!   latency shard and [`ServeMetrics`] summarizes p50/p95/p99 by
//!   merging the shards ([`crate::metrics::Percentiles::merge`])
//!   without re-sorting a global sample vector.
//! * **Plan-driven execution** — [`NativeBatchExecutor::with_plan`]
//!   consults a calibration [`crate::calib::registry::PlanRegistry`]
//!   per job and runs only the calibrated transform
//!   (`smoothrot serve --plan`), falling back to the full four-mode
//!   analyze for uncovered cells.
//!
//! The pool is generic over [`BatchExecutor`]; any per-job
//! [`Executor`] (e.g. the PJRT-backed one) gets a batch adapter for
//! free, and executors are built *inside* their worker thread via a
//! factory, so non-`Send` executors (PJRT handles) work unchanged.
//!
//! ```
//! use smoothrot::coordinator::Job;
//! use smoothrot::serve::{serve_all, NativeBatchExecutor, ServeConfig};
//! use smoothrot::tensor::Matrix;
//!
//! // two tenants, six analysis requests
//! let requests: Vec<(usize, Job)> = (0..6)
//!     .map(|i| {
//!         let job = Job {
//!             id: i as u64,
//!             layer: 0,
//!             module: "k_proj",
//!             x: Matrix::zeros(4, 8),
//!             w: Matrix::zeros(8, 4),
//!             alpha: 0.5,
//!             bits: 4,
//!         };
//!         (i % 2, job)
//!     })
//!     .collect();
//! let (responses, metrics) =
//!     serve_all(ServeConfig::default(), requests, |_| Ok(NativeBatchExecutor::new())).unwrap();
//! assert_eq!(responses.len(), 6);
//! assert_eq!(metrics.completed, 6);
//! assert_eq!(metrics.per_tenant.len(), 2);
//! ```

pub mod net;
pub mod proto;
pub mod shard;

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::panic::{self, AssertUnwindSafe};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::calib::registry::{PlanRegistry, ResolvedEntry};
use crate::coordinator::{Executor, Job};
use crate::kernels::par::{self, ThreadPool};
use crate::kernels::simd::{self, KernelBackend};
use crate::kernels::workspace::Workspace;
use crate::metrics::{CacheStats, Percentiles};
use crate::qtensor::PlannedWeight;
use crate::runtime::AnalyzeOut;
use crate::telemetry::{self, Telemetry};
use crate::tensor::Matrix;
use crate::transforms::RotationCache;

/// Identifier of one tenant (caller) of the serving core.
pub type TenantId = usize;

/// What to do when a tenant's admission queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Block the submitting thread until the scheduler frees space.
    Block,
    /// Fail fast with [`SubmitError::Full`] (HTTP-429 semantics).
    Reject,
}

/// Serving-core configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Worker threads (each owns one executor).
    pub workers: usize,
    /// Most jobs coalesced into a single executor dispatch.
    pub max_batch: usize,
    /// Per-tenant admission queue capacity.
    pub queue_depth: usize,
    /// Behavior when a tenant queue is full.
    pub admission: Admission,
    /// How long the scheduler lingers for more same-key work before
    /// dispatching a partial batch.  Zero dispatches immediately.
    pub linger_micros: u64,
    /// Hold scheduling until shutdown/drain — or, under
    /// [`Admission::Block`], until some tenant queue saturates, so a
    /// blocked submitter can never deadlock against a paused
    /// scheduler.  With every request queued up front (below capacity)
    /// this makes batch formation deterministic, which the scheduler
    /// tests and the batching benchmarks rely on.
    pub paused: bool,
    /// Per-request deadline, measured from admission.  A queued job
    /// whose deadline has passed is evicted at batch formation with a
    /// named error `Response` instead of wasting executor time on an
    /// answer nobody is waiting for.  `0` disables deadlines.
    pub deadline_micros: u64,
    /// SLO-aware admission shedding: when the total queued backlog
    /// reaches this many jobs, `submit` fails fast with
    /// [`SubmitError::Shed`] (carrying a retry-after hint) regardless of
    /// the per-tenant [`Admission`] policy — under overload, rejecting
    /// *now* beats admitting work that will blow its deadline anyway.
    /// `0` disables shedding.
    pub shed_queued: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 8,
            queue_depth: 32,
            admission: Admission::Block,
            linger_micros: 200,
            paused: false,
            deadline_micros: 0,
            shed_queued: 0,
        }
    }
}

/// Why a submission was not admitted.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The tenant's queue is at capacity (only under [`Admission::Reject`]).
    Full {
        /// The tenant whose queue was full.
        tenant: TenantId,
    },
    /// The server shed the request under queue pressure
    /// ([`ServeConfig::shed_queued`]).
    Shed {
        /// The tenant whose request was shed.
        tenant: TenantId,
        /// Hint: retry after roughly this long, estimated from the
        /// backlog and the observed mean per-request execution time.
        retry_after_micros: u64,
    },
    /// The server has been shut down (or is draining).
    Closed,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Full { tenant } => write!(f, "tenant {tenant}: admission queue full"),
            SubmitError::Shed { tenant, retry_after_micros } => write!(
                f,
                "tenant {tenant}: shed under queue pressure (retry after ~{retry_after_micros}µs)"
            ),
            SubmitError::Closed => write!(f, "server is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Coalescing key: jobs may share an executor dispatch only when every
/// field matches.  Shape is part of the key because the PJRT analyze
/// artifacts are specialized per (c_in, c_out); token-row counts may
/// differ within a batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct BatchKey {
    /// Module kind (one of [`crate::MODULES`]).
    pub module: &'static str,
    /// Quantization bit width.
    pub bits: u32,
    /// Migration strength, stored as raw bits so the key is `Eq`/`Hash`.
    alpha_bits: u32,
    /// Activation width / weight input channels.
    pub c_in: usize,
    /// Weight output channels.
    pub c_out: usize,
}

impl BatchKey {
    /// The key of one job.
    pub fn of(job: &Job) -> BatchKey {
        BatchKey {
            module: job.module,
            bits: job.bits,
            alpha_bits: job.alpha.to_bits(),
            c_in: job.x.cols(),
            c_out: job.w.cols(),
        }
    }

    /// Migration strength alpha.
    pub fn alpha(&self) -> f32 {
        f32::from_bits(self.alpha_bits)
    }
}

/// Anything that can process a coalesced batch of jobs.
///
/// The returned vector must hold exactly one result per job, in job
/// order (the pool pads/truncates defensively if an implementation
/// miscounts).  Every per-job [`Executor`] is a `BatchExecutor` via a
/// blanket adapter that runs the jobs sequentially.
pub trait BatchExecutor {
    /// Process every job of one batch.
    fn run_batch(&mut self, jobs: &[Job]) -> Vec<Result<AnalyzeOut, String>>;

    /// Rotation-cache counters for the serve summary; see
    /// [`Executor::rotation_stats`].
    fn rotation_stats(&self) -> Option<CacheStats> {
        None
    }
}

impl<E: Executor> BatchExecutor for E {
    fn run_batch(&mut self, jobs: &[Job]) -> Vec<Result<AnalyzeOut, String>> {
        jobs.iter().map(|j| self.run(j)).collect()
    }

    fn rotation_stats(&self) -> Option<CacheStats> {
        Executor::rotation_stats(self)
    }
}

/// Which arithmetic the plan-driven executor runs on plan-covered
/// cells.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// Simulated quantization: f32 quantize-dequantize followed by f32
    /// matmuls (the measurement path).
    #[default]
    F32,
    /// Real integer execution: per-token i8 activation codes through
    /// the `i32`-accumulated integer GEMM against weights the plan
    /// registry pre-quantized at load time
    /// ([`crate::kernels::fused::analyze_planned_int`]).  Cells without
    /// a pre-quantized weight fall back to [`ExecMode::F32`] behavior.
    Int8,
}

impl ExecMode {
    /// Parse a CLI name.
    pub fn from_name(s: &str) -> Result<ExecMode, String> {
        match s {
            "f32" => Ok(ExecMode::F32),
            "int8" => Ok(ExecMode::Int8),
            other => Err(format!("unknown exec mode {other:?} (want f32 | int8)")),
        }
    }

    /// Canonical CLI name.
    pub fn name(self) -> &'static str {
        match self {
            ExecMode::F32 => "f32",
            ExecMode::Int8 => "int8",
        }
    }
}

/// Native analysis executor on the fused kernel engine
/// ([`crate::kernels::fused::analyze_all_modes`]): one rotation per
/// distinct activation width (FWHT-planned, hit/miss counted) and one
/// reusable [`Workspace`], both shared by every job the executor ever
/// sees — so a warm worker's matrix-sized scratch is fully pooled.
///
/// It implements [`BatchExecutor`] **directly** (not via the blanket
/// per-job adapter): on plan-covered int8 cells, `run_batch` stacks a
/// whole same-cell group into ONE fused kernel invocation — one shared
/// transform pass, one per-token quantize, one tall integer GEMM
/// against the entry's packed weight
/// ([`crate::kernels::fused::analyze_planned_int_batch`]) — and splits
/// the rows back per job, bit-identically to per-job execution.  All
/// other cells (f32, uncovered, weightless) keep the per-job path.
/// When `threads > 1` the executor also owns a persistent
/// [`ThreadPool`], installed around every run so the kernels dispatch
/// to parked workers instead of spawning scoped threads per call.
#[derive(Debug)]
pub struct NativeBatchExecutor {
    cache: RotationCache,
    scratch: Workspace,
    /// Math threads inside the kernels (`0` = all cores).
    threads: usize,
    /// Persistent kernel worker pool (only when the resolved thread
    /// count exceeds one); see [`crate::kernels::par::with_pool`].
    pool: Option<Arc<ThreadPool>>,
    /// Calibration plan to consult per job (None = always run the full
    /// four-mode analyze).
    plan: Option<Arc<PlanRegistry>>,
    /// Arithmetic on plan-covered cells.
    exec: ExecMode,
    /// Whether `run_batch` may stack plan-covered int8 groups into
    /// fused GEMMs (default on; benches disable it to measure the
    /// per-job baseline).
    fuse: bool,
    /// Between-batches workspace retention budget in bytes (default
    /// [`NativeBatchExecutor::TRIM_BYTES`]; see
    /// [`NativeBatchExecutor::with_trim_budget`]).
    trim_bytes: usize,
    /// Integer microkernel backend, pinned at construction
    /// ([`simd::default_backend`] unless overridden by
    /// [`NativeBatchExecutor::with_kernel_backend`]) and installed
    /// around every run — bit-identical across choices by the
    /// [`crate::kernels::simd`] contract.
    backend: KernelBackend,
}

impl Default for NativeBatchExecutor {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeBatchExecutor {
    /// Default steady-state scratch budget: after each batch the
    /// executor trims its [`Workspace`] back under this many retained
    /// bytes ([`Workspace::trim`]), so one giant request releases its
    /// burst scratch instead of pinning the high-water mark for the
    /// worker's lifetime.  Ordinary serving traffic fits comfortably
    /// underneath, so the steady state stays allocation-free (pinned by
    /// a test below).  Deployments whose *legitimate* per-batch scratch
    /// exceeds this (very large shapes) should raise the budget with
    /// [`NativeBatchExecutor::with_trim_budget`] — otherwise every
    /// batch would evict and re-allocate its working set.
    pub const TRIM_BYTES: usize = 16 << 20;

    /// Single-threaded kernels (parallelism comes from the worker
    /// pool); empty rotation cache and workspace.
    pub fn new() -> Self {
        Self::with_threads(1)
    }

    /// Executor whose kernels fan out over `threads` math threads
    /// (`0` = all cores) — for deployments with more cores than
    /// workers.  With more than one resolved thread the executor spawns
    /// its persistent kernel pool up front, so no serving request ever
    /// pays a thread-spawn.
    pub fn with_threads(threads: usize) -> Self {
        let resolved = par::resolve_threads(threads);
        Self {
            cache: RotationCache::new(),
            scratch: Workspace::new(),
            threads,
            pool: (resolved > 1).then(|| Arc::new(ThreadPool::new(resolved))),
            plan: None,
            exec: ExecMode::F32,
            fuse: true,
            trim_bytes: Self::TRIM_BYTES,
            backend: simd::default_backend(),
        }
    }

    /// Pin the integer microkernel backend (`--kernel-backend`); the
    /// default is [`simd::default_backend`] — `SMOOTHROT_KERNEL` when
    /// set, else the best the host supports.  Results are bit-identical
    /// across backends, so this is a performance/debugging knob, never
    /// a correctness one.
    pub fn with_kernel_backend(mut self, backend: KernelBackend) -> Self {
        self.backend = backend;
        self
    }

    /// The integer microkernel backend this executor pins around every
    /// run (the serve summary reports it).
    pub fn kernel_backend(&self) -> KernelBackend {
        self.backend
    }

    /// Override the between-batches workspace retention budget
    /// ([`NativeBatchExecutor::TRIM_BYTES`] by default).  Size it above
    /// the steady-state per-batch scratch of your largest legitimate
    /// shapes — a budget *below* the working set makes every batch
    /// evict and re-allocate; `usize::MAX` disables trimming entirely.
    pub fn with_trim_budget(mut self, bytes: usize) -> Self {
        self.trim_bytes = bytes;
        self
    }

    /// Disable (or re-enable) stacked batch fusion — the per-job
    /// baseline knob the `serve_plan_int8_96req` bench scenario uses to
    /// quantify the fused path's win.  Production serving keeps the
    /// default (enabled).
    pub fn with_batch_fusion(mut self, fuse: bool) -> Self {
        self.fuse = fuse;
        self
    }

    /// Plan-driven executor (`smoothrot serve --plan`): each job is
    /// looked up in the calibration [`PlanRegistry`]; on a hit only the
    /// planned transform runs
    /// ([`crate::kernels::fused::analyze_planned`] — its smoothing
    /// vector and rotation come pre-resolved from the plan, so there is
    /// zero per-request transform search).  The calibrated transform
    /// — including its grid-searched alpha and smoothing vector —
    /// *overrides* the request's `alpha` on covered cells; that is the
    /// "calibrate once" contract.  Jobs the plan does not cover (or
    /// whose activation width disagrees with the calibrated `c_in`)
    /// fall back to the full four-mode analyze, which does honor the
    /// request's alpha; the registry counts both outcomes
    /// ([`PlanRegistry::stats`]).
    pub fn with_plan(plan: Arc<PlanRegistry>, threads: usize) -> Self {
        Self::with_plan_exec(plan, threads, ExecMode::F32)
    }

    /// [`NativeBatchExecutor::with_plan`] with an explicit execution
    /// path (`smoothrot serve --plan --exec int8`): under
    /// [`ExecMode::Int8`], plan-covered jobs whose entry carries a
    /// pre-quantized weight ([`PlanRegistry::set_weight_provider`]) run
    /// the real integer pipeline — transform + quantize only the
    /// activation rows, then the `i32`-accumulated integer GEMM — and
    /// report the *executed* Eq. 2 error.  Covered jobs without a
    /// usable pre-quantized weight run the f32 planned path; uncovered
    /// jobs fall back to the full four-mode analyze as before.
    ///
    /// **Contract:** the registry's weight provider must serve the same
    /// model the request stream carries — on int8-covered cells the
    /// GEMM multiplies the *registry's* pre-quantized weight, and only
    /// its shape is checked against the request's `job.w` (content
    /// equality is not verified per request; that is the "the registry
    /// IS the model" analogue of the calibrated-alpha override above).
    pub fn with_plan_exec(plan: Arc<PlanRegistry>, threads: usize, exec: ExecMode) -> Self {
        let mut e = Self::with_threads(threads);
        e.plan = Some(plan);
        e.exec = exec;
        e
    }

    /// Process one job through the plan-driven / full-analyze dispatch
    /// (the per-job path; the serving core reaches the same logic — or
    /// its stacked batch fusion — through [`BatchExecutor::run_batch`]).
    pub fn run(&mut self, job: &Job) -> Result<AnalyzeOut, String> {
        let pool = self.pool.clone();
        let backend = self.backend;
        simd::with_backend(backend, || par::with_pool(pool, || self.run_one(job)))
    }

    /// The per-job dispatch body (callers have the kernel pool
    /// installed).
    fn run_one(&mut self, job: &Job) -> Result<AnalyzeOut, String> {
        if let Some(reg) = self.plan.clone() {
            if let Some(e) = reg.lookup(job.module, job.layer, job.bits, job.x.cols()) {
                if self.exec == ExecMode::Int8 {
                    let usable = e
                        .qweight
                        .clone()
                        .filter(|pw| pw.packed.shape() == (job.x.cols(), job.w.cols()));
                    // count the outcome either way: a missing or
                    // shape-mismatched pre-quantized weight silently
                    // degrades to the f32 planned path below, and the
                    // degradation must be observable (int8_stats)
                    reg.note_int8(usable.is_some());
                    if let Some(pw) = usable {
                        return self.run_planned_int(job, &e, &pw);
                    }
                }
                return self.run_planned_f32(job, &e);
            }
        }
        self.run_full(job)
    }

    /// The resolved entry's smoothing pair, gated to what its mode uses.
    fn smooth_pair(e: &ResolvedEntry) -> Option<(&[f32], &[f32])> {
        match (&e.smooth, &e.smooth_inv) {
            (Some(s), Some(inv)) => Some((s.as_slice(), inv.as_slice())),
            _ => None,
        }
    }

    /// Planned integer evaluation of one job (covered cell with a
    /// usable pre-quantized weight).
    fn run_planned_int(
        &mut self,
        job: &Job,
        e: &ResolvedEntry,
        pw: &PlannedWeight,
    ) -> Result<AnalyzeOut, String> {
        let out = crate::kernels::fused::analyze_planned_int(
            &job.x,
            &job.w,
            job.bits,
            e.mode,
            Self::smooth_pair(e),
            e.rotation.as_deref(),
            pw,
            &mut self.scratch,
            self.threads,
        )?;
        let m = e.mode.index();
        telemetry::difficulty::observe(
            job.module,
            job.layer,
            out.act_difficulty[m],
            out.errors[m],
            e.calib_difficulty,
        );
        Ok(out)
    }

    /// Planned f32 (simulated-quantization) evaluation of one job.
    fn run_planned_f32(&mut self, job: &Job, e: &ResolvedEntry) -> Result<AnalyzeOut, String> {
        crate::kernels::fused::analyze_planned(
            &job.x,
            &job.w,
            job.bits,
            e.mode,
            Self::smooth_pair(e),
            e.rotation.as_deref(),
            &mut self.scratch,
            self.threads,
        )
    }

    /// Full four-mode analyze of one uncovered job.
    fn run_full(&mut self, job: &Job) -> Result<AnalyzeOut, String> {
        crate::kernels::fused::analyze_all_modes(
            &job.x,
            &job.w,
            job.bits,
            job.alpha,
            &mut self.cache,
            &mut self.scratch,
            self.threads,
        )
    }

    /// The batch body (callers have the kernel pool installed): stack
    /// each plan-covered int8 group into one fused kernel invocation,
    /// run everything else per job.
    fn run_batch_inner(&mut self, jobs: &[Job]) -> Vec<Result<AnalyzeOut, String>> {
        // `serve.exec_panic` failpoint: a poisoned job (keyed trigger on
        // its id) panics whenever it is dispatched — including on the
        // worker's per-job retry after a batch split, so the chaos tests
        // can prove quarantine end to end.  No-op branch when unarmed.
        if crate::faults::armed() {
            for j in jobs {
                if crate::faults::fire_key("serve.exec_panic", j.id) {
                    panic!("fault injected: serve.exec_panic (job {})", j.id);
                }
            }
        }
        let fused_eligible = self.fuse && self.exec == ExecMode::Int8 && self.plan.is_some();
        if !fused_eligible {
            return jobs.iter().map(|j| self.run_one(j)).collect();
        }
        let reg = self.plan.clone().expect("checked above");
        let mut results: Vec<Option<Result<AnalyzeOut, String>>> =
            (0..jobs.len()).map(|_| None).collect();
        // Group by the full execution identity.  The scheduler's
        // BatchKey deliberately omits the layer (layers coalesce fine
        // for dispatch), but the planned weight is per (module, layer),
        // so fusion splits on it; shapes are re-derived defensively
        // because run_batch accepts arbitrary job mixes.
        let mut groups: BTreeMap<(&'static str, usize, u32, usize, usize, usize), Vec<usize>> =
            BTreeMap::new();
        for (i, j) in jobs.iter().enumerate() {
            groups
                .entry((j.module, j.layer, j.bits, j.x.cols(), j.w.rows(), j.w.cols()))
                .or_default()
                .push(i);
        }
        for ((module, layer, bits, c_in, _w_rows, c_out), idxs) in groups {
            let n = idxs.len() as u64;
            // one lookup resolves the whole group; the extra requests
            // are credited so the coverage counters keep their
            // per-request meaning
            let Some(e) = reg.lookup(module, layer, bits, c_in) else {
                reg.note_fallback_many(n - 1);
                for &i in &idxs {
                    results[i] = Some(self.run_full(&jobs[i]));
                }
                continue;
            };
            reg.note_planned_many(n - 1);
            let usable = e.qweight.clone().filter(|pw| pw.packed.shape() == (c_in, c_out));
            reg.note_int8_many(usable.is_some(), n);
            let Some(pw) = usable else {
                for &i in &idxs {
                    results[i] = Some(self.run_planned_f32(&jobs[i], &e));
                }
                continue;
            };
            let pairs: Vec<(&Matrix, &Matrix)> =
                idxs.iter().map(|&i| (&jobs[i].x, &jobs[i].w)).collect();
            match crate::kernels::fused::analyze_planned_int_batch(
                &pairs,
                bits,
                e.mode,
                Self::smooth_pair(&e),
                e.rotation.as_deref(),
                &pw,
                &mut self.scratch,
                self.threads,
            ) {
                Ok(outs) => {
                    reg.note_batch_fused(n);
                    let m = e.mode.index();
                    for (&i, out) in idxs.iter().zip(outs) {
                        telemetry::difficulty::observe(
                            module,
                            layer,
                            out.act_difficulty[m],
                            out.errors[m],
                            e.calib_difficulty,
                        );
                        results[i] = Some(Ok(out));
                    }
                }
                Err(msg) => {
                    for &i in &idxs {
                        results[i] = Some(Err(msg.clone()));
                    }
                }
            }
        }
        results.into_iter().map(|r| r.expect("every job assigned")).collect()
    }
}

impl BatchExecutor for NativeBatchExecutor {
    /// One coalesced batch as (at most a handful of) fused kernel
    /// invocations: plan-covered int8 groups are stacked — one shared
    /// transform pass, one per-token quantize, ONE tall integer GEMM
    /// against the pre-quantized weight — and split back per job,
    /// bit-identical to per-job execution (the transform, Eq. 1 grids
    /// and GEMM rows are all row-local; pinned by
    /// `rust/tests/proptest_batchfused.rs`).  Uncovered / f32 /
    /// weightless cells fall back to the per-job path inside the same
    /// call.  Between batches the executor trims burst scratch back
    /// under [`NativeBatchExecutor::TRIM_BYTES`].
    fn run_batch(&mut self, jobs: &[Job]) -> Vec<Result<AnalyzeOut, String>> {
        let pool = self.pool.clone();
        let backend = self.backend;
        let out =
            simd::with_backend(backend, || par::with_pool(pool, || self.run_batch_inner(jobs)));
        self.scratch.trim(self.trim_bytes);
        out
    }

    fn rotation_stats(&self) -> Option<CacheStats> {
        Some(self.cache.stats())
    }
}

/// Per-job [`Executor`] view of [`NativeBatchExecutor`] for the
/// experiment coordinator's pool ([`crate::coordinator::run_jobs`]),
/// which dispatches one job at a time.  The serving core uses
/// [`NativeBatchExecutor`] directly as a [`BatchExecutor`] (whose
/// `run_batch` stacks plan-covered int8 groups into fused GEMMs); this
/// thin adapter exists because the blanket `Executor → BatchExecutor`
/// impl would otherwise conflict with that dedicated batch impl.
#[derive(Debug, Default)]
pub struct NativeJobExecutor(pub NativeBatchExecutor);

impl Executor for NativeJobExecutor {
    fn run(&mut self, job: &Job) -> Result<AnalyzeOut, String> {
        self.0.run(job)
    }

    fn rotation_stats(&self) -> Option<CacheStats> {
        Some(self.0.cache.stats())
    }
}

/// One completed request, streamed to the response channel as its batch
/// finishes.
#[derive(Clone, Debug)]
pub struct Response {
    /// The submitted job id.
    pub id: u64,
    /// Submitting tenant.
    pub tenant: TenantId,
    /// Module kind of the job.
    pub module: &'static str,
    /// Layer index of the job.
    pub layer: usize,
    /// Worker that executed the batch (`usize::MAX` for a request the
    /// scheduler evicted before dispatch, e.g. on deadline expiry).
    pub worker: usize,
    /// Batch this request was coalesced into (`u64::MAX` when evicted
    /// before dispatch).
    pub batch_id: u64,
    /// Number of jobs in that batch (`0` when evicted before dispatch).
    pub batch_size: usize,
    /// Analysis output, or the executor's error.
    pub out: Result<AnalyzeOut, String>,
    /// Microseconds from admission to batch execution start.
    pub queue_micros: u64,
    /// Microseconds the whole batch spent in the executor.
    pub exec_micros: u64,
    /// Microseconds from admission to completion.
    pub total_micros: u64,
}

/// Per-tenant request counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantStats {
    /// Requests admitted.
    pub submitted: u64,
    /// Requests completed (including errored ones).
    pub completed: u64,
    /// Requests rejected at admission.
    pub rejected: u64,
}

/// End-of-run summary returned by [`Server::finish`].
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    /// Requests admitted across all tenants.
    pub submitted: u64,
    /// Requests completed (including errored ones).
    pub completed: u64,
    /// Requests rejected at admission.
    pub rejected: u64,
    /// Requests shed at admission under queue pressure
    /// ([`ServeConfig::shed_queued`]); disjoint from `rejected`.
    pub shed: u64,
    /// Completed requests whose executor returned an error.
    pub errors: u64,
    /// Jobs quarantined after a panicking dispatch: the batch was split
    /// and retried per job, and this job panicked again alone.  Each
    /// quarantined job also counts in `completed` and `errors` (it gets
    /// a terminal errored [`Response`]).
    pub quarantined: u64,
    /// Jobs evicted at batch formation because their
    /// [`ServeConfig::deadline_micros`] deadline had passed (each also
    /// counts in `completed` and `errors`).
    pub deadline_expired: u64,
    /// Graceful drains completed ([`Server::drain`]).
    pub drains: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Batches a worker stole from a peer's deque.
    pub steals: u64,
    /// Largest batch observed.
    pub max_batch_observed: usize,
    /// Wall time from server start to the end of [`Server::finish`].
    pub wall_micros: u64,
    /// Total executor time across all batches.
    pub exec_micros_total: u64,
    /// p50/p95/p99 of per-request end-to-end latency (microseconds),
    /// over a bounded reservoir of the most recent ~65k samples.
    pub latency: Percentiles,
    /// Rotation-cache hit/miss counters summed over all workers'
    /// executors (zero when the executor keeps no cache).
    pub rotation: CacheStats,
    /// Per-tenant counters.
    pub per_tenant: BTreeMap<TenantId, TenantStats>,
    /// Batches executed by each worker.
    pub per_worker_batches: Vec<u64>,
    /// Batches initially *placed* on each worker's deque by the
    /// scheduler (before any stealing).  Under owner routing this is
    /// the shard-key distribution; under classic least-loaded dispatch
    /// it tracks the load balancer's placements.
    pub per_worker_routed: Vec<u64>,
    /// Batches each worker stole from a peer's deque
    /// (`steals == per_worker_steals.iter().sum()`).
    pub per_worker_steals: Vec<u64>,
    /// Per-worker end-to-end latency percentiles over each worker's own
    /// reservoir shard; [`ServeMetrics::latency`] merges the same
    /// shards into the run-wide summary.
    pub per_worker_latency: Vec<Percentiles>,
}

impl ServeMetrics {
    /// Completed requests per wall-clock second.
    pub fn throughput(&self) -> f64 {
        if self.wall_micros == 0 {
            return 0.0;
        }
        self.completed as f64 / (self.wall_micros as f64 / 1e6)
    }

    /// Mean jobs per dispatched batch.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.completed as f64 / self.batches as f64
    }

    /// Register every field of this summary in `t`'s metric registry
    /// under the canonical `smoothrot_*` names — the rows
    /// [`crate::telemetry::export::render_summary`] and the exporters
    /// consume — so the console summary, the JSON file and the
    /// Prometheus text all come from ONE snapshot.  Counters are set by
    /// delta against their current value, so filling the same
    /// [`Telemetry`] twice with the same metrics is idempotent.
    pub fn fill(&self, t: &Telemetry) {
        let reg = t.registry();
        let bump = |name: &str, labels: &[(&str, &str)], v: u64| {
            let c = reg.counter(name, labels);
            c.add(v.saturating_sub(c.value()));
        };
        let counters = [
            ("smoothrot_requests_submitted_total", self.submitted),
            ("smoothrot_requests_completed_total", self.completed),
            ("smoothrot_requests_rejected_total", self.rejected),
            ("smoothrot_request_errors_total", self.errors),
            ("smoothrot_jobs_quarantined", self.quarantined),
            ("smoothrot_deadline_expired", self.deadline_expired),
            ("smoothrot_shed_total", self.shed),
            ("smoothrot_drain_total", self.drains),
            ("smoothrot_batches_total", self.batches),
            ("smoothrot_steals_total", self.steals),
            ("smoothrot_exec_microseconds_total", self.exec_micros_total),
            ("smoothrot_rotation_cache_hits_total", self.rotation.hits),
            ("smoothrot_rotation_cache_misses_total", self.rotation.misses),
        ];
        for (name, v) in counters {
            bump(name, &[], v);
        }
        reg.gauge("smoothrot_wall_microseconds", &[]).set(self.wall_micros as f64);
        reg.gauge("smoothrot_batch_size_max", &[]).set(self.max_batch_observed as f64);
        let quants = |p: &Percentiles| {
            [("p50", p.p50), ("p95", p.p95), ("p99", p.p99), ("p999", p.p999)]
        };
        for (q, v) in quants(&self.latency) {
            reg.gauge("smoothrot_latency_microseconds", &[("quantile", q)]).set(v);
        }
        for (i, &b) in self.per_worker_batches.iter().enumerate() {
            let id = i.to_string();
            let l: [(&str, &str); 1] = [("runner", &id)];
            bump("smoothrot_runner_batches_total", &l, b);
            bump(
                "smoothrot_runner_routed_total",
                &l,
                self.per_worker_routed.get(i).copied().unwrap_or(0),
            );
            bump(
                "smoothrot_runner_steals_total",
                &l,
                self.per_worker_steals.get(i).copied().unwrap_or(0),
            );
            let lat = self.per_worker_latency.get(i).copied().unwrap_or_default();
            for (q, v) in quants(&lat) {
                reg.gauge(
                    "smoothrot_runner_latency_microseconds",
                    &[("quantile", q), ("runner", &id)],
                )
                .set(v);
            }
        }
        for (tenant, ts) in &self.per_tenant {
            let id = tenant.to_string();
            let l: [(&str, &str); 1] = [("tenant", &id)];
            bump("smoothrot_tenant_submitted_total", &l, ts.submitted);
            bump("smoothrot_tenant_completed_total", &l, ts.completed);
            bump("smoothrot_tenant_rejected_total", &l, ts.rejected);
        }
    }

    /// Human-readable multi-line summary (used by the CLI and
    /// examples).  Rendered by filling a snapshot and formatting *it*
    /// ([`crate::telemetry::export::render_summary`]) — the same rows
    /// the metric exporters write, so the console and the exported
    /// files cannot disagree.
    pub fn summary(&self) -> String {
        let t = Telemetry::new();
        self.fill(&t);
        telemetry::render_summary(&t.snapshot())
    }
}

/// A request waiting in a tenant queue.
struct Pending {
    job: Job,
    tenant: TenantId,
    admitted: Instant,
    /// Owning worker index, computed at submit time from the server's
    /// [`Route`] (always `0` under [`Route::LeastLoaded`], so classic
    /// serving coalesces exactly as before).  Requests only share a
    /// batch when their routes match — a batch has one owner.
    route: usize,
}

/// One tenant's admission queue, indexed by [`BatchKey`] so batch
/// formation never rescans it.
///
/// The naive `VecDeque` + `iter().position(key)` fill made
/// [`form_batch`] O(batch × queue-depth) — quadratic under deep
/// same-key queues, exactly the backlog shape a hot key produces.  Here
/// every request gets an ascending admission sequence number; `items`
/// keeps FIFO order (a `BTreeMap` keyed by sequence) and `by_key` maps
/// each [`BatchKey`] to its requests' sequence numbers in admission
/// order.  Seeding pops the overall front, filling pops a key's front —
/// both O(log n) — so forming a batch is O(batch · log depth), and
/// same-key requests of a tenant still complete FIFO relative to each
/// other (each key deque ascends in admission order).
#[derive(Default)]
struct TenantQueue {
    /// Admission-ordered requests (key = per-tenant sequence number).
    items: BTreeMap<u64, Pending>,
    /// Per-(key, route) index into `items`; every deque ascends in
    /// sequence.  The route is part of the index because a batch must
    /// have ONE owning worker: two same-key jobs with different shard
    /// keys (e.g. different layers under layer sharding) may not share
    /// a dispatch.  Under [`Route::LeastLoaded`] every route is `0`, so
    /// the index degenerates to the pure per-key map and coalescing is
    /// unchanged.
    by_key: BTreeMap<(BatchKey, usize), VecDeque<u64>>,
    next_seq: u64,
}

impl TenantQueue {
    fn len(&self) -> usize {
        self.items.len()
    }

    fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    fn push_back(&mut self, p: Pending) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.by_key.entry((BatchKey::of(&p.job), p.route)).or_default().push_back(seq);
        self.items.insert(seq, p);
    }

    /// Pop the oldest request of any key.
    fn pop_front(&mut self) -> Option<Pending> {
        let (&seq, _) = self.items.iter().next()?;
        let p = self.items.remove(&seq).expect("peeked above");
        let key = (BatchKey::of(&p.job), p.route);
        let q = self.by_key.get_mut(&key).expect("indexed at push");
        // the overall-oldest request is necessarily its key's oldest
        debug_assert_eq!(q.front(), Some(&seq));
        q.pop_front();
        if q.is_empty() {
            self.by_key.remove(&key);
        }
        Some(p)
    }

    /// Pop the oldest request of `key`, if any — the O(log) replacement
    /// for the linear rescan.
    fn pop_key(&mut self, key: &(BatchKey, usize)) -> Option<Pending> {
        let q = self.by_key.get_mut(key)?;
        let seq = q.pop_front().expect("index never holds empty deques");
        if q.is_empty() {
            self.by_key.remove(key);
        }
        Some(self.items.remove(&seq).expect("index points into items"))
    }

    /// Remove one request by sequence number (deadline eviction; unlike
    /// the pops, the seq may sit anywhere in its key deque when a fault
    /// forces an out-of-order expiry).
    fn remove_seq(&mut self, seq: u64) -> Option<Pending> {
        let p = self.items.remove(&seq)?;
        let key = (BatchKey::of(&p.job), p.route);
        if let Some(q) = self.by_key.get_mut(&key) {
            if let Some(pos) = q.iter().position(|&s| s == seq) {
                q.remove(pos);
            }
            if q.is_empty() {
                self.by_key.remove(&key);
            }
        }
        Some(p)
    }
}

/// Response-side metadata of one batched request (everything small the
/// worker needs after execution, so the jobs — whose matrices dominate
/// request memory — go to the executor without being cloned).
struct BatchMeta {
    id: u64,
    tenant: TenantId,
    module: &'static str,
    layer: usize,
    admitted: Instant,
}

/// A coalesced dispatch unit; `jobs[i]` corresponds to `meta[i]`.
struct Batch {
    id: u64,
    jobs: Vec<Job>,
    meta: Vec<BatchMeta>,
    /// Worker index this batch was routed to (the shard owner under
    /// [`Route::Owner`]; the seed request's route — always `0` — under
    /// [`Route::LeastLoaded`], where dispatch ignores it).
    owner: usize,
}

/// Counters accumulated under the center lock.
#[derive(Default)]
struct CenterStats {
    submitted: u64,
    completed: u64,
    rejected: u64,
    shed: u64,
    errors: u64,
    quarantined: u64,
    deadline_expired: u64,
    drains: u64,
    batches: u64,
    max_batch_observed: usize,
    exec_micros_total: u64,
    /// One ascending-sorted latency shard per worker, indexed by worker
    /// and assigned at worker exit (pre-sized at start, so per-runner
    /// percentiles keep their index even when a worker saw no work);
    /// combined at [`Server::finish`] via [`Percentiles::merge`] (no
    /// global concatenation is ever re-sorted).
    worker_latencies: Vec<Vec<f64>>,
    rotation: CacheStats,
    per_tenant: BTreeMap<TenantId, TenantStats>,
    per_worker_batches: Vec<u64>,
}

/// Admission + scheduling state (one lock).
struct Center {
    queues: BTreeMap<TenantId, TenantQueue>,
    /// Tenant ids in first-seen order; the scheduler's round-robin ring.
    ring: Vec<TenantId>,
    /// Next ring position to seed a batch from.
    cursor: usize,
    /// Total requests across all tenant queues.
    queued: usize,
    /// Requests popped into batches but not yet completed.
    in_flight: usize,
    closed: bool,
    /// Graceful drain in progress: admission stops (submit fails
    /// [`SubmitError::Closed`]) but queued and in-flight work completes
    /// normally; see [`Server::drain`].
    draining: bool,
    next_batch_id: u64,
    stats: CenterStats,
}

/// Worker-pool state: per-worker batch deques (one lock).
struct Pool {
    queues: Vec<VecDeque<Batch>>,
    done: bool,
    /// Batches initially placed on each worker's deque by the
    /// scheduler.
    routed: Vec<u64>,
    /// Batches each worker stole from a peer's deque.
    steals: Vec<u64>,
    /// Whether idle workers may steal at all (the sharded proptests
    /// force it off to pin placement).
    stealing: bool,
    /// Minimum victim deque length for a steal.  Classic serving uses
    /// `1` (any queued batch is fair game); owner routing uses `2`, so
    /// a runner that was routed at least one batch always executes at
    /// least one — peers may only skim a victim's *surplus*.  That
    /// guarantee is what makes the CI "no runner served zero batches"
    /// gate deterministic under a skewed stream.
    steal_min: usize,
}

/// How the scheduler picks a worker deque for each batch.
enum Route {
    /// Classic load balancing: push to the shortest deque.
    LeastLoaded,
    /// Sharded ownership: `f(job, tenant) % workers` names the owning
    /// runner; computed at submit time so coalescing never mixes
    /// owners.  The function must be deterministic — same job, same
    /// owner — or batches of one shard would scatter.
    Owner(Arc<dyn Fn(&Job, TenantId) -> usize + Send + Sync>),
}

struct Shared {
    cfg: ServeConfig,
    center: Mutex<Center>,
    /// Wakes the scheduler on new work / shutdown.
    sched_cv: Condvar,
    /// Wakes blocked submitters when queue space frees up.
    admit_cv: Condvar,
    pool: Mutex<Pool>,
    /// Wakes idle workers on new batches / shutdown.
    pool_cv: Condvar,
    /// Wakes a [`Server::drain`] waiter as work completes.
    drain_cv: Condvar,
    /// Batch-to-worker placement policy.
    route: Route,
    /// Telemetry sinks installed around every executor dispatch plus
    /// the scheduler/worker stage timers (`None` = telemetry off; the
    /// disabled path pays one `Option` check per batch).
    telemetry: Option<Arc<Telemetry>>,
}

/// Cap on retained latency samples across all workers: percentile
/// quality degrades gracefully under overwrite, memory does not grow
/// with uptime.  Each worker keeps its own `LATENCY_RESERVOIR /
/// workers` shard, sorted once at worker exit and merged at
/// [`Server::finish`].
const LATENCY_RESERVOIR: usize = 1 << 16;

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Form one batch from the tenant queues.  Caller guarantees
/// `center.queued > 0` and holds the center lock.
fn form_batch(c: &mut Center, max_batch: usize) -> Batch {
    let n = c.ring.len();
    debug_assert!(n > 0 && c.queued > 0);
    // Seed: the oldest request of the next non-empty tenant in ring
    // order.  Seeding from queue fronts means no request waits forever.
    let mut seed_pos = c.cursor % n;
    for k in 0..n {
        let pos = (c.cursor + k) % n;
        if !c.queues[&c.ring[pos]].is_empty() {
            seed_pos = pos;
            break;
        }
    }
    c.cursor = (seed_pos + 1) % n;
    let seed_tenant = c.ring[seed_pos];
    let first = c.queues.get_mut(&seed_tenant).unwrap().pop_front().unwrap();
    let owner = first.route;
    let key = (BatchKey::of(&first.job), owner);
    let mut items = vec![first];
    // Fill: round-robin passes over the ring starting after the seed,
    // taking at most one matching request per tenant per pass (fair
    // share).  Each take pops the key's oldest request straight off the
    // tenant's [`BatchKey`] index (O(log) instead of a linear queue
    // rescan), so same-key requests of a tenant stay FIFO relative to
    // each other and batch formation is O(batch · log depth).
    'fill: loop {
        let mut progressed = false;
        for k in 0..n {
            if items.len() >= max_batch {
                break 'fill;
            }
            let t = c.ring[(seed_pos + 1 + k) % n];
            if let Some(p) = c.queues.get_mut(&t).unwrap().pop_key(&key) {
                items.push(p);
                progressed = true;
            }
        }
        if !progressed {
            break;
        }
    }
    c.queued -= items.len();
    c.in_flight += items.len();
    c.stats.batches += 1;
    c.stats.max_batch_observed = c.stats.max_batch_observed.max(items.len());
    let id = c.next_batch_id;
    c.next_batch_id += 1;
    let mut jobs = Vec::with_capacity(items.len());
    let mut meta = Vec::with_capacity(items.len());
    for p in items {
        meta.push(BatchMeta {
            id: p.job.id,
            tenant: p.tenant,
            module: p.job.module,
            layer: p.job.layer,
            admitted: p.admitted,
        });
        jobs.push(p.job);
    }
    Batch { id, jobs, meta, owner }
}

/// Handle to a running serving core.
///
/// Built by [`Server::start`]; submissions go through [`Server::submit`]
/// and results stream on the [`Receiver`] returned at start.  Dropping
/// the server (or calling [`Server::finish`]) drains every admitted
/// request, then joins the scheduler and worker threads.
pub struct Server {
    shared: Arc<Shared>,
    scheduler: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    started: Instant,
}

impl Server {
    /// Spawn the scheduler and `cfg.workers` worker threads.
    ///
    /// `make_executor(worker_idx)` runs *inside* each worker thread, so
    /// non-`Send` executors (PJRT) work; a failing factory does not kill
    /// the pool — that worker reports every job it receives as errored,
    /// mirroring [`crate::coordinator::run_jobs`].
    pub fn start<E, F>(cfg: ServeConfig, make_executor: F) -> (Server, Receiver<Response>)
    where
        E: BatchExecutor,
        F: Fn(usize) -> Result<E, String> + Send + Sync + 'static,
    {
        Self::start_routed(cfg, Route::LeastLoaded, true, None, make_executor)
    }

    /// [`Server::start`] with a [`Telemetry`] subsystem attached
    /// (`smoothrot serve --metrics-file`): workers install its
    /// stage-timer and difficulty sinks around every executor dispatch,
    /// the scheduler times batch formation, and admission-to-dispatch
    /// wait lands in the `admission_wait` stage histogram.  `None`
    /// behaves exactly like [`Server::start`].
    pub fn start_with_telemetry<E, F>(
        cfg: ServeConfig,
        telemetry: Option<Arc<Telemetry>>,
        make_executor: F,
    ) -> (Server, Receiver<Response>)
    where
        E: BatchExecutor,
        F: Fn(usize) -> Result<E, String> + Send + Sync + 'static,
    {
        Self::start_routed(cfg, Route::LeastLoaded, true, telemetry, make_executor)
    }

    /// [`Server::start`] with an explicit batch-placement policy and
    /// steal switch — the engine under [`shard::ShardedServer`].  Under
    /// [`Route::Owner`] the steal threshold rises to 2 (only a victim's
    /// surplus may be stolen; see [`Pool::steal_min`]).
    fn start_routed<E, F>(
        cfg: ServeConfig,
        route: Route,
        stealing: bool,
        telemetry: Option<Arc<Telemetry>>,
        make_executor: F,
    ) -> (Server, Receiver<Response>)
    where
        E: BatchExecutor,
        F: Fn(usize) -> Result<E, String> + Send + Sync + 'static,
    {
        assert!(cfg.workers >= 1, "need at least one worker");
        assert!(cfg.max_batch >= 1, "max_batch must be >= 1");
        assert!(cfg.queue_depth >= 1, "queue_depth must be >= 1");

        let steal_min = match route {
            Route::LeastLoaded => 1,
            Route::Owner(_) => 2,
        };
        let shared = Arc::new(Shared {
            cfg,
            center: Mutex::new(Center {
                queues: BTreeMap::new(),
                ring: Vec::new(),
                cursor: 0,
                queued: 0,
                in_flight: 0,
                closed: false,
                draining: false,
                next_batch_id: 0,
                stats: CenterStats {
                    per_worker_batches: vec![0; cfg.workers],
                    worker_latencies: vec![Vec::new(); cfg.workers],
                    ..CenterStats::default()
                },
            }),
            sched_cv: Condvar::new(),
            admit_cv: Condvar::new(),
            pool: Mutex::new(Pool {
                queues: (0..cfg.workers).map(|_| VecDeque::new()).collect(),
                done: false,
                routed: vec![0; cfg.workers],
                steals: vec![0; cfg.workers],
                stealing,
                steal_min,
            }),
            pool_cv: Condvar::new(),
            drain_cv: Condvar::new(),
            route,
            telemetry,
        });
        let (res_tx, res_rx) = mpsc::channel::<Response>();
        let make_executor = Arc::new(make_executor);

        let mut workers = Vec::with_capacity(cfg.workers);
        for idx in 0..cfg.workers {
            let shared = Arc::clone(&shared);
            let tx = res_tx.clone();
            let mk = Arc::clone(&make_executor);
            workers.push(std::thread::spawn(move || worker_loop(idx, shared, tx, mk)));
        }
        let sched_shared = Arc::clone(&shared);
        // the scheduler keeps the original sender: it sends terminal
        // Responses itself for jobs it evicts at batch formation
        // (deadline expiry); the receiver disconnects once the
        // scheduler and every worker have exited
        let scheduler = std::thread::spawn(move || scheduler_loop(sched_shared, res_tx));

        (
            Server { shared, scheduler: Some(scheduler), workers, started: Instant::now() },
            res_rx,
        )
    }

    /// Admit one request for `tenant`.
    ///
    /// With [`Admission::Block`] a full tenant queue blocks the caller
    /// until the scheduler frees space; with [`Admission::Reject`] it
    /// returns [`SubmitError::Full`] immediately.
    pub fn submit(&self, tenant: TenantId, job: Job) -> Result<(), SubmitError> {
        // the shard owner is a pure function of (job, tenant), so it is
        // pinned here at admission — batch formation then only ever
        // coalesces same-owner requests
        let route = match &self.shared.route {
            Route::LeastLoaded => 0,
            Route::Owner(f) => f(&job, tenant) % self.shared.cfg.workers,
        };
        let mut center = lock(&self.shared.center);
        loop {
            if center.closed || center.draining {
                return Err(SubmitError::Closed);
            }
            // SLO-aware shedding: a backlog at/over the threshold fails
            // fast regardless of the per-tenant Admission policy —
            // blocking or queueing more work under overload only turns
            // would-be rejections into deadline misses.
            if self.shared.cfg.shed_queued > 0 && center.queued >= self.shared.cfg.shed_queued {
                let retry_after_micros = retry_after_hint(&center, &self.shared.cfg);
                center.stats.shed += 1;
                center.stats.per_tenant.entry(tenant).or_default().rejected += 1;
                return Err(SubmitError::Shed { tenant, retry_after_micros });
            }
            if !center.queues.contains_key(&tenant) {
                center.queues.insert(tenant, TenantQueue::default());
                center.ring.push(tenant);
            }
            if center.queues[&tenant].len() < self.shared.cfg.queue_depth {
                let pending = Pending { job, tenant, admitted: Instant::now(), route };
                center.queues.get_mut(&tenant).unwrap().push_back(pending);
                center.queued += 1;
                center.stats.submitted += 1;
                center.stats.per_tenant.entry(tenant).or_default().submitted += 1;
                self.shared.sched_cv.notify_one();
                return Ok(());
            }
            match self.shared.cfg.admission {
                Admission::Reject => {
                    center.stats.rejected += 1;
                    center.stats.per_tenant.entry(tenant).or_default().rejected += 1;
                    return Err(SubmitError::Full { tenant });
                }
                Admission::Block => {
                    // Wake the scheduler even when paused: a saturated
                    // queue overrides the pause (see scheduler_loop),
                    // so a blocked submitter always makes progress.
                    self.shared.sched_cv.notify_all();
                    center = match self.shared.admit_cv.wait(center) {
                        Ok(g) => g,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                }
            }
        }
    }

    /// Close admissions, drain every queued request, join all threads
    /// and return the run summary.  Responses not yet read remain
    /// buffered on the receiver.
    pub fn finish(mut self) -> ServeMetrics {
        self.shutdown();
        let wall = self.started.elapsed().as_micros() as u64;
        let center = lock(&self.shared.center);
        let pool = lock(&self.shared.pool);
        debug_assert_eq!(center.queued, 0, "drain left requests queued");
        debug_assert_eq!(center.in_flight, 0, "drain left requests in flight");
        let s = &center.stats;
        let shards: Vec<&[f64]> = s.worker_latencies.iter().map(|v| v.as_slice()).collect();
        ServeMetrics {
            submitted: s.submitted,
            completed: s.completed,
            rejected: s.rejected,
            shed: s.shed,
            errors: s.errors,
            quarantined: s.quarantined,
            deadline_expired: s.deadline_expired,
            drains: s.drains,
            batches: s.batches,
            steals: pool.steals.iter().sum(),
            max_batch_observed: s.max_batch_observed,
            wall_micros: wall,
            exec_micros_total: s.exec_micros_total,
            latency: Percentiles::merge(&shards),
            rotation: s.rotation,
            per_tenant: s.per_tenant.clone(),
            per_worker_batches: s.per_worker_batches.clone(),
            per_worker_routed: pool.routed.clone(),
            per_worker_steals: pool.steals.clone(),
            per_worker_latency: Percentiles::of_each_sorted(&s.worker_latencies),
        }
    }

    /// Graceful drain: stop admitting new requests, let the scheduler
    /// dispatch every queued request (deadline eviction still applies)
    /// and block until the workers have completed all in-flight
    /// batches.  Responses keep streaming on the receiver throughout.
    ///
    /// Draining is safe to run concurrently with a plan hot-swap
    /// ([`PlanRegistry::reload_if_changed`]): executors resolve the
    /// registry per batch, so in-flight work finishes on whichever plan
    /// generation it started with and nothing is torn.  A drained
    /// server still needs [`Server::finish`] (or drop) to join its
    /// threads; further [`Server::submit`] calls fail with
    /// [`SubmitError::Closed`].
    ///
    /// When telemetry is attached the drain is flushed into the metric
    /// registry immediately (`smoothrot_drain_total`), so a final
    /// snapshot taken after `drain` — even if the process never reaches
    /// `finish` — records that the drain completed.
    pub fn drain(&self) {
        let mut center = lock(&self.shared.center);
        if !center.draining {
            center.draining = true;
        }
        // a paused scheduler yields to a drain (see scheduler_loop);
        // blocked submitters must observe the drain and fail out
        self.shared.sched_cv.notify_all();
        self.shared.admit_cv.notify_all();
        while center.queued > 0 || center.in_flight > 0 {
            center = match self.shared.drain_cv.wait(center) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
        center.stats.drains += 1;
        drop(center);
        if let Some(t) = &self.shared.telemetry {
            // ServeMetrics::fill bumps counters by delta, so this early
            // flush and a later finish() reconcile instead of
            // double-counting
            t.registry().counter("smoothrot_drain_total", &[]).add(1);
        }
    }

    fn shutdown(&mut self) {
        {
            let mut center = lock(&self.shared.center);
            center.closed = true;
        }
        self.shared.sched_cv.notify_all();
        self.shared.admit_cv.notify_all();
        self.shared.drain_cv.notify_all();
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Whether any tenant queue is at capacity (pause override: a blocked
/// submitter needs the scheduler to free space).
fn saturated(c: &Center, depth: usize) -> bool {
    c.queues.values().any(|q| q.len() >= depth)
}

/// Retry-after hint for a shed request: the backlog's expected service
/// time (queued jobs × observed mean per-request executor time, spread
/// over the workers), floored so the hint never tells a client to
/// hammer straight back.
fn retry_after_hint(c: &Center, cfg: &ServeConfig) -> u64 {
    let mean_exec = if c.stats.completed > 0 {
        c.stats.exec_micros_total / c.stats.completed
    } else {
        // nothing observed yet: assume a batch-formation linger is the
        // dominant cost
        cfg.linger_micros.max(100)
    };
    (c.queued as u64)
        .saturating_mul(mean_exec.max(1))
        .div_ceil(cfg.workers.max(1) as u64)
        .max(100)
}

/// Evict queued jobs whose deadline has passed (or that the
/// `serve.deadline_expire` failpoint forces to expire), producing their
/// terminal errored [`Response`]s.  Caller holds the center lock; the
/// returned responses must be sent after the bookkeeping here.
fn evict_expired(c: &mut Center, deadline_micros: u64) -> Vec<Response> {
    let now = Instant::now();
    let deadline = Duration::from_micros(deadline_micros);
    let mut out = Vec::new();
    for (&tenant, q) in c.queues.iter_mut() {
        let expired: Vec<u64> = q
            .items
            .iter()
            .filter(|(_, p)| {
                (deadline_micros > 0 && now.duration_since(p.admitted) >= deadline)
                    || crate::faults::fire_key("serve.deadline_expire", p.job.id)
            })
            .map(|(&seq, _)| seq)
            .collect();
        for seq in expired {
            let p = q.remove_seq(seq).expect("seq collected above");
            let waited = now.duration_since(p.admitted).as_micros() as u64;
            c.queued -= 1;
            c.stats.completed += 1;
            c.stats.errors += 1;
            c.stats.deadline_expired += 1;
            c.stats.per_tenant.entry(tenant).or_default().completed += 1;
            out.push(Response {
                id: p.job.id,
                tenant,
                module: p.job.module,
                layer: p.job.layer,
                worker: usize::MAX,
                batch_id: u64::MAX,
                batch_size: 0,
                out: Err(format!(
                    "deadline expired after {waited}µs in queue (deadline {deadline_micros}µs)"
                )),
                queue_micros: waited,
                exec_micros: 0,
                total_micros: waited,
            });
        }
    }
    out
}

fn scheduler_loop(shared: Arc<Shared>, tx: Sender<Response>) {
    let cfg = shared.cfg;
    // Under Reject admission nobody ever blocks on a full queue, so the
    // pause may hold through saturation (tests rely on that); under
    // Block it must yield or a submitter would deadlock.  A drain
    // always overrides the pause: queued work must complete.
    let unblock_on_full = cfg.admission == Admission::Block;
    let mut center = lock(&shared.center);
    loop {
        if cfg.paused
            && !center.closed
            && !center.draining
            && !(unblock_on_full && saturated(&center, cfg.queue_depth))
        {
            center = match shared.sched_cv.wait(center) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            continue;
        }
        if center.queued == 0 {
            if center.closed {
                break;
            }
            center = match shared.sched_cv.wait(center) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            continue;
        }
        // Dispatch throttle: keep at most ~2 batches of work per worker
        // in flight.  Without this the scheduler would drain tenant
        // queues into the (unbounded) worker deques as fast as batches
        // form, and admission control would bound nothing — memory
        // would grow with total submissions, not tenants x queue_depth.
        // Workers notify sched_cv as batches complete.
        let inflight_cap = cfg.workers * cfg.max_batch * 2;
        if center.in_flight >= inflight_cap {
            center = match shared.sched_cv.wait(center) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            continue;
        }
        // Linger for stragglers when the backlog cannot fill a batch
        // yet (skipped when paused: the backlog is already final).
        // Submits notify sched_cv, so each wait must be re-armed
        // against a fixed deadline — otherwise the first arrival would
        // cancel the window and cap live batches at ~2 jobs.
        if !cfg.paused && !center.closed && cfg.linger_micros > 0 && center.queued < cfg.max_batch
        {
            let deadline = Instant::now() + Duration::from_micros(cfg.linger_micros);
            while center.queued > 0 && center.queued < cfg.max_batch && !center.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                center = match shared.sched_cv.wait_timeout(center, deadline - now) {
                    Ok((g, _)) => g,
                    Err(p) => p.into_inner().0,
                };
            }
            if center.queued == 0 {
                continue;
            }
        }
        // Deadline eviction at batch formation: expired jobs get a
        // named terminal Response without ever reaching an executor.
        // The faults::armed() arm exists so the `serve.deadline_expire`
        // failpoint can force expiries with no deadline configured.
        if cfg.deadline_micros > 0 || crate::faults::armed() {
            let expired = evict_expired(&mut center, cfg.deadline_micros);
            if !expired.is_empty() {
                // queue space freed — and possibly the whole backlog
                shared.admit_cv.notify_all();
                if center.queued == 0 && center.in_flight == 0 {
                    shared.drain_cv.notify_all();
                }
                for r in expired {
                    let _ = tx.send(r);
                }
                if center.queued == 0 {
                    continue;
                }
            }
        }
        let batch = match &shared.telemetry {
            Some(t) => {
                let t0 = Instant::now();
                let b = form_batch(&mut center, cfg.max_batch);
                t.timers()
                    .record_ns(telemetry::Stage::BatchForm, t0.elapsed().as_nanos() as u64);
                b
            }
            None => form_batch(&mut center, cfg.max_batch),
        };
        shared.admit_cv.notify_all();
        drop(center);
        {
            let mut pool = lock(&shared.pool);
            let idx = match &shared.route {
                Route::LeastLoaded => {
                    (0..pool.queues.len()).min_by_key(|&i| pool.queues[i].len()).unwrap()
                }
                Route::Owner(_) => batch.owner,
            };
            pool.queues[idx].push_back(batch);
            pool.routed[idx] += 1;
            match &shared.route {
                // least-loaded placement: any single idle worker may
                // take it, so one wakeup suffices
                Route::LeastLoaded => shared.pool_cv.notify_one(),
                // owner placement: notify_one could wake a non-owner
                // that (with stealing off, or below the steal
                // threshold) cannot take the batch and parks again —
                // a lost wakeup.  Wake everyone; non-owners re-park.
                Route::Owner(_) => shared.pool_cv.notify_all(),
            }
        }
        center = lock(&shared.center);
    }
    drop(center);
    let mut pool = lock(&shared.pool);
    pool.done = true;
    shared.pool_cv.notify_all();
}

/// Best-effort text of a caught panic payload (the standard `&str` /
/// `String` payloads; anything else keeps a stable placeholder).
fn panic_message(p: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = p.downcast_ref::<&str>() {
        s
    } else if let Some(s) = p.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

fn worker_loop<E, F>(idx: usize, shared: Arc<Shared>, tx: Sender<Response>, mk: Arc<F>)
where
    E: BatchExecutor,
    F: Fn(usize) -> Result<E, String> + Send + Sync + 'static,
{
    let mut init_error = String::new();
    let mut exec = match mk(idx) {
        Ok(e) => Some(e),
        Err(msg) => {
            init_error = msg;
            None
        }
    };
    // Worker-local latency shard: samples accumulate off the center
    // lock and are sorted exactly once at worker exit, so the run
    // summary combines per-worker shards with one O(total) merge
    // (`Percentiles::merge`) instead of re-sorting a global vector.
    let lat_cap = (LATENCY_RESERVOIR / shared.cfg.workers).max(1);
    let mut latencies: Vec<u64> = Vec::new();
    let mut lat_seen: u64 = 0;
    loop {
        // Pop from the own deque front; steal from the back of the
        // longest peer deque when empty (if stealing is enabled and the
        // victim holds at least `steal_min` batches — owner routing
        // only lets peers skim a victim's surplus).
        let batch = {
            let mut pool = lock(&shared.pool);
            loop {
                if let Some(b) = pool.queues[idx].pop_front() {
                    break Some(b);
                }
                let victim = pool
                    .stealing
                    .then(|| {
                        (0..pool.queues.len())
                            .filter(|&i| i != idx && pool.queues[i].len() >= pool.steal_min)
                            .max_by_key(|&i| pool.queues[i].len())
                    })
                    .flatten();
                if let Some(v) = victim {
                    let b = pool.queues[v].pop_back().unwrap();
                    pool.steals[idx] += 1;
                    break Some(b);
                }
                if pool.done {
                    break None;
                }
                pool = match shared.pool_cv.wait(pool) {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
            }
        };
        let Some(batch) = batch else { break };

        let t0 = Instant::now();
        let mut quarantined_now: u64 = 0;
        let mut results: Vec<Result<AnalyzeOut, String>> = match exec.as_mut() {
            // the telemetry scope installs the stage-timer and
            // difficulty sinks on this thread for the duration of the
            // dispatch; with telemetry off this is a plain call
            Some(e) => {
                let jobs = &batch.jobs;
                match panic::catch_unwind(AssertUnwindSafe(|| {
                    telemetry::scoped(shared.telemetry.as_ref(), || e.run_batch(jobs))
                })) {
                    Ok(r) => r,
                    // A poisoned batch: one job's panic must not take
                    // its batchmates down.  Split and retry each job as
                    // its own single-job batch under its own
                    // catch_unwind — exact, because the fused batch
                    // path is row-local (docs/EQUATIONS.md) — and
                    // quarantine only the job(s) that panic alone.
                    // The executor survives the unwind: the kernel
                    // ThreadPool catches task panics internally and
                    // re-raises them on this thread with the pool
                    // intact, the Workspace re-allocates any buffer
                    // dropped mid-flight, and the RotationCache only
                    // ever gains fully-built entries.
                    Err(_) => jobs
                        .iter()
                        .map(|j| {
                            let one = panic::catch_unwind(AssertUnwindSafe(|| {
                                telemetry::scoped(shared.telemetry.as_ref(), || {
                                    e.run_batch(std::slice::from_ref(j))
                                })
                            }));
                            match one {
                                Ok(mut v) if v.len() == 1 => v.pop().expect("len checked"),
                                Ok(_) => Err(format!(
                                    "worker {idx}: job {} retry returned a wrong result count",
                                    j.id
                                )),
                                Err(p) => {
                                    quarantined_now += 1;
                                    Err(format!(
                                        "worker {idx}: job {} quarantined after panic: {}",
                                        j.id,
                                        panic_message(p.as_ref())
                                    ))
                                }
                            }
                        })
                        .collect(),
                }
            }
            None => batch
                .jobs
                .iter()
                .map(|j| {
                    Err(format!(
                        "worker {idx}: job {} dropped (executor init failed: {init_error})",
                        j.id
                    ))
                })
                .collect(),
        };
        let exec_micros = t0.elapsed().as_micros() as u64;
        let batch_size = batch.jobs.len();
        if results.len() != batch_size {
            results.truncate(batch_size);
            results.resize_with(batch_size, || {
                Err(format!("worker {idx}: batch executor returned a wrong result count"))
            });
        }

        let mut responses = Vec::with_capacity(batch_size);
        {
            let mut center = lock(&shared.center);
            for (m, out) in batch.meta.into_iter().zip(results) {
                let queue_micros = t0.saturating_duration_since(m.admitted).as_micros() as u64;
                if let Some(t) = &shared.telemetry {
                    t.timers().record_ns(
                        telemetry::Stage::AdmissionWait,
                        queue_micros.saturating_mul(1000),
                    );
                }
                let total_micros = m.admitted.elapsed().as_micros() as u64;
                center.stats.completed += 1;
                if out.is_err() {
                    center.stats.errors += 1;
                }
                // Bounded per-worker latency reservoir: the server may
                // live indefinitely, so samples beyond the cap
                // overwrite a deterministic pseudo-random slot
                // (Fibonacci hash of the sample index) instead of
                // growing the Vec.
                if latencies.len() < lat_cap {
                    latencies.push(total_micros);
                } else {
                    let slot =
                        (lat_seen.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 16) as usize % lat_cap;
                    latencies[slot] = total_micros;
                }
                lat_seen += 1;
                center.stats.per_tenant.entry(m.tenant).or_default().completed += 1;
                responses.push(Response {
                    id: m.id,
                    tenant: m.tenant,
                    module: m.module,
                    layer: m.layer,
                    worker: idx,
                    batch_id: batch.id,
                    batch_size,
                    out,
                    queue_micros,
                    exec_micros,
                    total_micros,
                });
            }
            center.in_flight -= batch_size;
            center.stats.quarantined += quarantined_now;
            center.stats.exec_micros_total += exec_micros;
            center.stats.per_worker_batches[idx] += 1;
        }
        // Wake the scheduler: completed work frees in-flight budget.
        // A drain waiter watches the same completions.
        shared.sched_cv.notify_one();
        shared.drain_cv.notify_all();
        for r in responses {
            // The receiver may have been dropped; completion is still
            // recorded in the metrics above.
            let _ = tx.send(r);
        }
    }
    // On exit, fold this worker's rotation-cache counters and its
    // sorted latency shard into the run summary (the executor lives
    // and dies with the worker thread).
    let mut shard: Vec<f64> = latencies.into_iter().map(|v| v as f64).collect();
    shard.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rotation = exec.as_ref().and_then(|e| e.rotation_stats());
    {
        let mut center = lock(&shared.center);
        center.stats.worker_latencies[idx] = shard;
        if let Some(stats) = rotation {
            center.stats.rotation.merge(stats);
        }
    }
}

/// Draw a tenant id with the demo skew: tenant 0 is the noisy neighbor
/// (~40% of the load) and the rest share the remainder uniformly.
pub fn skewed_tenant(rng: &mut crate::rng::Rng, tenants: usize) -> TenantId {
    if tenants <= 1 || rng.below(10) < 4 {
        0
    } else {
        1 + rng.below(tenants - 1)
    }
}

/// Synthetic multi-tenant request stream over paper-shaped activations
/// (via [`crate::synth::module_stream`], so no AOT artifacts are
/// needed): modules drawn uniformly at SynLlama scale, layers drawn
/// from `0..layers` (clamped to the model depth — pass the calibrated
/// layer count so every request hits a `--plan` entry), tenants drawn
/// by [`skewed_tenant`], `rows` token rows per request.  Activations
/// vary per request (per-request seeds), but every request for a given
/// (module, layer) shares the **fixed** weight of the stream's base
/// seed ([`crate::synth::layer_weight`]) — the "model" being served —
/// so the int8 plan registry can pre-quantize each layer's weight once
/// and have it match every request.  Shared by the `smoothrot serve`
/// native backend and the serving example.
pub fn synthetic_requests(
    n: usize,
    tenants: usize,
    rows: usize,
    layers: usize,
    seed: u64,
) -> Vec<(TenantId, Job)> {
    synthetic_requests_with(n, tenants, rows, layers, seed, |rng, layers| rng.below(layers))
}

/// [`synthetic_requests`] with a layer-skewed draw
/// ([`crate::synth::skewed_layer`]): ~half the stream lands on layer 0.
/// Under layer sharding that concentrates load on one runner — the
/// workload the `--runners` CI smoke uses to prove work stealing keeps
/// every runner busy while the steal threshold still guarantees the
/// hot shard's owner executes work of its own.
pub fn synthetic_requests_skewed(
    n: usize,
    tenants: usize,
    rows: usize,
    layers: usize,
    seed: u64,
) -> Vec<(TenantId, Job)> {
    synthetic_requests_with(n, tenants, rows, layers, seed, crate::synth::skewed_layer)
}

fn synthetic_requests_with(
    n: usize,
    tenants: usize,
    rows: usize,
    layers: usize,
    seed: u64,
    mut layer_of: impl FnMut(&mut crate::rng::Rng, usize) -> usize,
) -> Vec<(TenantId, Job)> {
    let model = crate::config::ModelConfig::default();
    let layers = layers.clamp(1, model.n_layers);
    let mut rng = crate::rng::Rng::new(seed);
    // the fixed per-layer weights are shared by every request of a
    // (module, layer), so generate each at most once and hand out
    // clones instead of re-running the O(c_in * c_out) generator per
    // request
    let mut weights: BTreeMap<(&'static str, usize), crate::tensor::Matrix> = BTreeMap::new();
    (0..n)
        .map(|i| {
            let tenant = skewed_tenant(&mut rng, tenants);
            let module = crate::MODULES[rng.below(4)];
            let layer = layer_of(&mut rng, layers);
            let (mut spec, _) =
                crate::synth::module_stream(module, seed.wrapping_add(7 + i as u64))
                    .expect("known module");
            spec.n_tokens = rows.max(1);
            let w = weights
                .entry((module, layer))
                .or_insert_with(|| {
                    crate::synth::layer_weight(module, layer, seed).expect("known module")
                })
                .clone();
            let job = Job {
                id: i as u64,
                layer,
                module,
                x: spec.layer(layer),
                w,
                alpha: model.alpha as f32,
                bits: model.bits,
            };
            (tenant, job)
        })
        .collect()
}

/// Resolve the between-batches [`Workspace`] trim budget from the CLI
/// value and the `SMOOTHROT_TRIM_BYTES` environment variable
/// ([`trim_bytes_from`] is the pure, testable core).  Precedence: CLI >
/// env > [`NativeBatchExecutor::TRIM_BYTES`]; `0` disables trimming
/// entirely (resolves to `usize::MAX`).  With N sharded runners each
/// holding its own workspace, total steady-state retention is
/// `runners x trim_bytes` — size the budget with that product in mind.
pub fn resolve_trim_bytes(cli: Option<usize>) -> Result<usize, String> {
    let env = std::env::var("SMOOTHROT_TRIM_BYTES").ok();
    trim_bytes_from(cli, env.as_deref())
}

/// [`resolve_trim_bytes`] with the environment value passed in
/// explicitly.  An empty (or whitespace) env value counts as unset; a
/// non-numeric one is a named error, never a silent default.
pub fn trim_bytes_from(cli: Option<usize>, env: Option<&str>) -> Result<usize, String> {
    let raw = match (cli, env.map(str::trim).filter(|s| !s.is_empty())) {
        (Some(v), _) => v,
        (None, Some(s)) => s
            .parse::<usize>()
            .map_err(|e| format!("SMOOTHROT_TRIM_BYTES={s:?}: {e}"))?,
        (None, None) => NativeBatchExecutor::TRIM_BYTES,
    };
    Ok(if raw == 0 { usize::MAX } else { raw })
}

/// Convenience driver: start a server, submit every request, drain and
/// return all responses (in completion order) plus the run metrics.
///
/// Requests rejected at admission (only possible under
/// [`Admission::Reject`]) are skipped and counted in
/// [`ServeMetrics::rejected`].
pub fn serve_all<E, F>(
    cfg: ServeConfig,
    requests: Vec<(TenantId, Job)>,
    make_executor: F,
) -> Result<(Vec<Response>, ServeMetrics), SubmitError>
where
    E: BatchExecutor,
    F: Fn(usize) -> Result<E, String> + Send + Sync + 'static,
{
    serve_all_with_telemetry(cfg, None, requests, make_executor)
}

/// [`serve_all`] with a [`Telemetry`] subsystem attached (see
/// [`Server::start_with_telemetry`]) — the driver behind
/// `smoothrot serve --metrics-file` and the telemetry-overhead bench
/// scenario.
pub fn serve_all_with_telemetry<E, F>(
    cfg: ServeConfig,
    telemetry: Option<Arc<Telemetry>>,
    requests: Vec<(TenantId, Job)>,
    make_executor: F,
) -> Result<(Vec<Response>, ServeMetrics), SubmitError>
where
    E: BatchExecutor,
    F: Fn(usize) -> Result<E, String> + Send + Sync + 'static,
{
    let (server, responses) = Server::start_with_telemetry(cfg, telemetry, make_executor);
    for (tenant, job) in requests {
        match server.submit(tenant, job) {
            Ok(()) | Err(SubmitError::Full { .. } | SubmitError::Shed { .. }) => {}
            Err(e) => return Err(e),
        }
    }
    let metrics = server.finish();
    Ok((responses.into_iter().collect(), metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NativeExecutor;
    use crate::rng::Rng;
    use crate::tensor::Matrix;

    fn job(id: u64, module: &'static str, c_in: usize, c_out: usize) -> Job {
        Job {
            id,
            layer: (id as usize) % 4,
            module,
            x: Matrix::zeros(4, c_in),
            w: Matrix::zeros(c_in, c_out),
            alpha: 0.5,
            bits: 4,
        }
    }

    /// Cheap executor that keys its output to the job id.
    struct SleepExec {
        micros: u64,
    }

    impl Executor for SleepExec {
        fn run(&mut self, job: &Job) -> Result<AnalyzeOut, String> {
            if self.micros > 0 {
                std::thread::sleep(Duration::from_micros(self.micros));
            }
            let mut out = AnalyzeOut::default();
            out.errors[0] = job.id as f64;
            Ok(out)
        }
    }

    #[test]
    fn all_requests_complete_exactly_once() {
        let cfg = ServeConfig { workers: 3, max_batch: 4, queue_depth: 64, ..Default::default() };
        let reqs: Vec<(TenantId, Job)> = (0..50)
            .map(|i| ((i % 3) as TenantId, job(i, crate::MODULES[(i % 4) as usize], 8, 8)))
            .collect();
        let (responses, m) = serve_all(cfg, reqs, |_| Ok(SleepExec { micros: 50 })).unwrap();
        assert_eq!(responses.len(), 50);
        assert_eq!(m.completed, 50);
        assert_eq!(m.errors, 0);
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 50, "every job exactly once");
        for r in &responses {
            assert_eq!(r.out.as_ref().unwrap().errors[0] as u64, r.id, "result keyed to job");
            assert!(r.total_micros >= r.queue_micros);
        }
        assert_eq!(m.per_worker_batches.len(), 3);
        assert_eq!(m.per_worker_batches.iter().sum::<u64>(), m.batches);
        assert!(m.latency.p50 > 0.0 && m.latency.p50 <= m.latency.p99);
        assert!(m.throughput() > 0.0);
        assert!(m.mean_batch() >= 1.0);
    }

    #[test]
    fn batches_coalesce_same_key_up_to_max_batch() {
        // paused server: all ten same-key jobs are queued before any
        // scheduling, so batches form deterministically as 4 + 4 + 2
        let cfg = ServeConfig {
            workers: 1,
            max_batch: 4,
            queue_depth: 64,
            paused: true,
            ..Default::default()
        };
        let reqs = (0..10).map(|i| (0, job(i, "k_proj", 8, 8))).collect();
        let (responses, m) = serve_all(cfg, reqs, |_| Ok(SleepExec { micros: 0 })).unwrap();
        assert_eq!(m.batches, 3);
        assert_eq!(m.max_batch_observed, 4);
        let mut by_batch: BTreeMap<u64, usize> = BTreeMap::new();
        for r in &responses {
            *by_batch.entry(r.batch_id).or_default() += 1;
        }
        let mut sizes: Vec<usize> = by_batch.values().copied().collect();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![2, 4, 4]);
        for r in &responses {
            assert_eq!(r.batch_size, by_batch[&r.batch_id], "batch_size field consistent");
        }
    }

    #[test]
    fn incompatible_keys_never_share_a_batch() {
        // alternate two modules from one tenant; coalescing must regroup
        // them into two single-module batches without starving either
        let cfg = ServeConfig {
            workers: 1,
            max_batch: 8,
            queue_depth: 64,
            paused: true,
            ..Default::default()
        };
        let reqs = (0..12)
            .map(|i| (0, job(i, if i % 2 == 0 { "k_proj" } else { "o_proj" }, 8, 8)))
            .collect();
        let (responses, m) = serve_all(cfg, reqs, |_| Ok(SleepExec { micros: 0 })).unwrap();
        assert_eq!(m.completed, 12);
        assert_eq!(m.batches, 2, "one batch per key");
        let mut modules_by_batch: BTreeMap<u64, Vec<&str>> = BTreeMap::new();
        for r in &responses {
            modules_by_batch.entry(r.batch_id).or_default().push(r.module);
        }
        for (batch, modules) in &modules_by_batch {
            assert!(
                modules.windows(2).all(|w| w[0] == w[1]),
                "batch {batch} mixes modules: {modules:?}"
            );
        }
    }

    #[test]
    fn reject_admission_rejects_when_tenant_queue_full() {
        let cfg = ServeConfig {
            workers: 1,
            max_batch: 4,
            queue_depth: 2,
            admission: Admission::Reject,
            paused: true,
            ..Default::default()
        };
        let (server, rx) = Server::start(cfg, |_| Ok(SleepExec { micros: 0 }));
        let (mut ok, mut full) = (0, 0);
        for i in 0..5 {
            match server.submit(7, job(i, "k_proj", 8, 8)) {
                Ok(()) => ok += 1,
                Err(SubmitError::Full { tenant }) => {
                    assert_eq!(tenant, 7);
                    full += 1;
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert_eq!((ok, full), (2, 3), "queue depth 2 admits 2 of 5");
        let m = server.finish();
        assert_eq!(m.completed, 2);
        assert_eq!(m.rejected, 3);
        assert_eq!(m.per_tenant[&7], TenantStats { submitted: 2, completed: 2, rejected: 3 });
        assert_eq!(rx.iter().count(), 2);
    }

    #[test]
    fn block_admission_completes_everything_through_a_tiny_queue() {
        let cfg = ServeConfig {
            workers: 2,
            max_batch: 2,
            queue_depth: 2,
            admission: Admission::Block,
            linger_micros: 0,
            ..Default::default()
        };
        let reqs = (0..30).map(|i| (0, job(i, "k_proj", 8, 8))).collect();
        let (responses, m) = serve_all(cfg, reqs, |_| Ok(SleepExec { micros: 300 })).unwrap();
        assert_eq!(m.completed, 30);
        assert_eq!(m.rejected, 0);
        assert_eq!(responses.len(), 30);
    }

    #[test]
    fn paused_block_admission_cannot_deadlock_on_saturation() {
        // a paused scheduler must still drain when a Block-mode
        // submitter saturates a tenant queue, or submit() would hang
        let cfg = ServeConfig {
            workers: 1,
            max_batch: 2,
            queue_depth: 2,
            admission: Admission::Block,
            paused: true,
            ..Default::default()
        };
        let reqs = (0..9).map(|i| (0, job(i, "k_proj", 8, 8))).collect();
        let (responses, m) = serve_all(cfg, reqs, |_| Ok(SleepExec { micros: 0 })).unwrap();
        assert_eq!(m.completed, 9);
        assert_eq!(responses.len(), 9);
    }

    #[test]
    fn skewed_load_does_not_starve_the_small_tenant() {
        // tenant 0 floods 40 requests, tenant 1 submits 8 afterwards;
        // fair-share filling must interleave them (~2+2 per batch), so
        // the small tenant finishes in the first third of the stream
        // instead of after the flood (position 47 under plain FIFO)
        let cfg = ServeConfig {
            workers: 1,
            max_batch: 4,
            queue_depth: 64,
            paused: true,
            ..Default::default()
        };
        let mut reqs = Vec::new();
        for i in 0..40 {
            reqs.push((0, job(i, "k_proj", 8, 8)));
        }
        for i in 0..8 {
            reqs.push((1, job(100 + i, "k_proj", 8, 8)));
        }
        let (responses, m) = serve_all(cfg, reqs, |_| Ok(SleepExec { micros: 0 })).unwrap();
        assert_eq!(m.completed, 48);
        assert_eq!(m.per_tenant[&1].completed, 8);
        let last_small = responses.iter().rposition(|r| r.tenant == 1).unwrap();
        assert!(last_small < 24, "small tenant starved: last completion at {last_small}");
    }

    #[test]
    fn executor_init_failure_surfaces_as_errored_responses() {
        let cfg = ServeConfig { workers: 1, max_batch: 4, queue_depth: 16, ..Default::default() };
        let reqs = (0..6).map(|i| (0, job(i, "k_proj", 8, 8))).collect();
        let (responses, m) =
            serve_all(cfg, reqs, |_| Err::<NativeBatchExecutor, _>("no artifacts".to_string()))
                .unwrap();
        assert_eq!(m.completed, 6);
        assert_eq!(m.errors, 6);
        assert_eq!(responses.len(), 6);
        for r in &responses {
            assert!(r.out.as_ref().unwrap_err().contains("no artifacts"));
        }
    }

    #[test]
    fn native_batch_executor_matches_native_executor() {
        let mut rng = Rng::new(9);
        let x = Matrix::from_vec(8, 16, rng.normals_f32(8 * 16));
        let w = Matrix::from_vec(16, 8, rng.normals_f32(16 * 8));
        let j = Job { id: 0, layer: 0, module: "k_proj", x: x.clone(), w: w.clone(), alpha: 0.5, bits: 4 };
        let mut be = NativeBatchExecutor::new();
        let got = be.run_batch(std::slice::from_ref(&j));
        let want = NativeExecutor::analyze(&x, &w, 4, 0.5).unwrap();
        assert_eq!(got.len(), 1);
        let got = got[0].as_ref().unwrap();
        assert_eq!(got.errors, want.errors);
        assert_eq!(got.act_difficulty, want.act_difficulty);
        // rotation cache warmed once for the single width
        assert_eq!(be.cache.len(), 1);
    }

    #[test]
    fn plan_driven_executor_applies_the_calibrated_transform() {
        use crate::calib::plan::{PlanEntry, Provenance, QuantPlan};
        use crate::calib::registry::PlanRegistry;
        use crate::transforms::Mode;

        // plan covering k_proj layers 0..4 at the test jobs' shape
        let plan = QuantPlan {
            provenance: Provenance::default(),
            entries: (0..4)
                .map(|layer| PlanEntry {
                    module: "k_proj".into(),
                    layer,
                    bits: 4,
                    c_in: 8,
                    mode: Mode::Rotate,
                    alpha: 0.5,
                    predicted_error: 1.0,
                    difficulty_before: 2.0,
                    difficulty_after: 1.0,
                    smooth: None,
                })
                .collect(),
        };
        let reg = Arc::new(PlanRegistry::from_plan(&plan).unwrap());
        let cfg = ServeConfig { workers: 2, max_batch: 4, queue_depth: 64, ..Default::default() };
        let reqs: Vec<(TenantId, Job)> =
            (0..12).map(|i| (0, job(i, "k_proj", 8, 8))).collect();
        let reg2 = Arc::clone(&reg);
        let (responses, m) =
            serve_all(cfg, reqs, move |_| Ok(NativeBatchExecutor::with_plan(Arc::clone(&reg2), 1)))
                .unwrap();
        assert_eq!(m.completed, 12);
        assert_eq!(m.errors, 0);
        let (planned, fallback) = reg.stats();
        assert_eq!((planned, fallback), (12, 0), "every request must hit the plan");
        for r in &responses {
            let out = r.out.as_ref().unwrap();
            // only the planned mode was evaluated; argmin recovers it
            let best = Mode::ALL
                .into_iter()
                .min_by(|a, b| {
                    out.errors[a.index()].partial_cmp(&out.errors[b.index()]).unwrap()
                })
                .unwrap();
            assert_eq!(best, Mode::Rotate);
            assert!(out.errors[Mode::None.index()].is_infinite());
        }
    }

    #[test]
    fn int8_exec_runs_the_integer_path_and_tracks_f32() {
        use crate::calib::plan::{PlanEntry, Provenance, QuantPlan};
        use crate::calib::registry::PlanRegistry;
        use crate::transforms::Mode;

        let c_in = 16usize;
        let plan = QuantPlan {
            provenance: Provenance::default(),
            entries: vec![PlanEntry {
                module: "k_proj".into(),
                layer: 0,
                bits: 4,
                c_in,
                mode: Mode::Rotate,
                alpha: 0.5,
                predicted_error: 1.0,
                difficulty_before: 2.0,
                difficulty_after: 1.0,
                smooth: None,
            }],
        };
        let reg = Arc::new(PlanRegistry::from_plan(&plan).unwrap());
        let mut rng = Rng::new(77);
        let w = Matrix::from_vec(c_in, 8, rng.normals_f32(c_in * 8));
        let w2 = w.clone();
        reg.set_weight_provider(Box::new(move |module, layer| {
            (module == "k_proj" && layer == 0).then(|| w2.clone())
        }))
        .unwrap();
        assert_eq!(reg.preloaded(), 1);
        let x = Matrix::from_vec(8, c_in, rng.normals_f32(8 * c_in));
        let j = Job { id: 0, layer: 0, module: "k_proj", x, w, alpha: 0.5, bits: 4 };
        let mut sim_exec = NativeBatchExecutor::with_plan(Arc::clone(&reg), 1);
        let sim = sim_exec.run(&j).unwrap();
        let mut int_exec =
            NativeBatchExecutor::with_plan_exec(Arc::clone(&reg), 1, ExecMode::Int8);
        let exec = int_exec.run(&j).unwrap();
        let i = Mode::Rotate.index();
        // executed (integer) error tracks the simulated (f32 qdq) error
        let denom = sim.errors[i].max(1e-12);
        let rel = (sim.errors[i] - exec.errors[i]).abs() / denom;
        assert!(rel < 1e-2, "sim {} vs exec {}", sim.errors[i], exec.errors[i]);
        // the planned-mode shape is preserved: argmin recovers the plan
        assert!(exec.errors[Mode::None.index()].is_infinite());
        let (planned, fallback) = reg.stats();
        assert_eq!((planned, fallback), (2, 0), "both paths must hit the plan");
        // only the Int8 executor bumps the int8 counters, and it
        // really ran the integer pipeline (no silent degradation)
        assert_eq!(reg.int8_stats(), (1, 0));
    }

    #[test]
    fn mixed_key_deep_queue_keeps_per_key_fifo() {
        // one tenant interleaves two keys deeply; the key-indexed queue
        // must form key-pure batches that preserve admission order per
        // key (the O(batch) form_batch satellite)
        let cfg = ServeConfig {
            workers: 1,
            max_batch: 4,
            queue_depth: 64,
            paused: true,
            ..Default::default()
        };
        let reqs: Vec<(TenantId, Job)> = (0..24)
            .map(|i| (0, job(i, if i % 2 == 0 { "k_proj" } else { "o_proj" }, 8, 8)))
            .collect();
        let (responses, m) = serve_all(cfg, reqs, |_| Ok(SleepExec { micros: 0 })).unwrap();
        assert_eq!(m.completed, 24);
        assert_eq!(m.batches, 6, "12 jobs per key at max_batch 4");
        let mut by_batch: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
        for r in &responses {
            by_batch.entry(r.batch_id).or_default().push(r.id);
        }
        for (id, members) in &by_batch {
            assert_eq!(members.len(), 4, "batch {id} not full");
            assert!(
                members.windows(2).all(|w| w[0] % 2 == w[1] % 2),
                "batch {id} mixes keys: {members:?}"
            );
            assert!(
                members.windows(2).all(|w| w[0] < w[1]),
                "batch {id} violates per-key FIFO: {members:?}"
            );
        }
    }

    /// Shared fixture for the batch-fusion tests: a 2-layer int8 plan
    /// with per-layer weights installed, plus a same-key request mix
    /// across both layers and varying row counts.
    fn int8_fixture(c_in: usize, n_jobs: usize) -> (Arc<PlanRegistry>, Vec<(TenantId, Job)>) {
        use crate::calib::plan::{PlanEntry, Provenance, QuantPlan};
        use crate::transforms::Mode;

        let plan = QuantPlan {
            provenance: Provenance::default(),
            entries: (0..2)
                .map(|layer| PlanEntry {
                    module: "k_proj".into(),
                    layer,
                    bits: 4,
                    c_in,
                    mode: Mode::Rotate,
                    alpha: 0.5,
                    predicted_error: 1.0,
                    difficulty_before: 2.0,
                    difficulty_after: 1.0,
                    smooth: None,
                })
                .collect(),
        };
        let reg = Arc::new(PlanRegistry::from_plan(&plan).unwrap());
        reg.set_weight_provider(Box::new(move |module, layer| {
            (module == "k_proj" && layer < 2).then(|| {
                let mut rng = Rng::new(900 + layer as u64);
                Matrix::from_vec(c_in, 8, rng.normals_f32(c_in * 8))
            })
        }))
        .unwrap();
        let mut rng = Rng::new(901);
        let reqs = (0..n_jobs)
            .map(|i| {
                let layer = i % 2;
                let rows = 2 + (i % 5);
                let x = Matrix::from_vec(rows, c_in, rng.normals_f32(rows * c_in));
                let w = {
                    let mut wr = Rng::new(900 + layer as u64);
                    Matrix::from_vec(c_in, 8, wr.normals_f32(c_in * 8))
                };
                let j = Job {
                    id: i as u64,
                    layer,
                    module: "k_proj",
                    x,
                    w,
                    alpha: 0.5,
                    bits: 4,
                };
                (0, j)
            })
            .collect();
        (reg, reqs)
    }

    #[test]
    fn batch_fused_int8_is_bit_identical_to_per_job() {
        // the tentpole pin at the executor level: run_batch's stacked
        // path must reproduce per-job execution exactly, across mixed
        // layers and row counts within one dispatch
        let (reg_fused, reqs) = int8_fixture(16, 10);
        let jobs: Vec<Job> = reqs.iter().map(|(_, j)| j.clone()).collect();
        let mut fused_exec =
            NativeBatchExecutor::with_plan_exec(Arc::clone(&reg_fused), 1, ExecMode::Int8);
        let fused = fused_exec.run_batch(&jobs);

        let (reg_pj, _) = int8_fixture(16, 10);
        let mut per_job_exec =
            NativeBatchExecutor::with_plan_exec(Arc::clone(&reg_pj), 1, ExecMode::Int8)
                .with_batch_fusion(false);
        let per_job = per_job_exec.run_batch(&jobs);

        assert_eq!(fused.len(), per_job.len());
        for (i, (a, b)) in fused.iter().zip(&per_job).enumerate() {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_eq!(a.errors, b.errors, "job {i} errors must be bit-identical");
            assert_eq!(a.act_difficulty, b.act_difficulty, "job {i} difficulty");
            assert_eq!(a.w_difficulty, b.w_difficulty, "job {i} w difficulty");
            assert_eq!(a.act_absmax, b.act_absmax, "job {i} absmax");
        }
        // the fused run really stacked: 10 jobs in 2 fused groups, all
        // counted (and observable — this is the batch_fused counter the
        // serve CLI gates on)
        assert_eq!(reg_fused.batch_fused(), 10);
        assert_eq!(reg_fused.int8_stats(), (10, 0));
        assert_eq!(reg_fused.stats(), (10, 0), "coverage keeps per-request meaning");
        // the per-job baseline never touches the fused counter
        assert_eq!(reg_pj.batch_fused(), 0);
        assert_eq!(reg_pj.int8_stats(), (10, 0));
    }

    #[test]
    fn kernel_backend_is_pinned_reported_and_bit_identical() {
        // every SIMD backend the host detects must reproduce the
        // scalar executor's results exactly, through the full
        // plan-driven int8 batch path (transform, per-token quantize,
        // fused GEMM) — and the pinned choice must be observable
        let (reg, reqs) = int8_fixture(16, 8);
        let jobs: Vec<Job> = reqs.iter().map(|(_, j)| j.clone()).collect();
        let mut scalar_exec =
            NativeBatchExecutor::with_plan_exec(Arc::clone(&reg), 1, ExecMode::Int8)
                .with_kernel_backend(KernelBackend::Scalar);
        assert_eq!(scalar_exec.kernel_backend(), KernelBackend::Scalar);
        let want = scalar_exec.run_batch(&jobs);
        for backend in [KernelBackend::Avx2, KernelBackend::Neon] {
            if !backend.available() {
                continue;
            }
            let (reg_b, _) = int8_fixture(16, 8);
            let mut exec =
                NativeBatchExecutor::with_plan_exec(Arc::clone(&reg_b), 1, ExecMode::Int8)
                    .with_kernel_backend(backend);
            assert_eq!(exec.kernel_backend(), backend);
            let got = exec.run_batch(&jobs);
            assert!(reg_b.batch_fused() > 0, "{backend}: the batch-fused gate must stay green");
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
                assert_eq!(a.errors, b.errors, "{backend} job {i}: errors must be bit-identical");
                assert_eq!(a.act_difficulty, b.act_difficulty, "{backend} job {i}: difficulty");
                assert_eq!(a.act_absmax, b.act_absmax, "{backend} job {i}: absmax");
            }
        }
        // construction defaults to the process default (SMOOTHROT_KERNEL
        // when set — the CI matrix knob — else hardware detection)
        assert_eq!(NativeBatchExecutor::new().kernel_backend(), simd::default_backend());
    }

    #[test]
    fn batch_fused_serving_end_to_end_matches_per_job_serving() {
        let (reg_fused, reqs) = int8_fixture(16, 12);
        let cfg = ServeConfig {
            workers: 1,
            max_batch: 6,
            queue_depth: 64,
            paused: true,
            ..Default::default()
        };
        let rf = Arc::clone(&reg_fused);
        let (responses_fused, m1) = serve_all(cfg, reqs.clone(), move |_| {
            Ok(NativeBatchExecutor::with_plan_exec(Arc::clone(&rf), 1, ExecMode::Int8))
        })
        .unwrap();
        assert_eq!(m1.completed, 12);
        assert!(reg_fused.batch_fused() > 0, "scheduler batches must reach the fused path");

        let (reg_pj, _) = int8_fixture(16, 12);
        let rp = Arc::clone(&reg_pj);
        let (responses_pj, m2) = serve_all(cfg, reqs, move |_| {
            Ok(NativeBatchExecutor::with_plan_exec(Arc::clone(&rp), 1, ExecMode::Int8)
                .with_batch_fusion(false))
        })
        .unwrap();
        assert_eq!(m2.completed, 12);
        assert_eq!(reg_pj.batch_fused(), 0);

        let by_id = |rs: &[Response]| -> BTreeMap<u64, AnalyzeOut> {
            rs.iter().map(|r| (r.id, r.out.as_ref().unwrap().clone())).collect()
        };
        let (fused, pj) = (by_id(&responses_fused), by_id(&responses_pj));
        assert_eq!(fused.len(), 12);
        for (id, a) in &fused {
            let b = &pj[id];
            assert_eq!(a.errors, b.errors, "request {id} diverged between paths");
            assert_eq!(a.act_difficulty, b.act_difficulty, "request {id} difficulty");
            assert_eq!(a.act_absmax, b.act_absmax, "request {id} absmax");
        }
    }

    #[test]
    fn run_batch_trims_burst_scratch_between_batches() {
        // simulate the aftermath of a giant request by parking burst
        // buffers in the executor's scratch; the next run_batch must
        // shrink retained capacity back under the steady budget
        let mut exec = NativeBatchExecutor::new();
        exec.scratch.give(vec![0.0f32; (NativeBatchExecutor::TRIM_BYTES * 2) / 4]);
        assert!(exec.scratch.pooled_bytes() > NativeBatchExecutor::TRIM_BYTES);
        let small = job(1, "k_proj", 8, 8);
        let out = exec.run_batch(std::slice::from_ref(&small));
        assert!(out[0].is_ok());
        assert!(
            exec.scratch.pooled_bytes() <= NativeBatchExecutor::TRIM_BYTES,
            "burst scratch must be trimmed between batches ({} bytes retained)",
            exec.scratch.pooled_bytes()
        );
        // ordinary traffic afterwards reaches an allocation-free steady
        // state despite the per-batch trim
        for _ in 0..3 {
            exec.run_batch(std::slice::from_ref(&small));
        }
        let (_, warm) = exec.scratch.stats();
        for _ in 0..4 {
            exec.run_batch(std::slice::from_ref(&small));
        }
        let (_, allocs) = exec.scratch.stats();
        assert_eq!(allocs, warm, "steady state with per-batch trim must not allocate");
        // a raised budget retains the burst (big-shape deployments)
        let mut lax = NativeBatchExecutor::new().with_trim_budget(usize::MAX);
        lax.scratch.give(vec![0.0f32; (NativeBatchExecutor::TRIM_BYTES * 2) / 4]);
        lax.run_batch(std::slice::from_ref(&small));
        assert!(
            lax.scratch.pooled_bytes() > NativeBatchExecutor::TRIM_BYTES,
            "with_trim_budget(usize::MAX) must disable trimming"
        );
    }

    #[test]
    fn trim_budget_resolution_precedence_and_zero() {
        // CLI > env > built-in default
        assert_eq!(trim_bytes_from(Some(1024), Some("2048")), Ok(1024));
        assert_eq!(trim_bytes_from(None, Some("2048")), Ok(2048));
        assert_eq!(trim_bytes_from(None, None), Ok(NativeBatchExecutor::TRIM_BYTES));
        // 0 = never trim, from either source
        assert_eq!(trim_bytes_from(Some(0), None), Ok(usize::MAX));
        assert_eq!(trim_bytes_from(None, Some("0")), Ok(usize::MAX));
        // empty / whitespace env counts as unset; a CLI value masks a
        // bad env value (it is never parsed)
        assert_eq!(trim_bytes_from(None, Some("")), Ok(NativeBatchExecutor::TRIM_BYTES));
        assert_eq!(trim_bytes_from(None, Some("  ")), Ok(NativeBatchExecutor::TRIM_BYTES));
        assert_eq!(trim_bytes_from(None, Some(" 4096 ")), Ok(4096));
        assert_eq!(trim_bytes_from(Some(512), Some("not-a-number")), Ok(512));
        let err = trim_bytes_from(None, Some("16MiB")).unwrap_err();
        assert!(err.contains("SMOOTHROT_TRIM_BYTES"), "error must name the env var: {err}");
    }

    #[test]
    fn uncovered_jobs_fall_back_to_the_full_analyze() {
        use crate::calib::plan::{PlanEntry, Provenance, QuantPlan};
        use crate::calib::registry::PlanRegistry;

        let plan = QuantPlan {
            provenance: Provenance::default(),
            entries: vec![PlanEntry {
                module: "k_proj".into(),
                layer: 0,
                bits: 4,
                c_in: 16,
                mode: crate::transforms::Mode::None,
                alpha: 0.5,
                predicted_error: 1.0,
                difficulty_before: 1.0,
                difficulty_after: 1.0,
                smooth: None,
            }],
        };
        let reg = Arc::new(PlanRegistry::from_plan(&plan).unwrap());
        let mut exec = NativeBatchExecutor::with_plan(Arc::clone(&reg), 1);
        // o_proj is not in the plan: full analyze, all four modes finite
        let mut rng = Rng::new(31);
        let x = Matrix::from_vec(8, 16, rng.normals_f32(8 * 16));
        let w = Matrix::from_vec(16, 8, rng.normals_f32(16 * 8));
        let j = Job { id: 0, layer: 0, module: "o_proj", x, w, alpha: 0.5, bits: 4 };
        let out = exec.run(&j).unwrap();
        assert!(out.errors.iter().all(|e| e.is_finite()));
        let (planned, fallback) = reg.stats();
        assert_eq!((planned, fallback), (0, 1));
    }

    #[test]
    fn rotation_cache_stats_surface_in_metrics() {
        let cfg = ServeConfig { workers: 1, max_batch: 4, queue_depth: 64, ..Default::default() };
        let reqs = (0..10).map(|i| (0, job(i, "k_proj", 8, 8))).collect();
        let (_, m) = serve_all(cfg, reqs, |_| Ok(NativeBatchExecutor::new())).unwrap();
        // one rotation lookup per request; the single worker builds the
        // width-8 rotation exactly once and hits thereafter
        assert_eq!(m.rotation.lookups(), 10);
        assert_eq!(m.rotation.misses, 1);
        assert_eq!(m.rotation.hits, 9);
        assert!(m.summary().contains("rot-cache 9 hit / 1 miss"), "{}", m.summary());
    }

    #[test]
    fn batch_key_separates_and_groups() {
        let a = BatchKey::of(&job(0, "k_proj", 8, 8));
        let b = BatchKey::of(&job(1, "k_proj", 8, 8));
        assert_eq!(a, b, "same config, different ids share a key");
        assert_ne!(a, BatchKey::of(&job(2, "o_proj", 8, 8)), "module splits");
        let mut wide = job(3, "k_proj", 16, 8);
        wide.bits = 4;
        assert_ne!(a, BatchKey::of(&wide), "shape splits");
        let mut coarse = job(4, "k_proj", 8, 8);
        coarse.bits = 8;
        assert_ne!(a, BatchKey::of(&coarse), "bits split");
        assert_eq!(a.alpha(), 0.5);
    }

    #[test]
    fn submit_after_finish_is_closed() {
        let cfg = ServeConfig { workers: 1, ..Default::default() };
        let (server, _rx) = Server::start(cfg, |_| Ok(SleepExec { micros: 0 }));
        server.submit(0, job(0, "k_proj", 8, 8)).unwrap();
        // finish consumes the server; a second one proves Closed
        let m = server.finish();
        assert_eq!(m.completed, 1);
        let (server2, _rx2) = Server::start(cfg, |_| Ok(SleepExec { micros: 0 }));
        {
            let mut center = lock(&server2.shared.center);
            center.closed = true;
        }
        assert_eq!(server2.submit(0, job(1, "k_proj", 8, 8)), Err(SubmitError::Closed));
    }

    /// Executor that panics whenever it sees the poison job id.
    struct PanicExec {
        poison: u64,
    }

    impl Executor for PanicExec {
        fn run(&mut self, job: &Job) -> Result<AnalyzeOut, String> {
            if job.id == self.poison {
                panic!("poison job {}", job.id);
            }
            let mut out = AnalyzeOut::default();
            out.errors[0] = job.id as f64;
            Ok(out)
        }
    }

    #[test]
    fn panicking_job_is_quarantined_and_batchmates_survive() {
        // paused server, one worker: eight same-key jobs form two
        // batches of four; job 2 panics its batch, the worker splits
        // and retries per job, quarantines only job 2, and survives to
        // run the second batch
        let cfg = ServeConfig {
            workers: 1,
            max_batch: 4,
            queue_depth: 64,
            paused: true,
            ..Default::default()
        };
        let reqs = (0..8).map(|i| (0, job(i, "k_proj", 8, 8))).collect();
        let (responses, m) = serve_all(cfg, reqs, |_| Ok(PanicExec { poison: 2 })).unwrap();
        assert_eq!(responses.len(), 8, "every job gets exactly one terminal response");
        assert_eq!(m.completed, 8);
        assert_eq!(m.errors, 1);
        assert_eq!(m.quarantined, 1);
        assert_eq!(m.batches, 2, "the worker survived its poisoned batch");
        for r in &responses {
            if r.id == 2 {
                let e = r.out.as_ref().unwrap_err();
                assert!(e.contains("quarantined after panic"), "{e}");
                assert!(e.contains("poison job 2"), "panic payload surfaced: {e}");
            } else {
                assert_eq!(
                    r.out.as_ref().unwrap().errors[0] as u64,
                    r.id,
                    "batchmates of the poison job still get their own results"
                );
            }
        }
    }

    // NOTE: failpoint-armed serving scenarios (serve.exec_panic,
    // serve.deadline_expire, plan.reload_corrupt) live in
    // tests/chaos_serve.rs, where every test serializes on
    // `faults::exclusive()`.  Arming the process-global fault plan from
    // this module would race the rest of this (parallel) unit suite.

    #[test]
    fn expired_deadline_evicts_queued_requests_with_named_error() {
        let _g = crate::faults::exclusive();
        crate::faults::disarm();
        // paused server: jobs sit in the tenant queues while we age
        // them past a 1ms deadline; the close-triggered dispatch then
        // evicts all of them at batch formation
        let cfg = ServeConfig {
            workers: 1,
            max_batch: 4,
            queue_depth: 64,
            paused: true,
            deadline_micros: 1_000,
            ..Default::default()
        };
        let (server, rx) = Server::start(cfg, |_| Ok(SleepExec { micros: 0 }));
        for i in 0..6 {
            server.submit(0, job(i, "k_proj", 8, 8)).unwrap();
        }
        std::thread::sleep(Duration::from_millis(20));
        let m = server.finish();
        let responses: Vec<Response> = rx.iter().collect();
        assert_eq!(responses.len(), 6);
        assert_eq!(m.completed, 6);
        assert_eq!(m.deadline_expired, 6);
        assert_eq!(m.errors, 6);
        for r in &responses {
            let e = r.out.as_ref().unwrap_err();
            assert!(e.contains("deadline expired"), "{e}");
            assert_eq!(r.worker, usize::MAX, "evicted by the scheduler, not a worker");
            assert_eq!(r.batch_size, 0);
        }
    }

    #[test]
    fn shed_kicks_in_at_the_queue_pressure_bound_with_a_retry_hint() {
        let cfg = ServeConfig {
            workers: 1,
            max_batch: 4,
            queue_depth: 64,
            shed_queued: 4,
            paused: true,
            ..Default::default()
        };
        let (server, rx) = Server::start(cfg, |_| Ok(SleepExec { micros: 0 }));
        for i in 0..4 {
            server.submit(0, job(i, "k_proj", 8, 8)).unwrap();
        }
        match server.submit(1, job(4, "k_proj", 8, 8)) {
            Err(SubmitError::Shed { tenant, retry_after_micros }) => {
                assert_eq!(tenant, 1);
                assert!(retry_after_micros >= 100, "hint floored: {retry_after_micros}");
            }
            other => panic!("expected Shed, got {other:?}"),
        }
        let m = server.finish();
        assert_eq!(m.shed, 1);
        assert_eq!(m.completed, 4);
        assert_eq!(rx.iter().count(), 4);
    }

    #[test]
    fn drain_completes_inflight_work_and_stops_admission() {
        let cfg = ServeConfig { workers: 2, max_batch: 4, queue_depth: 64, ..Default::default() };
        let (server, rx) = Server::start(cfg, |_| Ok(SleepExec { micros: 500 }));
        for i in 0..12 {
            server.submit((i % 2) as TenantId, job(i, "k_proj", 8, 8)).unwrap();
        }
        server.drain();
        // post-drain the backlog is fully executed and admission is off
        assert_eq!(server.submit(0, job(99, "k_proj", 8, 8)), Err(SubmitError::Closed));
        let m = server.finish();
        assert_eq!(m.completed, 12);
        assert_eq!(m.errors, 0);
        assert_eq!(m.drains, 1);
        let mut ids: BTreeMap<u64, usize> = BTreeMap::new();
        for r in rx.iter() {
            *ids.entry(r.id).or_default() += 1;
        }
        assert_eq!(ids.len(), 12, "every drained job answered exactly once");
        assert!(ids.values().all(|&n| n == 1));
    }
}
