//! TCP/HTTP front-end over the serving core — `smoothrot serve
//! --listen ADDR`.
//!
//! Dependency-free std networking: a thread-per-connection accept loop
//! bounded by a connection cap, per-connection read/write socket
//! deadlines (the slow-loris defense — a client that trickles bytes
//! only ever occupies its own connection thread, never a worker), and a
//! single response-router thread that fans the core's one
//! [`Response`] receiver out to the per-connection waiters by job id.
//!
//! ## Endpoints
//!
//! | endpoint | behavior |
//! |---|---|
//! | `POST /analyze` | body per [`crate::serve::proto::parse_job_specs`]; submits into the core and streams one NDJSON result object per job as its batch completes (chunked) |
//! | `GET /healthz` | liveness + drain state |
//! | `GET /metrics` | Prometheus text of the live telemetry snapshot (404 when no telemetry is attached) |
//! | `POST /admin/drain` | 202, then: stop accepting, [`drain`](crate::serve::Server::drain) the core (safe across plan hot-swaps), complete every in-flight connection, exit |
//!
//! ## Degradation ladder, wire tier
//!
//! Admission failures map to the HTTP taxonomy
//! ([`crate::serve::proto`]): shed → 429 with `Retry-After` (seconds,
//! ceiling) and `X-Retry-After-Micros` (the exact
//! [`crate::serve::SubmitError::Shed`] hint), tenant-queue-full → 429
//! without a hint, draining → 503, queue-deadline expiry → 504,
//! executor error / quarantined panic → 500.  Over the connection cap
//! the server answers 503 and closes instead of letting the accept
//! backlog grow unboundedly.
//!
//! ## Failpoints
//!
//! Four wire-level chaos sites ([`crate::faults`]): `net.accept_fail`
//! (accepted connection dropped immediately), `net.conn_drop` (keyed by
//! wire request id: connection torn down after submit, before the
//! response bytes), `net.slow_client` (keyed: the connection thread
//! stalls before reading, simulating a byte-trickling client),
//! `net.partial_write` (keyed: half the response bytes, then teardown).
//! All four fire in connection threads — workers never see them, which
//! is exactly the isolation the chaos suite asserts.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::Job;
use crate::faults;
use crate::jsonio::{self, Json};
use crate::serve::proto::{self, JobSpec};
use crate::serve::shard::{ShardBy, ShardConfig, ShardedServer};
use crate::serve::{
    BatchExecutor, Response, ServeConfig, ServeMetrics, Server, SubmitError, TenantId,
};
use crate::telemetry::export::{CounterRow, GaugeRow, Snapshot};
use crate::telemetry::Telemetry;

/// Front-end configuration.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Listen address (`host:port`; port 0 binds an ephemeral port).
    pub addr: String,
    /// Concurrent-connection cap; over it, new connections get an
    /// immediate 503 and close (bounded accept, the wire analogue of
    /// [`ServeConfig::shed_queued`]).
    pub max_conns: usize,
    /// Socket read deadline (request parse) — the slow-loris bound.
    pub read_timeout: Duration,
    /// Socket write deadline per response write.
    pub write_timeout: Duration,
    /// Longest wait for one job's result after admission; a safety
    /// valve only — drain guarantees delivery, so this should exceed
    /// any plausible queue + exec time.
    pub response_timeout: Duration,
    /// Request-body cap ([`proto::read_request`]).
    pub max_body_bytes: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".to_string(),
            max_conns: 256,
            read_timeout: Duration::from_millis(5_000),
            write_timeout: Duration::from_millis(5_000),
            response_timeout: Duration::from_millis(60_000),
            max_body_bytes: proto::DEFAULT_MAX_BODY,
        }
    }
}

/// Classic single-pool server or sharded multi-runner server behind one
/// submit/drain/finish surface (shared by the CLI and the front-end).
pub enum CoreServer {
    Classic(Server),
    Sharded(ShardedServer),
}

/// `(runners, shard_by, stealing)` when serving sharded.
pub type ShardTopo = Option<(usize, ShardBy, bool)>;

impl CoreServer {
    /// Start a classic or sharded core per the topology, mirroring
    /// `smoothrot serve`'s dispatch.
    pub fn start_with_telemetry<E, F>(
        cfg: ServeConfig,
        shard: ShardTopo,
        telemetry: Option<Arc<Telemetry>>,
        make_executor: F,
    ) -> (CoreServer, Receiver<Response>)
    where
        E: BatchExecutor,
        F: Fn(usize) -> Result<E, String> + Send + Sync + 'static,
    {
        match shard {
            Some((runners, shard_by, stealing)) => {
                let scfg = ShardConfig { runners, shard_by, stealing, base: cfg };
                let (s, rx) = ShardedServer::start_with_telemetry(scfg, telemetry, make_executor);
                (CoreServer::Sharded(s), rx)
            }
            None => {
                let (s, rx) = Server::start_with_telemetry(cfg, telemetry, make_executor);
                (CoreServer::Classic(s), rx)
            }
        }
    }

    pub fn submit(&self, tenant: TenantId, job: Job) -> Result<(), SubmitError> {
        match self {
            CoreServer::Classic(s) => s.submit(tenant, job),
            CoreServer::Sharded(s) => s.submit(tenant, job),
        }
    }

    pub fn drain(&self) {
        match self {
            CoreServer::Classic(s) => s.drain(),
            CoreServer::Sharded(s) => s.drain(),
        }
    }

    pub fn finish(self) -> ServeMetrics {
        match self {
            CoreServer::Classic(s) => s.finish(),
            CoreServer::Sharded(s) => s.finish(),
        }
    }

    /// Sharded runner count (1 for the classic pool's single scheduler).
    pub fn runners(&self) -> usize {
        match self {
            CoreServer::Classic(_) => 1,
            CoreServer::Sharded(s) => s.runners(),
        }
    }
}

/// HTTP statuses with always-present counter rows
/// (`smoothrot_net_responses_total{status=…}`) — the present-at-zero
/// discipline: dashboards and CI `jq` assertions must never key-error
/// on a status an idle server simply has not answered yet.
pub const STATUS_TAXONOMY: [u16; 13] =
    [200, 202, 400, 404, 405, 408, 411, 413, 429, 431, 500, 503, 504];

/// Wire-level counters, mirrored into every telemetry snapshot by
/// [`net_stats_collector`].
#[derive(Debug, Default)]
pub struct NetStats {
    /// Connections accepted (and handed to a connection thread).
    pub accepted: AtomicU64,
    /// Connections answered 503 at the cap.
    pub rejected_over_cap: AtomicU64,
    /// Accept-loop failures (transport errors + `net.accept_fail`).
    pub accept_fail: AtomicU64,
    /// Connections torn down mid-response (`net.conn_drop` plus real
    /// client disconnects observed as write failures).
    pub conn_dropped: AtomicU64,
    /// Responses truncated by `net.partial_write`.
    pub partial_write: AtomicU64,
    /// `net.slow_client` stalls injected.
    pub slow_client: AtomicU64,
    /// Requests that blew the socket read deadline (408s).
    pub read_timeout: AtomicU64,
    /// Currently open connections (gauge).
    pub open: AtomicUsize,
    /// HTTP status lines written, indexed like [`STATUS_TAXONOMY`]
    /// (last slot: anything off-taxonomy).
    statuses: [AtomicU64; 14],
}

impl NetStats {
    /// Count one written status line.
    pub fn note_status(&self, code: u16) {
        let idx = STATUS_TAXONOMY
            .iter()
            .position(|&c| c == code)
            .unwrap_or(STATUS_TAXONOMY.len());
        self.statuses[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Count of status lines written with `code` (0 for off-taxonomy
    /// codes — those pool in the `other` row).
    pub fn status(&self, code: u16) -> u64 {
        match STATUS_TAXONOMY.iter().position(|&c| c == code) {
            Some(idx) => self.statuses[idx].load(Ordering::Relaxed),
            None => 0,
        }
    }
}

/// Telemetry collector mirroring [`NetStats`] into every [`Snapshot`]:
/// all rows present-at-zero, including one
/// `smoothrot_net_responses_total{status=…}` per taxonomy code.
pub fn net_stats_collector(
    stats: &Arc<NetStats>,
) -> impl Fn(&mut Snapshot) + Send + Sync + 'static {
    let stats = Arc::clone(stats);
    move |snap: &mut Snapshot| {
        let counters = [
            ("smoothrot_net_connections_total", stats.accepted.load(Ordering::Relaxed)),
            ("smoothrot_net_conn_rejected_total", stats.rejected_over_cap.load(Ordering::Relaxed)),
            ("smoothrot_net_accept_fail_total", stats.accept_fail.load(Ordering::Relaxed)),
            ("smoothrot_net_conn_dropped_total", stats.conn_dropped.load(Ordering::Relaxed)),
            ("smoothrot_net_partial_write_total", stats.partial_write.load(Ordering::Relaxed)),
            ("smoothrot_net_slow_client_total", stats.slow_client.load(Ordering::Relaxed)),
            ("smoothrot_net_read_timeout_total", stats.read_timeout.load(Ordering::Relaxed)),
        ];
        for (name, value) in counters {
            snap.counters.push(CounterRow { name: name.into(), labels: Vec::new(), value });
        }
        for (i, &code) in STATUS_TAXONOMY.iter().enumerate() {
            snap.counters.push(CounterRow {
                name: "smoothrot_net_responses_total".into(),
                labels: vec![("status".into(), code.to_string())],
                value: stats.statuses[i].load(Ordering::Relaxed),
            });
        }
        snap.counters.push(CounterRow {
            name: "smoothrot_net_responses_total".into(),
            labels: vec![("status".into(), "other".into())],
            value: stats.statuses[STATUS_TAXONOMY.len()].load(Ordering::Relaxed),
        });
        snap.gauges.push(GaugeRow {
            name: "smoothrot_net_connections_open".into(),
            labels: Vec::new(),
            value: stats.open.load(Ordering::Relaxed) as f64,
        });
    }
}

/// Builds a `(tenant, Job)` from a wire [`JobSpec`] and a fresh core
/// job id.  The server owns the model; the builder is where the wire
/// names meet the weights.
pub type JobBuilder = Arc<dyn Fn(&JobSpec, u64) -> Result<(TenantId, Job), String> + Send + Sync>;

/// The standard builder: synthetic activations from the *client's*
/// seed ([`crate::synth::module_stream`]), the fixed per-(module,
/// layer) serving weight from the *server's* `stream_seed`
/// ([`crate::synth::layer_weight`]) — exactly the
/// [`crate::serve::synthetic_requests`] contract, so an int8 plan
/// pre-quantized against `stream_seed` matches every wire request, and
/// an in-process replay of the same specs is bit-identical.
pub fn synth_job_builder(stream_seed: u64) -> JobBuilder {
    let weights: Mutex<std::collections::BTreeMap<(&'static str, usize), crate::tensor::Matrix>> =
        Mutex::new(std::collections::BTreeMap::new());
    Arc::new(move |spec: &JobSpec, job_id: u64| {
        let module = crate::MODULES
            .iter()
            .find(|m| **m == spec.module)
            .copied()
            .ok_or_else(|| format!("unknown module {:?}", spec.module))?;
        let (mut synth_spec, _) = crate::synth::module_stream(module, spec.seed)
            .ok_or_else(|| format!("no stream for module {module:?}"))?;
        synth_spec.n_tokens = spec.rows.max(1);
        let x = synth_spec.layer(spec.layer);
        let w = {
            let mut cache = weights.lock().unwrap_or_else(|p| p.into_inner());
            cache
                .entry((module, spec.layer))
                .or_insert_with(|| {
                    crate::synth::layer_weight(module, spec.layer, stream_seed)
                        .expect("known module")
                })
                .clone()
        };
        let job = Job {
            id: job_id,
            layer: spec.layer,
            module,
            x,
            w,
            alpha: spec.alpha,
            bits: spec.bits,
        };
        Ok((spec.tenant, job))
    })
}

/// Serialize one core [`Response`] as an NDJSON result line.  `200` for
/// a clean result, `504` for a queue-deadline eviction (the scheduler
/// marks those with `worker == usize::MAX`), `500` for an executor
/// error or quarantined panic.  Results carry both readable errors and
/// exact IEEE-754 bit patterns ([`proto::f64_bits_hex`]) — the latter
/// are what the bit-identity gates compare.
pub fn result_line(client_id: u64, r: &Response) -> (u16, String) {
    let (status, fields) = match &r.out {
        Ok(out) => {
            let best = crate::transforms::Mode::ALL
                .into_iter()
                .min_by(|a, b| {
                    out.errors[a.index()].partial_cmp(&out.errors[b.index()]).unwrap()
                })
                .unwrap();
            (
                200u16,
                vec![
                    ("mode_best", Json::Str(best.name().to_string())),
                    ("errors", jsonio::num_arr(&out.errors)),
                    (
                        "errors_bits",
                        Json::Arr(
                            out.errors
                                .iter()
                                .map(|&e| Json::Str(proto::f64_bits_hex(e)))
                                .collect(),
                        ),
                    ),
                ],
            )
        }
        Err(msg) if r.worker == usize::MAX => {
            (504u16, vec![("error", Json::Str(msg.clone()))])
        }
        Err(msg) => (500u16, vec![("error", Json::Str(msg.clone()))]),
    };
    let mut obj = vec![
        ("id", Json::Num(client_id as f64)),
        ("status", Json::Num(status as f64)),
        ("tenant", Json::Num(r.tenant as f64)),
        ("module", Json::Str(r.module.to_string())),
        ("layer", Json::Num(r.layer as f64)),
        ("batch_size", Json::Num(r.batch_size as f64)),
        ("queue_us", Json::Num(r.queue_micros as f64)),
        ("exec_us", Json::Num(r.exec_micros as f64)),
        ("total_us", Json::Num(r.total_micros as f64)),
    ];
    obj.extend(fields);
    let mut line = jsonio::obj(obj).to_string_compact();
    line.push('\n');
    (status, line)
}

/// A submit failure serialized as an NDJSON result line (multi-job
/// requests stream these in place of a result for the failed job).
fn submit_error_line(client_id: u64, e: &SubmitError) -> (u16, String) {
    let (status, name, retry) = classify_submit(e);
    let mut obj = vec![
        ("id", Json::Num(client_id as f64)),
        ("status", Json::Num(status as f64)),
        ("error", Json::Str(name.to_string())),
        ("detail", Json::Str(e.to_string())),
    ];
    if let Some(micros) = retry {
        obj.push(("retry_after_us", Json::Num(micros as f64)));
    }
    let mut line = jsonio::obj(obj).to_string_compact();
    line.push('\n');
    (status, line)
}

/// `(http status, taxonomy name, retry hint µs)` of a [`SubmitError`].
fn classify_submit(e: &SubmitError) -> (u16, &'static str, Option<u64>) {
    match e {
        SubmitError::Shed { retry_after_micros, .. } => (429, "shed", Some(*retry_after_micros)),
        SubmitError::Full { .. } => (429, "admission_full", None),
        SubmitError::Closed => (503, "draining", None),
    }
}

struct NetShared {
    cfg: NetConfig,
    core: CoreServer,
    builder: JobBuilder,
    stats: Arc<NetStats>,
    telemetry: Option<Arc<Telemetry>>,
    /// Waiters keyed by core job id; the router delivers each response
    /// once and removes the entry (a dropped waiter just loses the
    /// send — the job itself completed normally).  Behind its own
    /// `Arc` so the router thread can outlive `NetShared` — it must
    /// not hold the whole shared state, or [`NetServer::wait`] could
    /// never unwrap it to finish the core (whose sender drop is what
    /// ends the router).
    pending: Arc<Mutex<HashMap<u64, mpsc::Sender<Response>>>>,
    /// Core job ids (wire requests share the space with nothing else).
    next_job_id: AtomicU64,
    /// Wire request counter — the key for `net.conn_drop` /
    /// `net.slow_client` / `net.partial_write`, so `mod:K:R` picks a
    /// deterministic subset of requests.
    next_req_key: AtomicU64,
    draining: AtomicBool,
    drained: Mutex<bool>,
    drained_cv: Condvar,
}

/// The running front-end.  [`NetServer::wait`] blocks until a drain
/// (SIGTERM, `POST /admin/drain`, or [`NetServer::drain`]) completes
/// and returns the core's end-of-run metrics.
pub struct NetServer {
    shared: Arc<NetShared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    router: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `cfg.addr`, attach the response router to `rx`, and start
    /// accepting.  The core must have been started with the same
    /// telemetry instance (its receiver is consumed here).
    pub fn start(
        cfg: NetConfig,
        core: CoreServer,
        rx: Receiver<Response>,
        telemetry: Option<Arc<Telemetry>>,
        builder: JobBuilder,
    ) -> Result<NetServer, String> {
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| format!("net: bind {}: {e}", cfg.addr))?;
        let addr = listener.local_addr().map_err(|e| format!("net: local_addr: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("net: set_nonblocking: {e}"))?;
        let stats = Arc::new(NetStats::default());
        if let Some(t) = &telemetry {
            t.add_collector(net_stats_collector(&stats));
        }
        let shared = Arc::new(NetShared {
            cfg,
            core,
            builder,
            stats,
            telemetry,
            pending: Arc::new(Mutex::new(HashMap::new())),
            next_job_id: AtomicU64::new(0),
            next_req_key: AtomicU64::new(0),
            draining: AtomicBool::new(false),
            drained: Mutex::new(false),
            drained_cv: Condvar::new(),
        });
        let router = {
            let pending = Arc::clone(&shared.pending);
            std::thread::spawn(move || router_loop(&pending, rx))
        };
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || accept_loop(&shared, listener))
        };
        Ok(NetServer { shared, addr, accept: Some(accept), router: Some(router) })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live wire counters.
    pub fn stats(&self) -> Arc<NetStats> {
        Arc::clone(&self.shared.stats)
    }

    /// Trigger a graceful drain (same path as SIGTERM and
    /// `POST /admin/drain`).  Returns immediately; [`NetServer::wait`]
    /// observes completion.
    pub fn drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
    }

    /// Whether a drain has been requested.
    pub fn draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// Block until the drain completes — accept loop stopped, every
    /// in-flight connection finished, core drained — then join all
    /// threads and return the core's end-of-run metrics.
    pub fn wait(self) -> Result<ServeMetrics, String> {
        {
            let mut done = self
                .shared
                .drained
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            while !*done {
                done = match self.shared.drained_cv.wait(done) {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
            }
        }
        let NetServer { shared, accept, router, .. } = self;
        if let Some(h) = accept {
            let _ = h.join();
        }
        // Connection threads exited before the accept loop signaled,
        // so the only transient co-holders left are short-lived (the
        // term watcher drops its clone within one 50ms poll of the
        // drain flag flipping) — retry briefly instead of failing.
        let mut shared = shared;
        let shared = {
            let mut tries = 0;
            loop {
                match Arc::try_unwrap(shared) {
                    Ok(s) => break s,
                    Err(arc) => {
                        tries += 1;
                        if tries > 1_000 {
                            return Err(
                                "net: a thread still holds the server after drain".to_string()
                            );
                        }
                        shared = arc;
                        std::thread::sleep(Duration::from_millis(10));
                    }
                }
            }
        };
        let metrics = shared.core.finish();
        // finish() drops the core's response sender, which ends the
        // router's receive loop
        if let Some(h) = router {
            let _ = h.join();
        }
        Ok(metrics)
    }
}

/// Fan the core's single response stream out to per-connection waiters.
/// Exits when the core's workers drop the sender (after `finish`).
fn router_loop(
    pending: &Mutex<HashMap<u64, mpsc::Sender<Response>>>,
    rx: Receiver<Response>,
) {
    for r in rx.iter() {
        let waiter = {
            let mut pending = pending.lock().unwrap_or_else(|p| p.into_inner());
            pending.remove(&r.id)
        };
        if let Some(tx) = waiter {
            // a dropped waiter (client gone) is not an error: the job
            // completed and its batchmates are untouched
            let _ = tx.send(r);
        }
    }
}

/// Accept until drain: bounded connections, named over-cap rejection,
/// deterministic accept failures, then the drain choreography — stop
/// accepting, join every connection thread, drain the core (safe
/// across plan hot-swaps), signal `wait`.
fn accept_loop(shared: &Arc<NetShared>, listener: TcpListener) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !shared.draining.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if faults::fire("net.accept_fail") {
                    shared.stats.accept_fail.fetch_add(1, Ordering::Relaxed);
                    drop(stream);
                    continue;
                }
                if shared.stats.open.load(Ordering::Relaxed) >= shared.cfg.max_conns {
                    shared.stats.rejected_over_cap.fetch_add(1, Ordering::Relaxed);
                    shared.stats.note_status(503);
                    let _ = stream.set_write_timeout(Some(Duration::from_millis(500)));
                    let mut w = BufWriter::new(&stream);
                    let _ = proto::write_error(
                        &mut w,
                        503,
                        "over_connection_cap",
                        &format!("{} connections open", shared.cfg.max_conns),
                        &[("Retry-After", "1")],
                    );
                    let _ = w.flush();
                    continue;
                }
                shared.stats.accepted.fetch_add(1, Ordering::Relaxed);
                shared.stats.open.fetch_add(1, Ordering::Relaxed);
                let shared = Arc::clone(shared);
                conns.push(std::thread::spawn(move || {
                    handle_conn(&shared, stream);
                    shared.stats.open.fetch_sub(1, Ordering::Relaxed);
                }));
                // reap finished handlers so a long-lived server never
                // accumulates unbounded join handles
                if conns.len() >= shared.cfg.max_conns * 2 {
                    for h in std::mem::take(&mut conns) {
                        if h.is_finished() {
                            let _ = h.join();
                        } else {
                            conns.push(h);
                        }
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => {
                shared.stats.accept_fail.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
    drop(listener); // stop accepting before touching in-flight work
    // Kick the core's drain BEFORE joining connection threads: drain
    // marks the core draining (racing submits fail Closed → 503),
    // overrides a paused scheduler, and completes every queued job —
    // which is exactly what connection threads still blocked on their
    // responses are waiting for.  Executors resolve the plan registry
    // per batch, so this is safe concurrent with hot swaps: in-flight
    // batches finish on whichever plan generation they started with.
    let drainer = {
        let shared = Arc::clone(shared);
        std::thread::spawn(move || shared.core.drain())
    };
    for h in conns {
        let _ = h.join();
    }
    let _ = drainer.join();
    let mut done = shared.drained.lock().unwrap_or_else(|p| p.into_inner());
    *done = true;
    shared.drained_cv.notify_all();
}

/// One connection, end to end.  Never panics the process over wire
/// input: every malformed shape is a named 4xx, every transport error a
/// close.
fn handle_conn(shared: &NetShared, stream: TcpStream) {
    let req_key = shared.next_req_key.fetch_add(1, Ordering::Relaxed);
    let _ = stream.set_read_timeout(Some(shared.cfg.read_timeout));
    let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
    let _ = stream.set_nodelay(true);

    // net.slow_client: this connection's thread stalls as a
    // byte-trickling client would make it; workers and other
    // connections are provably elsewhere.
    if faults::fire_key("net.slow_client", req_key) {
        shared.stats.slow_client.fetch_add(1, Ordering::Relaxed);
        std::thread::sleep(shared.cfg.read_timeout / 2);
    }

    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = BufWriter::new(&stream);

    let req = match proto::read_request(&mut reader, shared.cfg.max_body_bytes) {
        Ok(req) => req,
        Err(e) => {
            if matches!(e, proto::ProtoError::Timeout) {
                shared.stats.read_timeout.fetch_add(1, Ordering::Relaxed);
            }
            if let Some(code) = e.status() {
                shared.stats.note_status(code);
                let _ = proto::write_error(&mut writer, code, e.name(), &e.to_string(), &[]);
                let _ = writer.flush();
            }
            return;
        }
    };

    match (req.method.as_str(), req.target.as_str()) {
        ("GET", "/healthz") => {
            let body = jsonio::obj(vec![
                ("status", Json::Str("ok".to_string())),
                ("draining", Json::Bool(shared.draining.load(Ordering::SeqCst))),
            ])
            .to_string_compact();
            write_plain(shared, &mut writer, 200, "application/json", &body);
        }
        ("GET", "/metrics") => match &shared.telemetry {
            Some(t) => {
                let text = t.snapshot().to_prometheus();
                write_plain(shared, &mut writer, 200, "text/plain; version=0.0.4", &text);
            }
            None => {
                shared.stats.note_status(404);
                let _ = proto::write_error(
                    &mut writer,
                    404,
                    "no_telemetry",
                    "run serve with --metrics-file to attach telemetry",
                    &[],
                );
                let _ = writer.flush();
            }
        },
        ("POST", "/admin/drain") => {
            shared.draining.store(true, Ordering::SeqCst);
            let body = jsonio::obj(vec![("draining", Json::Bool(true))]).to_string_compact();
            write_plain(shared, &mut writer, 202, "application/json", &body);
        }
        ("POST", "/analyze") => handle_analyze(shared, &req, req_key, &stream, &mut writer),
        ("GET", "/analyze") | ("GET", "/admin/drain") | ("POST", "/healthz")
        | ("POST", "/metrics") => {
            let allow = if req.target == "/analyze" || req.target == "/admin/drain" {
                "POST"
            } else {
                "GET"
            };
            shared.stats.note_status(405);
            let _ = proto::write_error(
                &mut writer,
                405,
                "method_not_allowed",
                &format!("{} does not accept {}", req.target, req.method),
                &[("Allow", allow)],
            );
            let _ = writer.flush();
        }
        _ => {
            shared.stats.note_status(404);
            let _ = proto::write_error(
                &mut writer,
                404,
                "unknown_endpoint",
                &format!("no endpoint {:?}", req.target),
                &[],
            );
            let _ = writer.flush();
        }
    }
}

fn write_plain(
    shared: &NetShared,
    w: &mut impl Write,
    code: u16,
    content_type: &str,
    body: &str,
) {
    shared.stats.note_status(code);
    let len = body.len().to_string();
    let _ = proto::write_head(
        w,
        code,
        &[("Content-Type", content_type), ("Content-Length", len.as_str())],
    );
    let _ = w.write_all(body.as_bytes());
    let _ = w.flush();
}

/// The job path: parse specs, submit, stream results as they complete.
fn handle_analyze(
    shared: &NetShared,
    req: &proto::HttpRequest,
    req_key: u64,
    stream: &TcpStream,
    writer: &mut BufWriter<&TcpStream>,
) {
    if req.header("content-length").is_none() {
        shared.stats.note_status(411);
        let _ = proto::write_error(
            writer,
            411,
            "length_required",
            "POST /analyze needs a Content-Length body",
            &[],
        );
        let _ = writer.flush();
        return;
    }
    let specs = match proto::parse_job_specs(&req.body) {
        Ok(s) => s,
        Err(e) => {
            shared.stats.note_status(400);
            let _ = proto::write_error(writer, 400, e.name, &e.detail, &[]);
            let _ = writer.flush();
            return;
        }
    };

    // Submit every job first (results stream in completion order).
    // Each job gets a fresh core id and a single-response waiter
    // registered BEFORE submit, so the router can never race the
    // registration.
    let (tx, rx) = mpsc::channel::<Response>();
    let mut submitted: Vec<(u64, u64)> = Vec::new(); // (client id, job id)
    let mut failed: Vec<(u64, SubmitError)> = Vec::new();
    for spec in &specs {
        let job_id = shared.next_job_id.fetch_add(1, Ordering::Relaxed);
        let (tenant, job) = match (shared.builder)(spec, job_id) {
            Ok(pair) => pair,
            Err(msg) => {
                shared.stats.note_status(400);
                let _ = proto::write_error(writer, 400, "bad_job", &msg, &[]);
                let _ = writer.flush();
                return;
            }
        };
        {
            let mut pending = shared.pending.lock().unwrap_or_else(|p| p.into_inner());
            pending.insert(job_id, tx.clone());
        }
        match shared.core.submit(tenant, job) {
            Ok(()) => submitted.push((spec.id, job_id)),
            Err(e) => {
                let mut pending = shared.pending.lock().unwrap_or_else(|p| p.into_inner());
                pending.remove(&job_id);
                drop(pending);
                failed.push((spec.id, e));
            }
        }
    }

    // Single-job requests surface admission failures as the HTTP
    // status itself — the clean client taxonomy loadgen records.
    if submitted.is_empty() && failed.len() == 1 && specs.len() == 1 {
        let (_, e) = &failed[0];
        let (code, name, retry) = classify_submit(e);
        let secs;
        let micros;
        let mut extra: Vec<(&str, &str)> = Vec::new();
        if let Some(m) = retry {
            secs = m.div_ceil(1_000_000).max(1).to_string();
            micros = m.to_string();
            extra.push(("Retry-After", secs.as_str()));
            extra.push(("X-Retry-After-Micros", micros.as_str()));
        }
        shared.stats.note_status(code);
        let _ = proto::write_error(writer, code, name, &e.to_string(), &extra);
        let _ = writer.flush();
        return;
    }

    // net.conn_drop: tear the connection down after submit, before any
    // response byte — the batchmates of this connection's jobs must
    // complete untouched (the router discards the orphaned responses).
    if faults::fire_key("net.conn_drop", req_key) {
        shared.stats.conn_dropped.fetch_add(1, Ordering::Relaxed);
        let _ = stream.shutdown(Shutdown::Both);
        return;
    }

    shared.stats.note_status(200);
    if proto::write_head(
        writer,
        200,
        &[("Transfer-Encoding", "chunked"), ("Content-Type", "application/x-ndjson")],
    )
    .is_err()
    {
        shared.stats.conn_dropped.fetch_add(1, Ordering::Relaxed);
        return;
    }

    let by_job: HashMap<u64, u64> = submitted.iter().map(|&(cid, jid)| (jid, cid)).collect();

    // net.partial_write: half the bytes of the first result line, then
    // teardown — the client sees a truncated chunk; the server side
    // must stay clean (unwritten results route to the dropped waiter
    // and vanish without touching their batchmates).
    if faults::fire_key("net.partial_write", req_key) {
        shared.stats.partial_write.fetch_add(1, Ordering::Relaxed);
        let line = if let Some((client_id, e)) = failed.first() {
            submit_error_line(*client_id, e).1
        } else if let Ok(r) = rx.recv_timeout(shared.cfg.response_timeout) {
            let client_id = by_job.get(&r.id).copied().unwrap_or(r.id);
            result_line(client_id, &r).1
        } else {
            "{}\n".to_string()
        };
        let _ = stream_line(writer, &line, true);
        let _ = writer.flush();
        let _ = stream.shutdown(Shutdown::Both);
        return;
    }

    for (client_id, e) in &failed {
        let (status, line) = submit_error_line(*client_id, e);
        shared.stats.note_status(status);
        let _ = stream_line(writer, &line, false);
    }
    let mut remaining = submitted.len();
    while remaining > 0 {
        let r = match rx.recv_timeout(shared.cfg.response_timeout) {
            Ok(r) => r,
            Err(_) => {
                let line = jsonio::obj(vec![
                    ("status", Json::Num(500.0)),
                    ("error", Json::Str("response_wait_timeout".to_string())),
                ])
                .to_string_compact();
                shared.stats.note_status(500);
                let _ = stream_line(writer, &format!("{line}\n"), false);
                break;
            }
        };
        let client_id = by_job.get(&r.id).copied().unwrap_or(r.id);
        let (status, line) = result_line(client_id, &r);
        shared.stats.note_status(status);
        if stream_line(writer, &line, false).is_err() {
            // client went away mid-stream: the remaining results route
            // to this (dropped) waiter and are discarded by the router;
            // their batchmates on other connections are untouched
            shared.stats.conn_dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        remaining -= 1;
    }
    let _ = proto::finish_chunks(writer);
    let _ = writer.flush();
}

/// Write one NDJSON line as a chunk; `truncate` sends only the first
/// half of the bytes (the `net.partial_write` shape).
fn stream_line(w: &mut impl Write, line: &str, truncate: bool) -> std::io::Result<()> {
    let bytes = line.as_bytes();
    let bytes = if truncate { &bytes[..bytes.len() / 2] } else { bytes };
    proto::write_chunk(w, bytes)?;
    w.flush()
}

// ---------------------------------------------------------------------
// SIGTERM → drain (unix; no-op elsewhere).  std exposes no signal API,
// but libc is always linked on unix targets, so declare `signal`
// directly — the handler only stores to an atomic, which is
// async-signal-safe.
// ---------------------------------------------------------------------

/// Process-wide SIGTERM flag (also set by SIGINT).
static TERM: AtomicBool = AtomicBool::new(false);

/// Whether a termination signal has arrived since
/// [`install_term_handler`].
pub fn term_requested() -> bool {
    TERM.load(Ordering::SeqCst)
}

#[cfg(unix)]
/// Route SIGTERM/SIGINT to the drain flag.  Returns false if the
/// handler could not be installed.
pub fn install_term_handler() -> bool {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    extern "C" fn on_term(_sig: i32) {
        TERM.store(true, Ordering::SeqCst);
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    const SIG_ERR: usize = usize::MAX;
    unsafe { signal(SIGTERM, on_term) != SIG_ERR && signal(SIGINT, on_term) != SIG_ERR }
}

#[cfg(not(unix))]
/// No signal routing off unix; drain via `POST /admin/drain`.
pub fn install_term_handler() -> bool {
    false
}

/// Bridge the signal flag into a running server: poll `TERM` and
/// trigger [`NetServer::drain`] when it flips.  Returns the polling
/// thread's stop flag + handle (stopped automatically once drain is
/// requested from any source).
pub fn spawn_term_watcher(server: &NetServer) -> JoinHandle<()> {
    let shared = Arc::clone(&server.shared);
    std::thread::spawn(move || {
        while !shared.draining.load(Ordering::SeqCst) {
            if TERM.load(Ordering::SeqCst) {
                shared.draining.store(true, Ordering::SeqCst);
                break;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::NativeBatchExecutor;
    use std::io::BufRead;

    fn tiny_server(cfg: ServeConfig, net: NetConfig) -> NetServer {
        let (core, rx) =
            CoreServer::start_with_telemetry(cfg, None, None, |_| {
                Ok(NativeBatchExecutor::new())
            });
        NetServer::start(net, core, rx, None, synth_job_builder(2025)).unwrap()
    }

    fn post(addr: SocketAddr, target: &str, body: &[u8]) -> proto::HttpResponse {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        proto::write_request(&mut w, "POST", target, body).unwrap();
        w.flush().unwrap();
        proto::read_response(&mut BufReader::new(stream)).unwrap()
    }

    fn get(addr: SocketAddr, target: &str) -> proto::HttpResponse {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        proto::write_request(&mut w, "GET", target, b"").unwrap();
        w.flush().unwrap();
        proto::read_response(&mut BufReader::new(stream)).unwrap()
    }

    #[test]
    fn end_to_end_analyze_healthz_drain() {
        let server = tiny_server(
            ServeConfig { workers: 1, max_batch: 4, ..ServeConfig::default() },
            NetConfig::default(),
        );
        let addr = server.addr();

        let health = get(addr, "/healthz");
        assert_eq!(health.status, 200);
        assert!(String::from_utf8_lossy(&health.body).contains("\"draining\": false"));

        let resp = post(addr, "/analyze", br#"{"module":"k_proj","layer":0,"rows":4,"seed":9}"#);
        assert_eq!(resp.status, 200);
        let text = String::from_utf8(resp.body).unwrap();
        let line = jsonio::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(line.get("status").and_then(Json::as_usize), Some(200));
        assert_eq!(line.get("errors_bits").and_then(Json::as_arr).map(<[Json]>::len), Some(4));

        // multi-job request streams one line per job
        let resp = post(
            addr,
            "/analyze",
            br#"{"jobs":[{"module":"k_proj","layer":0,"rows":4},{"module":"down_proj","layer":1,"rows":4}]}"#,
        );
        assert_eq!(resp.status, 200);
        let text = String::from_utf8(resp.body).unwrap();
        assert_eq!(text.lines().count(), 2);

        let drain = post(addr, "/admin/drain", b"");
        assert_eq!(drain.status, 202);
        let metrics = server.wait().unwrap();
        assert_eq!(metrics.completed, 3);
        assert_eq!(metrics.errors, 0);
        assert_eq!(metrics.drains, 1);
    }

    #[test]
    fn unknown_endpoint_and_method_taxonomy() {
        let server = tiny_server(
            ServeConfig { workers: 1, ..ServeConfig::default() },
            NetConfig::default(),
        );
        let addr = server.addr();
        assert_eq!(get(addr, "/nope").status, 404);
        let wrong = get(addr, "/analyze");
        assert_eq!(wrong.status, 405);
        assert_eq!(wrong.header("allow"), Some("POST"));
        assert_eq!(post(addr, "/analyze", b"").status, 400); // empty body declared
        let stats = server.stats();
        assert_eq!(stats.status(404), 1);
        assert_eq!(stats.status(405), 1);
        server.drain();
        server.wait().unwrap();
    }

    #[test]
    fn missing_content_length_is_411() {
        let server = tiny_server(
            ServeConfig { workers: 1, ..ServeConfig::default() },
            NetConfig::default(),
        );
        let addr = server.addr();
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        w.write_all(b"POST /analyze HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n")
            .unwrap();
        w.flush().unwrap();
        let resp = proto::read_response(&mut BufReader::new(stream)).unwrap();
        assert_eq!(resp.status, 411);
        server.drain();
        server.wait().unwrap();
    }

    #[test]
    fn connection_cap_rejects_with_503() {
        let server = tiny_server(
            ServeConfig { workers: 1, ..ServeConfig::default() },
            NetConfig {
                max_conns: 1,
                // the held connection never sends bytes; a short read
                // deadline keeps the post-test join fast
                read_timeout: Duration::from_millis(300),
                ..NetConfig::default()
            },
        );
        let addr = server.addr();
        // hold one connection open (no bytes sent yet)
        let _held = TcpStream::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(100)); // let it be accepted
        let resp = get(addr, "/healthz");
        assert_eq!(resp.status, 503);
        assert_eq!(resp.header("retry-after"), Some("1"));
        assert!(String::from_utf8_lossy(&resp.body).contains("over_connection_cap"));
        drop(_held);
        server.drain();
        server.wait().unwrap();
    }

    #[test]
    fn slow_loris_read_deadline_closes_with_408() {
        let server = tiny_server(
            ServeConfig { workers: 1, ..ServeConfig::default() },
            NetConfig { read_timeout: Duration::from_millis(200), ..NetConfig::default() },
        );
        let addr = server.addr();
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        // half a request line, then silence: the read deadline must fire
        w.write_all(b"GET /heal").unwrap();
        w.flush().unwrap();
        let mut line = String::new();
        let mut r = BufReader::new(stream);
        r.read_line(&mut line).unwrap();
        assert!(line.contains("408"), "got {line:?}");
        assert_eq!(server.stats().read_timeout.load(Ordering::Relaxed), 1);
        server.drain();
        server.wait().unwrap();
    }

    #[test]
    fn shed_maps_to_429_with_retry_after() {
        // paused scheduler + shed threshold 1: the first submit queues,
        // the second sheds deterministically
        let server = tiny_server(
            ServeConfig {
                workers: 1,
                paused: true,
                shed_queued: 1,
                ..ServeConfig::default()
            },
            NetConfig::default(),
        );
        let addr = server.addr();
        let t = std::thread::spawn({
            let addr = addr;
            move || post(addr, "/analyze", br#"{"module":"k_proj","layer":0,"rows":4}"#)
        });
        // first job queued (paused scheduler holds it); second sheds
        std::thread::sleep(Duration::from_millis(300));
        let shed = post(addr, "/analyze", br#"{"module":"k_proj","layer":1,"rows":4}"#);
        assert_eq!(shed.status, 429);
        let retry: u64 = shed.header("retry-after").unwrap().parse().unwrap();
        assert!(retry >= 1);
        let micros: u64 = shed.header("x-retry-after-micros").unwrap().parse().unwrap();
        assert!(micros >= 100, "hint {micros} below the 100µs floor");
        assert!(String::from_utf8_lossy(&shed.body).contains("shed"));
        // drain releases the paused queue; the first request completes
        server.drain();
        let metrics = server.wait().unwrap();
        let first = t.join().unwrap();
        assert_eq!(first.status, 200);
        assert_eq!(metrics.shed, 1);
        assert_eq!(metrics.completed, 1);
    }

    #[test]
    fn draining_rejects_new_submits_with_503() {
        let server = tiny_server(
            ServeConfig { workers: 1, paused: true, ..ServeConfig::default() },
            NetConfig::default(),
        );
        let addr = server.addr();
        let drain = post(addr, "/admin/drain", b"");
        assert_eq!(drain.status, 202);
        // connections already accepted race the listener teardown; new
        // ones are refused once the accept loop exits.  Either way no
        // new work is admitted.
        match TcpStream::connect(addr) {
            Err(_) => {}
            Ok(stream) => {
                stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
                let mut w = BufWriter::new(stream.try_clone().unwrap());
                if proto::write_request(
                    &mut w,
                    "POST",
                    "/analyze",
                    br#"{"module":"k_proj","layer":0}"#,
                )
                .is_ok()
                    && w.flush().is_ok()
                {
                    if let Ok(resp) = proto::read_response(&mut BufReader::new(stream)) {
                        assert_eq!(resp.status, 503);
                    }
                }
            }
        }
        let metrics = server.wait().unwrap();
        assert_eq!(metrics.submitted, 0);
    }

    #[test]
    fn result_line_maps_deadline_to_504() {
        let r = Response {
            id: 7,
            tenant: 0,
            module: "k_proj",
            layer: 3,
            worker: usize::MAX,
            batch_id: u64::MAX,
            batch_size: 0,
            out: Err("deadline expired after 5000µs in queue".to_string()),
            queue_micros: 5000,
            exec_micros: 0,
            total_micros: 5000,
        };
        let (status, line) = result_line(7, &r);
        assert_eq!(status, 504);
        assert!(line.contains("deadline expired"));
        let (status, _) = result_line(
            7,
            &Response { worker: 0, out: Err("exec failed".to_string()), ..r.clone() },
        );
        assert_eq!(status, 500);
    }

    #[test]
    fn status_taxonomy_present_at_zero_in_snapshot() {
        let stats = Arc::new(NetStats::default());
        let collector = net_stats_collector(&stats);
        let mut snap = Snapshot::new();
        collector(&mut snap);
        for code in STATUS_TAXONOMY {
            let status = code.to_string();
            assert_eq!(
                snap.counter("smoothrot_net_responses_total", &[("status", status.as_str())]),
                Some(0),
                "status {code} row missing at zero"
            );
        }
        assert_eq!(snap.counter("smoothrot_net_connections_total", &[]), Some(0));
        assert_eq!(snap.counter("smoothrot_net_conn_dropped_total", &[]), Some(0));
        assert_eq!(snap.gauge("smoothrot_net_connections_open", &[]), Some(0.0));
        stats.note_status(429);
        stats.note_status(299); // off-taxonomy pools in "other"
        let mut snap = Snapshot::new();
        collector(&mut snap);
        assert_eq!(
            snap.counter("smoothrot_net_responses_total", &[("status", "429")]),
            Some(1)
        );
        assert_eq!(
            snap.counter("smoothrot_net_responses_total", &[("status", "other")]),
            Some(1)
        );
    }

    #[test]
    fn synth_builder_matches_synthetic_request_weights() {
        let builder = synth_job_builder(2025);
        let spec = JobSpec {
            id: 0,
            tenant: 1,
            module: "k_proj".to_string(),
            layer: 2,
            rows: 4,
            seed: 99,
            bits: 4,
            alpha: 0.5,
        };
        let (tenant, job) = builder(&spec, 42).unwrap();
        assert_eq!(tenant, 1);
        assert_eq!(job.id, 42);
        let w = crate::synth::layer_weight("k_proj", 2, 2025).unwrap();
        assert_eq!(job.w.as_slice(), w.as_slice(), "server weight must be the stream-seed weight");
        // same spec → bit-identical activations (the verify path's
        // foundation)
        let (_, job2) = builder(&spec, 43).unwrap();
        assert_eq!(job.x.as_slice(), job2.x.as_slice());
    }
}
