//! HTTP/1.1 wire protocol for the network serving front-end — no
//! dependencies, `std::io` only.
//!
//! The server side ([`crate::serve::net`]) needs exactly four things
//! from HTTP: parse a request off a deadline-bearing socket with every
//! malformed shape mapped to a *named* 4xx (never a panic, never a
//! silent close), write a response head, stream a chunked body, and
//! close.  The client side (`smoothrot loadgen`, the chaos tests)
//! needs the inverse: write a request and decode a possibly-chunked
//! response.  Both directions live here so the generator and the
//! server can never disagree about framing.
//!
//! ## Status-code taxonomy
//!
//! | code | meaning here |
//! |---|---|
//! | 200 | analysis result (streamed chunked NDJSON) |
//! | 202 | drain accepted |
//! | 400 | malformed request line / header / body (named in the JSON error) |
//! | 404 | unknown endpoint |
//! | 405 | known endpoint, wrong method (`Allow` header carried) |
//! | 408 | read deadline hit while parsing (slow-loris defense) |
//! | 411 | `POST /analyze` without `Content-Length` |
//! | 413 | declared body larger than the configured cap |
//! | 429 | shed/admission-full ([`crate::serve::SubmitError`]); `Retry-After` carried when the core issued a hint |
//! | 431 | header section too large |
//! | 500 | executor error |
//! | 503 | draining / over the connection cap |
//! | 504 | per-request deadline expired in queue ([`crate::serve::ServeConfig::deadline_micros`]) |

use std::io::{self, BufRead, Read, Write};

use crate::jsonio::{self, Json};

/// Longest accepted request line (method + target + version).
pub const MAX_REQUEST_LINE: usize = 8 * 1024;
/// Longest accepted single header line.
pub const MAX_HEADER_LINE: usize = 8 * 1024;
/// Most headers accepted per request.
pub const MAX_HEADERS: usize = 64;
/// Default request-body cap (overridable via
/// [`crate::serve::net::NetConfig::max_body_bytes`]).
pub const DEFAULT_MAX_BODY: usize = 1 << 20;
/// Most jobs accepted in one `POST /analyze` body.
pub const MAX_JOBS_PER_REQUEST: usize = 64;
/// Most token rows accepted per job.
pub const MAX_ROWS: usize = 4096;
/// Highest accepted layer index (bounds the server-side weight cache).
pub const MAX_LAYER: usize = 4096;
/// Highest accepted tenant id.
pub const MAX_TENANT: usize = 4096;

/// One parsed request.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    pub method: String,
    pub target: String,
    /// Header names are lower-cased at parse time.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup (names were lower-cased at parse).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }
}

/// Why a request could not be parsed.  Every variant that maps to a
/// response carries a stable taxonomy `name` the error body quotes, so
/// tests and dashboards match on names, not prose.
#[derive(Debug)]
pub enum ProtoError {
    /// Clean EOF before the first byte — the peer closed an idle
    /// connection; not an error response, just close.
    ConnClosed,
    /// Read deadline expired mid-request (slow-loris) → 408.
    Timeout,
    /// Transport error other than a deadline — close without a response.
    Io(io::Error),
    /// Unparseable request line → 400.
    BadRequestLine(String),
    /// Not HTTP/1.x → 400.
    BadVersion(String),
    /// A header line without `:` or with a non-ASCII name → 400.
    BadHeader(String),
    /// Header section over [`MAX_HEADER_LINE`]/[`MAX_REQUEST_LINE`] → 431.
    HeaderTooLarge,
    /// More than [`MAX_HEADERS`] headers → 431.
    TooManyHeaders,
    /// `Content-Length` present but not a number → 400.
    BadContentLength(String),
    /// Declared body over the configured cap → 413.
    BodyTooLarge { declared: usize, max: usize },
    /// Connection closed before `Content-Length` bytes arrived → 400.
    BodyIncomplete { got: usize, want: usize },
}

impl ProtoError {
    /// HTTP status to answer with (`None`: close without responding).
    pub fn status(&self) -> Option<u16> {
        match self {
            ProtoError::ConnClosed | ProtoError::Io(_) => None,
            ProtoError::Timeout => Some(408),
            ProtoError::BadRequestLine(_)
            | ProtoError::BadVersion(_)
            | ProtoError::BadHeader(_)
            | ProtoError::BadContentLength(_)
            | ProtoError::BodyIncomplete { .. } => Some(400),
            ProtoError::HeaderTooLarge | ProtoError::TooManyHeaders => Some(431),
            ProtoError::BodyTooLarge { .. } => Some(413),
        }
    }

    /// Stable taxonomy token for the error body / test assertions.
    pub fn name(&self) -> &'static str {
        match self {
            ProtoError::ConnClosed => "conn_closed",
            ProtoError::Timeout => "read_timeout",
            ProtoError::Io(_) => "io_error",
            ProtoError::BadRequestLine(_) => "bad_request_line",
            ProtoError::BadVersion(_) => "bad_version",
            ProtoError::BadHeader(_) => "bad_header",
            ProtoError::HeaderTooLarge => "header_too_large",
            ProtoError::TooManyHeaders => "too_many_headers",
            ProtoError::BadContentLength(_) => "bad_content_length",
            ProtoError::BodyTooLarge { .. } => "body_too_large",
            ProtoError::BodyIncomplete { .. } => "body_incomplete",
        }
    }
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::ConnClosed => write!(f, "connection closed"),
            ProtoError::Timeout => write!(f, "read deadline expired"),
            ProtoError::Io(e) => write!(f, "transport error: {e}"),
            ProtoError::BadRequestLine(l) => write!(f, "bad request line {l:?}"),
            ProtoError::BadVersion(v) => write!(f, "unsupported version {v:?}"),
            ProtoError::BadHeader(h) => write!(f, "bad header {h:?}"),
            ProtoError::HeaderTooLarge => write!(f, "header line too large"),
            ProtoError::TooManyHeaders => write!(f, "more than {MAX_HEADERS} headers"),
            ProtoError::BadContentLength(v) => write!(f, "bad content-length {v:?}"),
            ProtoError::BodyTooLarge { declared, max } => {
                write!(f, "declared body {declared} bytes over cap {max}")
            }
            ProtoError::BodyIncomplete { got, want } => {
                write!(f, "connection closed after {got}/{want} body bytes")
            }
        }
    }
}

/// A timed-out read surfaces as `WouldBlock` (unix non-blocking
/// semantics) or `TimedOut` depending on platform; both mean the peer
/// blew the socket deadline.
fn classify_io(e: io::Error) -> ProtoError {
    match e.kind() {
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => ProtoError::Timeout,
        _ => ProtoError::Io(e),
    }
}

/// Read one `\n`-terminated line (CR stripped) with a hard byte cap;
/// an over-cap line is [`ProtoError::HeaderTooLarge`] — the bytes are
/// *not* skipped, the caller must drop the connection.
fn read_line_bounded(r: &mut impl BufRead, cap: usize) -> Result<Option<String>, ProtoError> {
    let mut line = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        match r.read(&mut byte) {
            Ok(0) => {
                if line.is_empty() {
                    return Ok(None);
                }
                return Err(ProtoError::Io(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "eof mid-line",
                )));
            }
            Ok(_) => {
                if byte[0] == b'\n' {
                    if line.last() == Some(&b'\r') {
                        line.pop();
                    }
                    return String::from_utf8(line)
                        .map(Some)
                        .map_err(|e| ProtoError::BadHeader(format!("non-utf8 line: {e}")));
                }
                if line.len() >= cap {
                    return Err(ProtoError::HeaderTooLarge);
                }
                line.push(byte[0]);
            }
            Err(e) => return Err(classify_io(e)),
        }
    }
}

/// Parse one request off `r` (which should carry a socket read
/// deadline).  `max_body` caps the *declared* `Content-Length` — the
/// body is never buffered past it, so a hostile declaration cannot
/// balloon memory.  A request without `Content-Length` parses with an
/// empty body (the route layer decides whether that is a 411).
pub fn read_request(r: &mut impl BufRead, max_body: usize) -> Result<HttpRequest, ProtoError> {
    let line = match read_line_bounded(r, MAX_REQUEST_LINE)? {
        None => return Err(ProtoError::ConnClosed),
        Some(l) => l,
    };
    let mut parts = line.split_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) => (m.to_string(), t.to_string(), v.to_string()),
        _ => return Err(ProtoError::BadRequestLine(truncate(&line, 120))),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(ProtoError::BadVersion(truncate(&version, 40)));
    }
    if method.is_empty() || !method.chars().all(|c| c.is_ascii_uppercase()) {
        return Err(ProtoError::BadRequestLine(truncate(&line, 120)));
    }

    let mut headers = Vec::new();
    loop {
        let line = match read_line_bounded(r, MAX_HEADER_LINE)? {
            None => return Err(ProtoError::Io(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "eof in headers",
            ))),
            Some(l) => l,
        };
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(ProtoError::TooManyHeaders);
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(ProtoError::BadHeader(truncate(&line, 120)));
        };
        let name = name.trim();
        if name.is_empty() || !name.chars().all(|c| c.is_ascii_graphic()) {
            return Err(ProtoError::BadHeader(truncate(&line, 120)));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }

    let body = match headers.iter().find(|(k, _)| k == "content-length") {
        None => Vec::new(),
        Some((_, v)) => {
            let declared: usize = v
                .parse()
                .map_err(|_| ProtoError::BadContentLength(truncate(v, 40)))?;
            if declared > max_body {
                return Err(ProtoError::BodyTooLarge { declared, max: max_body });
            }
            let mut body = vec![0u8; declared];
            let mut got = 0;
            while got < declared {
                match r.read(&mut body[got..]) {
                    Ok(0) => return Err(ProtoError::BodyIncomplete { got, want: declared }),
                    Ok(n) => got += n,
                    Err(e) => return Err(classify_io(e)),
                }
            }
            body
        }
    };
    Ok(HttpRequest { method, target, headers, body })
}

fn truncate(s: &str, cap: usize) -> String {
    if s.len() <= cap {
        s.to_string()
    } else {
        let mut end = cap;
        while !s.is_char_boundary(end) {
            end -= 1;
        }
        format!("{}…", &s[..end])
    }
}

/// Canonical reason phrase for the taxonomy codes.
pub fn status_reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write a response head (status line + headers + blank line).  Every
/// response carries `Connection: close` — one request per connection
/// keeps the deadline story per-request and the parser stateless.
pub fn write_head(w: &mut impl Write, code: u16, headers: &[(&str, &str)]) -> io::Result<()> {
    write!(w, "HTTP/1.1 {} {}\r\n", code, status_reason(code))?;
    for (k, v) in headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "Connection: close\r\n\r\n")
}

/// Write one chunk of a `Transfer-Encoding: chunked` body.
pub fn write_chunk(w: &mut impl Write, data: &[u8]) -> io::Result<()> {
    if data.is_empty() {
        return Ok(()); // an empty chunk would terminate the stream
    }
    write!(w, "{:x}\r\n", data.len())?;
    w.write_all(data)?;
    w.write_all(b"\r\n")
}

/// Terminate a chunked body.
pub fn finish_chunks(w: &mut impl Write) -> io::Result<()> {
    w.write_all(b"0\r\n\r\n")
}

/// Write a complete JSON error response: `{"error": name, "detail": …}`
/// with `Content-Length` framing plus any extra headers (`Retry-After`).
pub fn write_error(
    w: &mut impl Write,
    code: u16,
    name: &str,
    detail: &str,
    extra: &[(&str, &str)],
) -> io::Result<()> {
    let body = jsonio::obj(vec![
        ("error", Json::Str(name.to_string())),
        ("detail", Json::Str(detail.to_string())),
    ])
    .to_string_compact();
    let len = body.len().to_string();
    let mut headers: Vec<(&str, &str)> =
        vec![("Content-Type", "application/json"), ("Content-Length", len.as_str())];
    headers.extend_from_slice(extra);
    write_head(w, code, &headers)?;
    w.write_all(body.as_bytes())
}

// ---------------------------------------------------------------------
// Job specs: the request body → the serving core's job/tenant model.
// ---------------------------------------------------------------------

/// One job named by a `POST /analyze` body — the wire analogue of
/// [`crate::serve::synthetic_requests`]'s per-request draw: the client
/// names a (module, layer) cell and an activation seed; the server owns
/// the model (the per-layer weights), exactly as the in-process stream
/// does.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    /// Client-chosen id echoed in the result (assigned from the request
    /// index when absent).
    pub id: u64,
    pub tenant: usize,
    pub module: String,
    pub layer: usize,
    /// Token rows of synthetic activations.
    pub rows: usize,
    /// Activation stream seed (the weight seed is the *server's*).
    pub seed: u64,
    pub bits: u32,
    pub alpha: f32,
}

/// A named 400: `name` is the stable taxonomy token, `detail` the
/// human-readable rejection.
#[derive(Clone, Debug)]
pub struct BodyError {
    pub name: &'static str,
    pub detail: String,
}

impl BodyError {
    fn new(name: &'static str, detail: impl Into<String>) -> BodyError {
        BodyError { name, detail: detail.into() }
    }
}

/// Parse a `POST /analyze` body: either one job object or
/// `{"jobs": [...]}`.  Every malformed shape is a *named* rejection —
/// the route layer answers 400 quoting `name`.
pub fn parse_job_specs(body: &[u8]) -> Result<Vec<JobSpec>, BodyError> {
    let text = std::str::from_utf8(body)
        .map_err(|e| BodyError::new("body_not_utf8", e.to_string()))?;
    let doc = jsonio::parse(text).map_err(|e| BodyError::new("body_not_json", e.to_string()))?;
    let items: Vec<&Json> = match doc.get("jobs") {
        Some(jobs) => {
            let arr = jobs
                .as_arr()
                .ok_or_else(|| BodyError::new("jobs_not_array", "\"jobs\" must be an array"))?;
            arr.iter().collect()
        }
        None => vec![&doc],
    };
    if items.is_empty() {
        return Err(BodyError::new("no_jobs", "empty job list"));
    }
    if items.len() > MAX_JOBS_PER_REQUEST {
        return Err(BodyError::new(
            "too_many_jobs",
            format!("{} jobs over the per-request cap {MAX_JOBS_PER_REQUEST}", items.len()),
        ));
    }
    let model = crate::config::ModelConfig::default();
    items
        .iter()
        .enumerate()
        .map(|(i, j)| parse_one_spec(j, i as u64, &model))
        .collect()
}

fn parse_one_spec(
    j: &Json,
    index: u64,
    model: &crate::config::ModelConfig,
) -> Result<JobSpec, BodyError> {
    if j.get("module").is_none() {
        return Err(BodyError::new("missing_module", format!("job {index}: no \"module\"")));
    }
    let module = j
        .get("module")
        .and_then(Json::as_str)
        .ok_or_else(|| BodyError::new("bad_module", format!("job {index}: module not a string")))?;
    if !crate::MODULES.contains(&module) {
        return Err(BodyError::new(
            "unknown_module",
            format!("job {index}: {module:?} (want one of {:?})", crate::MODULES),
        ));
    }
    let layer = j
        .get("layer")
        .ok_or_else(|| BodyError::new("missing_layer", format!("job {index}: no \"layer\"")))?
        .as_u64()
        .ok_or_else(|| {
            BodyError::new("bad_layer", format!("job {index}: layer not a non-negative integer"))
        })? as usize;
    if layer > MAX_LAYER {
        return Err(BodyError::new("bad_layer", format!("job {index}: layer {layer} > {MAX_LAYER}")));
    }
    let field_u64 = |name: &'static str, default: u64| -> Result<u64, BodyError> {
        match j.get(name) {
            None => Ok(default),
            Some(v) => v.as_u64().ok_or_else(|| {
                BodyError::new("bad_field", format!("job {index}: {name} not a non-negative integer"))
            }),
        }
    };
    let tenant = field_u64("tenant", 0)? as usize;
    if tenant > MAX_TENANT {
        return Err(BodyError::new("bad_tenant", format!("job {index}: tenant {tenant} > {MAX_TENANT}")));
    }
    let rows = field_u64("rows", 8)? as usize;
    if rows == 0 || rows > MAX_ROWS {
        return Err(BodyError::new("bad_rows", format!("job {index}: rows {rows} not in 1..={MAX_ROWS}")));
    }
    let bits = field_u64("bits", model.bits as u64)? as u32;
    if !(2..=8).contains(&bits) {
        return Err(BodyError::new("bad_bits", format!("job {index}: bits {bits} not in 2..=8")));
    }
    let alpha = match j.get("alpha") {
        None => model.alpha as f32,
        Some(v) => v
            .as_f64()
            .ok_or_else(|| BodyError::new("bad_field", format!("job {index}: alpha not a number")))?
            as f32,
    };
    if !(0.0..=1.0).contains(&alpha) {
        return Err(BodyError::new("bad_alpha", format!("job {index}: alpha {alpha} not in 0..=1")));
    }
    Ok(JobSpec {
        id: field_u64("id", index)?,
        tenant,
        module: module.to_string(),
        layer,
        rows,
        seed: field_u64("seed", 1)?,
        bits,
        alpha,
    })
}

impl JobSpec {
    /// Serialize for a wire request body (`loadgen` and the tests).
    pub fn to_json(&self) -> Json {
        jsonio::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("tenant", Json::Num(self.tenant as f64)),
            ("module", Json::Str(self.module.clone())),
            ("layer", Json::Num(self.layer as f64)),
            ("rows", Json::Num(self.rows as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("bits", Json::Num(self.bits as f64)),
            ("alpha", Json::Num(self.alpha as f64)),
        ])
    }
}

/// Exact `f64` round-trip for result payloads: JSON number formatting
/// may drop bits, so results carry the raw IEEE-754 pattern alongside
/// the readable value.  The bit-identity acceptance gates compare these.
pub fn f64_bits_hex(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

/// Inverse of [`f64_bits_hex`].
pub fn f64_from_bits_hex(s: &str) -> Option<f64> {
    u64::from_str_radix(s, 16).ok().map(f64::from_bits)
}

// ---------------------------------------------------------------------
// Client-side response decoding (loadgen + tests).
// ---------------------------------------------------------------------

/// One decoded response.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    pub status: u16,
    /// Lower-cased header names.
    pub headers: Vec<(String, String)>,
    /// Fully decoded (de-chunked) body.
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }
}

/// Decode one response off `r`: status line, headers, then a body
/// framed by `Content-Length`, `Transfer-Encoding: chunked`, or EOF
/// (the server always closes).
pub fn read_response(r: &mut impl BufRead) -> Result<HttpResponse, ProtoError> {
    let line = match read_line_bounded(r, MAX_REQUEST_LINE)? {
        None => return Err(ProtoError::ConnClosed),
        Some(l) => l,
    };
    let mut parts = line.split_whitespace();
    let status = match (parts.next(), parts.next()) {
        (Some(v), Some(code)) if v.starts_with("HTTP/1.") => code
            .parse::<u16>()
            .map_err(|_| ProtoError::BadRequestLine(truncate(&line, 120)))?,
        _ => return Err(ProtoError::BadRequestLine(truncate(&line, 120))),
    };
    let mut headers = Vec::new();
    loop {
        let line = match read_line_bounded(r, MAX_HEADER_LINE)? {
            None => return Err(ProtoError::Io(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "eof in headers",
            ))),
            Some(l) => l,
        };
        if line.is_empty() {
            break;
        }
        if let Some((name, value)) = line.split_once(':') {
            headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        }
    }
    let chunked = headers
        .iter()
        .any(|(k, v)| k == "transfer-encoding" && v.eq_ignore_ascii_case("chunked"));
    let body = if chunked {
        let mut body = Vec::new();
        loop {
            let size_line = match read_line_bounded(r, 64)? {
                None => return Err(ProtoError::BodyIncomplete { got: body.len(), want: 0 }),
                Some(l) => l,
            };
            let size = usize::from_str_radix(size_line.trim(), 16)
                .map_err(|_| ProtoError::BadContentLength(truncate(&size_line, 40)))?;
            if size == 0 {
                let _ = read_line_bounded(r, 8)?; // trailing CRLF
                break;
            }
            if body.len() + size > DEFAULT_MAX_BODY {
                return Err(ProtoError::BodyTooLarge {
                    declared: body.len() + size,
                    max: DEFAULT_MAX_BODY,
                });
            }
            let start = body.len();
            body.resize(start + size, 0);
            let mut got = 0;
            while got < size {
                match r.read(&mut body[start + got..]) {
                    Ok(0) => return Err(ProtoError::BodyIncomplete { got, want: size }),
                    Ok(n) => got += n,
                    Err(e) => return Err(classify_io(e)),
                }
            }
            let _ = read_line_bounded(r, 8)?; // chunk-terminating CRLF
        }
        body
    } else if let Some((_, v)) = headers.iter().find(|(k, _)| k == "content-length") {
        let declared: usize =
            v.parse().map_err(|_| ProtoError::BadContentLength(truncate(v, 40)))?;
        if declared > DEFAULT_MAX_BODY {
            return Err(ProtoError::BodyTooLarge { declared, max: DEFAULT_MAX_BODY });
        }
        let mut body = vec![0u8; declared];
        let mut got = 0;
        while got < declared {
            match r.read(&mut body[got..]) {
                Ok(0) => return Err(ProtoError::BodyIncomplete { got, want: declared }),
                Ok(n) => got += n,
                Err(e) => return Err(classify_io(e)),
            }
        }
        body
    } else {
        let mut body = Vec::new();
        r.read_to_end(&mut body).map_err(classify_io)?;
        body
    };
    Ok(HttpResponse { status, headers, body })
}

/// Serialize a request (the client side of [`read_request`]).
pub fn write_request(
    w: &mut impl Write,
    method: &str,
    target: &str,
    body: &[u8],
) -> io::Result<()> {
    write!(w, "{method} {target} HTTP/1.1\r\nHost: smoothrot\r\n")?;
    if !body.is_empty() || method == "POST" {
        write!(w, "Content-Type: application/json\r\nContent-Length: {}\r\n", body.len())?;
    }
    write!(w, "Connection: close\r\n\r\n")?;
    w.write_all(body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(bytes: &[u8]) -> Result<HttpRequest, ProtoError> {
        read_request(&mut BufReader::new(bytes), DEFAULT_MAX_BODY)
    }

    #[test]
    fn parses_get() {
        let req = parse(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.target, "/healthz");
        assert_eq!(req.header("host"), Some("x"));
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_post_with_body() {
        let req =
            parse(b"POST /analyze HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcd").unwrap();
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn named_rejections() {
        let cases: [(&[u8], &str, u16); 6] = [
            (b"garbage\r\n\r\n", "bad_request_line", 400),
            (b"GET /x SPDY/3\r\n\r\n", "bad_version", 400),
            (b"GET /x HTTP/1.1\r\nnocolon\r\n\r\n", "bad_header", 400),
            (b"POST /x HTTP/1.1\r\nContent-Length: banana\r\n\r\n", "bad_content_length", 400),
            (b"POST /x HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n", "body_too_large", 413),
            (b"POST /x HTTP/1.1\r\nContent-Length: 5\r\n\r\nab", "body_incomplete", 400),
        ];
        for (bytes, name, code) in cases {
            let err = parse(bytes).unwrap_err();
            assert_eq!(err.name(), name, "input {:?}", String::from_utf8_lossy(bytes));
            assert_eq!(err.status(), Some(code));
        }
    }

    #[test]
    fn body_over_cap_is_413_without_buffering() {
        let err = read_request(
            &mut BufReader::new(&b"POST /x HTTP/1.1\r\nContent-Length: 1000\r\n\r\n"[..]),
            100,
        )
        .unwrap_err();
        assert_eq!(err.name(), "body_too_large");
        assert_eq!(err.status(), Some(413));
    }

    #[test]
    fn clean_eof_is_conn_closed_not_a_response() {
        let err = parse(b"").unwrap_err();
        assert_eq!(err.name(), "conn_closed");
        assert_eq!(err.status(), None);
    }

    #[test]
    fn header_flood_bounded() {
        let mut req = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..(MAX_HEADERS + 1) {
            req.extend_from_slice(format!("h{i}: v\r\n").as_bytes());
        }
        req.extend_from_slice(b"\r\n");
        let err = parse(&req).unwrap_err();
        assert_eq!(err.name(), "too_many_headers");
        assert_eq!(err.status(), Some(431));
    }

    #[test]
    fn oversized_header_line_bounded() {
        let mut req = b"GET / HTTP/1.1\r\nbig: ".to_vec();
        req.extend(vec![b'a'; MAX_HEADER_LINE + 10]);
        req.extend_from_slice(b"\r\n\r\n");
        let err = parse(&req).unwrap_err();
        assert_eq!(err.name(), "header_too_large");
    }

    #[test]
    fn job_specs_roundtrip_and_defaults() {
        let specs =
            parse_job_specs(br#"{"module":"k_proj","layer":3,"rows":16,"seed":7}"#).unwrap();
        assert_eq!(specs.len(), 1);
        let s = &specs[0];
        assert_eq!((s.module.as_str(), s.layer, s.rows, s.seed), ("k_proj", 3, 16, 7));
        assert_eq!(s.tenant, 0);
        assert_eq!(s.bits, crate::config::ModelConfig::default().bits);

        let multi = parse_job_specs(
            br#"{"jobs":[{"module":"k_proj","layer":0},{"module":"down_proj","layer":1,"tenant":2}]}"#,
        )
        .unwrap();
        assert_eq!(multi.len(), 2);
        assert_eq!(multi[1].tenant, 2);
        assert_eq!(multi[0].id, 0);
        assert_eq!(multi[1].id, 1);

        // serialized spec re-parses to itself
        let body = multi[1].to_json().to_string_compact();
        let again = parse_job_specs(body.as_bytes()).unwrap();
        assert_eq!(again[0], multi[1]);
    }

    #[test]
    fn job_spec_named_rejections() {
        let cases: [(&[u8], &str); 7] = [
            (b"not json", "body_not_json"),
            (br#"{"jobs":[]}"#, "no_jobs"),
            (br#"{"jobs":42}"#, "jobs_not_array"),
            (br#"{"layer":0}"#, "missing_module"),
            (br#"{"module":"up_proj","layer":0}"#, "unknown_module"),
            (br#"{"module":"k_proj"}"#, "missing_layer"),
            (br#"{"module":"k_proj","layer":0,"rows":0}"#, "bad_rows"),
        ];
        for (body, name) in cases {
            let err = parse_job_specs(body).unwrap_err();
            assert_eq!(err.name, name, "body {:?}", String::from_utf8_lossy(body));
        }
        let mut many = String::from(r#"{"jobs":["#);
        for i in 0..(MAX_JOBS_PER_REQUEST + 1) {
            if i > 0 {
                many.push(',');
            }
            many.push_str(r#"{"module":"k_proj","layer":0}"#);
        }
        many.push_str("]}");
        assert_eq!(parse_job_specs(many.as_bytes()).unwrap_err().name, "too_many_jobs");
    }

    #[test]
    fn chunked_response_roundtrip() {
        let mut wire = Vec::new();
        write_head(
            &mut wire,
            200,
            &[("Transfer-Encoding", "chunked"), ("Content-Type", "application/x-ndjson")],
        )
        .unwrap();
        write_chunk(&mut wire, b"{\"a\":1}\n").unwrap();
        write_chunk(&mut wire, b"{\"b\":2}\n").unwrap();
        finish_chunks(&mut wire).unwrap();
        let resp = read_response(&mut BufReader::new(&wire[..])).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"{\"a\":1}\n{\"b\":2}\n");
    }

    #[test]
    fn content_length_response_roundtrip() {
        let mut wire = Vec::new();
        write_error(&mut wire, 429, "shed", "retry later", &[("Retry-After", "2")]).unwrap();
        let resp = read_response(&mut BufReader::new(&wire[..])).unwrap();
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("retry-after"), Some("2"));
        let doc = jsonio::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(doc.get("error").and_then(Json::as_str), Some("shed"));
    }

    #[test]
    fn f64_bits_roundtrip_exact() {
        for x in [0.0, -0.0, 1.5, f64::INFINITY, f64::MIN_POSITIVE, 0.1 + 0.2] {
            assert_eq!(f64_from_bits_hex(&f64_bits_hex(x)).unwrap().to_bits(), x.to_bits());
        }
        assert!(f64_from_bits_hex(&f64_bits_hex(f64::NAN)).unwrap().is_nan());
    }

    #[test]
    fn write_request_parses_back() {
        let mut wire = Vec::new();
        write_request(&mut wire, "POST", "/analyze", br#"{"module":"k_proj","layer":0}"#)
            .unwrap();
        let req = parse(&wire).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.target, "/analyze");
        assert_eq!(parse_job_specs(&req.body).unwrap().len(), 1);
    }
}
