//! Sharded multi-runner serving: N runner instances behind one
//! admission front door and one shared [`PlanRegistry`].
//!
//! The `num_runners` model applied to the plan-driven serving core:
//! each runner is one worker of the parent [`Server`], owning its own
//! executor — and therefore its own persistent `par::ThreadPool`,
//! `Workspace` scratch, kernel-backend pin, and pre-quantized weight
//! view — while coalesced batches are routed to the runner that *owns*
//! their shard key instead of to the least-loaded deque.
//!
//! **Shard key.** The key is a pure function of the request:
//! [`ShardBy::Layer`] routes `job.layer % runners` (the default — layer
//! weights are what runners keep hot), [`ShardBy::Tenant`] routes
//! `tenant % runners` (cache-friendly per-tenant isolation).  Because
//! the key is computed at admission and carried through batch
//! formation, a batch only ever contains jobs of one owner.
//!
//! **Work stealing.** A skewed stream (half of all traffic on layer 0,
//! say) would strand every other runner's cores.  Idle runners
//! therefore steal whole batches from the heaviest peer deque — but
//! only a victim's *surplus* (deque length ≥ 2), so a runner that was
//! routed at least one batch always executes at least one.  Stealing
//! moves a batch between runners wholesale; it never re-forms or splits
//! one.
//!
//! **Bit-invariance.** Sharding changes *placement*, never math.  Every
//! runner executes a batch with the same executor construction (same
//! plan entry resolution, same threads knob, same kernel backend), and
//! batch composition itself cannot change per-job results (pinned at
//! the executor level by the batch-fusion proptests).  So per-job
//! outputs are identical at any runner count, stealing on or off —
//! pinned end to end by `tests/proptest_serve_sharded.rs`.
//!
//! **Hot reload.** All runners share one [`PlanRegistry`] behind an
//! `Arc`; [`PlanRegistry::reload_if_changed`] swaps the resolved plan
//! inside a write lock, so a mid-serve reload is observed atomically —
//! no runner serves the old plan while another serves the new (see the
//! atomicity test below).

use std::sync::mpsc::Receiver;
use std::sync::Arc;

#[allow(unused_imports)] // doc links
use crate::calib::registry::PlanRegistry;
use crate::coordinator::Job;
use crate::kernels::par;
use crate::telemetry::Telemetry;

use super::{
    BatchExecutor, Response, Route, ServeConfig, ServeMetrics, Server, SubmitError, TenantId,
};

/// Which request attribute names the owning runner.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShardBy {
    /// Route by `job.layer % runners` (default): runners keep disjoint
    /// layer shards of the pre-quantized weights hot.
    #[default]
    Layer,
    /// Route by `tenant % runners`: per-tenant runner affinity.
    Tenant,
}

impl ShardBy {
    /// Parse a CLI name.
    ///
    /// ```
    /// use smoothrot::serve::shard::ShardBy;
    /// assert_eq!(ShardBy::from_name("layer").unwrap(), ShardBy::Layer);
    /// assert_eq!(ShardBy::from_name("tenant").unwrap(), ShardBy::Tenant);
    /// assert!(ShardBy::from_name("module").is_err());
    /// ```
    pub fn from_name(name: &str) -> Result<ShardBy, String> {
        match name {
            "layer" => Ok(ShardBy::Layer),
            "tenant" => Ok(ShardBy::Tenant),
            other => Err(format!("unknown shard key {other:?} (expected layer|tenant)")),
        }
    }

    /// The CLI name.
    pub fn name(self) -> &'static str {
        match self {
            ShardBy::Layer => "layer",
            ShardBy::Tenant => "tenant",
        }
    }

    /// The raw shard key of a request (reduced `% runners` at routing).
    fn key(self, job: &Job, tenant: TenantId) -> usize {
        match self {
            ShardBy::Layer => job.layer,
            ShardBy::Tenant => tenant,
        }
    }
}

/// Configuration of a sharded server: runner topology on top of the
/// base [`ServeConfig`] (whose `workers` field is overridden by the
/// resolved runner count).
#[derive(Clone, Copy, Debug)]
pub struct ShardConfig {
    /// Runner count; `0` = one per hardware thread
    /// ([`resolve_runners`]).
    pub runners: usize,
    /// Shard-key choice.
    pub shard_by: ShardBy,
    /// Whether idle runners may steal surplus batches from the
    /// heaviest peer.  On by default; the invariance proptests force it
    /// off to pin placement.
    pub stealing: bool,
    /// Admission / batching knobs shared with classic serving.
    pub base: ServeConfig,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            runners: 0,
            shard_by: ShardBy::default(),
            stealing: true,
            base: ServeConfig::default(),
        }
    }
}

/// Resolve a `--runners` request: `0` means one runner per hardware
/// thread (the same auto rule as the threads knob), anything else is
/// taken literally.
pub fn resolve_runners(runners: usize) -> usize {
    if runners == 0 {
        par::resolve_threads(0)
    } else {
        runners
    }
}

/// A serving core whose workers are shard-owning runners.
///
/// Thin wrapper over [`Server`]: construction installs an owner-routed
/// batch placement policy derived from
/// [`ShardConfig::shard_by`], everything else (admission, coalescing,
/// fair share, drain semantics) is the classic core.  Per-runner
/// routed/steal counters and latency percentiles surface through
/// [`ServeMetrics`].
pub struct ShardedServer {
    inner: Server,
    runners: usize,
}

impl ShardedServer {
    /// Spawn `resolve_runners(cfg.runners)` runners.
    /// `make_executor(runner_idx)` runs inside each runner thread, as
    /// with [`Server::start`].
    pub fn start<E, F>(cfg: ShardConfig, make_executor: F) -> (ShardedServer, Receiver<Response>)
    where
        E: BatchExecutor,
        F: Fn(usize) -> Result<E, String> + Send + Sync + 'static,
    {
        Self::start_with_telemetry(cfg, None, make_executor)
    }

    /// [`ShardedServer::start`] with a [`Telemetry`] subsystem attached
    /// (see [`Server::start_with_telemetry`]); all runners share the
    /// one instance — their stage timers merge into the same
    /// histograms, worker-count-invariantly.
    pub fn start_with_telemetry<E, F>(
        cfg: ShardConfig,
        telemetry: Option<Arc<Telemetry>>,
        make_executor: F,
    ) -> (ShardedServer, Receiver<Response>)
    where
        E: BatchExecutor,
        F: Fn(usize) -> Result<E, String> + Send + Sync + 'static,
    {
        let runners = resolve_runners(cfg.runners);
        let shard_by = cfg.shard_by;
        let route = Route::Owner(Arc::new(move |job: &Job, tenant: TenantId| {
            shard_by.key(job, tenant)
        }));
        let base = ServeConfig { workers: runners, ..cfg.base };
        let (inner, rx) = Server::start_routed(base, route, cfg.stealing, telemetry, make_executor);
        (ShardedServer { inner, runners }, rx)
    }

    /// Resolved runner count.
    pub fn runners(&self) -> usize {
        self.runners
    }

    /// Admit one request for `tenant` (see [`Server::submit`]).
    pub fn submit(&self, tenant: TenantId, job: Job) -> Result<(), SubmitError> {
        self.inner.submit(tenant, job)
    }

    /// Graceful drain across every runner: stop admission, complete all
    /// queued and in-flight batches (safe concurrently with a plan
    /// hot-swap — all runners share one registry and resolve it per
    /// batch), and flush the drain into telemetry (see
    /// [`Server::drain`]).
    pub fn drain(&self) {
        self.inner.drain()
    }

    /// Drain, join all runners and return the merged summary (see
    /// [`Server::finish`]).
    pub fn finish(self) -> ServeMetrics {
        self.inner.finish()
    }
}

/// Submit a fixed request list to a fresh sharded server, drain it and
/// return `(responses, metrics)` — the sharded twin of
/// [`super::serve_all`].  [`SubmitError::Full`] rejections and
/// [`SubmitError::Shed`] sheds are counted in the metrics, not
/// returned as errors.
pub fn serve_all_sharded<E, F>(
    cfg: ShardConfig,
    requests: Vec<(TenantId, Job)>,
    make_executor: F,
) -> Result<(Vec<Response>, ServeMetrics), SubmitError>
where
    E: BatchExecutor,
    F: Fn(usize) -> Result<E, String> + Send + Sync + 'static,
{
    serve_all_sharded_with_telemetry(cfg, None, requests, make_executor)
}

/// [`serve_all_sharded`] with a [`Telemetry`] subsystem attached (see
/// [`ShardedServer::start_with_telemetry`]).
pub fn serve_all_sharded_with_telemetry<E, F>(
    cfg: ShardConfig,
    telemetry: Option<Arc<Telemetry>>,
    requests: Vec<(TenantId, Job)>,
    make_executor: F,
) -> Result<(Vec<Response>, ServeMetrics), SubmitError>
where
    E: BatchExecutor,
    F: Fn(usize) -> Result<E, String> + Send + Sync + 'static,
{
    let (server, responses) = ShardedServer::start_with_telemetry(cfg, telemetry, make_executor);
    for (tenant, job) in requests {
        match server.submit(tenant, job) {
            Ok(()) | Err(SubmitError::Full { .. } | SubmitError::Shed { .. }) => {}
            Err(e) => return Err(e),
        }
    }
    let metrics = server.finish();
    Ok((responses.into_iter().collect(), metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::plan::{PlanEntry, Provenance, QuantPlan};
    use crate::calib::registry::PlanRegistry;
    use crate::coordinator::Executor;
    use crate::runtime::AnalyzeOut;
    use crate::serve::{serve_all, Admission, NativeBatchExecutor};
    use crate::tensor::Matrix;
    use crate::transforms::Mode;
    use std::collections::BTreeMap;
    use std::time::Duration;

    fn job(id: u64, layer: usize, c_in: usize) -> Job {
        Job {
            id,
            layer,
            module: "k_proj",
            x: Matrix::zeros(4, c_in),
            w: Matrix::zeros(c_in, 8),
            alpha: 0.5,
            bits: 4,
        }
    }

    /// Cheap executor keying its output to the job id.
    struct EchoExec {
        micros: u64,
    }

    impl Executor for EchoExec {
        fn run(&mut self, job: &Job) -> Result<AnalyzeOut, String> {
            if self.micros > 0 {
                std::thread::sleep(Duration::from_micros(self.micros));
            }
            let mut out = AnalyzeOut::default();
            out.errors[0] = job.id as f64;
            Ok(out)
        }
    }

    fn cfg(runners: usize, shard_by: ShardBy, stealing: bool) -> ShardConfig {
        ShardConfig {
            runners,
            shard_by,
            stealing,
            base: ServeConfig {
                workers: 1, // overridden by the runner count
                max_batch: 4,
                queue_depth: 64,
                paused: true,
                ..Default::default()
            },
        }
    }

    #[test]
    fn layer_sharding_pins_every_batch_to_its_owner() {
        // stealing off: placement is exactly the shard key
        let reqs: Vec<(TenantId, Job)> =
            (0..32).map(|i| ((i % 3) as TenantId, job(i, (i as usize) % 4, 8))).collect();
        let (responses, m) =
            serve_all_sharded(cfg(4, ShardBy::Layer, false), reqs, |_| Ok(EchoExec { micros: 0 }))
                .unwrap();
        assert_eq!(m.completed, 32);
        assert_eq!(m.steals, 0);
        for r in &responses {
            assert_eq!(r.out.as_ref().unwrap().errors[0] as u64, r.id);
            // layer < 4 and runners == 4, so owner == layer
            assert_eq!(r.worker, (r.id as usize) % 4, "job {} misplaced", r.id);
        }
        // every runner owned some traffic, and the counters reconcile
        assert_eq!(m.per_worker_routed.len(), 4);
        assert!(m.per_worker_routed.iter().all(|&r| r > 0));
        assert_eq!(m.per_worker_routed.iter().sum::<u64>(), m.batches);
        assert_eq!(m.per_worker_batches.iter().sum::<u64>(), m.batches);
        assert_eq!(m.per_worker_steals.iter().sum::<u64>(), 0);
        assert_eq!(m.per_worker_latency.len(), 4);
    }

    #[test]
    fn tenant_sharding_routes_by_tenant() {
        let reqs: Vec<(TenantId, Job)> =
            (0..24).map(|i| ((i % 3) as TenantId, job(i, 0, 8))).collect();
        let (responses, m) =
            serve_all_sharded(cfg(2, ShardBy::Tenant, false), reqs, |_| Ok(EchoExec { micros: 0 }))
                .unwrap();
        assert_eq!(m.completed, 24);
        for r in &responses {
            assert_eq!(r.worker, r.tenant % 2, "tenant {} misplaced", r.tenant);
        }
    }

    #[test]
    fn idle_runners_steal_a_skewed_stream_surplus() {
        // every request owned by runner 0; runner 1 has nothing routed
        // and must steal surplus batches for the drain to use it at all
        let reqs: Vec<(TenantId, Job)> = (0..48).map(|i| (0, job(i, 0, 8))).collect();
        let (responses, m) =
            serve_all_sharded(cfg(2, ShardBy::Layer, true), reqs, |_| Ok(EchoExec { micros: 800 }))
                .unwrap();
        assert_eq!(m.completed, 48);
        assert_eq!(m.per_worker_routed, vec![12, 0], "48 jobs / max_batch 4, all owned by 0");
        assert!(m.steals > 0, "idle runner never stole: {m:?}");
        assert_eq!(m.per_worker_steals[0], 0, "the owner has nothing to steal");
        // every job still completed exactly once, results intact
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 48);
        // surplus-only policy: the owner always executes at least one
        // of its own batches
        assert!(m.per_worker_batches[0] > 0);
    }

    #[test]
    fn sharded_results_match_single_runner_serving() {
        // quick end-to-end pin of the invariance argument (the proptest
        // sweeps the config space): 4-runner sharded serving returns
        // exactly what a single classic worker returns, per job id
        let reqs: Vec<(TenantId, Job)> = (0..16)
            .map(|i| {
                let mut rng = crate::rng::Rng::new(3000 + i);
                let x = Matrix::from_vec(4, 8, rng.normals_f32(32));
                let w = Matrix::from_vec(8, 8, rng.normals_f32(64));
                let j = Job {
                    id: i,
                    layer: (i as usize) % 4,
                    module: "k_proj",
                    x,
                    w,
                    alpha: 0.5,
                    bits: 4,
                };
                (0, j)
            })
            .collect();
        let base = ServeConfig { workers: 1, max_batch: 4, queue_depth: 64, paused: true, ..Default::default() };
        let (single, _) =
            serve_all(base, reqs.clone(), |_| Ok(NativeBatchExecutor::with_threads(1))).unwrap();
        let (sharded, m) = serve_all_sharded(
            ShardConfig { runners: 4, shard_by: ShardBy::Layer, stealing: true, base },
            reqs,
            |_| Ok(NativeBatchExecutor::with_threads(1)),
        )
        .unwrap();
        assert_eq!(m.completed, 16);
        let by_id = |rs: &[Response]| -> BTreeMap<u64, AnalyzeOut> {
            rs.iter().map(|r| (r.id, r.out.as_ref().unwrap().clone())).collect()
        };
        let (a, b) = (by_id(&single), by_id(&sharded));
        assert_eq!(a.len(), 16);
        for (id, want) in &a {
            assert_eq!(&b[id], want, "job {id} diverged under sharding");
        }
    }

    #[test]
    fn summary_reports_per_runner_lines() {
        let reqs: Vec<(TenantId, Job)> =
            (0..16).map(|i| (0, job(i, (i as usize) % 4, 8))).collect();
        let (_, m) =
            serve_all_sharded(cfg(4, ShardBy::Layer, false), reqs, |_| Ok(EchoExec { micros: 0 }))
                .unwrap();
        let s = m.summary();
        for i in 0..4 {
            assert!(s.contains(&format!("runner {i}: routed")), "missing runner {i} line:\n{s}");
        }
    }

    #[test]
    fn resolve_runners_auto_matches_thread_auto() {
        assert_eq!(resolve_runners(0), par::resolve_threads(0));
        assert!(resolve_runners(0) >= 1);
        assert_eq!(resolve_runners(3), 3);
    }

    fn plan_with_mode(mode: Mode) -> QuantPlan {
        QuantPlan {
            provenance: Provenance::default(),
            entries: (0..4)
                .map(|layer| PlanEntry {
                    module: "k_proj".into(),
                    layer,
                    bits: 4,
                    c_in: 8,
                    mode,
                    alpha: 0.5,
                    predicted_error: 1.0,
                    difficulty_before: 2.0,
                    difficulty_after: 1.0,
                    smooth: None,
                })
                .collect(),
        }
    }

    /// Which plan generation served a response: plan-driven execution
    /// evaluates only the planned mode (all other error slots are
    /// infinite), so the argmin mode identifies the plan version.
    fn served_mode(out: &AnalyzeOut) -> Mode {
        Mode::ALL
            .into_iter()
            .min_by(|a, b| out.errors[a.index()].partial_cmp(&out.errors[b.index()]).unwrap())
            .unwrap()
    }

    #[test]
    fn hot_reload_lands_atomically_across_all_runners() {
        let dir = std::env::temp_dir().join("smoothrot_shard_reload_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        plan_with_mode(Mode::Rotate).save(&path).unwrap();
        let reg = std::sync::Arc::new(PlanRegistry::load(&path).unwrap());

        let reg2 = std::sync::Arc::clone(&reg);
        let live = ShardConfig {
            runners: 4,
            shard_by: ShardBy::Layer,
            stealing: false,
            base: ServeConfig {
                workers: 1,
                max_batch: 4,
                queue_depth: 64,
                admission: Admission::Block,
                ..Default::default()
            },
        };
        let (server, rx) = ShardedServer::start(live, move |_| {
            Ok(NativeBatchExecutor::with_plan(std::sync::Arc::clone(&reg2), 1))
        });
        assert_eq!(server.runners(), 4);

        // wave 1: all four runners serve plan v1 (Rotate)
        for i in 0..16u64 {
            server.submit(0, job(i, (i as usize) % 4, 8)).unwrap();
        }
        let wave1: Vec<Response> = rx.iter().take(16).collect();
        for r in &wave1 {
            assert_eq!(served_mode(r.out.as_ref().unwrap()), Mode::Rotate);
        }

        // hot swap to plan v2 (None) through the shared registry; once
        // reload_if_changed returns, the swap is complete — no runner
        // may serve v1 afterwards
        plan_with_mode(Mode::None).save(&path).unwrap();
        assert!(reg.reload_if_changed().unwrap());

        // wave 2: every runner observes v2, none straddles
        for i in 100..116u64 {
            server.submit(0, job(i, (i as usize) % 4, 8)).unwrap();
        }
        let wave2: Vec<Response> = rx.iter().take(16).collect();
        let mut runners_seen = std::collections::BTreeSet::new();
        for r in &wave2 {
            assert_eq!(
                served_mode(r.out.as_ref().unwrap()),
                Mode::None,
                "runner {} served the old plan after reload",
                r.worker
            );
            runners_seen.insert(r.worker);
        }
        // stealing is off, wave 2 covers all four layers — the v2
        // observation really was made by every runner
        assert_eq!(runners_seen.len(), 4, "not all runners served wave 2: {runners_seen:?}");

        let m = server.finish();
        assert_eq!(m.completed, 32);
        assert_eq!(m.errors, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Executor that panics on one poison job id (sharded twin of the
    /// classic quarantine test).
    struct PoisonExec {
        poison: u64,
    }

    impl Executor for PoisonExec {
        fn run(&mut self, job: &Job) -> Result<AnalyzeOut, String> {
            if job.id == self.poison {
                panic!("poison job {}", job.id);
            }
            let mut out = AnalyzeOut::default();
            out.errors[0] = job.id as f64;
            Ok(out)
        }
    }

    #[test]
    fn sharded_quarantine_keeps_the_owning_runner_alive() {
        // the poison job (id 5, layer 1) panics its batch inside runner
        // 1; that runner must split, quarantine only job 5, and keep
        // serving its layer — no runner dies, no response is lost
        let reqs: Vec<(TenantId, Job)> =
            (0..16).map(|i| (0, job(i, (i as usize) % 4, 8))).collect();
        let (responses, m) =
            serve_all_sharded(cfg(4, ShardBy::Layer, false), reqs, |_| Ok(PoisonExec { poison: 5 }))
                .unwrap();
        assert_eq!(responses.len(), 16);
        assert_eq!(m.completed, 16);
        assert_eq!(m.quarantined, 1);
        assert_eq!(m.errors, 1);
        for r in &responses {
            if r.id == 5 {
                assert!(r.out.as_ref().unwrap_err().contains("quarantined after panic"));
            } else {
                assert_eq!(r.out.as_ref().unwrap().errors[0] as u64, r.id);
            }
        }
        // layer 1's other jobs (1, 9, 13) were still served by a live
        // runner after the poisoned batch
        let layer1_ok = responses.iter().filter(|r| r.layer == 1 && r.out.is_ok()).count();
        assert_eq!(layer1_ok, 3, "the poisoned runner kept serving its shard");
    }

    #[test]
    fn sharded_drain_finishes_every_runner() {
        let scfg = ShardConfig {
            runners: 3,
            shard_by: ShardBy::Layer,
            stealing: true,
            base: ServeConfig { workers: 1, max_batch: 4, queue_depth: 64, ..Default::default() },
        };
        let (server, rx) = ShardedServer::start(scfg, |_| Ok(EchoExec { micros: 300 }));
        for i in 0..18u64 {
            server.submit((i % 2) as TenantId, job(i, (i as usize) % 3, 8)).unwrap();
        }
        server.drain();
        assert_eq!(
            server.submit(0, job(99, 0, 8)),
            Err(SubmitError::Closed),
            "a drained sharded server admits nothing"
        );
        let m = server.finish();
        assert_eq!(m.completed, 18);
        assert_eq!(m.drains, 1);
        let ids: std::collections::BTreeSet<u64> = rx.iter().map(|r| r.id).collect();
        assert_eq!(ids.len(), 18, "every job answered exactly once across runners");
    }
}
