//! Native synthetic activation generator.
//!
//! A rust-side mirror of SynLlama's *outlier profiles* (not the full
//! transformer — that lives in the L2 HLO): generates per-layer
//! activation matrices with the same statistical structure (systematic
//! hot channels with layer-indexed amplitude, massive token spikes,
//! broad heavy tails) so the property tests, ablations and benches can
//! run without a PJRT client, and the figure benches have a cheap
//! workload generator.

use crate::rng::Rng;
use crate::tensor::Matrix;

/// Per-layer systematic-outlier profile shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    /// Rises to mid-stack then falls (k_proj in the paper).
    Peaked,
    /// Monotonic growth ~ (l/L)^1.5 (o_proj).
    Power,
    /// Linear growth (gate/down_proj).
    Linear,
    /// No systematic outliers.
    Flat,
}

impl Profile {
    /// Amplitude multiplier at layer `l` of `n_layers`.
    pub fn amplitude(self, l: usize, n_layers: usize) -> f64 {
        let t = l as f64 / (n_layers.max(2) - 1) as f64;
        match self {
            Profile::Peaked => (std::f64::consts::PI * t).sin(),
            Profile::Power => t.powf(1.5),
            Profile::Linear => t,
            Profile::Flat => 0.0,
        }
    }
}

/// Generator spec for one module kind's activation stream.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub n_tokens: usize,
    pub channels: usize,
    pub n_layers: usize,
    pub profile: Profile,
    pub peak_gain: f64,
    pub hot_channels: usize,
    /// Layers carrying a massive token spike.
    pub massive_layers: Vec<usize>,
    pub massive_tokens: usize,
    pub massive_channels: usize,
    pub massive_value: f32,
    pub seed: u64,
}

impl SynthSpec {
    /// k_proj-like stream at SynLlama scale.
    pub fn attention(seed: u64) -> Self {
        Self {
            n_tokens: 128,
            channels: 256,
            n_layers: 32,
            profile: Profile::Peaked,
            peak_gain: 24.0,
            hot_channels: 8,
            massive_layers: vec![],
            massive_tokens: 0,
            massive_channels: 0,
            massive_value: 0.0,
            seed,
        }
    }

    /// down_proj-like stream: linear systematic + massive spikes at 1/30.
    pub fn down_proj(seed: u64) -> Self {
        Self {
            n_tokens: 128,
            channels: 704,
            n_layers: 32,
            profile: Profile::Linear,
            peak_gain: 4.0,
            hot_channels: 22,
            massive_layers: vec![1, 30],
            massive_tokens: 2,
            massive_channels: 8,
            massive_value: 6000.0,
            seed,
        }
    }

    /// Generate the activation matrix of layer `l`.
    pub fn layer(&self, l: usize) -> Matrix {
        assert!(l < self.n_layers, "layer {l} out of range");
        // per-layer deterministic stream so layers can be generated in any order
        let mut rng = Rng::new(self.seed ^ (l as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut x = Matrix::from_vec(
            self.n_tokens,
            self.channels,
            rng.normals_f32(self.n_tokens * self.channels),
        );
        // systematic hot channels (deterministic set per spec, not per layer)
        let mut chan_rng = Rng::new(self.seed ^ 0xC0FFEE);
        let hot = chan_rng.choose_distinct(self.channels, self.hot_channels);
        let amp = self.peak_gain * self.profile.amplitude(l, self.n_layers);
        if amp > 0.0 && !self.massive_layers.contains(&l) {
            for i in 0..self.n_tokens {
                let row = x.row_mut(i);
                for (hi, &j) in hot.iter().enumerate() {
                    // per-channel spread mirrors SynLlama's 1 + 0.25*U
                    let per_ch = 1.0 + 0.25 * ((hi as f32 * 0.37) % 1.0);
                    row[j] *= 1.0 + (amp as f32) * per_ch;
                }
            }
        }
        // massive token spikes (capped to the matrix size so tiny
        // synthetic requests, e.g. the serve demo's --rows 1, stay valid)
        if self.massive_layers.contains(&l) && self.massive_tokens > 0 {
            let toks = rng.choose_distinct(self.n_tokens, self.massive_tokens.min(self.n_tokens));
            let chans =
                rng.choose_distinct(self.channels, self.massive_channels.min(self.channels));
            for &t in &toks {
                let row = x.row_mut(t);
                for &c in &chans {
                    row[c] = rng.sign() * self.massive_value * (1.0 + 0.15 * rng.f32());
                }
            }
        }
        x
    }

    /// gate_proj-like stream: linear systematic outliers at d_model width.
    pub fn gate_proj(seed: u64) -> Self {
        Self {
            profile: Profile::Linear,
            peak_gain: 6.0,
            hot_channels: 10,
            ..Self::attention(seed)
        }
    }

    /// Generate a weight matrix paired with this stream.
    pub fn weight(&self, c_out: usize, l: usize) -> Matrix {
        let mut rng = Rng::new(self.seed ^ 0xBEEF ^ (l as u64).wrapping_mul(0x2545F4914F6CDD1D));
        let std = (self.channels as f32).powf(-0.5);
        let mut w = Matrix::from_vec(self.channels, c_out, rng.normals_f32(self.channels * c_out));
        for v in w.as_mut_slice() {
            *v *= std;
        }
        w
    }
}

/// Synthetic activation stream + weight width for a recorded module
/// kind, at SynLlama scale (d_model 256, d_ffn 704).  Lets the serving
/// demos and benches generate per-module (X, W) request payloads with
/// paper-shaped outlier structure but **no AOT artifacts** — the
/// artifact-free twin of `pipeline::Workload::pair`.
///
/// Returns `(activation spec, c_out)`, or `None` for an unknown module.
pub fn module_stream(module: &str, seed: u64) -> Option<(SynthSpec, usize)> {
    match module {
        "k_proj" => Some((SynthSpec::attention(seed), 256)),
        "o_proj" => Some((
            SynthSpec { profile: Profile::Power, peak_gain: 12.0, ..SynthSpec::attention(seed ^ 0xA5) },
            256,
        )),
        "gate_proj" => Some((SynthSpec::gate_proj(seed ^ 0x5A), 704)),
        "down_proj" => Some((SynthSpec::down_proj(seed ^ 0xD0), 256)),
        _ => None,
    }
}

/// The fixed per-layer weight of the synthetic serving "model": the
/// weight [`module_stream`]`(module, seed)` pairs with `layer`,
/// independent of any per-request activation seed.  Serving demos draw
/// per-request activations from per-request seeds but share these
/// weights across requests, which is what lets the int8 plan registry
/// pre-quantize each layer's weight once and serve it to every request
/// (`None` for an unknown module).
pub fn layer_weight(module: &str, layer: usize, seed: u64) -> Option<Matrix> {
    let (spec, c_out) = module_stream(module, seed)?;
    Some(spec.weight(c_out, layer))
}

/// Draw a layer index with a deliberately skewed distribution: ~half of
/// all requests hit layer 0, the rest spread uniformly over the other
/// layers.  This is the adversarial stream for sharded serving — under
/// layer sharding it overloads one runner, so any aggregate-throughput
/// scaling (and the CI gate that every runner serves at least one
/// batch) can only come from cross-runner work stealing, not from a
/// conveniently uniform load.
pub fn skewed_layer(rng: &mut Rng, layers: usize) -> usize {
    if layers <= 1 || rng.below(2) == 0 {
        0
    } else {
        1 + rng.below(layers - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{self, Channels};

    #[test]
    fn profiles_have_expected_shape() {
        let n = 32;
        // peaked: mid > ends
        let p = Profile::Peaked;
        assert!(p.amplitude(16, n) > p.amplitude(1, n));
        assert!(p.amplitude(16, n) > p.amplitude(31, n));
        // linear: monotonic
        let l = Profile::Linear;
        assert!(l.amplitude(31, n) > l.amplitude(15, n));
        assert_eq!(Profile::Flat.amplitude(20, n), 0.0);
    }

    #[test]
    fn attention_stream_difficulty_tracks_profile() {
        let spec = SynthSpec::attention(1);
        let d_mid = metrics::quant_difficulty(&spec.layer(16), Channels::Columns);
        let d_early = metrics::quant_difficulty(&spec.layer(1), Channels::Columns);
        let d_late = metrics::quant_difficulty(&spec.layer(31), Channels::Columns);
        assert!(d_mid > 3.0 * d_early, "mid {d_mid} early {d_early}");
        assert!(d_mid > 3.0 * d_late, "mid {d_mid} late {d_late}");
    }

    #[test]
    fn skewed_layer_concentrates_on_layer_zero() {
        let mut rng = crate::rng::Rng::new(42);
        let layers = 8;
        let mut counts = vec![0usize; layers];
        for _ in 0..4000 {
            let l = skewed_layer(&mut rng, layers);
            assert!(l < layers);
            counts[l] += 1;
        }
        // ~50% of draws land on layer 0; every other layer still shows up
        assert!(counts[0] > 1600 && counts[0] < 2400, "layer-0 share: {counts:?}");
        assert!(counts[1..].iter().all(|&c| c > 0), "tail layer starved: {counts:?}");
        // degenerate cases pin to layer 0
        assert_eq!(skewed_layer(&mut rng, 1), 0);
        assert_eq!(skewed_layer(&mut rng, 0), 0);
    }

    #[test]
    fn down_stream_has_massive_spikes() {
        let spec = SynthSpec::down_proj(2);
        for &l in &[1usize, 30] {
            let x = spec.layer(l);
            assert!(x.abs_max() > 0.8 * spec.massive_value);
            let hot_rows = x
                .row_abs_max()
                .iter()
                .filter(|&&m| m > 0.5 * spec.massive_value)
                .count();
            assert!(hot_rows <= spec.massive_tokens);
        }
        // non-massive layer is bounded
        assert!(spec.layer(10).abs_max() < 100.0);
    }

    #[test]
    fn generation_is_deterministic_and_order_free() {
        let spec = SynthSpec::down_proj(3);
        let a = spec.layer(30);
        let _ = spec.layer(5); // interleave
        let b = spec.layer(30);
        assert_eq!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn module_streams_match_manifest_shapes() {
        let cfg = crate::config::ModelConfig::default();
        for module in crate::MODULES {
            let (spec, c_out) = module_stream(module, 1).unwrap();
            let (want_in, want_out) = cfg.module_shape(module).unwrap();
            assert_eq!(spec.channels, want_in, "{module} c_in");
            assert_eq!(c_out, want_out, "{module} c_out");
            // generated pair must be matmul-compatible
            let x = spec.layer(0);
            let w = spec.weight(c_out, 0);
            assert_eq!(x.cols(), w.rows(), "{module} X/W inner dims");
        }
        assert!(module_stream("nope", 1).is_none());
    }

    #[test]
    fn layer_weight_is_the_streams_fixed_weight() {
        for module in crate::MODULES {
            let a = layer_weight(module, 3, 42).unwrap();
            let b = layer_weight(module, 3, 42).unwrap();
            assert_eq!(a.as_slice(), b.as_slice(), "{module} weight must be deterministic");
            let (spec, c_out) = module_stream(module, 42).unwrap();
            assert_eq!(a.as_slice(), spec.weight(c_out, 3).as_slice(), "{module}");
        }
        assert!(layer_weight("nope", 0, 1).is_none());
    }

    #[test]
    fn weight_scale_is_unit_column_norm() {
        let spec = SynthSpec::attention(4);
        let w = spec.weight(128, 0);
        let norms = w.col_norms();
        let mean: f64 = norms.iter().sum::<f64>() / norms.len() as f64;
        assert!((mean - 1.0).abs() < 0.1, "mean col norm {mean}");
    }
}
