//! Live per-(module, layer) quantization-difficulty tracking — the
//! paper's Sec. II-B metric observed on *served* traffic, not just at
//! calibration time.
//!
//! Every integer-path dispatch ([`crate::kernels::fused::analyze_planned_int`]
//! and its batch twin) already computes the served rows' activation
//! difficulty (std of channel magnitudes) and the **executed** Eq. 2
//! error; the serving executor feeds those values here per job.  Each
//! cell keeps streaming aggregates:
//!
//! * Welford running mean (numerically stable, no sample retention),
//! * running max,
//! * an EWMA (`EWMA_ALPHA`-weighted) that tracks the *recent* stream —
//!   the early-warning signal for activation drift,
//! * the same three for the executed Eq. 2 error,
//! * the plan's recorded calibration difficulty
//!   (`PlanEntry::difficulty_after`, surfaced through
//!   [`crate::calib::registry::ResolvedEntry::calib_difficulty`]),
//!
//! so every snapshot row carries a ready-made **drift column**
//! (`live mean − calibration difficulty`) — the sensor layer ROADMAP
//! item 5's auto-recalibration will trigger on.
//!
//! Like the stage timers, observation goes through a thread-local sink
//! ([`with_sink`] / [`observe`]) so the executor hot path needs no
//! telemetry handle and pays one thread-local read when disabled.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// EWMA weight of the newest observation (≈ the last ~40 observations
/// dominate the value).
pub const EWMA_ALPHA: f64 = 0.05;

/// Streaming aggregates of one (module, layer) cell.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cell {
    /// Observations folded in.
    pub count: u64,
    /// Welford running mean of the live difficulty.
    pub mean: f64,
    /// Max live difficulty seen.
    pub max: f64,
    /// EWMA of the live difficulty (seeded by the first observation).
    pub ewma: f64,
    /// Welford running mean of the executed Eq. 2 error.
    pub err_mean: f64,
    /// Max executed Eq. 2 error seen.
    pub err_max: f64,
    /// The plan's calibration difficulty for this cell (last observed;
    /// follows plan hot reloads).
    pub plan: f64,
}

impl Cell {
    fn observe(&mut self, difficulty: f64, err: f64, plan: f64) {
        self.count += 1;
        let n = self.count as f64;
        self.mean += (difficulty - self.mean) / n;
        self.err_mean += (err - self.err_mean) / n;
        if self.count == 1 {
            self.max = difficulty;
            self.err_max = err;
            self.ewma = difficulty;
        } else {
            self.max = self.max.max(difficulty);
            self.err_max = self.err_max.max(err);
            self.ewma += EWMA_ALPHA * (difficulty - self.ewma);
        }
        self.plan = plan;
    }

    /// Live-vs-calibration drift: `mean − plan`.  Positive = the served
    /// stream is *harder* to quantize than the plan was calibrated for.
    pub fn drift(&self) -> f64 {
        self.mean - self.plan
    }
}

/// One snapshot row: a cell plus its identity.
#[derive(Clone, Debug, PartialEq)]
pub struct DifficultyRow {
    /// Module kind (e.g. `"k_proj"`).
    pub module: String,
    /// Layer index.
    pub layer: usize,
    /// The streaming aggregates.
    pub cell: Cell,
}

/// Shared tracker of every observed (module, layer) cell.
#[derive(Debug, Default)]
pub struct DifficultyTracker {
    cells: Mutex<BTreeMap<(String, usize), Cell>>,
}

impl DifficultyTracker {
    /// An empty tracker.
    pub fn new() -> Arc<DifficultyTracker> {
        Arc::new(DifficultyTracker::default())
    }

    /// Fold one served job's live difficulty, executed Eq. 2 error and
    /// plan calibration difficulty into its cell.
    pub fn observe(&self, module: &str, layer: usize, difficulty: f64, err: f64, plan: f64) {
        let mut map = match self.cells.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        // allocate the key only on a cell's first observation
        if let Some(cell) = map.get_mut(&(module.to_string(), layer)) {
            cell.observe(difficulty, err, plan);
        } else {
            let mut cell = Cell::default();
            cell.observe(difficulty, err, plan);
            map.insert((module.to_string(), layer), cell);
        }
    }

    /// Every observed cell, in (module, layer) order — deterministic
    /// because observation *order* only permutes commutative folds
    /// within a cell when jobs race, and the per-cell totals are what
    /// the snapshot tests compare.
    pub fn rows(&self) -> Vec<DifficultyRow> {
        let map = match self.cells.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        map.iter()
            .map(|((module, layer), cell)| DifficultyRow {
                module: module.clone(),
                layer: *layer,
                cell: *cell,
            })
            .collect()
    }
}

thread_local! {
    static SINK: RefCell<Option<Arc<DifficultyTracker>>> = const { RefCell::new(None) };
}

/// Run `f` with `sink` installed as this thread's difficulty
/// destination (restores the previous sink afterwards, panic-safe).
pub fn with_sink<R>(sink: Option<Arc<DifficultyTracker>>, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<Arc<DifficultyTracker>>);
    impl Drop for Restore {
        fn drop(&mut self) {
            SINK.with(|s| *s.borrow_mut() = self.0.take());
        }
    }
    let prev = SINK.with(|s| s.replace(sink));
    let _restore = Restore(prev);
    f()
}

/// Observe into the thread's installed tracker; a no-op (one
/// thread-local read) when none is installed.
pub fn observe(module: &str, layer: usize, difficulty: f64, err: f64, plan: f64) {
    let sink = SINK.with(|s| s.borrow().clone());
    if let Some(t) = sink {
        t.observe(module, layer, difficulty, err, plan);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_mean_max_and_ewma() {
        let t = DifficultyTracker::new();
        t.observe("k_proj", 0, 2.0, 0.5, 1.5);
        t.observe("k_proj", 0, 4.0, 1.5, 1.5);
        let rows = t.rows();
        assert_eq!(rows.len(), 1);
        let c = rows[0].cell;
        assert_eq!(c.count, 2);
        assert_eq!(c.mean, 3.0);
        assert_eq!(c.max, 4.0);
        assert_eq!(c.err_mean, 1.0);
        assert_eq!(c.err_max, 1.5);
        // ewma seeded at 2.0, then pulled toward 4.0 by EWMA_ALPHA
        assert_eq!(c.ewma, 2.0 + EWMA_ALPHA * 2.0);
        assert_eq!(c.plan, 1.5);
        assert_eq!(c.drift(), 1.5);
    }

    #[test]
    fn cells_are_keyed_and_ordered() {
        let t = DifficultyTracker::new();
        t.observe("o_proj", 3, 1.0, 0.0, 1.0);
        t.observe("k_proj", 1, 1.0, 0.0, 1.0);
        t.observe("k_proj", 0, 1.0, 0.0, 1.0);
        let rows = t.rows();
        let keys: Vec<(&str, usize)> =
            rows.iter().map(|r| (r.module.as_str(), r.layer)).collect();
        assert_eq!(keys, vec![("k_proj", 0), ("k_proj", 1), ("o_proj", 3)]);
    }

    #[test]
    fn thread_local_observe_is_inert_without_a_sink() {
        let t = DifficultyTracker::new();
        observe("k_proj", 0, 9.0, 9.0, 9.0);
        assert!(t.rows().is_empty());
        with_sink(Some(Arc::clone(&t)), || observe("k_proj", 0, 9.0, 1.0, 8.0));
        assert_eq!(t.rows().len(), 1);
        observe("k_proj", 0, 9.0, 9.0, 9.0);
        assert_eq!(t.rows()[0].cell.count, 1, "sink must be restored after the scope");
    }

    #[test]
    fn mean_is_order_invariant_enough_for_snapshots() {
        // commutative-enough: the same multiset of observations from
        // different interleavings lands within float-fold tolerance
        let a = DifficultyTracker::new();
        let b = DifficultyTracker::new();
        let vals = [1.0, 2.5, 3.25, 0.5];
        for &v in &vals {
            a.observe("k_proj", 0, v, v, 1.0);
        }
        for &v in vals.iter().rev() {
            b.observe("k_proj", 0, v, v, 1.0);
        }
        let (ca, cb) = (a.rows()[0].cell, b.rows()[0].cell);
        assert!((ca.mean - cb.mean).abs() < 1e-12);
        assert_eq!(ca.max, cb.max);
        assert_eq!(ca.count, cb.count);
    }
}
