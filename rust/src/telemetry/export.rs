//! Snapshot model and exporters: one [`Snapshot`] struct, two
//! renderings.
//!
//! A snapshot is pure data — every registered counter / gauge /
//! histogram row plus the live difficulty cells — captured in
//! deterministic `(name, labels)` order.  Off that one struct:
//!
//! * [`Snapshot::to_json_string`] — a schema-versioned JSON artifact
//!   (same version-ceiling discipline as the calibration plan:
//!   [`Snapshot::parse`] rejects snapshots written by a newer schema),
//! * [`Snapshot::to_prometheus`] — Prometheus text exposition with
//!   `# TYPE` lines, stable label ordering and cumulative histogram
//!   buckets (`_bucket{le=...}` / `_sum` / `_count`),
//! * [`render_summary`] — the human serve summary, rendered *from* the
//!   snapshot rows so the printed lines and the exported files can
//!   never disagree,
//! * [`write_files`] — atomic tmp+rename persistence of both renderings
//!   (the JSON at the given path, the Prometheus text next to it with a
//!   `.prom` extension), the same write discipline as plan artifacts.

use std::path::{Path, PathBuf};

use crate::jsonio::{self, Json};
use crate::telemetry::difficulty::{Cell, DifficultyRow};
use crate::telemetry::registry::Labels;

/// Schema version written into every JSON snapshot.  Parsing rejects
/// snapshots from a *newer* schema (forward compatibility is explicit,
/// like `PLAN_SCHEMA_VERSION`).
pub const TELEMETRY_SCHEMA_VERSION: u32 = 1;

/// Artifact kind marker in the JSON snapshot.
pub const TELEMETRY_KIND: &str = "smoothrot-telemetry";

/// One counter's snapshot value.
#[derive(Clone, Debug, PartialEq)]
pub struct CounterRow {
    pub name: String,
    pub labels: Labels,
    pub value: u64,
}

/// One gauge's snapshot value.
#[derive(Clone, Debug, PartialEq)]
pub struct GaugeRow {
    pub name: String,
    pub labels: Labels,
    pub value: f64,
}

/// One histogram's snapshot state: upper bounds `le`, per-bucket
/// (non-cumulative) counts with the `+Inf` overflow last, and the
/// exact totals.
#[derive(Clone, Debug, PartialEq)]
pub struct HistogramRow {
    pub name: String,
    pub labels: Labels,
    pub le: Vec<f64>,
    pub counts: Vec<u64>,
    /// Sum of observations in seconds (exact integer nanoseconds under
    /// the hood).
    pub sum: f64,
    pub count: u64,
}

/// A deterministic point-in-time capture of every metric.
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    pub version: u32,
    pub counters: Vec<CounterRow>,
    pub gauges: Vec<GaugeRow>,
    pub histograms: Vec<HistogramRow>,
    pub difficulty: Vec<DifficultyRow>,
}

fn canon(labels: &[(&str, &str)]) -> Labels {
    let mut v: Labels = labels.iter().map(|(k, val)| (k.to_string(), val.to_string())).collect();
    v.sort();
    v
}

impl Snapshot {
    /// An empty snapshot at the current schema version.
    pub fn new() -> Snapshot {
        Snapshot {
            version: TELEMETRY_SCHEMA_VERSION,
            counters: Vec::new(),
            gauges: Vec::new(),
            histograms: Vec::new(),
            difficulty: Vec::new(),
        }
    }

    /// The counter value registered under `(name, labels)`.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Option<u64> {
        let labels = canon(labels);
        self.counters.iter().find(|r| r.name == name && r.labels == labels).map(|r| r.value)
    }

    /// The gauge value registered under `(name, labels)`.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        let labels = canon(labels);
        self.gauges.iter().find(|r| r.name == name && r.labels == labels).map(|r| r.value)
    }

    /// The first histogram row named `name`.
    pub fn histogram(&self, name: &str) -> Option<&HistogramRow> {
        self.histograms.iter().find(|r| r.name == name)
    }

    /// JSON value of the snapshot (schema-versioned, deterministic).
    pub fn to_json(&self) -> Json {
        fn labels_json(labels: &Labels) -> Json {
            Json::Obj(labels.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect())
        }
        let counters: Vec<Json> = self
            .counters
            .iter()
            .map(|r| {
                jsonio::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("labels", labels_json(&r.labels)),
                    ("value", Json::Num(r.value as f64)),
                ])
            })
            .collect();
        let gauges: Vec<Json> = self
            .gauges
            .iter()
            .map(|r| {
                jsonio::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("labels", labels_json(&r.labels)),
                    ("value", Json::Num(r.value)),
                ])
            })
            .collect();
        let histograms: Vec<Json> = self
            .histograms
            .iter()
            .map(|r| {
                jsonio::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("labels", labels_json(&r.labels)),
                    ("le", jsonio::num_arr(&r.le)),
                    (
                        "counts",
                        Json::Arr(r.counts.iter().map(|&c| Json::Num(c as f64)).collect()),
                    ),
                    ("sum", Json::Num(r.sum)),
                    ("count", Json::Num(r.count as f64)),
                ])
            })
            .collect();
        let difficulty: Vec<Json> = self
            .difficulty
            .iter()
            .map(|r| {
                jsonio::obj(vec![
                    ("module", Json::Str(r.module.clone())),
                    ("layer", Json::Num(r.layer as f64)),
                    ("count", Json::Num(r.cell.count as f64)),
                    ("mean", Json::Num(r.cell.mean)),
                    ("max", Json::Num(r.cell.max)),
                    ("ewma", Json::Num(r.cell.ewma)),
                    ("err_mean", Json::Num(r.cell.err_mean)),
                    ("err_max", Json::Num(r.cell.err_max)),
                    ("plan", Json::Num(r.cell.plan)),
                    ("drift", Json::Num(r.cell.drift())),
                ])
            })
            .collect();
        jsonio::obj(vec![
            ("version", Json::Num(self.version as f64)),
            ("kind", Json::Str(TELEMETRY_KIND.into())),
            ("counters", Json::Arr(counters)),
            ("gauges", Json::Arr(gauges)),
            ("histograms", Json::Arr(histograms)),
            ("difficulty", Json::Arr(difficulty)),
        ])
    }

    /// Pretty JSON text of [`Snapshot::to_json`].
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Parse a JSON snapshot, enforcing the schema-version ceiling: a
    /// snapshot written by a newer schema is an error, not a silent
    /// partial read (mirroring the calibration-plan artifact).
    pub fn parse(text: &str) -> Result<Snapshot, String> {
        let j = jsonio::parse(text).map_err(|e| format!("telemetry snapshot: {e}"))?;
        let version = j
            .get("version")
            .and_then(Json::as_u64)
            .ok_or("telemetry snapshot: missing or invalid version")?;
        if version == 0 {
            return Err("telemetry snapshot: version 0 is invalid".into());
        }
        if version > TELEMETRY_SCHEMA_VERSION as u64 {
            return Err(format!(
                "telemetry snapshot: version {version} is newer than supported \
                 {TELEMETRY_SCHEMA_VERSION}"
            ));
        }
        fn labels_of(j: &Json) -> Result<Labels, String> {
            match j.get("labels") {
                None => Ok(Vec::new()),
                Some(Json::Obj(fields)) => {
                    let mut out: Labels = fields
                        .iter()
                        .map(|(k, v)| {
                            v.as_str()
                                .map(|s| (k.clone(), s.to_string()))
                                .ok_or_else(|| format!("label {k}: expected string"))
                        })
                        .collect::<Result<_, String>>()?;
                    out.sort();
                    Ok(out)
                }
                Some(_) => Err("labels: expected object".into()),
            }
        }
        fn name_of(j: &Json) -> Result<String, String> {
            j.get("name")
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| "metric row: missing name".to_string())
        }
        let mut snap = Snapshot { version: version as u32, ..Snapshot::new() };
        for row in j.get("counters").and_then(Json::as_arr).unwrap_or(&[]) {
            snap.counters.push(CounterRow {
                name: name_of(row)?,
                labels: labels_of(row)?,
                value: row
                    .get("value")
                    .and_then(Json::as_u64)
                    .ok_or("counter row: missing value")?,
            });
        }
        for row in j.get("gauges").and_then(Json::as_arr).unwrap_or(&[]) {
            snap.gauges.push(GaugeRow {
                name: name_of(row)?,
                labels: labels_of(row)?,
                value: row.get("value").and_then(Json::as_f64).ok_or("gauge row: missing value")?,
            });
        }
        for row in j.get("histograms").and_then(Json::as_arr).unwrap_or(&[]) {
            let counts = row
                .get("counts")
                .and_then(Json::as_arr)
                .ok_or("histogram row: missing counts")?
                .iter()
                .map(|c| c.as_u64().ok_or("histogram count: expected integer".to_string()))
                .collect::<Result<Vec<u64>, String>>()?;
            snap.histograms.push(HistogramRow {
                name: name_of(row)?,
                labels: labels_of(row)?,
                le: row.get("le").and_then(Json::as_f64_vec).ok_or("histogram row: missing le")?,
                counts,
                sum: row.get("sum").and_then(Json::as_f64).ok_or("histogram row: missing sum")?,
                count: row
                    .get("count")
                    .and_then(Json::as_u64)
                    .ok_or("histogram row: missing count")?,
            });
        }
        for row in j.get("difficulty").and_then(Json::as_arr).unwrap_or(&[]) {
            let f = |key: &str| -> Result<f64, String> {
                row.get(key)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("difficulty row: missing {key}"))
            };
            snap.difficulty.push(DifficultyRow {
                module: row
                    .get("module")
                    .and_then(Json::as_str)
                    .ok_or("difficulty row: missing module")?
                    .to_string(),
                layer: row
                    .get("layer")
                    .and_then(Json::as_usize)
                    .ok_or("difficulty row: missing layer")?,
                cell: Cell {
                    count: row
                        .get("count")
                        .and_then(Json::as_u64)
                        .ok_or("difficulty row: missing count")?,
                    mean: f("mean")?,
                    max: f("max")?,
                    ewma: f("ewma")?,
                    err_mean: f("err_mean")?,
                    err_max: f("err_max")?,
                    plan: f("plan")?,
                },
            });
        }
        Ok(snap)
    }

    /// Prometheus text exposition: one `# TYPE` line per metric family,
    /// rows in snapshot (= sorted) order, histogram buckets cumulative
    /// with the `+Inf` bucket, label order stable.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last = String::new();
        for r in &self.counters {
            type_line(&mut out, &mut last, &r.name, "counter");
            out.push_str(&format!("{}{} {}\n", r.name, fmt_labels(&r.labels, None), r.value));
        }
        last.clear();
        for r in &self.gauges {
            type_line(&mut out, &mut last, &r.name, "gauge");
            out.push_str(&format!(
                "{}{} {}\n",
                r.name,
                fmt_labels(&r.labels, None),
                fmt_value(r.value)
            ));
        }
        last.clear();
        for r in &self.histograms {
            type_line(&mut out, &mut last, &r.name, "histogram");
            let mut cum = 0u64;
            for (i, &c) in r.counts.iter().enumerate() {
                cum += c;
                let le = match r.le.get(i) {
                    Some(b) => fmt_value(*b),
                    None => "+Inf".to_string(),
                };
                out.push_str(&format!(
                    "{}_bucket{} {cum}\n",
                    r.name,
                    fmt_labels(&r.labels, Some(("le", &le))),
                ));
            }
            out.push_str(&format!(
                "{}_sum{} {}\n",
                r.name,
                fmt_labels(&r.labels, None),
                fmt_value(r.sum)
            ));
            out.push_str(&format!("{}_count{} {}\n", r.name, fmt_labels(&r.labels, None), r.count));
        }
        // the live difficulty cells, flattened into gauge families
        let fams: [(&str, &str, fn(&Cell) -> f64); 7] = [
            ("smoothrot_live_difficulty", "gauge", |c| c.mean),
            ("smoothrot_live_difficulty_max", "gauge", |c| c.max),
            ("smoothrot_live_difficulty_ewma", "gauge", |c| c.ewma),
            ("smoothrot_plan_difficulty", "gauge", |c| c.plan),
            ("smoothrot_difficulty_drift", "gauge", |c| c.drift()),
            ("smoothrot_executed_error_mean", "gauge", |c| c.err_mean),
            ("smoothrot_executed_error_max", "gauge", |c| c.err_max),
        ];
        for (name, kind, pick) in fams {
            if self.difficulty.is_empty() {
                continue;
            }
            out.push_str(&format!("# TYPE {name} {kind}\n"));
            for r in &self.difficulty {
                let labels = vec![
                    ("layer".to_string(), r.layer.to_string()),
                    ("module".to_string(), r.module.clone()),
                ];
                out.push_str(&format!(
                    "{name}{} {}\n",
                    fmt_labels(&labels, None),
                    fmt_value(pick(&r.cell))
                ));
            }
        }
        if !self.difficulty.is_empty() {
            out.push_str("# TYPE smoothrot_difficulty_samples_total counter\n");
            for r in &self.difficulty {
                let labels = vec![
                    ("layer".to_string(), r.layer.to_string()),
                    ("module".to_string(), r.module.clone()),
                ];
                out.push_str(&format!(
                    "smoothrot_difficulty_samples_total{} {}\n",
                    fmt_labels(&labels, None),
                    r.cell.count
                ));
            }
        }
        out
    }
}

impl Default for Snapshot {
    fn default() -> Self {
        Snapshot::new()
    }
}

fn type_line(out: &mut String, last: &mut String, name: &str, kind: &str) {
    if last != name {
        out.push_str(&format!("# TYPE {name} {kind}\n"));
        *last = name.to_string();
    }
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn fmt_labels(labels: &Labels, extra: Option<(&str, &str)>) -> String {
    if labels.is_empty() && extra.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{}\"", escape_label(v)));
    }
    format!("{{{}}}", parts.join(","))
}

/// Prometheus sample value formatting: Rust's shortest-roundtrip
/// `Display` for finite values, the exposition-format spellings for the
/// rest.
fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".into()
    } else if v == f64::INFINITY {
        "+Inf".into()
    } else if v == f64::NEG_INFINITY {
        "-Inf".into()
    } else {
        v.to_string()
    }
}

/// One parsed Prometheus sample line.
#[derive(Clone, Debug, PartialEq)]
pub struct PromSample {
    pub name: String,
    pub labels: Labels,
    pub value: f64,
}

/// Minimal Prometheus text-format parser: enough to round-trip
/// [`Snapshot::to_prometheus`] output (comment lines skipped, labels
/// returned sorted).  Used by the telemetry proptests to pin that the
/// exposition is machine-readable, not just greppable.
pub fn parse_prometheus(text: &str) -> Result<Vec<PromSample>, String> {
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let err = |what: &str| format!("prometheus line {}: {what}: {line}", ln + 1);
        let (name, rest) = match line.find(['{', ' ']) {
            Some(i) => (line[..i].to_string(), &line[i..]),
            None => return Err(err("missing value")),
        };
        if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
            return Err(err("invalid metric name"));
        }
        let (labels, value_str) = if let Some(rest) = rest.strip_prefix('{') {
            let close = rest.find('}').ok_or_else(|| err("unterminated labels"))?;
            let mut labels: Labels = Vec::new();
            let body = &rest[..close];
            if !body.is_empty() {
                for pair in body.split(',') {
                    let (k, v) = pair.split_once('=').ok_or_else(|| err("bad label pair"))?;
                    let v = v
                        .strip_prefix('"')
                        .and_then(|v| v.strip_suffix('"'))
                        .ok_or_else(|| err("unquoted label value"))?;
                    let v = v.replace("\\n", "\n").replace("\\\"", "\"").replace("\\\\", "\\");
                    labels.push((k.trim().to_string(), v));
                }
            }
            labels.sort();
            (labels, rest[close + 1..].trim())
        } else {
            (Vec::new(), rest.trim())
        };
        let value = match value_str {
            "NaN" => f64::NAN,
            "+Inf" => f64::INFINITY,
            "-Inf" => f64::NEG_INFINITY,
            s => s.parse::<f64>().map_err(|_| err("bad sample value"))?,
        };
        out.push(PromSample { name, labels, value });
    }
    Ok(out)
}

/// The Prometheus sibling of a JSON snapshot path (`m.json` →
/// `m.prom`).
pub fn prom_path(path: &Path) -> PathBuf {
    path.with_extension("prom")
}

fn write_atomic(path: &Path, text: &str) -> Result<(), String> {
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, text).map_err(|e| format!("write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("rename {} -> {}: {e}", tmp.display(), path.display()))
}

/// Persist both renderings atomically (tmp + rename, the plan-artifact
/// discipline): the JSON snapshot at `path`, the Prometheus text at
/// [`prom_path`].  Returns the Prometheus path.
pub fn write_files(snap: &Snapshot, path: &Path) -> Result<PathBuf, String> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| format!("mkdir {}: {e}", dir.display()))?;
        }
    }
    write_atomic(path, &snap.to_json_string())?;
    let pp = prom_path(path);
    write_atomic(&pp, &snap.to_prometheus())?;
    Ok(pp)
}

fn parse_num_label(labels: &Labels, key: &str) -> Option<usize> {
    labels.iter().find(|(k, _)| k == key).and_then(|(_, v)| v.parse().ok())
}

/// Render the human serve summary **from** a snapshot — the exact
/// lines [`crate::serve::ServeMetrics::summary`] prints, sourced from
/// the same rows the exporters write, so the console and the exported
/// files cannot disagree.
pub fn render_summary(s: &Snapshot) -> String {
    let c = |name: &str| s.counter(name, &[]).unwrap_or(0);
    let completed = c("smoothrot_requests_completed_total");
    let wall_us = s.gauge("smoothrot_wall_microseconds", &[]).unwrap_or(0.0);
    let throughput =
        if wall_us <= 0.0 { 0.0 } else { completed as f64 / (wall_us / 1e6) };
    let batches = c("smoothrot_batches_total");
    let mean_batch = if batches == 0 { 0.0 } else { completed as f64 / batches as f64 };
    let hits = c("smoothrot_rotation_cache_hits_total");
    let misses = c("smoothrot_rotation_cache_misses_total");
    let hit_rate =
        if hits + misses == 0 { 0.0 } else { hits as f64 / (hits + misses) as f64 };
    let lat = |q: &str| {
        s.gauge("smoothrot_latency_microseconds", &[("quantile", q)]).unwrap_or(0.0)
    };
    let mut out = format!(
        "throughput {:.1} req/s | latency ms p50 {:.2} p95 {:.2} p99 {:.2} p999 {:.2}\n\
         batches {} (mean size {:.2}, max {}) | steals {} | rejected {} | errors {} | \
         rot-cache {} hit / {} miss ({:.0}%)\n",
        throughput,
        lat("p50") / 1e3,
        lat("p95") / 1e3,
        lat("p99") / 1e3,
        lat("p999") / 1e3,
        batches,
        mean_batch,
        s.gauge("smoothrot_batch_size_max", &[]).unwrap_or(0.0) as u64,
        c("smoothrot_steals_total"),
        c("smoothrot_requests_rejected_total"),
        c("smoothrot_request_errors_total"),
        hits,
        misses,
        100.0 * hit_rate,
    );
    // per-runner lines, in numeric runner order (label values are
    // strings, so "10" would sort before "2" lexically)
    let mut runners: Vec<usize> = s
        .counters
        .iter()
        .filter(|r| r.name == "smoothrot_runner_batches_total")
        .filter_map(|r| parse_num_label(&r.labels, "runner"))
        .collect();
    runners.sort_unstable();
    for i in runners {
        let id = i.to_string();
        let l: [(&str, &str); 1] = [("runner", &id)];
        let rc = |name: &str| s.counter(name, &l).unwrap_or(0);
        let rq = |q: &str| {
            s.gauge("smoothrot_runner_latency_microseconds", &[("quantile", q), ("runner", &id)])
                .unwrap_or(0.0)
        };
        out.push_str(&format!(
            "  runner {i}: routed {} batches {} steals {} | p50 {:.2} ms p95 {:.2} ms\n",
            rc("smoothrot_runner_routed_total"),
            rc("smoothrot_runner_batches_total"),
            rc("smoothrot_runner_steals_total"),
            rq("p50") / 1e3,
            rq("p95") / 1e3,
        ));
    }
    let mut tenants: Vec<usize> = s
        .counters
        .iter()
        .filter(|r| r.name == "smoothrot_tenant_submitted_total")
        .filter_map(|r| parse_num_label(&r.labels, "tenant"))
        .collect();
    tenants.sort_unstable();
    for t in tenants {
        let id = t.to_string();
        let l: [(&str, &str); 1] = [("tenant", &id)];
        out.push_str(&format!(
            "  tenant {t}: submitted {} completed {} rejected {}\n",
            s.counter("smoothrot_tenant_submitted_total", &l).unwrap_or(0),
            s.counter("smoothrot_tenant_completed_total", &l).unwrap_or(0),
            s.counter("smoothrot_tenant_rejected_total", &l).unwrap_or(0),
        ));
    }
    // wire front-end lines, only when the net collector registered
    // (in-process serving has no connection rows at all)
    if let Some(conns) = s.counter("smoothrot_net_connections_total", &[]) {
        out.push_str(&format!(
            "  net: conns {} (open {}, over-cap {}) | dropped {} partial {} slow {} read-timeout {}\n",
            conns,
            s.gauge("smoothrot_net_connections_open", &[]).unwrap_or(0.0) as i64,
            c("smoothrot_net_conn_rejected_total"),
            c("smoothrot_net_conn_dropped_total"),
            c("smoothrot_net_partial_write_total"),
            c("smoothrot_net_slow_client_total"),
            c("smoothrot_net_read_timeout_total"),
        ));
        // status taxonomy, non-zero rows only, in numeric order
        let mut statuses: Vec<(String, u64)> = s
            .counters
            .iter()
            .filter(|r| r.name == "smoothrot_net_responses_total" && r.value > 0)
            .filter_map(|r| {
                r.labels
                    .iter()
                    .find(|(k, _)| k == "status")
                    .map(|(_, v)| (v.clone(), r.value))
            })
            .collect();
        statuses.sort();
        if !statuses.is_empty() {
            let rendered: Vec<String> =
                statuses.iter().map(|(code, n)| format!("{code}:{n}")).collect();
            out.push_str(&format!("  net statuses: {}\n", rendered.join(" ")));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_snapshot() -> Snapshot {
        let mut s = Snapshot::new();
        s.counters.push(CounterRow { name: "a_total".into(), labels: vec![], value: 3 });
        s.counters.push(CounterRow {
            name: "b_total".into(),
            labels: vec![("tenant".into(), "1".into())],
            value: 7,
        });
        s.gauges.push(GaugeRow { name: "g".into(), labels: vec![], value: 1.25 });
        s.histograms.push(HistogramRow {
            name: "h_seconds".into(),
            labels: vec![],
            le: vec![0.001, 0.01],
            counts: vec![2, 1, 1],
            sum: 0.0155,
            count: 4,
        });
        s.difficulty.push(DifficultyRow {
            module: "k_proj".into(),
            layer: 0,
            cell: Cell {
                count: 5,
                mean: 2.0,
                max: 3.0,
                ewma: 2.1,
                err_mean: 0.5,
                err_max: 0.9,
                plan: 1.5,
            },
        });
        s
    }

    #[test]
    fn json_round_trips() {
        let s = sample_snapshot();
        let text = s.to_json_string();
        let back = Snapshot::parse(&text).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn newer_schema_versions_are_rejected() {
        let s = sample_snapshot();
        let text = s.to_json_string();
        let bumped = text.replacen(
            &format!("\"version\": {TELEMETRY_SCHEMA_VERSION}"),
            &format!("\"version\": {}", TELEMETRY_SCHEMA_VERSION + 1),
            1,
        );
        assert_ne!(text, bumped, "fixture must actually bump the version");
        let err = Snapshot::parse(&bumped).unwrap_err();
        assert!(err.contains("newer than supported"), "{err}");
        let zeroed = text.replacen(
            &format!("\"version\": {TELEMETRY_SCHEMA_VERSION}"),
            "\"version\": 0",
            1,
        );
        assert!(Snapshot::parse(&zeroed).is_err());
    }

    #[test]
    fn prometheus_has_type_lines_and_cumulative_buckets() {
        let text = sample_snapshot().to_prometheus();
        assert!(text.contains("# TYPE a_total counter"));
        assert!(text.contains("# TYPE h_seconds histogram"));
        assert!(text.contains("h_seconds_bucket{le=\"0.001\"} 2"));
        assert!(text.contains("h_seconds_bucket{le=\"0.01\"} 3"));
        assert!(text.contains("h_seconds_bucket{le=\"+Inf\"} 4"));
        assert!(text.contains("h_seconds_sum 0.0155"));
        assert!(text.contains("h_seconds_count 4"));
        assert!(text.contains("b_total{tenant=\"1\"} 7"));
        assert!(text.contains("smoothrot_live_difficulty{layer=\"0\",module=\"k_proj\"} 2"));
        assert!(text.contains("smoothrot_difficulty_drift{layer=\"0\",module=\"k_proj\"} 0.5"));
    }

    #[test]
    fn prometheus_parses_back() {
        let s = sample_snapshot();
        let samples = parse_prometheus(&s.to_prometheus()).unwrap();
        let find = |name: &str| samples.iter().find(|p| p.name == name).unwrap();
        assert_eq!(find("a_total").value, 3.0);
        assert_eq!(find("h_seconds_count").value, 4.0);
        let bucket_inf = samples
            .iter()
            .find(|p| {
                p.name == "h_seconds_bucket"
                    && p.labels.iter().any(|(k, v)| k == "le" && v == "+Inf")
            })
            .unwrap();
        assert_eq!(bucket_inf.value, 4.0);
        assert!(parse_prometheus("not a metric line !").is_err());
    }

    #[test]
    fn write_files_is_atomic_and_paired() {
        let dir = std::env::temp_dir().join("smoothrot_telemetry_export_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.json");
        let s = sample_snapshot();
        let pp = write_files(&s, &path).unwrap();
        assert_eq!(pp, dir.join("m.prom"));
        let back = Snapshot::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back, s);
        assert!(std::fs::read_to_string(&pp).unwrap().contains("# TYPE a_total counter"));
        assert!(!dir.join("m.tmp").exists(), "tmp file must be renamed away");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn render_summary_reads_the_snapshot_rows() {
        let mut s = Snapshot::new();
        let mut c = |name: &str, labels: Labels, v: u64| {
            s.counters.push(CounterRow { name: name.into(), labels, value: v })
        };
        c("smoothrot_requests_completed_total", vec![], 100);
        c("smoothrot_batches_total", vec![], 25);
        c("smoothrot_rotation_cache_hits_total", vec![], 9);
        c("smoothrot_rotation_cache_misses_total", vec![], 1);
        c("smoothrot_runner_batches_total", vec![("runner".into(), "0".into())], 25);
        c("smoothrot_runner_routed_total", vec![("runner".into(), "0".into())], 25);
        c("smoothrot_runner_steals_total", vec![("runner".into(), "0".into())], 0);
        c("smoothrot_tenant_submitted_total", vec![("tenant".into(), "2".into())], 100);
        c("smoothrot_tenant_completed_total", vec![("tenant".into(), "2".into())], 100);
        s.gauges.push(GaugeRow {
            name: "smoothrot_wall_microseconds".into(),
            labels: vec![],
            value: 2_000_000.0,
        });
        s.gauges.push(GaugeRow {
            name: "smoothrot_latency_microseconds".into(),
            labels: vec![("quantile".into(), "p50".into())],
            value: 1500.0,
        });
        let text = render_summary(&s);
        assert!(text.starts_with("throughput 50.0 req/s | latency ms p50 1.50"), "{text}");
        assert!(text.contains("batches 25 (mean size 4.00, max 0)"), "{text}");
        assert!(text.contains("rot-cache 9 hit / 1 miss (90%)"), "{text}");
        assert!(text.contains("  runner 0: routed 25 batches 25 steals 0"), "{text}");
        assert!(text.contains("  tenant 2: submitted 100 completed 100 rejected 0"), "{text}");
        // no net collector registered → no net lines at all
        assert!(!text.contains("net:"), "{text}");
    }

    #[test]
    fn render_summary_adds_net_lines_when_collector_present() {
        let mut s = Snapshot::new();
        let mut c = |name: &str, labels: Labels, v: u64| {
            s.counters.push(CounterRow { name: name.into(), labels, value: v })
        };
        c("smoothrot_net_connections_total", vec![], 12);
        c("smoothrot_net_conn_dropped_total", vec![], 2);
        c("smoothrot_net_responses_total", vec![("status".into(), "200".into())], 9);
        c("smoothrot_net_responses_total", vec![("status".into(), "429".into())], 3);
        // zero rows (present-at-zero taxonomy) must not clutter the line
        c("smoothrot_net_responses_total", vec![("status".into(), "504".into())], 0);
        s.gauges.push(GaugeRow {
            name: "smoothrot_net_connections_open".into(),
            labels: vec![],
            value: 1.0,
        });
        let text = render_summary(&s);
        assert!(
            text.contains("  net: conns 12 (open 1, over-cap 0) | dropped 2"),
            "{text}"
        );
        assert!(text.contains("  net statuses: 200:9 429:3\n"), "{text}");
        assert!(!text.contains("504"), "{text}");
    }
}
