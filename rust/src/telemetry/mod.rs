//! Serving telemetry: typed metric registry, per-stage hot-path timers,
//! live quantization-difficulty tracking, and snapshot exporters.
//!
//! The subsystem has one composition point, [`Telemetry`]:
//!
//! * a [`registry::Registry`] of counters / gauges / histograms,
//! * the six per-stage latency histograms ([`timers::StageTimers`]),
//! * the live per-(module, layer) difficulty tracker
//!   ([`difficulty::DifficultyTracker`]),
//! * **collectors** — closures that read externally-owned counters
//!   (e.g. [`crate::calib::registry::PlanRegistry`]'s atomics) into the
//!   snapshot at capture time, so existing subsystems keep their own
//!   state and the snapshot still sees everything.
//!
//! [`Telemetry::snapshot`] captures all of it into one
//! [`export::Snapshot`], off which every rendering hangs: Prometheus
//! text, schema-versioned JSON, and the human serve summary
//! ([`export::render_summary`]) — one source, three views, no drift
//! between them.
//!
//! Hot-path instrumentation stays out of band: kernels open stage spans
//! and the executor reports difficulty through thread-local sinks that
//! [`Telemetry::scope`] installs around a dispatch, so code that never
//! runs under telemetry pays one thread-local read per site.

pub mod difficulty;
pub mod export;
pub mod registry;
pub mod timers;

use std::sync::{Arc, Mutex};

pub use difficulty::DifficultyTracker;
pub use export::{render_summary, Snapshot, TELEMETRY_SCHEMA_VERSION};
pub use registry::Registry;
pub use timers::{Stage, StageTimers};

type Collector = Box<dyn Fn(&mut Snapshot) + Send + Sync>;

/// The composed telemetry subsystem: registry + stage timers +
/// difficulty tracker + snapshot collectors.
pub struct Telemetry {
    registry: Registry,
    timers: Arc<StageTimers>,
    difficulty: Arc<DifficultyTracker>,
    collectors: Mutex<Vec<Collector>>,
}

impl Telemetry {
    /// A fresh telemetry instance with the six stage histograms already
    /// registered.
    pub fn new() -> Arc<Telemetry> {
        let registry = Registry::new();
        let timers = StageTimers::new(&registry);
        Arc::new(Telemetry {
            registry,
            timers,
            difficulty: DifficultyTracker::new(),
            collectors: Mutex::new(Vec::new()),
        })
    }

    /// The metric registry (register serving counters/gauges here).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// The per-stage timers (installed as a thread-local sink by
    /// [`Telemetry::scope`]).
    pub fn timers(&self) -> &Arc<StageTimers> {
        &self.timers
    }

    /// The live difficulty tracker.
    pub fn difficulty(&self) -> &Arc<DifficultyTracker> {
        &self.difficulty
    }

    /// Register a snapshot collector: a closure run at every
    /// [`Telemetry::snapshot`] that appends rows read from
    /// externally-owned state.  Rows are re-sorted after collection, so
    /// collector registration order never shows in a snapshot.
    pub fn add_collector(&self, f: impl Fn(&mut Snapshot) + Send + Sync + 'static) {
        let mut guard = match self.collectors.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        guard.push(Box::new(f));
    }

    /// Capture everything into one deterministic [`Snapshot`]: registry
    /// rows, collector rows, difficulty cells — sorted by
    /// `(name, labels)` regardless of where a row came from.
    pub fn snapshot(&self) -> Snapshot {
        let mut snap = Snapshot::new();
        self.registry.snapshot_into(&mut snap);
        {
            let guard = match self.collectors.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            for c in guard.iter() {
                c(&mut snap);
            }
        }
        snap.counters.sort_by(|a, b| (&a.name, &a.labels).cmp(&(&b.name, &b.labels)));
        snap.gauges.sort_by(|a, b| (&a.name, &a.labels).cmp(&(&b.name, &b.labels)));
        snap.histograms.sort_by(|a, b| (&a.name, &a.labels).cmp(&(&b.name, &b.labels)));
        snap.difficulty = self.difficulty.rows();
        snap
    }

    /// Run `f` with this telemetry's stage-timer and difficulty sinks
    /// installed on the current thread (the serving worker wraps each
    /// executor dispatch in this).
    pub fn scope<R>(self: &Arc<Self>, f: impl FnOnce() -> R) -> R {
        timers::with_sink(Some(Arc::clone(&self.timers)), || {
            difficulty::with_sink(Some(Arc::clone(&self.difficulty)), f)
        })
    }
}

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Telemetry").field("registry", &self.registry).finish_non_exhaustive()
    }
}

/// Run `f` under `t`'s sinks when telemetry is on, plainly when off —
/// the one-liner call sites use so the disabled path stays branchless
/// beyond this check.
pub fn scoped<R>(t: Option<&Arc<Telemetry>>, f: impl FnOnce() -> R) -> R {
    match t {
        Some(t) => t.scope(f),
        None => f(),
    }
}

/// A snapshot collector reading [`PlanRegistry`]'s scattered atomic
/// counters (plan coverage, int8 execution, batch fusion, hot-reload
/// bookkeeping) into every snapshot, without moving their ownership.
///
/// [`PlanRegistry`]: crate::calib::registry::PlanRegistry
pub fn plan_registry_collector(
    reg: &Arc<crate::calib::registry::PlanRegistry>,
) -> impl Fn(&mut Snapshot) + Send + Sync + 'static {
    use export::{CounterRow, GaugeRow};
    let reg = Arc::clone(reg);
    move |snap: &mut Snapshot| {
        let (planned, fallback) = reg.stats();
        let (executed, degraded) = reg.int8_stats();
        let counters = [
            ("smoothrot_plan_planned_total", planned),
            ("smoothrot_plan_fallback_total", fallback),
            ("smoothrot_int8_executed_total", executed),
            ("smoothrot_int8_degraded_total", degraded),
            ("smoothrot_batch_fused_total", reg.batch_fused()),
            ("smoothrot_plan_reload_skipped_total", reg.reload_skipped_identical()),
            ("smoothrot_reload_failed", reg.reload_failed()),
            ("smoothrot_preload_degraded", reg.preload_degraded()),
        ];
        for (name, value) in counters {
            snap.counters.push(CounterRow { name: name.into(), labels: Vec::new(), value });
        }
        snap.gauges.push(GaugeRow {
            name: "smoothrot_plan_generation".into(),
            labels: Vec::new(),
            value: reg.generation() as f64,
        });
        snap.gauges.push(GaugeRow {
            name: "smoothrot_plan_entries".into(),
            labels: Vec::new(),
            value: reg.len() as f64,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_composes_registry_collectors_and_difficulty() {
        let t = Telemetry::new();
        t.registry().counter("zz_total", &[]).add(3);
        t.add_collector(|snap| {
            snap.counters.push(export::CounterRow {
                name: "aa_total".into(),
                labels: Vec::new(),
                value: 7,
            });
        });
        t.difficulty().observe("k_proj", 2, 1.5, 0.25, 1.0);
        let s = t.snapshot();
        assert_eq!(s.counter("zz_total", &[]), Some(3));
        assert_eq!(s.counter("aa_total", &[]), Some(7));
        // collector rows are merged into sorted order, not appended
        assert_eq!(s.counters[0].name, "aa_total");
        assert_eq!(s.difficulty.len(), 1);
        assert_eq!(s.difficulty[0].layer, 2);
        // the six stage histograms exist from birth
        for stage in Stage::ALL {
            assert!(s.histogram(stage.metric_name()).is_some(), "{}", stage.metric_name());
        }
    }

    #[test]
    fn scope_installs_both_sinks() {
        let t = Telemetry::new();
        t.scope(|| {
            drop(timers::span(Stage::Igemm));
            difficulty::observe("k_proj", 0, 2.0, 0.5, 1.5);
        });
        let s = t.snapshot();
        assert_eq!(s.histogram(Stage::Igemm.metric_name()).unwrap().count, 1);
        assert_eq!(s.difficulty.len(), 1);
        // outside the scope both sinks are gone
        drop(timers::span(Stage::Igemm));
        difficulty::observe("k_proj", 0, 9.0, 9.0, 9.0);
        let s2 = t.snapshot();
        assert_eq!(s2.histogram(Stage::Igemm.metric_name()).unwrap().count, 1);
        assert_eq!(s2.difficulty[0].cell.count, 1);
    }

    #[test]
    fn scoped_runs_plainly_without_telemetry() {
        assert_eq!(scoped(None, || 42), 42);
        let t = Telemetry::new();
        assert_eq!(scoped(Some(&t), || 42), 42);
    }

    #[test]
    fn plan_registry_counters_appear_in_snapshots() {
        use crate::calib::plan::{PlanEntry, Provenance, QuantPlan};
        use crate::transforms::Mode;
        let plan = QuantPlan {
            provenance: Provenance::default(),
            entries: vec![PlanEntry {
                module: "k_proj".into(),
                layer: 0,
                bits: 4,
                c_in: 8,
                mode: Mode::None,
                alpha: 0.5,
                predicted_error: 1.0,
                difficulty_before: 2.0,
                difficulty_after: 1.0,
                smooth: None,
            }],
        };
        let reg = Arc::new(crate::calib::registry::PlanRegistry::from_plan(&plan).unwrap());
        let t = Telemetry::new();
        t.add_collector(plan_registry_collector(&reg));
        reg.lookup("k_proj", 0, 4, 8).unwrap();
        reg.lookup("o_proj", 0, 4, 8);
        reg.note_int8(true);
        let s = t.snapshot();
        assert_eq!(s.counter("smoothrot_plan_planned_total", &[]), Some(1));
        assert_eq!(s.counter("smoothrot_plan_fallback_total", &[]), Some(1));
        assert_eq!(s.counter("smoothrot_int8_executed_total", &[]), Some(1));
        assert_eq!(s.gauge("smoothrot_plan_entries", &[]), Some(1.0));
    }
}
