//! Typed metric registry: counters, gauges and fixed-boundary
//! histograms behind stable `(name, labels)` keys.
//!
//! Three metric kinds, chosen to mirror the Prometheus data model so
//! the exporters ([`crate::telemetry::export`]) are a direct rendering:
//!
//! * [`Counter`] — monotonic `u64`, sharded across a fixed number of
//!   atomic cells so concurrent writers from different serving workers
//!   rarely contend on one cache line.  Reading sums the shards, so the
//!   value is **exact** and independent of how many threads wrote it —
//!   the worker-count-invariance property the snapshot tests pin.
//! * [`Gauge`] — a last-write-wins `f64` (stored as atomic bits).
//! * [`Histogram`] — fixed upper-bound buckets (log-scaled latency
//!   buckets by default, [`latency_buckets`]), per-shard atomic bucket
//!   counts, and a **sum kept in integer nanoseconds** so the total is
//!   an exact integer sum regardless of observation order or thread
//!   count — no float-accumulation nondeterminism in snapshots.
//!
//! Registration is get-or-create: asking for the same `(name, labels)`
//! twice returns the same `Arc`, so call sites never coordinate.  The
//! hot path touches only its own shard's atomics; the registry map lock
//! is taken at registration and snapshot time only.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::telemetry::export::{CounterRow, GaugeRow, HistogramRow, Snapshot};

/// Number of atomic shards per counter / histogram.  A small power of
/// two: enough that a handful of serving workers land on distinct
/// cells, cheap enough that snapshot sums stay trivial.
pub const SHARDS: usize = 16;

static NEXT_SHARD: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Each thread picks one shard index round-robin at first use and
    /// keeps it for life — writers spread out, reads stay exact sums.
    static MY_SHARD: usize = NEXT_SHARD.fetch_add(1, Ordering::Relaxed) % SHARDS;
}

fn my_shard() -> usize {
    MY_SHARD.with(|s| *s)
}

/// Monotonic counter, sharded across [`SHARDS`] atomic cells.
#[derive(Debug)]
pub struct Counter {
    shards: [AtomicU64; SHARDS],
}

impl Counter {
    /// A fresh zero counter (usually obtained via
    /// [`Registry::counter`]).
    pub fn new() -> Counter {
        Counter { shards: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    /// Add 1.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.shards[my_shard()].fetch_add(n, Ordering::Relaxed);
    }

    /// Exact total across all shards.  Integer addition commutes, so
    /// the result does not depend on which thread incremented what.
    pub fn value(&self) -> u64 {
        self.shards.iter().map(|s| s.load(Ordering::Relaxed)).sum()
    }
}

impl Default for Counter {
    fn default() -> Self {
        Counter::new()
    }
}

/// Last-write-wins `f64` gauge (atomic bit store).
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    /// A fresh zero gauge (usually obtained via [`Registry::gauge`]).
    pub fn new() -> Gauge {
        Gauge { bits: AtomicU64::new(0f64.to_bits()) }
    }

    /// Set the gauge.
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn value(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Fixed-boundary histogram of durations in seconds.
///
/// `bounds` are strictly increasing finite upper bounds; every
/// observation lands in the first bucket whose bound it does not
/// exceed, or the implicit `+Inf` overflow bucket.  Counts are sharded
/// like [`Counter`]; the sum is accumulated in integer **nanoseconds**
/// (one atomic add per observation), so bucket counts and the sum are
/// exact integer totals — deterministic at any worker count.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    /// `SHARDS` rows of `bounds.len() + 1` bucket cells (last = +Inf).
    counts: Vec<AtomicU64>,
    sum_ns: AtomicU64,
}

impl Histogram {
    /// A fresh histogram over `bounds` (usually obtained via
    /// [`Registry::histogram`]).  Non-increasing or non-finite bounds
    /// are rejected.
    pub fn new(bounds: &[f64]) -> Result<Histogram, String> {
        for w in bounds.windows(2) {
            if !(w[0] < w[1]) {
                return Err(format!("histogram bounds not increasing: {} then {}", w[0], w[1]));
            }
        }
        if bounds.iter().any(|b| !b.is_finite()) {
            return Err("histogram bounds must be finite (the +Inf bucket is implicit)".into());
        }
        let cells = SHARDS * (bounds.len() + 1);
        Ok(Histogram {
            bounds: bounds.to_vec(),
            counts: (0..cells).map(|_| AtomicU64::new(0)).collect(),
            sum_ns: AtomicU64::new(0),
        })
    }

    /// Upper bucket bounds (without the implicit `+Inf`).
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Record a duration given in integer nanoseconds.
    pub fn observe_ns(&self, ns: u64) {
        let secs = ns as f64 / 1e9;
        let bucket = self.bounds.partition_point(|&b| b < secs);
        let row = my_shard() * (self.bounds.len() + 1);
        self.counts[row + bucket].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record a duration in seconds (converted to whole nanoseconds;
    /// negative or non-finite observations count as zero time).
    pub fn observe_secs(&self, secs: f64) {
        let ns = if secs.is_finite() && secs > 0.0 { (secs * 1e9).round() as u64 } else { 0 };
        self.observe_ns(ns);
    }

    /// Per-bucket counts (length `bounds.len() + 1`; last = +Inf), the
    /// exact shard-summed totals.
    pub fn bucket_counts(&self) -> Vec<u64> {
        let width = self.bounds.len() + 1;
        let mut out = vec![0u64; width];
        for (i, c) in self.counts.iter().enumerate() {
            out[i % width] += c.load(Ordering::Relaxed);
        }
        out
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.bucket_counts().iter().sum()
    }

    /// Exact nanosecond total of all observations.
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Ordering::Relaxed)
    }

    /// Sum in seconds (`sum_ns / 1e9`).
    pub fn sum_secs(&self) -> f64 {
        self.sum_ns() as f64 / 1e9
    }
}

/// Log-scaled latency bucket bounds: powers of two from 1 µs to ~8 s
/// (24 buckets plus the implicit `+Inf` overflow).
pub fn latency_buckets() -> Vec<f64> {
    (0..24).map(|k| 1e-6 * (1u64 << k) as f64).collect()
}

/// Sorted label pairs — the canonical half of a metric key.
pub type Labels = Vec<(String, String)>;

fn canon_labels(labels: &[(&str, &str)]) -> Labels {
    let mut v: Labels =
        labels.iter().map(|(k, val)| (k.to_string(), val.to_string())).collect();
    v.sort();
    v
}

type Key = (String, Labels);

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// Typed metric registry: get-or-create handles keyed by
/// `(name, sorted labels)`, snapshotted in deterministic order.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<std::collections::BTreeMap<Key, Arc<Counter>>>,
    gauges: Mutex<std::collections::BTreeMap<Key, Arc<Gauge>>>,
    hists: Mutex<std::collections::BTreeMap<Key, Arc<Histogram>>>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// The counter registered under `(name, labels)` (created on first
    /// use).
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let key = (name.to_string(), canon_labels(labels));
        Arc::clone(lock(&self.counters).entry(key).or_insert_with(|| Arc::new(Counter::new())))
    }

    /// The gauge registered under `(name, labels)`.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let key = (name.to_string(), canon_labels(labels));
        Arc::clone(lock(&self.gauges).entry(key).or_insert_with(|| Arc::new(Gauge::new())))
    }

    /// The histogram registered under `(name, labels)`.  The first
    /// registration fixes the bucket bounds; later calls return the
    /// existing histogram regardless of the bounds they pass (one
    /// metric name = one bucket layout, as in Prometheus).
    pub fn histogram(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        bounds: &[f64],
    ) -> Result<Arc<Histogram>, String> {
        let key = (name.to_string(), canon_labels(labels));
        let mut map = lock(&self.hists);
        if let Some(h) = map.get(&key) {
            return Ok(Arc::clone(h));
        }
        let h = Arc::new(Histogram::new(bounds)?);
        map.insert(key, Arc::clone(&h));
        Ok(h)
    }

    /// Append every registered metric's current value to `snap`, in
    /// `(name, labels)` order.  Deterministic: the map is ordered and
    /// every value is an exact shard sum (or a single gauge cell).
    pub fn snapshot_into(&self, snap: &mut Snapshot) {
        for ((name, labels), c) in lock(&self.counters).iter() {
            snap.counters.push(CounterRow {
                name: name.clone(),
                labels: labels.clone(),
                value: c.value(),
            });
        }
        for ((name, labels), g) in lock(&self.gauges).iter() {
            snap.gauges.push(GaugeRow {
                name: name.clone(),
                labels: labels.clone(),
                value: g.value(),
            });
        }
        for ((name, labels), h) in lock(&self.hists).iter() {
            snap.histograms.push(HistogramRow {
                name: name.clone(),
                labels: labels.clone(),
                le: h.bounds().to_vec(),
                counts: h.bucket_counts(),
                sum: h.sum_secs(),
                count: h.count(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_sums_exactly_across_threads() {
        let reg = Registry::new();
        let c = reg.counter("reqs_total", &[]);
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.value(), 4000);
        // get-or-create: same key, same cell
        reg.counter("reqs_total", &[]).add(5);
        assert_eq!(c.value(), 4005);
    }

    #[test]
    fn labels_are_canonicalized() {
        let reg = Registry::new();
        let a = reg.counter("x", &[("b", "2"), ("a", "1")]);
        let b = reg.counter("x", &[("a", "1"), ("b", "2")]);
        a.inc();
        assert_eq!(b.value(), 1, "label order must not split the key");
    }

    #[test]
    fn histogram_buckets_and_sum_are_exact() {
        let h = Histogram::new(&[0.001, 0.01, 0.1]).unwrap();
        h.observe_secs(0.0005); // bucket 0
        h.observe_secs(0.005); // bucket 1
        h.observe_secs(0.05); // bucket 2
        h.observe_secs(5.0); // +Inf
        h.observe_ns(1_000_000); // exactly 1ms -> bucket 0 (le is inclusive)
        assert_eq!(h.bucket_counts(), vec![2, 1, 1, 1]);
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum_ns(), 500_000 + 5_000_000 + 50_000_000 + 5_000_000_000 + 1_000_000);
    }

    #[test]
    fn histogram_rejects_bad_bounds() {
        assert!(Histogram::new(&[1.0, 1.0]).is_err());
        assert!(Histogram::new(&[2.0, 1.0]).is_err());
        assert!(Histogram::new(&[f64::INFINITY]).is_err());
        assert!(Histogram::new(&[]).is_ok(), "a single +Inf bucket is legal");
    }

    #[test]
    fn latency_buckets_are_log_scaled_and_increasing() {
        let b = latency_buckets();
        assert_eq!(b.len(), 24);
        assert_eq!(b[0], 1e-6);
        for w in b.windows(2) {
            assert_eq!(w[1], w[0] * 2.0);
        }
    }

    #[test]
    fn snapshot_order_is_deterministic() {
        let reg = Registry::new();
        reg.counter("z", &[]).inc();
        reg.counter("a", &[("t", "1")]).add(2);
        reg.gauge("g", &[]).set(1.5);
        reg.histogram("h", &[], &[0.1]).unwrap().observe_secs(0.05);
        let mut s1 = Snapshot::new();
        reg.snapshot_into(&mut s1);
        let mut s2 = Snapshot::new();
        reg.snapshot_into(&mut s2);
        assert_eq!(s1, s2);
        assert_eq!(s1.counters[0].name, "a");
        assert_eq!(s1.counters[1].name, "z");
        assert_eq!(s1.histograms[0].counts, vec![1, 0]);
    }
}
