//! Per-stage hot-path timers: split batch execution into admission-wait
//! / batch-form / transform / quantize / igemm / postprocess spans.
//!
//! The kernel code ([`crate::kernels::fused`]) never sees a telemetry
//! handle — it opens spans through a **thread-local sink** that the
//! serving worker installs around each executor dispatch
//! ([`with_sink`], mirroring the `simd::with_backend` /
//! `par::with_pool` scoping idiom).  When no sink is installed,
//! [`span`] returns an inert guard without ever calling
//! `Instant::now()` — the disabled cost is one thread-local read and a
//! branch, which is what lets telemetry-off serving stay within the
//! <2% overhead budget pinned by the
//! `serve_plan_int8_telemetry_on_vs_off_96req` bench scenario.
//!
//! Spans are recorded on the thread that opened them (the batch
//! orchestration thread); row-parallel pool workers only execute row
//! chunks *inside* a span, so each stage's wall time is attributed
//! exactly once per dispatch.

use std::cell::RefCell;
use std::sync::Arc;
use std::time::Instant;

use crate::telemetry::registry::{latency_buckets, Histogram, Registry};

/// The six execution stages a served batch passes through.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Admission to dispatch: how long a job waited in its tenant queue
    /// and the scheduler ring before a worker picked its batch up.
    AdmissionWait,
    /// Forming one coalesced batch inside the scheduler.
    BatchForm,
    /// Plan transform of the activation rows (Eq. 4 scaling + Eq. 3/5
    /// rotation).
    Transform,
    /// Per-token quantization onto integer grids (Eq. 1).
    Quantize,
    /// The integer GEMM itself.
    Igemm,
    /// Reference product, executed-error and difficulty folds after the
    /// GEMM.
    Postprocess,
}

impl Stage {
    /// All stages, in pipeline order.
    pub const ALL: [Stage; 6] = [
        Stage::AdmissionWait,
        Stage::BatchForm,
        Stage::Transform,
        Stage::Quantize,
        Stage::Igemm,
        Stage::Postprocess,
    ];

    /// Index into [`StageTimers`]'s histogram array.
    fn index(self) -> usize {
        match self {
            Stage::AdmissionWait => 0,
            Stage::BatchForm => 1,
            Stage::Transform => 2,
            Stage::Quantize => 3,
            Stage::Igemm => 4,
            Stage::Postprocess => 5,
        }
    }

    /// The exported metric name of this stage's histogram.
    pub fn metric_name(self) -> &'static str {
        match self {
            Stage::AdmissionWait => "smoothrot_admission_wait_seconds",
            Stage::BatchForm => "smoothrot_batch_form_seconds",
            Stage::Transform => "smoothrot_transform_seconds",
            Stage::Quantize => "smoothrot_quantize_seconds",
            Stage::Igemm => "smoothrot_igemm_seconds",
            Stage::Postprocess => "smoothrot_postprocess_seconds",
        }
    }
}

/// One latency histogram per [`Stage`], registered into the owning
/// [`Registry`] so snapshots and exporters see them like any other
/// metric.
#[derive(Debug)]
pub struct StageTimers {
    hists: [Arc<Histogram>; 6],
}

impl StageTimers {
    /// Register the six stage histograms (log-scaled latency buckets)
    /// into `reg`.
    pub fn new(reg: &Registry) -> Arc<StageTimers> {
        let bounds = latency_buckets();
        let hists = Stage::ALL.map(|s| {
            reg.histogram(s.metric_name(), &[], &bounds)
                .expect("latency_buckets are valid histogram bounds")
        });
        Arc::new(StageTimers { hists })
    }

    /// Record one `stage` span of `ns` nanoseconds.
    pub fn record_ns(&self, stage: Stage, ns: u64) {
        self.hists[stage.index()].observe_ns(ns);
    }

    /// The histogram backing `stage` (snapshot assertions in tests).
    pub fn histogram(&self, stage: Stage) -> &Histogram {
        &self.hists[stage.index()]
    }
}

thread_local! {
    static SINK: RefCell<Option<Arc<StageTimers>>> = const { RefCell::new(None) };
}

/// Run `f` with `sink` installed as this thread's span destination,
/// restoring the previous sink afterwards (panic-safe via the guard's
/// `Drop`).  `None` explicitly disables span recording for the scope.
pub fn with_sink<R>(sink: Option<Arc<StageTimers>>, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<Arc<StageTimers>>);
    impl Drop for Restore {
        fn drop(&mut self) {
            SINK.with(|s| *s.borrow_mut() = self.0.take());
        }
    }
    let prev = SINK.with(|s| s.replace(sink));
    let _restore = Restore(prev);
    f()
}

/// An open stage span; records its wall time into the installed sink on
/// drop.  Inert (no clock read at open *or* close) when no sink was
/// installed at open time.
pub struct Span {
    open: Option<(Stage, Arc<StageTimers>, Instant)>,
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((stage, sink, t0)) = self.open.take() {
            let ns = u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX);
            sink.record_ns(stage, ns);
        }
    }
}

/// Open a span for `stage` against the thread's installed sink.  The
/// disabled path is one thread-local read — no `Instant::now()`.
pub fn span(stage: Stage) -> Span {
    let open = SINK.with(|s| {
        s.borrow().as_ref().map(|sink| (stage, Arc::clone(sink), Instant::now()))
    });
    Span { open }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_record_only_under_a_sink() {
        let reg = Registry::new();
        let timers = StageTimers::new(&reg);
        // no sink installed: inert guard
        drop(span(Stage::Igemm));
        assert_eq!(timers.histogram(Stage::Igemm).count(), 0);
        with_sink(Some(Arc::clone(&timers)), || {
            drop(span(Stage::Igemm));
            drop(span(Stage::Transform));
        });
        assert_eq!(timers.histogram(Stage::Igemm).count(), 1);
        assert_eq!(timers.histogram(Stage::Transform).count(), 1);
        // the scope restored the previous (absent) sink
        drop(span(Stage::Igemm));
        assert_eq!(timers.histogram(Stage::Igemm).count(), 1);
    }

    #[test]
    fn sinks_nest_and_restore() {
        let reg = Registry::new();
        let outer = StageTimers::new(&reg);
        let reg2 = Registry::new();
        let inner = StageTimers::new(&reg2);
        with_sink(Some(Arc::clone(&outer)), || {
            with_sink(Some(Arc::clone(&inner)), || drop(span(Stage::Quantize)));
            drop(span(Stage::Quantize));
        });
        assert_eq!(inner.histogram(Stage::Quantize).count(), 1);
        assert_eq!(outer.histogram(Stage::Quantize).count(), 1);
    }

    #[test]
    fn every_stage_has_a_distinct_metric_name() {
        let names: std::collections::BTreeSet<_> =
            Stage::ALL.iter().map(|s| s.metric_name()).collect();
        assert_eq!(names.len(), 6);
        assert!(names.iter().all(|n| n.starts_with("smoothrot_") && n.ends_with("_seconds")));
    }

    #[test]
    fn recorded_time_lands_in_sum() {
        let reg = Registry::new();
        let timers = StageTimers::new(&reg);
        timers.record_ns(Stage::BatchForm, 2_500_000);
        timers.record_ns(Stage::BatchForm, 500_000);
        assert_eq!(timers.histogram(Stage::BatchForm).sum_ns(), 3_000_000);
        assert_eq!(timers.histogram(Stage::BatchForm).count(), 2);
    }
}
